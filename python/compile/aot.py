"""AOT compiler: lower the L2/L1 graphs to HLO text artifacts.

Run once via ``make artifacts``. Python never appears on the training
hot path: the Rust coordinator loads ``artifacts/*.hlo.txt`` through the
PJRT C API (`xla` crate).

Interchange format is **HLO text**, not serialized HloModuleProto: the
published xla crate binds xla_extension 0.5.1, which rejects jax≥0.5's
64-bit instruction ids; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --out-dir ../artifacts [--models tiny,e2e]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels import topk as topk_kernels


def to_hlo_text(lowered) -> str:
    """jax lowered -> XlaComputation -> HLO text (return_tuple=True: the
    Rust side unwraps with to_tuple)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_and_save(fn, example_args, name, out_dir, meta, attrs=None):
    lowered = jax.jit(fn).lower(*example_args)
    text = to_hlo_text(lowered)
    path = os.path.join(out_dir, f"{name}.hlo.txt")
    with open(path, "w") as f:
        f.write(text)

    def shape_of(x):
        return list(x.shape)

    out_tree = jax.eval_shape(fn, *example_args)
    outputs = [shape_of(o) for o in jax.tree_util.tree_leaves(out_tree)]
    meta[name] = {
        "inputs": [shape_of(a) for a in example_args],
        "outputs": outputs,
        "attrs": attrs or {},
    }
    print(f"  {name}: {len(text) / 1e6:.2f} MB HLO text, "
          f"{len(meta[name]['inputs'])} in / {len(outputs)} out")


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def build_model_artifacts(name, cfg, out_dir, meta):
    print(f"model '{name}': {model.num_params(cfg):,} params "
          f"(E={cfg.num_experts}, d={cfg.d_model}, L={cfg.n_layers})")
    n = len(model.param_spec(cfg))
    state_specs = [spec(s) for _, s in model.param_spec(cfg)]
    state_specs = state_specs + state_specs + state_specs + [spec(())]

    # init: seed (i32 scalar) -> flat state tuple.
    init = model.init_fn_seeded(cfg)
    lower_and_save(
        init,
        [spec((), jnp.int32)],
        f"{name}_init",
        out_dir,
        meta,
        attrs={"num_params": model.num_params(cfg), "tensors": 3 * n + 1},
    )

    # step: (state..., tokens, targets) -> (state..., loss).
    def step(*args):
        state = list(args[:-2])
        return model.train_step(cfg, state, args[-2], args[-1])

    tok = spec((cfg.batch, cfg.seq), jnp.int32)
    lower_and_save(
        step,
        state_specs + [tok, tok],
        f"{name}_step",
        out_dir,
        meta,
        attrs={
            "vocab": cfg.vocab,
            "batch": cfg.batch,
            "seq": cfg.seq,
            "lr": cfg.lr,
            "num_params": model.num_params(cfg),
            "d_model": cfg.d_model,
            "num_experts": cfg.num_experts,
        },
    )


def build_piece_artifacts(out_dir, meta):
    """Piecewise graphs for the Rust expert-parallel pipeline + the
    standalone L1 kernel artifact."""
    d, e, h, cap, t = 256, 16, 512, 128, 1024

    lower_and_save(
        model.gate_scores_fn,
        [spec((t, d)), spec((d, e))],
        "gate_scores",
        out_dir,
        meta,
        attrs={"num_experts": e, "d_model": d},
    )
    lower_and_save(
        model.expert_ffn_fn,
        [spec((cap, d)), spec((d, h)), spec((h,)), spec((h, d)), spec((d,))],
        "expert_ffn",
        out_dir,
        meta,
        attrs={"ffn_hidden": h, "d_model": d, "capacity": cap},
    )

    # Standalone Pallas top-1 kernel (indices cast to f32 so the Rust
    # Tensor type can carry them).
    def top1_f32(scores):
        vals, idx = topk_kernels.top1(scores)
        return vals, idx.astype(jnp.float32)

    lower_and_save(
        top1_f32,
        [spec((t, e))],
        "top1_pallas",
        out_dir,
        meta,
        attrs={"num_experts": e, "block_t": topk_kernels.BLOCK_T},
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--models", default="tiny,e2e",
                    help="comma list from {tiny,e2e}; empty to skip")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    meta = {}
    build_piece_artifacts(args.out_dir, meta)
    for name in [m for m in args.models.split(",") if m]:
        build_model_artifacts(name, model.CONFIGS[name], args.out_dir, meta)
    meta_path = os.path.join(args.out_dir, "meta.json")
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=1)
    print(f"wrote {meta_path} ({len(meta)} artifacts)")


if __name__ == "__main__":
    main()
