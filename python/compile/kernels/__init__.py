"""Layer-1 Pallas kernels (build-time only; lowered with interpret=True)."""
