"""Pallas Gumbel-softmax kernel (Dense-to-Sparse gate, Nie et al. 2021).

Elementwise + row-reduction kernel: ``softmax((log_softmax(s) + g)/tau)``
over VMEM row blocks. The Gumbel noise is supplied as an input (sampled
with jax.random outside) so the kernel stays deterministic and testable.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 128


def _gumbel_softmax_kernel(s_ref, g_ref, out_ref, *, tau):
    s = s_ref[...]
    g = g_ref[...]
    logp = jax.nn.log_softmax(s, axis=-1)
    out_ref[...] = jax.nn.softmax((logp + g) / tau, axis=-1)


def gumbel_softmax(scores, gumbel_noise, tau):
    """scores, gumbel_noise: [T, E] -> soft routing weights [T, E]."""
    assert scores.shape == gumbel_noise.shape
    t, e = scores.shape
    pt = -(-t // BLOCK_T) * BLOCK_T
    if pt != t:
        pad = ((0, pt - t), (0, 0))
        scores = jnp.pad(scores, pad)
        gumbel_noise = jnp.pad(gumbel_noise, pad)
    grid = (pt // BLOCK_T,)
    out = pl.pallas_call(
        functools.partial(_gumbel_softmax_kernel, tau=float(tau)),
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_T, e), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_T, e), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_T, e), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((pt, e), scores.dtype),
        interpret=True,
    )(scores, gumbel_noise)
    return out[:t]


def tau_schedule(step, tau0=2.0, tau_min=0.1, anneal_steps=10_000):
    """Exponential temperature annealing (matches the Rust gate)."""
    frac = jnp.minimum(step, anneal_steps) / anneal_steps
    return tau0 * (tau_min / tau0) ** frac
