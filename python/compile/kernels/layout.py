"""Pallas layout-transform (dispatch/combine) kernels (paper Fig 4).

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the CUDA layout
transform is an atomics + scatter kernel; scatters are hostile to the
TPU. Instead we express dispatch as the GShard-style **one-hot matmul**
``out[S, d] = onehot[T, S]^T · x[T, d]`` which maps directly onto the
MXU systolic array, tiled so each grid step contracts a (BLOCK_T)-token
panel. Combine is the transpose matmul, scaled by the gate weights.

The one-hot matrix is built from the same first-come-first-served
capacity positions the Rust coordinator computes (``ref.py``'s
``ref_capacity_positions`` is the shared specification).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK_T = 128
BLOCK_S = 128
BLOCK_D = 128


def _dispatch_kernel(oh_ref, x_ref, out_ref):
    """One grid step: out[bs, bd] += onehot[bt, bs]^T @ x[bt, bd]."""
    t_idx = pl.program_id(2)
    oh = oh_ref[...]  # [bt, bs]
    x = x_ref[...]  # [bt, bd]
    acc = jnp.dot(oh.T, x, preferred_element_type=jnp.float32)

    @pl.when(t_idx == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(t_idx > 0)
    def _acc():
        out_ref[...] += acc


def _pad_to(x, mult, axis, value=0.0):
    size = x.shape[axis]
    target = -(-size // mult) * mult
    if target == size:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, target - size)
    return jnp.pad(x, pads, constant_values=value)


def dispatch(x, onehot):
    """Tiled MXU dispatch: x [T, d], onehot [T, S] -> out [S, d]."""
    t, d = x.shape
    s = onehot.shape[1]
    xp = _pad_to(_pad_to(x, BLOCK_T, 0), BLOCK_D, 1)
    ohp = _pad_to(_pad_to(onehot, BLOCK_T, 0), BLOCK_S, 1)
    pt, pd = xp.shape
    ps = ohp.shape[1]
    grid = (ps // BLOCK_S, pd // BLOCK_D, pt // BLOCK_T)
    out = pl.pallas_call(
        _dispatch_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_T, BLOCK_S), lambda i, j, k: (k, i)),
            pl.BlockSpec((BLOCK_T, BLOCK_D), lambda i, j, k: (k, j)),
        ],
        out_specs=pl.BlockSpec((BLOCK_S, BLOCK_D), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ps, pd), jnp.float32),
        interpret=True,
    )(ohp, xp)
    return out[:s, :d]


def _combine_kernel(oh_ref, buf_ref, w_ref, out_ref):
    s_idx = pl.program_id(2)
    oh = oh_ref[...]  # [bt, bs]
    buf = buf_ref[...]  # [bs, bd]
    w = w_ref[...]  # [bt, 1]
    acc = jnp.dot(oh, buf, preferred_element_type=jnp.float32) * w

    @pl.when(s_idx == 0)
    def _init():
        out_ref[...] = acc

    @pl.when(s_idx > 0)
    def _acc():
        out_ref[...] += acc


def combine(buf, onehot, weights):
    """Tiled MXU combine: buf [S, d], onehot [T, S], weights [T] -> [T, d]."""
    s, d = buf.shape
    t = onehot.shape[0]
    bufp = _pad_to(_pad_to(buf, BLOCK_S, 0), BLOCK_D, 1)
    ohp = _pad_to(_pad_to(onehot, BLOCK_T, 0), BLOCK_S, 1)
    wp = _pad_to(weights[:, None], BLOCK_T, 0)
    pt = ohp.shape[0]
    ps, pd = bufp.shape
    grid = (pt // BLOCK_T, pd // BLOCK_D, ps // BLOCK_S)
    out = pl.pallas_call(
        _combine_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((BLOCK_T, BLOCK_S), lambda i, j, k: (i, k)),
            pl.BlockSpec((BLOCK_S, BLOCK_D), lambda i, j, k: (k, j)),
            pl.BlockSpec((BLOCK_T, 1), lambda i, j, k: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_T, BLOCK_D), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((pt, pd), jnp.float32),
        interpret=True,
    )(ohp, bufp, wp)
    return out[:t, :d]


def vmem_bytes(dtype_bytes=4):
    """Static per-step VMEM estimate for the dispatch kernel blocks."""
    return (
        BLOCK_T * BLOCK_S  # onehot block
        + BLOCK_T * BLOCK_D  # x block
        + BLOCK_S * BLOCK_D  # out accumulator
    ) * dtype_bytes
