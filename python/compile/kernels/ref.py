"""Pure-jnp reference oracles for every Pallas kernel.

These are the ground truth the L1 kernels are tested against (pytest +
hypothesis in ``python/tests``). They are also what the kernels lower to
semantically — a Pallas kernel that disagrees with its oracle is a bug,
full stop.
"""

import jax
import jax.numpy as jnp


def ref_top1(scores):
    """Top-1 over the expert axis. scores: [T, E] -> (vals [T], idx [T])."""
    idx = jnp.argmax(scores, axis=-1)
    vals = jnp.max(scores, axis=-1)
    return vals, idx.astype(jnp.int32)


def ref_top2(scores):
    """Top-2 (vals [T,2] desc, idx [T,2]); ties resolve to smaller index."""
    vals, idx = jax.lax.top_k(scores, 2)
    return vals, idx.astype(jnp.int32)


def ref_topk(scores, k):
    """Generic top-k via lax.top_k."""
    vals, idx = jax.lax.top_k(scores, k)
    return vals, idx.astype(jnp.int32)


def ref_softmax(x):
    return jax.nn.softmax(x, axis=-1)


def ref_dispatch(x, onehot):
    """Dispatch tokens into expert slots: onehot [T, S] (S = E*C slots),
    x [T, d] -> out [S, d] = onehot^T @ x."""
    return jnp.einsum("ts,td->sd", onehot, x)


def ref_combine(buf, onehot, weights):
    """Combine expert outputs back per token:
    buf [S, d], onehot [T, S], weights [T] -> out [T, d]."""
    return weights[:, None] * jnp.einsum("ts,sd->td", onehot, buf)


def ref_gumbel_softmax(scores, key, tau):
    """Gumbel-softmax sample at temperature tau. scores [T, E]."""
    g = jax.random.gumbel(key, scores.shape, dtype=scores.dtype)
    logp = jax.nn.log_softmax(scores, axis=-1)
    return jax.nn.softmax((logp + g) / tau, axis=-1)


def ref_capacity_positions(expert_idx, num_experts, capacity):
    """First-come-first-served capacity assignment (matches the Rust
    ``apply_capacity``): returns destination slot per token, -1 if
    dropped. expert_idx: [T] int32."""
    onehot = jax.nn.one_hot(expert_idx, num_experts, dtype=jnp.int32)
    pos_within = jnp.cumsum(onehot, axis=0) - 1  # [T, E]
    pos = jnp.take_along_axis(pos_within, expert_idx[:, None], axis=1)[:, 0]
    dest = expert_idx * capacity + pos
    return jnp.where(pos < capacity, dest, -1).astype(jnp.int32)


def make_onehot(dest, num_slots):
    """Build the [T, S] dispatch one-hot from destination slots
    (-1 = dropped)."""
    t = dest.shape[0]
    rows = jnp.arange(t)
    valid = dest >= 0
    oh = jnp.zeros((t, num_slots), dtype=jnp.float32)
    return oh.at[rows, jnp.clip(dest, 0)].set(jnp.where(valid, 1.0, 0.0))
