"""Pallas top-k gating kernels (paper §3.2 "Gate Optimization", Fig 3).

TPU re-expression of the paper's CUDA insight (see DESIGN.md
§Hardware-Adaptation): the scores matrix ``(tokens, experts)`` is tiled
into VMEM blocks of ``(BLOCK_T, E)``; top-1/top-2 are vectorized
reductions over the lane (expert) axis — one pass, no sort, no heap.
``k > 2`` unrolls k masked-max passes (k is tiny in MoE).

All kernels run with ``interpret=True``: the CPU PJRT plugin cannot
execute Mosaic custom-calls, and correctness is what we validate here
(pytest + hypothesis against ``ref.py``). VMEM footprints and MXU notes
for a real TPU lowering are recorded in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Token-block size: 128 rows keeps a (128, E≤256) f32 block ≤ 128 KiB of
# VMEM — comfortably inside a TPU core's ~16 MiB alongside double
# buffering.
BLOCK_T = 128


def _top1_kernel(s_ref, vals_ref, idx_ref):
    s = s_ref[...]  # [bt, E]
    vals_ref[...] = jnp.max(s, axis=-1, keepdims=True)
    idx_ref[...] = jnp.argmax(s, axis=-1, keepdims=True).astype(jnp.int32)


def _top2_kernel(s_ref, vals_ref, idx_ref):
    s = s_ref[...]  # [bt, E]
    e = s.shape[-1]
    i1 = jnp.argmax(s, axis=-1)
    v1 = jnp.max(s, axis=-1)
    # Mask the winner, re-reduce: two passes, still no sort.
    masked = jnp.where(jax.nn.one_hot(i1, e, dtype=bool), -jnp.inf, s)
    i2 = jnp.argmax(masked, axis=-1)
    v2 = jnp.max(masked, axis=-1)
    vals_ref[...] = jnp.stack([v1, v2], axis=-1)
    idx_ref[...] = jnp.stack([i1, i2], axis=-1).astype(jnp.int32)


def _topk_kernel(s_ref, vals_ref, idx_ref, *, k):
    s = s_ref[...]
    e = s.shape[-1]
    cur = s
    vals = []
    idxs = []
    for _ in range(k):  # unrolled: k is 1..8 in MoE
        i = jnp.argmax(cur, axis=-1)
        v = jnp.max(cur, axis=-1)
        vals.append(v)
        idxs.append(i)
        cur = jnp.where(jax.nn.one_hot(i, e, dtype=bool), -jnp.inf, cur)
    vals_ref[...] = jnp.stack(vals, axis=-1)
    idx_ref[...] = jnp.stack(idxs, axis=-1).astype(jnp.int32)


def _pad_tokens(scores):
    t = scores.shape[0]
    padded_t = -(-t // BLOCK_T) * BLOCK_T
    if padded_t != t:
        pad = jnp.full((padded_t - t, scores.shape[1]), -jnp.inf, scores.dtype)
        scores = jnp.concatenate([scores, pad], axis=0)
    return scores, t


def top1(scores):
    """Pallas top-1. scores [T, E] -> (vals [T], idx [T] int32)."""
    scores, t = _pad_tokens(scores)
    pt, e = scores.shape
    grid = (pt // BLOCK_T,)
    vals, idx = pl.pallas_call(
        _top1_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_T, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((BLOCK_T, 1), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_T, 1), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pt, 1), scores.dtype),
            jax.ShapeDtypeStruct((pt, 1), jnp.int32),
        ],
        interpret=True,
    )(scores)
    return vals[:t, 0], idx[:t, 0]


def top2(scores):
    """Pallas top-2. scores [T, E] -> (vals [T,2], idx [T,2] int32)."""
    scores, t = _pad_tokens(scores)
    pt, e = scores.shape
    grid = (pt // BLOCK_T,)
    vals, idx = pl.pallas_call(
        _top2_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_T, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((BLOCK_T, 2), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_T, 2), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pt, 2), scores.dtype),
            jax.ShapeDtypeStruct((pt, 2), jnp.int32),
        ],
        interpret=True,
    )(scores)
    return vals[:t], idx[:t]


def topk(scores, k):
    """Pallas top-k (k unrolled masked-max passes)."""
    if k == 1:
        v, i = top1(scores)
        return v[:, None], i[:, None]
    if k == 2:
        return top2(scores)
    scores, t = _pad_tokens(scores)
    pt, e = scores.shape
    grid = (pt // BLOCK_T,)
    vals, idx = pl.pallas_call(
        functools.partial(_topk_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((BLOCK_T, e), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((BLOCK_T, k), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_T, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((pt, k), scores.dtype),
            jax.ShapeDtypeStruct((pt, k), jnp.int32),
        ],
        interpret=True,
    )(scores)
    return vals[:t], idx[:t]


def vmem_bytes(block_t, num_experts, k, dtype_bytes=4):
    """Static VMEM footprint estimate of one grid step (DESIGN.md §Perf):
    input block + both output blocks + the masked copy."""
    in_block = block_t * num_experts * dtype_bytes
    out_blocks = 2 * block_t * k * dtype_bytes
    scratch = in_block  # masked copy for the k>1 passes
    return in_block + out_blocks + scratch
