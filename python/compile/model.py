"""Layer-2: the MoE Transformer LM in pure JAX.

Architecture: tied-embedding decoder with causal self-attention and a
Switch-style (top-1) MoE FFN in every block. The router's top-1 comes
from the **Pallas kernel** (`kernels.topk.top1`) so the L1 kernel lowers
into the same HLO the Rust runtime executes; dispatch/combine use the
one-hot einsum formulation (differentiable; indices are stop-gradient,
weights flow through the softmax gather — standard Switch training).

Everything here is build-time only: ``aot.py`` lowers ``init_fn`` and
``train_step`` to HLO text once, and the Rust trainer drives them
through PJRT.
"""

import dataclasses
import functools

import jax
import jax.numpy as jnp

from compile.kernels import topk as topk_kernels


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    vocab: int = 8192
    d_model: int = 256
    n_layers: int = 6
    n_heads: int = 4
    ffn_hidden: int = 512
    num_experts: int = 64
    capacity_factor: float = 1.25
    seq: int = 128
    batch: int = 4
    lr: float = 3e-4
    aux_loss_weight: float = 0.01

    @property
    def capacity(self):
        tokens = self.batch * self.seq
        return max(1, int(tokens / self.num_experts * self.capacity_factor + 0.999))


TINY = ModelConfig(
    vocab=256, d_model=32, n_layers=2, n_heads=2, ffn_hidden=64,
    num_experts=4, seq=16, batch=4, lr=1e-2,
)

# ~104M parameters, expert-dominated (64 experts × 6 layers), small
# active compute — sized for the single-core CPU testbed (DESIGN.md §2).
E2E = ModelConfig(
    vocab=8192, d_model=256, n_layers=6, n_heads=4, ffn_hidden=512,
    num_experts=64, seq=128, batch=4, lr=1e-3,
)

CONFIGS = {"tiny": TINY, "e2e": E2E}


# --------------------------------------------------------------------------
# Parameters. Stored as a flat list of arrays (stable order) so the Rust
# trainer can round-trip them positionally. `param_spec` names each slot.
# --------------------------------------------------------------------------

def param_spec(cfg: ModelConfig):
    """[(name, shape)] in flat order."""
    spec = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.seq, cfg.d_model)),
    ]
    for layer in range(cfg.n_layers):
        p = f"l{layer}."
        spec += [
            (p + "ln1_g", (cfg.d_model,)),
            (p + "ln1_b", (cfg.d_model,)),
            (p + "wqkv", (cfg.d_model, 3 * cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2_g", (cfg.d_model,)),
            (p + "ln2_b", (cfg.d_model,)),
            (p + "gate_w", (cfg.d_model, cfg.num_experts)),
            (p + "w1", (cfg.num_experts, cfg.d_model, cfg.ffn_hidden)),
            (p + "b1", (cfg.num_experts, cfg.ffn_hidden)),
            (p + "w2", (cfg.num_experts, cfg.ffn_hidden, cfg.d_model)),
            (p + "b2", (cfg.num_experts, cfg.d_model)),
        ]
    spec += [("lnf_g", (cfg.d_model,)), ("lnf_b", (cfg.d_model,))]
    return spec


def num_params(cfg: ModelConfig):
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_spec(cfg))


def init_params(cfg: ModelConfig, seed):
    """Flat list of parameter arrays (f32)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for name, shape in param_spec(cfg):
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            params.append(jnp.ones(shape, jnp.float32))
        elif name.endswith(("_b", "b1", "b2")):
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
            scale = 1.0 / jnp.sqrt(jnp.maximum(1.0, fan_in))
            params.append(scale * jax.random.normal(sub, shape, jnp.float32))
    return params


def _as_dict(cfg, params):
    return {name: p for (name, _), p in zip(param_spec(cfg), params)}


# --------------------------------------------------------------------------
# Forward pieces.
# --------------------------------------------------------------------------

def layernorm(x, g, b, eps=1e-5):
    mean = x.mean(-1, keepdims=True)
    var = ((x - mean) ** 2).mean(-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * g + b


def causal_attention(x, wqkv, wo, n_heads):
    b, s, d = x.shape
    qkv = x @ wqkv  # [b, s, 3d]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    hd = d // n_heads

    def heads(t):
        return t.reshape(b, s, n_heads, hd).transpose(0, 2, 1, 3)

    q, k, v = heads(q), heads(k), heads(v)
    att = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(hd)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask, att, -1e30)
    att = jax.nn.softmax(att, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = out.transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ wo


def moe_ffn(x, gate_w, w1, b1, w2, b2, cfg: ModelConfig):
    """Switch-style top-1 MoE FFN over flattened tokens.

    x: [T, d]. Returns ([T, d], aux_loss). Routing uses the Pallas top-1
    kernel; dispatch/combine are one-hot einsums over the capacity-padded
    expert buffer (GShard formulation, MXU-friendly — DESIGN.md
    §Hardware-Adaptation).
    """
    t, d = x.shape
    e, cap = cfg.num_experts, cfg.capacity
    scores = x @ gate_w  # [T, E]
    probs = jax.nn.softmax(scores, axis=-1)
    # L1 Pallas kernel. Routing indices are non-differentiable by design
    # (Switch training): stop-gradient the kernel's input so autodiff
    # treats the routing decision as a constant.
    _, idx = topk_kernels.top1(jax.lax.stop_gradient(scores))
    gate_weight = jnp.take_along_axis(probs, idx[:, None], axis=1)[:, 0]  # [T]

    # Capacity positions (FCFS, matches Rust apply_capacity).
    onehot_e = jax.nn.one_hot(idx, e, dtype=jnp.float32)  # [T, E]
    pos = jnp.cumsum(onehot_e, axis=0) - 1.0
    pos = jnp.sum(pos * onehot_e, axis=1)  # [T] position within expert
    keep = pos < cap
    # Dispatch one-hot [T, E, cap].
    pos_oh = jax.nn.one_hot(jnp.clip(pos, 0, cap - 1).astype(jnp.int32), cap,
                            dtype=jnp.float32)
    dispatch = onehot_e[:, :, None] * pos_oh[:, None, :] * keep[:, None, None]

    # Expert buffers [E, cap, d] → per-expert FFN → combine.
    buf = jnp.einsum("tec,td->ecd", dispatch, x)
    hid = jax.nn.gelu(jnp.einsum("ecd,edh->ech", buf, w1) + b1[:, None, :])
    out_buf = jnp.einsum("ech,ehd->ecd", hid, w2) + b2[:, None, :]
    combined = jnp.einsum("tec,ecd->td", dispatch, out_buf)
    y = combined * gate_weight[:, None]

    # Switch auxiliary loss: E · Σ f_e P_e.
    f = onehot_e.mean(0)
    p = probs.mean(0)
    aux = e * jnp.sum(f * p)
    return y, aux


def forward(cfg: ModelConfig, params, tokens):
    """tokens [batch, seq] int32 -> (logits [batch, seq, vocab], aux)."""
    pd = _as_dict(cfg, params)
    x = pd["embed"][tokens] + pd["pos"][None, :, :]
    aux_total = 0.0
    for layer in range(cfg.n_layers):
        p = f"l{layer}."
        h = layernorm(x, pd[p + "ln1_g"], pd[p + "ln1_b"])
        x = x + causal_attention(h, pd[p + "wqkv"], pd[p + "wo"], cfg.n_heads)
        h = layernorm(x, pd[p + "ln2_g"], pd[p + "ln2_b"])
        flat = h.reshape(-1, cfg.d_model)
        y, aux = moe_ffn(
            flat,
            pd[p + "gate_w"], pd[p + "w1"], pd[p + "b1"],
            pd[p + "w2"], pd[p + "b2"], cfg,
        )
        x = x + y.reshape(x.shape)
        aux_total = aux_total + aux
    x = layernorm(x, pd["lnf_g"], pd["lnf_b"])
    logits = x @ pd["embed"].T  # tied embedding
    return logits, aux_total / cfg.n_layers


def loss_fn(cfg: ModelConfig, params, tokens, targets):
    logits, aux = forward(cfg, params, tokens)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1).mean()
    return nll + cfg.aux_loss_weight * aux, nll


# --------------------------------------------------------------------------
# Training step: Adam, fused fwd/bwd/update. The flat state the Rust
# trainer round-trips is params + adam_m + adam_v + step_count.
# --------------------------------------------------------------------------

def init_state(cfg: ModelConfig, seed):
    """Flat training state: params…, m…, v…, step."""
    params = init_params(cfg, seed)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    return params + m + v + [jnp.zeros((), jnp.float32)]


def train_step(cfg: ModelConfig, state, tokens, targets):
    """One Adam step. Returns (new_state…, nll_loss) as a flat tuple."""
    n = len(param_spec(cfg))
    params, m, v, step = state[:n], state[n:2 * n], state[2 * n:3 * n], state[3 * n]

    (total, nll), grads = jax.value_and_grad(
        lambda p: loss_fn(cfg, p, tokens, targets), has_aux=True
    )(params)
    del total
    b1, b2, eps = 0.9, 0.999, 1e-8
    step = step + 1.0
    new_params, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = b1 * mi + (1 - b1) * g
        vi = b2 * vi + (1 - b2) * (g * g)
        mhat = mi / (1 - b1 ** step)
        vhat = vi / (1 - b2 ** step)
        new_params.append(p - cfg.lr * mhat / (jnp.sqrt(vhat) + eps))
        new_m.append(mi)
        new_v.append(vi)
    return tuple(new_params + new_m + new_v + [step, nll])


def init_fn(cfg: ModelConfig):
    """jit-able init: seed scalar (i32) -> flat state tuple."""
    def f(seed):
        # jax.random needs a concrete key path; fold the traced seed in.
        del seed  # lowered artifact bakes seed handling below
        return tuple(init_state(cfg, 0))
    return f


def init_fn_seeded(cfg: ModelConfig):
    """Seed-respecting init (seed folds into the PRNG key)."""
    def f(seed):
        key = jax.random.PRNGKey(0)
        key = jax.random.fold_in(key, seed)
        params = []
        keys = jax.random.split(key, len(param_spec(cfg)))
        for (name, shape), sub in zip(param_spec(cfg), keys):
            if name.endswith("_g"):
                params.append(jnp.ones(shape, jnp.float32))
            elif name.endswith(("_b", "b1", "b2")):
                params.append(jnp.zeros(shape, jnp.float32))
            else:
                fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
                scale = 1.0 / jnp.sqrt(jnp.maximum(1.0, float(fan_in)))
                params.append(scale * jax.random.normal(sub, shape, jnp.float32))
        m = [jnp.zeros_like(p) for p in params]
        v = [jnp.zeros_like(p) for p in params]
        return tuple(params + m + v + [jnp.zeros((), jnp.float32)])
    return f


def step_fn(cfg: ModelConfig):
    """jit-able train step over the flat state."""
    @functools.partial(jax.jit, static_argnums=())
    def f(*args):
        *state_and_batch, = args
        state = list(state_and_batch[:-2])
        tokens = state_and_batch[-2]
        targets = state_and_batch[-1]
        return train_step(cfg, state, tokens, targets)
    return f


# --------------------------------------------------------------------------
# Piecewise graphs for the Rust expert-parallel pipeline.
# --------------------------------------------------------------------------

def gate_scores_fn(x, gate_w):
    """x [T, d], gate_w [d, E] -> (scores, top1 idx as f32, top1 prob)."""
    scores = x @ gate_w
    probs = jax.nn.softmax(scores, axis=-1)
    _, idx = topk_kernels.top1(scores)
    w = jnp.take_along_axis(probs, idx[:, None], axis=1)[:, 0]
    return scores, idx.astype(jnp.float32), w


def expert_ffn_fn(x, w1, b1, w2, b2):
    """One expert FFN: x [C, d] -> [C, d] (GeLU MLP)."""
    return jax.nn.gelu(x @ w1 + b1) @ w2 + b2
