"""AOT lowering tests: HLO text emission, meta.json integrity, and
numerical equivalence of the lowered piece functions."""

import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def tmp_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    meta = {}
    aot.build_piece_artifacts(str(out), meta)
    with open(out / "meta.json", "w") as f:
        json.dump(meta, f)
    return out, meta


def test_emits_parseable_hlo_text(tmp_artifacts):
    out, meta = tmp_artifacts
    for name in ["gate_scores", "expert_ffn", "top1_pallas"]:
        path = out / f"{name}.hlo.txt"
        text = path.read_text()
        assert text.startswith("HloModule"), name
        assert "ENTRY" in text, name
        assert name in meta


def test_meta_shapes_are_consistent(tmp_artifacts):
    _, meta = tmp_artifacts
    ef = meta["expert_ffn"]
    cap, d = ef["inputs"][0]
    assert ef["outputs"][0] == [cap, d]
    assert ef["attrs"]["d_model"] == d
    gs = meta["gate_scores"]
    t, d2 = gs["inputs"][0]
    e = gs["inputs"][1][1]
    assert gs["outputs"][0] == [t, e]
    assert gs["attrs"]["num_experts"] == e


def test_lowered_pallas_kernel_is_pure_hlo(tmp_artifacts):
    """interpret=True must lower to plain HLO ops (no Mosaic custom-call
    the CPU PJRT client would choke on)."""
    out, _ = tmp_artifacts
    text = (out / "top1_pallas.hlo.txt").read_text()
    assert "mosaic" not in text.lower()
    assert "custom-call" not in text.lower() or "topk" not in text.lower()


def test_roundtrip_numerics_through_xla_computation():
    """Lowered HLO (via the same path the Rust loader uses) computes the
    same numbers as the original jax function."""
    from jax._src.lib import xla_client as xc

    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (8, 16))
    w1 = jax.random.normal(key, (16, 32)) * 0.1
    b1 = jnp.zeros(32)
    w2 = jax.random.normal(key, (32, 16)) * 0.1
    b2 = jnp.zeros(16)

    lowered = jax.jit(model.expert_ffn_fn).lower(x, w1, b1, w2, b2)
    text = aot.to_hlo_text(lowered)
    assert text.startswith("HloModule")

    # Execute through the XLA client from the text-parsed computation.
    client = xc._xla.get_tfrt_cpu_client()  # noqa: SLF001
    expect = model.expert_ffn_fn(x, w1, b1, w2, b2)
    # (Parsing text back requires the same C++ parser the Rust side
    # uses; here we assert the text is complete and well-formed, and
    # trust tests/runtime_integration.rs for the execute path.)
    assert "gelu" in text.lower() or "tanh" in text.lower() or "erf" in text.lower()
    del client, expect


def test_cli_entrypoint_tiny(tmp_path):
    """`python -m compile.aot` end-to-end with the tiny model."""
    out = tmp_path / "arts"
    env = dict(os.environ)
    res = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--models", "tiny"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(aot.__file__))),
        capture_output=True,
        text=True,
        env=env,
        timeout=560,
    )
    assert res.returncode == 0, res.stderr[-2000:]
    meta = json.loads((out / "meta.json").read_text())
    assert "tiny_init" in meta and "tiny_step" in meta
    n = len(model.param_spec(model.TINY))
    assert len(meta["tiny_init"]["outputs"]) == 3 * n + 1
    assert len(meta["tiny_step"]["inputs"]) == 3 * n + 1 + 2
    # loss appended to the state outputs.
    assert len(meta["tiny_step"]["outputs"]) == 3 * n + 2
