"""Gumbel-softmax (dense-to-sparse) kernel tests."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import gumbel, ref

hypothesis.settings.register_profile(
    "ci", max_examples=15, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("ci")


@hypothesis.given(
    t=st.integers(1, 200),
    e=st.sampled_from([4, 16, 64]),
    tau=st.sampled_from([0.1, 0.5, 1.0, 2.0]),
    seed=st.integers(0, 2**31),
)
def test_matches_ref(t, e, tau, seed):
    key = jax.random.PRNGKey(seed % 1000)
    rng = np.random.default_rng(seed)
    s = jnp.asarray(rng.standard_normal((t, e)), jnp.float32)
    g = jax.random.gumbel(key, s.shape)
    out = gumbel.gumbel_softmax(s, g, tau)
    logp = jax.nn.log_softmax(s, axis=-1)
    expect = jax.nn.softmax((logp + g) / tau, axis=-1)
    assert jnp.allclose(out, expect, atol=1e-5)


def test_rows_are_distributions():
    key = jax.random.PRNGKey(0)
    s = jax.random.normal(key, (100, 8))
    g = jax.random.gumbel(key, s.shape)
    out = gumbel.gumbel_softmax(s, g, 0.7)
    assert jnp.allclose(out.sum(-1), 1.0, atol=1e-5)
    assert jnp.all(out >= 0)


def test_low_temperature_sharpens():
    """As tau → 0, the distribution approaches one-hot (dense→sparse)."""
    key = jax.random.PRNGKey(1)
    s = jax.random.normal(key, (200, 16))
    g = jax.random.gumbel(key, s.shape)
    hot = gumbel.gumbel_softmax(s, g, 0.05)
    mild = gumbel.gumbel_softmax(s, g, 2.0)
    assert float(hot.max(-1).mean()) > 0.95
    assert float(mild.max(-1).mean()) < 0.7
    # Effective experts per token (mass above 1%) shrinks with tau.
    k_hot = float((hot > 0.01).sum(-1).mean())
    k_mild = float((mild > 0.01).sum(-1).mean())
    assert k_hot < k_mild


def test_tau_schedule_monotone():
    taus = [float(gumbel.tau_schedule(s, 2.0, 0.1, 1000)) for s in [0, 250, 500, 1000, 2000]]
    assert abs(taus[0] - 2.0) < 1e-5
    assert abs(taus[3] - 0.1) < 1e-5
    assert abs(taus[4] - 0.1) < 1e-5
    assert all(a >= b for a, b in zip(taus, taus[1:]))


def test_agrees_with_ref_sampler():
    """ref_gumbel_softmax(key) == kernel given the same key's noise."""
    key = jax.random.PRNGKey(7)
    s = jax.random.normal(jax.random.PRNGKey(8), (64, 8))
    expect = ref.ref_gumbel_softmax(s, key, 0.5)
    g = jax.random.gumbel(key, s.shape, dtype=s.dtype)
    out = gumbel.gumbel_softmax(s, g, 0.5)
    assert jnp.allclose(out, expect, atol=1e-5)
