"""Dispatch/combine Pallas kernels vs oracles + roundtrip properties."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np

from compile.kernels import layout, ref

hypothesis.settings.register_profile(
    "ci", max_examples=15, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("ci")


def routing_case(t, e, cap, d, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    scores = jnp.asarray(rng.standard_normal((t, e)), jnp.float32)
    idx = jnp.argmax(scores, axis=-1).astype(jnp.int32)
    dest = ref.ref_capacity_positions(idx, e, cap)
    onehot = ref.make_onehot(dest, e * cap)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.take_along_axis(w, idx[:, None], axis=1)[:, 0]
    return x, onehot, w


@hypothesis.given(
    t=st.integers(1, 200),
    e=st.sampled_from([2, 4, 16]),
    d=st.sampled_from([8, 32, 130]),
    seed=st.integers(0, 2**31),
)
def test_dispatch_matches_ref(t, e, d, seed):
    cap = max(1, t // e)
    x, onehot, _ = routing_case(t, e, cap, d, seed)
    out = layout.dispatch(x, onehot)
    expect = ref.ref_dispatch(x, onehot)
    assert jnp.allclose(out, expect, atol=1e-4), float(jnp.abs(out - expect).max())


@hypothesis.given(
    t=st.integers(1, 150),
    e=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31),
)
def test_combine_matches_ref(t, e, seed):
    d, cap = 16, max(1, t // e + 1)
    x, onehot, w = routing_case(t, e, cap, d, seed)
    buf = ref.ref_dispatch(x, onehot)
    out = layout.combine(buf, onehot, w)
    expect = ref.ref_combine(buf, onehot, w)
    assert jnp.allclose(out, expect, atol=1e-4)


def test_roundtrip_recovers_tokens():
    # cap >= tokens, unit weights: combine(dispatch(x)) == x.
    t, e, d = 60, 4, 24
    x, onehot, _ = routing_case(t, e, t, d, 0)
    buf = layout.dispatch(x, onehot)
    back = layout.combine(buf, onehot, jnp.ones(t))
    assert jnp.allclose(back, x, atol=1e-4)


def test_dropped_tokens_are_zero():
    # Capacity 1, all tokens to one expert: only the first survives.
    t, e, d = 5, 2, 3
    idx = jnp.zeros(t, jnp.int32)
    dest = ref.ref_capacity_positions(idx, e, 1)
    assert int(dest[0]) == 0 and all(int(v) == -1 for v in dest[1:])
    onehot = ref.make_onehot(dest, e * 1)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    buf = layout.dispatch(x, onehot)
    back = layout.combine(buf, onehot, jnp.ones(t))
    assert jnp.allclose(back[0], x[0], atol=1e-5)
    assert jnp.allclose(back[1:], 0.0)


def test_capacity_positions_match_fcfs_spec():
    # Exactly the Rust apply_capacity semantics.
    idx = jnp.asarray([1, 0, 1, 1, 0], jnp.int32)
    dest = ref.ref_capacity_positions(idx, 2, 2)
    # expert buffers: e0 rows 0..2, e1 rows 2..4.
    assert list(map(int, dest)) == [2, 0, 3, -1, 1]
