"""L2 model tests: shapes, gradient flow, loss decrease, MoE invariants."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def tiny():
    return model.TINY


def test_param_spec_and_count(tiny):
    spec = model.param_spec(tiny)
    names = [n for n, _ in spec]
    assert names[0] == "embed"
    assert f"l{tiny.n_layers - 1}.w2" in names
    params = model.init_params(tiny, 0)
    assert len(params) == len(spec)
    for p, (_, shape) in zip(params, spec):
        assert p.shape == shape
    assert model.num_params(tiny) == sum(int(np.prod(s)) for _, s in spec)


def test_e2e_config_is_100m_class():
    assert 90_000_000 < model.num_params(model.E2E) < 150_000_000


def test_forward_shapes_and_finite(tiny):
    params = model.init_params(tiny, 0)
    tok = jnp.zeros((tiny.batch, tiny.seq), jnp.int32)
    logits, aux = model.forward(tiny, params, tok)
    assert logits.shape == (tiny.batch, tiny.seq, tiny.vocab)
    assert bool(jnp.isfinite(logits).all())
    assert float(aux) > 0.0


def test_initial_loss_near_uniform(tiny):
    params = model.init_params(tiny, 0)
    tok = jnp.zeros((tiny.batch, tiny.seq), jnp.int32)
    tgt = jnp.ones((tiny.batch, tiny.seq), jnp.int32)
    _, nll = model.loss_fn(tiny, params, tok, tgt)
    assert abs(float(nll) - np.log(tiny.vocab)) < 1.0


def test_gradients_reach_experts_and_gate(tiny):
    params = model.init_params(tiny, 0)
    tok = jnp.zeros((tiny.batch, tiny.seq), jnp.int32)
    tgt = jnp.ones((tiny.batch, tiny.seq), jnp.int32)
    grads = jax.grad(lambda p: model.loss_fn(tiny, p, tok, tgt)[0])(params)
    gd = {n: g for (n, _), g in zip(model.param_spec(tiny), grads)}
    # Expert weights and the router both receive gradient.
    assert float(jnp.abs(gd["l0.w1"]).max()) > 0.0
    assert float(jnp.abs(gd["l0.gate_w"]).max()) > 0.0
    assert float(jnp.abs(gd["embed"]).max()) > 0.0


def test_train_step_memorizes_fixed_sequence(tiny):
    base = (np.arange(tiny.seq + 1) * 13 + 5) % tiny.vocab
    tok = jnp.asarray(np.tile(base[:-1], (tiny.batch, 1)), jnp.int32)
    tgt = jnp.asarray(np.tile(base[1:], (tiny.batch, 1)), jnp.int32)
    state = list(model.init_state(tiny, 0))
    step = jax.jit(lambda *a: model.train_step(tiny, list(a[:-2]), a[-2], a[-1]))
    losses = []
    for _ in range(40):
        out = step(*state, tok, tgt)
        state = list(out[:-1])
        losses.append(float(out[-1]))
    assert losses[-1] < 1.0, losses[::8]
    assert losses[-1] < losses[0] / 3


def test_capacity_property():
    cfg = dataclasses.replace(model.TINY, capacity_factor=1.0)
    # tokens = 64, E=4 → capacity 16.
    assert cfg.capacity == 16
    cfg2 = dataclasses.replace(cfg, capacity_factor=2.0)
    assert cfg2.capacity == 32


def test_moe_ffn_respects_capacity_drops():
    cfg = model.TINY
    t, d = 32, cfg.d_model
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (t, d))
    # Gate weight that routes everything to expert 0.
    gate_w = jnp.zeros((d, cfg.num_experts)).at[:, 0].set(1.0)
    params = model.init_params(cfg, 0)
    pd = {n: p for (n, _), p in zip(model.param_spec(cfg), params)}
    y, aux = model.moe_ffn(x, gate_w, pd["l0.w1"], pd["l0.b1"],
                           pd["l0.w2"], pd["l0.b2"], cfg)
    assert y.shape == (t, d)
    # Collapsed routing → aux loss strictly above the uniform value 1.0
    # (aux = E · f_0 · P_0 with f_0 = 1).
    assert float(aux) > 1.1


def test_gate_scores_piece_matches_model_routing():
    cfg = model.TINY
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (50, cfg.d_model))
    gate_w = jax.random.normal(key, (cfg.d_model, cfg.num_experts))
    scores, idx_f, w = model.gate_scores_fn(x, gate_w)
    assert scores.shape == (50, cfg.num_experts)
    idx = idx_f.astype(jnp.int32)
    assert jnp.array_equal(idx, jnp.argmax(scores, -1).astype(jnp.int32))
    probs = jax.nn.softmax(scores, -1)
    expect_w = jnp.take_along_axis(probs, idx[:, None], 1)[:, 0]
    assert jnp.allclose(w, expect_w, atol=1e-6)


def test_expert_ffn_piece():
    key = jax.random.PRNGKey(4)
    x = jax.random.normal(key, (8, 16))
    w1 = jax.random.normal(key, (16, 32)) * 0.1
    b1 = jnp.zeros(32)
    w2 = jax.random.normal(key, (32, 16)) * 0.1
    b2 = jnp.zeros(16)
    y = model.expert_ffn_fn(x, w1, b1, w2, b2)
    expect = jax.nn.gelu(x @ w1) @ w2
    assert jnp.allclose(y, expect, atol=1e-5)
