"""L1 Pallas top-k kernels vs the pure-jnp oracle (hypothesis sweeps)."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import ref, topk

hypothesis.settings.register_profile(
    "ci", max_examples=25, deadline=None, derandomize=True
)
hypothesis.settings.load_profile("ci")


def scores_of(t, e, seed, dtype):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal((t, e)), dtype)


@hypothesis.given(
    t=st.integers(1, 300),
    e=st.sampled_from([2, 4, 16, 64, 256]),
    seed=st.integers(0, 2**31),
)
def test_top1_matches_ref(t, e, seed):
    s = scores_of(t, e, seed, jnp.float32)
    v, i = topk.top1(s)
    rv, ri = ref.ref_top1(s)
    assert jnp.array_equal(i, ri)
    assert jnp.allclose(v, rv)


@hypothesis.given(
    t=st.integers(1, 300),
    e=st.sampled_from([2, 8, 16, 128]),
    seed=st.integers(0, 2**31),
)
def test_top2_matches_ref(t, e, seed):
    s = scores_of(t, e, seed, jnp.float32)
    v, i = topk.top2(s)
    rv, ri = ref.ref_top2(s)
    assert jnp.array_equal(i, ri)
    assert jnp.allclose(v, rv)


@hypothesis.given(
    t=st.integers(1, 150),
    e=st.sampled_from([8, 16, 32]),
    k=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_topk_matches_ref(t, e, k, seed):
    k = min(k, e)
    s = scores_of(t, e, seed, jnp.float32)
    v, i = topk.topk(s, k)
    rv, ri = ref.ref_topk(s, k)
    assert jnp.array_equal(i, ri)
    assert jnp.allclose(v, rv)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    s = scores_of(130, 16, 0, dtype)
    v, i = topk.top1(s)
    rv, ri = ref.ref_top1(s)
    assert jnp.array_equal(i, ri)
    assert v.dtype == dtype
    assert jnp.allclose(v.astype(jnp.float32), rv.astype(jnp.float32))


def test_ties_resolve_to_smallest_index():
    s = jnp.ones((5, 8))
    _, i = topk.top1(s)
    assert jnp.array_equal(i, jnp.zeros(5, jnp.int32))
    _, i2 = topk.top2(s)
    assert jnp.array_equal(i2, jnp.tile(jnp.array([0, 1], jnp.int32), (5, 1)))


def test_block_boundary_shapes():
    # Exactly BLOCK_T, one less, one more.
    for t in [topk.BLOCK_T - 1, topk.BLOCK_T, topk.BLOCK_T + 1, 2 * topk.BLOCK_T]:
        s = scores_of(t, 16, t, jnp.float32)
        v, i = topk.top1(s)
        rv, ri = ref.ref_top1(s)
        assert jnp.array_equal(i, ri), f"t={t}"


def test_negative_scores_and_padding():
    # All-negative scores must not be confused by the -inf padding rows.
    s = -jnp.abs(scores_of(100, 8, 1, jnp.float32)) - 1.0
    v, i = topk.top1(s)
    rv, ri = ref.ref_top1(s)
    assert jnp.array_equal(i, ri)
    assert jnp.all(v < 0)


def test_jit_and_grad_compatible():
    # The kernel lowers inside jit (what aot.py relies on).
    s = scores_of(64, 16, 2, jnp.float32)
    v, i = jax.jit(topk.top1)(s)
    rv, ri = ref.ref_top1(s)
    assert jnp.array_equal(i, ri)


def test_vmem_estimate_within_budget():
    # A (128, 256) f32 block with outputs fits well under 1 MiB.
    assert topk.vmem_bytes(topk.BLOCK_T, 256, 2) < 1 << 20
