//! Ablation benches for the design choices DESIGN.md §7 calls out:
//!   A. hierarchical-vs-flat crossover in per-GPU payload;
//!   B. capacity factor: drop rate vs padding waste;
//!   C. specialized vs generic top-k across k (where the heap wins back);
//!   D. dense one-hot dispatch vs sparse scatter as a function of batch
//!      (the mechanism behind Fig 8's DeepSpeed gap);
//!   E. gate zoo load balance at a glance.

use hetumoe::benchkit::{bench, black_box, BenchOpts, Table};
use hetumoe::cluster::NetworkModel;
use hetumoe::comm::alltoall::flat_alltoall_timing;
use hetumoe::comm::hierarchical::hierarchical_alltoall_timing;
use hetumoe::config::{ClusterConfig, GateKind, HashScheme, MoeConfig};
use hetumoe::gating::topk::{topk_rows, topk_rows_heap};
use hetumoe::gating::{apply_capacity, make_gate, Gate, GateBatch, SwitchGate};
use hetumoe::layout::opt_layout;
use hetumoe::moe::layer::dense_einsum_layout;
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::{fmt_duration, load_cv};

fn main() {
    ablation_a_crossover();
    ablation_b_capacity();
    ablation_c_topk_k();
    ablation_d_dispatch();
    ablation_e_gates();
}

fn ablation_a_crossover() {
    let mut t = Table::new(
        "Ablation A: hierarchical AllToAll crossover (4x8 cluster)",
        &["payload/GPU", "flat", "hier", "winner"],
    );
    for mib in [1usize, 8, 16, 64, 256, 1024] {
        let net = NetworkModel::new(ClusterConfig::commodity(4));
        let chunk = mib * 1024 * 1024 / net.cfg.world();
        let flat = flat_alltoall_timing(&net, chunk).total;
        let hier = hierarchical_alltoall_timing(&net, chunk).total;
        t.row(vec![
            format!("{mib} MiB"),
            fmt_duration(flat),
            fmt_duration(hier),
            if flat > hier { "hierarchical".into() } else { "flat".to_string() },
        ]);
    }
    t.emit(Some("bench_results/ablation_a.csv"));
    println!("(hierarchy pays in the small-message regime; at huge payloads the gather hop costs more than the latency it saves)\n");
}

fn ablation_b_capacity() {
    let mut rng = Rng::seed(0);
    let tokens = 8192;
    let e = 16;
    let scores = Tensor::randn(&[tokens, e], &mut rng);
    let routing = SwitchGate::new(e, 1.0).route_scores(&scores, 0);
    let mut t = Table::new(
        "Ablation B: capacity factor — drops vs padding",
        &["cf", "capacity", "drop rate", "padding waste"],
    );
    for cf in [0.5f64, 0.75, 1.0, 1.25, 1.5, 2.0] {
        let cap = ((tokens as f64 / e as f64) * cf).ceil() as usize;
        let plan = apply_capacity(&routing, cap);
        t.row(vec![
            format!("{cf}"),
            cap.to_string(),
            format!("{:.2}%", 100.0 * plan.drop_rate()),
            format!("{:.2}%", 100.0 * plan.padding_waste()),
        ]);
    }
    t.emit(Some("bench_results/ablation_b.csv"));
}

fn ablation_c_topk_k() {
    let opts = BenchOpts::quick();
    let mut rng = Rng::seed(1);
    let scores = Tensor::randn(&[16384, 64], &mut rng);
    let mut t = Table::new(
        "Ablation C: specialized selection vs heap across k",
        &["k", "heap", "specialized", "speedup"],
    );
    for k in [1usize, 2, 4, 8, 16, 32] {
        let heap = bench("heap", &opts, || {
            black_box(topk_rows_heap(black_box(&scores), k));
        });
        let spec = bench("spec", &opts, || {
            black_box(topk_rows(black_box(&scores), k, 1));
        });
        t.row(vec![
            k.to_string(),
            fmt_duration(heap.median),
            fmt_duration(spec.median),
            format!("{:.2}×", heap.median / spec.median),
        ]);
    }
    t.emit(Some("bench_results/ablation_c.csv"));
    println!("(the O(k·E) selection loses its lead as k grows — MoE's k ∈ {{1,2}} is exactly the specialized kernels' sweet spot)\n");
}

fn ablation_d_dispatch() {
    let opts = BenchOpts::quick();
    let mut rng = Rng::seed(2);
    let e = 16;
    let d = 256;
    let mut t = Table::new(
        "Ablation D: sparse scatter vs dense one-hot einsum dispatch (DeepSpeed mechanism)",
        &["tokens", "scatter", "dense einsum", "einsum/scatter"],
    );
    for tokens in [512usize, 2048, 8192] {
        let x = Tensor::randn(&[tokens, d], &mut rng);
        let scores = Tensor::randn(&[tokens, e], &mut rng);
        let routing = SwitchGate::new(e, 1.25).route_scores(&scores, 0);
        let cap = ((tokens as f64 / e as f64) * 1.25).ceil() as usize;
        let plan = apply_capacity(&routing, cap);
        let scatter = bench("scatter", &opts, || {
            black_box(opt_layout(black_box(&x), black_box(&plan), 1));
        });
        let einsum = bench("einsum", &opts, || {
            black_box(dense_einsum_layout(black_box(&x), black_box(&plan)));
        });
        t.row(vec![
            tokens.to_string(),
            fmt_duration(scatter.median),
            fmt_duration(einsum.median),
            format!("{:.1}×", einsum.median / scatter.median),
        ]);
    }
    t.emit(Some("bench_results/ablation_d.csv"));
    println!("(the dense dispatch's cost grows ∝ tokens² — real compute, the measured root of the 8.1× Fig-8 gap)\n");
}

fn ablation_e_gates() {
    let mut rng = Rng::seed(3);
    let tokens = 8192;
    let e = 16;
    let scores = Tensor::randn(&[tokens, e], &mut rng);
    let emb = Tensor::randn(&[1024, 16], &mut rng);
    let ids: Vec<u32> = (0..tokens as u32).map(|t| t % 1024).collect();
    let mut t = Table::new(
        "Ablation E: load balance across the gate zoo",
        &["gate", "mean k", "load CV", "drop@cf1.25"],
    );
    for kind in [
        GateKind::Switch,
        GateKind::GShard,
        GateKind::TopK { k: 4 },
        GateKind::KTop1 { k: 4 },
        GateKind::SamHTopK { groups: 4, k: 2 },
        GateKind::Base,
        GateKind::Hash { scheme: HashScheme::Balanced },
        GateKind::DenseToSparse { tau0: 2.0, tau_min: 0.1, anneal_steps: 1000 },
    ] {
        let cfg = MoeConfig {
            num_experts: e,
            d_model: 16,
            ffn_hidden: 16,
            capacity_factor: 1.25,
            gate: kind,
        };
        let gate = make_gate(&cfg, 1024, Some(&emb)).unwrap();
        let r = gate.route(&GateBatch { scores: &scores, token_ids: Some(&ids), step: 500 });
        let plan = apply_capacity(&r, cfg.capacity(tokens));
        t.row(vec![
            gate.name(),
            format!("{:.2}", r.mean_active_k()),
            format!("{:.3}", load_cv(&r.expert_counts())),
            format!("{:.2}%", 100.0 * plan.drop_rate()),
        ]);
    }
    t.emit(Some("bench_results/ablation_e.csv"));
}
