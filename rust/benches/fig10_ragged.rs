//! Fig 10 (ours): padded vs ragged dispatch pipeline on the training
//! forward path, across capacity factors.
//!
//! The padded pipeline ships `[E, cap, d]` buffers — padding included —
//! through both AllToAll legs and runs expert FFNs over capacity rows;
//! the ragged pipeline moves and computes only occupied rows. This
//! bench measures the real step wall time of both modes and the
//! attributed savings (bytes on wire, expert FLOPs, simulated comm),
//! asserting the ragged invariants the whole PR rests on:
//! strictly fewer bytes and strictly fewer FLOPs on non-uniform routing.

use hetumoe::benchkit::{bench, black_box, BenchOpts, Table};
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::moe::{DispatchMode, MoeLayer, MoeLayerOptions, StepReport};
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::fmt_duration;

fn mib(bytes: usize) -> String {
    format!("{:.2} MiB", bytes as f64 / (1024.0 * 1024.0))
}

fn main() {
    let opts = BenchOpts::quick();
    let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
    let world = cluster.world();
    let tokens_per_rank = 256usize;
    let d = 64usize;
    let mut table = Table::new(
        "Fig 10: padded vs ragged dispatch (16 experts, 2x2 GPUs, 256 tokens/rank)",
        &[
            "cap factor",
            "padded wall",
            "ragged wall",
            "speedup",
            "padded bytes",
            "ragged bytes",
            "bytes saved",
            "FLOPs saved",
        ],
    );

    for &cf in &[1.0f64, 1.25, 2.0, 4.0] {
        let cfg = MoeConfig {
            num_experts: 16,
            d_model: d,
            ffn_hidden: 2 * d,
            capacity_factor: cf,
            gate: GateKind::Switch,
        };
        let padded = MoeLayer::native(
            cfg.clone(),
            cluster.clone(),
            MoeLayerOptions { dispatch: DispatchMode::Padded, ..Default::default() },
            42,
        )
        .unwrap();
        let ragged = MoeLayer::native(
            cfg,
            cluster.clone(),
            MoeLayerOptions { dispatch: DispatchMode::Ragged, ..Default::default() },
            42,
        )
        .unwrap();
        let mut rng = Rng::seed(7);
        let shards: Vec<Tensor> = (0..world)
            .map(|_| Tensor::randn(&[tokens_per_rank, d], &mut rng))
            .collect();

        // Correctness + invariant gate before timing.
        let (out_p, rep_p): (Vec<Tensor>, StepReport) = padded.forward(&shards).unwrap();
        let (out_r, rep_r) = ragged.forward(&shards).unwrap();
        for (a, b) in out_p.iter().zip(&out_r) {
            assert!(a.allclose(b, 0.0), "padded and ragged must agree bit-for-bit");
        }
        assert_eq!(rep_p.expert_counts, rep_r.expert_counts);
        assert!(
            rep_r.bytes_on_wire < rep_p.bytes_on_wire,
            "cf={cf}: ragged must move strictly fewer bytes \
             ({} vs {})",
            rep_r.bytes_on_wire,
            rep_p.bytes_on_wire
        );
        assert!(
            rep_r.expert_flops < rep_p.expert_flops,
            "cf={cf}: ragged must execute strictly fewer expert FLOPs \
             ({:.3e} vs {:.3e})",
            rep_r.expert_flops,
            rep_p.expert_flops
        );
        assert_eq!(rep_r.padding_waste, 0.0);

        let tp = bench("padded", &opts, || {
            black_box(padded.forward(black_box(&shards)).unwrap());
        });
        let tr = bench("ragged", &opts, || {
            black_box(ragged.forward(black_box(&shards)).unwrap());
        });
        table.row(vec![
            format!("{cf:.2}"),
            fmt_duration(tp.median),
            fmt_duration(tr.median),
            format!("{:.2}×", tp.median / tr.median),
            mib(rep_p.bytes_on_wire),
            mib(rep_r.bytes_on_wire),
            format!(
                "{:.1}%",
                100.0 * (1.0 - rep_r.bytes_on_wire as f64 / rep_p.bytes_on_wire as f64)
            ),
            format!(
                "{:.1}%",
                100.0 * (1.0 - rep_r.expert_flops / rep_p.expert_flops)
            ),
        ]);
    }
    table.emit(None);
    println!(
        "ragged moves only occupied rows: savings grow with the capacity factor \
         (padding_waste of the padded buffers)."
    );
}
