//! Fig 11 (ours): end-to-end native training step anatomy.
//!
//! Runs the pure-Rust trainer (forward + backward + gradient AllReduce
//! + Adam) and reports the per-phase step-time breakdown split by
//! direction, the bytes-on-wire of both AllToAll directions, and the
//! per-leg flat-vs-hier schedule picks — the backward half of the
//! communication bill that the forward-only benches cannot see.
//!
//! Asserts the training invariants this PR rests on: the loss moves
//! down, the backward legs retrace the forward routes (identical
//! intra-node bytes; NIC bytes identical on flat steps and never
//! *larger* on hierarchical ones, where the backward return leg
//! pre-sums per-token partial gradients), and every step picks a
//! schedule for both directions.

use hetumoe::backprop::{smoothed_losses, NativeTrainer, TrainRunConfig};
use hetumoe::benchkit::Table;
use hetumoe::util::stats::fmt_duration;

fn main() {
    let mut cfg = TrainRunConfig::default_run();
    cfg.steps = 40;
    cfg.log_every = 0;
    let mut trainer = NativeTrainer::new(cfg).unwrap();
    let summary = trainer.run().unwrap();
    let b = &summary.breakdown;

    let dir_of = |name: &str| -> usize {
        if name == "optimizer" {
            2
        } else if name.starts_with("bwd_") || name.ends_with("_bwd") || name == "allreduce_grads"
        {
            1
        } else {
            0
        }
    };
    let labels = ["fwd", "bwd", "opt"];
    let mut table = Table::new(
        "Fig 11: native training step breakdown (8 experts, 2x2 GPUs, 64 tok/rank)",
        &["phase", "dir", "mean/step", "fraction"],
    );
    let mut totals = [0.0f64; 3];
    for (name, t) in &b.phases {
        let dir = dir_of(name);
        totals[dir] += *t;
        table.row(vec![
            name.clone(),
            labels[dir].into(),
            fmt_duration(*t),
            format!("{:.1}%", 100.0 * t / b.total),
        ]);
    }
    table.emit(None);

    let mut dir_table = Table::new("direction totals", &["direction", "mean/step", "fraction"]);
    for (i, label) in ["forward", "backward", "optimizer"].iter().enumerate() {
        dir_table.row(vec![
            label.to_string(),
            fmt_duration(totals[i]),
            format!("{:.1}%", 100.0 * totals[i] / b.total),
        ]);
    }
    dir_table.emit(None);

    let (ff, fh) = summary.fwd_schedules;
    let (bf, bh) = summary.bwd_schedules;
    println!(
        "bytes_on_wire/step (NIC): fwd {:.0} | bwd {:.0} | intra-node: fwd {:.0} | bwd {:.0}",
        b.bytes_on_wire, b.bytes_on_wire_bwd, b.bytes_intra_node, b.bytes_intra_node_bwd
    );
    println!("schedule picks: fwd flat={ff} hier={fh} | bwd flat={bf} hier={bh}");

    // ---- Invariants this figure rests on ----
    let losses = trainer.losses();
    let smooth = smoothed_losses(&losses, 0.1);
    assert!(
        smooth[39] < smooth[5],
        "loss must move down over 40 steps: {:.4} → {:.4}",
        smooth[5],
        smooth[39]
    );
    assert!(b.bytes_on_wire_bwd > 0.0, "backward must move bytes every step");
    // Backward gradient rows retrace the forward routes: same traffic
    // matrix, so same NIC bytes on flat steps — and on hierarchical
    // steps the backward's pre-summed return leg can only shave bytes
    // off the forward's full-rate combine, never add.
    assert!(
        b.bytes_on_wire_bwd <= b.bytes_on_wire + 1e-6,
        "backward NIC bytes must never exceed the forward's: bwd {:.0} vs fwd {:.0}",
        b.bytes_on_wire_bwd,
        b.bytes_on_wire
    );
    assert!(
        (b.bytes_intra_node_bwd - b.bytes_intra_node).abs() < 1e-6,
        "backward intra-node traffic retraces the forward's byte-for-byte"
    );
    assert_eq!(ff + fh, 40, "every step picks a forward schedule");
    assert_eq!(bf + bh, 40, "every step picks a backward schedule");
    assert!(totals[1] > 0.0, "backward wall time must be attributed");
    println!("fig11 invariants hold: loss falls, backward traffic attributed per leg.");
}
