//! Fig 12 (ours): exposed communication vs chunk count — micro-chunked
//! comm/compute overlap on the ragged training pipeline.
//!
//! Splits each ragged exchange into chunks along the destination-rank
//! axis so dispatch-of-chunk-i overlaps expert-FFN-of-chunk-i−1 (and
//! symmetrically on combine), across batch sizes and both AllToAll
//! schedules on a multi-node cluster. Reports the exchange time left
//! exposed on the critical path, what fraction was hidden under expert
//! compute, and the modeled step wall — and asserts the invariant the
//! whole PR rests on: some measured config hides strictly more than
//! zero comm, i.e. its exposed comm is strictly below the unchunked
//! sum-of-phases comm time.

use hetumoe::benchkit::Table;
use hetumoe::comm::schedule::CommChoice;
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::moe::{MoeLayer, MoeLayerOptions, StepReport};
use hetumoe::pipeline::ChunkChoice;
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::fmt_duration;

fn run_once(
    cfg: &MoeConfig,
    cluster: &ClusterConfig,
    shards: &[Tensor],
    alltoall: CommChoice,
    chunks: ChunkChoice,
) -> StepReport {
    // Serial expert stage on purpose: the figure's invariants need the
    // *measured* FFN wall to dominate the *simulated* exchange time on
    // any host CI runs on, and pool-parallel compute would shrink the
    // margin by a core-count-dependent factor. (The pool path has its
    // own coverage in tests/overlap_equivalence.rs.)
    let opts = MoeLayerOptions { alltoall, chunks, threads: 1, ..Default::default() };
    let layer = MoeLayer::native(cfg.clone(), cluster.clone(), opts, 42).unwrap();
    let (_, report) = layer.forward(shards).unwrap();
    report
}

fn main() {
    // Multi-node so both schedules are meaningful; FFN wide enough that
    // expert compute dominates the simulated exchange time (the regime
    // where overlap pays — MegaScale-MoE's operating point).
    let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
    let world = cluster.world();
    let d = 64usize;

    let mut table = Table::new(
        "Fig 12: exposed comm vs chunk count (16 experts, 2x2 GPUs, ragged dispatch)",
        &[
            "tokens/rank",
            "schedule",
            "chunks",
            "comm total",
            "comm exposed",
            "hidden",
            "efficiency",
            "modeled wall",
        ],
    );

    let mut best_hidden = 0.0f64;
    let mut chunked_beats_unchunked = false;
    let mut auto_picked_multi = false;

    for &tokens in &[128usize, 1024] {
        let cfg = MoeConfig {
            num_experts: 16,
            d_model: d,
            ffn_hidden: 8 * d,
            capacity_factor: 2.0,
            gate: GateKind::Switch,
        };
        let mut rng = Rng::seed(7);
        let shards: Vec<Tensor> =
            (0..world).map(|_| Tensor::randn(&[tokens, d], &mut rng)).collect();

        for &alltoall in &[CommChoice::Flat, CommChoice::Hierarchical, CommChoice::Auto] {
            // The unchunked baseline: the whole exchange is exposed.
            let base = run_once(&cfg, &cluster, &shards, alltoall, ChunkChoice::Fixed(1));
            assert_eq!(base.n_chunks, 1);
            assert_eq!(base.comm_hidden, 0.0);
            let base_comm = base.comm_total();

            let mut rows: Vec<(String, StepReport)> = vec![("1".into(), base)];
            for &n in &[2usize, 4] {
                let rep = run_once(&cfg, &cluster, &shards, alltoall, ChunkChoice::Fixed(n));
                // Requested count is honored up to the schedule's
                // chunkable units: destination ranks under flat, nodes
                // under hierarchical (node-axis chunking keeps the
                // aggregated inter-node messages whole).
                let units = if rep.comm_schedule == "hier" { cluster.nodes } else { world };
                let per = units.div_ceil(n.clamp(1, units));
                assert_eq!(
                    rep.n_chunks,
                    units.div_ceil(per),
                    "requested chunk count must be honored up to {units} units"
                );
                rows.push((n.to_string(), rep));
            }
            let auto = run_once(&cfg, &cluster, &shards, alltoall, ChunkChoice::Auto);
            if auto.n_chunks > 1 {
                auto_picked_multi = true;
            }
            rows.push((format!("auto={}", auto.n_chunks), auto));

            for (label, rep) in rows {
                // Invariant: chunking never changes what was computed.
                assert!(rep.critical_path <= rep.wall_phase("expert") + rep.comm_total() + 1e-9);
                if rep.n_chunks > 1 {
                    if rep.comm_hidden > best_hidden {
                        best_hidden = rep.comm_hidden;
                    }
                    if rep.comm_exposed < base_comm {
                        chunked_beats_unchunked = true;
                    }
                }
                table.row(vec![
                    tokens.to_string(),
                    format!("{}[{}]", rep.comm_schedule, alltoall.name()),
                    label,
                    fmt_duration(rep.comm_total()),
                    fmt_duration(rep.comm_exposed),
                    fmt_duration(rep.comm_hidden),
                    format!("{:.1}%", 100.0 * rep.overlap_efficiency()),
                    fmt_duration(rep.critical_wall()),
                ]);
            }
        }
    }
    table.emit(None);

    // ---- Invariants this figure rests on ----
    assert!(
        best_hidden > 0.0,
        "some measured config must hide > 0 comm under expert compute"
    );
    assert!(
        chunked_beats_unchunked,
        "some chunked config must expose strictly less comm than the \
         unchunked sum-of-phases comm time"
    );
    assert!(
        auto_picked_multi,
        "auto chunking must pick a multi-chunk plan in a compute-dominated regime"
    );
    println!(
        "fig12 invariants hold: chunked overlap hides comm (best hidden {} per step), \
         exposed comm drops below the unchunked exchange time, auto chunks when it pays.",
        fmt_duration(best_hidden)
    );
}
