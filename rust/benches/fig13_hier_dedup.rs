//! Fig 13 (ours): inter-node bytes and predicted exchange time — flat
//! vs hierarchical vs hierarchical + top-k dedup.
//!
//! Runs the real ragged pipeline (the four-phase hierarchical data path,
//! not a cost model) on skewed batches across gate arities and node
//! counts, and reports the **honest** traffic split: `bytes_on_wire` is
//! NIC traffic only (post-dedup, replication-index overhead included),
//! `bytes_intra_node` is the node-fabric bill. Asserts the invariants
//! this PR rests on:
//!
//! - aggregation alone never changes NIC bytes (every cross-node row
//!   still crosses once): flat and hier-without-dedup agree exactly;
//! - for k ≥ 2 on skewed batches, dedup **strictly** reduces NIC bytes
//!   and strictly cheapens the simulated exchange;
//! - for k = 1 the adaptive per-block decision never pays the index
//!   overhead (bytes identical to no-dedup);
//! - outputs are bit-identical across all three configurations.

use hetumoe::benchkit::Table;
use hetumoe::comm::schedule::CommChoice;
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::moe::{MoeLayer, MoeLayerOptions, StepReport};
use hetumoe::pipeline::ChunkChoice;
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::fmt_duration;

fn run_once(
    cfg: &MoeConfig,
    cluster: &ClusterConfig,
    shards: &[Tensor],
    alltoall: CommChoice,
    dedup: bool,
) -> (Vec<Tensor>, StepReport) {
    // Unchunked on purpose: the figure compares the simulated exchange
    // bill, so the comm charge must be the plain leg totals.
    let opts = MoeLayerOptions {
        alltoall,
        dedup,
        chunks: ChunkChoice::Fixed(1),
        threads: 1,
        ..Default::default()
    };
    let layer = MoeLayer::native(cfg.clone(), cluster.clone(), opts, 42).unwrap();
    layer.forward(shards).unwrap()
}

/// Skewed batch: tokens cluster around centroids aligned with the gate
/// columns of *adjacent* expert pairs (2c, 2c+1) — adjacent experts
/// always share a rank under the contiguous placement (experts-per-rank
/// is even here), so a top-k gate routes most tokens' top-2 replicas to
/// one node. This is the co-located-replica regime where HierMoE-style
/// dedup pays, constructed deterministically instead of hoping a random
/// batch happens to co-locate.
fn skewed_shards(
    gate_weight: &Tensor, // [d, E]
    w: usize,
    tokens: usize,
    d: usize,
    seed: u64,
) -> Vec<Tensor> {
    let mut rng = Rng::seed(seed);
    let e = gate_weight.row_len();
    let centroids: Vec<Vec<f32>> = (0..3)
        .map(|c| {
            let (e1, e2) = ((2 * c) % e, (2 * c + 1) % e);
            (0..d)
                .map(|i| 3.0 * (gate_weight.row(i)[e1] + gate_weight.row(i)[e2]))
                .collect()
        })
        .collect();
    (0..w)
        .map(|_| {
            let mut x = Tensor::zeros(&[tokens, d]);
            for t in 0..tokens {
                let c = &centroids[t % centroids.len()];
                let row = x.row_mut(t);
                for (i, v) in row.iter_mut().enumerate() {
                    *v = c[i] + 0.1 * rng.normal_f32();
                }
            }
            x
        })
        .collect()
}

fn main() {
    let d = 64usize;
    let tokens = 128usize;
    let mut table = Table::new(
        "Fig 13: NIC bytes per step, flat vs hier vs hier+dedup (ragged dispatch, skewed batches)",
        &[
            "gate",
            "k",
            "nodes",
            "NIC flat",
            "NIC hier",
            "NIC hier+dedup",
            "rows deduped",
            "intra hier",
            "exchange hier",
            "exchange dedup",
        ],
    );

    let mut k2_strict_savings = false;
    for &nodes in &[2usize, 4] {
        let cluster =
            ClusterConfig { nodes, gpus_per_node: 2, ..ClusterConfig::commodity(nodes) };
        let w = cluster.world();
        for (gate, k) in [
            (GateKind::Switch, 1usize),
            (GateKind::GShard, 2),
            (GateKind::TopK { k: 4 }, 4),
        ] {
            let cfg = MoeConfig {
                num_experts: 16,
                d_model: d,
                ffn_hidden: 2 * d,
                capacity_factor: 4.0,
                gate: gate.clone(),
            };
            // Same seed as `run_once`'s layers: identical gate weight.
            let probe =
                MoeLayer::native(cfg.clone(), cluster.clone(), Default::default(), 42)
                    .unwrap();
            let shards =
                skewed_shards(&probe.gate_weight, w, tokens, d, 7 + nodes as u64);

            let (fo, flat) = run_once(&cfg, &cluster, &shards, CommChoice::Flat, false);
            let (ho, hier) =
                run_once(&cfg, &cluster, &shards, CommChoice::Hierarchical, false);
            let (po, ded) =
                run_once(&cfg, &cluster, &shards, CommChoice::Hierarchical, true);

            // Bit-identity across all three data paths.
            for (x, y) in fo.iter().zip(&ho) {
                assert!(x.allclose(y, 0.0), "hier output diverged from flat");
            }
            for (x, y) in fo.iter().zip(&po) {
                assert!(x.allclose(y, 0.0), "dedup output diverged from flat");
            }

            // Aggregation alone never changes what crosses the NIC.
            assert_eq!(
                hier.bytes_on_wire, flat.bytes_on_wire,
                "{gate:?} nodes={nodes}: hier-without-dedup must move flat's NIC bytes"
            );
            assert!(ded.bytes_on_wire <= hier.bytes_on_wire);
            if k >= 2 {
                assert!(
                    ded.bytes_on_wire < hier.bytes_on_wire,
                    "{gate:?} nodes={nodes}: k={k} skewed batch must dedup strictly \
                     ({} vs {})",
                    ded.bytes_on_wire,
                    hier.bytes_on_wire
                );
                assert!(ded.rows_deduped > 0);
                // And the simulated exchange gets strictly cheaper.
                assert!(
                    ded.comm_total() < hier.comm_total(),
                    "{gate:?} nodes={nodes}: dedup must cheapen the exchange \
                     ({} vs {})",
                    ded.comm_total(),
                    hier.comm_total()
                );
                k2_strict_savings = true;
            } else {
                // k = 1: no replicas — the adaptive per-block decision
                // must not pay the index overhead.
                assert_eq!(ded.bytes_on_wire, hier.bytes_on_wire);
                assert_eq!(ded.rows_deduped, 0);
            }

            table.row(vec![
                gate.name().to_string(),
                k.to_string(),
                nodes.to_string(),
                format!("{:.1} KiB", flat.bytes_on_wire as f64 / 1024.0),
                format!("{:.1} KiB", hier.bytes_on_wire as f64 / 1024.0),
                format!("{:.1} KiB", ded.bytes_on_wire as f64 / 1024.0),
                ded.rows_deduped.to_string(),
                format!("{:.1} KiB", ded.bytes_intra_node as f64 / 1024.0),
                fmt_duration(hier.comm_total()),
                fmt_duration(ded.comm_total()),
            ]);
        }
    }
    table.emit(None);

    assert!(k2_strict_savings, "at least one k >= 2 config must show strict savings");
    println!(
        "fig13 invariants hold: honest NIC accounting, dedup strictly shrinks \
         inter-node traffic for k >= 2, k = 1 never pays overhead, outputs bit-identical."
    );
}
