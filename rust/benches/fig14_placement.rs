//! Fig 14 (ours): adaptive expert placement under Zipf-skewed traffic —
//! swap co-located hot experts apart, replicate a dominant expert, and
//! migrate live training state, all against the static contiguous
//! layout.
//!
//! Three parts, all deterministic:
//!
//! 1. **Swap (serving view).** A skewed batch concentrates on two
//!    experts that the contiguous formula co-locates on one node. The
//!    optimizer's table must strictly reduce both the max per-node NIC
//!    bytes (ground truth from the routed traffic matrix) and the
//!    predicted exchange round trip on the same batch.
//! 2. **Replicate.** A single dominant expert gains a second-node copy;
//!    the router's deterministic rotation splits its fan-in and the same
//!    two figures strictly improve.
//! 3. **Migrate (training).** An adaptive trainer with a skew-seeded
//!    traffic window migrates experts (params + both Adam moments,
//!    charged as a `migrate` comm phase), and its loss trajectory is
//!    **bitwise identical** to a from-scratch static run pinned to the
//!    final table — placement moves bytes and time, never numerics.

use hetumoe::backprop::{NativeTrainer, TrainRunConfig};
use hetumoe::benchkit::Table;
use hetumoe::comm::schedule::{pick_schedule, CommChoice};
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::placement::{
    max_node_nic_bytes, PlacementOptimizer, PlacementPolicy, ReplicaMap, TrafficWindow,
};
use hetumoe::serve::PlacementRouter;
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::fmt_duration;

/// A batch whose tokens cluster on the gate columns of `hot` experts —
/// the deterministic Zipf-head stand-in: the listed experts soak up the
/// whole batch, round-robin, everyone else starves.
fn skewed_batch(gate_weight: &Tensor, hot: &[usize], tokens: usize, seed: u64) -> Tensor {
    let d = gate_weight.rows();
    let mut rng = Rng::seed(seed);
    let centroids: Vec<Vec<f32>> = hot
        .iter()
        .map(|&e| (0..d).map(|i| 3.0 * gate_weight.row(i)[e]).collect())
        .collect();
    let mut x = Tensor::zeros(&[tokens, d]);
    for t in 0..tokens {
        let c = &centroids[t % centroids.len()];
        let row = x.row_mut(t);
        for (i, v) in row.iter_mut().enumerate() {
            *v = c[i] + 0.05 * rng.normal_f32();
        }
    }
    x
}

fn moe_cfg(d: usize) -> MoeConfig {
    MoeConfig {
        num_experts: 8,
        d_model: d,
        ffn_hidden: 2 * d,
        capacity_factor: 4.0,
        gate: GateKind::Switch,
    }
}

fn cluster() -> ClusterConfig {
    ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) }
}

/// `(max per-node NIC bytes, flat exchange round trip)` of one routed
/// traffic matrix — the two figures the whole bench compares.
fn figures(router: &PlacementRouter, counts: &[Vec<usize>], row_bytes: usize) -> (usize, f64) {
    let g = router.cluster.gpus_per_node;
    let nic = max_node_nic_bytes(counts, g, row_bytes);
    // Both layouts scored under the same (flat) schedule: the comparison
    // isolates what placement does to the wire, not schedule choice.
    let rt = pick_schedule(&router.net, counts, row_bytes, CommChoice::Flat).flat_time;
    (nic, rt)
}

fn main() {
    let d = 64usize;
    let tokens = 256usize;
    let row_bytes = d * 4;
    let mut table = Table::new(
        "Fig 14: adaptive placement vs static contiguous (Zipf-skewed batches, 2 nodes x 2 GPUs)",
        &["scenario", "layout", "max node NIC", "exchange RT", "gain"],
    );

    // ---- Part 1: swap co-located hot experts apart -------------------
    // Experts 0 and 1 share rank 0 (node 0) under the contiguous
    // formula; the skewed batch sends them the entire token stream.
    let mut r_static =
        PlacementRouter::new(moe_cfg(d), cluster(), CommChoice::Auto, 14).unwrap();
    let batch = skewed_batch(&r_static.gate_weight, &[0, 1], tokens, 140);
    let mut window = TrafficWindow::new(8);
    let mut last = None;
    for step in 0..8u64 {
        let dec = r_static.route_batch(&batch, step);
        window.observe(&dec.expert_counts);
        last = Some(dec);
    }
    let d_static = last.unwrap();
    assert!(
        d_static.expert_counts[0] + d_static.expert_counts[1]
            > d_static.expert_counts.iter().sum::<usize>() * 9 / 10,
        "the skewed batch must concentrate on experts 0 and 1: {:?}",
        d_static.expert_counts
    );
    let (nic_static, rt_static) = figures(&r_static, &d_static.counts, row_bytes);

    let opt = PlacementOptimizer { min_gain: 0.0, ..Default::default() };
    let current = r_static.placement();
    let delta = opt
        .propose(&window, &current, &ReplicaMap::new(8), &[], &r_static.net, row_bytes)
        .expect("co-located hot experts must yield an improving swap");
    assert!(!delta.moves.is_empty(), "the delta must move experts, not replicate");

    let mut r_adapt =
        PlacementRouter::new(moe_cfg(d), cluster(), CommChoice::Auto, 14).unwrap();
    r_adapt.set_table(Some(delta.table.clone())).unwrap();
    // The hot pair must no longer share a node (node = rank / 2 here).
    assert_ne!(
        r_adapt.rank_of_expert(0) / 2,
        r_adapt.rank_of_expert(1) / 2,
        "optimizer must split the hot pair across nodes: {:?}",
        delta.table
    );
    let d_adapt = r_adapt.route_batch(&batch, 0);
    assert_eq!(
        d_adapt.expert_counts, d_static.expert_counts,
        "placement must not change routing, only destinations"
    );
    let (nic_adapt, rt_adapt) = figures(&r_adapt, &d_adapt.counts, row_bytes);
    assert!(
        nic_adapt < nic_static,
        "swap must strictly cut the max per-node NIC load: {nic_adapt} vs {nic_static}"
    );
    assert!(
        rt_adapt < rt_static,
        "swap must strictly cut the exchange round trip: {rt_adapt} vs {rt_static}"
    );
    table.row(vec![
        "swap hot pair".into(),
        "static".into(),
        format!("{:.1} KiB", nic_static as f64 / 1024.0),
        fmt_duration(rt_static),
        "-".into(),
    ]);
    table.row(vec![
        "swap hot pair".into(),
        "adaptive".into(),
        format!("{:.1} KiB", nic_adapt as f64 / 1024.0),
        fmt_duration(rt_adapt),
        format!("{:.0}%", 100.0 * (1.0 - nic_adapt as f64 / nic_static as f64)),
    ]);

    // ---- Part 2: replicate a dominant expert -------------------------
    // One expert soaks up everything; a copy on the other node splits
    // its fan-in via the router's deterministic rotation.
    let mut r_one =
        PlacementRouter::new(moe_cfg(d), cluster(), CommChoice::Auto, 15).unwrap();
    let dom = skewed_batch(&r_one.gate_weight, &[0], tokens, 150);
    let d_one = r_one.route_batch(&dom, 0);
    assert!(
        d_one.expert_counts[0] > d_one.expert_counts.iter().sum::<usize>() * 9 / 10,
        "the dominant batch must concentrate on expert 0: {:?}",
        d_one.expert_counts
    );
    let (nic_one, rt_one) = figures(&r_one, &d_one.counts, row_bytes);

    let mut r_rep =
        PlacementRouter::new(moe_cfg(d), cluster(), CommChoice::Auto, 15).unwrap();
    r_rep.add_replica(0, 2).unwrap(); // rank 2 = node 1
    let d_rep = r_rep.route_batch(&dom, 0);
    assert!(d_rep.replicated, "the spread batch must be flagged replicated");
    assert_eq!(d_rep.expert_counts, d_one.expert_counts);
    let (nic_rep, rt_rep) = figures(&r_rep, &d_rep.counts, row_bytes);
    assert!(
        nic_rep < nic_one,
        "replication must strictly cut the max per-node NIC load: {nic_rep} vs {nic_one}"
    );
    assert!(
        rt_rep < rt_one,
        "replication must strictly cut the exchange round trip: {rt_rep} vs {rt_one}"
    );
    table.row(vec![
        "replicate dominant".into(),
        "static".into(),
        format!("{:.1} KiB", nic_one as f64 / 1024.0),
        fmt_duration(rt_one),
        "-".into(),
    ]);
    table.row(vec![
        "replicate dominant".into(),
        "adaptive".into(),
        format!("{:.1} KiB", nic_rep as f64 / 1024.0),
        fmt_duration(rt_rep),
        format!("{:.0}%", 100.0 * (1.0 - nic_rep as f64 / nic_one as f64)),
    ]);

    // ---- Part 3: live migration with bitwise-equal numerics ----------
    let train_cfg = TrainRunConfig {
        steps: 30,
        tokens_per_rank: 32,
        log_every: 0,
        seed: 11,
        placement: PlacementPolicy::Adaptive,
        placement_every: 5,
        placement_window: 64,
        placement_min_gain: 0.0,
        ..TrainRunConfig::default_run()
    };
    let mut a = NativeTrainer::new(train_cfg.clone()).unwrap();
    // Seed the traffic window with the Zipf head (experts 0 and 1 hot,
    // co-located on rank 0): the first placement check sees sustained
    // skew instead of waiting on the synthetic task to drift.
    for _ in 0..64 {
        a.traffic.observe(&[300, 300, 1, 1, 1, 1, 1, 1]);
    }
    let sa = a.run().unwrap();
    assert!(sa.migrations > 0, "the skewed window must trigger migrations");
    assert!(sa.bytes_migrated > 0, "migrations must charge real bytes");
    let migrate_charged = a
        .logs
        .iter()
        .any(|l| l.report.comm.iter().any(|(n, t)| n == "migrate" && *t > 0.0));
    assert!(migrate_charged, "the migrate phase must appear in a step's comm bill");
    let final_table = a
        .layer
        .opts
        .placement_table
        .clone()
        .expect("an applied migration must leave a live table installed");

    // From-scratch static run pinned to the final table: bitwise the
    // same trajectory — migration moved bytes, never numerics.
    let mut cfg_b = TrainRunConfig {
        placement: PlacementPolicy::Static,
        ..train_cfg
    };
    cfg_b.opts.placement_table = Some(final_table);
    let mut b = NativeTrainer::new(cfg_b).unwrap();
    let sb = b.run().unwrap();
    assert_eq!(sb.migrations, 0, "static never migrates");
    assert_eq!(
        a.losses(),
        b.losses(),
        "adaptive and pinned-static loss trajectories must be bitwise equal"
    );

    table.emit(None);
    println!(
        "fig14 invariants hold: adaptive placement strictly cuts the max per-node NIC \
         load and the exchange round trip on skewed traffic (swap {}% / replicate {}%), \
         migrated {} experts / {} bytes with a bitwise-unchanged loss trajectory.",
        (100.0 * (1.0 - nic_adapt as f64 / nic_static as f64)).round(),
        (100.0 * (1.0 - nic_rep as f64 / nic_one as f64)).round(),
        sa.migrations,
        sa.bytes_migrated
    );
}
