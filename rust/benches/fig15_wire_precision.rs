//! Fig 15 (ours): NIC bytes and predicted exchange time across wire
//! precisions — f32 vs bf16 vs f16, flat and hierarchical, dedup on/off.
//!
//! Runs the real ragged pipeline (payloads actually round-trip through
//! the compressed encodings, not a cost model) on skewed batches and
//! asserts the invariants the mixed-precision wire rests on:
//!
//! - **f32 is the identity**: explicit `--wire f32` moves exactly the
//!   bytes and produces exactly the outputs of the default options —
//!   the compressed legs are pay-to-play;
//! - **bf16 exactly halves NIC bytes** on every leg: payload rows go
//!   `d*4 → d*2`, and under dedup the replication index packs
//!   `u32+f32 → u16+bf16` and the presum entries `u32 → u16`, so the
//!   whole bill is 0.5× — not approximately, exactly;
//! - f16 moves the same byte count as bf16 (both 2-byte encodings);
//! - halved bytes make the simulated exchange strictly cheaper;
//! - quantization happens uniformly at exchange entry, so the flat and
//!   hierarchical data paths stay **bit-identical to each other** at
//!   every precision (only the precision itself moves the outputs, and
//!   only within the encoding's tolerance of the f32 run).

use hetumoe::benchkit::Table;
use hetumoe::comm::schedule::CommChoice;
use hetumoe::comm::WirePrecision;
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::moe::{MoeLayer, MoeLayerOptions, StepReport};
use hetumoe::pipeline::ChunkChoice;
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::fmt_duration;

fn run_once(
    cfg: &MoeConfig,
    cluster: &ClusterConfig,
    shards: &[Tensor],
    alltoall: CommChoice,
    dedup: bool,
    wire: WirePrecision,
) -> (Vec<Tensor>, StepReport) {
    // Unchunked on purpose: the figure compares the simulated exchange
    // bill, so the comm charge must be the plain leg totals.
    let opts = MoeLayerOptions {
        alltoall,
        dedup,
        wire,
        chunks: ChunkChoice::Fixed(1),
        threads: 1,
        ..Default::default()
    };
    let layer = MoeLayer::native(cfg.clone(), cluster.clone(), opts, 42).unwrap();
    layer.forward(shards).unwrap()
}

/// Skewed batch aligned with co-located expert pairs (same construction
/// as fig13) so the dedup × precision interaction is exercised, not
/// just the plain payload legs.
fn skewed_shards(
    gate_weight: &Tensor, // [d, E]
    w: usize,
    tokens: usize,
    d: usize,
    seed: u64,
) -> Vec<Tensor> {
    let mut rng = Rng::seed(seed);
    let e = gate_weight.row_len();
    let centroids: Vec<Vec<f32>> = (0..3)
        .map(|c| {
            let (e1, e2) = ((2 * c) % e, (2 * c + 1) % e);
            (0..d)
                .map(|i| 3.0 * (gate_weight.row(i)[e1] + gate_weight.row(i)[e2]))
                .collect()
        })
        .collect();
    (0..w)
        .map(|_| {
            let mut x = Tensor::zeros(&[tokens, d]);
            for t in 0..tokens {
                let c = &centroids[t % centroids.len()];
                let row = x.row_mut(t);
                for (i, v) in row.iter_mut().enumerate() {
                    *v = c[i] + 0.1 * rng.normal_f32();
                }
            }
            x
        })
        .collect()
}

fn max_abs(outs: &[Tensor]) -> f32 {
    outs.iter()
        .flat_map(|t| t.data().iter())
        .fold(0.0f32, |m, &v| m.max(v.abs()))
}

fn max_diff(a: &[Tensor], b: &[Tensor]) -> f32 {
    a.iter().zip(b).map(|(x, y)| x.max_abs_diff(y)).fold(0.0f32, f32::max)
}

fn main() {
    let d = 64usize;
    let tokens = 128usize;
    let nodes = 2usize;
    let cluster = ClusterConfig { nodes, gpus_per_node: 2, ..ClusterConfig::commodity(nodes) };
    let w = cluster.world();

    let mut table = Table::new(
        "Fig 15: NIC bytes per step across wire precisions (ragged dispatch, skewed batches)",
        &[
            "gate",
            "schedule",
            "dedup",
            "NIC f32",
            "NIC bf16",
            "NIC f16",
            "exchange f32",
            "exchange bf16",
            "max |out - f32|",
        ],
    );

    // Switch isolates the payload legs (k=1, nothing to dedup); TopK
    // exercises the packed replication index under every precision.
    for gate in [GateKind::Switch, GateKind::TopK { k: 4 }] {
        let cfg = MoeConfig {
            num_experts: 16,
            d_model: d,
            ffn_hidden: 2 * d,
            capacity_factor: 4.0,
            gate: gate.clone(),
        };
        // Same seed as `run_once`'s layers: identical gate weight.
        let probe =
            MoeLayer::native(cfg.clone(), cluster.clone(), Default::default(), 42).unwrap();
        let shards = skewed_shards(&probe.gate_weight, w, tokens, d, 15);

        for (schedule, dedup) in [
            (CommChoice::Flat, false),
            (CommChoice::Hierarchical, false),
            (CommChoice::Hierarchical, true),
        ] {
            let (o32, r32) = run_once(&cfg, &cluster, &shards, schedule, dedup, WirePrecision::F32);
            let (obf, rbf) =
                run_once(&cfg, &cluster, &shards, schedule, dedup, WirePrecision::Bf16);
            let (ohf, rhf) = run_once(&cfg, &cluster, &shards, schedule, dedup, WirePrecision::F16);

            // f32 is the identity: same outputs + same bill as the
            // default option set (which never mentions wire).
            let defaults = MoeLayerOptions {
                alltoall: schedule,
                dedup,
                chunks: ChunkChoice::Fixed(1),
                threads: 1,
                ..Default::default()
            };
            let layer = MoeLayer::native(cfg.clone(), cluster.clone(), defaults, 42).unwrap();
            let (od, rd) = layer.forward(&shards).unwrap();
            for (x, y) in o32.iter().zip(&od) {
                assert!(x.allclose(y, 0.0), "explicit --wire f32 diverged from defaults");
            }
            assert_eq!(r32.bytes_on_wire, rd.bytes_on_wire);
            assert_eq!(r32.bytes_intra_node, rd.bytes_intra_node);

            // bf16 exactly halves every leg of the NIC bill (payload,
            // dedup index, and presum entries all shrink 2x), and f16
            // moves the same bytes as bf16.
            assert_eq!(
                r32.bytes_on_wire,
                2 * rbf.bytes_on_wire,
                "{gate:?} {}/dedup={dedup}: bf16 must exactly halve NIC bytes",
                schedule.name(),
            );
            assert_eq!(
                r32.bytes_intra_node,
                2 * rbf.bytes_intra_node,
                "{gate:?} {}/dedup={dedup}: bf16 must exactly halve intra-node bytes",
                schedule.name(),
            );
            assert_eq!(rbf.bytes_on_wire, rhf.bytes_on_wire);
            assert_eq!(rbf.bytes_intra_node, rhf.bytes_intra_node);

            // Halved bytes must make the simulated exchange strictly
            // cheaper (latency terms are unchanged, bandwidth halves).
            assert!(
                rbf.comm_total() < r32.comm_total(),
                "{gate:?} {}/dedup={dedup}: compressed exchange must be cheaper \
                 ({} vs {})",
                schedule.name(),
                rbf.comm_total(),
                r32.comm_total(),
            );

            // Quantized outputs track the f32 run within the encoding's
            // tolerance: bf16 keeps 8 mantissa bits, f16 keeps 11.
            let scale = max_abs(&o32).max(1.0);
            let dbf = max_diff(&o32, &obf);
            let dhf = max_diff(&o32, &ohf);
            assert!(dbf <= 0.05 * scale, "bf16 outputs drifted: {dbf} vs scale {scale}");
            assert!(dhf <= 0.01 * scale, "f16 outputs drifted: {dhf} vs scale {scale}");
            assert!(dbf > 0.0, "bf16 must actually quantize (outputs identical to f32?)");

            if dedup {
                assert_eq!(
                    rbf.rows_deduped,
                    r32.rows_deduped,
                    "dedup decisions must not depend on wire",
                );
            }

            table.row(vec![
                gate.name().to_string(),
                schedule.name().to_string(),
                dedup.to_string(),
                format!("{:.1} KiB", r32.bytes_on_wire as f64 / 1024.0),
                format!("{:.1} KiB", rbf.bytes_on_wire as f64 / 1024.0),
                format!("{:.1} KiB", rhf.bytes_on_wire as f64 / 1024.0),
                fmt_duration(r32.comm_total()),
                fmt_duration(rbf.comm_total()),
                format!("{dbf:.4}"),
            ]);
        }

        // Uniform quantization at exchange entry keeps the flat and
        // hierarchical forward data paths bit-identical to each other
        // at every precision (dedup off isolates the payload legs; the
        // k>=2 dedup expansion re-weights with the same wire encoding).
        for wire in [WirePrecision::Bf16, WirePrecision::F16] {
            let (fo, _) = run_once(&cfg, &cluster, &shards, CommChoice::Flat, false, wire);
            let (ho, _) = run_once(&cfg, &cluster, &shards, CommChoice::Hierarchical, false, wire);
            for (x, y) in fo.iter().zip(&ho) {
                assert!(
                    x.allclose(y, 0.0),
                    "{gate:?} {}: flat/hier diverged under compressed wire",
                    wire.name()
                );
            }
        }
    }
    table.emit(None);

    println!(
        "fig15 invariants hold: f32 wire is the identity, bf16/f16 exactly halve \
         the NIC bill and cheapen the exchange, flat == hier bitwise at every precision."
    );
}
