//! Figure 1 reproduction: time breakdown of the MoE layer.
//!
//! Paper claims: (a) single node — gate + layout + AllToAll together
//! exceed 50% of MoE-layer time on a DeepSpeed-MoE profile; (b) multi-
//! node at 100 Gbps — AllToAll ≈ 99% of iteration time.
//!
//! Regenerated two ways: analytically at the paper's scale (TITAN RTX
//! roofline + α-β network), and measured on the real CPU pipeline at a
//! scaled config.

use hetumoe::baselines::{sim_step, SystemKind, SystemProfile};
use hetumoe::benchkit::Table;
use hetumoe::cluster::GpuModel;
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::coordinator::Coordinator;
use hetumoe::util::stats::fmt_duration;

fn main() {
    analytic();
    measured();
}

fn analytic() {
    let moe = MoeConfig { gate: GateKind::Switch, ..MoeConfig::paper_layer() };
    let gpu = GpuModel::a100(); // paper Fig 1 profiled on 8×A100
    let profile = SystemProfile::of(SystemKind::DeepSpeedMoE);

    let mut table = Table::new(
        "Fig 1 (analytic): DeepSpeed-MoE layer breakdown, batch 8/GPU, seq 1024",
        &["setting", "gate+layout", "alltoall", "expert", "MoE-specific share", "paper"],
    );
    for (name, nodes, tokens) in [("1 node × 8 GPUs", 1usize, 8 * 1024usize),
                                  ("8 nodes × 8 GPUs (100 Gbps)", 8, 2 * 1024)] {
        let cluster = ClusterConfig::commodity(nodes);
        let step = sim_step(&profile, &moe, &cluster, &gpu, tokens);
        let gate_layout = step.phase("gate") + step.phase("layout") + step.phase("reverse");
        let a2a = step.phase("alltoall");
        let expert = step.phase("expert");
        let share = (gate_layout + a2a) / step.total();
        table.row(vec![
            name.into(),
            format!("{} ({:.0}%)", fmt_duration(gate_layout), 100.0 * gate_layout / step.total()),
            format!("{} ({:.0}%)", fmt_duration(a2a), 100.0 * a2a / step.total()),
            format!("{} ({:.0}%)", fmt_duration(expert), 100.0 * expert / step.total()),
            format!("{:.0}%", share * 100.0),
            if nodes == 1 { ">50%".into() } else { "~99% (alltoall)".into() },
        ]);
    }
    table.emit(Some("bench_results/fig1_analytic.csv"));
}

fn measured() {
    // Real CPU pipeline at bench scale, DeepSpeed profile (dense einsum
    // dispatch): the measured gate+layout share must dominate too.
    let profile = SystemProfile::of(SystemKind::DeepSpeedMoE);
    let moe = MoeConfig { gate: GateKind::Switch, ..MoeConfig::bench_layer() };
    let cluster = ClusterConfig { nodes: 1, gpus_per_node: 4, ..ClusterConfig::commodity(1) };
    // 2048 tokens/rank: large enough that the dense dispatch einsum's
    // quadratic cost shows (at tiny batches the expert GEMM still hides it).
    let mut coord = Coordinator::new(moe, cluster, profile.options(1), 32_000, 2048, 0)
        .expect("coordinator");
    let summary = coord.run(3).expect("run");
    let mut table = Table::new(
        "Fig 1 (measured, CPU bench scale): DeepSpeed-profile MoE layer",
        &["phase", "mean/step", "fraction"],
    );
    for (name, t) in &summary.breakdown.phases {
        table.row(vec![
            name.clone(),
            fmt_duration(*t),
            format!("{:.1}%", 100.0 * t / summary.breakdown.total),
        ]);
    }
    table.emit(Some("bench_results/fig1_measured.csv"));
    let moe_specific = summary.breakdown.fraction_of(&["gate", "layout", "reverse", "alltoall"]);
    println!(
        "MoE-specific (gate+layout+alltoall) share: {:.1}% (paper: >50%)",
        100.0 * moe_specific
    );
}
