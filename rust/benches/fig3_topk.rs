//! Figure 3 reproduction: specialized top-k gating kernel vs the
//! generic (heap-based, "PyTorch-style") kernel, sweeping num_tokens ×
//! num_experts for k ∈ {1, 2}.
//!
//! Paper claim: ~25% average speedup. Here both kernels are real Rust
//! (same machine, same data); the speedup is measured wall-clock.

use hetumoe::benchkit::{bench, black_box, BenchOpts, Table};
use hetumoe::gating::topk::{topk_rows, topk_rows_heap};
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::fmt_duration;

fn main() {
    let opts = BenchOpts::quick();
    let mut rng = Rng::seed(0);
    let mut table = Table::new(
        "Fig 3: specialized vs generic top-k kernel (paper: ≈25% average speedup)",
        &["tokens", "experts", "k", "generic (heap)", "specialized", "speedup"],
    );
    let mut speedups = Vec::new();
    for &tokens in &[1024usize, 4096, 16384, 65536] {
        for &experts in &[16usize, 64, 256] {
            for &k in &[1usize, 2] {
                let scores = Tensor::randn(&[tokens, experts], &mut rng);
                let generic = bench("generic", &opts, || {
                    black_box(topk_rows_heap(black_box(&scores), k));
                });
                let fast = bench("fast", &opts, || {
                    black_box(topk_rows(black_box(&scores), k, 1));
                });
                let s = generic.median / fast.median;
                speedups.push(s);
                table.row(vec![
                    tokens.to_string(),
                    experts.to_string(),
                    k.to_string(),
                    fmt_duration(generic.median),
                    fmt_duration(fast.median),
                    format!("{s:.2}×"),
                ]);
            }
        }
    }
    table.emit(Some("bench_results/fig3_topk.csv"));
    let avg = speedups.iter().sum::<f64>() / speedups.len() as f64;
    let geo = (speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64).exp();
    println!("average speedup: {avg:.2}× (geomean {geo:.2}×) — paper: ≈1.25×");
}
