//! Figure 4 reproduction: optimized (counting-sort scatter) vs naive
//! (stable-sort + gather) layout transform.
//!
//! Paper claim: >26% improvement over the state-of-the-art
//! implementation. Both paths produce bit-identical buffers (asserted).

use hetumoe::benchkit::{bench, black_box, BenchOpts, Table};
use hetumoe::gating::{apply_capacity, Gate, SwitchGate};
use hetumoe::layout::{naive_layout, opt_layout};
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::fmt_duration;

fn main() {
    let opts = BenchOpts::quick();
    let mut rng = Rng::seed(0);
    let experts = 16usize;
    let mut table = Table::new(
        "Fig 4: layout transform, optimized vs sort-based (paper: ≥26% faster)",
        &["tokens", "d_model", "naive (sort)", "optimized", "speedup"],
    );
    let mut speedups = Vec::new();
    for &tokens in &[4096usize, 16384, 65536] {
        for &d in &[128usize, 512, 1024] {
            let x = Tensor::randn(&[tokens, d], &mut rng);
            let scores = Tensor::randn(&[tokens, experts], &mut rng);
            let routing = SwitchGate::new(experts, 1.25).route_scores(&scores, 0);
            let cap = ((tokens as f64 / experts as f64) * 1.25).ceil() as usize;
            let plan = apply_capacity(&routing, cap);

            // Correctness gate before timing.
            assert_eq!(opt_layout(&x, &plan, 1).data, naive_layout(&x, &plan).data);

            let naive = bench("naive", &opts, || {
                black_box(naive_layout(black_box(&x), black_box(&plan)));
            });
            let fast = bench("opt", &opts, || {
                black_box(opt_layout(black_box(&x), black_box(&plan), 1));
            });
            let s = naive.median / fast.median;
            speedups.push(s);
            table.row(vec![
                tokens.to_string(),
                d.to_string(),
                fmt_duration(naive.median),
                fmt_duration(fast.median),
                format!("{s:.2}×"),
            ]);
        }
    }
    table.emit(Some("bench_results/fig4_layout.csv"));
    let geo = (speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64).exp();
    println!("geomean speedup: {geo:.2}× — paper: ≥1.26×");
}
