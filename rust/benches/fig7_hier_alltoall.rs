//! Figure 7 reproduction: hierarchical vs flat (NCCL-style) AllToAll.
//!
//! Paper claims: 1.66× speedup on 4×8 GPUs, 2× on 8×8 GPUs (16 MB per
//! GPU, PCIe intra-node, one NIC per node). Timing is the simulated α–β
//! model; the data movement is real and asserted bit-identical.

use hetumoe::benchkit::Table;
use hetumoe::cluster::NetworkModel;
use hetumoe::comm::alltoall::flat_alltoall_timing;
use hetumoe::comm::hierarchical::hierarchical_alltoall_timing;
use hetumoe::comm::{alltoall, hierarchical_alltoall};
use hetumoe::config::ClusterConfig;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::fmt_duration;

fn main() {
    let payload: usize = 16 * 1024 * 1024; // paper's B = 16 MB per GPU

    let mut table = Table::new(
        "Fig 7: hierarchical AllToAll speedup (16 MB/GPU, 8 GPUs/node, 1×100 Gbps NIC)",
        &["cluster", "flat", "hierarchical", "speedup", "paper"],
    );
    for (nodes, paper) in [(2usize, "-"), (4, "1.66×"), (8, "2.0×")] {
        let net = NetworkModel::new(ClusterConfig::commodity(nodes));
        let chunk = payload / net.cfg.world();
        let flat = flat_alltoall_timing(&net, chunk);
        let hier = hierarchical_alltoall_timing(&net, chunk);
        table.row(vec![
            format!("{nodes}x8"),
            fmt_duration(flat.total),
            fmt_duration(hier.total),
            format!("{:.2}×", flat.total / hier.total),
            paper.into(),
        ]);
    }
    table.emit(Some("bench_results/fig7_hier_alltoall.csv"));

    // Semantics check with real data movement (small payload so the
    // bit-for-bit comparison is cheap).
    let net = NetworkModel::new(ClusterConfig::commodity(4));
    let w = net.cfg.world();
    let mut rng = Rng::seed(7);
    let mut a: Vec<Vec<f32>> =
        (0..w).map(|_| (0..w * 64).map(|_| rng.normal_f32()).collect()).collect();
    let mut b = a.clone();
    alltoall(&net, &mut a).unwrap();
    hierarchical_alltoall(&net, &mut b).unwrap();
    assert_eq!(a, b, "hierarchical must be a drop-in replacement");
    println!("semantics: hierarchical == flat (bit-identical) ✓");

    // Message-size sweep: where aggregation pays (the mechanism).
    let mut sweep = Table::new(
        "Fig 7 mechanism: speedup vs per-GPU payload (8x8 cluster)",
        &["payload/GPU", "flat msg size", "speedup"],
    );
    for mib in [1usize, 4, 16, 64, 256] {
        let payload = mib * 1024 * 1024;
        let net = NetworkModel::new(ClusterConfig::commodity(8));
        let chunk = payload / net.cfg.world();
        let flat = flat_alltoall_timing(&net, chunk).total;
        let hier = hierarchical_alltoall_timing(&net, chunk).total;
        sweep.row(vec![
            format!("{mib} MiB"),
            format!("{} KiB", chunk / 1024),
            format!("{:.2}×", flat / hier),
        ]);
    }
    sweep.emit(Some("bench_results/fig7_sweep.csv"));
    println!("(speedup shrinks as messages grow — aggregation pays in the latency-bound regime)");
}
