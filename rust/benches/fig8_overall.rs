//! Figure 8 reproduction: overall MoE-layer iteration time — HetuMoE vs
//! Tutel vs FastMoE vs DeepSpeed-MoE, Switch and GShard gates, batch
//! sweep.
//!
//! Paper claims: ≥15% over the baselines (18% vs FastMoE on Switch,
//! 15% on GShard); up to **8.1×** over DeepSpeed-MoE at batch 32
//! (Switch). Two tracks:
//!  1. analytic at paper scale (16 experts, d=2048, ffn 2048, seq 1024,
//!     TITAN RTX roofline) — the headline table;
//!  2. measured on the real CPU pipeline at bench scale — same pipeline
//!     options per system, real wall-clock.

use hetumoe::baselines::{sim_step, SystemKind, SystemProfile};
use hetumoe::benchkit::Table;
use hetumoe::cluster::GpuModel;
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::coordinator::Coordinator;
use hetumoe::util::stats::fmt_duration;

fn main() {
    for gate in [GateKind::Switch, GateKind::GShard] {
        analytic(gate);
    }
    measured(GateKind::Switch);
}

fn analytic(gate: GateKind) {
    let moe = MoeConfig { gate: gate.clone(), ..MoeConfig::paper_layer() };
    let cluster = ClusterConfig::commodity(1); // paper: single node × 8 GPUs
    let gpu = GpuModel::titan_rtx();
    let mut table = Table::new(
        &format!(
            "Fig 8 (analytic, paper scale): {} gate, per-GPU batch sweep, seq 1024",
            gate.name()
        ),
        &["batch", "HetuMoE", "Tutel", "FastMoE", "DeepSpeed", "FastMoE/Hetu", "DeepSpeed/Hetu"],
    );
    for batch in [16usize, 32, 64, 128] {
        let tokens = batch * 1024;
        let t: Vec<f64> = SystemKind::all()
            .iter()
            .map(|&k| sim_step(&SystemProfile::of(k), &moe, &cluster, &gpu, tokens).total())
            .collect();
        table.row(vec![
            batch.to_string(),
            fmt_duration(t[0]),
            fmt_duration(t[1]),
            fmt_duration(t[2]),
            fmt_duration(t[3]),
            format!("{:.2}×", t[2] / t[0]),
            format!("{:.2}×", t[3] / t[0]),
        ]);
    }
    table.emit(Some(&format!("bench_results/fig8_{}.csv", gate.name())));
    println!("paper: ≥1.15-1.18× vs FastMoE; up to 8.1× vs DeepSpeed at batch 32 (switch)\n");
}

fn measured(gate: GateKind) {
    // Real pipeline at CPU scale: d=256, seq-equivalent tokens per rank.
    let mut table = Table::new(
        "Fig 8 (measured, CPU bench scale): real pipeline wall-clock per step",
        &["tokens/rank", "HetuMoE", "Tutel", "FastMoE", "DeepSpeed", "DeepSpeed/Hetu"],
    );
    for tokens in [256usize, 1024] {
        let mut row = vec![tokens.to_string()];
        let mut times = Vec::new();
        for kind in SystemKind::all() {
            let profile = SystemProfile::of(kind);
            let moe = MoeConfig { gate: gate.clone(), ..MoeConfig::bench_layer() };
            let cluster =
                ClusterConfig { nodes: 1, gpus_per_node: 4, ..ClusterConfig::commodity(1) };
            let mut coord = Coordinator::new(moe, cluster, profile.options(1), 32_000, tokens, 0)
                .expect("coordinator");
            let summary = coord.run(3).expect("run");
            // Wall phases only (comm is simulated; identical world here).
            times.push(summary.breakdown.total);
            row.push(fmt_duration(summary.breakdown.total));
        }
        row.push(format!("{:.2}×", times[3] / times[0]));
        table.row(row);
    }
    table.emit(Some("bench_results/fig8_measured.csv"));
    println!("(the DeepSpeed column's blow-up is the dense one-hot dispatch einsum — the paper's mechanism)");
}
