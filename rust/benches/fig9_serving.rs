//! Figure 9 (new): online serving under open-loop load — arrival rate ×
//! gate type × AllToAll schedule.
//!
//! The training-side figures show hierarchical AllToAll winning on
//! fixed 16 MB payloads; this bench shows the same mechanism at serving
//! granularity, where batches are small and ragged. Per (rate, gate)
//! point the same Poisson trace is served twice — flat vs hierarchical —
//! and the table reports tail latency, goodput and drop rate. At
//! NIC-constrained rates the hierarchical schedule must win (asserted),
//! which is exactly why the serving router's `auto` mode exists.

use hetumoe::benchkit::Table;
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::serve::{ArrivalProcess, CommChoice, ServeConfig, ServeEngine};
use hetumoe::util::stats::fmt_duration;

fn run_point(rate: f64, gate: GateKind, comm: CommChoice) -> hetumoe::serve::SloReport {
    let cfg = ServeConfig {
        moe: MoeConfig {
            num_experts: 16,
            d_model: 64,
            ffn_hidden: 128,
            capacity_factor: 1.25,
            gate,
        },
        cluster: ClusterConfig::commodity(2), // 2×8 GPUs, one NIC per node
        process: ArrivalProcess::Poisson { rate },
        comm,
        slo: 0.05,
        duration: 0.5,
        seed: 42,
        ..ServeConfig::default_run()
    };
    let mut engine = ServeEngine::new(cfg).expect("serve config");
    engine.run().expect("serve run")
}

fn main() {
    let rates = [500.0, 2000.0, 8000.0];
    let gates = [GateKind::Switch, GateKind::GShard];

    let mut table = Table::new(
        "Fig 9: serving p95 latency / goodput, flat vs hierarchical AllToAll \
         (2x8 commodity GPUs, Poisson arrivals, 50 ms SLO)",
        &[
            "rate (req/s)",
            "gate",
            "flat p95",
            "hier p95",
            "flat goodput",
            "hier goodput",
            "flat drop",
            "hier drop",
            "p95 speedup",
        ],
    );

    let mut hier_wins_at_any_point = false;
    // Switch-gate results are reused by the Fig 9b auto comparison.
    let mut switch_points: Vec<(f64, f64, f64)> = Vec::new();
    for &rate in &rates {
        for gate in &gates {
            let flat = run_point(rate, gate.clone(), CommChoice::Flat);
            let hier = run_point(rate, gate.clone(), CommChoice::Hierarchical);
            if hier.latency.p95 < flat.latency.p95 && hier.goodput_tps >= flat.goodput_tps
            {
                hier_wins_at_any_point = true;
            }
            if *gate == GateKind::Switch {
                switch_points.push((rate, flat.latency.p95, hier.latency.p95));
            }
            table.row(vec![
                format!("{rate:.0}"),
                gate.name(),
                fmt_duration(flat.latency.p95),
                fmt_duration(hier.latency.p95),
                format!("{:.0} tok/s", flat.goodput_tps),
                format!("{:.0} tok/s", hier.goodput_tps),
                format!("{:.3}", flat.drop_rate),
                format!("{:.3}", hier.drop_rate),
                format!("{:.2}×", flat.latency.p95 / hier.latency.p95.max(1e-12)),
            ]);
        }
    }
    table.emit(Some("bench_results/fig9_serving.csv"));
    assert!(
        hier_wins_at_any_point,
        "hierarchical AllToAll must beat flat at >= 1 NIC-constrained rate point"
    );
    println!("hierarchical beats flat at >= 1 NIC-constrained arrival rate ✓");

    // The auto router should track (or beat) the better fixed schedule
    // per batch — show its decision mix across the rate sweep.
    let mut auto_table = Table::new(
        "Fig 9b: auto schedule selection per batch (switch gate)",
        &["rate (req/s)", "auto p95", "best-fixed p95", "flat/hier batches"],
    );
    for &(rate, flat_p95, hier_p95) in &switch_points {
        let best_fixed = flat_p95.min(hier_p95);

        let cfg = ServeConfig {
            process: ArrivalProcess::Poisson { rate },
            cluster: ClusterConfig::commodity(2),
            comm: CommChoice::Auto,
            slo: 0.05,
            duration: 0.5,
            seed: 42,
            ..ServeConfig::default_run()
        };
        let mut engine = ServeEngine::new(cfg).expect("serve config");
        let auto = engine.run().expect("serve run");
        let (f, h) = engine.router.comm_decisions();
        auto_table.row(vec![
            format!("{rate:.0}"),
            fmt_duration(auto.latency.p95),
            fmt_duration(best_fixed),
            format!("{f} / {h}"),
        ]);
    }
    auto_table.emit(Some("bench_results/fig9_serving_auto.csv"));
}
