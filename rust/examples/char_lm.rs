//! Character-level language modelling on real text (the embedded
//! Shakespeare corpus) through the `tiny` MoE-Transformer artifact —
//! the smallest full demonstration that all three layers compose on
//! non-synthetic data, plus checkpoint save/restore.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example char_lm -- [steps]
//! ```

use hetumoe::config::TrainConfig;
use hetumoe::data::{CharTokenizer, TINY_CORPUS};
use hetumoe::train::Trainer;
use hetumoe::util::rng::Rng;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let steps: usize = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(120);

    let cfg = TrainConfig { model: "tiny".into(), log_every: 1_000_000, ..TrainConfig::default_run() };
    let mut trainer = Trainer::new(cfg)?;
    let tok = CharTokenizer::fit(TINY_CORPUS);
    println!(
        "char LM on {} chars of Shakespeare (vocab {} ≤ artifact vocab {})",
        TINY_CORPUS.len(),
        tok.vocab_size(),
        trainer.vocab
    );
    assert!(tok.vocab_size() <= trainer.vocab);

    // Batch sampler over corpus windows.
    let seq_len = trainer.cfg.seq_len;
    let pairs = tok.training_pairs(TINY_CORPUS, seq_len);
    let mut rng = Rng::seed(0);
    let bs = trainer.cfg.batch_size;
    let sample = |rng: &mut Rng| {
        let mut xs = Vec::with_capacity(bs * seq_len);
        let mut ys = Vec::with_capacity(bs * seq_len);
        for _ in 0..bs {
            let (x, y) = &pairs[rng.below(pairs.len())];
            xs.extend_from_slice(x);
            ys.extend_from_slice(y);
        }
        (xs, ys)
    };

    let mut first = None;
    let mut last = 0.0f32;
    for step in 0..steps {
        let (x, y) = sample(&mut rng);
        last = trainer.train_step(&x, &y)?;
        first.get_or_insert(last);
        if step % 20 == 0 {
            println!("step {step:>4}  loss {last:.4}");
        }
    }
    let first = first.unwrap();
    println!("loss {first:.4} → {last:.4} over {steps} steps");
    assert!(last < first, "char LM must learn");

    // Checkpoint roundtrip: save, continue 1 step, restore, verify the
    // restored state reproduces the same next loss.
    let ckpt = std::env::temp_dir().join("hetumoe_char_lm.ckpt");
    trainer.save_checkpoint(&ckpt)?;
    let (x, y) = sample(&mut rng);
    let loss_a = trainer.train_step(&x, &y)?;
    trainer.load_checkpoint(&ckpt)?;
    let loss_b = trainer.train_step(&x, &y)?;
    println!("checkpoint determinism: {loss_a:.6} vs {loss_b:.6}");
    assert!((loss_a - loss_b).abs() < 1e-5);
    std::fs::remove_file(&ckpt).ok();
    println!("char_lm OK");
    Ok(())
}
