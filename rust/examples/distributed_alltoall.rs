//! Hierarchical vs flat AllToAll on simulated commodity clusters
//! (paper Figures 5–7): real data movement + simulated timing.
//!
//! ```bash
//! cargo run --release --example distributed_alltoall -- [payload_mib]
//! ```

use hetumoe::cluster::NetworkModel;
use hetumoe::comm::{alltoall, hierarchical_alltoall};
use hetumoe::config::ClusterConfig;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::{fmt_bytes, fmt_duration};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let payload_mib: f64 = std::env::args()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .unwrap_or(16.0);
    let payload_bytes = (payload_mib * 1024.0 * 1024.0) as usize;

    println!("AllToAll comparison — {} per GPU, 8 GPUs/node, 1 NIC/node\n", fmt_bytes(payload_bytes));
    println!("{:<7} {:>12} {:>14} {:>9}   correctness", "nodes", "flat", "hierarchical", "speedup");

    for nodes in [2usize, 4, 8] {
        let cluster = ClusterConfig::commodity(nodes);
        let net = NetworkModel::new(cluster.clone());
        let w = cluster.world();
        let elems_per_rank = (payload_bytes / 4 / w) * w; // divisible

        let mut rng = Rng::seed(nodes as u64);
        let make = |rng: &mut Rng| -> Vec<Vec<f32>> {
            (0..w).map(|_| (0..elems_per_rank).map(|_| rng.normal_f32()).collect()).collect()
        };
        let mut flat_bufs = make(&mut rng);
        let mut hier_bufs = flat_bufs.clone();

        let t_flat = alltoall(&net, &mut flat_bufs)?;
        let t_hier = hierarchical_alltoall(&net, &mut hier_bufs)?;
        let identical = flat_bufs == hier_bufs;

        println!(
            "{:<7} {:>12} {:>14} {:>8.2}×   {}",
            format!("{nodes}x8"),
            fmt_duration(t_flat.total),
            fmt_duration(t_hier.total),
            t_flat.total / t_hier.total,
            if identical { "bit-identical ✓" } else { "MISMATCH ✗" }
        );
        assert!(identical);

        // Phase detail for the largest cluster.
        if nodes == 8 {
            println!("\n  hierarchical phases at 8x8:");
            for (name, t) in &t_hier.phases {
                println!("    {name:<10} {}", fmt_duration(*t));
            }
            println!("  (paper Fig 7: 1.66× at 4x8 GPUs, 2× at 8x8 GPUs)");
        }
    }
    println!("\ndistributed_alltoall OK");
    Ok(())
}
