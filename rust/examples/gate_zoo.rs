//! The full gating-strategy zoo (paper Figure 2): route one batch
//! through all eight gate families and compare routing quality.
//!
//! ```bash
//! cargo run --release --example gate_zoo
//! ```

use hetumoe::config::{GateKind, HashScheme, MoeConfig};
use hetumoe::gating::{apply_capacity, make_gate, GateBatch};
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::{load_cv, normalized_entropy};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let tokens = 8192;
    let e = 16;
    let mut rng = Rng::seed(0);
    let scores = Tensor::randn(&[tokens, e], &mut rng);
    let embeddings = Tensor::randn(&[1024, 32], &mut rng);
    // Zipf-ish token ids (natural-language-like imbalance for the hash gates).
    let zipf = hetumoe::util::rng::Zipf::new(1024, 1.1);
    let token_ids: Vec<u32> = (0..tokens).map(|_| zipf.sample(&mut rng) as u32).collect();

    let gates = vec![
        GateKind::Switch,
        GateKind::GShard,
        GateKind::TopK { k: 4 },
        GateKind::KTop1 { k: 4 },
        GateKind::SamHTopK { groups: 4, k: 2 },
        GateKind::Base,
        GateKind::Hash { scheme: HashScheme::Random },
        GateKind::Hash { scheme: HashScheme::Balanced },
        GateKind::Hash { scheme: HashScheme::Clustered },
        GateKind::DenseToSparse { tau0: 2.0, tau_min: 0.1, anneal_steps: 1000 },
    ];

    println!("Gating zoo — {tokens} tokens, {e} experts (cf=1.25)\n");
    println!(
        "{:<16} {:>7} {:>9} {:>9} {:>9} {:>10}",
        "gate", "mean k", "load CV", "entropy", "aux", "drop rate"
    );
    for kind in gates {
        let cfg = MoeConfig {
            num_experts: e,
            d_model: 32,
            ffn_hidden: 64,
            capacity_factor: 1.25,
            gate: kind,
        };
        let gate = make_gate(&cfg, 1024, Some(&embeddings))?;
        // Step 500: mid-annealing for dense-to-sparse.
        let routing = gate.route(&GateBatch {
            scores: &scores,
            token_ids: Some(&token_ids),
            step: 500,
        });
        routing.validate()?;
        let plan = apply_capacity(&routing, cfg.capacity(tokens));
        let counts = routing.expert_counts();
        println!(
            "{:<16} {:>7.2} {:>9.3} {:>9.3} {:>9.3} {:>9.1}%",
            gate.name(),
            routing.mean_active_k(),
            load_cv(&counts),
            normalized_entropy(&counts),
            routing.aux_loss,
            100.0 * plan.drop_rate()
        );
    }
    println!("\nnotes:");
    println!("  · BASE achieves load CV = 0 by construction (balanced assignment)");
    println!("  · hash_balanced balances over the *vocab*; Zipf token draws still skew loads");
    println!("  · dense_to_sparse's mean k anneals from E toward 1 with the step count");
    println!("gate_zoo OK");
    Ok(())
}
