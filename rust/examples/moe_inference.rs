//! Artifact-backed inference: run the gate-scores and expert-FFN HLO
//! artifacts (L2 graphs containing the L1 Pallas top-1 kernel) from
//! Rust, assemble a full MoE layer forward, and verify against the
//! native implementation.
//!
//! ```bash
//! make artifacts && cargo run --release --example moe_inference
//! ```

use hetumoe::runtime::RuntimeClient;
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use std::time::Instant;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let mut rt = RuntimeClient::cpu("artifacts")?;
    println!("platform: {}", rt.platform());

    // --- gate scores + Pallas top-1 through PJRT ---
    let gate = rt.runner("gate_scores")?;
    let t = gate.meta.inputs[0][0];
    let d = gate.meta.inputs[0][1];
    let e = gate.meta.attr_usize("num_experts")?;
    let mut rng = Rng::seed(1);
    let x = Tensor::randn(&[t, d], &mut rng);
    let mut gw = Tensor::randn(&[d, e], &mut rng);
    gw.scale(1.0 / (d as f32).sqrt());

    let t0 = Instant::now();
    let outs = gate.run(&[x.clone(), gw.clone()])?;
    let gate_time = t0.elapsed();
    let (scores, idx_f32, weights) = (&outs[0], &outs[1], &outs[2]);
    println!(
        "gate_scores artifact: {t}×{d} tokens → scores {:?} in {:.1} ms (Pallas top-1 inside)",
        scores.shape(),
        gate_time.as_secs_f64() * 1e3
    );

    // Cross-check the artifact's routing against the native gate kernels.
    let native = hetumoe::nn::matmul(&x, &gw);
    let (nat_ids, _) = hetumoe::gating::topk::topk_rows(&native, 1, 1);
    let mut agree = 0usize;
    for i in 0..t {
        if nat_ids[i] == idx_f32.data()[i] as u32 {
            agree += 1;
        }
    }
    println!("top-1 agreement artifact vs native: {agree}/{t}");
    assert!(agree == t, "routing mismatch");

    // --- expert FFN through PJRT ---
    let expert = rt.runner("expert_ffn")?;
    let cap = expert.meta.attr_usize("capacity")?;
    let h = expert.meta.attr_usize("ffn_hidden")?;
    let ed = expert.meta.attr_usize("d_model")?;
    let rows = Tensor::randn(&[cap, ed], &mut rng);
    let mut w1 = Tensor::randn(&[ed, h], &mut rng);
    w1.scale(0.05);
    let b1 = Tensor::zeros(&[h]);
    let mut w2 = Tensor::randn(&[h, ed], &mut rng);
    w2.scale(0.05);
    let b2 = Tensor::zeros(&[ed]);
    let t1 = Instant::now();
    let y = expert.run(&[rows.clone(), w1.clone(), b1, w2.clone(), b2])?;
    println!(
        "expert_ffn artifact: [{cap}, {ed}] → {:?} in {:.1} ms",
        y[0].shape(),
        t1.elapsed().as_secs_f64() * 1e3
    );

    // Verify vs native GeLU MLP.
    let mut hid = hetumoe::nn::matmul(&rows, &w1);
    for v in hid.data_mut() {
        *v = hetumoe::nn::gelu(*v);
    }
    let native_y = hetumoe::nn::matmul(&hid, &w2);
    let diff = y[0].max_abs_diff(&native_y);
    println!("max |artifact − native| = {diff:.2e}");
    assert!(diff < 1e-3);

    let _ = weights;
    println!("moe_inference OK");
    Ok(())
}
