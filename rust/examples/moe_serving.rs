//! Serving walkthrough: turn the MoE layer into an online service.
//!
//! Runs the same gate + expert placement as the training pipeline under
//! open-loop traffic, shows continuous batching admitting work under the
//! expert-capacity/latency budgets, the router choosing flat vs
//! hierarchical AllToAll per batch, and the SLO report with tail
//! latencies, goodput and hot-expert tracking.
//!
//! ```bash
//! cargo run --release --example moe_serving
//! ```

use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::serve::{ArrivalProcess, CommChoice, ServeConfig, ServeEngine, Trace, WorkloadGen};

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // --- 1. a serving config over the paper's commodity cluster ---
    let cfg = ServeConfig {
        moe: MoeConfig {
            num_experts: 16,
            d_model: 64,
            ffn_hidden: 128,
            capacity_factor: 1.25,
            gate: GateKind::Switch,
        },
        cluster: ClusterConfig::commodity(2), // 2 nodes × 8 GPUs, 1 NIC each
        process: ArrivalProcess::Poisson { rate: 2000.0 },
        comm: CommChoice::Auto,
        slo: 0.05, // 50 ms per request
        duration: 1.0,
        seed: 7,
        ..ServeConfig::default_run()
    };
    println!(
        "cluster: {}x{} GPUs | {} experts ({} per rank) | gate {} | SLO {:.0} ms",
        cfg.cluster.nodes,
        cfg.cluster.gpus_per_node,
        cfg.moe.num_experts,
        cfg.moe.num_experts / cfg.cluster.world(),
        cfg.moe.gate.name(),
        cfg.slo * 1e3,
    );

    // --- 2. steady traffic, auto schedule selection ---
    let mut engine = ServeEngine::new(cfg.clone())?;
    println!(
        "admission budget: {} tokens/iteration (expert-capacity + latency budget)",
        engine.batch_token_budget()
    );
    let report = engine.run()?;
    report.emit();
    let (flat, hier) = engine.router.comm_decisions();
    println!("router schedule choices: {flat} flat, {hier} hierarchical");
    let hot = engine.router.hot_experts(1.5);
    println!("hot experts (>1.5x mean EWMA load): {hot:?}");

    // --- 3. the same trace under a traffic burst ---
    let mut bursty_cfg = cfg.clone();
    bursty_cfg.process = ArrivalProcess::Bursty {
        base_rate: 1000.0,
        burst_rate: 8000.0,
        mean_burst: 0.05,
        mean_calm: 0.2,
    };
    let mut bursty = ServeEngine::new(bursty_cfg)?;
    let burst_report = bursty.run()?;
    println!(
        "\nbursty traffic: p99 {:.1} ms (steady was {:.1} ms), drop rate {:.3}",
        burst_report.latency.p99 * 1e3,
        report.latency.p99 * 1e3,
        burst_report.drop_rate,
    );

    // --- 4. capture + replay a trace (regression workflow) ---
    let mut gen = WorkloadGen::new(
        ArrivalProcess::Poisson { rate: 1500.0 },
        cfg.min_tokens,
        cfg.max_tokens,
        cfg.slo,
        99,
    );
    let trace = Trace::from_requests(&gen.generate(0.5));
    let mut replayer = ServeEngine::new(cfg)?;
    let replayed = replayer.run_requests(&trace.requests(0.05))?;
    println!(
        "trace replay: {} requests, p50 {:.1} ms, goodput {:.0} tok/s",
        replayed.offered,
        replayed.latency.p50 * 1e3,
        replayed.goodput_tps,
    );

    println!("\nmoe_serving OK");
    Ok(())
}
