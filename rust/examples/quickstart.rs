//! Quickstart: build a 16-expert MoE layer, route a batch through the
//! full Algorithm-1 pipeline on a simulated 2×2 cluster, and print the
//! phase breakdown.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::moe::{MoeLayer, MoeLayerOptions};
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::fmt_duration;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // A small MoE layer: 16 experts, d=64, top-1 (Switch) routing.
    let moe = MoeConfig {
        num_experts: 16,
        d_model: 64,
        ffn_hidden: 128,
        capacity_factor: 1.25,
        gate: GateKind::Switch,
    };
    // Simulated cluster: 2 nodes × 2 GPUs, commodity network (PCIe +
    // one 100 Gbps NIC per node).
    let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
    let layer = MoeLayer::native(moe, cluster.clone(), MoeLayerOptions::default(), 0)?;

    // 256 tokens per rank.
    let mut rng = Rng::seed(42);
    let shards: Vec<Tensor> =
        (0..cluster.world()).map(|_| Tensor::randn(&[256, 64], &mut rng)).collect();

    let (outputs, report) = layer.forward(&shards)?;

    println!("HetuMoE quickstart — Algorithm 1 over {} simulated GPUs\n", cluster.world());
    println!("per-phase breakdown (local phases measured, comm simulated):");
    for (name, t) in &report.wall {
        println!("  {name:<18} {}", fmt_duration(*t));
    }
    for (name, t) in &report.comm {
        println!("  {name:<18} {} (simulated)", fmt_duration(*t));
    }
    println!("\nrouting: drop_rate={:.3} padding_waste={:.3} aux_loss={:.3}",
        report.drop_rate, report.padding_waste, report.aux_loss);
    println!("expert loads: {:?}", report.expert_counts);
    println!("output shards: {} × {:?}", outputs.len(), outputs[0].shape());

    // Verify against the dense reference.
    let reference = layer.reference_forward(&shards)?;
    let max_diff = outputs
        .iter()
        .zip(&reference)
        .map(|(a, b)| a.max_abs_diff(b))
        .fold(0.0f32, f32::max);
    println!("max |pipeline − reference| = {max_diff:.2e}");
    assert!(max_diff < 1e-4);
    println!("quickstart OK");
    Ok(())
}
