//! End-to-end validation driver (DESIGN.md §5 "E2E"): train the ~104M-
//! parameter MoE Transformer LM on synthetic data through the AOT
//! artifacts and log the loss curve.
//!
//! The model (6 layers × 64 experts, d=256 — Switch top-1 routing using
//! the Pallas top-1 kernel) was lowered once by `make artifacts`; this
//! binary is pure Rust + PJRT — Python is not involved.
//!
//! ```bash
//! make artifacts
//! cargo run --release --example train_moe_transformer -- [steps] [model]
//! ```

use hetumoe::config::TrainConfig;
use hetumoe::train::Trainer;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(200);
    let model = args.get(2).cloned().unwrap_or_else(|| "e2e".to_string());

    let cfg = TrainConfig { steps, model, log_every: 10, ..TrainConfig::default_run() };
    let mut trainer = Trainer::new(cfg)?;
    println!(
        "training '{}' on {} | {} parameter tensors, {} elements ({} steps, batch {}, seq {})",
        trainer.cfg.model,
        trainer.runtime.platform(),
        trainer.num_param_tensors(),
        trainer.num_params(),
        trainer.cfg.steps,
        trainer.cfg.batch_size,
        trainer.cfg.seq_len,
    );
    let logs = trainer.run()?;

    // Loss-curve summary for EXPERIMENTS.md.
    println!("\nloss curve (every 20 steps):");
    for l in logs.iter().step_by(20) {
        println!("  step {:>5}  loss {:.4}", l.step, l.loss);
    }
    let first = logs.first().unwrap();
    let last = logs.last().unwrap();
    let mean_wall: f64 = logs.iter().map(|l| l.wall).sum::<f64>() / logs.len() as f64;
    println!(
        "\nfinal: {:.4} → {:.4} over {} steps ({:.2}s/step mean)",
        first.loss,
        last.loss,
        logs.len(),
        mean_wall
    );
    assert!(
        last.loss < first.loss,
        "loss must decrease: {} → {}",
        first.loss,
        last.loss
    );
    println!("train_moe_transformer OK");
    Ok(())
}
