//! Adam optimizer (Kingma & Ba, 2015) over flat parameter slices.
//!
//! The trainer's parameters live in heterogeneous containers (router
//! weight, expert FFNs, classifier head), so the optimizer works on a
//! parallel list of `&mut [f32]` slices — one moment pair per tensor,
//! matched by position. Bias-corrected first/second moments, no
//! weight decay (the paper's benchmark setup).

/// Adam state for a fixed list of parameter tensors.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    t: u64,
    m: Vec<Vec<f32>>,
    v: Vec<Vec<f32>>,
}

impl Adam {
    /// Standard hyperparameters (β₁=0.9, β₂=0.999, ε=1e-8) with one
    /// moment pair per tensor size in `sizes`.
    pub fn new(lr: f32, sizes: &[usize]) -> Adam {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            t: 0,
            m: sizes.iter().map(|&s| vec![0.0; s]).collect(),
            v: sizes.iter().map(|&s| vec![0.0; s]).collect(),
        }
    }

    /// Steps taken so far.
    pub fn steps(&self) -> u64 {
        self.t
    }

    /// Snapshot the optimizer state (step counter + both moment lists)
    /// for checkpointing. Moments are cloned; exact f32 values.
    pub fn export_state(&self) -> (u64, Vec<Vec<f32>>, Vec<Vec<f32>>) {
        (self.t, self.m.clone(), self.v.clone())
    }

    /// Borrow one tensor's moment pair — expert migration serializes
    /// the moments alongside the parameters.
    pub fn moments(&self, idx: usize) -> (&[f32], &[f32]) {
        (&self.m[idx], &self.v[idx])
    }

    /// Overwrite one tensor's moment pair bitwise (the receive side of
    /// an expert migration). Lengths must match the built sizes.
    pub fn set_moments(&mut self, idx: usize, m: &[f32], v: &[f32]) {
        assert_eq!(m.len(), self.m[idx].len(), "moment m size mismatch");
        assert_eq!(v.len(), self.v[idx].len(), "moment v size mismatch");
        self.m[idx].copy_from_slice(m);
        self.v[idx].copy_from_slice(v);
    }

    /// Restore a state exported by [`Adam::export_state`]. The tensor
    /// list must match the sizes this optimizer was built with.
    pub fn restore_state(
        &mut self,
        t: u64,
        m: Vec<Vec<f32>>,
        v: Vec<Vec<f32>>,
    ) -> crate::error::Result<()> {
        let sizes: Vec<usize> = self.m.iter().map(|s| s.len()).collect();
        let msz: Vec<usize> = m.iter().map(|s| s.len()).collect();
        let vsz: Vec<usize> = v.iter().map(|s| s.len()).collect();
        if msz != sizes || vsz != sizes {
            return Err(crate::ckpt_err!(
                "Adam state shape mismatch: optimizer has {sizes:?}, checkpoint has m={msz:?} v={vsz:?}"
            ));
        }
        self.t = t;
        self.m = m;
        self.v = v;
        Ok(())
    }

    /// One update: `params[i]` and `grads[i]` must match the sizes the
    /// optimizer was built with, by position.
    pub fn step(&mut self, params: &mut [&mut [f32]], grads: &[&[f32]]) {
        assert_eq!(params.len(), self.m.len(), "param tensor count mismatch");
        assert_eq!(grads.len(), self.m.len(), "grad tensor count mismatch");
        self.t += 1;
        let bc1 = 1.0 - self.beta1.powi(self.t as i32);
        let bc2 = 1.0 - self.beta2.powi(self.t as i32);
        for (i, p) in params.iter_mut().enumerate() {
            let g = grads[i];
            assert_eq!(p.len(), self.m[i].len(), "tensor {i} size mismatch");
            assert_eq!(g.len(), self.m[i].len(), "grad {i} size mismatch");
            let m = &mut self.m[i];
            let v = &mut self.v[i];
            for j in 0..g.len() {
                m[j] = self.beta1 * m[j] + (1.0 - self.beta1) * g[j];
                v[j] = self.beta2 * v[j] + (1.0 - self.beta2) * g[j] * g[j];
                let mhat = m[j] / bc1;
                let vhat = v[j] / bc2;
                p[j] -= self.lr * mhat / (vhat.sqrt() + self.eps);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Adam on a convex quadratic must converge to the minimum.
    #[test]
    fn minimizes_quadratic() {
        let mut x = vec![5.0f32, -3.0];
        let mut opt = Adam::new(0.1, &[2]);
        for _ in 0..500 {
            let grads: Vec<f32> = x.iter().map(|&v| 2.0 * v).collect();
            opt.step(&mut [&mut x], &[&grads]);
        }
        assert!(x.iter().all(|&v| v.abs() < 1e-2), "x = {x:?}");
        assert_eq!(opt.steps(), 500);
    }

    /// First step moves every coordinate by exactly ±lr (bias-corrected
    /// Adam's signature property, up to eps).
    #[test]
    fn first_step_is_lr_sized() {
        let mut x = vec![1.0f32, -2.0, 3.0];
        let grads = [0.5f32, -0.25, 2.0];
        let mut opt = Adam::new(0.01, &[3]);
        opt.step(&mut [&mut x], &[&grads[..]]);
        let expect = [1.0 - 0.01, -2.0 + 0.01, 3.0 - 0.01];
        for (a, b) in x.iter().zip(&expect) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn multiple_tensors_update_independently() {
        let mut a = vec![1.0f32];
        let mut b = vec![1.0f32, 1.0];
        let mut opt = Adam::new(0.5, &[1, 2]);
        let ga = [1.0f32];
        let gb = [0.0f32, -1.0];
        opt.step(&mut [&mut a, &mut b], &[&ga[..], &gb[..]]);
        assert!(a[0] < 1.0);
        assert_eq!(b[0], 1.0, "zero grad leaves the param untouched");
        assert!(b[1] > 1.0);
    }

    /// Export → restore into a fresh optimizer must continue the exact
    /// same trajectory (checkpoint exactness depends on this).
    #[test]
    fn state_round_trip_is_exact() {
        let mut x = vec![5.0f32, -3.0];
        let mut opt = Adam::new(0.1, &[2]);
        for _ in 0..10 {
            let grads: Vec<f32> = x.iter().map(|&v| 2.0 * v).collect();
            opt.step(&mut [&mut x], &[&grads]);
        }
        let (t, m, v) = opt.export_state();
        let mut x2 = x.clone();
        let mut opt2 = Adam::new(0.1, &[2]);
        opt2.restore_state(t, m, v).unwrap();
        for _ in 0..10 {
            let g1: Vec<f32> = x.iter().map(|&v| 2.0 * v).collect();
            opt.step(&mut [&mut x], &[&g1]);
            let g2: Vec<f32> = x2.iter().map(|&v| 2.0 * v).collect();
            opt2.step(&mut [&mut x2], &[&g2]);
        }
        assert_eq!(x, x2, "restored optimizer must be bit-identical");
    }

    #[test]
    fn restore_rejects_wrong_shapes() {
        let mut opt = Adam::new(0.1, &[2]);
        let err = opt.restore_state(1, vec![vec![0.0; 3]], vec![vec![0.0; 3]]);
        assert!(err.is_err());
    }

    #[test]
    #[should_panic(expected = "size mismatch")]
    fn rejects_mismatched_sizes() {
        let mut x = vec![0.0f32; 3];
        let g = [0.0f32; 2];
        let mut opt = Adam::new(0.1, &[3]);
        opt.step(&mut [&mut x], &[&g[..]]);
    }
}
