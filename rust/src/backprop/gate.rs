//! Gate backward: combine-weight gradients → score gradients.
//!
//! The forward computes combine weights from the score matrix through a
//! softmax — a *full-row* softmax for Switch (the weight is the
//! winner's probability over all `E` experts) and a *subset* softmax
//! for Top-K/GShard (weights renormalized over the selected slots,
//! which is exactly a softmax restricted to the selected logits). The
//! expert *selection* itself is discrete and gets the standard
//! straight-through treatment: no gradient flows through which expert
//! won, only through the weights. Slots dropped by the capacity rule
//! contribute no output, so their incoming weight gradient is zero —
//! but they still sit in the subset softmax's normalization, so their
//! logits still receive gradient through the kept slots' weights.

use crate::config::GateKind;
use crate::error::Result;
use crate::gating::Routing;
use crate::nn::softmax_rows;
use crate::tensor::Tensor;

/// Backward of the gate's weight computation plus the auxiliary
/// load-balancing loss: given `d_weights[t*k + j]` (gradient of the
/// loss w.r.t. each slot's combine weight — zero for dropped or
/// inactive slots) and the auxiliary-loss coefficient, produce the
/// gradient w.r.t. the score matrix `[T, E]`.
pub fn gate_backward(
    kind: &GateKind,
    scores: &Tensor,
    routing: &Routing,
    d_weights: &[f32],
    aux_coef: f32,
) -> Result<Tensor> {
    let tokens = routing.tokens;
    let e = routing.num_experts;
    let k = routing.k;
    if d_weights.len() != tokens * k {
        return Err(crate::shape_err!(
            "d_weights must be tokens*k = {}, got {}",
            tokens * k,
            d_weights.len()
        ));
    }
    let mut probs = scores.clone();
    softmax_rows(&mut probs);
    let mut ds = Tensor::zeros(&[tokens, e]);
    match kind {
        GateKind::Switch => {
            // w = p_win over the full row: ds_i = dw·p_win·(δ_{i,win} − p_i).
            for t in 0..tokens {
                let dw = d_weights[t];
                if dw == 0.0 {
                    continue;
                }
                let win = routing.expert_ids[t] as usize;
                let p_win = routing.weights[t];
                let prow = probs.row(t);
                let drow = ds.row_mut(t);
                for (i, d) in drow.iter_mut().enumerate() {
                    let indicator = if i == win { 1.0 } else { 0.0 };
                    *d += dw * p_win * (indicator - prow[i]);
                }
            }
        }
        GateKind::TopK { .. } | GateKind::GShard => {
            // Subset softmax over the active slots:
            // ds_{sel_j} = w_j·(dw_j − Σ_m dw_m·w_m).
            for t in 0..tokens {
                let wslots = &routing.weights[t * k..(t + 1) * k];
                let dslots = &d_weights[t * k..(t + 1) * k];
                let g: f32 = wslots.iter().zip(dslots).map(|(w, d)| w * d).sum();
                let drow = ds.row_mut(t);
                for (j, &w) in wslots.iter().enumerate() {
                    if w == 0.0 {
                        continue; // inactive slot (e.g. GShard's dropped 2nd)
                    }
                    let ei = routing.expert_ids[t * k + j] as usize;
                    drow[ei] += w * (dslots[j] - g);
                }
            }
        }
        other => {
            return Err(crate::config_err!(
                "gate backward not implemented for {other:?} (Switch/TopK/GShard only)"
            ));
        }
    }
    if aux_coef != 0.0 {
        aux_loss_grad(&mut ds, &probs, routing, aux_coef);
    }
    Ok(ds)
}

/// Gradient of the Switch-style auxiliary load-balancing loss
/// `L = E · Σ_e (c_e/T)·(P_e/T)` (see [`crate::gating`]'s `aux_loss`),
/// accumulated into `ds` with coefficient `coef`. The assignment counts
/// `c_e` are discrete and treated as constants (the standard
/// straight-through treatment); the router probabilities `P_e`
/// differentiate through the softmax:
/// `∂L/∂s_{t,i} = (E/T²)·p_{t,i}·(c_i − Σ_e c_e·p_{t,e})`.
pub fn aux_loss_grad(ds: &mut Tensor, probs: &Tensor, routing: &Routing, coef: f32) {
    let tokens = routing.tokens;
    let e = routing.num_experts;
    let k = routing.k;
    if tokens == 0 {
        return;
    }
    // Top-1 assignment counts, matching aux_loss()'s `f` vector.
    let mut c = vec![0.0f32; e];
    for t in 0..tokens {
        c[routing.expert_ids[t * k] as usize] += 1.0;
    }
    let scale = coef * e as f32 / (tokens as f32 * tokens as f32);
    for t in 0..tokens {
        let prow = probs.row(t);
        let dot: f32 = prow.iter().zip(&c).map(|(p, ce)| p * ce).sum();
        let drow = ds.row_mut(t);
        for (i, d) in drow.iter_mut().enumerate() {
            *d += scale * prow[i] * (c[i] - dot);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gating::{aux_loss, Gate, SwitchGate, TopKGate};
    use crate::util::rng::Rng;

    /// Finite-difference check: loss = Σ_slot d_weights[slot]·w(slot)
    /// so its score gradient is exactly `gate_backward(..., 0.0)`.
    fn check_weight_grad(gate: &dyn Gate, kind: &GateKind, tokens: usize, e: usize, seed: u64) {
        let mut rng = Rng::seed(seed);
        let mut scores = Tensor::randn(&[tokens, e], &mut rng);
        // Widen the score gaps so the ±eps perturbations cannot cross a
        // discrete selection boundary (where the weight is continuous
        // but its derivative jumps).
        scores.scale(2.0);
        let routing = gate.route_scores(&scores, 0);
        let k = routing.k;
        let d_weights: Vec<f32> = (0..tokens * k).map(|_| rng.normal_f32()).collect();
        let ds = gate_backward(kind, &scores, &routing, &d_weights, 0.0).unwrap();

        let loss = |s: &Tensor| -> f64 {
            let r = gate.route_scores(s, 0);
            r.weights
                .iter()
                .zip(&d_weights)
                .map(|(&w, &d)| w as f64 * d as f64)
                .sum()
        };
        let eps = 1e-3f32;
        let mut sp = scores.clone();
        let mut checked = 0usize;
        for t in 0..tokens {
            for i in 0..e {
                let orig = sp.at(t, i);
                sp.set(t, i, orig + eps);
                let lp = loss(&sp);
                let ids_p = gate.route_scores(&sp, 0).expert_ids;
                sp.set(t, i, orig - eps);
                let lm = loss(&sp);
                let ids_m = gate.route_scores(&sp, 0).expert_ids;
                sp.set(t, i, orig);
                // Skip entries where the ±eps perturbation flipped the
                // discrete expert selection (detected exactly).
                if ids_p != routing.expert_ids || ids_m != routing.expert_ids {
                    continue;
                }
                let numeric = (lp - lm) / (2.0 * eps as f64);
                let analytic = ds.at(t, i) as f64;
                let scale = numeric.abs().max(analytic.abs()).max(0.1);
                assert!(
                    (numeric - analytic).abs() / scale < 5e-2,
                    "t={t} i={i}: numeric {numeric} vs analytic {analytic}"
                );
                checked += 1;
            }
        }
        assert!(checked > tokens * e / 2, "too few smooth entries checked");
    }

    #[test]
    fn switch_weight_grad_matches_finite_difference() {
        let gate = SwitchGate::new(6, 1.25);
        check_weight_grad(&gate, &GateKind::Switch, 12, 6, 11);
    }

    #[test]
    fn topk_weight_grad_matches_finite_difference() {
        let gate = TopKGate::new(6, 3);
        check_weight_grad(&gate, &GateKind::TopK { k: 3 }, 10, 6, 13);
    }

    #[test]
    fn aux_grad_matches_finite_difference() {
        let e = 5;
        let tokens = 16;
        let mut rng = Rng::seed(17);
        let scores = Tensor::randn(&[tokens, e], &mut rng);
        let gate = SwitchGate::new(e, 1.0);
        let routing = gate.route_scores(&scores, 0);
        let mut ds = Tensor::zeros(&[tokens, e]);
        let mut probs = scores.clone();
        softmax_rows(&mut probs);
        aux_loss_grad(&mut ds, &probs, &routing, 1.0);

        // L(s) with the assignment held fixed at the unperturbed top-1
        // (the straight-through treatment the gradient implements).
        let top1: Vec<u32> = (0..tokens).map(|t| routing.expert_ids[t]).collect();
        let loss = |s: &Tensor| -> f64 {
            let mut p = s.clone();
            softmax_rows(&mut p);
            aux_loss(&p, &top1, e) as f64
        };
        let eps = 1e-3f32;
        let mut sp = scores.clone();
        for t in 0..tokens {
            for i in 0..e {
                let orig = sp.at(t, i);
                sp.set(t, i, orig + eps);
                let lp = loss(&sp);
                sp.set(t, i, orig - eps);
                let lm = loss(&sp);
                sp.set(t, i, orig);
                let numeric = (lp - lm) / (2.0 * eps as f64);
                let analytic = ds.at(t, i) as f64;
                assert!(
                    (numeric - analytic).abs() < 5e-3,
                    "t={t} i={i}: numeric {numeric} vs analytic {analytic}"
                );
            }
        }
    }

    #[test]
    fn dropped_slots_get_zero_direct_grad_but_shape_holds() {
        let gate = SwitchGate::new(4, 1.0);
        let mut rng = Rng::seed(3);
        let scores = Tensor::randn(&[8, 4], &mut rng);
        let routing = gate.route_scores(&scores, 0);
        // All-zero d_weights (every slot dropped): no weight-path grad.
        let ds =
            gate_backward(&GateKind::Switch, &scores, &routing, &[0.0; 8], 0.0).unwrap();
        assert!(ds.data().iter().all(|&v| v == 0.0));
    }

    #[test]
    fn unsupported_gate_errors() {
        let gate = SwitchGate::new(4, 1.0);
        let mut rng = Rng::seed(4);
        let scores = Tensor::randn(&[4, 4], &mut rng);
        let routing = gate.route_scores(&scores, 0);
        let r = gate_backward(&GateKind::Base, &scores, &routing, &[0.0; 4], 0.0);
        assert!(r.is_err());
    }
}
