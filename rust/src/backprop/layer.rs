//! The differentiable MoE layer: the shared staged pipeline's cached
//! forward, and its exact backward through both dispatch pipelines.
//!
//! [`TrainMoeLayer`] owns concrete [`Ffn`] experts (the inference-path
//! [`crate::moe::MoeLayer`] hides executors behind a trait object, which
//! cannot expose parameters for updates). Construction from the same
//! seed replays [`crate::moe::MoeLayer::native`]'s RNG stream, so the
//! two layers hold identical parameters — and since **both layers now
//! consume the same [`crate::pipeline::StepExecutor`]** (this file no
//! longer carries its own copy of the six-step forward), the forward
//! outputs are bit-identical by construction *and* asserted in tests.
//! `forward_t` simply runs the executor's forward + cache flavor; the
//! returned [`TrainCache`] is the pipeline's [`ForwardCache`].
//!
//! The backward expresses the dispatch/combine gradients as the same
//! `comm/` exchanges on the transposed traffic: the gradient of the
//! combine leg travels the forward-dispatch routes (transpose of the
//! combine matrix), and the gradient of the dispatch leg travels the
//! forward-combine routes — which is exactly what reusing
//! [`ragged_dispatch_placed`] + [`ragged_combine_placed`] with the
//! forward `kept` matrix implements. Timing and bytes are charged through the same
//! cost models, the flat-vs-hier schedule is the forward's per-step
//! decision, and the backward exchanges get the same micro-chunked
//! comm/compute overlap as the forward: dispatch-of-chunk-*i* overlaps
//! FFN-backward-of-chunk-*i − 1*, with the chunk count re-picked from
//! the (identical) traffic matrix and the measured backward walls.

use crate::cluster::{ExpertPlacement, NetworkModel};
use crate::comm::hier_ragged::{
    dedup_traffic, hier_ragged_combine, hier_ragged_dispatch, row_meta, DedupMeta,
    DedupTraffic, PresumMeta, RowMeta,
};
use crate::comm::ragged::{ragged_combine_placed, ragged_dispatch_placed, split_wire_bytes};
use crate::comm::schedule::{transpose_counts, Schedule};
use crate::comm::{alltoall, hierarchical_alltoall, CommTiming, WireBytes, F32_BYTES};
use crate::config::{ClusterConfig, MoeConfig};
use crate::error::Result;
use crate::gating::{make_gate, DispatchPlan, Gate};
use crate::layout::{gather_expert_slices, scatter_expert_slices, RaggedLayoutBuffer};
use crate::moe::{validate_dead_ranks, CommImpl, DispatchMode, MoeLayerOptions, StepReport};
use crate::nn::{matmul_nt_par, matmul_tn_par, Ffn, FfnGrads};
use crate::obs::trace;
use crate::pipeline::executor::rank_expert_jobs;
use crate::pipeline::{ExpertBank, ForwardCache, OverlapTiming, StagePlan, StepExecutor};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::threadpool;
use std::time::Instant;

/// Parameter gradients of one expert FFN.
#[derive(Clone, Debug)]
pub struct ExpertGrads {
    pub dw1: Tensor, // [d, h]
    pub db1: Vec<f32>,
    pub dw2: Tensor, // [h, d]
    pub db2: Vec<f32>,
}

impl ExpertGrads {
    fn zeros(d: usize, h: usize) -> ExpertGrads {
        ExpertGrads {
            dw1: Tensor::zeros(&[d, h]),
            db1: vec![0.0; h],
            dw2: Tensor::zeros(&[h, d]),
            db2: vec![0.0; d],
        }
    }
}

/// Gradients of one layer backward pass.
#[derive(Clone, Debug)]
pub struct LayerGrads {
    /// Per-rank router-weight contributions `[d, E]`. The router weight
    /// is *replicated*, so these must be summed across ranks — the
    /// trainer charges that through `comm::allreduce`, mirroring the
    /// dense-gradient AllReduce of real MoE training.
    pub d_gate_weight: Vec<Tensor>,
    /// Per-expert parameter grads, index = global expert id. Expert
    /// parameters are *sharded* (the live [`ExpertPlacement`] names the
    /// owning rank — the contiguous `e/(E/W)` formula unless an
    /// adaptive table is installed), so no reduction is needed — the
    /// exchanges already delivered every gradient row to the owning
    /// rank.
    pub experts: Vec<ExpertGrads>,
}

/// Forward activations saved for [`TrainMoeLayer::backward`] — the
/// shared pipeline's cached-forward output ([`ForwardCache`]). The
/// input shards themselves are *not* cached — the caller still owns
/// them and passes them back to `backward` (no per-step copy).
pub type TrainCache = ForwardCache;

/// The trainable expert-parallel MoE layer.
pub struct TrainMoeLayer {
    pub cfg: MoeConfig,
    pub cluster: ClusterConfig,
    pub net: NetworkModel,
    pub gate: Box<dyn Gate>,
    /// Router weight `[d, E]` (replicated across ranks).
    pub gate_weight: Tensor,
    /// All `E` experts, index = global expert id.
    pub experts: Vec<Ffn>,
    pub opts: MoeLayerOptions,
}

impl TrainMoeLayer {
    /// Build with the exact RNG stream of [`crate::moe::MoeLayer::native`],
    /// so both layers hold bit-identical parameters for a given seed.
    pub fn native(
        cfg: MoeConfig,
        cluster: ClusterConfig,
        opts: MoeLayerOptions,
        seed: u64,
    ) -> Result<TrainMoeLayer> {
        cfg.validate()?;
        let w = cluster.world();
        if cfg.num_experts % w != 0 {
            return Err(crate::config_err!(
                "num_experts {} must divide by world {w}",
                cfg.num_experts
            ));
        }
        validate_dead_ranks(&opts, w)?;
        crate::moe::validate_placement_table(&opts, cfg.num_experts, w)?;
        let mut rng = Rng::seed(seed);
        let experts: Vec<Ffn> = (0..cfg.num_experts)
            .map(|_| Ffn::init(cfg.d_model, cfg.ffn_hidden, &mut rng))
            .collect();
        let mut gate_weight = Tensor::randn(&[cfg.d_model, cfg.num_experts], &mut rng);
        gate_weight.scale(1.0 / (cfg.d_model as f32).sqrt());
        let gate = make_gate(&cfg, 1, None)?;
        let net = NetworkModel::new(cluster.clone());
        Ok(TrainMoeLayer { cfg, cluster, net, gate, gate_weight, experts, opts })
    }

    /// The shared expert placement: the adaptive table when one is
    /// installed (`opts.placement_table`), elastically remapped when
    /// `opts.dead_ranks` marks ranks down.
    pub fn placement(&self) -> ExpertPlacement {
        ExpertPlacement::resolve(
            self.cfg.num_experts,
            self.cluster.world(),
            self.opts.placement_table.as_deref(),
            &self.opts.dead_ranks,
        )
    }

    /// Total trainable parameter count (router + experts).
    pub fn num_params(&self) -> usize {
        self.gate_weight.len() + self.experts.iter().map(|f| f.num_params()).sum::<usize>()
    }

    fn run_alltoall(&self, flat: &mut [Vec<f32>]) -> Result<CommTiming> {
        match self.opts.comm_impl {
            CommImpl::Flat => alltoall(&self.net, flat),
            CommImpl::Hierarchical => hierarchical_alltoall(&self.net, flat),
        }
    }

    /// Forward over per-rank token shards `[T, d]`, saving everything the
    /// backward needs — the shared pipeline's forward + cache flavor.
    /// Outputs are bit-identical to [`crate::moe::MoeLayer::forward`]
    /// with the same seed and options (same executor, same RNG stream).
    pub fn forward_t(
        &self,
        shards: &[Tensor],
        step: u64,
    ) -> Result<(Vec<Tensor>, StepReport, TrainCache)> {
        self.forward_t_with(shards, step, None)
    }

    /// [`TrainMoeLayer::forward_t`] with one step's timing faults folded
    /// into the report (`None` = healthy; see [`crate::fault`]).
    pub fn forward_t_with(
        &self,
        shards: &[Tensor],
        step: u64,
        faults: Option<&crate::fault::StepFaults>,
    ) -> Result<(Vec<Tensor>, StepReport, TrainCache)> {
        let route = |scores: &Tensor| self.gate.route_scores(scores, step);
        let exec = StepExecutor {
            cfg: &self.cfg,
            cluster: &self.cluster,
            net: &self.net,
            opts: &self.opts,
            gate_weight: &self.gate_weight,
            experts: ExpertBank::Train(&self.experts),
            route: &route,
            faults,
        };
        let out = exec.run(shards, true)?;
        let cache = out.cache.expect("cached flavor always returns a cache");
        Ok((out.outputs, out.report, cache))
    }

    /// Backward over per-rank upstream gradients `dy [T, d]`. `shards`
    /// must be the same inputs the forward ran on (the router-weight
    /// gradient needs them; they are not cached to avoid a per-step
    /// copy).
    ///
    /// Returns the input gradients (per rank), the parameter gradients,
    /// and a backward [`StepReport`] (wall phases `bwd_*`, comm phases
    /// `alltoall_*_bwd`, bytes-on-wire, schedule and overlap accounting
    /// of the backward exchanges) to be folded into the forward report
    /// via [`StepReport::absorb_backward`].
    pub fn backward(
        &self,
        shards: &[Tensor],
        dy_shards: &[Tensor],
        cache: &TrainCache,
        aux_coef: f32,
    ) -> Result<(Vec<Tensor>, LayerGrads, StepReport)> {
        let w = self.cluster.world();
        if dy_shards.len() != w || shards.len() != w {
            return Err(crate::shape_err!(
                "got {} shards / {} dy shards for world {w}",
                shards.len(),
                dy_shards.len()
            ));
        }
        let d = self.cfg.d_model;
        let mut report = StepReport::default();
        let mut step_span = trace::span("bwd_step");

        // ---- Combine backward: slot gradients + weighted dy scatter ----
        let s0 = Instant::now();
        let scatter_span = trace::span("bwd_scatter");
        let mut d_weights_all: Vec<Vec<f32>> = Vec::with_capacity(w);
        let mut dbufs: Vec<Vec<f32>> = Vec::with_capacity(w);
        for rank in 0..w {
            let plan = &cache.plans[rank];
            let dy = &dy_shards[rank];
            if dy.rows() != plan.tokens || dy.row_len() != d {
                return Err(crate::shape_err!("dy shard {rank} has wrong shape"));
            }
            let (dw, dbuf) =
                scatter_grad(plan, dy, &cache.expert_out[rank], d, self.opts.dispatch);
            d_weights_all.push(dw);
            dbufs.push(dbuf);
        }
        drop(scatter_span);
        report.wall.push(("bwd_scatter".into(), s0.elapsed().as_secs_f64() / w as f64));

        // ---- Backward exchanges + expert backward ----
        let mut grads = LayerGrads {
            d_gate_weight: Vec::with_capacity(w),
            experts: self
                .experts
                .iter()
                .map(|f| ExpertGrads::zeros(f.d, f.h))
                .collect(),
        };
        match self.opts.dispatch {
            DispatchMode::Ragged => {
                self.backward_exchange_ragged(
                    cache,
                    dy_shards,
                    &mut dbufs,
                    &mut grads,
                    &mut report,
                )?;
            }
            DispatchMode::Padded => {
                self.backward_exchange_padded(cache, &mut dbufs, &mut grads, &mut report)?;
            }
        }

        // ---- Reverse scatter: input grads from the expert path ----
        let r0 = Instant::now();
        let reverse_span = trace::span("bwd_reverse");
        let mut dx_shards: Vec<Tensor> = Vec::with_capacity(w);
        for rank in 0..w {
            let plan = &cache.plans[rank];
            let mut dx = Tensor::zeros(&[plan.tokens, d]);
            accumulate_input_grad(plan, &dbufs[rank], d, self.opts.dispatch, &mut dx);
            dx_shards.push(dx);
        }
        drop(reverse_span);
        report.wall.push(("bwd_reverse".into(), r0.elapsed().as_secs_f64() / w as f64));

        // ---- Gate backward: scores → router weight + input grads ----
        let g0 = Instant::now();
        let gate_span = trace::span("bwd_gate");
        for rank in 0..w {
            let ds = crate::backprop::gate::gate_backward(
                &self.cfg.gate,
                &cache.scores[rank],
                &cache.routings[rank],
                &d_weights_all[rank],
                aux_coef,
            )?;
            grads
                .d_gate_weight
                .push(matmul_tn_par(&shards[rank], &ds, self.opts.threads));
            dx_shards[rank]
                .add_assign(&matmul_nt_par(&ds, &self.gate_weight, self.opts.threads));
        }
        drop(gate_span);
        report.wall.push(("bwd_gate".into(), g0.elapsed().as_secs_f64() / w as f64));

        step_span.arg("comm_schedule", report.comm_schedule.as_str());
        step_span.arg("n_chunks", report.n_chunks);
        step_span.arg("bytes_on_wire", report.bytes_on_wire);
        step_span.arg("bytes_intra_node", report.bytes_intra_node);
        step_span.arg("rows_deduped", report.rows_deduped);
        Ok((dx_shards, grads, report))
    }

    fn backward_exchange_ragged(
        &self,
        cache: &TrainCache,
        dy_shards: &[Tensor],
        dbufs: &mut [Vec<f32>],
        grads: &mut LayerGrads,
        report: &mut StepReport,
    ) -> Result<()> {
        let w = self.cluster.world();
        let d = self.cfg.d_model;
        let g = self.cluster.gpus_per_node;
        let placement = self.placement();
        let counts = placement.traffic_matrix(&cache.kept);
        // Gradient rows cross the wire in the same format as the
        // forward's activations; accumulation back into f32 happens on
        // the receive side.
        let wire = self.opts.wire;
        let row_bytes = d * wire.elem_bytes();

        // The backward exchanges reuse the forward's per-step schedule
        // decision: gradient rows travel the same routes, so the same
        // traffic matrix (and therefore the same `pick_schedule`
        // outcome) governs both directions.
        let schedule = cache.schedule;
        // Under an elastic remap the forward forced the flat schedule
        // with dedup off; the backward mirrors that degraded mode.
        let dedup_on = self.opts.dedup && placement.is_contiguous();
        let dedup: Option<DedupTraffic> = dedup_on.then(|| {
            dedup_traffic(cache.plans.iter(), &placement, &self.cluster).with_wire(wire)
        });
        // Row metadata describes dedup groups and pre-sum runs; it is
        // only consumed when both the hierarchical schedule runs and
        // dedup is on.
        let metas: Vec<RowMeta> = match schedule {
            Schedule::Hierarchical if dedup_on => {
                cache.plans.iter().map(|p| row_meta(p, &placement, g)).collect()
            }
            _ => Vec::new(),
        };
        let mut rows_deduped = 0usize;

        // The combine-leg gradient travels the forward-dispatch routes
        // (data movement; timing is attributed per chunk below, so the
        // chunked backward is bit-identical by construction). Under the
        // hierarchical schedule this runs the real four-phase path;
        // with dedup, a token routed k ≥ 2 times to one node ships its
        // `dy` row once plus the slot weights, and the destination
        // leader re-applies `w · dy` — bit-identical to the source-side
        // multiply `scatter_grad` performed.
        let mut dispatch_span = trace::span("bwd_dispatch_data");
        dispatch_span.arg("schedule", schedule.name());
        let dispatch_wire: WireBytes = match schedule {
            Schedule::Flat => {
                ragged_dispatch_placed(
                    &self.net, dbufs, &cache.kept, d, schedule, &placement, wire,
                )?;
                split_wire_bytes(&counts, row_bytes, g)
            }
            Schedule::Hierarchical => {
                let dm = dedup_on
                    .then(|| DedupMeta { rows: &metas, payloads: dy_shards, scaled: true });
                let leg =
                    hier_ragged_dispatch(&self.net, dbufs, &cache.kept, d, dm.as_ref(), wire)?;
                rows_deduped += leg.rows_saved;
                leg.wire
            }
        };
        dispatch_span.arg("bytes_on_wire", dispatch_wire.inter);
        dispatch_span.arg("bytes_intra_node", dispatch_wire.intra);
        dispatch_span.arg("rows_deduped", rows_deduped);
        drop(dispatch_span);

        // Expert backward over each contiguous gradient batch; one
        // rank's batches run on the shared pool (disjoint outputs →
        // bit-identical to serial), wall measured per rank for the
        // overlap model's compute profile. The gradient buffers have
        // the forward receive layout, so the job scan is the forward's.
        let expert_span = trace::span("bwd_expert");
        let mut rank_wall = vec![0.0f64; w];
        for (r, buf) in dbufs.iter_mut().enumerate() {
            let jobs = rank_expert_jobs(&placement, &cache.kept, r, d);
            let x0 = Instant::now();
            let results = self.run_backward_jobs(&jobs, &buf[..], cache)?;
            for ((ge, off, n), fg) in jobs.into_iter().zip(results) {
                report.expert_flops += 2.0 * self.experts[ge].flops(n) as f64;
                buf[off..off + n * d].copy_from_slice(fg.dx.data());
                grads.experts[ge] =
                    ExpertGrads { dw1: fg.dw1, db1: fg.db1, dw2: fg.dw2, db2: fg.db2 };
            }
            rank_wall[r] = x0.elapsed().as_secs_f64();
        }
        drop(expert_span);
        report.wall.push(("bwd_expert".into(), rank_wall.iter().sum::<f64>() / w as f64));

        // ---- Chunked overlap on the transposed exchanges (the
        // StagePlan's chunk half): the backward region has the same
        // dispatch → expert → combine shape on the same traffic matrix,
        // so the same model applies. ----
        let compute_per_rank: Vec<f64> =
            rank_wall.iter().map(|t| t / w as f64).collect();
        let (stage_plan, overlap) = StagePlan::for_schedule(
            &self.net,
            &counts,
            row_bytes,
            schedule,
            self.opts.chunks,
            &compute_per_rank,
            dedup.as_ref(),
            dedup_on,
        );
        report.comm_schedule = stage_plan.schedule.name().into();
        report.comm.push(("alltoall_dispatch_bwd".into(), overlap.dispatch_total()));

        // The dispatch-leg gradient travels the forward-combine routes.
        // Under the hierarchical schedule with dedup, per-token partial
        // input gradients of one slot-run are pre-summed at the expert
        // node's leader before the return leg (the run total lands at
        // the head row, members arrive zero — the downstream per-slot
        // accumulation performs the flat path's exact addition order).
        let combine_span = trace::span("bwd_combine_data");
        let combine_wire: WireBytes = match schedule {
            Schedule::Flat => {
                ragged_combine_placed(
                    &self.net, dbufs, &cache.kept, d, schedule, &placement, wire,
                )?;
                split_wire_bytes(&transpose_counts(&counts), row_bytes, g)
            }
            Schedule::Hierarchical => {
                let pm = dedup_on.then(|| PresumMeta { rows: &metas });
                let leg =
                    hier_ragged_combine(&self.net, dbufs, &cache.kept, d, pm.as_ref(), wire)?;
                rows_deduped += leg.rows_saved;
                leg.wire
            }
        };
        drop(combine_span);
        report.comm.push(("alltoall_combine_bwd".into(), overlap.combine_total()));
        report.bytes_on_wire = dispatch_wire.inter + combine_wire.inter;
        report.bytes_intra_node = dispatch_wire.intra + combine_wire.intra;
        report.rows_deduped = rows_deduped;
        report.apply_overlap(&overlap);
        if trace::enabled() {
            let at = trace::model_window(overlap.critical_path);
            trace::model_overlap(
                at,
                "bwd_",
                &overlap,
                vec![
                    ("schedule".into(), schedule.name().into()),
                    ("bytes_on_wire".into(), report.bytes_on_wire.into()),
                    ("bytes_intra_node".into(), report.bytes_intra_node.into()),
                    ("rows_deduped".into(), rows_deduped.into()),
                ],
            );
        }
        Ok(())
    }

    /// Run one rank's per-expert FFN backward batches: `jobs` are
    /// disjoint `(global expert, element offset, rows)` regions of
    /// `buf`. Pool-parallel when `opts.threads > 1` — bit-identical to
    /// serial, each batch is an independent pure function.
    fn run_backward_jobs(
        &self,
        jobs: &[(usize, usize, usize)],
        buf: &[f32],
        cache: &TrainCache,
    ) -> Result<Vec<FfnGrads>> {
        let d = self.cfg.d_model;
        let run_one = |ge: usize, off: usize, n: usize| -> Result<FfnGrads> {
            let dy_e = Tensor::from_vec(buf[off..off + n * d].to_vec(), &[n, d])?;
            let fcache = cache.expert_caches[ge]
                .as_ref()
                .ok_or_else(|| crate::shape_err!("missing cache for expert {ge}"))?;
            Ok(self.experts[ge].backward(fcache, &dy_e))
        };
        threadpool::pooled(self.opts.threads, jobs.len(), |j| {
            let (ge, off, n) = jobs[j];
            run_one(ge, off, n)
        })
        .into_iter()
        .collect()
    }

    fn backward_exchange_padded(
        &self,
        cache: &TrainCache,
        dbufs: &mut [Vec<f32>],
        grads: &mut LayerGrads,
        report: &mut StepReport,
    ) -> Result<()> {
        let w = self.cluster.world();
        let d = self.cfg.d_model;
        let placement = self.placement();
        let epr = placement.experts_per_rank();
        let cap = cache.plans[0].capacity;
        report.comm_schedule = self.opts.comm_impl.name().into();

        let dispatch_span = trace::span("bwd_dispatch_data");
        let timing = self.run_alltoall(dbufs)?;
        drop(dispatch_span);
        report.comm.push(("alltoall_dispatch_bwd".into(), timing.total));

        let x0 = Instant::now();
        let expert_span = trace::span("bwd_expert");
        for (r, buf) in dbufs.iter_mut().enumerate() {
            if epr == 1 {
                // In-place fast path, mirroring the forward.
                let rows = Tensor::from_vec(std::mem::take(buf), &[w * cap, d])?;
                let fcache = cache.expert_caches[r]
                    .as_ref()
                    .ok_or_else(|| crate::shape_err!("missing cache for expert {r}"))?;
                let fg = self.experts[r].backward(fcache, &rows);
                report.expert_flops += 2.0 * self.experts[r].flops(w * cap) as f64;
                *buf = fg.dx.into_vec();
                grads.experts[r] =
                    ExpertGrads { dw1: fg.dw1, db1: fg.db1, dw2: fg.dw2, db2: fg.db2 };
                continue;
            }
            // One scratch per rank, reused across its local experts.
            let mut rows = Tensor::zeros(&[w * cap, d]);
            for le in 0..epr {
                let ge = placement.expert_of(r, le);
                gather_expert_slices(buf, &mut rows, w, epr, le, cap);
                let fcache = cache.expert_caches[ge]
                    .as_ref()
                    .ok_or_else(|| crate::shape_err!("missing cache for expert {ge}"))?;
                let fg = self.experts[ge].backward(fcache, &rows);
                report.expert_flops += 2.0 * self.experts[ge].flops(w * cap) as f64;
                scatter_expert_slices(buf, fg.dx.data(), w, epr, le, cap, d);
                grads.experts[ge] =
                    ExpertGrads { dw1: fg.dw1, db1: fg.db1, dw2: fg.dw2, db2: fg.db2 };
            }
        }
        drop(expert_span);
        let bwd_expert_wall = x0.elapsed().as_secs_f64() / w as f64;
        report.wall.push(("bwd_expert".into(), bwd_expert_wall));

        let combine_span = trace::span("bwd_combine_data");
        let timing2 = self.run_alltoall(dbufs)?;
        drop(combine_span);
        report.comm.push(("alltoall_combine_bwd".into(), timing2.total));
        // Placement-aware closed-form split, mirroring the forward's.
        let (nodes, g) = (self.cluster.nodes, self.cluster.gpus_per_node);
        let chunk_bytes = epr * cap * d * F32_BYTES;
        report.bytes_on_wire = 2 * (w * w - nodes * g * g) * chunk_bytes;
        report.bytes_intra_node = 2 * nodes * g * g.saturating_sub(1) * chunk_bytes;
        // Equal-chunk exchanges are never chunked: one-chunk overlap
        // model, fully exposed.
        let overlap = OverlapTiming {
            dispatch: vec![timing.total],
            compute: vec![bwd_expert_wall],
            combine: vec![timing2.total],
            critical_path: timing.total + bwd_expert_wall + timing2.total,
        };
        report.apply_overlap(&overlap);
        if trace::enabled() {
            let at = trace::model_window(overlap.critical_path);
            trace::model_overlap(
                at,
                "bwd_",
                &overlap,
                vec![
                    ("schedule".into(), self.opts.comm_impl.name().into()),
                    ("bytes_on_wire".into(), report.bytes_on_wire.into()),
                    ("bytes_intra_node".into(), report.bytes_intra_node.into()),
                ],
            );
        }
        Ok(())
    }
}

/// Combine backward: returns per-slot combine-weight gradients
/// (`dw_slot = dy_t · expert_out_row`) and the weighted
/// upstream-gradient buffer (`w_slot · dy_t` at the slot's row), in the
/// dispatch mode's source layout, ready for the backward dispatch
/// exchange. In padded mode the untouched padding rows stay zero and
/// vanish from every downstream gradient sum — the other half of the
/// padded/ragged bit-identical-gradients invariant.
fn scatter_grad(
    plan: &DispatchPlan,
    dy: &Tensor,
    expert_out: &[f32],
    d: usize,
    mode: DispatchMode,
) -> (Vec<f32>, Vec<f32>) {
    let offsets = plan.ragged_offsets();
    let rows = match mode {
        DispatchMode::Ragged => plan.occupied_rows(),
        DispatchMode::Padded => plan.buffer_rows(),
    };
    let mut d_weights = vec![0.0f32; plan.tokens * plan.k];
    let mut dbuf = vec![0.0f32; rows * d];
    for t in 0..plan.tokens {
        let dyrow = dy.row(t);
        for j in 0..plan.k {
            let slot = t * plan.k + j;
            let dest = plan.dest[slot];
            if dest == u32::MAX {
                continue;
            }
            let row = match mode {
                DispatchMode::Ragged => {
                    RaggedLayoutBuffer::ragged_row(&offsets, plan.capacity, dest as usize)
                }
                DispatchMode::Padded => dest as usize,
            };
            let orow = &expert_out[row * d..(row + 1) * d];
            let mut acc = 0.0f32;
            for (a, b) in dyrow.iter().zip(orow) {
                acc += a * b;
            }
            d_weights[slot] = acc;
            let wgt = plan.weights[slot];
            let drow = &mut dbuf[row * d..(row + 1) * d];
            for (o, &g) in drow.iter_mut().zip(dyrow) {
                *o = wgt * g;
            }
        }
    }
    (d_weights, dbuf)
}

/// Dispatch backward: gather each token's returned input-row gradients
/// (weights were already applied on the way out, so the sum here is
/// unweighted; dropped slots contribute nothing).
fn accumulate_input_grad(
    plan: &DispatchPlan,
    dbuf: &[f32],
    d: usize,
    mode: DispatchMode,
    dx: &mut Tensor,
) {
    let offsets = plan.ragged_offsets();
    for t in 0..plan.tokens {
        let dst = dx.row_mut(t);
        for j in 0..plan.k {
            let slot = t * plan.k + j;
            let dest = plan.dest[slot];
            if dest == u32::MAX {
                continue;
            }
            let row = match mode {
                DispatchMode::Ragged => {
                    RaggedLayoutBuffer::ragged_row(&offsets, plan.capacity, dest as usize)
                }
                DispatchMode::Padded => dest as usize,
            };
            let src = &dbuf[row * d..(row + 1) * d];
            for (o, &g) in dst.iter_mut().zip(src) {
                *o += g;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GateKind;
    use crate::moe::MoeLayer;
    use crate::nn::matmul;

    fn tiny_cfg(gate: GateKind) -> MoeConfig {
        MoeConfig {
            num_experts: 4,
            d_model: 8,
            ffn_hidden: 16,
            capacity_factor: 4.0,
            gate,
        }
    }

    fn small_cluster() -> ClusterConfig {
        ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) }
    }

    fn shards_for(world: usize, tokens: usize, d: usize, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::seed(seed);
        (0..world).map(|_| Tensor::randn(&[tokens, d], &mut rng)).collect()
    }

    #[test]
    fn forward_matches_inference_layer_bitwise() {
        for dispatch in [DispatchMode::Ragged, DispatchMode::Padded] {
            let opts = MoeLayerOptions { dispatch, ..Default::default() };
            let layer = MoeLayer::native(
                tiny_cfg(GateKind::Switch),
                small_cluster(),
                opts.clone(),
                42,
            )
            .unwrap();
            let train =
                TrainMoeLayer::native(tiny_cfg(GateKind::Switch), small_cluster(), opts, 42)
                    .unwrap();
            let shards = shards_for(4, 12, 8, 7);
            let (a, ra) = layer.forward(&shards).unwrap();
            let (b, rb, cache) = train.forward_t(&shards, 0).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert!(x.allclose(y, 0.0), "{dispatch:?}: outputs must be bit-identical");
            }
            assert_eq!(ra.expert_counts, rb.expert_counts);
            assert_eq!(ra.comm_schedule, rb.comm_schedule);
            assert_eq!(cache.plans.len(), 4);
        }
    }

    #[test]
    fn ragged_and_padded_backward_grads_bitwise_equal() {
        for gate in [GateKind::Switch, GateKind::TopK { k: 2 }, GateKind::GShard] {
            let mk = |dispatch| {
                TrainMoeLayer::native(
                    tiny_cfg(gate.clone()),
                    small_cluster(),
                    MoeLayerOptions { dispatch, ..Default::default() },
                    17,
                )
                .unwrap()
            };
            let ragged = mk(DispatchMode::Ragged);
            let padded = mk(DispatchMode::Padded);
            let shards = shards_for(4, 16, 8, 3);
            let dy = shards_for(4, 16, 8, 5);
            let (_, _, rc) = ragged.forward_t(&shards, 0).unwrap();
            let (_, _, pc) = padded.forward_t(&shards, 0).unwrap();
            let (rdx, rg, _) = ragged.backward(&shards, &dy, &rc, 0.01).unwrap();
            let (pdx, pg, _) = padded.backward(&shards, &dy, &pc, 0.01).unwrap();
            for (a, b) in rdx.iter().zip(&pdx) {
                assert!(a.allclose(b, 0.0), "{gate:?}: dx must be bit-identical");
            }
            for (a, b) in rg.d_gate_weight.iter().zip(&pg.d_gate_weight) {
                assert!(a.allclose(b, 0.0), "{gate:?}: d_gate_weight");
            }
            for (a, b) in rg.experts.iter().zip(&pg.experts) {
                assert!(a.dw1.allclose(&b.dw1, 0.0), "{gate:?}: dw1");
                assert!(a.dw2.allclose(&b.dw2, 0.0), "{gate:?}: dw2");
                assert_eq!(a.db1.len(), b.db1.len());
                for (x, y) in a.db1.iter().zip(&b.db1) {
                    assert!((x - y).abs() == 0.0, "{gate:?}: db1");
                }
                for (x, y) in a.db2.iter().zip(&b.db2) {
                    assert!((x - y).abs() == 0.0, "{gate:?}: db2");
                }
            }
        }
    }

    /// Finite-difference check of the full layer backward: scalar loss
    /// `L = Σ dy ⊙ Y(θ)` over every rank, checked against a sample of
    /// router-weight and expert-parameter entries.
    #[test]
    fn layer_backward_matches_finite_differences() {
        let cfg = tiny_cfg(GateKind::Switch);
        let cluster = small_cluster();
        let mut train =
            TrainMoeLayer::native(cfg, cluster, MoeLayerOptions::default(), 9).unwrap();
        let shards = shards_for(4, 8, 8, 21);
        let dy = shards_for(4, 8, 8, 23);
        let loss = |layer: &TrainMoeLayer| -> f64 {
            let (outs, _, _) = layer.forward_t(&shards, 0).unwrap();
            outs.iter()
                .zip(&dy)
                .map(|(o, g)| {
                    o.data()
                        .iter()
                        .zip(g.data())
                        .map(|(&a, &b)| a as f64 * b as f64)
                        .sum::<f64>()
                })
                .sum()
        };
        let (_, _, cache) = train.forward_t(&shards, 0).unwrap();
        let (_, grads, _) = train.backward(&shards, &dy, &cache, 0.0).unwrap();
        // Router weight: per-rank contributions sum to the full grad.
        let mut d_gw = Tensor::zeros(&[8, 4]);
        for g in &grads.d_gate_weight {
            d_gw.add_assign(g);
        }
        // The discrete expert selection makes the loss only piecewise
        // smooth in the router weight: a finite-difference entry is
        // valid only if the ±eps perturbations leave every token's
        // selection unchanged (detected exactly, not heuristically).
        let routing_ids = |layer: &TrainMoeLayer| -> Vec<Vec<u32>> {
            shards
                .iter()
                .map(|s| {
                    let scores = matmul(s, &layer.gate_weight);
                    layer.gate.route_scores(&scores, 0).expert_ids
                })
                .collect()
        };
        let base_ids = routing_ids(&train);
        let eps = 1e-2f32;
        let mut checked = 0usize;
        for idx in [0usize, 3, 5, 9, 13, 18, 22, 27, 30] {
            let orig = train.gate_weight.data()[idx];
            train.gate_weight.data_mut()[idx] = orig + eps;
            let lp = loss(&train);
            let ids_p = routing_ids(&train);
            train.gate_weight.data_mut()[idx] = orig - eps;
            let lm = loss(&train);
            let ids_m = routing_ids(&train);
            train.gate_weight.data_mut()[idx] = orig;
            if ids_p != base_ids || ids_m != base_ids {
                continue; // perturbation crossed a routing boundary
            }
            let numeric = (lp - lm) / (2.0 * eps as f64);
            let analytic = d_gw.data()[idx] as f64;
            let scale = numeric.abs().max(analytic.abs()).max(1.0);
            assert!(
                (numeric - analytic).abs() / scale < 5e-2,
                "gate_weight[{idx}]: numeric {numeric} vs analytic {analytic}"
            );
            checked += 1;
        }
        assert!(checked >= 3, "only {checked} smooth entries found");
        // Expert 0's first-layer weight.
        for idx in [0usize, 17, 40] {
            let orig = train.experts[0].w1.data()[idx];
            train.experts[0].w1.data_mut()[idx] = orig + eps;
            let lp = loss(&train);
            train.experts[0].w1.data_mut()[idx] = orig - eps;
            let lm = loss(&train);
            train.experts[0].w1.data_mut()[idx] = orig;
            let numeric = (lp - lm) / (2.0 * eps as f64);
            let analytic = grads.experts[0].dw1.data()[idx] as f64;
            let scale = numeric.abs().max(analytic.abs()).max(1.0);
            assert!(
                (numeric - analytic).abs() / scale < 5e-2,
                "expert0.w1[{idx}]: numeric {numeric} vs analytic {analytic}"
            );
        }
    }

    #[test]
    fn backward_report_attributes_comm_like_forward() {
        let train = TrainMoeLayer::native(
            tiny_cfg(GateKind::Switch),
            small_cluster(),
            MoeLayerOptions::default(),
            31,
        )
        .unwrap();
        let shards = shards_for(4, 16, 8, 29);
        let dy = shards_for(4, 16, 8, 33);
        let (_, mut report, cache) = train.forward_t(&shards, 0).unwrap();
        let (_, _, bwd) = train.backward(&shards, &dy, &cache, 0.01).unwrap();
        assert!(bwd.comm.iter().any(|(n, _)| n == "alltoall_dispatch_bwd"));
        assert!(bwd.comm.iter().any(|(n, _)| n == "alltoall_combine_bwd"));
        assert!(bwd.bytes_on_wire > 0);
        // Backward moves the same gradient rows the forward moved
        // tokens: identical traffic matrix. NIC bytes are equal on the
        // flat schedule; under hierarchical + dedup the backward's
        // pre-summed return leg can only *shave* bytes off the
        // forward's full-rate combine.
        assert!(bwd.bytes_on_wire <= report.bytes_on_wire);
        assert_eq!(bwd.bytes_intra_node, report.bytes_intra_node);
        if report.comm_schedule == "flat" {
            assert_eq!(bwd.bytes_on_wire, report.bytes_on_wire);
        }
        assert!(bwd.comm_schedule == "flat" || bwd.comm_schedule == "hier");
        // The backward region carries its own overlap accounting.
        assert!(bwd.n_chunks >= 1);
        assert!(bwd.critical_path > 0.0);
        report.absorb_backward(bwd);
        assert!(report.bytes_on_wire_bwd <= report.bytes_on_wire);
        assert!(report.bytes_on_wire_bwd > 0);
        assert!(!report.comm_schedule_bwd.is_empty());
        assert!(report.n_chunks_bwd >= 1);
        assert!(report.wall_phase("bwd_expert") >= 0.0);
    }

    #[test]
    fn dropped_tokens_block_expert_grads_but_not_gate_path() {
        let mut cfg = tiny_cfg(GateKind::Switch);
        cfg.capacity_factor = 0.25; // heavy drops
        let train =
            TrainMoeLayer::native(cfg, small_cluster(), MoeLayerOptions::default(), 3).unwrap();
        let shards = shards_for(4, 32, 8, 41);
        let dy = shards_for(4, 32, 8, 43);
        let (_, report, cache) = train.forward_t(&shards, 0).unwrap();
        assert!(report.drop_rate > 0.0);
        let (dx, _, _) = train.backward(&shards, &dy, &cache, 0.0).unwrap();
        // Dropped tokens get no expert-path gradient, but every token
        // still gets the gate-score path; shapes must hold.
        assert_eq!(dx.len(), 4);
        assert_eq!(dx[0].shape(), &[32, 8]);
    }
}
