//! Pure-Rust end-to-end MoE training: the backward pass of the full
//! Algorithm-1 pipeline, the auxiliary load-balancing loss gradient, an
//! Adam optimizer, and a native [`NativeTrainer`] loop — no `pjrt`
//! feature required.
//!
//! Gradient flow mirrors the forward pipeline in reverse (DESIGN.md §9):
//!
//! 1. **Combine backward** — the upstream gradient `dY` is split per
//!    routed slot: `d(expert output row) = w_slot · dY_t` (scattered
//!    with the same [`DispatchPlan`] the forward used; dropped tokens
//!    contribute nothing), and `d w_slot = dY_t · expert_out_row`.
//! 2. **Exchange backward** — the slot gradients travel to the expert
//!    ranks over the *same* routes as the forward dispatch (the
//!    backward of the combine leg is the transpose of the combine
//!    traffic, i.e. the forward dispatch matrix), reusing
//!    [`ragged_dispatch`]/[`ragged_combine`] and the
//!    `alltoallv_timing` cost models, so backward bytes-on-wire and
//!    schedule choice are attributed in [`StepReport`] exactly like the
//!    forward legs.
//! 3. **Expert backward** — each expert runs its FFN backward over its
//!    contiguous gradient batch ([`crate::nn::Ffn::backward`]),
//!    producing parameter grads and input-row grads.
//! 4. **Gate backward** — combine-weight gradients flow through the
//!    softmax (full-row for Switch, subset for Top-K/GShard) plus the
//!    auxiliary load-balancing loss gradient, into the router weight
//!    and the token inputs.
//! 5. **Gradient AllReduce** — replicated parameters (router weight,
//!    classifier head) sum their per-rank contributions through
//!    [`crate::comm::allreduce`]; expert parameters are sharded and
//!    need no reduction (that is the point of expert parallelism).
//!
//! Both dispatch modes are differentiable, and the ragged and padded
//! backward produce **bit-identical** gradients (the PR-2 forward
//! equivalence story extended to the backward pass; asserted in
//! `tests/backprop_training.rs`).
//!
//! [`DispatchPlan`]: crate::gating::DispatchPlan
//! [`ragged_dispatch`]: crate::comm::ragged::ragged_dispatch
//! [`ragged_combine`]: crate::comm::ragged::ragged_combine
//! [`StepReport`]: crate::moe::StepReport

pub mod adam;
pub mod gate;
pub mod layer;
pub mod trainer;

pub use adam::Adam;
pub use gate::{aux_loss_grad, gate_backward};
pub use layer::{ExpertGrads, LayerGrads, TrainCache, TrainMoeLayer};
pub use trainer::{smoothed_losses, NativeTrainer, TrainRunConfig, TrainStepLog, TrainSummary};
