//! The native training loop: residual MoE block + linear classifier
//! head over the cluster-correlated synthetic task, trained end-to-end
//! with Adam — pure Rust, no `pjrt` feature.
//!
//! Model per token row `x`:
//! `h = x + MoE(x)`, `logits = h·W_head + b_head`,
//! `L = CE(logits, label) + λ·mean_rank(aux)`.
//!
//! Every step exercises the full distributed pipeline: gate → dispatch
//! exchange → expert FFNs → combine exchange (forward), the transposed
//! exchanges + FFN/gate backward (backward), a gradient AllReduce for
//! the replicated router/head parameters, and an Adam update. The
//! [`StepReport`] carries forward *and* backward wall/comm phases, both
//! legs' bytes-on-wire, and the per-leg schedule choice.

use crate::backprop::adam::Adam;
use crate::backprop::layer::TrainMoeLayer;
use crate::comm::allreduce;
use crate::config::{ClusterConfig, GateKind, MoeConfig};
use crate::coordinator::metrics::{Breakdown, MetricsAgg};
use crate::data::ClusterTask;
use crate::error::Result;
use crate::moe::{MoeLayerOptions, StepReport};
use crate::nn::{log_softmax, matmul, matmul_nt, matmul_tn};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::stats::load_cv;
use std::time::Instant;

/// Configuration of one native training run.
#[derive(Clone, Debug)]
pub struct TrainRunConfig {
    pub moe: MoeConfig,
    pub cluster: ClusterConfig,
    pub opts: MoeLayerOptions,
    pub steps: usize,
    pub tokens_per_rank: usize,
    /// Classes of the synthetic task (= its cluster count).
    pub num_classes: usize,
    pub lr: f32,
    /// Auxiliary load-balancing loss coefficient λ.
    pub aux_coef: f32,
    /// Feature noise around each cluster centroid.
    pub noise: f32,
    pub seed: u64,
    pub log_every: usize,
}

impl TrainRunConfig {
    /// Small-but-real default: 8 experts on 2×2 simulated GPUs.
    pub fn default_run() -> TrainRunConfig {
        TrainRunConfig {
            moe: MoeConfig {
                num_experts: 8,
                d_model: 32,
                ffn_hidden: 64,
                capacity_factor: 1.5,
                gate: GateKind::Switch,
            },
            cluster: ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) },
            opts: MoeLayerOptions::default(),
            steps: 300,
            tokens_per_rank: 64,
            num_classes: 8,
            lr: 2e-3,
            aux_coef: 1e-2,
            noise: 0.3,
            seed: 0,
            log_every: 25,
        }
    }
}

/// One step's record.
#[derive(Clone, Debug)]
pub struct TrainStepLog {
    pub step: usize,
    /// Total objective: `ce + aux_coef·aux`.
    pub loss: f32,
    pub ce: f32,
    pub aux: f32,
    /// Coefficient of variation of the per-expert token loads.
    pub load_cv: f64,
    pub report: StepReport,
}

/// End-of-run summary.
#[derive(Clone, Debug)]
pub struct TrainSummary {
    pub steps: usize,
    pub final_loss: f32,
    pub breakdown: Breakdown,
    /// (flat, hier) schedule picks of the forward exchanges.
    pub fwd_schedules: (usize, usize),
    /// (flat, hier) schedule picks of the backward exchanges.
    pub bwd_schedules: (usize, usize),
}

/// Exponential smoothing of a loss curve (α = weight of the new value).
pub fn smoothed_losses(losses: &[f32], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(losses.len());
    let mut acc = None;
    for &l in losses {
        let s = match acc {
            None => l as f64,
            Some(prev) => (1.0 - alpha) * prev + alpha * l as f64,
        };
        acc = Some(s);
        out.push(s);
    }
    out
}

/// The native end-to-end trainer (see module docs).
pub struct NativeTrainer {
    pub cfg: TrainRunConfig,
    pub layer: TrainMoeLayer,
    /// Classifier head `[d, C]` (+ bias), replicated like the router.
    pub head_w: Tensor,
    pub head_b: Vec<f32>,
    pub logs: Vec<TrainStepLog>,
    task: ClusterTask,
    data_rng: Rng,
    opt: Adam,
    agg: MetricsAgg,
    step_idx: usize,
    fwd_flat: usize,
    fwd_hier: usize,
    bwd_flat: usize,
    bwd_hier: usize,
}

impl NativeTrainer {
    pub fn new(cfg: TrainRunConfig) -> Result<NativeTrainer> {
        let layer = TrainMoeLayer::native(
            cfg.moe.clone(),
            cfg.cluster.clone(),
            cfg.opts.clone(),
            cfg.seed,
        )?;
        let d = cfg.moe.d_model;
        let c = cfg.num_classes;
        if c < 2 {
            return Err(crate::config_err!("num_classes must be ≥ 2, got {c}"));
        }
        let mut rng = Rng::seed(cfg.seed ^ 0x4EAD);
        let mut head_w = Tensor::randn(&[d, c], &mut rng);
        head_w.scale(1.0 / (d as f32).sqrt());
        let head_b = vec![0.0f32; c];
        let task = ClusterTask::new(c, d, cfg.noise, cfg.seed);
        let data_rng = Rng::seed(cfg.seed ^ 0xDA7A);
        let mut sizes = vec![layer.gate_weight.len(), head_w.len(), c];
        for f in &layer.experts {
            sizes.extend([f.w1.len(), f.b1.len(), f.w2.len(), f.b2.len()]);
        }
        let opt = Adam::new(cfg.lr, &sizes);
        Ok(NativeTrainer {
            cfg,
            layer,
            head_w,
            head_b,
            logs: Vec::new(),
            task,
            data_rng,
            opt,
            agg: MetricsAgg::new(),
            step_idx: 0,
            fwd_flat: 0,
            fwd_hier: 0,
            bwd_flat: 0,
            bwd_hier: 0,
        })
    }

    /// Total trainable parameters (router + experts + head).
    pub fn num_params(&self) -> usize {
        self.layer.num_params() + self.head_w.len() + self.head_b.len()
    }

    /// One full training step: forward, loss, backward, gradient
    /// AllReduce of the replicated params, Adam update.
    pub fn step(&mut self) -> Result<TrainStepLog> {
        let w = self.cfg.cluster.world();
        let per = self.cfg.tokens_per_rank;
        let c = self.cfg.num_classes;
        let total_tokens = (w * per) as f32;

        // ---- Batch: per-rank shards of the cluster task ----
        let mut shards = Vec::with_capacity(w);
        let mut labels: Vec<Vec<u32>> = Vec::with_capacity(w);
        for _ in 0..w {
            let (x, y) = self.task.sample(per, &mut self.data_rng);
            shards.push(x);
            labels.push(y);
        }

        // ---- Forward: MoE block with residual, then the head ----
        let (moe_out, mut report, cache) =
            self.layer.forward_t(&shards, self.step_idx as u64)?;
        let mut h = moe_out;
        for (hr, xr) in h.iter_mut().zip(&shards) {
            hr.add_assign(xr);
        }
        let mut head_fwd = 0.0f64;
        let mut head_bwd = 0.0f64;
        let mut ce_sum = 0.0f64;
        let mut dh: Vec<Tensor> = Vec::with_capacity(w);
        let mut d_head_w: Vec<Tensor> = Vec::with_capacity(w);
        let mut d_head_b: Vec<Vec<f32>> = Vec::with_capacity(w);
        for rank in 0..w {
            let f0 = Instant::now();
            let mut logits = matmul(&h[rank], &self.head_w);
            for t in 0..per {
                let row = logits.row_mut(t);
                for (j, v) in row.iter_mut().enumerate() {
                    *v += self.head_b[j];
                }
            }
            log_softmax(&mut logits);
            let y = &labels[rank];
            for t in 0..per {
                ce_sum -= logits.at(t, y[t] as usize) as f64;
            }
            head_fwd += f0.elapsed().as_secs_f64();
            // dlogits = (softmax − onehot) / total_tokens.
            let b0 = Instant::now();
            let mut dl = logits;
            for v in dl.data_mut() {
                *v = v.exp();
            }
            for t in 0..per {
                let row = dl.row_mut(t);
                row[y[t] as usize] -= 1.0;
                for v in row.iter_mut() {
                    *v /= total_tokens;
                }
            }
            d_head_w.push(matmul_tn(&h[rank], &dl));
            let mut db = vec![0.0f32; c];
            for t in 0..per {
                for (j, &g) in dl.row(t).iter().enumerate() {
                    db[j] += g;
                }
            }
            d_head_b.push(db);
            dh.push(matmul_nt(&dl, &self.head_w));
            head_bwd += b0.elapsed().as_secs_f64();
        }
        report.wall.push(("head".into(), head_fwd / w as f64));
        report.wall.push(("bwd_head".into(), head_bwd / w as f64));
        let ce = (ce_sum / total_tokens as f64) as f32;
        let aux = report.aux_loss as f32;

        // ---- Backward through the MoE block ----
        // (The residual path's dx goes to the non-trainable input.)
        let (_dx, grads, bwd_report) =
            self.layer.backward(&shards, &dh, &cache, self.cfg.aux_coef / w as f32)?;
        report.absorb_backward(bwd_report);

        // ---- Gradient AllReduce for the replicated params ----
        let gw_len = self.layer.gate_weight.len();
        let hw_len = self.head_w.len();
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|r| {
                let mut v = Vec::with_capacity(gw_len + hw_len + c);
                v.extend_from_slice(grads.d_gate_weight[r].data());
                v.extend_from_slice(d_head_w[r].data());
                v.extend_from_slice(&d_head_b[r]);
                v
            })
            .collect();
        let timing = allreduce(&self.layer.net, &mut bufs)?;
        report.comm.push(("allreduce_grads".into(), timing.total));
        let reduced = bufs.swap_remove(0);
        let (gw_grad, rest) = reduced.split_at(gw_len);
        let (hw_grad, hb_grad) = rest.split_at(hw_len);

        // ---- Adam update over every trainable tensor ----
        let o0 = Instant::now();
        let mut params: Vec<&mut [f32]> = vec![
            self.layer.gate_weight.data_mut(),
            self.head_w.data_mut(),
            self.head_b.as_mut_slice(),
        ];
        let mut grad_slices: Vec<&[f32]> = vec![gw_grad, hw_grad, hb_grad];
        for (f, g) in self.layer.experts.iter_mut().zip(&grads.experts) {
            params.push(f.w1.data_mut());
            params.push(f.b1.as_mut_slice());
            params.push(f.w2.data_mut());
            params.push(f.b2.as_mut_slice());
            grad_slices.push(g.dw1.data());
            grad_slices.push(&g.db1);
            grad_slices.push(g.dw2.data());
            grad_slices.push(&g.db2);
        }
        self.opt.step(&mut params, &grad_slices);
        // Per-rank mean like every other wall phase (expert params are
        // sharded E/W per rank; the replicated router/head update is
        // negligible next to them).
        report.wall.push(("optimizer".into(), o0.elapsed().as_secs_f64() / w as f64));

        // ---- Bookkeeping ----
        match report.comm_schedule.as_str() {
            "flat" => self.fwd_flat += 1,
            "hier" => self.fwd_hier += 1,
            _ => {}
        }
        match report.comm_schedule_bwd.as_str() {
            "flat" => self.bwd_flat += 1,
            "hier" => self.bwd_hier += 1,
            _ => {}
        }
        self.agg.push(&report);
        let log = TrainStepLog {
            step: self.step_idx,
            loss: ce + self.cfg.aux_coef * aux,
            ce,
            aux,
            load_cv: load_cv(&report.expert_counts),
            report,
        };
        self.step_idx += 1;
        self.logs.push(log.clone());
        Ok(log)
    }

    /// Run `cfg.steps` steps; returns the summary (per-step logs stay in
    /// `self.logs`). Fails fast on divergence (non-finite loss).
    pub fn run(&mut self) -> Result<TrainSummary> {
        for _ in 0..self.cfg.steps {
            let log = self.step()?;
            if !log.loss.is_finite() {
                return Err(crate::error::HetuError::Runtime(format!(
                    "loss diverged (NaN/inf) at step {}",
                    log.step
                )));
            }
            if self.cfg.log_every > 0 && log.step % self.cfg.log_every == 0 {
                eprintln!(
                    "step {:>5}  loss {:.4}  ce {:.4}  aux {:.3}  load_cv {:.3}",
                    log.step, log.loss, log.ce, log.aux, log.load_cv
                );
            }
        }
        Ok(self.summary())
    }

    /// Summary over everything run so far.
    pub fn summary(&self) -> TrainSummary {
        TrainSummary {
            steps: self.step_idx,
            final_loss: self.logs.last().map(|l| l.loss).unwrap_or(f32::NAN),
            breakdown: self.agg.breakdown(),
            fwd_schedules: (self.fwd_flat, self.fwd_hier),
            bwd_schedules: (self.bwd_flat, self.bwd_hier),
        }
    }

    /// Per-step total losses.
    pub fn losses(&self) -> Vec<f32> {
        self.logs.iter().map(|l| l.loss).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::DispatchMode;

    fn quick_cfg() -> TrainRunConfig {
        TrainRunConfig {
            moe: MoeConfig {
                num_experts: 4,
                d_model: 16,
                ffn_hidden: 32,
                capacity_factor: 2.0,
                gate: GateKind::Switch,
            },
            cluster: ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) },
            opts: MoeLayerOptions::default(),
            steps: 30,
            tokens_per_rank: 16,
            num_classes: 4,
            lr: 5e-3,
            aux_coef: 1e-2,
            noise: 0.3,
            seed: 0,
            log_every: 0,
        }
    }

    #[test]
    fn short_run_reduces_loss_and_reports_both_directions() {
        let mut t = NativeTrainer::new(quick_cfg()).unwrap();
        let summary = t.run().unwrap();
        assert_eq!(summary.steps, 30);
        let losses = t.losses();
        let first5: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let last5: f32 = losses[25..].iter().sum::<f32>() / 5.0;
        assert!(
            last5 < first5,
            "loss must move down even in 30 steps: {first5} → {last5}"
        );
        let log = t.logs.last().unwrap();
        assert!(log.report.bytes_on_wire > 0);
        assert!(log.report.bytes_on_wire_bwd > 0);
        assert!(!log.report.comm_schedule_bwd.is_empty());
        assert!(log.report.comm.iter().any(|(n, _)| n == "allreduce_grads"));
        assert!(log.report.wall.iter().any(|(n, _)| n == "optimizer"));
        let (ff, fh) = summary.fwd_schedules;
        assert_eq!(ff + fh, 30);
        let (bf, bh) = summary.bwd_schedules;
        assert_eq!(bf + bh, 30);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = NativeTrainer::new(quick_cfg()).unwrap();
        let mut b = NativeTrainer::new(quick_cfg()).unwrap();
        for _ in 0..5 {
            let la = a.step().unwrap();
            let lb = b.step().unwrap();
            assert_eq!(la.loss, lb.loss);
            assert_eq!(la.report.expert_counts, lb.report.expert_counts);
        }
        let mut c = NativeTrainer::new(TrainRunConfig { seed: 1, ..quick_cfg() }).unwrap();
        let lc = c.step().unwrap();
        assert_ne!(lc.loss, a.logs[0].loss);
    }

    #[test]
    fn padded_mode_also_trains() {
        let cfg = TrainRunConfig {
            opts: MoeLayerOptions { dispatch: DispatchMode::Padded, ..Default::default() },
            steps: 5,
            ..quick_cfg()
        };
        let mut t = NativeTrainer::new(cfg).unwrap();
        let summary = t.run().unwrap();
        assert_eq!(summary.steps, 5);
        assert!(summary.final_loss.is_finite());
    }

    #[test]
    fn smoothing_is_monotone_on_monotone_input() {
        let xs: Vec<f32> = (0..50).map(|i| 5.0 - 0.1 * i as f32).collect();
        let s = smoothed_losses(&xs, 0.2);
        assert_eq!(s.len(), 50);
        for w in s.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
        assert!(smoothed_losses(&[], 0.5).is_empty());
    }
}
