//! The native training loop: residual MoE block + linear classifier
//! head over the cluster-correlated synthetic task, trained end-to-end
//! with Adam — pure Rust, no `pjrt` feature.
//!
//! Model per token row `x`:
//! `h = x + MoE(x)`, `logits = h·W_head + b_head`,
//! `L = CE(logits, label) + λ·mean_rank(aux)`.
//!
//! Every step exercises the full distributed pipeline: gate → dispatch
//! exchange → expert FFNs → combine exchange (forward), the transposed
//! exchanges + FFN/gate backward (backward), a gradient AllReduce for
//! the replicated router/head parameters, and an Adam update. The
//! [`StepReport`] carries forward *and* backward wall/comm phases, both
//! legs' bytes-on-wire, and the per-leg schedule choice.

use crate::backprop::adam::Adam;
use crate::backprop::layer::TrainMoeLayer;
use crate::ckpt;
use crate::cluster::{ExpertPlacement, LinkKind, Timeline};
use crate::comm::{allreduce, F32_BYTES};
use crate::config::{ClusterConfig, GateKind, MoeConfig};
use crate::coordinator::metrics::{Breakdown, MetricsAgg};
use crate::data::ClusterTask;
use crate::error::Result;
use crate::fault::FaultPlan;
use crate::moe::{MoeLayerOptions, StepReport};
use crate::nn::{log_softmax, matmul, matmul_nt, matmul_tn};
use crate::obs::trace;
use crate::placement::{
    migration_bytes_per_expert, PlacementDelta, PlacementOptimizer, PlacementPolicy,
    ReplicaMap, TrafficWindow,
};
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use crate::util::stats::load_cv;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Configuration of one native training run.
#[derive(Clone, Debug)]
pub struct TrainRunConfig {
    pub moe: MoeConfig,
    pub cluster: ClusterConfig,
    pub opts: MoeLayerOptions,
    pub steps: usize,
    pub tokens_per_rank: usize,
    /// Classes of the synthetic task (= its cluster count).
    pub num_classes: usize,
    pub lr: f32,
    /// Auxiliary load-balancing loss coefficient λ.
    pub aux_coef: f32,
    /// Feature noise around each cluster centroid.
    pub noise: f32,
    pub seed: u64,
    pub log_every: usize,
    /// Deterministic fault-injection schedule (empty = healthy run).
    pub faults: FaultPlan,
    /// Checkpoint every N steps (0 = never).
    pub ckpt_every: usize,
    /// Directory checkpoints are written into (required when
    /// `ckpt_every > 0`).
    pub ckpt_dir: Option<String>,
    /// Expert placement policy. `Static` (the default) freezes the
    /// contiguous formula and is bit-identical to the pre-adaptive
    /// trainer; `Adaptive` re-optimizes from observed traffic and
    /// migrates experts (weights + Adam moments) at step boundaries.
    pub placement: PlacementPolicy,
    /// Under `Adaptive`: consider a migration every N steps (0 = never).
    pub placement_every: usize,
    /// Steps of per-expert traffic the optimizer's rolling window holds.
    pub placement_window: usize,
    /// Minimum relative NIC-peak gain for a migration to fire
    /// (thrash guard; benches set 0.0 to surface every strict win).
    pub placement_min_gain: f64,
}

impl TrainRunConfig {
    /// Small-but-real default: 8 experts on 2×2 simulated GPUs.
    pub fn default_run() -> TrainRunConfig {
        TrainRunConfig {
            moe: MoeConfig {
                num_experts: 8,
                d_model: 32,
                ffn_hidden: 64,
                capacity_factor: 1.5,
                gate: GateKind::Switch,
            },
            cluster: ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) },
            opts: MoeLayerOptions::default(),
            steps: 300,
            tokens_per_rank: 64,
            num_classes: 8,
            lr: 2e-3,
            aux_coef: 1e-2,
            noise: 0.3,
            seed: 0,
            log_every: 25,
            faults: FaultPlan::none(),
            ckpt_every: 0,
            ckpt_dir: None,
            placement: PlacementPolicy::Static,
            placement_every: 25,
            placement_window: 16,
            placement_min_gain: 0.01,
        }
    }
}

/// One step's record.
#[derive(Clone, Debug)]
pub struct TrainStepLog {
    pub step: usize,
    /// Total objective: `ce + aux_coef·aux`.
    pub loss: f32,
    pub ce: f32,
    pub aux: f32,
    /// Coefficient of variation of the per-expert token loads.
    pub load_cv: f64,
    pub report: StepReport,
}

/// End-of-run summary.
#[derive(Clone, Debug)]
pub struct TrainSummary {
    pub steps: usize,
    pub final_loss: f32,
    pub breakdown: Breakdown,
    /// (flat, hier) schedule picks of the forward exchanges.
    pub fwd_schedules: (usize, usize),
    /// (flat, hier) schedule picks of the backward exchanges.
    pub bwd_schedules: (usize, usize),
    /// Steps re-executed after rank-failure recovery (fail step minus
    /// checkpoint step, summed over recoveries).
    pub recovery_steps: usize,
    /// Expert migrations the adaptive placement executed (0 static).
    pub migrations: usize,
    /// Bytes those migrations moved — FFN params **and both Adam
    /// moments** — also charged into the step bytes-on-wire/intra
    /// splits as they happen.
    pub bytes_migrated: usize,
}

/// Exponential smoothing of a loss curve (α = weight of the new value).
pub fn smoothed_losses(losses: &[f32], alpha: f64) -> Vec<f64> {
    let mut out = Vec::with_capacity(losses.len());
    let mut acc = None;
    for &l in losses {
        let s = match acc {
            None => l as f64,
            Some(prev) => (1.0 - alpha) * prev + alpha * l as f64,
        };
        acc = Some(s);
        out.push(s);
    }
    out
}

/// The native end-to-end trainer (see module docs).
pub struct NativeTrainer {
    pub cfg: TrainRunConfig,
    pub layer: TrainMoeLayer,
    /// Classifier head `[d, C]` (+ bias), replicated like the router.
    pub head_w: Tensor,
    pub head_b: Vec<f32>,
    pub logs: Vec<TrainStepLog>,
    /// Steps re-executed after rank-failure recovery so far.
    pub recovery_steps: usize,
    /// Fault and recovery events on the simulated clock (`straggle/*`,
    /// `retry/*`, `rank_fail/*`), kept apart from base phase time.
    pub fault_timeline: Timeline,
    /// Rolling per-expert traffic feeding the adaptive optimizer
    /// (only populated under `placement: Adaptive`).
    pub traffic: TrafficWindow,
    /// Expert migrations executed so far.
    pub migrations: usize,
    /// Bytes those migrations moved (params + both Adam moments).
    pub bytes_migrated: usize,
    /// Migration charge (simulated seconds, NIC bytes, intra bytes)
    /// waiting to be folded into the next step's report.
    pending_migration: Option<(f64, usize, usize)>,
    task: ClusterTask,
    data_rng: Rng,
    opt: Adam,
    agg: MetricsAgg,
    step_idx: usize,
    last_ckpt: Option<(usize, PathBuf)>,
    fwd_flat: usize,
    fwd_hier: usize,
    bwd_flat: usize,
    bwd_hier: usize,
}

impl NativeTrainer {
    pub fn new(mut cfg: TrainRunConfig) -> Result<NativeTrainer> {
        // `dead:` clauses mark ranks down from step 0: fold them into the
        // layer's dead set so the elastic placement covers them.
        let initial_dead = cfg.faults.initial_dead();
        if !initial_dead.is_empty() {
            cfg.opts.dead_ranks.extend(initial_dead);
            cfg.opts.dead_ranks.sort_unstable();
            cfg.opts.dead_ranks.dedup();
        }
        if cfg.ckpt_every > 0 && cfg.ckpt_dir.is_none() {
            return Err(crate::config_err!(
                "--ckpt-every needs --ckpt-dir to write checkpoints into"
            ));
        }
        let layer = TrainMoeLayer::native(
            cfg.moe.clone(),
            cfg.cluster.clone(),
            cfg.opts.clone(),
            cfg.seed,
        )?;
        let d = cfg.moe.d_model;
        let c = cfg.num_classes;
        if c < 2 {
            return Err(crate::config_err!("num_classes must be ≥ 2, got {c}"));
        }
        let mut rng = Rng::seed(cfg.seed ^ 0x4EAD);
        let mut head_w = Tensor::randn(&[d, c], &mut rng);
        head_w.scale(1.0 / (d as f32).sqrt());
        let head_b = vec![0.0f32; c];
        let task = ClusterTask::new(c, d, cfg.noise, cfg.seed);
        let data_rng = Rng::seed(cfg.seed ^ 0xDA7A);
        let mut sizes = vec![layer.gate_weight.len(), head_w.len(), c];
        for f in &layer.experts {
            sizes.extend([f.w1.len(), f.b1.len(), f.w2.len(), f.b2.len()]);
        }
        let opt = Adam::new(cfg.lr, &sizes);
        let traffic = TrafficWindow::new(cfg.placement_window);
        Ok(NativeTrainer {
            cfg,
            layer,
            head_w,
            head_b,
            logs: Vec::new(),
            recovery_steps: 0,
            fault_timeline: Timeline::new(),
            traffic,
            migrations: 0,
            bytes_migrated: 0,
            pending_migration: None,
            task,
            data_rng,
            opt,
            agg: MetricsAgg::new(),
            step_idx: 0,
            last_ckpt: None,
            fwd_flat: 0,
            fwd_hier: 0,
            bwd_flat: 0,
            bwd_hier: 0,
        })
    }

    /// Total trainable parameters (router + experts + head).
    pub fn num_params(&self) -> usize {
        self.layer.num_params() + self.head_w.len() + self.head_b.len()
    }

    /// One full training step: forward, loss, backward, gradient
    /// AllReduce of the replicated params, Adam update.
    pub fn step(&mut self) -> Result<TrainStepLog> {
        let w = self.cfg.cluster.world();
        let per = self.cfg.tokens_per_rank;
        let c = self.cfg.num_classes;
        // Dead ranks contribute no tokens: losses normalize over the
        // alive world (identical to /w when nothing is dead).
        let dead = self.layer.opts.dead_ranks.clone();
        let n_alive = (w - dead.len()).max(1);
        let total_tokens = (n_alive * per) as f32;

        // ---- Batch: per-rank shards of the cluster task ----
        // Dead ranks sample nothing — crucially they also *draw* nothing
        // from the data RNG, so a recovered run's stream matches a fresh
        // run started from the same checkpoint with the same dead set.
        let mut shards = Vec::with_capacity(w);
        let mut labels: Vec<Vec<u32>> = Vec::with_capacity(w);
        for r in 0..w {
            if dead.binary_search(&r).is_ok() {
                shards.push(Tensor::zeros(&[0, self.cfg.moe.d_model]));
                labels.push(Vec::new());
                continue;
            }
            let (x, y) = self.task.sample(per, &mut self.data_rng);
            shards.push(x);
            labels.push(y);
        }

        // ---- Faults scheduled for this step (pure function of the
        // plan and the step index — fully replayable) ----
        let step_faults = (!self.cfg.faults.is_empty()).then(|| {
            self.cfg.faults.at_step(self.step_idx, w, self.cfg.cluster.nodes)
        });

        // ---- Forward: MoE block with residual, then the head ----
        let (moe_out, mut report, cache) = self.layer.forward_t_with(
            &shards,
            self.step_idx as u64,
            step_faults.as_ref(),
        )?;
        let mut h = moe_out;
        for (hr, xr) in h.iter_mut().zip(&shards) {
            hr.add_assign(xr);
        }
        let mut head_fwd = 0.0f64;
        let mut head_bwd = 0.0f64;
        let mut ce_sum = 0.0f64;
        let mut dh: Vec<Tensor> = Vec::with_capacity(w);
        let mut d_head_w: Vec<Tensor> = Vec::with_capacity(w);
        let mut d_head_b: Vec<Vec<f32>> = Vec::with_capacity(w);
        for rank in 0..w {
            // Dead ranks carry zero rows: every loop below is a no-op
            // and their head gradients come out zero.
            let rows = h[rank].rows();
            let f0 = Instant::now();
            let mut logits = matmul(&h[rank], &self.head_w);
            for t in 0..rows {
                let row = logits.row_mut(t);
                for (j, v) in row.iter_mut().enumerate() {
                    *v += self.head_b[j];
                }
            }
            log_softmax(&mut logits);
            let y = &labels[rank];
            for t in 0..rows {
                ce_sum -= logits.at(t, y[t] as usize) as f64;
            }
            head_fwd += f0.elapsed().as_secs_f64();
            // dlogits = (softmax − onehot) / total_tokens.
            let b0 = Instant::now();
            let mut dl = logits;
            for v in dl.data_mut() {
                *v = v.exp();
            }
            for t in 0..rows {
                let row = dl.row_mut(t);
                row[y[t] as usize] -= 1.0;
                for v in row.iter_mut() {
                    *v /= total_tokens;
                }
            }
            d_head_w.push(matmul_tn(&h[rank], &dl));
            let mut db = vec![0.0f32; c];
            for t in 0..rows {
                for (j, &g) in dl.row(t).iter().enumerate() {
                    db[j] += g;
                }
            }
            d_head_b.push(db);
            dh.push(matmul_nt(&dl, &self.head_w));
            head_bwd += b0.elapsed().as_secs_f64();
        }
        report.wall.push(("head".into(), head_fwd / w as f64));
        report.wall.push(("bwd_head".into(), head_bwd / w as f64));
        let ce = (ce_sum / total_tokens as f64) as f32;
        let aux = report.aux_loss as f32;

        // ---- Backward through the MoE block ----
        // (The residual path's dx goes to the non-trainable input.)
        let (_dx, grads, bwd_report) = self.layer.backward(
            &shards,
            &dh,
            &cache,
            self.cfg.aux_coef / n_alive as f32,
        )?;
        report.absorb_backward(bwd_report);

        // ---- Fault accounting on the dedicated timeline ----
        if let Some(sf) = &step_faults {
            if !sf.is_clean() {
                let s = report.wall_phase("straggle/expert");
                if s > 0.0 {
                    self.fault_timeline.push_fault("straggle/expert", s);
                }
                let n = report.comm_phase("straggle/nic");
                if n > 0.0 {
                    self.fault_timeline.push_fault("straggle/nic", n);
                }
                let r = report.comm_phase("retry/dispatch");
                if r > 0.0 {
                    self.fault_timeline.push_fault("retry/dispatch", r);
                }
            }
        }

        // ---- Gradient AllReduce for the replicated params ----
        let gw_len = self.layer.gate_weight.len();
        let hw_len = self.head_w.len();
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|r| {
                let mut v = Vec::with_capacity(gw_len + hw_len + c);
                v.extend_from_slice(grads.d_gate_weight[r].data());
                v.extend_from_slice(d_head_w[r].data());
                v.extend_from_slice(&d_head_b[r]);
                v
            })
            .collect();
        let timing = allreduce(&self.layer.net, &mut bufs)?;
        report.comm.push(("allreduce_grads".into(), timing.total));
        let reduced = bufs.swap_remove(0);
        let (gw_grad, rest) = reduced.split_at(gw_len);
        let (hw_grad, hb_grad) = rest.split_at(hw_len);

        // ---- Adam update over every trainable tensor ----
        let o0 = Instant::now();
        let mut params: Vec<&mut [f32]> = vec![
            self.layer.gate_weight.data_mut(),
            self.head_w.data_mut(),
            self.head_b.as_mut_slice(),
        ];
        let mut grad_slices: Vec<&[f32]> = vec![gw_grad, hw_grad, hb_grad];
        for (f, g) in self.layer.experts.iter_mut().zip(&grads.experts) {
            params.push(f.w1.data_mut());
            params.push(f.b1.as_mut_slice());
            params.push(f.w2.data_mut());
            params.push(f.b2.as_mut_slice());
            grad_slices.push(g.dw1.data());
            grad_slices.push(&g.db1);
            grad_slices.push(g.dw2.data());
            grad_slices.push(&g.db2);
        }
        self.opt.step(&mut params, &grad_slices);
        // Per-rank mean like every other wall phase (expert params are
        // sharded E/W per rank; the replicated router/head update is
        // negligible next to them).
        report.wall.push(("optimizer".into(), o0.elapsed().as_secs_f64() / w as f64));

        // ---- Migration charge from the preceding step boundary ----
        // The move itself already happened (weights + moments landed
        // bitwise); its simulated wire cost is billed to this step so
        // the aggregates never lose it.
        if let Some((mig_time, mig_inter, mig_intra)) = self.pending_migration.take() {
            report.comm.push(("migrate".into(), mig_time));
            report.bytes_on_wire += mig_inter;
            report.bytes_intra_node += mig_intra;
        }

        // ---- Bookkeeping ----
        match report.comm_schedule.as_str() {
            "flat" => self.fwd_flat += 1,
            "hier" => self.fwd_hier += 1,
            _ => {}
        }
        match report.comm_schedule_bwd.as_str() {
            "flat" => self.bwd_flat += 1,
            "hier" => self.bwd_hier += 1,
            _ => {}
        }
        self.agg.push(&report);
        let log = TrainStepLog {
            step: self.step_idx,
            loss: ce + self.cfg.aux_coef * aux,
            ce,
            aux,
            load_cv: load_cv(&report.expert_counts),
            report,
        };
        self.step_idx += 1;
        self.logs.push(log.clone());
        Ok(log)
    }

    /// Run `cfg.steps` steps; returns the summary (per-step logs stay in
    /// `self.logs`). Fails fast on divergence (non-finite loss).
    ///
    /// `kill:` faults fire *before* the victim executes its step: the
    /// trainer rolls back to the last checkpoint, marks the rank dead,
    /// and re-executes from there with the shrunken world. Recovery
    /// needs `--ckpt-every`/`--ckpt-dir`; a step-0 snapshot is written
    /// up front so even an immediate kill is recoverable.
    pub fn run(&mut self) -> Result<TrainSummary> {
        self.maybe_checkpoint()?;
        while self.step_idx < self.cfg.steps {
            let at = self.step_idx;
            let kills: Vec<usize> = self
                .cfg
                .faults
                .kills_at(at)
                .into_iter()
                .filter(|r| !self.layer.opts.dead_ranks.contains(r))
                .collect();
            if !kills.is_empty() {
                self.recover(&kills, at)?;
                continue;
            }
            let log = self.step()?;
            if !log.loss.is_finite() {
                return Err(crate::error::HetuError::Runtime(format!(
                    "loss diverged (NaN/inf) at step {}",
                    log.step
                )));
            }
            if self.cfg.log_every > 0 && log.step % self.cfg.log_every == 0 {
                eprintln!(
                    "step {:>5}  loss {:.4}  ce {:.4}  aux {:.3}  load_cv {:.3}",
                    log.step, log.loss, log.ce, log.aux, log.load_cv
                );
            }
            // Adaptive placement: fold this step's traffic into the
            // window and re-optimize at the configured boundaries —
            // before checkpointing, so snapshots carry the live table.
            if self.cfg.placement.is_adaptive() {
                self.traffic.observe(&log.report.expert_counts);
                if self.cfg.placement_every > 0
                    && self.step_idx % self.cfg.placement_every == 0
                {
                    self.maybe_migrate()?;
                }
            }
            self.maybe_checkpoint()?;
        }
        Ok(self.summary())
    }

    /// Rank-failure recovery: rebuild the trainer from the last
    /// checkpoint with `kills` added to the dead set and resume. The
    /// re-executed span (`at − ckpt_step`) accrues to `recovery_steps`.
    fn recover(&mut self, kills: &[usize], at: usize) -> Result<()> {
        let w = self.cfg.cluster.world();
        for &r in kills {
            if r >= w {
                return Err(crate::fault_err!(
                    "kill:rank={r} is outside the world of {w} ranks"
                ));
            }
        }
        let Some((cstep, path)) = self.last_ckpt.clone() else {
            return Err(crate::fault_err!(
                "rank failure at step {at} but no checkpoint exists — run with \
                 --ckpt-every N (and --ckpt-dir) to enable recovery"
            ));
        };
        for &r in kills {
            self.fault_timeline.push_fault(&format!("rank_fail/rank{r}"), 0.0);
        }
        let mut cfg = self.cfg.clone();
        cfg.opts.dead_ranks.extend_from_slice(kills);
        cfg.opts.dead_ranks.sort_unstable();
        cfg.opts.dead_ranks.dedup();
        let mut fresh = NativeTrainer::from_checkpoint(cfg, &path)?;
        // Adaptive placement: re-home the killed ranks' experts onto
        // the least-*loaded* survivors per the observed traffic window
        // (the uniform least-populated greedy is the fallback when no
        // traffic was seen yet), and pin the result as the live table.
        if fresh.cfg.placement.is_adaptive() {
            if let Some(load) = self.traffic.mean_load() {
                let e = fresh.cfg.moe.num_experts;
                let world = fresh.cfg.cluster.world();
                let base = ExpertPlacement::resolve(
                    e,
                    world,
                    fresh.layer.opts.placement_table.as_deref(),
                    &[],
                );
                let remapped =
                    base.compose_dead_loaded(&fresh.layer.opts.dead_ranks, Some(&load));
                fresh.layer.opts.placement_table = Some(remapped.table_vec());
                fresh.cfg.opts.placement_table = fresh.layer.opts.placement_table.clone();
            }
            fresh.traffic = self.traffic.clone();
            fresh.migrations = self.migrations;
            fresh.bytes_migrated = self.bytes_migrated;
        }
        // Carry the history from before the checkpoint: those steps are
        // not re-executed, so their logs and aggregates stand.
        for log in self.logs.iter().filter(|l| l.step < cstep) {
            fresh.agg.push(&log.report);
            match log.report.comm_schedule.as_str() {
                "flat" => fresh.fwd_flat += 1,
                "hier" => fresh.fwd_hier += 1,
                _ => {}
            }
            match log.report.comm_schedule_bwd.as_str() {
                "flat" => fresh.bwd_flat += 1,
                "hier" => fresh.bwd_hier += 1,
                _ => {}
            }
            fresh.logs.push(log.clone());
        }
        fresh.recovery_steps = self.recovery_steps + (at - cstep);
        fresh.last_ckpt = self.last_ckpt.clone();
        fresh.fault_timeline = std::mem::take(&mut self.fault_timeline);
        *self = fresh;
        Ok(())
    }

    /// Ask the optimizer for a better layout under the observed window
    /// and execute the migration when one exists.
    fn maybe_migrate(&mut self) -> Result<()> {
        let opt = PlacementOptimizer {
            min_gain: self.cfg.placement_min_gain,
            ..Default::default()
        };
        let current = self.layer.placement();
        // Score candidate layouts at the wire element size so placement
        // decisions see the same per-row cost the dispatch path charges.
        let row_bytes = self.cfg.moe.d_model * self.layer.opts.wire.elem_bytes();
        let Some(delta) = opt.propose(
            &self.traffic,
            &current,
            &ReplicaMap::new(self.cfg.moe.num_experts),
            &self.layer.opts.dead_ranks,
            &self.layer.net,
            row_bytes,
        ) else {
            return Ok(());
        };
        self.apply_migration(&delta)
    }

    /// Execute a [`PlacementDelta`]: round-trip each migrating expert's
    /// FFN parameters **and Adam moments** through a wire buffer (a
    /// bitwise send/recv between the old and new owner), charge the
    /// simulated point-to-point transfer per move, install the new
    /// table, and stash the charge for the next step's report.
    fn apply_migration(&mut self, delta: &PlacementDelta) -> Result<()> {
        let d = self.cfg.moe.d_model;
        let h = self.cfg.moe.ffn_hidden;
        let g = self.cfg.cluster.gpus_per_node;
        let per_bytes = migration_bytes_per_expert(d, h);
        let mut span = trace::span("migrate");
        let mut mig_time = 0.0f64;
        let (mut inter, mut intra) = (0usize, 0usize);
        for m in &delta.moves {
            // Serialize: w1, b1, w2, b2, then m and v of each (the
            // expert's Adam slots sit at 3 + 4e .. 3 + 4e + 4 — after
            // gate weight, head weight, head bias).
            let mut payload: Vec<f32> = Vec::with_capacity(per_bytes / 4);
            {
                let f = &self.layer.experts[m.expert];
                payload.extend_from_slice(f.w1.data());
                payload.extend_from_slice(&f.b1);
                payload.extend_from_slice(f.w2.data());
                payload.extend_from_slice(&f.b2);
            }
            for slot in 0..4 {
                let (mm, _) = self.opt.moments(3 + 4 * m.expert + slot);
                payload.extend_from_slice(mm);
            }
            for slot in 0..4 {
                let (_, vv) = self.opt.moments(3 + 4 * m.expert + slot);
                payload.extend_from_slice(vv);
            }
            debug_assert_eq!(payload.len() * F32_BYTES, per_bytes);
            // Deserialize at the new owner — bitwise, so the loss
            // trajectory is untouched by construction.
            let mut off = 0usize;
            {
                let f = &mut self.layer.experts[m.expert];
                let w1 = f.w1.len();
                f.w1.data_mut().copy_from_slice(&payload[off..off + w1]);
                off += w1;
                let b1 = f.b1.len();
                f.b1.copy_from_slice(&payload[off..off + b1]);
                off += b1;
                let w2 = f.w2.len();
                f.w2.data_mut().copy_from_slice(&payload[off..off + w2]);
                off += w2;
                let b2 = f.b2.len();
                f.b2.copy_from_slice(&payload[off..off + b2]);
                off += b2;
            }
            let moment_sizes: Vec<usize> =
                (0..4).map(|s| self.opt.moments(3 + 4 * m.expert + s).0.len()).collect();
            let m_off = off;
            let v_off = off + moment_sizes.iter().sum::<usize>();
            let mut mo = m_off;
            let mut vo = v_off;
            for (slot, &len) in moment_sizes.iter().enumerate() {
                let mm = payload[mo..mo + len].to_vec();
                let vv = payload[vo..vo + len].to_vec();
                self.opt.set_moments(3 + 4 * m.expert + slot, &mm, &vv);
                mo += len;
                vo += len;
            }
            // Charge the transfer on the link it actually crosses.
            let kind =
                if m.from / g == m.to / g { LinkKind::Intra } else { LinkKind::Inter };
            mig_time += self.layer.net.msg_time(kind, per_bytes as f64);
            match kind {
                LinkKind::Inter => inter += per_bytes,
                _ => intra += per_bytes,
            }
        }
        span.arg("moves", delta.moves.len());
        span.arg("bytes", inter + intra);
        self.layer.opts.placement_table = Some(delta.table.clone());
        self.cfg.opts.placement_table = Some(delta.table.clone());
        self.migrations += delta.moves.len();
        self.bytes_migrated += inter + intra;
        let (t0, i0, n0) = self.pending_migration.take().unwrap_or((0.0, 0, 0));
        self.pending_migration = Some((t0 + mig_time, i0 + inter, n0 + intra));
        Ok(())
    }

    /// Build a trainer whose model, optimizer, data-RNG, and step index
    /// come from the checkpoint at `path` (cfg supplies everything the
    /// checkpoint doesn't carry: cluster, faults, hyperparameters).
    pub fn from_checkpoint(cfg: TrainRunConfig, path: &Path) -> Result<NativeTrainer> {
        let state = ckpt::load(path)?;
        state.validate_dims(
            cfg.moe.num_experts,
            cfg.moe.d_model,
            cfg.moe.ffn_hidden,
            cfg.num_classes,
            cfg.cluster.world(),
        )?;
        let d = cfg.moe.d_model;
        let e = cfg.moe.num_experts;
        let h = cfg.moe.ffn_hidden;
        let c = cfg.num_classes;
        let mut t = NativeTrainer::new(cfg)?;
        t.layer.gate_weight = Tensor::from_vec(state.gate_weight, &[d, e])?;
        t.head_w = Tensor::from_vec(state.head_w, &[d, c])?;
        if state.head_b.len() != c {
            return Err(crate::ckpt_err!(
                "head bias length {} does not match num_classes {c}",
                state.head_b.len()
            ));
        }
        t.head_b = state.head_b;
        for (i, (ffn, p)) in t.layer.experts.iter_mut().zip(state.experts).enumerate() {
            if p.b1.len() != h || p.b2.len() != d {
                return Err(crate::ckpt_err!(
                    "expert {i} bias lengths ({}, {}) do not match dims ({h}, {d})",
                    p.b1.len(),
                    p.b2.len()
                ));
            }
            ffn.w1 = Tensor::from_vec(p.w1, &[d, h])?;
            ffn.b1 = p.b1;
            ffn.w2 = Tensor::from_vec(p.w2, &[h, d])?;
            ffn.b2 = p.b2;
        }
        t.opt.restore_state(state.adam_t, state.adam_m, state.adam_v)?;
        t.data_rng = Rng::from_state(state.data_rng);
        t.step_idx = state.step as usize;
        // The checkpoint's live placement wins over whatever the config
        // carried — resuming after adaptive migrations must continue on
        // the migrated layout, not the formula.
        if let Some(table) = state.placement {
            let table: Vec<usize> = table.iter().map(|&r| r as usize).collect();
            ExpertPlacement::validate_table(e, t.cfg.cluster.world(), &table)?;
            t.layer.opts.placement_table = Some(table.clone());
            t.cfg.opts.placement_table = Some(table);
        }
        Ok(t)
    }

    /// Snapshot of everything a bit-exact resume needs.
    fn train_state(&self) -> ckpt::TrainState {
        let (adam_t, adam_m, adam_v) = self.opt.export_state();
        ckpt::TrainState {
            step: self.step_idx as u64,
            num_experts: self.cfg.moe.num_experts as u64,
            d_model: self.cfg.moe.d_model as u64,
            ffn_hidden: self.cfg.moe.ffn_hidden as u64,
            num_classes: self.cfg.num_classes as u64,
            world: self.cfg.cluster.world() as u64,
            gate_weight: self.layer.gate_weight.data().to_vec(),
            head_w: self.head_w.data().to_vec(),
            head_b: self.head_b.clone(),
            experts: self
                .layer
                .experts
                .iter()
                .map(|f| ckpt::ExpertParams {
                    w1: f.w1.data().to_vec(),
                    b1: f.b1.clone(),
                    w2: f.w2.data().to_vec(),
                    b2: f.b2.clone(),
                })
                .collect(),
            adam_t,
            adam_m,
            adam_v,
            data_rng: self.data_rng.state(),
            placement: self
                .layer
                .opts
                .placement_table
                .as_ref()
                .map(|t| t.iter().map(|&r| r as u64).collect()),
            replicas: Vec::new(),
        }
    }

    /// Write a checkpoint of the current state into `dir` and remember
    /// it as the recovery point. Returns the file's path.
    pub fn checkpoint(&mut self, dir: &Path) -> Result<PathBuf> {
        let path = dir.join(format!("ckpt_{:06}.bin", self.step_idx));
        ckpt::save(&path, &self.train_state())?;
        self.last_ckpt = Some((self.step_idx, path.clone()));
        Ok(path)
    }

    fn maybe_checkpoint(&mut self) -> Result<()> {
        if self.cfg.ckpt_every == 0 || self.step_idx % self.cfg.ckpt_every != 0 {
            return Ok(());
        }
        let Some(dir) = self.cfg.ckpt_dir.clone() else { return Ok(()) };
        self.checkpoint(Path::new(&dir))?;
        Ok(())
    }

    /// Summary over everything run so far.
    pub fn summary(&self) -> TrainSummary {
        TrainSummary {
            steps: self.step_idx,
            final_loss: self.logs.last().map(|l| l.loss).unwrap_or(f32::NAN),
            breakdown: self.agg.breakdown(),
            fwd_schedules: (self.fwd_flat, self.fwd_hier),
            bwd_schedules: (self.bwd_flat, self.bwd_hier),
            recovery_steps: self.recovery_steps,
            migrations: self.migrations,
            bytes_migrated: self.bytes_migrated,
        }
    }

    /// Per-step total losses.
    pub fn losses(&self) -> Vec<f32> {
        self.logs.iter().map(|l| l.loss).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::moe::DispatchMode;

    fn quick_cfg() -> TrainRunConfig {
        TrainRunConfig {
            moe: MoeConfig {
                num_experts: 4,
                d_model: 16,
                ffn_hidden: 32,
                capacity_factor: 2.0,
                gate: GateKind::Switch,
            },
            cluster: ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) },
            opts: MoeLayerOptions::default(),
            steps: 30,
            tokens_per_rank: 16,
            num_classes: 4,
            lr: 5e-3,
            aux_coef: 1e-2,
            noise: 0.3,
            seed: 0,
            log_every: 0,
            faults: FaultPlan::none(),
            ckpt_every: 0,
            ckpt_dir: None,
            ..TrainRunConfig::default_run()
        }
    }

    #[test]
    fn short_run_reduces_loss_and_reports_both_directions() {
        let mut t = NativeTrainer::new(quick_cfg()).unwrap();
        let summary = t.run().unwrap();
        assert_eq!(summary.steps, 30);
        let losses = t.losses();
        let first5: f32 = losses[..5].iter().sum::<f32>() / 5.0;
        let last5: f32 = losses[25..].iter().sum::<f32>() / 5.0;
        assert!(
            last5 < first5,
            "loss must move down even in 30 steps: {first5} → {last5}"
        );
        let log = t.logs.last().unwrap();
        assert!(log.report.bytes_on_wire > 0);
        assert!(log.report.bytes_on_wire_bwd > 0);
        assert!(!log.report.comm_schedule_bwd.is_empty());
        assert!(log.report.comm.iter().any(|(n, _)| n == "allreduce_grads"));
        assert!(log.report.wall.iter().any(|(n, _)| n == "optimizer"));
        let (ff, fh) = summary.fwd_schedules;
        assert_eq!(ff + fh, 30);
        let (bf, bh) = summary.bwd_schedules;
        assert_eq!(bf + bh, 30);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut a = NativeTrainer::new(quick_cfg()).unwrap();
        let mut b = NativeTrainer::new(quick_cfg()).unwrap();
        for _ in 0..5 {
            let la = a.step().unwrap();
            let lb = b.step().unwrap();
            assert_eq!(la.loss, lb.loss);
            assert_eq!(la.report.expert_counts, lb.report.expert_counts);
        }
        let mut c = NativeTrainer::new(TrainRunConfig { seed: 1, ..quick_cfg() }).unwrap();
        let lc = c.step().unwrap();
        assert_ne!(lc.loss, a.logs[0].loss);
    }

    #[test]
    fn padded_mode_also_trains() {
        let cfg = TrainRunConfig {
            opts: MoeLayerOptions { dispatch: DispatchMode::Padded, ..Default::default() },
            steps: 5,
            ..quick_cfg()
        };
        let mut t = NativeTrainer::new(cfg).unwrap();
        let summary = t.run().unwrap();
        assert_eq!(summary.steps, 5);
        assert!(summary.final_loss.is_finite());
    }

    #[test]
    fn skewed_window_migrates_with_honest_bytes_and_exact_numerics() {
        let mut cfg = quick_cfg();
        cfg.placement = PlacementPolicy::Adaptive;
        cfg.placement_min_gain = 0.0;
        let mut t = NativeTrainer::new(cfg).unwrap();
        // E=4 over 2×2: the formula puts experts 0 and 1 on node 0 —
        // a hot pair there must split across the node boundary.
        for _ in 0..8 {
            t.traffic.observe(&[300, 300, 1, 1]);
        }
        t.maybe_migrate().unwrap();
        assert!(t.migrations > 0, "co-located hot experts must migrate");
        assert!(t.bytes_migrated > 0, "migration bytes must be charged");
        let table = t.layer.opts.placement_table.clone().expect("table installed");
        let node = |r: usize| r / 2;
        assert_ne!(node(table[0]), node(table[1]), "hot pair still co-located");
        // Placement never touches numerics: the next step matches a
        // static trainer bit-for-bit, with the migration charge billed
        // as a comm phase on top.
        let la = t.step().unwrap();
        let mut s = NativeTrainer::new(quick_cfg()).unwrap();
        let lb = s.step().unwrap();
        assert_eq!(la.loss, lb.loss);
        assert_eq!(la.report.expert_counts, lb.report.expert_counts);
        let mig = la
            .report
            .comm
            .iter()
            .find(|(n, _)| n == "migrate")
            .expect("migrate phase billed");
        assert!(mig.1 > 0.0);
        assert!(!lb.report.comm.iter().any(|(n, _)| n == "migrate"));
    }

    #[test]
    fn adaptive_trajectory_matches_from_scratch_with_final_table() {
        let mut cfg = quick_cfg();
        cfg.placement = PlacementPolicy::Adaptive;
        cfg.placement_every = 5;
        cfg.placement_min_gain = 0.0;
        let mut a = NativeTrainer::new(cfg).unwrap();
        let sa = a.run().unwrap();
        // A fresh run that *starts* on the adaptive run's final table
        // must produce the bit-identical loss trajectory (same seed):
        // placement only moves bytes, never values.
        let mut cfg2 = quick_cfg();
        cfg2.opts.placement_table = a.layer.opts.placement_table.clone();
        let mut b = NativeTrainer::new(cfg2).unwrap();
        let sb = b.run().unwrap();
        assert_eq!(a.losses(), b.losses(), "placement must never touch numerics");
        if sa.migrations > 0 {
            assert!(sa.bytes_migrated > 0);
        }
        assert_eq!(sb.migrations, 0, "static runs never migrate");
    }

    #[test]
    fn smoothing_is_monotone_on_monotone_input() {
        let xs: Vec<f32> = (0..50).map(|i| 5.0 - 0.1 * i as f32).collect();
        let s = smoothed_losses(&xs, 0.2);
        assert_eq!(s.len(), 50);
        for w in s.windows(2) {
            assert!(w[1] <= w[0] + 1e-9);
        }
        assert!(smoothed_losses(&[], 0.5).is_empty());
    }
}
