//! Baseline MoE systems (paper Figure 8: DeepSpeed-MoE, FastMoE, Tutel)
//! plus HetuMoE itself, each expressed two ways:
//!
//! 1. **Pipeline options** over the one real [`crate::moe::MoeLayer`]
//!    implementation (`options()`): the systems differ only in which
//!    gate kernel, layout transform and AllToAll they use, so measured
//!    CPU-scale gaps come from the same mechanisms the paper identifies.
//! 2. **Analytic step model** (`sim_step`): the same phase structure
//!    charged on the [`crate::cluster::GpuModel`] roofline +
//!    [`crate::cluster::NetworkModel`], with per-system kernel-launch
//!    counts taken from each system's actual kernel structure — used to
//!    regenerate Fig 1 and Fig 8 at the paper's scale (tokens = batch ×
//!    1024, d = 2048), which does not fit a CPU wallclock budget.

pub mod profiles;

pub use profiles::{sim_step, SimStep, SystemKind, SystemProfile};
