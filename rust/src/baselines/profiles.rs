//! Per-system cost profiles and the analytic MoE-layer step model.

use crate::cluster::{GpuModel, NetworkModel};
use crate::comm::alltoall::flat_alltoall_timing;
use crate::comm::F32_BYTES_F;
use crate::comm::hierarchical::hierarchical_alltoall_timing;
use crate::config::{ClusterConfig, GateKind, MoeConfig};
use crate::comm::schedule::CommChoice;
use crate::moe::{CommImpl, DispatchMode, GateImpl, LayoutImpl, MoeLayerOptions};
use crate::pipeline::ChunkChoice;

/// Which system a profile models.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SystemKind {
    HetuMoE,
    Tutel,
    FastMoE,
    DeepSpeedMoE,
}

impl SystemKind {
    pub fn all() -> [SystemKind; 4] {
        [
            SystemKind::HetuMoE,
            SystemKind::Tutel,
            SystemKind::FastMoE,
            SystemKind::DeepSpeedMoE,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            SystemKind::HetuMoE => "HetuMoE",
            SystemKind::Tutel => "Tutel",
            SystemKind::FastMoE => "FastMoE",
            SystemKind::DeepSpeedMoE => "DeepSpeed-MoE",
        }
    }
}

/// Implementation profile of one system.
///
/// Launch counts reflect each system's published kernel structure circa
/// the paper (2022): DeepSpeed-MoE's gate was a long chain of small
/// framework ops (einsums, one-hots, cumsums — tens of launches, host
/// syncs); FastMoE fused some but kept a generic top-k and sort-based
/// layout; Tutel fused the gate+dispatch into a few kernels; HetuMoE
/// ships specialized top-k and a single-pass layout kernel.
#[derive(Clone, Debug)]
pub struct SystemProfile {
    pub kind: SystemKind,
    /// Kernel launches in the gating phase (score matmul excluded).
    pub gate_launches: usize,
    /// Kernel launches per layout transform (forward or reverse).
    pub layout_launches: usize,
    /// Dispatch implementation.
    pub layout_impl: LayoutImpl,
    /// Top-k kernel implementation.
    pub gate_impl: GateImpl,
    /// AllToAll flavor.
    pub comm_impl: CommImpl,
    /// Relative top-k kernel efficiency vs HetuMoE's specialized kernel
    /// (>1 = slower). PyTorch generic ≈ 1.25× (paper Fig 3).
    pub topk_slowdown: f64,
    /// Relative layout kernel efficiency (>1 = slower; paper Fig 4 ≈ 1.26).
    pub layout_slowdown: f64,
    /// Expert-GEMM efficiency (≤1): HetuMoE batches all local experts
    /// into one grouped GEMM; FastMoE loops per-expert GEMMs (launch +
    /// tail-effect losses on small capacity batches); Tutel's 2022
    /// dispatcher sat in between. Calibrated against the paper's Fig 8
    /// relative gaps.
    pub expert_gemm_eff: f64,
}

impl SystemProfile {
    pub fn of(kind: SystemKind) -> SystemProfile {
        match kind {
            SystemKind::HetuMoE => SystemProfile {
                kind,
                gate_launches: 3,
                layout_launches: 1,
                layout_impl: LayoutImpl::Optimized,
                gate_impl: GateImpl::Fast,
                comm_impl: CommImpl::Hierarchical,
                topk_slowdown: 1.0,
                layout_slowdown: 1.0,
                expert_gemm_eff: 1.0,
            },
            SystemKind::Tutel => SystemProfile {
                kind,
                gate_launches: 5,
                layout_launches: 2,
                layout_impl: LayoutImpl::Optimized,
                gate_impl: GateImpl::Fast,
                comm_impl: CommImpl::Flat,
                topk_slowdown: 1.05,
                layout_slowdown: 1.1,
                expert_gemm_eff: 0.82,
            },
            SystemKind::FastMoE => SystemProfile {
                kind,
                gate_launches: 9,
                layout_launches: 3,
                layout_impl: LayoutImpl::Naive,
                gate_impl: GateImpl::Generic,
                comm_impl: CommImpl::Flat,
                topk_slowdown: 1.25,
                layout_slowdown: 1.26,
                expert_gemm_eff: 0.75,
            },
            SystemKind::DeepSpeedMoE => SystemProfile {
                kind,
                gate_launches: 30,
                layout_launches: 4,
                layout_impl: LayoutImpl::DenseEinsum,
                gate_impl: GateImpl::Generic,
                comm_impl: CommImpl::Flat,
                topk_slowdown: 1.25,
                layout_slowdown: 1.0, // dispatch cost is modeled as the einsum
                expert_gemm_eff: 1.0, // dense einsum path batches fine
            },
        }
    }

    /// Options tuple for running this system on the real pipeline.
    ///
    /// All four 2022-era systems ran the padded `[E, cap, d]` dispatch,
    /// so profiles pin [`DispatchMode::Padded`] (and force the ragged
    /// path's schedule to the profile's AllToAll flavor for callers that
    /// flip `dispatch` afterwards, e.g. `layer-bench --dispatch ragged`).
    pub fn options(&self, threads: usize) -> MoeLayerOptions {
        MoeLayerOptions {
            gate_impl: self.gate_impl,
            layout_impl: self.layout_impl,
            comm_impl: self.comm_impl,
            dispatch: DispatchMode::Padded,
            alltoall: match self.comm_impl {
                CommImpl::Flat => CommChoice::Flat,
                CommImpl::Hierarchical => CommChoice::Hierarchical,
            },
            // 2022-era systems ran their exchanges back-to-back with the
            // expert compute; no overlap, and no top-k dedup on the
            // hierarchical inter-node legs (HierMoE-era technique).
            chunks: ChunkChoice::Fixed(1),
            dedup: false,
            threads,
        }
    }
}

/// Analytic breakdown of one MoE-layer forward (per training iteration,
/// per rank) at the paper's scale.
#[derive(Clone, Debug)]
pub struct SimStep {
    pub system: SystemKind,
    /// (phase, seconds) — gate, layout, alltoall (×2 folded), expert,
    /// reverse_layout.
    pub phases: Vec<(String, f64)>,
}

impl SimStep {
    pub fn total(&self) -> f64 {
        self.phases.iter().map(|(_, t)| t).sum()
    }

    pub fn phase(&self, name: &str) -> f64 {
        self.phases
            .iter()
            .filter(|(n, _)| n.starts_with(name))
            .map(|(_, t)| t)
            .sum()
    }
}

/// Analytic per-iteration time of one MoE layer on the simulated
/// cluster. `tokens_per_rank` = local batch × sequence length.
pub fn sim_step(
    profile: &SystemProfile,
    moe: &MoeConfig,
    cluster: &ClusterConfig,
    gpu: &GpuModel,
    tokens_per_rank: usize,
) -> SimStep {
    let net = NetworkModel::new(cluster.clone());
    let w = cluster.world();
    let t = tokens_per_rank as f64;
    let d = moe.d_model as f64;
    let e = moe.num_experts as f64;
    let h = moe.ffn_hidden as f64;
    let k = match moe.gate {
        GateKind::GShard => 2.0,
        GateKind::TopK { k } => k as f64,
        _ => 1.0,
    };
    let cap = moe.capacity(tokens_per_rank) as f64;

    // --- gate: score matmul + top-k kernel chain ---
    let score_flops = 2.0 * t * d * e;
    let topk_bytes = t * e * F32_BYTES_F * profile.topk_slowdown;
    let gate_time = gpu.kernel_time(score_flops, t * (d + e) * F32_BYTES_F, 1)
        + gpu.memory_time(topk_bytes, profile.gate_launches);

    // --- layout transform (dispatch) ---
    let layout_time = match profile.layout_impl {
        LayoutImpl::DenseEinsum => {
            // onehot [E*cap, T] · tokens [T, d] — real matmul flops.
            let flops = 2.0 * (e * cap) * t * d;
            gpu.kernel_time(flops, (e * cap * d + t * d) * F32_BYTES_F, profile.layout_launches)
        }
        _ => {
            // Scatter: read + write each routed row once.
            let bytes = 2.0 * t * k * d * F32_BYTES_F * profile.layout_slowdown;
            gpu.memory_time(bytes, profile.layout_launches)
        }
    };

    // --- AllToAll (dispatch + combine) ---
    // Per-rank payload: full padded dispatch buffer [E, cap, d] f32.
    let payload_bytes = (e * cap * d * F32_BYTES_F) as usize;
    let chunk = payload_bytes / w;
    let a2a_once = match profile.comm_impl {
        CommImpl::Flat => flat_alltoall_timing(&net, chunk).total,
        CommImpl::Hierarchical => hierarchical_alltoall_timing(&net, chunk).total,
    };

    // --- expert FFN over the padded buffer ---
    // Each rank hosts E/W experts, each with W·cap rows after exchange:
    // rows_total = (E/W)·W·cap = E·cap.
    let expert_flops = 4.0 * (e * cap) * d * h / profile.expert_gemm_eff;
    let expert_time = gpu.kernel_time(
        expert_flops,
        (e * cap) * (d + h) * F32_BYTES_F,
        2 * (moe.num_experts / w.max(1)).max(1),
    );

    // --- reverse layout (combine) ---
    let reverse_time = match profile.layout_impl {
        LayoutImpl::DenseEinsum => {
            let flops = 2.0 * t * (e * cap) * d;
            gpu.kernel_time(flops, (e * cap * d + t * d) * F32_BYTES_F, profile.layout_launches)
        }
        _ => gpu.memory_time(
            2.0 * t * k * d * F32_BYTES_F * profile.layout_slowdown,
            profile.layout_launches,
        ),
    };

    SimStep {
        system: profile.kind,
        phases: vec![
            ("gate".into(), gate_time),
            ("layout".into(), layout_time),
            ("alltoall_dispatch".into(), a2a_once),
            ("expert".into(), expert_time),
            ("alltoall_combine".into(), a2a_once),
            ("reverse_layout".into(), reverse_time),
        ],
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_moe(gate: GateKind) -> MoeConfig {
        MoeConfig { gate, ..MoeConfig::paper_layer() }
    }

    fn titan_cluster(nodes: usize) -> ClusterConfig {
        ClusterConfig::commodity(nodes)
    }

    #[test]
    fn hetu_beats_all_baselines_fig8_shape() {
        // Paper layer, single node of 8 GPUs, switch gate.
        let moe = paper_moe(GateKind::Switch);
        let cluster = titan_cluster(1);
        let gpu = GpuModel::titan_rtx();
        for batch in [16usize, 32, 64, 128] {
            // Paper batch sizes are per-GPU (seq len 1024).
            let tokens = batch * 1024;
            let hetu = sim_step(
                &SystemProfile::of(SystemKind::HetuMoE),
                &moe,
                &cluster,
                &gpu,
                tokens,
            )
            .total();
            for kind in [SystemKind::Tutel, SystemKind::FastMoE, SystemKind::DeepSpeedMoE] {
                let other =
                    sim_step(&SystemProfile::of(kind), &moe, &cluster, &gpu, tokens).total();
                assert!(
                    other > hetu,
                    "batch {batch}: {} ({other:.6}) must be slower than HetuMoE ({hetu:.6})",
                    kind.name()
                );
            }
        }
    }

    #[test]
    fn deepspeed_gap_is_large_at_small_batch_switch() {
        // Paper: up to 8.1× at batch 32 under the switch gate.
        let moe = paper_moe(GateKind::Switch);
        let cluster = titan_cluster(1);
        let gpu = GpuModel::titan_rtx();
        let tokens = 32 * 1024;
        let hetu = sim_step(
            &SystemProfile::of(SystemKind::HetuMoE),
            &moe,
            &cluster,
            &gpu,
            tokens,
        )
        .total();
        let ds = sim_step(
            &SystemProfile::of(SystemKind::DeepSpeedMoE),
            &moe,
            &cluster,
            &gpu,
            tokens,
        )
        .total();
        let ratio = ds / hetu;
        assert!(ratio > 6.0, "DeepSpeed/Hetu at bs=32: {ratio:.2} (paper: 8.1)");
        assert!(ratio < 13.0, "gap implausibly large: {ratio:.2}");
    }

    #[test]
    fn fastmoe_gap_is_modest() {
        // Paper: HetuMoE ≥ 15-18% over FastMoE.
        let moe = paper_moe(GateKind::GShard);
        let cluster = titan_cluster(1);
        let gpu = GpuModel::titan_rtx();
        let tokens = 64 * 1024;
        let hetu = sim_step(
            &SystemProfile::of(SystemKind::HetuMoE),
            &moe,
            &cluster,
            &gpu,
            tokens,
        )
        .total();
        let fm = sim_step(
            &SystemProfile::of(SystemKind::FastMoE),
            &moe,
            &cluster,
            &gpu,
            tokens,
        )
        .total();
        let ratio = fm / hetu;
        assert!(ratio > 1.12, "FastMoE/Hetu: {ratio:.3} (paper: ≥1.15)");
        assert!(ratio < 2.0, "gap implausible: {ratio:.3}");
    }

    #[test]
    fn multinode_is_comm_dominated_fig1_shape() {
        // Paper Fig 1: AllToAll ≈ 99% of time at 100 Gbps multi-node for
        // flat-AllToAll systems.
        let moe = paper_moe(GateKind::Switch);
        let cluster = titan_cluster(8);
        let gpu = GpuModel::titan_rtx();
        // Per-GPU batch 2 × seq 1024 → ~16-21 MB dispatch payload per GPU,
        // the paper's Fig-5/6 "common setting" where AllToAll messages are
        // latency-bound. (At much larger payloads flat AllToAll is already
        // bandwidth-saturated and hierarchy stops paying — see the
        // `ablations` bench for that crossover.)
        let tokens = 2 * 1024;
        // FastMoE = flat AllToAll without the dense-einsum dispatch, the
        // cleanest view of the communication share.
        let fm = sim_step(
            &SystemProfile::of(SystemKind::FastMoE),
            &moe,
            &cluster,
            &gpu,
            tokens,
        );
        let comm = fm.phase("alltoall");
        let frac = comm / fm.total();
        assert!(frac > 0.75, "comm fraction {frac:.3} (paper: ~0.99)");
        // Hierarchical reduces it substantially.
        let hetu = sim_step(
            &SystemProfile::of(SystemKind::HetuMoE),
            &moe,
            &cluster,
            &gpu,
            tokens,
        );
        assert!(hetu.phase("alltoall") < comm * 0.75);
    }

    #[test]
    fn options_map_to_pipeline_choices() {
        let p = SystemProfile::of(SystemKind::DeepSpeedMoE);
        let o = p.options(2);
        assert_eq!(o.layout_impl, LayoutImpl::DenseEinsum);
        assert_eq!(o.comm_impl, CommImpl::Flat);
        assert_eq!(o.threads, 2);
        let h = SystemProfile::of(SystemKind::HetuMoE).options(1);
        assert_eq!(h.comm_impl, CommImpl::Hierarchical);
        // All 2022-era profiles model the padded pipeline, with the
        // ragged-mode schedule pinned to the profile's flavor.
        assert_eq!(o.dispatch, DispatchMode::Padded);
        assert_eq!(o.alltoall, CommChoice::Flat);
        assert_eq!(h.dispatch, DispatchMode::Padded);
        assert_eq!(h.alltoall, CommChoice::Hierarchical);
    }
}
