//! Benchmark harness (criterion is not vendored offline).
//!
//! Provides warmed, auto-tuned timing with robust statistics (median +
//! MAD), a markdown table printer used by every `rust/benches/fig*.rs`
//! target, and CSV export so EXPERIMENTS.md rows can be regenerated
//! mechanically.

use crate::util::stats::{fmt_duration, mad, percentile};
use std::hint::black_box as bb;
use std::time::Instant;

/// Re-export of `std::hint::black_box` for benchmark bodies.
pub fn black_box<T>(x: T) -> T {
    bb(x)
}

/// Result of one benchmark measurement.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// Median seconds per iteration.
    pub median: f64,
    /// Median absolute deviation (seconds).
    pub mad: f64,
    /// Mean seconds per iteration.
    pub mean: f64,
    /// p90 seconds.
    pub p90: f64,
    /// Total measured iterations.
    pub iters: usize,
}

impl BenchResult {
    /// JSON export via the canonical schema module (see `obs::schema`),
    /// so the `metrics` harness and any `--json` surface agree on
    /// field names.
    pub fn to_json(&self) -> crate::util::json::Json {
        crate::obs::schema::bench_result_json(self)
    }

    pub fn display(&self) -> String {
        format!(
            "{}: {} ± {} (n={})",
            self.name,
            fmt_duration(self.median),
            fmt_duration(self.mad),
            self.iters
        )
    }
}

/// Options controlling a measurement.
#[derive(Clone, Debug)]
pub struct BenchOpts {
    /// Target total measurement wall time (seconds).
    pub measure_secs: f64,
    /// Warmup wall time (seconds).
    pub warmup_secs: f64,
    /// Max samples to record.
    pub max_samples: usize,
    /// Min samples to record.
    pub min_samples: usize,
}

impl Default for BenchOpts {
    fn default() -> Self {
        BenchOpts { measure_secs: 1.0, warmup_secs: 0.3, max_samples: 200, min_samples: 10 }
    }
}

impl BenchOpts {
    /// Quick profile for cheap micro benches in CI.
    pub fn quick() -> Self {
        BenchOpts { measure_secs: 0.25, warmup_secs: 0.05, max_samples: 100, min_samples: 5 }
    }

    /// Profile for expensive end-to-end steps.
    pub fn slow() -> Self {
        BenchOpts { measure_secs: 3.0, warmup_secs: 0.5, max_samples: 60, min_samples: 3 }
    }
}

/// Measure `f` with warmup and batching; returns per-iteration statistics.
pub fn bench<F: FnMut()>(name: &str, opts: &BenchOpts, mut f: F) -> BenchResult {
    // Warmup + estimate single-iteration cost.
    let warm_start = Instant::now();
    let mut warm_iters = 0usize;
    while warm_start.elapsed().as_secs_f64() < opts.warmup_secs || warm_iters == 0 {
        f();
        warm_iters += 1;
        if warm_iters > 1_000_000 {
            break;
        }
    }
    let est = warm_start.elapsed().as_secs_f64() / warm_iters as f64;

    // Choose a batch size so each sample is ≥ ~50µs (timer noise floor).
    let batch = ((5e-5 / est.max(1e-12)).ceil() as usize).max(1);
    let target_samples = ((opts.measure_secs / (est * batch as f64).max(1e-9)) as usize)
        .clamp(opts.min_samples, opts.max_samples);

    let mut samples = Vec::with_capacity(target_samples);
    let measure_start = Instant::now();
    for _ in 0..target_samples {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_secs_f64() / batch as f64);
        if measure_start.elapsed().as_secs_f64() > opts.measure_secs * 3.0 {
            break; // hard wall: don't let a mis-estimated batch run forever
        }
    }
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    BenchResult {
        name: name.to_string(),
        median: percentile(&samples, 50.0),
        mad: if samples.len() > 1 { mad(&samples) } else { 0.0 },
        mean,
        p90: percentile(&samples, 90.0),
        iters: samples.len() * batch,
    }
}

/// Markdown table builder used by the figure benches.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row arity mismatch");
        self.rows.push(cells);
    }

    /// Render as a GitHub-flavored markdown table.
    pub fn to_markdown(&self) -> String {
        let ncol = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let mut out = String::new();
        out.push_str(&format!("\n### {}\n\n", self.title));
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("|");
            for i in 0..ncol {
                line.push_str(&format!(" {:<w$} |", cells[i], w = widths[i]));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        let mut sep = String::from("|");
        for w in &widths {
            sep.push_str(&format!("{:-<w$}|", "", w = w + 2));
        }
        sep.push('\n');
        out.push_str(&sep);
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
        }
        out
    }

    /// Render as CSV (headers + rows).
    pub fn to_csv(&self) -> String {
        let esc = |s: &str| {
            if s.contains(',') || s.contains('"') {
                format!("\"{}\"", s.replace('"', "\"\""))
            } else {
                s.to_string()
            }
        };
        let mut out = String::new();
        out.push_str(&self.headers.iter().map(|h| esc(h)).collect::<Vec<_>>().join(","));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }

    /// Print markdown to stdout and optionally write CSV next to it.
    pub fn emit(&self, csv_path: Option<&str>) {
        println!("{}", self.to_markdown());
        if let Some(path) = csv_path {
            if let Some(dir) = std::path::Path::new(path).parent() {
                let _ = std::fs::create_dir_all(dir);
            }
            if let Err(e) = std::fs::write(path, self.to_csv()) {
                eprintln!("warning: could not write {path}: {e}");
            } else {
                println!("(csv written to {path})");
            }
        }
    }
}

/// Format a speedup ratio for tables.
pub fn fmt_speedup(baseline: f64, ours: f64) -> String {
    if ours <= 0.0 {
        return "n/a".into();
    }
    format!("{:.2}×", baseline / ours)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut acc = 0u64;
        let r = bench("noop-ish", &BenchOpts::quick(), || {
            acc = black_box(acc.wrapping_add(1));
        });
        assert!(r.median >= 0.0);
        assert!(r.iters > 0);
        assert!(r.display().contains("noop-ish"));
    }

    #[test]
    fn bench_orders_magnitudes() {
        let cheap = bench("cheap", &BenchOpts::quick(), || {
            black_box(1 + 1);
        });
        let costly = bench("costly", &BenchOpts::quick(), || {
            let mut s = 0u64;
            for i in 0..50_000u64 {
                s = s.wrapping_add(black_box(i));
            }
            black_box(s);
        });
        assert!(costly.median > cheap.median * 10.0);
    }

    #[test]
    fn table_markdown_and_csv() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["1".into(), "x,y".into()]);
        let md = t.to_markdown();
        assert!(md.contains("### Demo"));
        assert!(md.contains("| 1"));
        let csv = t.to_csv();
        assert!(csv.starts_with("a,b\n"));
        assert!(csv.contains("\"x,y\""));
    }

    #[test]
    #[should_panic(expected = "row arity mismatch")]
    fn table_rejects_bad_row() {
        let mut t = Table::new("Demo", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn speedup_formatting() {
        assert_eq!(fmt_speedup(2.0, 1.0), "2.00×");
        assert_eq!(fmt_speedup(1.0, 0.0), "n/a");
    }
}
