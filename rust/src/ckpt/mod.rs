//! Training checkpoints: model + optimizer + RNG streams.
//!
//! A checkpoint is everything needed to resume `NativeTrainer` **bit-
//! identically**: gate/head/expert parameters, Adam's step counter and
//! both moment lists, the data RNG mid-stream state (including the
//! Box–Muller spare) and the step index. The format is a little-endian
//! binary container — f32 bit patterns are written verbatim, because a
//! decimal round-trip (JSON) would break the exactness guarantee the
//! recovery tests assert.
//!
//! Layout: `"HMCK"` magic, `u32` version, `u64` step, five `u64` dims
//! `(E, d, h, classes, world)`, then length-prefixed f32 vectors for
//! gate weight / head weight / head bias, `E` expert blocks (w1, b1,
//! w2, b2), the Adam state (t, then m and v vector lists), the RNG
//! state, and (v2) the live expert placement: a presence byte + the
//! expert→rank table when an adaptive table is installed, then the
//! serving replica pair list (always empty for training snapshots).
//! Without the placement a restore after adaptive migrations would
//! silently fall back to the contiguous formula — same numerics, wrong
//! traffic accounting — so v1 files are rejected outright rather than
//! guessed at.

use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

use crate::ckpt_err;
use crate::error::{HetuError, Result};
use crate::util::rng::RngState;

const MAGIC: &[u8; 4] = b"HMCK";
const VERSION: u32 = 2;

/// One expert FFN's flat parameters.
#[derive(Clone, Debug, PartialEq)]
pub struct ExpertParams {
    pub w1: Vec<f32>,
    pub b1: Vec<f32>,
    pub w2: Vec<f32>,
    pub b2: Vec<f32>,
}

/// Full resumable training state (see module docs).
#[derive(Clone, Debug, PartialEq)]
pub struct TrainState {
    /// Step index the checkpoint resumes *at* (steps `< step` are done).
    pub step: u64,
    pub num_experts: u64,
    pub d_model: u64,
    pub ffn_hidden: u64,
    pub num_classes: u64,
    pub world: u64,
    pub gate_weight: Vec<f32>,
    pub head_w: Vec<f32>,
    pub head_b: Vec<f32>,
    pub experts: Vec<ExpertParams>,
    pub adam_t: u64,
    pub adam_m: Vec<Vec<f32>>,
    pub adam_v: Vec<Vec<f32>>,
    pub data_rng: RngState,
    /// Live expert→rank table installed by the adaptive placement
    /// (`None` = the static contiguous formula).
    pub placement: Option<Vec<u64>>,
    /// Serving replica pairs `(expert, rank)` (empty for training
    /// snapshots — training keeps single assignment).
    pub replicas: Vec<(u64, u64)>,
}

impl TrainState {
    /// Check the checkpoint's model dims against a config about to
    /// resume from it. `world` may legitimately differ only in its dead
    /// set, which the config carries — so it is compared as the full
    /// simulated world size, which recovery keeps fixed.
    pub fn validate_dims(
        &self,
        num_experts: usize,
        d_model: usize,
        ffn_hidden: usize,
        num_classes: usize,
        world: usize,
    ) -> Result<()> {
        let want = [
            ("num_experts", self.num_experts, num_experts as u64),
            ("d_model", self.d_model, d_model as u64),
            ("ffn_hidden", self.ffn_hidden, ffn_hidden as u64),
            ("num_classes", self.num_classes, num_classes as u64),
            ("world", self.world, world as u64),
        ];
        for (name, got, expect) in want {
            if got != expect {
                return Err(ckpt_err!(
                    "checkpoint {name}={got} does not match the config's {name}={expect}"
                ));
            }
        }
        Ok(())
    }
}

/// Write a checkpoint atomically (tmp file + rename, so a crash mid-save
/// never leaves a truncated checkpoint behind for recovery to trip on).
pub fn save(path: &Path, state: &TrainState) -> Result<()> {
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir).map_err(|e| {
                ckpt_err!("cannot create checkpoint dir '{}': {e}", dir.display())
            })?;
        }
    }
    let tmp = path.with_extension("tmp");
    {
        let file = std::fs::File::create(&tmp)
            .map_err(|e| ckpt_err!("cannot create '{}': {e}", tmp.display()))?;
        let mut w = BufWriter::new(file);
        write_state(&mut w, state)
            .map_err(|e| ckpt_err!("cannot write '{}': {e}", tmp.display()))?;
        w.flush().map_err(|e| ckpt_err!("cannot flush '{}': {e}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .map_err(|e| ckpt_err!("cannot move checkpoint into place at '{}': {e}", path.display()))
}

/// Load a checkpoint written by [`save`].
pub fn load(path: &Path) -> Result<TrainState> {
    let file = std::fs::File::open(path)
        .map_err(|e| ckpt_err!("cannot open checkpoint '{}': {e}", path.display()))?;
    let mut r = BufReader::new(file);
    read_state(&mut r).map_err(|e| match e {
        HetuError::Ckpt(m) => ckpt_err!("'{}': {m}", path.display()),
        other => ckpt_err!("cannot read '{}': {other}", path.display()),
    })
}

fn write_state<W: Write>(w: &mut W, s: &TrainState) -> std::io::Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&VERSION.to_le_bytes())?;
    for v in [s.step, s.num_experts, s.d_model, s.ffn_hidden, s.num_classes, s.world] {
        w.write_all(&v.to_le_bytes())?;
    }
    write_f32s(w, &s.gate_weight)?;
    write_f32s(w, &s.head_w)?;
    write_f32s(w, &s.head_b)?;
    w.write_all(&(s.experts.len() as u64).to_le_bytes())?;
    for e in &s.experts {
        write_f32s(w, &e.w1)?;
        write_f32s(w, &e.b1)?;
        write_f32s(w, &e.w2)?;
        write_f32s(w, &e.b2)?;
    }
    w.write_all(&s.adam_t.to_le_bytes())?;
    w.write_all(&(s.adam_m.len() as u64).to_le_bytes())?;
    for t in s.adam_m.iter().chain(s.adam_v.iter()) {
        write_f32s(w, t)?;
    }
    for lane in s.data_rng.s {
        w.write_all(&lane.to_le_bytes())?;
    }
    match s.data_rng.gauss_spare {
        Some(z) => {
            w.write_all(&[1u8])?;
            w.write_all(&z.to_le_bytes())?;
        }
        None => w.write_all(&[0u8])?,
    }
    match &s.placement {
        Some(table) => {
            w.write_all(&[1u8])?;
            w.write_all(&(table.len() as u64).to_le_bytes())?;
            for &r in table {
                w.write_all(&r.to_le_bytes())?;
            }
        }
        None => w.write_all(&[0u8])?,
    }
    w.write_all(&(s.replicas.len() as u64).to_le_bytes())?;
    for &(e, r) in &s.replicas {
        w.write_all(&e.to_le_bytes())?;
        w.write_all(&r.to_le_bytes())?;
    }
    Ok(())
}

fn read_state<R: Read>(r: &mut R) -> Result<TrainState> {
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(ckpt_err!("bad magic {magic:?} (not a HetuMoE checkpoint)"));
    }
    let version = read_u32(r)?;
    if version != VERSION {
        return Err(ckpt_err!("unsupported checkpoint version {version} (expected {VERSION})"));
    }
    let step = read_u64(r)?;
    let num_experts = read_u64(r)?;
    let d_model = read_u64(r)?;
    let ffn_hidden = read_u64(r)?;
    let num_classes = read_u64(r)?;
    let world = read_u64(r)?;
    let gate_weight = read_f32s(r)?;
    let head_w = read_f32s(r)?;
    let head_b = read_f32s(r)?;
    let n_experts = read_u64(r)? as usize;
    if n_experts != num_experts as usize {
        return Err(ckpt_err!("expert block count {n_experts} != num_experts {num_experts}"));
    }
    let mut experts = Vec::with_capacity(n_experts);
    for _ in 0..n_experts {
        experts.push(ExpertParams {
            w1: read_f32s(r)?,
            b1: read_f32s(r)?,
            w2: read_f32s(r)?,
            b2: read_f32s(r)?,
        });
    }
    let adam_t = read_u64(r)?;
    let n_tensors = read_u64(r)? as usize;
    let mut adam_m = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        adam_m.push(read_f32s(r)?);
    }
    let mut adam_v = Vec::with_capacity(n_tensors);
    for _ in 0..n_tensors {
        adam_v.push(read_f32s(r)?);
    }
    let mut s = [0u64; 4];
    for lane in s.iter_mut() {
        *lane = read_u64(r)?;
    }
    let mut flag = [0u8; 1];
    r.read_exact(&mut flag)?;
    let gauss_spare = match flag[0] {
        0 => None,
        1 => {
            let mut b = [0u8; 8];
            r.read_exact(&mut b)?;
            Some(f64::from_le_bytes(b))
        }
        other => return Err(ckpt_err!("corrupt RNG spare flag {other}")),
    };
    r.read_exact(&mut flag)?;
    let placement = match flag[0] {
        0 => None,
        1 => {
            let n = read_u64(r)?;
            if n != num_experts {
                return Err(ckpt_err!(
                    "placement table length {n} != num_experts {num_experts}"
                ));
            }
            let mut table = Vec::with_capacity(n as usize);
            for _ in 0..n {
                let rank = read_u64(r)?;
                if rank >= world {
                    return Err(ckpt_err!("placement rank {rank} outside world {world}"));
                }
                table.push(rank);
            }
            Some(table)
        }
        other => return Err(ckpt_err!("corrupt placement flag {other}")),
    };
    let n_replicas = read_u64(r)?;
    if n_replicas > MAX_VEC {
        return Err(ckpt_err!("corrupt replica count {n_replicas}"));
    }
    let mut replicas = Vec::with_capacity(n_replicas as usize);
    for _ in 0..n_replicas {
        let e = read_u64(r)?;
        let rank = read_u64(r)?;
        if e >= num_experts || rank >= world {
            return Err(ckpt_err!("corrupt replica pair ({e}, {rank})"));
        }
        replicas.push((e, rank));
    }
    Ok(TrainState {
        step,
        num_experts,
        d_model,
        ffn_hidden,
        num_classes,
        world,
        gate_weight,
        head_w,
        head_b,
        experts,
        adam_t,
        adam_m,
        adam_v,
        data_rng: RngState { s, gauss_spare },
        placement,
        replicas,
    })
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> std::io::Result<()> {
    w.write_all(&(xs.len() as u64).to_le_bytes())?;
    for &x in xs {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

const MAX_VEC: u64 = 1 << 32;

fn read_f32s<R: Read>(r: &mut R) -> Result<Vec<f32>> {
    let n = read_u64(r)?;
    if n > MAX_VEC {
        return Err(ckpt_err!("corrupt vector length {n}"));
    }
    let mut out = Vec::with_capacity(n as usize);
    let mut b = [0u8; 4];
    for _ in 0..n {
        r.read_exact(&mut b)?;
        out.push(f32::from_le_bytes(b));
    }
    Ok(out)
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_state() -> TrainState {
        let mut rng = Rng::seed(99);
        for _ in 0..5 {
            rng.next_u64();
        }
        rng.normal(); // cache a spare so the Option path is exercised
        TrainState {
            step: 17,
            num_experts: 2,
            d_model: 3,
            ffn_hidden: 4,
            num_classes: 5,
            world: 2,
            gate_weight: vec![1.5, -2.25, f32::MIN_POSITIVE, 0.0, 3.0, -0.125],
            head_w: vec![0.1; 15],
            head_b: vec![-0.5; 5],
            experts: (0..2)
                .map(|i| ExpertParams {
                    w1: vec![i as f32 + 0.25; 12],
                    b1: vec![0.0; 4],
                    w2: vec![-(i as f32); 12],
                    b2: vec![1e-30; 3],
                })
                .collect(),
            adam_t: 17,
            adam_m: vec![vec![0.5; 6], vec![0.25; 15]],
            adam_v: vec![vec![0.125; 6], vec![1e-9; 15]],
            data_rng: rng.state(),
            // Deliberately NOT the contiguous formula (that would be
            // [0, 1]): the round trip must preserve a live adaptive
            // layout and a serving replica verbatim.
            placement: Some(vec![1, 0]),
            replicas: vec![(0, 1)],
        }
    }

    #[test]
    fn save_load_round_trip_is_exact() {
        let dir = std::env::temp_dir().join("hetu_ckpt_test_rt");
        let path = dir.join("ckpt_000017.bin");
        let state = sample_state();
        save(&path, &state).unwrap();
        let loaded = load(&path).unwrap();
        assert_eq!(state, loaded, "bit-exact round trip incl. RNG spare");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_wrong_magic_and_truncation() {
        let dir = std::env::temp_dir().join("hetu_ckpt_test_bad");
        std::fs::create_dir_all(&dir).unwrap();
        let garbage = dir.join("garbage.bin");
        std::fs::write(&garbage, b"NOPE").unwrap();
        assert!(load(&garbage).is_err());

        let trunc = dir.join("trunc.bin");
        let good = dir.join("good.bin");
        save(&good, &sample_state()).unwrap();
        let bytes = std::fs::read(&good).unwrap();
        std::fs::write(&trunc, &bytes[..bytes.len() / 2]).unwrap();
        assert!(load(&trunc).is_err(), "truncated checkpoint must not load");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn static_snapshot_without_table_also_round_trips() {
        let dir = std::env::temp_dir().join("hetu_ckpt_test_static");
        let path = dir.join("ckpt_static.bin");
        let mut state = sample_state();
        state.placement = None;
        state.replicas = Vec::new();
        save(&path, &state).unwrap();
        assert_eq!(load(&path).unwrap(), state);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rejects_v1_and_corrupt_placement() {
        let dir = std::env::temp_dir().join("hetu_ckpt_test_v1");
        std::fs::create_dir_all(&dir).unwrap();
        let good = dir.join("good.bin");
        save(&good, &sample_state()).unwrap();
        let mut bytes = std::fs::read(&good).unwrap();
        // Rewrite the version word to 1: pre-placement files carry no
        // layout, so resuming them would silently mis-account traffic.
        bytes[4..8].copy_from_slice(&1u32.to_le_bytes());
        let old = dir.join("v1.bin");
        std::fs::write(&old, &bytes).unwrap();
        let err = load(&old).unwrap_err();
        assert!(err.to_string().contains("version 1"), "{err}");
        // A placement rank outside the world must be rejected on load,
        // not installed.
        let mut state = sample_state();
        state.placement = Some(vec![9, 0]);
        let bad = dir.join("bad.bin");
        save(&bad, &state).unwrap();
        assert!(load(&bad).unwrap_err().to_string().contains("outside world"));
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_file_is_a_typed_error() {
        let err = load(Path::new("/definitely/not/here.bin")).unwrap_err();
        assert!(matches!(err, HetuError::Ckpt(_)));
        assert!(err.to_string().contains("checkpoint"));
    }

    #[test]
    fn validate_dims_catches_mismatch() {
        let s = sample_state();
        assert!(s.validate_dims(2, 3, 4, 5, 2).is_ok());
        let err = s.validate_dims(4, 3, 4, 5, 2).unwrap_err();
        assert!(err.to_string().contains("num_experts"));
        assert!(s.validate_dims(2, 3, 4, 5, 8).is_err(), "world is pinned");
    }
}
