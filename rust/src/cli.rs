//! Command-line argument parsing (`clap` is not vendored offline).
//!
//! Supports `--flag`, `--key value`, `--key=value` and positional
//! arguments, with typed accessors and generated usage text. The `hetumoe`
//! binary's subcommands are built on this.

use crate::error::{HetuError, Result};
use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Args {
    /// Positional arguments in order (subcommand first, by convention).
    pub positional: Vec<String>,
    /// `--key value` / `--key=value` options.
    pub options: BTreeMap<String, String>,
    /// Bare `--flag` switches.
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    ///
    /// An option consumes the next token as its value unless that token
    /// starts with `--`; then it is treated as a boolean flag.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Args {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(a) = iter.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else {
                    match iter.next_if(|next| !next.starts_with("--")) {
                        Some(v) => {
                            out.options.insert(rest.to_string(), v);
                        }
                        None => out.flags.push(rest.to_string()),
                    }
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    /// Parse from the process environment.
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// The subcommand (first positional), if any.
    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(String::as_str)
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(String::as_str)
    }

    pub fn str_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                HetuError::Config(format!("--{name} expects an integer, got '{v}'"))
            }),
        }
    }

    pub fn u64_or(&self, name: &str, default: u64) -> Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                HetuError::Config(format!("--{name} expects an integer, got '{v}'"))
            }),
        }
    }

    pub fn f64_or(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| {
                HetuError::Config(format!("--{name} expects a number, got '{v}'"))
            }),
        }
    }

    /// Comma-separated usize list, e.g. `--batches 16,32,64`.
    pub fn usize_list_or(&self, name: &str, default: &[usize]) -> Result<Vec<usize>> {
        match self.get(name) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().parse().map_err(|_| {
                        HetuError::Config(format!("--{name}: bad integer '{s}'"))
                    })
                })
                .collect(),
        }
    }
}

/// A subcommand description for `--help` output.
pub struct CommandSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub options: &'static [(&'static str, &'static str)],
}

/// Render usage text for the binary.
pub fn usage(bin: &str, commands: &[CommandSpec]) -> String {
    let mut s = format!("USAGE: {bin} <command> [options]\n\ncommands:\n");
    for c in commands {
        s.push_str(&format!("  {:<18} {}\n", c.name, c.about));
    }
    s.push_str("\nper-command options:\n");
    for c in commands {
        if !c.options.is_empty() {
            s.push_str(&format!("  {}:\n", c.name));
            for (opt, about) in c.options {
                s.push_str(&format!("    --{:<20} {}\n", opt, about));
            }
        }
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string()))
    }

    #[test]
    fn parses_positional_and_options() {
        let a = parse(&["train", "--steps", "100", "--gate=gshard", "--verbose"]);
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get("steps"), Some("100"));
        assert_eq!(a.get("gate"), Some("gshard"));
        assert!(a.has_flag("verbose"));
    }

    #[test]
    fn typed_accessors() {
        let a = parse(&["x", "--n", "8", "--f", "2.5", "--list", "1,2,3"]);
        assert_eq!(a.usize_or("n", 0).unwrap(), 8);
        assert_eq!(a.usize_or("missing", 7).unwrap(), 7);
        assert_eq!(a.f64_or("f", 0.0).unwrap(), 2.5);
        assert_eq!(a.usize_list_or("list", &[]).unwrap(), vec![1, 2, 3]);
        assert!(a.usize_or("f", 0).is_err());
    }

    #[test]
    fn flag_followed_by_flag() {
        let a = parse(&["--quick", "--deep"]);
        assert!(a.has_flag("quick"));
        assert!(a.has_flag("deep"));
        assert!(a.options.is_empty());
    }

    #[test]
    fn negative_number_value() {
        // A value starting with '-' (not '--') is consumed as a value.
        let a = parse(&["--offset", "-5"]);
        assert_eq!(a.get("offset"), Some("-5"));
    }

    #[test]
    fn usage_renders() {
        let cmds = [CommandSpec {
            name: "train",
            about: "run training",
            options: &[("steps", "number of steps")],
        }];
        let u = usage("hetumoe", &cmds);
        assert!(u.contains("train"));
        assert!(u.contains("--steps"));
    }
}
