//! Analytic GPU compute model.
//!
//! The paper's evaluation runs on TITAN RTX GPUs we do not have; compute
//! phases of the *simulated* pipelines (Fig 1 / Fig 8) are charged with a
//! roofline model: `time = launches·overhead + flops/peak + bytes/membw`.
//! FLOP and byte counts come from the real tensor dimensions, launch
//! counts from each system's actual kernel structure (fused vs unfused) —
//! so relative system gaps emerge from mechanism, not fudge factors.

/// Roofline parameters of one simulated GPU.
#[derive(Clone, Debug)]
pub struct GpuModel {
    /// Effective matmul throughput, FLOP/s.
    pub flops: f64,
    /// Device memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Kernel launch + host sync overhead, seconds per launch.
    pub launch_overhead: f64,
}

impl GpuModel {
    /// TITAN RTX-class card (fp32 ≈ 16.3 TFLOPs peak, ~70% matmul
    /// efficiency; 672 GB/s HBM; ~10 µs per launch incl. driver time).
    pub fn titan_rtx() -> GpuModel {
        GpuModel { flops: 11.5e12, mem_bw: 672.0e9, launch_overhead: 10.0e-6 }
    }

    /// A100-class card (for the Fig-1 single-node profile).
    pub fn a100() -> GpuModel {
        GpuModel { flops: 19.5e12 * 0.7, mem_bw: 1555.0e9, launch_overhead: 8.0e-6 }
    }

    /// Time of a compute-bound kernel.
    pub fn compute_time(&self, flops: f64, launches: usize) -> f64 {
        self.launch_overhead * launches as f64 + flops / self.flops
    }

    /// Time of a bandwidth-bound kernel.
    pub fn memory_time(&self, bytes: f64, launches: usize) -> f64 {
        self.launch_overhead * launches as f64 + bytes / self.mem_bw
    }

    /// Time of a kernel doing both (max of rails, plus launches).
    pub fn kernel_time(&self, flops: f64, bytes: f64, launches: usize) -> f64 {
        self.launch_overhead * launches as f64
            + (flops / self.flops).max(bytes / self.mem_bw)
    }
}

/// Extra simulated compute time a straggling rank adds on top of a
/// baseline kernel/phase wall: `base·(factor−1)`, clamped so a healthy
/// factor (≤ 1) injects nothing. Fault injection is additive — the base
/// phase time stays untouched so breakdowns remain honest.
pub fn straggle_extra(base: f64, factor: f64) -> f64 {
    base * (factor - 1.0).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn launch_overhead_dominates_tiny_kernels() {
        let g = GpuModel::titan_rtx();
        let tiny = g.compute_time(1e3, 1);
        assert!(tiny > 0.9 * g.launch_overhead);
        // 30 launches of tiny kernels ≈ 30× one launch.
        let many = g.compute_time(1e3, 30);
        assert!(many / tiny > 25.0);
    }

    #[test]
    fn big_matmul_is_compute_bound() {
        let g = GpuModel::titan_rtx();
        let flops = 2.0 * 32768.0 * 2048.0 * 2048.0;
        let t = g.kernel_time(flops, 32768.0 * 2048.0 * 4.0 * 3.0, 1);
        assert!((t - flops / g.flops).abs() / t < 0.2);
    }

    #[test]
    fn bandwidth_bound_copy() {
        let g = GpuModel::titan_rtx();
        let bytes = 1e9;
        let t = g.memory_time(bytes, 2);
        assert!(t > bytes / g.mem_bw);
        assert!(t < bytes / g.mem_bw + 3.0 * g.launch_overhead);
    }
}
