//! Cluster simulator: topology math, the α–β network cost model, and the
//! simulated event timeline.
//!
//! The paper's testbed (nodes of 8 TITAN RTX GPUs over PCIe, one NIC per
//! node) is unavailable here, so "GPUs" are simulated ranks that own real
//! host buffers. Collectives in [`crate::comm`] move the actual bytes
//! (semantics are testable) and charge simulated time through
//! [`NetworkModel`] (performance is analyzable). See DESIGN.md §2.

pub mod gpu;
pub mod network;
pub mod placement;
pub mod timeline;

pub use gpu::GpuModel;
pub use network::{LinkKind, NetworkModel};
pub use placement::ExpertPlacement;
pub use timeline::{Event, Timeline};
