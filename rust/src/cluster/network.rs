//! α–β network cost model with small-message bandwidth penalty and NIC
//! contention.
//!
//! A point-to-point message of `m` bytes over a link with latency `α` and
//! peak bandwidth `β` costs
//!
//! ```text
//!   t(m) = α + m / eff_bw(m),     eff_bw(m) = β · m / (m + c)
//! ```
//!
//! where `c` (`msg_bw_const`) is the half-peak message size — the standard
//! way to capture that NCCL/RDMA reaches peak bandwidth only for large
//! messages. Inter-node traffic of all GPUs in a node serializes through
//! the node's NIC(s); that contention is what hierarchical AllToAll
//! exploits (fewer, larger messages through the same NIC).

use crate::config::ClusterConfig;

/// Which physical link a transfer crosses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LinkKind {
    /// GPU↔GPU inside a node (PCIe / NVLink), pairwise.
    Intra,
    /// Node↔node through the NIC.
    Inter,
    /// On-device copy (layout transform, message aggregation).
    Device,
}

/// The cost model. Cheap to copy around; all methods are pure.
#[derive(Clone, Debug)]
pub struct NetworkModel {
    pub cfg: ClusterConfig,
}

impl NetworkModel {
    pub fn new(cfg: ClusterConfig) -> Self {
        NetworkModel { cfg }
    }

    /// Effective bandwidth of one message of `bytes` on a link with peak
    /// `bw`: `bw · m/(m+c)`.
    pub fn eff_bw(&self, bw: f64, bytes: f64) -> f64 {
        if bytes <= 0.0 {
            return bw;
        }
        bw * bytes / (bytes + self.cfg.msg_bw_const)
    }

    /// Time for one point-to-point message.
    pub fn msg_time(&self, kind: LinkKind, bytes: f64) -> f64 {
        match kind {
            LinkKind::Intra => {
                self.cfg.intra_lat + bytes / self.eff_bw(self.cfg.intra_bw, bytes)
            }
            LinkKind::Inter => {
                self.cfg.inter_lat + bytes / self.eff_bw(self.cfg.inter_bw, bytes)
            }
            LinkKind::Device => bytes / self.cfg.gpu_mem_bw,
        }
    }

    /// Time for a batch of `count` equal messages through one NIC,
    /// serialized (α per message + bytes at message-size effective bw),
    /// spread over the node's NICs.
    pub fn nic_batch_time(&self, count: usize, msg_bytes: f64) -> f64 {
        if count == 0 || msg_bytes <= 0.0 {
            return 0.0;
        }
        let per_msg = self.cfg.inter_lat + msg_bytes / self.eff_bw(self.cfg.inter_bw, msg_bytes);
        per_msg * count as f64 / self.cfg.nics_per_node as f64
    }

    /// Time for `count` equal messages on one GPU's intra-node link,
    /// serialized.
    pub fn intra_batch_time(&self, count: usize, msg_bytes: f64) -> f64 {
        if count == 0 || msg_bytes <= 0.0 {
            return 0.0;
        }
        (self.cfg.intra_lat + msg_bytes / self.eff_bw(self.cfg.intra_bw, msg_bytes))
            * count as f64
    }

    /// Gather/scatter of `total_bytes` through the node's PCIe-switch
    /// fabric (aggregate bandwidth `intra_gather_bw`), `count` messages.
    pub fn gather_time(&self, count: usize, total_bytes: f64) -> f64 {
        if count == 0 || total_bytes <= 0.0 {
            return 0.0;
        }
        self.cfg.intra_lat * count as f64 + total_bytes / self.cfg.intra_gather_bw
    }

    /// On-device copy time (layout transform / aggregation buffers).
    pub fn device_copy_time(&self, bytes: f64) -> f64 {
        bytes / self.cfg.gpu_mem_bw
    }

    /// Extra simulated time a degraded NIC adds on top of a baseline
    /// exchange wall: `base·(factor−1)`, clamped so a healthy factor
    /// (≤ 1) injects nothing. The exchange serializes on the slowest
    /// NIC, so callers pass the worst per-node degradation factor.
    /// Additive by design — the base exchange time is never rescaled,
    /// keeping fault-free accounting bit-identical.
    pub fn degraded_extra(&self, base: f64, factor: f64) -> f64 {
        base * (factor - 1.0).max(0.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn model() -> NetworkModel {
        NetworkModel::new(ClusterConfig::commodity(4))
    }

    #[test]
    fn eff_bw_monotone_in_message_size() {
        let m = model();
        let bw = m.cfg.inter_bw;
        let small = m.eff_bw(bw, 1e4);
        let mid = m.eff_bw(bw, 1e6);
        let large = m.eff_bw(bw, 64e6);
        assert!(small < mid && mid < large);
        assert!(large <= bw);
        // Half-peak at msg == c.
        let half = m.eff_bw(bw, m.cfg.msg_bw_const);
        assert!((half - bw / 2.0).abs() / bw < 1e-9);
    }

    #[test]
    fn msg_time_has_latency_floor() {
        let m = model();
        let t = m.msg_time(LinkKind::Inter, 1.0);
        assert!(t >= m.cfg.inter_lat);
        // Zero-ish bytes → ~pure latency.
        assert!((m.msg_time(LinkKind::Inter, 0.0) - m.cfg.inter_lat).abs() < 1e-12);
    }

    #[test]
    fn inter_slower_than_intra_for_same_bytes() {
        let m = model();
        let bytes = 4.0e6;
        assert!(m.msg_time(LinkKind::Inter, bytes) > m.msg_time(LinkKind::Intra, bytes) * 0.5);
        assert!(m.msg_time(LinkKind::Device, bytes) < m.msg_time(LinkKind::Intra, bytes));
    }

    #[test]
    fn aggregation_beats_fragmentation_through_nic() {
        // Same total bytes, 64 small messages vs 1 large: the large
        // message must be strictly faster (this inequality IS the paper's
        // hierarchical-AllToAll argument).
        let m = model();
        let total = 32.0e6;
        let frag = m.nic_batch_time(64, total / 64.0);
        let agg = m.nic_batch_time(1, total);
        assert!(
            agg < frag * 0.7,
            "aggregated={agg:.6}s fragmented={frag:.6}s"
        );
    }

    #[test]
    fn nic_count_divides_time() {
        let mut cfg = ClusterConfig::commodity(2);
        cfg.nics_per_node = 2;
        let m2 = NetworkModel::new(cfg);
        let m1 = model();
        let t1 = m1.nic_batch_time(8, 1e6);
        let t2 = m2.nic_batch_time(8, 1e6);
        assert!((t1 / t2 - 2.0).abs() < 1e-9);
    }

    #[test]
    fn zero_edges() {
        let m = model();
        assert_eq!(m.nic_batch_time(0, 1e6), 0.0);
        assert_eq!(m.intra_batch_time(3, 0.0), 0.0);
        assert_eq!(m.gather_time(0, 0.0), 0.0);
    }
}
