//! Expert placement: which rank hosts which expert.
//!
//! HetuMoE partitions the `E` experts contiguously across the `W`
//! ranks, `E/W` per rank, so expert `e` lives on rank `e / (E/W)`.
//! Both the training layer and the serving router (and the backward
//! pass's traffic-matrix construction) depend on this one formula; it
//! lives here so the two paths can never disagree about where an
//! expert is.
//!
//! Rank failure breaks contiguity: [`ExpertPlacement::with_dead`]
//! elastically remaps the dead ranks' experts onto the survivors
//! (greedy least-loaded, deterministic), and every lookup generalizes
//! through an explicit expert→rank table. The contiguous case keeps the
//! closed-form arithmetic — no table is materialized, so the healthy
//! path costs exactly what it did before elasticity existed.

/// Expert partitioning over a world of ranks: contiguous by default,
/// table-based after an elastic remap around dead ranks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExpertPlacement {
    pub num_experts: usize,
    pub world: usize,
    /// Explicit expert→rank table; `None` means contiguous `e/(E/W)`.
    table: Option<Vec<usize>>,
    /// Per-rank hosted expert lists (ascending), only when remapped.
    hosted: Vec<Vec<usize>>,
}

impl ExpertPlacement {
    /// The one constructor every healthy path uses. Divisibility is
    /// validated at configuration time (`MoeLayer::native` & co. reject
    /// indivisible `E`/`W` with a config error); here it is a
    /// programming-error assert, not a recoverable condition.
    pub fn new(num_experts: usize, world: usize) -> ExpertPlacement {
        debug_assert!(
            world > 0 && num_experts > 0 && num_experts % world == 0,
            "num_experts {num_experts} must be a positive multiple of world {world}"
        );
        ExpertPlacement { num_experts, world, table: None, hosted: Vec::new() }
    }

    /// Elastic placement for a world with dead ranks: start from the
    /// contiguous layout, then move each dead rank's experts — dead
    /// ranks in ascending order, experts in ascending order — one at a
    /// time onto the surviving rank currently hosting the fewest
    /// experts (ties → lowest rank id). Greedy least-loaded keeps the
    /// remapped load within one expert of balanced, and the order makes
    /// the result a pure function of `(E, W, dead)` so training and
    /// serving can never disagree about the recovered layout.
    ///
    /// With no dead ranks this *is* [`ExpertPlacement::new`] (compares
    /// equal), so healthy paths stay on the closed-form arithmetic.
    pub fn with_dead(num_experts: usize, world: usize, dead: &[usize]) -> ExpertPlacement {
        let mut dead: Vec<usize> = dead.iter().copied().filter(|&r| r < world).collect();
        dead.sort_unstable();
        dead.dedup();
        if dead.is_empty() {
            return ExpertPlacement::new(num_experts, world);
        }
        debug_assert!(
            dead.len() < world,
            "cannot place {num_experts} experts with all {world} ranks dead"
        );
        let base = ExpertPlacement::new(num_experts, world);
        let is_dead = |r: usize| dead.binary_search(&r).is_ok();
        let mut hosted: Vec<Vec<usize>> = (0..world)
            .map(|r| if is_dead(r) { Vec::new() } else { base.hosted_experts(r) })
            .collect();
        for &dr in &dead {
            for e in base.hosted_experts(dr) {
                let target = (0..world)
                    .filter(|&r| !is_dead(r))
                    .min_by_key(|&r| (hosted[r].len(), r))
                    .expect("at least one survivor");
                hosted[target].push(e);
            }
        }
        let mut table = vec![0usize; num_experts];
        for (r, list) in hosted.iter_mut().enumerate() {
            list.sort_unstable();
            for &e in list.iter() {
                table[e] = r;
            }
        }
        ExpertPlacement { num_experts, world, table: Some(table), hosted }
    }

    /// True for the contiguous `E/W`-per-rank layout (no remap active).
    /// The hierarchical exchange and top-k dedup paths require this;
    /// a remapped placement falls back to the flat exchange.
    pub fn is_contiguous(&self) -> bool {
        self.table.is_none()
    }

    /// Nominal experts hosted per rank (`E/W`) of the contiguous
    /// layout. Under a remap, per-rank counts vary — use
    /// [`ExpertPlacement::num_hosted`] / [`ExpertPlacement::max_hosted`].
    pub fn experts_per_rank(&self) -> usize {
        self.num_experts / self.world
    }

    /// Rank hosting global expert `e` (the paper's `e / (E/W)` when
    /// contiguous; the remap table otherwise).
    pub fn rank_of(&self, expert: usize) -> usize {
        debug_assert!(expert < self.num_experts);
        match &self.table {
            None => expert / self.experts_per_rank(),
            Some(t) => t[expert],
        }
    }

    /// Local index of global expert `e` inside its host rank (its
    /// position in the rank's ascending hosted list).
    pub fn local_of(&self, expert: usize) -> usize {
        match &self.table {
            None => expert % self.experts_per_rank(),
            Some(t) => self.hosted[t[expert]]
                .binary_search(&expert)
                .expect("table and hosted lists agree"),
        }
    }

    /// Global expert id of rank `r`'s `local`-th expert.
    pub fn expert_of(&self, rank: usize, local: usize) -> usize {
        match &self.table {
            None => rank * self.experts_per_rank() + local,
            Some(_) => self.hosted[rank][local],
        }
    }

    /// Global expert ids hosted by rank `r`, ascending. Empty for a
    /// dead rank under a remap.
    pub fn hosted_experts(&self, rank: usize) -> Vec<usize> {
        match &self.table {
            None => {
                let epr = self.experts_per_rank();
                (rank * epr..(rank + 1) * epr).collect()
            }
            Some(_) => self.hosted[rank].clone(),
        }
    }

    /// Number of experts hosted by rank `r`.
    pub fn num_hosted(&self, rank: usize) -> usize {
        match &self.table {
            None => self.experts_per_rank(),
            Some(_) => self.hosted[rank].len(),
        }
    }

    /// Largest per-rank hosted count (== `E/W` when contiguous).
    pub fn max_hosted(&self) -> usize {
        match &self.table {
            None => self.experts_per_rank(),
            Some(_) => self.hosted.iter().map(Vec::len).max().unwrap_or(0),
        }
    }

    /// Collapse one source rank's per-expert kept counts into its row of
    /// the rank-level traffic matrix.
    pub fn rank_counts_row(&self, kept: &[usize]) -> Vec<usize> {
        debug_assert_eq!(kept.len(), self.num_experts);
        let mut counts = vec![0usize; self.world];
        for (e, &c) in kept.iter().enumerate() {
            counts[self.rank_of(e)] += c;
        }
        counts
    }

    /// Full `counts[src][dst]` traffic matrix from the per-(rank, expert)
    /// kept matrix (forward dispatch direction; the combine leg is its
    /// transpose).
    pub fn traffic_matrix(&self, kept: &[Vec<usize>]) -> Vec<Vec<usize>> {
        kept.iter().map(|row| self.rank_counts_row(row)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_formula() {
        let p = ExpertPlacement::new(8, 4);
        assert_eq!(p.experts_per_rank(), 2);
        assert_eq!(p.rank_of(0), 0);
        assert_eq!(p.rank_of(3), 1);
        assert_eq!(p.rank_of(7), 3);
        assert_eq!(p.local_of(3), 1);
        assert_eq!(p.expert_of(3, 1), 7);
        assert!(p.is_contiguous());
        assert_eq!(p.hosted_experts(1), vec![2, 3]);
        assert_eq!(p.num_hosted(2), 2);
        assert_eq!(p.max_hosted(), 2);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "positive multiple")]
    fn rejects_indivisible() {
        let _ = ExpertPlacement::new(7, 2);
    }

    #[test]
    fn traffic_matrix_matches_manual_collapse() {
        let p = ExpertPlacement::new(4, 2);
        let kept = vec![vec![1usize, 2, 3, 4], vec![5, 6, 7, 8]];
        assert_eq!(p.traffic_matrix(&kept), vec![vec![3, 7], vec![11, 15]]);
        assert_eq!(p.rank_counts_row(&kept[0]), vec![3, 7]);
    }

    #[test]
    fn with_dead_empty_is_contiguous() {
        assert_eq!(ExpertPlacement::with_dead(8, 4, &[]), ExpertPlacement::new(8, 4));
    }

    #[test]
    fn with_dead_redistributes_evenly_and_deterministically() {
        let p = ExpertPlacement::with_dead(8, 4, &[1]);
        assert!(!p.is_contiguous());
        // Rank 1's experts {2, 3} go to the least-loaded survivors:
        // all tie at 2 hosted, so lowest ids win — rank 0 then rank 2.
        assert_eq!(p.hosted_experts(0), vec![0, 1, 2]);
        assert_eq!(p.hosted_experts(1), Vec::<usize>::new());
        assert_eq!(p.hosted_experts(2), vec![3, 4, 5]);
        assert_eq!(p.hosted_experts(3), vec![6, 7]);
        // Pure function of (E, W, dead): rebuilt placements agree.
        assert_eq!(p, ExpertPlacement::with_dead(8, 4, &[1]));
        // Unsorted/duplicated dead lists normalize.
        assert_eq!(p, ExpertPlacement::with_dead(8, 4, &[1, 1]));
    }

    #[test]
    fn with_dead_lookups_are_consistent() {
        for dead in [&[0usize][..], &[2], &[1, 3], &[0, 1]] {
            let p = ExpertPlacement::with_dead(12, 4, dead);
            let mut seen = vec![false; 12];
            for r in 0..4 {
                if dead.contains(&r) {
                    assert_eq!(p.num_hosted(r), 0, "dead rank {r} hosts nothing");
                }
                for (l, e) in p.hosted_experts(r).into_iter().enumerate() {
                    assert_eq!(p.rank_of(e), r);
                    assert_eq!(p.local_of(e), l);
                    assert_eq!(p.expert_of(r, l), e);
                    assert!(!seen[e], "expert {e} placed twice");
                    seen[e] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "every expert placed: dead={dead:?}");
            // Survivor load stays within one expert of balanced.
            let alive_counts: Vec<usize> = (0..4)
                .filter(|r| !dead.contains(r))
                .map(|r| p.num_hosted(r))
                .collect();
            let (lo, hi) = (
                *alive_counts.iter().min().unwrap(),
                *alive_counts.iter().max().unwrap(),
            );
            assert!(hi - lo <= 1, "unbalanced remap {alive_counts:?} for dead={dead:?}");
            assert_eq!(p.max_hosted(), hi);
        }
    }

    #[test]
    fn with_dead_traffic_never_targets_dead_ranks() {
        let p = ExpertPlacement::with_dead(8, 4, &[2]);
        let kept = vec![vec![1usize; 8]; 4];
        for row in p.traffic_matrix(&kept) {
            assert_eq!(row[2], 0, "no tokens routed to the dead rank");
            assert_eq!(row.iter().sum::<usize>(), 8);
        }
    }

    #[test]
    fn with_dead_ignores_out_of_range_ranks() {
        assert_eq!(ExpertPlacement::with_dead(8, 4, &[9]), ExpertPlacement::new(8, 4));
    }
}
