//! Expert placement: which rank hosts which expert.
//!
//! HetuMoE partitions the `E` experts contiguously across the `W`
//! ranks, `E/W` per rank, so expert `e` lives on rank `e / (E/W)`.
//! Both the training layer and the serving router (and now the backward
//! pass's traffic-matrix construction) depend on this one formula; it
//! lives here so the two paths can never disagree about where an
//! expert is.

/// Contiguous expert partitioning over a world of ranks.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpertPlacement {
    pub num_experts: usize,
    pub world: usize,
}

impl ExpertPlacement {
    /// The one constructor every path uses. Divisibility is validated at
    /// configuration time (`MoeLayer::native` & co. reject indivisible
    /// `E`/`W` with a config error); here it is a programming-error
    /// assert, not a recoverable condition.
    pub fn new(num_experts: usize, world: usize) -> ExpertPlacement {
        debug_assert!(
            world > 0 && num_experts > 0 && num_experts % world == 0,
            "num_experts {num_experts} must be a positive multiple of world {world}"
        );
        ExpertPlacement { num_experts, world }
    }

    /// Experts hosted per rank (`E/W`).
    pub fn experts_per_rank(&self) -> usize {
        self.num_experts / self.world
    }

    /// Rank hosting global expert `e` (the paper's `e / (E/W)`).
    pub fn rank_of(&self, expert: usize) -> usize {
        debug_assert!(expert < self.num_experts);
        expert / self.experts_per_rank()
    }

    /// Local index of global expert `e` inside its host rank.
    pub fn local_of(&self, expert: usize) -> usize {
        expert % self.experts_per_rank()
    }

    /// Global expert id of rank `r`'s `local`-th expert.
    pub fn expert_of(&self, rank: usize, local: usize) -> usize {
        rank * self.experts_per_rank() + local
    }

    /// Collapse one source rank's per-expert kept counts into its row of
    /// the rank-level traffic matrix.
    pub fn rank_counts_row(&self, kept: &[usize]) -> Vec<usize> {
        debug_assert_eq!(kept.len(), self.num_experts);
        let mut counts = vec![0usize; self.world];
        for (e, &c) in kept.iter().enumerate() {
            counts[self.rank_of(e)] += c;
        }
        counts
    }

    /// Full `counts[src][dst]` traffic matrix from the per-(rank, expert)
    /// kept matrix (forward dispatch direction; the combine leg is its
    /// transpose).
    pub fn traffic_matrix(&self, kept: &[Vec<usize>]) -> Vec<Vec<usize>> {
        kept.iter().map(|row| self.rank_counts_row(row)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_formula() {
        let p = ExpertPlacement::new(8, 4);
        assert_eq!(p.experts_per_rank(), 2);
        assert_eq!(p.rank_of(0), 0);
        assert_eq!(p.rank_of(3), 1);
        assert_eq!(p.rank_of(7), 3);
        assert_eq!(p.local_of(3), 1);
        assert_eq!(p.expert_of(3, 1), 7);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "positive multiple")]
    fn rejects_indivisible() {
        let _ = ExpertPlacement::new(7, 2);
    }

    #[test]
    fn traffic_matrix_matches_manual_collapse() {
        let p = ExpertPlacement::new(4, 2);
        let kept = vec![vec![1usize, 2, 3, 4], vec![5, 6, 7, 8]];
        assert_eq!(p.traffic_matrix(&kept), vec![vec![3, 7], vec![11, 15]]);
        assert_eq!(p.rank_counts_row(&kept[0]), vec![3, 7]);
    }
}
