//! Expert placement: which rank hosts which expert.
//!
//! HetuMoE partitions the `E` experts contiguously across the `W`
//! ranks, `E/W` per rank, so expert `e` lives on rank `e / (E/W)`.
//! Both the training layer and the serving router (and the backward
//! pass's traffic-matrix construction) depend on this one formula; it
//! lives here so the two paths can never disagree about where an
//! expert is.
//!
//! Rank failure breaks contiguity: [`ExpertPlacement::with_dead`]
//! elastically remaps the dead ranks' experts onto the survivors
//! (greedy least-loaded, deterministic), and every lookup generalizes
//! through an explicit expert→rank table. The contiguous case keeps the
//! closed-form arithmetic — no table is materialized, so the healthy
//! path costs exactly what it did before elasticity existed.
//!
//! Adaptive placement (`placement/`) generalizes further: an arbitrary
//! expert→rank table installed via [`ExpertPlacement::from_table`]
//! (e.g. after the optimizer swapped a hot expert across nodes), with
//! [`ExpertPlacement::compose_dead`] layering the elastic remap on top
//! so a kill during an adaptive run degrades exactly like a kill under
//! the formula. [`ExpertPlacement::resolve`] is the one entry point the
//! layer, executor, backward pass and serving router all share.

/// Expert partitioning over a world of ranks: contiguous by default,
/// table-based after an elastic remap around dead ranks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ExpertPlacement {
    pub num_experts: usize,
    pub world: usize,
    /// Explicit expert→rank table; `None` means contiguous `e/(E/W)`.
    table: Option<Vec<usize>>,
    /// Per-rank hosted expert lists (ascending), only when remapped.
    hosted: Vec<Vec<usize>>,
}

impl ExpertPlacement {
    /// The one constructor every healthy path uses. Divisibility is
    /// validated at configuration time (`MoeLayer::native` & co. reject
    /// indivisible `E`/`W` with a config error); here it is a
    /// programming-error assert, not a recoverable condition.
    pub fn new(num_experts: usize, world: usize) -> ExpertPlacement {
        debug_assert!(
            world > 0 && num_experts > 0 && num_experts % world == 0,
            "num_experts {num_experts} must be a positive multiple of world {world}"
        );
        ExpertPlacement { num_experts, world, table: None, hosted: Vec::new() }
    }

    /// Elastic placement for a world with dead ranks: start from the
    /// contiguous layout, then move each dead rank's experts — dead
    /// ranks in ascending order, experts in ascending order — one at a
    /// time onto the surviving rank currently hosting the fewest
    /// experts (ties → lowest rank id). Greedy least-loaded keeps the
    /// remapped load within one expert of balanced, and the order makes
    /// the result a pure function of `(E, W, dead)` so training and
    /// serving can never disagree about the recovered layout.
    ///
    /// With no dead ranks this *is* [`ExpertPlacement::new`] (compares
    /// equal), so healthy paths stay on the closed-form arithmetic.
    pub fn with_dead(num_experts: usize, world: usize, dead: &[usize]) -> ExpertPlacement {
        Self::with_dead_loaded(num_experts, world, dead, None)
    }

    /// [`ExpertPlacement::with_dead`] with an optional observed
    /// per-expert load window. With `None` the remap is the historical
    /// uniform-count greedy (bit-identical to `with_dead`); with
    /// `Some(load)` the dead ranks' experts move heaviest-first onto
    /// the survivor carrying the least *observed* hosted load (ties →
    /// fewest hosted, then lowest rank id), so a skewed history lands
    /// the hot orphan on a genuinely idle rank instead of merely the
    /// shortest hosted list. Still a pure function of its arguments.
    pub fn with_dead_loaded(
        num_experts: usize,
        world: usize,
        dead: &[usize],
        load: Option<&[f64]>,
    ) -> ExpertPlacement {
        ExpertPlacement::new(num_experts, world).compose_dead_loaded(dead, load)
    }

    /// Layer the elastic dead-rank remap on top of *this* placement
    /// (identity when `dead` is empty or hosts nothing here). This is
    /// how an adaptive table composes with PR 7's fault path: the
    /// optimizer's layout stays in force and only the dead ranks'
    /// experts move, with the same deterministic greedy as
    /// [`ExpertPlacement::with_dead`].
    pub fn compose_dead(&self, dead: &[usize]) -> ExpertPlacement {
        self.compose_dead_loaded(dead, None)
    }

    /// [`ExpertPlacement::compose_dead`] with an optional observed
    /// per-expert load: when present, orphaned experts re-home onto the
    /// least-*loaded* survivor (hottest orphan first) instead of the
    /// least-*populated* one. `None` is bit-identical to the historical
    /// uniform remap.
    pub fn compose_dead_loaded(
        &self,
        dead: &[usize],
        load: Option<&[f64]>,
    ) -> ExpertPlacement {
        let world = self.world;
        let num_experts = self.num_experts;
        let mut dead: Vec<usize> = dead.iter().copied().filter(|&r| r < world).collect();
        dead.sort_unstable();
        dead.dedup();
        let is_dead = |r: usize| dead.binary_search(&r).is_ok();
        if dead.is_empty() || (0..world).filter(|&r| is_dead(r)).all(|r| self.num_hosted(r) == 0)
        {
            return self.clone();
        }
        debug_assert!(
            dead.len() < world,
            "cannot place {num_experts} experts with all {world} ranks dead"
        );
        debug_assert!(load.is_none_or(|l| l.len() == num_experts));
        let expert_load = |e: usize| load.map_or(0.0, |l| l[e]);
        let mut hosted: Vec<Vec<usize>> = (0..world)
            .map(|r| if is_dead(r) { Vec::new() } else { self.hosted_experts(r) })
            .collect();
        let mut rank_load: Vec<f64> = hosted
            .iter()
            .map(|list| list.iter().map(|&e| expert_load(e)).sum())
            .collect();
        for &dr in &dead {
            let mut orphans = self.hosted_experts(dr);
            // Heaviest orphan places first when a load window is
            // available (better final balance); ascending-id otherwise —
            // the exact historical order, keeping `with_dead` pinned.
            if load.is_some() {
                orphans.sort_by(|&a, &b| {
                    expert_load(b).total_cmp(&expert_load(a)).then(a.cmp(&b))
                });
            }
            for e in orphans {
                let target = (0..world)
                    .filter(|&r| !is_dead(r))
                    .min_by(|&a, &b| match load {
                        None => (hosted[a].len(), a).cmp(&(hosted[b].len(), b)),
                        Some(_) => rank_load[a]
                            .total_cmp(&rank_load[b])
                            .then((hosted[a].len(), a).cmp(&(hosted[b].len(), b))),
                    })
                    .expect("at least one survivor");
                hosted[target].push(e);
                rank_load[target] += expert_load(e);
            }
        }
        let mut table = vec![0usize; num_experts];
        for (r, list) in hosted.iter_mut().enumerate() {
            list.sort_unstable();
            for &e in list.iter() {
                table[e] = r;
            }
        }
        ExpertPlacement { num_experts, world, table: Some(table), hosted }
    }

    /// Placement from an explicit expert→rank table (the adaptive
    /// optimizer's output). A table that coincides with the contiguous
    /// formula normalizes to [`ExpertPlacement::new`] (compares equal,
    /// `is_contiguous` true), so "adaptive but never moved" stays on
    /// the closed-form fast path with hierarchical + dedup eligible.
    /// Callers validate untrusted tables with
    /// [`ExpertPlacement::validate_table`] first.
    pub fn from_table(num_experts: usize, world: usize, table: &[usize]) -> ExpertPlacement {
        debug_assert_eq!(table.len(), num_experts);
        debug_assert!(table.iter().all(|&r| r < world));
        if num_experts % world == 0 {
            let epr = num_experts / world;
            if table.iter().enumerate().all(|(e, &r)| r == e / epr) {
                return ExpertPlacement::new(num_experts, world);
            }
        }
        let mut hosted: Vec<Vec<usize>> = vec![Vec::new(); world];
        for (e, &r) in table.iter().enumerate() {
            hosted[r].push(e); // ascending: e iterates in order
        }
        ExpertPlacement { num_experts, world, table: Some(table.to_vec()), hosted }
    }

    /// Typed validation of an untrusted expert→rank table (CLI /
    /// checkpoint input) — checked at configuration time so the hot
    /// paths can keep plain asserts.
    pub fn validate_table(
        num_experts: usize,
        world: usize,
        table: &[usize],
    ) -> crate::error::Result<()> {
        if table.len() != num_experts {
            return Err(crate::config_err!(
                "placement table has {} entries for {num_experts} experts",
                table.len()
            ));
        }
        if let Some(&r) = table.iter().find(|&&r| r >= world) {
            return Err(crate::config_err!(
                "placement table maps an expert to rank {r}, outside the world of {world}"
            ));
        }
        Ok(())
    }

    /// The one placement-derivation entry point shared by the training
    /// layer, the step executor, the backward pass and the serving
    /// router: an optional explicit table (adaptive placement)
    /// composed with the elastic dead-rank remap. `resolve(E, W, None,
    /// dead)` is exactly the historical `with_dead(E, W, dead)`.
    pub fn resolve(
        num_experts: usize,
        world: usize,
        table: Option<&[usize]>,
        dead: &[usize],
    ) -> ExpertPlacement {
        match table {
            None => ExpertPlacement::with_dead(num_experts, world, dead),
            Some(t) => {
                ExpertPlacement::from_table(num_experts, world, t).compose_dead(dead)
            }
        }
    }

    /// The full expert→rank table (materialized even when contiguous) —
    /// what checkpoints persist.
    pub fn table_vec(&self) -> Vec<usize> {
        (0..self.num_experts).map(|e| self.rank_of(e)).collect()
    }

    /// True for the contiguous `E/W`-per-rank layout (no remap active).
    /// The hierarchical exchange and top-k dedup paths require this;
    /// a remapped placement falls back to the flat exchange.
    pub fn is_contiguous(&self) -> bool {
        self.table.is_none()
    }

    /// Nominal experts hosted per rank (`E/W`) of the contiguous
    /// layout. Under a remap, per-rank counts vary — use
    /// [`ExpertPlacement::num_hosted`] / [`ExpertPlacement::max_hosted`].
    pub fn experts_per_rank(&self) -> usize {
        self.num_experts / self.world
    }

    /// Rank hosting global expert `e` (the paper's `e / (E/W)` when
    /// contiguous; the remap table otherwise).
    pub fn rank_of(&self, expert: usize) -> usize {
        debug_assert!(expert < self.num_experts);
        match &self.table {
            None => expert / self.experts_per_rank(),
            Some(t) => t[expert],
        }
    }

    /// Local index of global expert `e` inside its host rank (its
    /// position in the rank's ascending hosted list).
    pub fn local_of(&self, expert: usize) -> usize {
        match &self.table {
            None => expert % self.experts_per_rank(),
            Some(t) => self.hosted[t[expert]]
                .binary_search(&expert)
                .expect("table and hosted lists agree"),
        }
    }

    /// Global expert id of rank `r`'s `local`-th expert.
    pub fn expert_of(&self, rank: usize, local: usize) -> usize {
        match &self.table {
            None => rank * self.experts_per_rank() + local,
            Some(_) => self.hosted[rank][local],
        }
    }

    /// Global expert ids hosted by rank `r`, ascending. Empty for a
    /// dead rank under a remap.
    pub fn hosted_experts(&self, rank: usize) -> Vec<usize> {
        match &self.table {
            None => {
                let epr = self.experts_per_rank();
                (rank * epr..(rank + 1) * epr).collect()
            }
            Some(_) => self.hosted[rank].clone(),
        }
    }

    /// Number of experts hosted by rank `r`.
    pub fn num_hosted(&self, rank: usize) -> usize {
        match &self.table {
            None => self.experts_per_rank(),
            Some(_) => self.hosted[rank].len(),
        }
    }

    /// Largest per-rank hosted count (== `E/W` when contiguous).
    pub fn max_hosted(&self) -> usize {
        match &self.table {
            None => self.experts_per_rank(),
            Some(_) => self.hosted.iter().map(Vec::len).max().unwrap_or(0),
        }
    }

    /// Collapse one source rank's per-expert kept counts into its row of
    /// the rank-level traffic matrix.
    pub fn rank_counts_row(&self, kept: &[usize]) -> Vec<usize> {
        debug_assert_eq!(kept.len(), self.num_experts);
        let mut counts = vec![0usize; self.world];
        for (e, &c) in kept.iter().enumerate() {
            counts[self.rank_of(e)] += c;
        }
        counts
    }

    /// Full `counts[src][dst]` traffic matrix from the per-(rank, expert)
    /// kept matrix (forward dispatch direction; the combine leg is its
    /// transpose).
    pub fn traffic_matrix(&self, kept: &[Vec<usize>]) -> Vec<Vec<usize>> {
        kept.iter().map(|row| self.rank_counts_row(row)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn placement_formula() {
        let p = ExpertPlacement::new(8, 4);
        assert_eq!(p.experts_per_rank(), 2);
        assert_eq!(p.rank_of(0), 0);
        assert_eq!(p.rank_of(3), 1);
        assert_eq!(p.rank_of(7), 3);
        assert_eq!(p.local_of(3), 1);
        assert_eq!(p.expert_of(3, 1), 7);
        assert!(p.is_contiguous());
        assert_eq!(p.hosted_experts(1), vec![2, 3]);
        assert_eq!(p.num_hosted(2), 2);
        assert_eq!(p.max_hosted(), 2);
    }

    #[cfg(debug_assertions)]
    #[test]
    #[should_panic(expected = "positive multiple")]
    fn rejects_indivisible() {
        let _ = ExpertPlacement::new(7, 2);
    }

    #[test]
    fn traffic_matrix_matches_manual_collapse() {
        let p = ExpertPlacement::new(4, 2);
        let kept = vec![vec![1usize, 2, 3, 4], vec![5, 6, 7, 8]];
        assert_eq!(p.traffic_matrix(&kept), vec![vec![3, 7], vec![11, 15]]);
        assert_eq!(p.rank_counts_row(&kept[0]), vec![3, 7]);
    }

    #[test]
    fn with_dead_empty_is_contiguous() {
        assert_eq!(ExpertPlacement::with_dead(8, 4, &[]), ExpertPlacement::new(8, 4));
    }

    #[test]
    fn with_dead_redistributes_evenly_and_deterministically() {
        let p = ExpertPlacement::with_dead(8, 4, &[1]);
        assert!(!p.is_contiguous());
        // Rank 1's experts {2, 3} go to the least-loaded survivors:
        // all tie at 2 hosted, so lowest ids win — rank 0 then rank 2.
        assert_eq!(p.hosted_experts(0), vec![0, 1, 2]);
        assert_eq!(p.hosted_experts(1), Vec::<usize>::new());
        assert_eq!(p.hosted_experts(2), vec![3, 4, 5]);
        assert_eq!(p.hosted_experts(3), vec![6, 7]);
        // Pure function of (E, W, dead): rebuilt placements agree.
        assert_eq!(p, ExpertPlacement::with_dead(8, 4, &[1]));
        // Unsorted/duplicated dead lists normalize.
        assert_eq!(p, ExpertPlacement::with_dead(8, 4, &[1, 1]));
    }

    #[test]
    fn with_dead_lookups_are_consistent() {
        for dead in [&[0usize][..], &[2], &[1, 3], &[0, 1]] {
            let p = ExpertPlacement::with_dead(12, 4, dead);
            let mut seen = vec![false; 12];
            for r in 0..4 {
                if dead.contains(&r) {
                    assert_eq!(p.num_hosted(r), 0, "dead rank {r} hosts nothing");
                }
                for (l, e) in p.hosted_experts(r).into_iter().enumerate() {
                    assert_eq!(p.rank_of(e), r);
                    assert_eq!(p.local_of(e), l);
                    assert_eq!(p.expert_of(r, l), e);
                    assert!(!seen[e], "expert {e} placed twice");
                    seen[e] = true;
                }
            }
            assert!(seen.iter().all(|&s| s), "every expert placed: dead={dead:?}");
            // Survivor load stays within one expert of balanced.
            let alive_counts: Vec<usize> = (0..4)
                .filter(|r| !dead.contains(r))
                .map(|r| p.num_hosted(r))
                .collect();
            let (lo, hi) = (
                *alive_counts.iter().min().unwrap(),
                *alive_counts.iter().max().unwrap(),
            );
            assert!(hi - lo <= 1, "unbalanced remap {alive_counts:?} for dead={dead:?}");
            assert_eq!(p.max_hosted(), hi);
        }
    }

    #[test]
    fn with_dead_traffic_never_targets_dead_ranks() {
        let p = ExpertPlacement::with_dead(8, 4, &[2]);
        let kept = vec![vec![1usize; 8]; 4];
        for row in p.traffic_matrix(&kept) {
            assert_eq!(row[2], 0, "no tokens routed to the dead rank");
            assert_eq!(row.iter().sum::<usize>(), 8);
        }
    }

    #[test]
    fn with_dead_ignores_out_of_range_ranks() {
        assert_eq!(ExpertPlacement::with_dead(8, 4, &[9]), ExpertPlacement::new(8, 4));
    }

    #[test]
    fn from_table_normalizes_the_contiguous_formula() {
        let t: Vec<usize> = (0..8).map(|e| e / 2).collect();
        let p = ExpertPlacement::from_table(8, 4, &t);
        assert!(p.is_contiguous());
        assert_eq!(p, ExpertPlacement::new(8, 4));
        assert_eq!(p.table_vec(), t);
    }

    #[test]
    fn from_table_arbitrary_permutation_is_consistent() {
        // Swap experts 0 and 7 across ranks, plus an uneven host.
        let t = vec![3usize, 0, 1, 1, 2, 2, 3, 0];
        let p = ExpertPlacement::from_table(8, 4, &t);
        assert!(!p.is_contiguous());
        assert_eq!(p.table_vec(), t);
        assert_eq!(p.hosted_experts(0), vec![1, 7]);
        assert_eq!(p.hosted_experts(3), vec![0, 6]);
        for e in 0..8 {
            assert_eq!(p.rank_of(e), t[e]);
            let (r, l) = (p.rank_of(e), p.local_of(e));
            assert_eq!(p.expert_of(r, l), e);
        }
        let kept = vec![1usize; 8];
        assert_eq!(p.rank_counts_row(&kept), vec![2, 2, 2, 2]);
        assert!(ExpertPlacement::validate_table(8, 4, &t).is_ok());
        assert!(ExpertPlacement::validate_table(8, 4, &t[..7]).is_err());
        assert!(ExpertPlacement::validate_table(8, 4, &[0, 0, 0, 0, 0, 0, 0, 4]).is_err());
    }

    #[test]
    fn compose_dead_on_contiguous_matches_with_dead() {
        for dead in [&[0usize][..], &[1], &[1, 3], &[0, 2]] {
            let composed = ExpertPlacement::new(8, 4).compose_dead(dead);
            assert_eq!(composed, ExpertPlacement::with_dead(8, 4, dead));
        }
    }

    #[test]
    fn compose_dead_preserves_the_adaptive_table_for_survivors() {
        let t = vec![3usize, 0, 1, 1, 2, 2, 3, 0];
        let p = ExpertPlacement::from_table(8, 4, &t).compose_dead(&[1]);
        // Rank 1's experts {2, 3} move; everyone else stays put.
        assert_eq!(p.num_hosted(1), 0);
        for (e, &r) in t.iter().enumerate() {
            if r != 1 {
                assert_eq!(p.rank_of(e), r, "survivor expert {e} moved");
            } else {
                assert_ne!(p.rank_of(e), 1);
            }
        }
        // resolve() is the same composition.
        assert_eq!(p, ExpertPlacement::resolve(8, 4, Some(&t), &[1]));
        // Dead rank hosting nothing already: identity.
        let q = ExpertPlacement::from_table(8, 4, &[0, 0, 2, 2, 2, 3, 3, 3]);
        assert_eq!(q.compose_dead(&[1]), q);
    }

    #[test]
    fn resolve_without_table_is_with_dead() {
        assert_eq!(
            ExpertPlacement::resolve(8, 4, None, &[2]),
            ExpertPlacement::with_dead(8, 4, &[2])
        );
        assert_eq!(ExpertPlacement::resolve(8, 4, None, &[]), ExpertPlacement::new(8, 4));
    }

    #[test]
    fn loaded_remap_consults_the_observed_window() {
        // 8 experts, 4 ranks; rank 1 dies hosting experts {2, 3} where
        // expert 2 is hot. Uniform remap sends 2→rank 0, 3→rank 2 (by
        // hosted-count ties, lowest id first). With the observed window
        // rank 3 is nearly idle, so the hot orphan must land there.
        let load = [5.0, 5.0, 40.0, 1.0, 5.0, 5.0, 0.1, 0.1];
        let uniform = ExpertPlacement::with_dead(8, 4, &[1]);
        assert_eq!(uniform.rank_of(2), 0);
        assert_eq!(uniform.rank_of(3), 2);
        let loaded = ExpertPlacement::with_dead_loaded(8, 4, &[1], Some(&load));
        // Rank loads before remap: r0=10, r2=10, r3=0.2 → hot orphan
        // (expert 2, placed first as the heaviest) goes to rank 3; the
        // light orphan (expert 3) then also prefers rank 3? No — rank 3
        // now carries 40.2, so expert 3 goes to the lightest of r0/r2
        // (tie at 10.0 → fewer hosted ties too → rank 0).
        assert_eq!(loaded.rank_of(2), 3);
        assert_eq!(loaded.rank_of(3), 0);
        assert_eq!(loaded.num_hosted(1), 0);
        // Pure function: rebuilt identically.
        assert_eq!(loaded, ExpertPlacement::with_dead_loaded(8, 4, &[1], Some(&load)));
        // No window → bit-identical to the historical remap.
        assert_eq!(ExpertPlacement::with_dead_loaded(8, 4, &[1], None), uniform);
    }
}
