//! Simulated event timeline.
//!
//! Collectives and pipeline phases append [`Event`]s (name, simulated
//! start, simulated duration). The timeline produces the per-phase
//! breakdown behind Figure 1 and exports JSON for offline inspection.

use crate::util::json::Json;

/// Whether an event is base phase work or injected fault delay. Keeping
/// the two apart is what lets breakdowns stay honest under fault
/// injection: `total_for`/`base_total` report only real phase time,
/// while `injected_total`/`injected_for` account the added delay.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum EventKind {
    #[default]
    Base,
    Fault,
}

/// One recorded phase/event on the simulated clock.
#[derive(Clone, Debug, PartialEq)]
pub struct Event {
    pub name: String,
    /// Simulated start time (seconds since step start).
    pub start: f64,
    /// Simulated duration (seconds).
    pub dur: f64,
    /// Base phase time vs injected fault delay.
    pub kind: EventKind,
}

/// An append-only simulated timeline with a running clock.
#[derive(Clone, Debug, Default)]
pub struct Timeline {
    events: Vec<Event>,
    clock: f64,
}

impl Timeline {
    pub fn new() -> Timeline {
        Timeline::default()
    }

    /// Current simulated time.
    pub fn now(&self) -> f64 {
        self.clock
    }

    /// Record an event of `dur` seconds starting now; advances the clock.
    pub fn push(&mut self, name: &str, dur: f64) {
        self.events.push(Event {
            name: name.to_string(),
            start: self.clock,
            dur,
            kind: EventKind::Base,
        });
        self.clock += dur;
    }

    /// Record injected fault delay (`straggle/*`, `retry/*`,
    /// `rank_fail/*`) as a first-class event; advances the clock but is
    /// kept out of the base-phase totals.
    pub fn push_fault(&mut self, name: &str, dur: f64) {
        self.events.push(Event {
            name: name.to_string(),
            start: self.clock,
            dur,
            kind: EventKind::Fault,
        });
        self.clock += dur;
    }

    /// Record an event that overlaps (does not advance the clock).
    pub fn push_overlapped(&mut self, name: &str, dur: f64) {
        self.events.push(Event {
            name: name.to_string(),
            start: self.clock,
            dur,
            kind: EventKind::Base,
        });
    }

    /// Advance the clock without an event (idle / barrier wait).
    pub fn advance(&mut self, dur: f64) {
        self.clock += dur;
    }

    pub fn events(&self) -> &[Event] {
        &self.events
    }

    /// Total *base* duration attributed to events whose name starts with
    /// `prefix`. Injected fault delay is excluded so per-phase breakdowns
    /// stay honest under fault injection (see [`Timeline::injected_for`]).
    pub fn total_for(&self, prefix: &str) -> f64 {
        self.events
            .iter()
            .filter(|e| e.kind == EventKind::Base && e.name.starts_with(prefix))
            .map(|e| e.dur)
            .sum()
    }

    /// Injected fault delay attributed to events whose name starts with
    /// `prefix`.
    pub fn injected_for(&self, prefix: &str) -> f64 {
        self.events
            .iter()
            .filter(|e| e.kind == EventKind::Fault && e.name.starts_with(prefix))
            .map(|e| e.dur)
            .sum()
    }

    /// Sum of all event durations (base + injected).
    pub fn total(&self) -> f64 {
        self.events.iter().map(|e| e.dur).sum()
    }

    /// Sum of base phase durations only.
    pub fn base_total(&self) -> f64 {
        self.events.iter().filter(|e| e.kind == EventKind::Base).map(|e| e.dur).sum()
    }

    /// Sum of injected fault delay only.
    pub fn injected_total(&self) -> f64 {
        self.events.iter().filter(|e| e.kind == EventKind::Fault).map(|e| e.dur).sum()
    }

    /// Collapse into (name → total seconds) pairs in first-seen order.
    pub fn breakdown(&self) -> Vec<(String, f64)> {
        let mut order: Vec<String> = Vec::new();
        let mut totals: std::collections::HashMap<String, f64> = Default::default();
        for e in &self.events {
            if !totals.contains_key(&e.name) {
                order.push(e.name.clone());
            }
            *totals.entry(e.name.clone()).or_insert(0.0) += e.dur;
        }
        order.into_iter().map(|n| {
            let t = totals[&n];
            (n, t)
        }).collect()
    }

    /// Merge another timeline's events under a prefix, sequentially after
    /// the current clock. Event kinds are preserved.
    pub fn absorb(&mut self, prefix: &str, other: &Timeline) {
        for e in other.events() {
            match e.kind {
                EventKind::Base => self.push(&format!("{prefix}{}", e.name), e.dur),
                EventKind::Fault => self.push_fault(&format!("{prefix}{}", e.name), e.dur),
            }
        }
    }

    /// Export as JSON (for tooling / EXPERIMENTS.md appendices).
    pub fn to_json(&self) -> Json {
        Json::arr(self.events.iter().map(|e| {
            Json::obj(vec![
                ("name", Json::str(e.name.clone())),
                ("start", Json::num(e.start)),
                ("dur", Json::num(e.dur)),
                (
                    "kind",
                    Json::str(match e.kind {
                        EventKind::Base => "base",
                        EventKind::Fault => "fault",
                    }),
                ),
            ])
        }))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances_with_events() {
        let mut t = Timeline::new();
        t.push("gate", 0.1);
        t.push("alltoall", 0.2);
        assert!((t.now() - 0.3).abs() < 1e-12);
        assert_eq!(t.events().len(), 2);
        assert_eq!(t.events()[1].start, 0.1);
    }

    #[test]
    fn overlapped_does_not_advance() {
        let mut t = Timeline::new();
        t.push_overlapped("prefetch", 0.5);
        assert_eq!(t.now(), 0.0);
        assert_eq!(t.total(), 0.5);
    }

    #[test]
    fn breakdown_aggregates_by_name() {
        let mut t = Timeline::new();
        t.push("alltoall", 0.1);
        t.push("expert", 0.3);
        t.push("alltoall", 0.2);
        let b = t.breakdown();
        assert_eq!(b[0].0, "alltoall");
        assert!((b[0].1 - 0.3).abs() < 1e-12);
        assert!((t.total_for("all") - 0.3).abs() < 1e-12);
    }

    #[test]
    fn absorb_prefixes_and_sequences() {
        let mut inner = Timeline::new();
        inner.push("gather", 1.0);
        inner.push("inter", 2.0);
        let mut outer = Timeline::new();
        outer.push("gate", 0.5);
        outer.absorb("a2a/", &inner);
        assert!((outer.now() - 3.5).abs() < 1e-12);
        assert!((outer.total_for("a2a/") - 3.0).abs() < 1e-12);
    }

    #[test]
    fn json_export_shape() {
        let mut t = Timeline::new();
        t.push("x", 0.25);
        let j = t.to_json();
        let arr = j.as_arr().unwrap();
        assert_eq!(arr.len(), 1);
        assert_eq!(arr[0].str_field("name").unwrap(), "x");
        assert_eq!(arr[0].f64_field("dur").unwrap(), 0.25);
        assert_eq!(arr[0].str_field("kind").unwrap(), "base");
    }

    #[test]
    fn fault_events_separate_from_base_totals() {
        let mut t = Timeline::new();
        t.push("alltoall", 0.2);
        t.push_fault("straggle/rank1", 0.5);
        t.push_fault("retry/dispatch", 0.1);
        t.push("alltoall", 0.3);
        // Clock advances through fault delay (it is real simulated time)...
        assert!((t.now() - 1.1).abs() < 1e-12);
        // ...but base-phase aggregation stays honest.
        assert!((t.total_for("alltoall") - 0.5).abs() < 1e-12);
        assert!((t.total_for("straggle/") - 0.0).abs() < 1e-12);
        assert!((t.injected_for("straggle/") - 0.5).abs() < 1e-12);
        assert!((t.base_total() - 0.5).abs() < 1e-12);
        assert!((t.injected_total() - 0.6).abs() < 1e-12);
        assert!((t.total() - 1.1).abs() < 1e-12);
    }

    #[test]
    fn absorb_preserves_event_kind() {
        let mut inner = Timeline::new();
        inner.push("work", 1.0);
        inner.push_fault("straggle/rank0", 2.0);
        let mut outer = Timeline::new();
        outer.absorb("s/", &inner);
        assert!((outer.base_total() - 1.0).abs() < 1e-12);
        assert!((outer.injected_for("s/straggle/") - 2.0).abs() < 1e-12);
    }
}
