//! AllGather and ReduceScatter (ring schedules).
//!
//! Used by the coordinator for expert-parallel parameter collection and
//! by the sharding ablations.

use crate::cluster::NetworkModel;
use crate::comm::{uniform_len, CommTiming, F32_BYTES};
use crate::error::Result;

/// AllGather: every rank ends with the concatenation of all ranks'
/// buffers (rank order). Returns (gathered buffers, timing).
pub fn allgather(net: &NetworkModel, buffers: &[Vec<f32>]) -> Result<(Vec<Vec<f32>>, CommTiming)> {
    let w = buffers.len();
    let len = uniform_len(buffers)?;
    if w != net.cfg.world() {
        return Err(crate::comm_err!(
            "allgather over {w} buffers but cluster world is {}",
            net.cfg.world()
        ));
    }
    let mut cat = Vec::with_capacity(w * len);
    for b in buffers {
        cat.extend_from_slice(b);
    }
    let out = vec![cat; w];
    Ok((out, ring_timing(net, len * F32_BYTES, w.saturating_sub(1))))
}

/// ReduceScatter: rank `r` ends with the elementwise sum of everyone's
/// chunk `r`. Buffers must be `W` equal chunks long.
pub fn reduce_scatter(
    net: &NetworkModel,
    buffers: &mut [Vec<f32>],
) -> Result<CommTiming> {
    let w = buffers.len();
    let len = uniform_len(buffers)?;
    if w != net.cfg.world() {
        return Err(crate::comm_err!(
            "reduce_scatter over {w} buffers but cluster world is {}",
            net.cfg.world()
        ));
    }
    if len % w != 0 {
        return Err(crate::comm_err!("buffer len {len} not divisible by world {w}"));
    }
    let chunk = len / w;
    let mut outs: Vec<Vec<f32>> = Vec::with_capacity(w);
    for r in 0..w {
        let mut acc = vec![0.0f32; chunk];
        for b in buffers.iter() {
            for (a, x) in acc.iter_mut().zip(&b[r * chunk..(r + 1) * chunk]) {
                *a += *x;
            }
        }
        outs.push(acc);
    }
    for (b, o) in buffers.iter_mut().zip(outs) {
        *b = o;
    }
    Ok(ring_timing(net, chunk * F32_BYTES, w.saturating_sub(1)))
}

/// Ring timing: `steps` steps, each forwarding `seg_bytes` along the ring.
fn ring_timing(net: &NetworkModel, seg_bytes: usize, steps: usize) -> CommTiming {
    let cfg = &net.cfg;
    if steps == 0 {
        return CommTiming { phases: vec![("ring".into(), 0.0)], total: 0.0 };
    }
    let seg = seg_bytes as f64;
    let intra_hop = cfg.intra_lat + seg / net.eff_bw(cfg.intra_bw, seg);
    let hop = if cfg.nodes > 1 {
        let inter_hop = cfg.inter_lat + seg / net.eff_bw(cfg.inter_bw, seg);
        intra_hop.max(inter_hop)
    } else {
        intra_hop
    };
    let total = hop * steps as f64;
    CommTiming { phases: vec![("ring".into(), total)], total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn net(nodes: usize, gpus: usize) -> NetworkModel {
        let mut cfg = ClusterConfig::commodity(nodes);
        cfg.gpus_per_node = gpus;
        NetworkModel::new(cfg)
    }

    #[test]
    fn allgather_concatenates_in_rank_order() {
        let m = net(1, 3);
        let bufs = vec![vec![1.0f32], vec![2.0], vec![3.0]];
        let (out, t) = allgather(&m, &bufs).unwrap();
        for o in &out {
            assert_eq!(o, &vec![1.0, 2.0, 3.0]);
        }
        assert!(t.total > 0.0);
    }

    #[test]
    fn reduce_scatter_sums_chunks() {
        let m = net(2, 2);
        // 4 ranks, chunk=2. Rank r ends with sum of chunk r.
        let mut bufs: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..8).map(|i| (r * 10 + i) as f32).collect())
            .collect();
        reduce_scatter(&m, &mut bufs).unwrap();
        // chunk r elementwise: sum over ranks of (r*2+i + 10*rank).
        for r in 0..4 {
            for i in 0..2 {
                let expect: f32 = (0..4).map(|s| (s * 10 + r * 2 + i) as f32).sum();
                assert_eq!(bufs[r][i], expect);
            }
        }
    }

    #[test]
    fn reduce_scatter_then_allgather_is_allreduce() {
        let m = net(2, 2);
        let mut a: Vec<Vec<f32>> = (0..4)
            .map(|r| (0..8).map(|i| (r + i) as f32).collect())
            .collect();
        let mut expected = a.clone();
        crate::comm::allreduce(&m, &mut expected).unwrap();
        reduce_scatter(&m, &mut a).unwrap();
        let (gathered, _) = allgather(&m, &a).unwrap();
        assert_eq!(gathered[0], expected[0]);
    }

    #[test]
    fn validates_divisibility() {
        let m = net(1, 4);
        let mut bad = vec![vec![0.0; 5]; 4];
        assert!(reduce_scatter(&m, &mut bad).is_err());
    }
}
