//! Ring AllReduce (for the dense, non-expert gradients).
//!
//! MoE models still allreduce the non-expert parameters every step; the
//! coordinator charges this through the same cost model so end-to-end
//! step times (Fig 8) include it.

use crate::cluster::NetworkModel;
use crate::comm::{uniform_len, CommTiming, F32_BYTES};
use crate::error::Result;

/// In-place sum-AllReduce: every rank's buffer becomes the elementwise
/// sum over all ranks. Timing models the standard 2(W−1)-step ring.
pub fn allreduce(net: &NetworkModel, buffers: &mut [Vec<f32>]) -> Result<CommTiming> {
    let w = buffers.len();
    let len = uniform_len(buffers)?;
    if w != net.cfg.world() {
        return Err(crate::comm_err!(
            "allreduce over {w} buffers but cluster world is {}",
            net.cfg.world()
        ));
    }

    // ---- data movement: reduce then broadcast (semantically equal to ring) ----
    let mut sum = vec![0.0f32; len];
    for b in buffers.iter() {
        for (acc, x) in sum.iter_mut().zip(b) {
            *acc += *x;
        }
    }
    for b in buffers.iter_mut() {
        b.copy_from_slice(&sum);
    }

    Ok(allreduce_timing(net, len * F32_BYTES))
}

/// Ring-allreduce timing for a `bytes`-sized buffer per rank.
///
/// 2(W−1) steps; in each step every rank forwards `bytes/W` to its ring
/// neighbor. Within a node the hop crosses PCIe; at node boundaries it
/// crosses the NIC — with the ring laid out rank-major, each node has
/// exactly one outbound boundary hop per step, so the NIC carries one
/// message per step.
pub fn allreduce_timing(net: &NetworkModel, bytes: usize) -> CommTiming {
    let cfg = &net.cfg;
    let w = cfg.world();
    if w == 1 {
        return CommTiming { phases: vec![("local".into(), 0.0)], total: 0.0 };
    }
    let seg = bytes as f64 / w as f64;
    let steps = 2 * (w - 1);
    let intra_hop = cfg.intra_lat + seg / net.eff_bw(cfg.intra_bw, seg);
    let step_time = if cfg.nodes > 1 {
        let inter_hop = cfg.inter_lat + seg / net.eff_bw(cfg.inter_bw, seg);
        intra_hop.max(inter_hop) // slowest hop paces the ring
    } else {
        intra_hop
    };
    let total = steps as f64 * step_time;
    CommTiming { phases: vec![("ring".into(), total)], total }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::util::rng::Rng;

    fn net(nodes: usize, gpus: usize) -> NetworkModel {
        let mut cfg = ClusterConfig::commodity(nodes);
        cfg.gpus_per_node = gpus;
        NetworkModel::new(cfg)
    }

    #[test]
    fn sums_across_ranks() {
        let m = net(2, 2);
        let mut bufs = vec![
            vec![1.0f32, 2.0],
            vec![10.0, 20.0],
            vec![100.0, 200.0],
            vec![1000.0, 2000.0],
        ];
        allreduce(&m, &mut bufs).unwrap();
        for b in &bufs {
            assert_eq!(b, &vec![1111.0, 2222.0]);
        }
    }

    #[test]
    fn idempotent_on_equal_inputs_scaled() {
        let m = net(1, 4);
        let mut rng = Rng::seed(0);
        let base: Vec<f32> = (0..16).map(|_| rng.normal_f32()).collect();
        let mut bufs = vec![base.clone(); 4];
        allreduce(&m, &mut bufs).unwrap();
        for b in &bufs {
            for (x, y) in b.iter().zip(&base) {
                assert!((x - y * 4.0).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn timing_grows_with_world_and_bytes() {
        let t_small = allreduce_timing(&net(1, 2), 1 << 20);
        let t_big = allreduce_timing(&net(4, 8), 1 << 20);
        assert!(t_big.total > t_small.total);
        let t_more_bytes = allreduce_timing(&net(4, 8), 1 << 24);
        assert!(t_more_bytes.total > t_big.total);
    }

    #[test]
    fn single_rank_is_free() {
        let t = allreduce_timing(&net(1, 1), 1 << 20);
        assert_eq!(t.total, 0.0);
        let mut bufs = vec![vec![3.0f32]];
        allreduce(&net(1, 1), &mut bufs).unwrap();
        assert_eq!(bufs[0], vec![3.0]);
    }
}
