//! Vanilla (flat, NCCL-style) AllToAll.
//!
//! Paper Figure 5: every GPU splits its buffer into `W` equal chunks and
//! exchanges chunk `j` with rank `j`. With `N` nodes × `G` GPUs and
//! per-GPU payload `B`, each inter-node message is only `B/(NG)` bytes —
//! at the paper's common setting (`N=8, G=8, B=16 MB`) that is 256 KB,
//! far below the NIC's bandwidth saturation point, which is exactly the
//! inefficiency hierarchical AllToAll removes.

use crate::cluster::NetworkModel;
use crate::comm::{uniform_len, CommTiming, F32_BYTES};
use crate::error::Result;

/// Flat AllToAll over equal chunks.
///
/// `buffers[r]` holds rank `r`'s send data, logically `W` chunks of
/// `len/W` elements; on return `buffers[r]` chunk `s` contains what rank
/// `s` had in chunk `r`. Returns the simulated timing on `net`'s cluster.
pub fn alltoall(net: &NetworkModel, buffers: &mut [Vec<f32>]) -> Result<CommTiming> {
    let w = buffers.len();
    let len = uniform_len(buffers)?;
    let cfg = &net.cfg;
    if w != cfg.world() {
        return Err(crate::comm_err!(
            "alltoall over {w} buffers but cluster world is {}",
            cfg.world()
        ));
    }
    if len % w != 0 {
        return Err(crate::comm_err!("buffer len {len} not divisible by world {w}"));
    }
    let chunk = len / w;

    // ---- data movement: out[r][s] = in[s][r] (chunk-wise transpose) ----
    let mut out: Vec<Vec<f32>> = vec![vec![0.0f32; len]; w];
    for r in 0..w {
        for s in 0..w {
            out[r][s * chunk..(s + 1) * chunk]
                .copy_from_slice(&buffers[s][r * chunk..(r + 1) * chunk]);
        }
    }
    for (b, o) in buffers.iter_mut().zip(out) {
        *b = o;
    }

    // ---- simulated timing ----
    Ok(flat_alltoall_timing(net, chunk * F32_BYTES))
}

/// Timing of a flat AllToAll with `chunk_bytes` per pairwise message
/// (separate so benches can sweep payloads without allocating).
pub fn flat_alltoall_timing(net: &NetworkModel, chunk_bytes: usize) -> CommTiming {
    let cfg = &net.cfg;
    let (n, g) = (cfg.nodes, cfg.gpus_per_node);
    let w = n * g;
    let cb = chunk_bytes as f64;

    // Each GPU sends G-1 intra-node chunks over its own PCIe link.
    let t_intra = net.intra_batch_time(g.saturating_sub(1), cb);
    // Each node pushes G·(W−G) chunks through its NIC(s).
    let t_inter = net.nic_batch_time(g * (w - g), cb);
    // Intra and inter rails run concurrently (NCCL overlaps channels).
    let total = t_intra.max(t_inter);
    CommTiming {
        phases: vec![("intra".into(), t_intra), ("inter".into(), t_inter)],
        total,
    }
}

/// Variable-count AllToAll (`alltoallv`): `counts[s][d]` elements flow
/// from rank `s` to rank `d`. `buffers[s]` is the concatenation of the
/// `W` destination segments in rank order; on return `buffers[d]` is the
/// concatenation of the `W` source segments in rank order.
pub fn alltoallv(
    net: &NetworkModel,
    buffers: &mut [Vec<f32>],
    counts: &[Vec<usize>],
) -> Result<CommTiming> {
    let w = buffers.len();
    let cfg = &net.cfg;
    if w != cfg.world() {
        return Err(crate::comm_err!(
            "alltoallv over {w} buffers but cluster world is {}",
            cfg.world()
        ));
    }
    if counts.len() != w || counts.iter().any(|row| row.len() != w) {
        return Err(crate::comm_err!("counts must be {w}x{w}"));
    }
    for s in 0..w {
        let expect: usize = counts[s].iter().sum();
        if buffers[s].len() != expect {
            return Err(crate::comm_err!(
                "rank {s}: buffer has {} elements but counts sum to {expect}",
                buffers[s].len()
            ));
        }
    }

    // Source-side segment offsets.
    let offsets: Vec<Vec<usize>> = counts
        .iter()
        .map(|row| {
            let mut off = vec![0usize; w];
            for d in 1..w {
                off[d] = off[d - 1] + row[d - 1];
            }
            off
        })
        .collect();

    // ---- data movement ----
    let mut out: Vec<Vec<f32>> = (0..w)
        .map(|d| {
            let total: usize = counts.iter().map(|row| row[d]).sum();
            Vec::with_capacity(total)
        })
        .collect();
    for (d, out_d) in out.iter_mut().enumerate() {
        for s in 0..w {
            let lo = offsets[s][d];
            out_d.extend_from_slice(&buffers[s][lo..lo + counts[s][d]]);
        }
    }
    for (b, o) in buffers.iter_mut().zip(out) {
        *b = o;
    }

    // ---- simulated timing ----
    Ok(alltoallv_timing(net, counts, F32_BYTES))
}

/// Timing of a flat variable-count AllToAll: `counts[s][d]` messages of
/// `elem_bytes`-sized elements flow from rank `s` to rank `d` (zero
/// counts send nothing). Worst GPU intra rail vs worst NIC inter rail,
/// overlapped. Separate from [`alltoallv`] so cost-model callers (the
/// serving router, benches) can score a dispatch plan without moving
/// bytes.
pub fn alltoallv_timing(
    net: &NetworkModel,
    counts: &[Vec<usize>],
    elem_bytes: usize,
) -> CommTiming {
    let cfg = &net.cfg;
    let (n, g) = (cfg.nodes, cfg.gpus_per_node);
    let w = n * g;
    let mut t_intra_max = 0.0f64;
    let mut t_inter_max = 0.0f64;
    for node in 0..n {
        let mut nic_time = 0.0f64;
        for local in 0..g {
            let s = node * g + local;
            let mut gpu_intra = 0.0f64;
            for d in 0..w {
                if d == s || counts[s][d] == 0 {
                    continue;
                }
                let bytes = (counts[s][d] * elem_bytes) as f64;
                if cfg.node_of(d) == node {
                    gpu_intra +=
                        cfg.intra_lat + bytes / net.eff_bw(cfg.intra_bw, bytes);
                } else {
                    nic_time += cfg.inter_lat + bytes / net.eff_bw(cfg.inter_bw, bytes);
                }
            }
            t_intra_max = t_intra_max.max(gpu_intra);
        }
        t_inter_max = t_inter_max.max(nic_time / cfg.nics_per_node as f64);
    }
    CommTiming {
        phases: vec![("intra".into(), t_intra_max), ("inter".into(), t_inter_max)],
        total: t_intra_max.max(t_inter_max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn net(nodes: usize, gpus: usize) -> NetworkModel {
        let mut cfg = ClusterConfig::commodity(nodes);
        cfg.gpus_per_node = gpus;
        NetworkModel::new(cfg)
    }

    /// Tag each element with (source rank, chunk index, offset) so the
    /// permutation is fully checkable.
    fn tagged(w: usize, chunk: usize) -> Vec<Vec<f32>> {
        (0..w)
            .map(|r| {
                (0..w * chunk)
                    .map(|i| (r * w * chunk + i) as f32)
                    .collect()
            })
            .collect()
    }

    #[test]
    fn alltoall_permutation_semantics() {
        let m = net(2, 2);
        let chunk = 3;
        let mut bufs = tagged(4, chunk);
        let orig = bufs.clone();
        alltoall(&m, &mut bufs).unwrap();
        for r in 0..4 {
            for s in 0..4 {
                assert_eq!(
                    &bufs[r][s * chunk..(s + 1) * chunk],
                    &orig[s][r * chunk..(r + 1) * chunk],
                    "dest {r} chunk {s}"
                );
            }
        }
    }

    #[test]
    fn alltoall_is_involution() {
        let m = net(2, 4);
        let mut rng = Rng::seed(0);
        let w = 8;
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..w * 5).map(|_| rng.normal_f32()).collect())
            .collect();
        let orig = bufs.clone();
        alltoall(&m, &mut bufs).unwrap();
        alltoall(&m, &mut bufs).unwrap();
        assert_eq!(bufs, orig);
    }

    #[test]
    fn alltoall_conserves_elements() {
        let m = net(2, 2);
        let mut rng = Rng::seed(1);
        let mut bufs: Vec<Vec<f32>> = (0..4)
            .map(|_| (0..8).map(|_| rng.normal_f32()).collect())
            .collect();
        let sum_before: f64 = bufs.iter().flatten().map(|&x| x as f64).sum();
        alltoall(&m, &mut bufs).unwrap();
        let sum_after: f64 = bufs.iter().flatten().map(|&x| x as f64).sum();
        assert!((sum_before - sum_after).abs() < 1e-6);
    }

    #[test]
    fn alltoall_validates_inputs() {
        let m = net(1, 4);
        let mut bad_world = vec![vec![0.0; 4]; 3];
        assert!(alltoall(&m, &mut bad_world).is_err());
        let mut bad_len = vec![vec![0.0; 5]; 4]; // 5 % 4 != 0
        assert!(alltoall(&m, &mut bad_len).is_err());
    }

    #[test]
    fn timing_inter_dominates_on_commodity() {
        // Multi-node flat alltoall must be NIC-bound (paper: 99% of time
        // under 100 Gbps).
        let m = net(8, 8);
        let t = flat_alltoall_timing(&m, 16 * 1024 * 1024 / 64);
        assert!(t.phase("inter") > t.phase("intra") * 5.0);
        assert_eq!(t.total, t.phase("inter").max(t.phase("intra")));
    }

    #[test]
    fn timing_scales_with_payload() {
        let m = net(4, 8);
        let small = flat_alltoall_timing(&m, 1024);
        let big = flat_alltoall_timing(&m, 1024 * 1024);
        assert!(big.total > small.total);
    }

    #[test]
    fn alltoallv_matches_alltoall_on_equal_counts() {
        let m = net(2, 2);
        let w = 4;
        let chunk = 3;
        let mut a = tagged(w, chunk);
        let mut b = a.clone();
        let counts = vec![vec![chunk; w]; w];
        alltoall(&m, &mut a).unwrap();
        alltoallv(&m, &mut b, &counts).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn alltoallv_ragged_counts() {
        let m = net(1, 3);
        // counts[s][d]: s sends (s+d) elements to d.
        let counts: Vec<Vec<usize>> =
            (0..3).map(|s| (0..3).map(|d| s + d).collect()).collect();
        let mut bufs: Vec<Vec<f32>> = (0..3)
            .map(|s| {
                let total: usize = counts[s].iter().sum();
                (0..total).map(|i| (s * 100 + i) as f32).collect()
            })
            .collect();
        alltoallv(&m, &mut bufs, &counts).unwrap();
        for d in 0..3 {
            let expect: usize = (0..3).map(|s| counts[s][d]).sum();
            assert_eq!(bufs[d].len(), expect, "dest {d}");
        }
        // Spot-check: dest 2 receives from src 1 the segment after src 0's.
        // src 1 sends to 2: counts[1][2]=3 elements starting at offset 1+2=3.
        let received = &bufs[2][counts[0][2]..counts[0][2] + counts[1][2]];
        assert_eq!(received, &[103.0, 104.0, 105.0]);
    }

    #[test]
    fn alltoallv_timing_matches_flat_on_uniform_counts() {
        for (nodes, gpus, chunk) in [(2usize, 2usize, 64usize), (4, 8, 256)] {
            let m = net(nodes, gpus);
            let w = nodes * gpus;
            let counts = vec![vec![chunk; w]; w];
            let ragged = alltoallv_timing(&m, &counts, 4);
            let flat = flat_alltoall_timing(&m, chunk * 4);
            assert!(
                (ragged.total - flat.total).abs() < 1e-12,
                "nodes={nodes} gpus={gpus}: {} vs {}",
                ragged.total,
                flat.total
            );
        }
    }

    #[test]
    fn alltoallv_timing_is_direction_sensitive() {
        // Fan-in to one rank spreads the sends across ranks; the
        // reverse (fan-out from that rank) serializes them on a single
        // link — the serving router charges the combine leg on the
        // transposed matrix for exactly this reason.
        let m = net(1, 4);
        let mut fan_in = vec![vec![0usize; 4]; 4];
        fan_in[1][0] = 10;
        fan_in[2][0] = 10;
        fan_in[3][0] = 10;
        let fan_out: Vec<Vec<usize>> =
            (0..4).map(|d| (0..4).map(|s| fan_in[s][d]).collect()).collect();
        let t_in = alltoallv_timing(&m, &fan_in, 4).total;
        let t_out = alltoallv_timing(&m, &fan_out, 4).total;
        assert!(
            t_out > t_in * 2.0,
            "fan-out {t_out} must serialize vs fan-in {t_in}"
        );
    }

    #[test]
    fn alltoallv_conservation_property() {
        for_all(20, |g| {
            let w = 4;
            let m = net(2, 2);
            let counts: Vec<Vec<usize>> = (0..w)
                .map(|_| (0..w).map(|_| g.usize_in(0..6)).collect())
                .collect();
            let mut bufs: Vec<Vec<f32>> = (0..w)
                .map(|s| {
                    let total: usize = counts[s].iter().sum();
                    (0..total).map(|i| (s * 1000 + i) as f32).collect()
                })
                .collect();
            let before: usize = bufs.iter().map(|b| b.len()).sum();
            alltoallv(&m, &mut bufs, &counts).unwrap();
            let after: usize = bufs.iter().map(|b| b.len()).sum();
            assert_eq!(before, after);
        });
    }
}
