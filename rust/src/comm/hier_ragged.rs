//! The **real** ragged hierarchical AllToAllv data path (paper §3.2,
//! Figure 6), with HierMoE-style top-k token deduplication.
//!
//! [`crate::comm::ragged`] moves exact-count token rows but applies the
//! permutation in one logical step — the hierarchical schedule existed
//! only as a timing charge. This module executes the four phases for a
//! **variable-count** exchange:
//!
//! 1. **intra-node gather** — every GPU ships its ragged buffer to the
//!    node leader;
//! 2. **leader layout / aggregation** — the leader reorders rows so
//!    everything destined to the same remote *node* forms one
//!    contiguous block (message aggregation), optionally
//!    **deduplicating** replicas: a gate with k ≥ 2 that routes one
//!    token to several experts on the same destination node produced
//!    identical (or scalar-multiple) rows, which are shipped **once**
//!    plus a replication index list;
//! 3. **exact-count inter-node AllToAllv between leaders** — message
//!    sizes are the per-(src-node, dst-node) byte counts, not uniform
//!    chunks;
//! 4. **leader expansion + intra-node scatter** — the destination
//!    leader expands deduplicated blocks (replicating payload rows, or
//!    scaling them by the shipped per-slot weights) and delivers each
//!    local GPU's expert-major receive buffer.
//!
//! The final buffers are **bit-identical** to
//! [`crate::comm::ragged::ragged_dispatch`] /
//! [`crate::comm::ragged::ragged_combine`] on the same inputs: dedup
//! expansion is a memcpy (forward payloads) or the very `w · dy`
//! multiply the flat path performed at the source (backward payloads),
//! and combine-side **pre-summation** (see below) only regroups f32
//! additions in ways that preserve the consumer's exact summation
//! order.
//!
//! ## Pre-summation on the return legs
//!
//! The backward's dispatch-gradient leg ([`hier_ragged_combine`] with a
//! [`PresumMeta`]) sums, at the expert-side node leader, the per-token
//! partial input gradients of a **run** — a maximal set of consecutive
//! active slots of one token whose experts live on the same node — and
//! ships one row per run; the destination writes the run total at the
//! head row and zeros at the member rows, so the downstream per-slot
//! accumulation performs *exactly* the flat path's addition sequence
//! (zero rows are additive no-ops). Runs are restricted to consecutive
//! slots precisely because f32 addition is non-associative: summing a
//! non-contiguous group would reorder the accumulation and break the
//! bit-identity contract. The forward combine leg is **not**
//! pre-summed: the combine weights are applied token-side in the
//! reverse layout and the training cache needs the per-slot expert
//! outputs for the combine-weight gradient.
//!
//! ## Honest byte accounting
//!
//! Every leg reports a [`WireBytes`] split: `inter` is what actually
//! crosses a NIC (post-dedup payloads plus the replication-index
//! overhead — [`DEDUP_INDEX_BYTES`] per logical row), `intra` is the
//! node-fabric traffic (gather + scatter through the leader). Dedup is
//! decided **per (src-node, dst-node) block**, deterministically, and
//! only when it strictly shrinks the block
//! (`payloads·row + rows·index < rows·row`), so a k = 1 gate never
//! pays the index overhead. [`DedupTraffic`] derives the same counts
//! from the [`DispatchPlan`]s alone, which is what the schedule pick
//! ([`crate::comm::schedule::pick_schedule_dedup`]) and the serving
//! router score — the cost model and the data path can never disagree
//! about what would cross the wire.

use crate::cluster::{ExpertPlacement, NetworkModel};
use crate::comm::hierarchical::hierarchical_alltoallv_timing_with;
use crate::comm::precision::{bf16_round, WirePrecision};
use crate::comm::ragged::rank_counts;
use crate::comm::{CommTiming, WireBytes};
use crate::config::ClusterConfig;
use crate::error::Result;
use crate::gating::DispatchPlan;
use crate::obs::trace;
use crate::tensor::Tensor;
use std::collections::{BTreeMap, BTreeSet};

/// Wire overhead per logical row of a deduplicated dispatch block: a
/// `u32` payload index plus the `f32` expansion scale (the slot's
/// combine weight on the backward leg; 1.0 forward).
pub const DEDUP_INDEX_BYTES: usize = 8;

/// Wire overhead per logical row of a pre-summed combine block: a `u32`
/// head-map entry telling the receiver which rows arrived and which are
/// zero-filled members.
pub const PRESUM_INDEX_BYTES: usize = 4;

/// Packed replication-index entry under a compressed wire mode: a `u16`
/// payload slot plus a `bf16` expansion weight.
pub const PACKED_DEDUP_INDEX_BYTES: usize = 4;

/// Packed head-map entry under a compressed wire mode: a `u16` slot.
pub const PACKED_PRESUM_INDEX_BYTES: usize = 2;

/// Largest block (in logical rows) the `u16` packed index can address.
pub const PACKED_INDEX_MAX_ROWS: usize = 1 << 16;

/// Replication-index width of one dispatch block. The packed layout
/// applies only under a compressed wire mode (the f32 wire keeps the
/// u32+f32 layout bit-for-bit) and only when the block is small enough
/// for `u16` slot addressing. Both the data path and [`DedupTraffic`]
/// call this with the same `block_rows`, so the cost model and the wire
/// can never disagree about the index overhead.
pub fn dedup_index_bytes(packed: bool, block_rows: usize) -> usize {
    if packed && block_rows <= PACKED_INDEX_MAX_ROWS {
        PACKED_DEDUP_INDEX_BYTES
    } else {
        DEDUP_INDEX_BYTES
    }
}

/// Head-map width of one pre-summed combine block (same packing rule as
/// [`dedup_index_bytes`]).
pub fn presum_index_bytes(packed: bool, block_rows: usize) -> usize {
    if packed && block_rows <= PACKED_INDEX_MAX_ROWS {
        PACKED_PRESUM_INDEX_BYTES
    } else {
        PRESUM_INDEX_BYTES
    }
}

// ---------------------------------------------------------------------------
// Row metadata + node-level dedup summary (derived from the plans)
// ---------------------------------------------------------------------------

/// Per-row metadata of one rank's ragged layout buffer, derived from
/// its [`DispatchPlan`]: which token produced each row, the slot's
/// combine weight, and the row's pre-summation *run* (maximal set of
/// consecutive active slots of one token on one destination node).
#[derive(Clone, Debug, Default)]
pub struct RowMeta {
    /// Ragged row → source token.
    pub token: Vec<u32>,
    /// Ragged row → its slot's combine weight.
    pub weight: Vec<f32>,
    /// Ragged row → head row of its run (itself for heads/singletons).
    pub run_head: Vec<u32>,
    /// Ragged row → position within its run, in slot order (head = 0).
    pub run_rank: Vec<u32>,
}

/// Build the [`RowMeta`] of one rank's plan under the shared placement.
pub fn row_meta(
    plan: &DispatchPlan,
    placement: &ExpertPlacement,
    gpus_per_node: usize,
) -> RowMeta {
    let offsets = plan.ragged_offsets();
    let rows = plan.occupied_rows();
    let mut meta = RowMeta {
        token: vec![0u32; rows],
        weight: vec![0.0f32; rows],
        run_head: vec![0u32; rows],
        run_rank: vec![0u32; rows],
    };
    for t in 0..plan.tokens {
        let mut cur_node = usize::MAX;
        let mut head = 0u32;
        let mut rank_in_run = 0u32;
        for j in 0..plan.k {
            let slot = t * plan.k + j;
            let dest = plan.dest[slot];
            if dest == u32::MAX {
                continue;
            }
            let row = ragged_row(&offsets, plan.capacity, dest as usize);
            meta.token[row] = t as u32;
            meta.weight[row] = plan.weights[slot];
            let expert = dest as usize / plan.capacity;
            let node = placement.rank_of(expert) / gpus_per_node;
            if node == cur_node {
                rank_in_run += 1;
            } else {
                cur_node = node;
                head = row as u32;
                rank_in_run = 0;
            }
            meta.run_head[row] = head;
            meta.run_rank[row] = rank_in_run;
        }
    }
    meta
}

/// Ragged row index of a padded-buffer destination slot (the layout
/// module's formula, reproduced here to keep `comm` self-contained).
fn ragged_row(offsets: &[usize], capacity: usize, dest: usize) -> usize {
    let e = dest / capacity;
    offsets[e] + (dest - e * capacity)
}

/// Node-level traffic summary of one dispatch-shaped exchange leg,
/// derived from the per-rank [`DispatchPlan`]s: total replica rows,
/// unique payload rows (top-k dedup), and pre-summable run heads per
/// (source node, destination node) pair. This is what both the
/// training schedule pick and the serving router score — and what the
/// data path's adaptive per-block dedup decision reproduces.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DedupTraffic {
    pub gpus_per_node: usize,
    /// `rows[sn][dn]`: kept replica rows from node `sn` to node `dn`.
    pub rows: Vec<Vec<usize>>,
    /// Unique `(rank, token)` payloads per node pair (`≤ rows`).
    pub payloads: Vec<Vec<usize>>,
    /// Pre-summable run heads per node pair (`payloads ≤ heads ≤ rows`).
    pub heads: Vec<Vec<usize>>,
    /// Whether index lists are costed in the packed (compressed-wire)
    /// layout — set from the step's [`WirePrecision`] via
    /// [`DedupTraffic::with_wire`] so scoring matches the data path.
    pub packed_index: bool,
}

/// Derive the [`DedupTraffic`] of a step from its per-rank plans (in
/// rank order).
pub fn dedup_traffic<'a>(
    plans: impl IntoIterator<Item = &'a DispatchPlan>,
    placement: &ExpertPlacement,
    cluster: &ClusterConfig,
) -> DedupTraffic {
    let n = cluster.nodes;
    let g = cluster.gpus_per_node;
    let mut out = DedupTraffic {
        gpus_per_node: g,
        rows: vec![vec![0usize; n]; n],
        payloads: vec![vec![0usize; n]; n],
        heads: vec![vec![0usize; n]; n],
        packed_index: false,
    };
    let mut hit = vec![false; n];
    for (s, plan) in plans.into_iter().enumerate() {
        let sn = s / g;
        for t in 0..plan.tokens {
            hit.fill(false);
            let mut cur_node = usize::MAX;
            for j in 0..plan.k {
                let slot = t * plan.k + j;
                if plan.dest[slot] == u32::MAX {
                    continue;
                }
                let expert = plan.dest[slot] as usize / plan.capacity;
                let dn = placement.rank_of(expert) / g;
                out.rows[sn][dn] += 1;
                if !hit[dn] {
                    hit[dn] = true;
                    out.payloads[sn][dn] += 1;
                }
                if dn != cur_node {
                    cur_node = dn;
                    out.heads[sn][dn] += 1;
                }
            }
        }
    }
    out
}

/// The adaptive per-block wire size of one dispatch block: deduplicate
/// only when it strictly shrinks the block.
fn dispatch_block_bytes(
    rows: usize,
    payloads: usize,
    elem_bytes: usize,
    packed: bool,
) -> usize {
    let raw = rows * elem_bytes;
    let dedup = payloads * elem_bytes + rows * dedup_index_bytes(packed, rows);
    raw.min(dedup)
}

/// The adaptive per-block wire size of one pre-summed combine block.
fn presum_block_bytes(rows: usize, heads: usize, elem_bytes: usize, packed: bool) -> usize {
    let raw = rows * elem_bytes;
    let pre = heads * elem_bytes + rows * presum_index_bytes(packed, rows);
    raw.min(pre)
}

impl DedupTraffic {
    /// An all-zero summary (used when dedup scoring is disabled — no
    /// per-slot scan is worth paying for a summary nobody reads).
    pub fn empty(cluster: &ClusterConfig) -> DedupTraffic {
        let n = cluster.nodes;
        DedupTraffic {
            gpus_per_node: cluster.gpus_per_node,
            rows: vec![vec![0usize; n]; n],
            payloads: vec![vec![0usize; n]; n],
            heads: vec![vec![0usize; n]; n],
            packed_index: false,
        }
    }

    /// Cost the index lists in the layout the given wire mode ships
    /// (packed `u16`+`bf16` under a compressed wire).
    pub fn with_wire(mut self, wire: WirePrecision) -> DedupTraffic {
        self.packed_index = wire.is_compressed();
        self
    }

    pub fn nodes(&self) -> usize {
        self.rows.len()
    }

    /// NIC bytes of the dispatch leg per (src node, dst node) pair
    /// under the adaptive per-block dedup decision (diagonal pairs
    /// never touch a NIC and are reported as 0).
    pub fn dispatch_inter_bytes(&self, elem_bytes: usize) -> Vec<Vec<f64>> {
        let n = self.nodes();
        (0..n)
            .map(|sn| {
                (0..n)
                    .map(|dn| {
                        if sn == dn {
                            0.0
                        } else {
                            dispatch_block_bytes(
                                self.rows[sn][dn],
                                self.payloads[sn][dn],
                                elem_bytes,
                                self.packed_index,
                            ) as f64
                        }
                    })
                    .collect()
            })
            .collect()
    }

    /// Total dispatch-leg NIC bytes under the dedup decision.
    pub fn dispatch_inter_total(&self, elem_bytes: usize) -> usize {
        let n = self.nodes();
        let mut total = 0usize;
        for sn in 0..n {
            for dn in 0..n {
                if sn != dn {
                    total += dispatch_block_bytes(
                        self.rows[sn][dn],
                        self.payloads[sn][dn],
                        elem_bytes,
                        self.packed_index,
                    );
                }
            }
        }
        total
    }

    /// Total NIC bytes without any dedup (every replica row crosses).
    pub fn raw_inter_total(&self, elem_bytes: usize) -> usize {
        let n = self.nodes();
        let mut total = 0usize;
        for sn in 0..n {
            for dn in 0..n {
                if sn != dn {
                    total += self.rows[sn][dn] * elem_bytes;
                }
            }
        }
        total
    }

    /// NIC bytes of the pre-summed *return* leg, in the **transposed**
    /// orientation the combine-leg timing uses: entry `[dn][sn]` is the
    /// flow from expert node `dn` back to token node `sn`.
    pub fn presum_inter_bytes_t(&self, elem_bytes: usize) -> Vec<Vec<f64>> {
        let n = self.nodes();
        (0..n)
            .map(|dn| {
                (0..n)
                    .map(|sn| {
                        if sn == dn {
                            0.0
                        } else {
                            presum_block_bytes(
                                self.rows[sn][dn],
                                self.heads[sn][dn],
                                elem_bytes,
                                self.packed_index,
                            ) as f64
                        }
                    })
                    .collect()
            })
            .collect()
    }

    /// Total return-leg NIC bytes under the pre-summation decision.
    pub fn presum_inter_total(&self, elem_bytes: usize) -> usize {
        let n = self.nodes();
        let mut total = 0usize;
        for sn in 0..n {
            for dn in 0..n {
                if sn != dn {
                    total += presum_block_bytes(
                        self.rows[sn][dn],
                        self.heads[sn][dn],
                        elem_bytes,
                        self.packed_index,
                    );
                }
            }
        }
        total
    }

    /// Replica rows the dispatch leg's adaptive dedup keeps off the NIC
    /// (rows of blocks where deduplication wins at this row width).
    pub fn dispatch_rows_saved(&self, elem_bytes: usize) -> usize {
        let n = self.nodes();
        let mut saved = 0usize;
        for sn in 0..n {
            for dn in 0..n {
                if sn == dn {
                    continue;
                }
                let (rows, payloads) = (self.rows[sn][dn], self.payloads[sn][dn]);
                let idx = dedup_index_bytes(self.packed_index, rows);
                if payloads * elem_bytes + rows * idx < rows * elem_bytes {
                    saved += rows - payloads;
                }
            }
        }
        saved
    }

    /// Partial-gradient rows the return leg's pre-summation keeps off
    /// the NIC.
    pub fn presum_rows_saved(&self, elem_bytes: usize) -> usize {
        let n = self.nodes();
        let mut saved = 0usize;
        for sn in 0..n {
            for dn in 0..n {
                if sn == dn {
                    continue;
                }
                let (rows, heads) = (self.rows[sn][dn], self.heads[sn][dn]);
                let idx = presum_index_bytes(self.packed_index, rows);
                if heads * elem_bytes + rows * idx < rows * elem_bytes {
                    saved += rows - heads;
                }
            }
        }
        saved
    }

    /// Restrict the summary to destination nodes `lo..hi` (the overlap
    /// model's node-axis chunk masking).
    pub fn mask_dst_nodes(&self, lo: usize, hi: usize) -> DedupTraffic {
        let n = self.nodes();
        let mask = |m: &[Vec<usize>]| -> Vec<Vec<usize>> {
            (0..n)
                .map(|sn| {
                    (0..n)
                        .map(|dn| if dn >= lo && dn < hi { m[sn][dn] } else { 0 })
                        .collect()
                })
                .collect()
        };
        DedupTraffic {
            gpus_per_node: self.gpus_per_node,
            rows: mask(&self.rows),
            payloads: mask(&self.payloads),
            heads: mask(&self.heads),
            packed_index: self.packed_index,
        }
    }
}

// ---------------------------------------------------------------------------
// Wire-byte helpers
// ---------------------------------------------------------------------------

/// The hierarchical leg's intra-node fabric traffic: every non-leader
/// GPU's payload gathers at the leader on the send side and scatters
/// from the leader on the receive side. `counts` is in the leg's flow
/// orientation.
pub fn hier_leg_intra_bytes(
    counts: &[Vec<usize>],
    elem_bytes: usize,
    gpus_per_node: usize,
) -> usize {
    let w = counts.len();
    let g = gpus_per_node;
    let mut intra = 0usize;
    for s in 0..w {
        if s % g == 0 {
            continue; // the leader's own rows take no intra hop
        }
        let send: usize = counts[s].iter().sum();
        let recv: usize = (0..w).map(|src| counts[src][s]).sum();
        intra += (send + recv) * elem_bytes;
    }
    intra
}

/// Cost-side twin of the data path's byte accounting for one
/// hierarchical leg: `inter` from the (possibly dedup-reduced) NIC
/// total, `intra` from the gather/scatter volumes.
pub fn hier_leg_wire_bytes(
    counts: &[Vec<usize>],
    elem_bytes: usize,
    gpus_per_node: usize,
    inter_total: Option<usize>,
) -> WireBytes {
    let inter = inter_total.unwrap_or_else(|| {
        crate::comm::ragged::split_wire_bytes(counts, elem_bytes, gpus_per_node).inter
    });
    WireBytes { inter, intra: hier_leg_intra_bytes(counts, elem_bytes, gpus_per_node) }
}

// ---------------------------------------------------------------------------
// The four-phase data path
// ---------------------------------------------------------------------------

/// What one hierarchical leg actually did.
#[derive(Clone, Debug)]
pub struct HierLeg {
    /// Simulated timing of the four phases.
    pub timing: CommTiming,
    /// NIC vs node-fabric bytes the leg moved.
    pub wire: WireBytes,
    /// Replica rows dedup/pre-summation kept off the NIC.
    pub rows_saved: usize,
}

/// Dedup description of a dispatch-shaped leg.
pub struct DedupMeta<'a> {
    /// Per source rank, the ragged-row metadata of its plan.
    pub rows: &'a [RowMeta],
    /// Per source rank, the `[tokens, d]` base payloads: the token
    /// shard on the forward dispatch, the upstream-gradient (`dy`)
    /// shard on the backward's transposed dispatch.
    pub payloads: &'a [Tensor],
    /// `false`: buffer rows are verbatim payload replicas (forward) —
    /// expansion is a memcpy. `true`: buffer rows are
    /// `weight · payload` (backward) — expansion re-applies the shipped
    /// weight, bit-identical to the source-side multiply.
    pub scaled: bool,
}

/// Pre-summation description of a combine-shaped leg (the backward's
/// dispatch-gradient return): per **destination** (token-owner) rank,
/// the ragged-row run structure of its plan.
pub struct PresumMeta<'a> {
    pub rows: &'a [RowMeta],
}

fn validate(
    net: &NetworkModel,
    buffers: &[Vec<f32>],
    kept: &[Vec<usize>],
) -> Result<(usize, usize)> {
    let w = buffers.len();
    if w != net.cfg.world() {
        return Err(crate::comm_err!(
            "hier ragged exchange over {w} buffers but cluster world is {}",
            net.cfg.world()
        ));
    }
    if kept.len() != w {
        return Err(crate::comm_err!("kept matrix must have {w} rows"));
    }
    let e = kept[0].len();
    if e == 0 || e % w != 0 || kept.iter().any(|row| row.len() != e) {
        return Err(crate::comm_err!(
            "kept rows must all list the same expert count divisible by {w}"
        ));
    }
    Ok((e, e / w))
}

fn expert_offsets(kept: &[Vec<usize>], e: usize) -> Vec<Vec<usize>> {
    kept.iter()
        .map(|row| {
            let mut off = vec![0usize; e + 1];
            for (i, &c) in row.iter().enumerate() {
                off[i + 1] = off[i] + c;
            }
            off
        })
        .collect()
}

/// Dispatch leg over the four-phase hierarchical schedule. Semantics
/// (final buffers) are bit-identical to
/// [`crate::comm::ragged::ragged_dispatch`] under the same `wire` mode
/// (every payload row is quantized at the send boundary, and dedup
/// expansion replicates already-quantized payloads — quantization is
/// idempotent, so both paths land on the same bits); with `dedup`,
/// replica rows of one token bound for the same remote node ship once
/// (see module docs). Zero-row ranks and empty (node, node) blocks are
/// first-class: no error, no allocation, no NIC message.
pub fn hier_ragged_dispatch(
    net: &NetworkModel,
    buffers: &mut [Vec<f32>],
    kept: &[Vec<usize>],
    d: usize,
    dedup: Option<&DedupMeta>,
    wire: WirePrecision,
) -> Result<HierLeg> {
    let (e, epr) = validate(net, buffers, kept)?;
    let cfg = &net.cfg;
    let (n, g) = (cfg.nodes, cfg.gpus_per_node);
    let w = n * g;
    let rb = d * wire.elem_bytes();
    let packed = wire.is_compressed();
    for (s, buf) in buffers.iter().enumerate() {
        let expect: usize = kept[s].iter().sum::<usize>() * d;
        if buf.len() != expect {
            return Err(crate::comm_err!(
                "rank {s}: ragged buffer has {} elements, kept counts say {expect}",
                buf.len()
            ));
        }
    }
    if let Some(meta) = dedup {
        if meta.rows.len() != w || meta.payloads.len() != w {
            return Err(crate::comm_err!("dedup meta must describe all {w} ranks"));
        }
        for (s, payload) in meta.payloads.iter().enumerate() {
            if payload.rows() > 0 && payload.row_len() != d {
                return Err(crate::comm_err!(
                    "rank {s}: dedup payload width {} != d {d}",
                    payload.row_len()
                ));
            }
        }
    }
    // Quantize every row at the send boundary — uniformly, including
    // same-node rows, so the intra-node fabric ships the same narrow
    // format and the flat path (which quantizes the same buffers)
    // produces bit-identical results.
    for buf in buffers.iter_mut() {
        wire.quantize_slice(buf);
    }
    let offs = expert_offsets(kept, e);
    let mut leg_span = trace::span("hier_dispatch_leg");

    // Phases 1+2 (gather at the leader, aggregate by destination node):
    // build one message block per (src node, dst node). Canonical block
    // row order: dst_local → local expert → src_local → rows of
    // (src rank, global expert) in buffer order — so the destination
    // leader's per-rank assembly reads contiguous segments.
    let gather_span = trace::span("hier_gather_agg");
    let mut inter_bytes = 0usize;
    let mut rows_saved = 0usize;
    let mut inter_override = vec![vec![0.0f64; n]; n];
    // expanded[sn][dn]: the block in full-row canonical order (the
    // destination leader's post-expansion view).
    let mut expanded: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n);
    for sn in 0..n {
        let mut per_dst: Vec<Vec<f32>> = Vec::with_capacity(n);
        for dn in 0..n {
            let mut block_rows = 0usize;
            for dl in 0..g {
                let r = dn * g + dl;
                for le in 0..epr {
                    let ge = r * epr + le;
                    for sl in 0..g {
                        block_rows += kept[sn * g + sl][ge];
                    }
                }
            }
            if block_rows == 0 {
                per_dst.push(Vec::new());
                continue;
            }
            // Dedup decision for cross-node blocks: count unique
            // (rank, token) payloads first, then choose the smaller
            // wire representation — deterministically, from counts both
            // sides can derive.
            let mut use_dedup = false;
            let mut payload_rows = 0usize;
            if sn != dn {
                if let Some(meta) = dedup {
                    let mut seen: BTreeSet<(u32, u32)> = BTreeSet::new();
                    for dl in 0..g {
                        let r = dn * g + dl;
                        for le in 0..epr {
                            let ge = r * epr + le;
                            for sl in 0..g {
                                let s = sn * g + sl;
                                for row in offs[s][ge]..offs[s][ge + 1] {
                                    let t = meta.rows[s].token[row];
                                    seen.insert((s as u32, t));
                                }
                            }
                        }
                    }
                    payload_rows = seen.len();
                    let idx = dedup_index_bytes(packed, block_rows);
                    use_dedup = payload_rows * rb + block_rows * idx < block_rows * rb;
                }
            }
            // Build the expanded block. For a deduplicated block the
            // wire carries `payload_rows` rows + an index list; the
            // destination leader expands it — a memcpy per replica
            // (forward) or the `weight · payload` multiply (backward),
            // bit-identical to the source rows by construction.
            let mut block: Vec<f32> = Vec::with_capacity(block_rows * d);
            for dl in 0..g {
                let r = dn * g + dl;
                for le in 0..epr {
                    let ge = r * epr + le;
                    for sl in 0..g {
                        let s = sn * g + sl;
                        let lo = offs[s][ge] * d;
                        let hi = offs[s][ge + 1] * d;
                        if !use_dedup {
                            block.extend_from_slice(&buffers[s][lo..hi]);
                            continue;
                        }
                        let meta = dedup.expect("use_dedup implies meta");
                        let idx = dedup_index_bytes(packed, block_rows);
                        for row in offs[s][ge]..offs[s][ge + 1] {
                            let t = meta.rows[s].token[row] as usize;
                            let payload = meta.payloads[s].row(t);
                            if meta.scaled {
                                // The expansion weight travels inside
                                // the index list: f32 in the u32+f32
                                // layout, bf16 in the packed layout.
                                let wgt = meta.rows[s].weight[row];
                                let wgt = if idx == PACKED_DEDUP_INDEX_BYTES {
                                    bf16_round(wgt)
                                } else {
                                    wgt
                                };
                                block.extend(
                                    payload.iter().map(|&p| wgt * wire.quantize(p)),
                                );
                            } else {
                                // Payload rows crossed the wire in the
                                // narrow format; replication is a
                                // memcpy of the quantized row — the
                                // same bits the flat path produced.
                                block.extend(payload.iter().map(|&p| wire.quantize(p)));
                            }
                        }
                    }
                }
            }
            if sn != dn {
                let bytes = if use_dedup {
                    rows_saved += block_rows - payload_rows;
                    payload_rows * rb + block_rows * dedup_index_bytes(packed, block_rows)
                } else {
                    block_rows * rb
                };
                inter_bytes += bytes;
                inter_override[sn][dn] = bytes as f64;
            }
            per_dst.push(block);
        }
        expanded.push(per_dst);
    }
    drop(gather_span);

    // Phase 4 (expansion happened above; assemble + scatter): each
    // destination rank's expert-major receive buffer reads, per local
    // expert, one contiguous segment from every source node's block.
    let scatter_span = trace::span("hier_expand_scatter");
    let counts = rank_counts(kept, epr);
    let mut cursors = vec![vec![0usize; n]; n]; // [sn][dn] read position
    let mut out: Vec<Vec<f32>> = Vec::with_capacity(w);
    for dn in 0..n {
        for dl in 0..g {
            let r = dn * g + dl;
            let total: usize = (0..w).map(|src| counts[src][r]).sum();
            let mut buf = Vec::with_capacity(total * d);
            for le in 0..epr {
                let ge = r * epr + le;
                for sn in 0..n {
                    let seg: usize =
                        (0..g).map(|sl| kept[sn * g + sl][ge]).sum::<usize>() * d;
                    let lo = cursors[sn][dn];
                    buf.extend_from_slice(&expanded[sn][dn][lo..lo + seg]);
                    cursors[sn][dn] = lo + seg;
                }
            }
            out.push(buf);
        }
    }
    for (b, o) in buffers.iter_mut().zip(out) {
        *b = o;
    }
    drop(scatter_span);

    let timing = hierarchical_alltoallv_timing_with(net, &counts, rb, Some(&inter_override));
    let wb = hier_leg_wire_bytes(&counts, rb, g, Some(inter_bytes));
    leg_span.arg("rows_saved", rows_saved);
    leg_span.arg("bytes_inter", wb.inter);
    leg_span.arg("bytes_intra", wb.intra);
    leg_span.arg("wire", wire.name());
    Ok(HierLeg { timing, wire: wb, rows_saved })
}

/// Combine leg over the four-phase hierarchical schedule: the exact
/// inverse of [`hier_ragged_dispatch`]'s permutation (bit-identical to
/// [`crate::comm::ragged::ragged_combine`] when `presum` is `None`).
/// With `presum`, per-token partial gradients of one run are summed at
/// the expert-side node leader **in slot order** before the return leg;
/// the destination receives the run total at the head row and zeros at
/// the member rows (see module docs for why this preserves the
/// downstream accumulation bit-for-bit).
pub fn hier_ragged_combine(
    net: &NetworkModel,
    buffers: &mut [Vec<f32>],
    kept: &[Vec<usize>],
    d: usize,
    presum: Option<&PresumMeta>,
    wire: WirePrecision,
) -> Result<HierLeg> {
    let (e, epr) = validate(net, buffers, kept)?;
    let cfg = &net.cfg;
    let (n, g) = (cfg.nodes, cfg.gpus_per_node);
    let w = n * g;
    let rb = d * wire.elem_bytes();
    let packed = wire.is_compressed();
    // Offsets of block (local expert, source rank) inside each owner
    // rank's expert-major buffer (the `ragged_combine` layout).
    let mut block_off: Vec<Vec<usize>> = Vec::with_capacity(w);
    for r in 0..w {
        let mut off = vec![0usize; epr * w + 1];
        for le in 0..epr {
            for s in 0..w {
                let i = le * w + s;
                off[i + 1] = off[i] + kept[s][r * epr + le];
            }
        }
        block_off.push(off);
    }
    for (r, buf) in buffers.iter().enumerate() {
        let expect = block_off[r][epr * w] * d;
        if buf.len() != expect {
            return Err(crate::comm_err!(
                "rank {r}: expert-major buffer has {} elements, kept counts say {expect}",
                buf.len()
            ));
        }
    }
    if let Some(meta) = presum {
        if meta.rows.len() != w {
            return Err(crate::comm_err!("presum meta must describe all {w} ranks"));
        }
    }
    // Same uniform send-boundary quantization as the dispatch leg; run
    // sums below add the already-quantized rows in f32 and re-quantize
    // the shipped head row.
    for buf in buffers.iter_mut() {
        wire.quantize_slice(buf);
    }
    let offs = expert_offsets(kept, e); // source-side ragged row offsets
    let mut leg_span = trace::span("hier_combine_leg");

    // Phases 1+2 at the *expert* side: gather each node's expert-major
    // buffers at the leader and aggregate per destination (token) node.
    // Canonical block (m → q) row order: dst_local (token rank) →
    // expert rank within m → local expert → rows of (s, ge) in order.
    let gather_span = trace::span("hier_gather_presum");
    let mut inter_bytes = 0usize;
    let mut rows_saved = 0usize;
    let mut inter_override = vec![vec![0.0f64; n]; n]; // [m][q]
    let mut expanded: Vec<Vec<Vec<f32>>> = Vec::with_capacity(n);
    for m in 0..n {
        let mut per_dst: Vec<Vec<f32>> = Vec::with_capacity(n);
        for q in 0..n {
            // Canonical scan: (source rank, source ragged row, data) of
            // every block row, in block order.
            let mut entries: Vec<(usize, usize, &[f32])> = Vec::new();
            for dl in 0..g {
                let s = q * g + dl;
                for rl in 0..g {
                    let r = m * g + rl;
                    for le in 0..epr {
                        let ge = r * epr + le;
                        let lo = block_off[r][le * w + s];
                        for (i, row) in (offs[s][ge]..offs[s][ge + 1]).enumerate() {
                            entries.push((
                                s,
                                row,
                                &buffers[r][(lo + i) * d..(lo + i + 1) * d],
                            ));
                        }
                    }
                }
            }
            let block_rows = entries.len();
            if block_rows == 0 {
                per_dst.push(Vec::new());
                continue;
            }
            // Pre-summation decision for cross-node blocks: ship one
            // row per run iff that strictly shrinks the block.
            let mut use_presum = false;
            let mut head_rows = 0usize;
            if m != q {
                if let Some(meta) = presum {
                    head_rows = entries
                        .iter()
                        .filter(|&&(s, row, _)| meta.rows[s].run_head[row] as usize == row)
                        .count();
                    let idx = presum_index_bytes(packed, block_rows);
                    use_presum = head_rows * rb + block_rows * idx < block_rows * rb;
                }
            }
            // Build the destination leader's expanded view. Raw blocks
            // carry every row; pre-summed blocks carry the slot-order
            // run total at each head row and zeros at member rows.
            let mut block = vec![0.0f32; block_rows * d];
            if use_presum {
                let meta = presum.expect("use_presum implies meta");
                // Group block positions by run, then sum each run
                // sequentially in slot (run-rank) order — the exact
                // addition sequence the flat path's per-slot
                // accumulation performs.
                let mut runs: BTreeMap<(u32, u32), Vec<(u32, usize)>> = BTreeMap::new();
                for (k, &(s, row, _)) in entries.iter().enumerate() {
                    let head = meta.rows[s].run_head[row];
                    runs.entry((s as u32, head))
                        .or_default()
                        .push((meta.rows[s].run_rank[row], k));
                }
                for members in runs.values_mut() {
                    members.sort_unstable_by_key(|&(rank, _)| rank);
                    let head_k = members[0].1;
                    let (lo, hi) = (head_k * d, (head_k + 1) * d);
                    block[lo..hi].copy_from_slice(entries[head_k].2);
                    for &(_, k) in &members[1..] {
                        let src = entries[k].2;
                        for (o, &v) in block[lo..hi].iter_mut().zip(src) {
                            *o += v;
                        }
                    }
                    // The run total crosses the NIC as one narrow row.
                    wire.quantize_slice(&mut block[lo..hi]);
                }
            } else {
                for (k, &(_, _, data)) in entries.iter().enumerate() {
                    block[k * d..(k + 1) * d].copy_from_slice(data);
                }
            }
            if m != q {
                let bytes = if use_presum {
                    rows_saved += block_rows - head_rows;
                    head_rows * rb + block_rows * presum_index_bytes(packed, block_rows)
                } else {
                    block_rows * rb
                };
                inter_bytes += bytes;
                inter_override[m][q] = bytes as f64;
            }
            per_dst.push(block);
        }
        expanded.push(per_dst);
    }
    drop(gather_span);

    // Phase 4: the token-side leader assembles each local rank's source
    // ragged buffer from the expanded blocks and scatters it.
    let scatter_span = trace::span("hier_expand_scatter");
    let mut cursors = vec![vec![0usize; n]; n]; // [m][q] read position (elems)
    let mut out: Vec<Vec<f32>> = Vec::with_capacity(w);
    for q in 0..n {
        for dl in 0..g {
            let s = q * g + dl;
            let total: usize = kept[s].iter().sum();
            let mut buf = Vec::with_capacity(total * d);
            for ge in 0..e {
                let r = ge / epr;
                let m = r / g;
                let seg = kept[s][ge] * d;
                let lo = cursors[m][q];
                buf.extend_from_slice(&expanded[m][q][lo..lo + seg]);
                cursors[m][q] = lo + seg;
            }
            out.push(buf);
        }
    }
    for (b, o) in buffers.iter_mut().zip(out) {
        *b = o;
    }
    drop(scatter_span);

    // The combine leg's timing is charged on the transposed rank
    // matrix; `inter_override` is already in the (expert node → token
    // node) orientation that transpose produces.
    let counts_t = crate::comm::schedule::transpose_counts(&rank_counts(kept, epr));
    let timing =
        hierarchical_alltoallv_timing_with(net, &counts_t, rb, Some(&inter_override));
    let wb = hier_leg_wire_bytes(&counts_t, rb, g, Some(inter_bytes));
    leg_span.arg("rows_saved", rows_saved);
    leg_span.arg("bytes_inter", wb.inter);
    leg_span.arg("bytes_intra", wb.intra);
    leg_span.arg("wire", wire.name());
    Ok(HierLeg { timing, wire: wb, rows_saved })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::ragged::{ragged_combine, ragged_dispatch};
    use crate::comm::schedule::Schedule;
    use crate::config::ClusterConfig;
    use crate::gating::{apply_capacity, Routing};
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn net(nodes: usize, gpus: usize) -> NetworkModel {
        let mut cfg = ClusterConfig::commodity(nodes);
        cfg.gpus_per_node = gpus;
        NetworkModel::new(cfg)
    }

    /// Random per-rank plans over `e` experts with top-`k` routing and
    /// the given capacity; returns (plans, token shards, ragged buffers).
    fn random_step(
        g: &mut crate::util::proptest::Gen,
        w: usize,
        e: usize,
        k: usize,
        tokens: usize,
        cap: usize,
        d: usize,
    ) -> (Vec<DispatchPlan>, Vec<Tensor>, Vec<Vec<f32>>) {
        let mut plans = Vec::with_capacity(w);
        let mut shards = Vec::with_capacity(w);
        let mut bufs = Vec::with_capacity(w);
        for rank in 0..w {
            let mut rng = Rng::seed((g.case * 131 + rank) as u64);
            let shard = Tensor::randn(&[tokens, d], &mut rng);
            let mut ids = Vec::with_capacity(tokens * k);
            let mut weights = Vec::with_capacity(tokens * k);
            for _ in 0..tokens {
                // k distinct experts per token (replicas of one token
                // never target the same expert, like a real top-k gate).
                let mut picked: Vec<u32> = Vec::new();
                while picked.len() < k {
                    let c = g.u32_in(0..e as u32);
                    if !picked.contains(&c) {
                        picked.push(c);
                    }
                }
                for &ex in &picked {
                    ids.push(ex);
                    weights.push(0.25 + 0.5 * rng.normal_f32().abs());
                }
            }
            let routing = Routing {
                k,
                tokens,
                num_experts: e,
                expert_ids: ids,
                weights,
                aux_loss: 0.0,
            };
            let plan = apply_capacity(&routing, cap);
            // Build the ragged buffer exactly like `ragged_layout`.
            let offsets = plan.ragged_offsets();
            let mut buf = vec![0.0f32; plan.occupied_rows() * d];
            for t in 0..tokens {
                for j in 0..k {
                    let dest = plan.dest[t * k + j];
                    if dest != u32::MAX {
                        let row = ragged_row(&offsets, plan.capacity, dest as usize);
                        buf[row * d..(row + 1) * d].copy_from_slice(shard.row(t));
                    }
                }
            }
            plans.push(plan);
            shards.push(shard);
            bufs.push(buf);
        }
        (plans, shards, bufs)
    }

    #[test]
    fn dispatch_matches_flat_ragged_bitwise() {
        for_all(20, |g| {
            let nodes = g.usize_in(1..4);
            let gpus = g.usize_in(1..4);
            let m = net(nodes, gpus);
            let w = nodes * gpus;
            let epr = g.usize_in(1..3);
            let e = epr * w;
            let k = g.usize_in(1..(e.min(3) + 1));
            let tokens = g.usize_in(1..12);
            let cap = g.usize_in(1..(tokens * 2 + 1)); // drops possible
            let d = g.usize_in(1..5);
            let (plans, shards, bufs) = random_step(g, w, e, k, tokens, cap, d);
            let kept: Vec<Vec<usize>> = plans.iter().map(|p| p.kept.clone()).collect();

            let mut flat = bufs.clone();
            ragged_dispatch(&m, &mut flat, &kept, d, Schedule::Flat).unwrap();

            // Plain four-phase path.
            let mut hier = bufs.clone();
            hier_ragged_dispatch(&m, &mut hier, &kept, d, None, WirePrecision::F32).unwrap();
            assert_eq!(flat, hier, "case {}: four-phase != flat", g.case);

            // Deduplicated four-phase path.
            let placement = ExpertPlacement::new(e, w);
            let metas: Vec<RowMeta> =
                plans.iter().map(|p| row_meta(p, &placement, gpus)).collect();
            let meta = DedupMeta { rows: &metas, payloads: &shards, scaled: false };
            let mut deduped = bufs.clone();
            let leg = hier_ragged_dispatch(
                &m,
                &mut deduped,
                &kept,
                d,
                Some(&meta),
                WirePrecision::F32,
            )
            .unwrap();
            assert_eq!(flat, deduped, "case {}: dedup changed the bits", g.case);

            // The leg's NIC bytes equal the plan-derived cost model's.
            let traffic = dedup_traffic(&plans, &placement, &m.cfg);
            assert_eq!(
                leg.wire.inter,
                traffic.dispatch_inter_total(d * 4),
                "case {}: data path and cost model disagree on NIC bytes",
                g.case
            );
            assert!(leg.wire.inter <= traffic.raw_inter_total(d * 4));
        });
    }

    #[test]
    fn combine_matches_flat_ragged_bitwise_and_presum_preserves_sums() {
        for_all(20, |g| {
            let nodes = g.usize_in(1..4);
            let gpus = g.usize_in(1..4);
            let m = net(nodes, gpus);
            let w = nodes * gpus;
            let epr = g.usize_in(1..3);
            let e = epr * w;
            let k = g.usize_in(1..(e.min(3) + 1));
            let tokens = g.usize_in(1..12);
            let cap = g.usize_in(1..(tokens * 2 + 1));
            let d = g.usize_in(1..5);
            let (plans, _, bufs) = random_step(g, w, e, k, tokens, cap, d);
            let kept: Vec<Vec<usize>> = plans.iter().map(|p| p.kept.clone()).collect();

            // Expert-major buffers: run the flat dispatch, then fill
            // with fresh values standing in for expert outputs.
            let mut expert_major = bufs.clone();
            ragged_dispatch(&m, &mut expert_major, &kept, d, Schedule::Flat).unwrap();
            let mut rng = Rng::seed(g.case as u64 + 917);
            for buf in expert_major.iter_mut() {
                for v in buf.iter_mut() {
                    *v = rng.normal_f32();
                }
            }

            let mut flat = expert_major.clone();
            ragged_combine(&m, &mut flat, &kept, d, Schedule::Flat).unwrap();

            let mut hier = expert_major.clone();
            hier_ragged_combine(&m, &mut hier, &kept, d, None, WirePrecision::F32).unwrap();
            assert_eq!(flat, hier, "case {}: four-phase combine != flat", g.case);

            // Pre-summed path: per-token sums must match the flat
            // path's slot-order accumulation exactly.
            let placement = ExpertPlacement::new(e, w);
            let metas: Vec<RowMeta> =
                plans.iter().map(|p| row_meta(p, &placement, gpus)).collect();
            let meta = PresumMeta { rows: &metas };
            let mut pre = expert_major.clone();
            let leg =
                hier_ragged_combine(&m, &mut pre, &kept, d, Some(&meta), WirePrecision::F32)
                    .unwrap();
            let traffic = dedup_traffic(&plans, &placement, &m.cfg);
            assert_eq!(
                leg.wire.inter,
                traffic.presum_inter_total(d * 4),
                "case {}: presum data path and cost model disagree",
                g.case
            );
            for (rank, plan) in plans.iter().enumerate() {
                let offsets = plan.ragged_offsets();
                for t in 0..plan.tokens {
                    // Slot-order accumulation over both buffers.
                    let mut want = vec![0.0f32; d];
                    let mut got = vec![0.0f32; d];
                    for j in 0..plan.k {
                        let dest = plan.dest[t * plan.k + j];
                        if dest == u32::MAX {
                            continue;
                        }
                        let row = ragged_row(&offsets, plan.capacity, dest as usize);
                        for x in 0..d {
                            want[x] += flat[rank][row * d + x];
                            got[x] += pre[rank][row * d + x];
                        }
                    }
                    for x in 0..d {
                        assert!(
                            (want[x] - got[x]).abs() == 0.0,
                            "case {}: rank {rank} token {t} presum drifted",
                            g.case
                        );
                    }
                }
            }
        });
    }

    #[test]
    fn dedup_saves_inter_bytes_with_k2_and_never_inflates_k1() {
        let m = net(2, 2);
        let w = 4;
        let e = 8;
        let placement = ExpertPlacement::new(e, w);
        // k = 2, both replicas on the same remote node for every token.
        let tokens = 16;
        let mut ids = Vec::new();
        let mut weights = Vec::new();
        for _ in 0..tokens {
            ids.extend_from_slice(&[4u32, 5]); // experts 4,5 → ranks 2,2? (epr=2: 4→2, 5→2)
            weights.extend_from_slice(&[0.6, 0.4]);
        }
        let routing = Routing {
            k: 2,
            tokens,
            num_experts: e,
            expert_ids: ids,
            weights,
            aux_loss: 0.0,
        };
        let plans: Vec<DispatchPlan> =
            (0..w).map(|_| apply_capacity(&routing, tokens * 2)).collect();
        let traffic = dedup_traffic(&plans, &placement, &m.cfg);
        let d = 16;
        let rb = d * 4;
        assert!(
            traffic.dispatch_inter_total(rb) < traffic.raw_inter_total(rb),
            "k=2 same-node replicas must dedup: {} vs raw {}",
            traffic.dispatch_inter_total(rb),
            traffic.raw_inter_total(rb)
        );
        // k = 1: no replicas, the adaptive decision must not pay the
        // index overhead.
        let r1 = Routing {
            k: 1,
            tokens,
            num_experts: e,
            expert_ids: (0..tokens as u32).map(|t| t % e as u32).collect(),
            weights: vec![1.0; tokens],
            aux_loss: 0.0,
        };
        let p1: Vec<DispatchPlan> = (0..w).map(|_| apply_capacity(&r1, tokens)).collect();
        let t1 = dedup_traffic(&p1, &placement, &m.cfg);
        assert_eq!(t1.dispatch_inter_total(rb), t1.raw_inter_total(rb));
    }

    #[test]
    fn zero_rows_and_empty_blocks_are_first_class() {
        // Every rank keeps nothing: no error, no bytes, empty buffers.
        let m = net(2, 2);
        let kept = vec![vec![0usize; 8]; 4];
        let mut bufs: Vec<Vec<f32>> = vec![Vec::new(); 4];
        let leg = hier_ragged_dispatch(&m, &mut bufs, &kept, 4, None, WirePrecision::F32).unwrap();
        assert!(bufs.iter().all(|b| b.is_empty()));
        assert_eq!(leg.wire.inter, 0);
        assert_eq!(leg.wire.intra, 0);
        let leg2 =
            hier_ragged_combine(&m, &mut bufs, &kept, 4, None, WirePrecision::F32).unwrap();
        assert_eq!(leg2.wire.inter + leg2.wire.intra, 0);

        // One populated (src, dst) pair, everything else zero.
        let mut kept = vec![vec![0usize; 8]; 4];
        kept[0][6] = 3; // rank 0 → expert 6 (rank 3, node 1)
        let mut bufs: Vec<Vec<f32>> = vec![Vec::new(); 4];
        bufs[0] = (0..3 * 4).map(|i| i as f32).collect();
        let mut flat = bufs.clone();
        ragged_dispatch(&m, &mut flat, &kept, 4, Schedule::Flat).unwrap();
        let leg = hier_ragged_dispatch(&m, &mut bufs, &kept, 4, None, WirePrecision::F32).unwrap();
        assert_eq!(flat, bufs);
        assert_eq!(leg.wire.inter, 3 * 4 * 4);
    }

    #[test]
    fn wire_split_is_placement_aware() {
        // 2 nodes × 2 GPUs; rows rank0→rank1 are intra-node, rank0→rank2
        // are inter-node.
        let mut counts = vec![vec![0usize; 4]; 4];
        counts[0][1] = 5;
        counts[0][2] = 7;
        let wb = hier_leg_wire_bytes(&counts, 4, 2, None);
        assert_eq!(wb.inter, 7 * 4);
        // Gather: rank 1's sends (0) + non-leader receives: rank 1 gets 5,
        // rank 3 gets 0. Scatter side counts rank 1's received rows.
        assert_eq!(wb.intra, 5 * 4);
    }

    #[test]
    fn run_structure_respects_slot_contiguity() {
        // Token with slots on nodes [0, 1, 0]: the two node-0 slots are
        // NOT contiguous, so they must form two separate runs (summing
        // them together would reorder the flat accumulation).
        let placement = ExpertPlacement::new(4, 4); // epr=1, node = rank/2
        let routing = Routing {
            k: 3,
            tokens: 1,
            num_experts: 4,
            expert_ids: vec![0, 2, 1], // nodes 0, 1, 0
            weights: vec![0.5, 0.3, 0.2],
            aux_loss: 0.0,
        };
        let plan = apply_capacity(&routing, 4);
        let meta = row_meta(&plan, &placement, 2);
        let offsets = plan.ragged_offsets();
        let row0 = ragged_row(&offsets, 4, plan.dest[0] as usize);
        let row1 = ragged_row(&offsets, 4, plan.dest[1] as usize);
        let row2 = ragged_row(&offsets, 4, plan.dest[2] as usize);
        assert_eq!(meta.run_head[row0] as usize, row0);
        assert_eq!(meta.run_head[row1] as usize, row1);
        assert_eq!(meta.run_head[row2] as usize, row2, "non-contiguous → own run");
        // And consecutive same-node slots DO share a run.
        let routing2 = Routing {
            k: 3,
            tokens: 1,
            num_experts: 4,
            expert_ids: vec![0, 1, 2], // nodes 0, 0, 1
            weights: vec![0.5, 0.3, 0.2],
            aux_loss: 0.0,
        };
        let plan2 = apply_capacity(&routing2, 4);
        let meta2 = row_meta(&plan2, &placement, 2);
        let off2 = plan2.ragged_offsets();
        let r0 = ragged_row(&off2, 4, plan2.dest[0] as usize);
        let r1 = ragged_row(&off2, 4, plan2.dest[1] as usize);
        assert_eq!(meta2.run_head[r1] as usize, r0);
        assert_eq!(meta2.run_rank[r1], 1);
    }
}
