//! Hierarchical AllToAll (the paper's §3.2 communication contribution).
//!
//! Paper Figure 6, four phases per node:
//! 1. **gather** — every GPU ships its whole payload to the node leader
//!    over the intra-node fabric;
//! 2. **layout** — the leader reorders tokens so data destined to the
//!    same remote *node* is contiguous (message aggregation);
//! 3. **inter-node AllToAll** — only `N` leaders exchange; each message
//!    carries `G·B/N` bytes, i.e. `G²×` larger than the flat scheme's
//!    `B/(NG)` — this is the whole trick: the NIC sees few, large,
//!    bandwidth-saturating messages instead of many small ones;
//! 4. **layout + scatter** — reorder received data per destination GPU
//!    and ship it from the leader to local GPUs.
//!
//! The data movement below implements the real permutation (verified
//! equal to vanilla [`super::alltoall`]); the timing charges each phase
//! on the [`NetworkModel`].

use crate::cluster::NetworkModel;
use crate::comm::{uniform_len, CommTiming};
use crate::error::Result;

/// Hierarchical AllToAll with equal chunks.
///
/// Semantics identical to [`super::alltoall`]; timing reflects the
/// four-phase hierarchical schedule.
pub fn hierarchical_alltoall(
    net: &NetworkModel,
    buffers: &mut [Vec<f32>],
) -> Result<CommTiming> {
    let w = buffers.len();
    let len = uniform_len(buffers)?;
    let cfg = &net.cfg;
    if w != cfg.world() {
        return Err(crate::comm_err!(
            "hierarchical_alltoall over {w} buffers but cluster world is {}",
            cfg.world()
        ));
    }
    if len % w != 0 {
        return Err(crate::comm_err!("buffer len {len} not divisible by world {w}"));
    }
    let (n, g) = (cfg.nodes, cfg.gpus_per_node);
    let chunk = len / w;

    // ---- data movement ----
    // Phase 1: gather every local GPU's buffer at the node leader.
    // node_buf[node] = [local g][dest rank d] -> chunk  (g-major)
    let mut node_buf: Vec<Vec<f32>> = (0..n)
        .map(|node| {
            let mut v = Vec::with_capacity(g * len);
            for local in 0..g {
                v.extend_from_slice(&buffers[node * g + local]);
            }
            v
        })
        .collect();

    // Phase 2: layout transform — regroup by destination node:
    // send_block[node][dest_node] = for each local g (source), the G chunks
    // destined to dest_node's GPUs, concatenated. Block size = G*G*chunk.
    let block = g * g * chunk;
    let mut send: Vec<Vec<f32>> = vec![Vec::with_capacity(n * block); n];
    for node in 0..n {
        for dest_node in 0..n {
            for local in 0..g {
                let base = local * len + dest_node * g * chunk;
                send[node].extend_from_slice(&node_buf[node][base..base + g * chunk]);
            }
        }
    }

    // Phase 3: inter-node AllToAll between leaders (block-wise transpose).
    let mut recv: Vec<Vec<f32>> = vec![vec![0.0f32; n * block]; n];
    for dst in 0..n {
        for src in 0..n {
            recv[dst][src * block..(src + 1) * block]
                .copy_from_slice(&send[src][dst * block..(dst + 1) * block]);
        }
    }

    // Phase 4: reverse layout + scatter to local GPUs.
    // recv[dst] from src node: [src local g'][dest local g] -> chunk.
    for node in 0..n {
        for local in 0..g {
            let d = node * g + local;
            for src_node in 0..n {
                for src_local in 0..g {
                    let s = src_node * g + src_local;
                    let base = src_node * block + src_local * g * chunk + local * chunk;
                    buffers[d][s * chunk..(s + 1) * chunk]
                        .copy_from_slice(&recv[node][base..base + chunk]);
                }
            }
        }
        node_buf[node].clear(); // appease borrowck-free logic; cheap
    }

    // ---- simulated timing ----
    Ok(hierarchical_alltoall_timing(net, chunk * 4))
}

/// Timing of the hierarchical schedule with `chunk_bytes` per (GPU,GPU)
/// logical chunk (per-GPU payload `B = W * chunk_bytes`).
pub fn hierarchical_alltoall_timing(net: &NetworkModel, chunk_bytes: usize) -> CommTiming {
    let cfg = &net.cfg;
    let (n, g) = (cfg.nodes, cfg.gpus_per_node);
    let w = n * g;
    let payload = (w * chunk_bytes) as f64; // B, bytes per GPU

    if n == 1 {
        // Degenerates to the intra-node exchange of the flat scheme.
        let t = net.intra_batch_time(g - 1, chunk_bytes as f64);
        return CommTiming { phases: vec![("intra".into(), t)], total: t };
    }

    // Phase 1: leader collects (G-1) payloads over the node fabric.
    let t_gather = net.gather_time(g - 1, (g - 1) as f64 * payload);
    // Phase 2: on-device re-layout of the aggregated G·B buffer.
    let t_layout = net.device_copy_time(g as f64 * payload);
    // Phase 3: each leader sends N-1 aggregated messages of G·B/N bytes.
    let msg = g as f64 * payload / n as f64;
    let t_inter = net.nic_batch_time(n - 1, msg);
    // Phase 4: mirror of 2 + 1.
    let total = 2.0 * t_gather + 2.0 * t_layout + t_inter;
    CommTiming {
        phases: vec![
            ("gather".into(), t_gather),
            ("layout".into(), t_layout),
            ("inter".into(), t_inter),
            ("layout2".into(), t_layout),
            ("scatter".into(), t_gather),
        ],
        total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::alltoall::{alltoall, flat_alltoall_timing};
    use crate::config::ClusterConfig;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn net(nodes: usize, gpus: usize) -> NetworkModel {
        let mut cfg = ClusterConfig::commodity(nodes);
        cfg.gpus_per_node = gpus;
        NetworkModel::new(cfg)
    }

    #[test]
    fn matches_vanilla_semantics_exactly() {
        for (nodes, gpus, chunk) in [(2, 2, 3), (2, 4, 1), (4, 2, 5), (3, 3, 2)] {
            let m = net(nodes, gpus);
            let w = nodes * gpus;
            let mut rng = Rng::seed((nodes * 100 + gpus) as u64);
            let mut a: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..w * chunk).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut b = a.clone();
            alltoall(&m, &mut a).unwrap();
            hierarchical_alltoall(&m, &mut b).unwrap();
            assert_eq!(a, b, "nodes={nodes} gpus={gpus} chunk={chunk}");
        }
    }

    #[test]
    fn matches_vanilla_property() {
        for_all(12, |gen| {
            let nodes = gen.usize_in(1..4);
            let gpus = gen.usize_in(1..4);
            let chunk = gen.usize_in(1..4);
            let m = net(nodes, gpus);
            let w = nodes * gpus;
            let mut a: Vec<Vec<f32>> = (0..w)
                .map(|r| {
                    (0..w * chunk)
                        .map(|i| (r * w * chunk + i) as f32)
                        .collect()
                })
                .collect();
            let mut b = a.clone();
            alltoall(&m, &mut a).unwrap();
            hierarchical_alltoall(&m, &mut b).unwrap();
            assert_eq!(a, b);
        });
    }

    #[test]
    fn involution_roundtrip() {
        let m = net(2, 3);
        let mut rng = Rng::seed(7);
        let w = 6;
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..w * 4).map(|_| rng.normal_f32()).collect())
            .collect();
        let orig = bufs.clone();
        hierarchical_alltoall(&m, &mut bufs).unwrap();
        hierarchical_alltoall(&m, &mut bufs).unwrap();
        assert_eq!(bufs, orig);
    }

    /// The paper's headline communication claim: hierarchical beats flat
    /// by ~1.66× on 4×8 GPUs and ~2× on 8×8 at B = 16 MB per GPU.
    #[test]
    fn paper_fig7_speedup_shape() {
        let payload: usize = 16 * 1024 * 1024; // B per GPU

        let m4 = net(4, 8);
        let chunk4 = payload / m4.cfg.world();
        let flat4 = flat_alltoall_timing(&m4, chunk4).total;
        let hier4 = hierarchical_alltoall_timing(&m4, chunk4).total;
        let s4 = flat4 / hier4;

        let m8 = net(8, 8);
        let chunk8 = payload / m8.cfg.world();
        let flat8 = flat_alltoall_timing(&m8, chunk8).total;
        let hier8 = hierarchical_alltoall_timing(&m8, chunk8).total;
        let s8 = flat8 / hier8;

        assert!(s4 > 1.3, "4x8 speedup {s4:.2} (paper: 1.66)");
        assert!(s8 > s4, "speedup must grow with node count: {s4:.2} vs {s8:.2}");
        assert!(s8 > 1.7 && s8 < 3.5, "8x8 speedup {s8:.2} (paper: 2.0)");
    }

    #[test]
    fn single_node_degenerates() {
        let m = net(1, 4);
        let t = hierarchical_alltoall_timing(&m, 1024);
        assert_eq!(t.phases.len(), 1);
        assert!(t.phase("intra") > 0.0);
        // Same as flat intra time.
        let flat = flat_alltoall_timing(&m, 1024);
        assert!((t.total - flat.phase("intra")).abs() < 1e-12);
    }

    #[test]
    fn message_size_amplification_is_g_squared() {
        // Flat inter message: chunk. Hier inter message: G*B/N = G^2 * chunk * ...
        // With B = W*chunk: G*B/N bytes = G*W*chunk/N = G^2 * chunk.
        let g = 8usize;
        let n = 4usize;
        let chunk = 1024usize;
        let b = n * g * chunk;
        assert_eq!(g * b / n, g * g * chunk);
    }

    #[test]
    fn validates_world() {
        let m = net(2, 2);
        let mut bad = vec![vec![0.0; 8]; 3];
        assert!(hierarchical_alltoall(&m, &mut bad).is_err());
    }
}
