//! Hierarchical AllToAll (the paper's §3.2 communication contribution).
//!
//! Paper Figure 6, four phases per node:
//! 1. **gather** — every GPU ships its whole payload to the node leader
//!    over the intra-node fabric;
//! 2. **layout** — the leader reorders tokens so data destined to the
//!    same remote *node* is contiguous (message aggregation);
//! 3. **inter-node AllToAll** — only `N` leaders exchange; each message
//!    carries `G·B/N` bytes, i.e. `G²×` larger than the flat scheme's
//!    `B/(NG)` — this is the whole trick: the NIC sees few, large,
//!    bandwidth-saturating messages instead of many small ones;
//! 4. **layout + scatter** — reorder received data per destination GPU
//!    and ship it from the leader to local GPUs.
//!
//! The data movement below implements the real permutation (verified
//! equal to vanilla [`super::alltoall`]); the timing charges each phase
//! on the [`NetworkModel`].

use crate::cluster::NetworkModel;
use crate::comm::{uniform_len, CommTiming, F32_BYTES};
use crate::error::Result;

/// Hierarchical AllToAll with equal chunks.
///
/// Semantics identical to [`super::alltoall`]; timing reflects the
/// four-phase hierarchical schedule.
pub fn hierarchical_alltoall(
    net: &NetworkModel,
    buffers: &mut [Vec<f32>],
) -> Result<CommTiming> {
    let w = buffers.len();
    let len = uniform_len(buffers)?;
    let cfg = &net.cfg;
    if w != cfg.world() {
        return Err(crate::comm_err!(
            "hierarchical_alltoall over {w} buffers but cluster world is {}",
            cfg.world()
        ));
    }
    if len == 0 {
        // Zero-count ranks are first-class: an empty exchange moves
        // nothing, allocates nothing, and costs nothing (the ragged
        // pipeline routinely produces empty (src, dst) payloads).
        return Ok(CommTiming::default());
    }
    if len % w != 0 {
        return Err(crate::comm_err!("buffer len {len} not divisible by world {w}"));
    }
    let (n, g) = (cfg.nodes, cfg.gpus_per_node);
    let chunk = len / w;

    // ---- data movement ----
    // Phase 1: gather every local GPU's buffer at the node leader.
    // node_buf[node] = [local g][dest rank d] -> chunk  (g-major)
    let mut node_buf: Vec<Vec<f32>> = (0..n)
        .map(|node| {
            let mut v = Vec::with_capacity(g * len);
            for local in 0..g {
                v.extend_from_slice(&buffers[node * g + local]);
            }
            v
        })
        .collect();

    // Phase 2: layout transform — regroup by destination node:
    // send_block[node][dest_node] = for each local g (source), the G chunks
    // destined to dest_node's GPUs, concatenated. Block size = G*G*chunk.
    let block = g * g * chunk;
    let mut send: Vec<Vec<f32>> = vec![Vec::with_capacity(n * block); n];
    for node in 0..n {
        for dest_node in 0..n {
            for local in 0..g {
                let base = local * len + dest_node * g * chunk;
                send[node].extend_from_slice(&node_buf[node][base..base + g * chunk]);
            }
        }
    }

    // Phase 3: inter-node AllToAll between leaders (block-wise transpose).
    let mut recv: Vec<Vec<f32>> = vec![vec![0.0f32; n * block]; n];
    for dst in 0..n {
        for src in 0..n {
            recv[dst][src * block..(src + 1) * block]
                .copy_from_slice(&send[src][dst * block..(dst + 1) * block]);
        }
    }

    // Phase 4: reverse layout + scatter to local GPUs.
    // recv[dst] from src node: [src local g'][dest local g] -> chunk.
    for node in 0..n {
        for local in 0..g {
            let d = node * g + local;
            for src_node in 0..n {
                for src_local in 0..g {
                    let s = src_node * g + src_local;
                    let base = src_node * block + src_local * g * chunk + local * chunk;
                    buffers[d][s * chunk..(s + 1) * chunk]
                        .copy_from_slice(&recv[node][base..base + chunk]);
                }
            }
        }
        node_buf[node].clear(); // appease borrowck-free logic; cheap
    }

    // ---- simulated timing ----
    Ok(hierarchical_alltoall_timing(net, chunk * F32_BYTES))
}

/// Timing of the hierarchical schedule with `chunk_bytes` per (GPU,GPU)
/// logical chunk (per-GPU payload `B = W * chunk_bytes`).
pub fn hierarchical_alltoall_timing(net: &NetworkModel, chunk_bytes: usize) -> CommTiming {
    let cfg = &net.cfg;
    let (n, g) = (cfg.nodes, cfg.gpus_per_node);
    let w = n * g;
    let payload = (w * chunk_bytes) as f64; // B, bytes per GPU

    if n == 1 {
        // Degenerates to the intra-node exchange of the flat scheme.
        let t = net.intra_batch_time(g - 1, chunk_bytes as f64);
        return CommTiming { phases: vec![("intra".into(), t)], total: t };
    }

    // Phase 1: leader collects (G-1) payloads over the node fabric.
    let t_gather = net.gather_time(g - 1, (g - 1) as f64 * payload);
    // Phase 2: on-device re-layout of the aggregated G·B buffer.
    let t_layout = net.device_copy_time(g as f64 * payload);
    // Phase 3: each leader sends N-1 aggregated messages of G·B/N bytes.
    let msg = g as f64 * payload / n as f64;
    let t_inter = net.nic_batch_time(n - 1, msg);
    // Phase 4: mirror of 2 + 1.
    let total = 2.0 * t_gather + 2.0 * t_layout + t_inter;
    CommTiming {
        phases: vec![
            ("gather".into(), t_gather),
            ("layout".into(), t_layout),
            ("inter".into(), t_inter),
            ("layout2".into(), t_layout),
            ("scatter".into(), t_gather),
        ],
        total,
    }
}

/// Timing of the hierarchical schedule for a **variable-count** exchange:
/// `counts[s][d]` elements of `elem_bytes` from rank `s` to rank `d`.
///
/// Same four phases as [`hierarchical_alltoall_timing`], with each phase
/// charged for the bytes the ragged plan actually moves: non-leader GPUs
/// gather their whole payload at the node leader, the leader re-lays the
/// aggregate out by destination node, and each leader pair exchanges one
/// aggregated message. With uniform counts this reduces exactly to the
/// equal-chunk formula (asserted in tests). Cost-model twin of
/// [`super::alltoall::alltoallv_timing`], used by the serving router to
/// score a dispatch plan against both schedules.
pub fn hierarchical_alltoallv_timing(
    net: &NetworkModel,
    counts: &[Vec<usize>],
    elem_bytes: usize,
) -> CommTiming {
    hierarchical_alltoallv_timing_with(net, counts, elem_bytes, None)
}

/// [`hierarchical_alltoallv_timing`] with an optional per-(node, node)
/// override of the inter-leg message bytes — how the dedup-aware cost
/// model charges the NIC for what a deduplicated leader block *actually*
/// ships (payload rows + replication index) instead of every replica
/// row. Gather/layout/scatter phases are unchanged: full rows always
/// move inside the node.
pub fn hierarchical_alltoallv_timing_with(
    net: &NetworkModel,
    counts: &[Vec<usize>],
    elem_bytes: usize,
    inter_bytes: Option<&[Vec<f64>]>,
) -> CommTiming {
    let cfg = &net.cfg;
    let (n, g) = (cfg.nodes, cfg.gpus_per_node);
    let w = n * g;
    let eb = elem_bytes as f64;

    if n == 1 {
        // Degenerates to the flat scheme's intra-node exchange.
        let flat = super::alltoall::alltoallv_timing(net, counts, elem_bytes);
        let t = flat.phase("intra");
        return CommTiming { phases: vec![("intra".into(), t)], total: t };
    }

    let mut gather_max = 0.0f64;
    let mut layout_max = 0.0f64;
    let mut inter_max = 0.0f64;
    let mut layout2_max = 0.0f64;
    let mut scatter_max = 0.0f64;
    for node in 0..n {
        // Send side (gather + first layout): bytes this node's GPUs hold.
        let mut send_bytes = 0.0f64;
        let mut gather_bytes = 0.0f64;
        // Receive side (second layout + scatter): bytes destined to this
        // node's GPUs — ragged traffic need not be symmetric, so the
        // mirror phases are charged from the receive profile.
        let mut recv_bytes = 0.0f64;
        let mut scatter_bytes = 0.0f64;
        for local in 0..g {
            let s = node * g + local;
            let row: usize = counts[s].iter().sum();
            let out_bytes = row as f64 * eb;
            send_bytes += out_bytes;
            let col: usize = (0..w).map(|src| counts[src][s]).sum();
            let in_bytes = col as f64 * eb;
            recv_bytes += in_bytes;
            if local != 0 {
                gather_bytes += out_bytes; // leader's own payload needs no hop
                scatter_bytes += in_bytes;
            }
        }
        let t_gather = net.gather_time(g - 1, gather_bytes);
        let t_layout = net.device_copy_time(send_bytes);
        let t_layout2 = net.device_copy_time(recv_bytes);
        let t_scatter = net.gather_time(g - 1, scatter_bytes);
        let mut nic_time = 0.0f64;
        for dest_node in 0..n {
            if dest_node == node {
                continue;
            }
            let bytes = match inter_bytes {
                Some(m) => m[node][dest_node],
                None => {
                    let mut msg = 0usize;
                    for local in 0..g {
                        let s = node * g + local;
                        for dest_local in 0..g {
                            msg += counts[s][dest_node * g + dest_local];
                        }
                    }
                    msg as f64 * eb
                }
            };
            if bytes > 0.0 {
                nic_time += cfg.inter_lat + bytes / net.eff_bw(cfg.inter_bw, bytes);
            }
        }
        let t_inter = nic_time / cfg.nics_per_node as f64;
        gather_max = gather_max.max(t_gather);
        layout_max = layout_max.max(t_layout);
        inter_max = inter_max.max(t_inter);
        layout2_max = layout2_max.max(t_layout2);
        scatter_max = scatter_max.max(t_scatter);
    }
    let total = gather_max + layout_max + inter_max + layout2_max + scatter_max;
    CommTiming {
        phases: vec![
            ("gather".into(), gather_max),
            ("layout".into(), layout_max),
            ("inter".into(), inter_max),
            ("layout2".into(), layout2_max),
            ("scatter".into(), scatter_max),
        ],
        total,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::alltoall::{alltoall, alltoallv_timing, flat_alltoall_timing};
    use crate::config::ClusterConfig;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn net(nodes: usize, gpus: usize) -> NetworkModel {
        let mut cfg = ClusterConfig::commodity(nodes);
        cfg.gpus_per_node = gpus;
        NetworkModel::new(cfg)
    }

    #[test]
    fn matches_vanilla_semantics_exactly() {
        for (nodes, gpus, chunk) in [(2, 2, 3), (2, 4, 1), (4, 2, 5), (3, 3, 2)] {
            let m = net(nodes, gpus);
            let w = nodes * gpus;
            let mut rng = Rng::seed((nodes * 100 + gpus) as u64);
            let mut a: Vec<Vec<f32>> = (0..w)
                .map(|_| (0..w * chunk).map(|_| rng.normal_f32()).collect())
                .collect();
            let mut b = a.clone();
            alltoall(&m, &mut a).unwrap();
            hierarchical_alltoall(&m, &mut b).unwrap();
            assert_eq!(a, b, "nodes={nodes} gpus={gpus} chunk={chunk}");
        }
    }

    #[test]
    fn matches_vanilla_property() {
        for_all(12, |gen| {
            let nodes = gen.usize_in(1..4);
            let gpus = gen.usize_in(1..4);
            let chunk = gen.usize_in(1..4);
            let m = net(nodes, gpus);
            let w = nodes * gpus;
            let mut a: Vec<Vec<f32>> = (0..w)
                .map(|r| {
                    (0..w * chunk)
                        .map(|i| (r * w * chunk + i) as f32)
                        .collect()
                })
                .collect();
            let mut b = a.clone();
            alltoall(&m, &mut a).unwrap();
            hierarchical_alltoall(&m, &mut b).unwrap();
            assert_eq!(a, b);
        });
    }

    #[test]
    fn involution_roundtrip() {
        let m = net(2, 3);
        let mut rng = Rng::seed(7);
        let w = 6;
        let mut bufs: Vec<Vec<f32>> = (0..w)
            .map(|_| (0..w * 4).map(|_| rng.normal_f32()).collect())
            .collect();
        let orig = bufs.clone();
        hierarchical_alltoall(&m, &mut bufs).unwrap();
        hierarchical_alltoall(&m, &mut bufs).unwrap();
        assert_eq!(bufs, orig);
    }

    /// The paper's headline communication claim: hierarchical beats flat
    /// by ~1.66× on 4×8 GPUs and ~2× on 8×8 at B = 16 MB per GPU.
    #[test]
    fn paper_fig7_speedup_shape() {
        let payload: usize = 16 * 1024 * 1024; // B per GPU

        let m4 = net(4, 8);
        let chunk4 = payload / m4.cfg.world();
        let flat4 = flat_alltoall_timing(&m4, chunk4).total;
        let hier4 = hierarchical_alltoall_timing(&m4, chunk4).total;
        let s4 = flat4 / hier4;

        let m8 = net(8, 8);
        let chunk8 = payload / m8.cfg.world();
        let flat8 = flat_alltoall_timing(&m8, chunk8).total;
        let hier8 = hierarchical_alltoall_timing(&m8, chunk8).total;
        let s8 = flat8 / hier8;

        assert!(s4 > 1.3, "4x8 speedup {s4:.2} (paper: 1.66)");
        assert!(s8 > s4, "speedup must grow with node count: {s4:.2} vs {s8:.2}");
        assert!(s8 > 1.7 && s8 < 3.5, "8x8 speedup {s8:.2} (paper: 2.0)");
    }

    #[test]
    fn single_node_degenerates() {
        let m = net(1, 4);
        let t = hierarchical_alltoall_timing(&m, 1024);
        assert_eq!(t.phases.len(), 1);
        assert!(t.phase("intra") > 0.0);
        // Same as flat intra time.
        let flat = flat_alltoall_timing(&m, 1024);
        assert!((t.total - flat.phase("intra")).abs() < 1e-12);
    }

    #[test]
    fn message_size_amplification_is_g_squared() {
        // Flat inter message: chunk. Hier inter message: G*B/N = G^2 * chunk * ...
        // With B = W*chunk: G*B/N bytes = G*W*chunk/N = G^2 * chunk.
        let g = 8usize;
        let n = 4usize;
        let chunk = 1024usize;
        let b = n * g * chunk;
        assert_eq!(g * b / n, g * g * chunk);
    }

    #[test]
    fn validates_world() {
        let m = net(2, 2);
        let mut bad = vec![vec![0.0; 8]; 3];
        assert!(hierarchical_alltoall(&m, &mut bad).is_err());
    }

    #[test]
    fn empty_exchange_is_first_class() {
        // Zero-length buffers (the ragged path's empty steps) must be a
        // no-op: no error, no allocation, zero cost.
        let m = net(2, 2);
        let mut bufs: Vec<Vec<f32>> = vec![Vec::new(); 4];
        let t = hierarchical_alltoall(&m, &mut bufs).unwrap();
        assert_eq!(t.total, 0.0);
        assert!(bufs.iter().all(|b| b.is_empty()));
        let mut bufs2: Vec<Vec<f32>> = vec![Vec::new(); 4];
        let t2 = alltoall(&m, &mut bufs2).unwrap();
        assert_eq!(t2.total, 0.0);
        // Non-empty lengths that don't divide by the world still error.
        let mut bad = vec![vec![0.0f32; 3]; 4];
        assert!(hierarchical_alltoall(&m, &mut bad).is_err());
    }

    #[test]
    fn inter_bytes_override_changes_only_the_inter_phase() {
        let m = net(2, 2);
        let counts = vec![vec![8usize; 4]; 4];
        let base = hierarchical_alltoallv_timing(&m, &counts, 64);
        // Halve the NIC bytes (what dedup does); every other phase must
        // be untouched and the inter phase must strictly shrink.
        let mut override_bytes = vec![vec![0.0f64; 2]; 2];
        override_bytes[0][1] = 8.0 * 2.0 * 2.0 * 64.0 / 2.0;
        override_bytes[1][0] = override_bytes[0][1];
        let cut =
            hierarchical_alltoallv_timing_with(&m, &counts, 64, Some(&override_bytes));
        assert!(cut.phase("inter") < base.phase("inter"));
        for phase in ["gather", "layout", "layout2", "scatter"] {
            assert_eq!(cut.phase(phase), base.phase(phase), "{phase}");
        }
        // Zero override drops the inter phase entirely.
        let zero = vec![vec![0.0f64; 2]; 2];
        let none = hierarchical_alltoallv_timing_with(&m, &counts, 64, Some(&zero));
        assert_eq!(none.phase("inter"), 0.0);
    }

    #[test]
    fn ragged_timing_matches_equal_chunk_on_uniform_counts() {
        for (nodes, gpus, chunk) in [(2usize, 4usize, 256usize), (4, 8, 64), (1, 4, 128)] {
            let m = net(nodes, gpus);
            let w = nodes * gpus;
            let counts = vec![vec![chunk; w]; w];
            let ragged = hierarchical_alltoallv_timing(&m, &counts, 4);
            let equal = hierarchical_alltoall_timing(&m, chunk * 4);
            assert!(
                (ragged.total - equal.total).abs() < 1e-12,
                "nodes={nodes} gpus={gpus}: {} vs {}",
                ragged.total,
                equal.total
            );
        }
    }

    #[test]
    fn ragged_timing_skips_empty_destinations() {
        // All traffic stays on node 0: no inter phase at all.
        let m = net(2, 2);
        let mut counts = vec![vec![0usize; 4]; 4];
        counts[0][1] = 100;
        counts[1][0] = 100;
        let t = hierarchical_alltoallv_timing(&m, &counts, 4);
        assert_eq!(t.phase("inter"), 0.0);
        assert!(t.total > 0.0); // gather/layout still move the payload
    }

    #[test]
    fn ragged_timing_charges_receive_skew() {
        // Only node 0's *leader* sends, and only to node 1's non-leader
        // GPUs: nothing needs gathering on the send side, but the rows
        // still fan out from node 1's leader — the scatter phase must be
        // charged from the receive profile, not mirrored from the send.
        let m = net(2, 4);
        let w = 8;
        let mut counts = vec![vec![0usize; w]; w];
        counts[0][5] = 50;
        counts[0][6] = 50;
        let t = hierarchical_alltoallv_timing(&m, &counts, 4);
        assert_eq!(t.phase("gather"), 0.0, "leader-held payload needs no gather");
        assert!(t.phase("scatter") > 0.0, "non-leader destinations need a scatter");
        assert!(t.phase("inter") > 0.0);
    }

    #[test]
    fn aggregation_beats_flat_on_small_serving_batches() {
        // Serving-scale dispatch: a few token rows per (src, dst) pair.
        // Flat pays one NIC latency per pair; hierarchical pays one per
        // node pair — the paper's mechanism at online batch sizes.
        let m = net(4, 8);
        let w = m.cfg.world();
        let counts = vec![vec![2usize; w]; w]; // 2 rows per pair
        let row_bytes = 256; // d_model 64 × f32
        let flat = alltoallv_timing(&m, &counts, row_bytes).total;
        let hier = hierarchical_alltoallv_timing(&m, &counts, row_bytes).total;
        assert!(
            hier < flat * 0.5,
            "hier {hier:.6}s must clearly beat flat {flat:.6}s on small messages"
        );
    }
}
