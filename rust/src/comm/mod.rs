//! Collective communication over the simulated cluster.
//!
//! Every collective does **two** things:
//! 1. moves the real bytes between per-rank host buffers — so semantics
//!    are unit-testable (hierarchical AllToAll must produce *exactly* the
//!    vanilla AllToAll permutation);
//! 2. returns a [`CommTiming`] computed from the [`NetworkModel`] — the
//!    simulated wall time the same schedule would take on the paper's
//!    cluster (PCIe intra-node, one NIC inter-node).
//!
//! The split mirrors the paper's Figure 5 (vanilla NCCL AllToAll) and
//! Figure 6 (hierarchical AllToAll: intra-node gather → on-device layout
//! transform → aggregated inter-node AllToAll → scatter).

pub mod allgather;
pub mod allreduce;
pub mod alltoall;
pub mod hier_ragged;
pub mod hierarchical;
pub mod precision;
pub mod ragged;
pub mod schedule;

pub use allgather::{allgather, reduce_scatter};
pub use allreduce::allreduce;
pub use alltoall::{alltoall, alltoallv};
pub use hier_ragged::{
    dedup_traffic, hier_ragged_combine, hier_ragged_dispatch, row_meta, DedupMeta,
    DedupTraffic, HierLeg, PresumMeta, RowMeta,
};
pub use hierarchical::hierarchical_alltoall;
pub use precision::{WirePrecision, F32_BYTES, F32_BYTES_F};
pub use ragged::{
    ragged_combine, ragged_combine_placed, ragged_dispatch, ragged_dispatch_placed,
    split_wire_bytes,
};
pub use schedule::{
    pick_schedule, pick_schedule_dedup, CommChoice, Schedule, SchedulePick,
};

/// Bytes one exchange leg moves, split by the link they actually cross:
/// `inter` is NIC traffic between nodes (the paper's scarce resource),
/// `intra` is node-fabric traffic between GPUs of one node (direct
/// same-node rows under the flat schedule; leader gather + scatter
/// relays under the hierarchical schedule). Self-traffic (a rank's rows
/// to itself) crosses nothing and is counted in neither.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct WireBytes {
    pub inter: usize,
    pub intra: usize,
}

impl WireBytes {
    pub fn total(&self) -> usize {
        self.inter + self.intra
    }
}

impl std::ops::Add for WireBytes {
    type Output = WireBytes;
    fn add(self, o: WireBytes) -> WireBytes {
        WireBytes { inter: self.inter + o.inter, intra: self.intra + o.intra }
    }
}

/// Simulated timing of one collective, with a per-phase breakdown.
#[derive(Clone, Debug, Default)]
pub struct CommTiming {
    /// (phase name, simulated seconds). Phases may overlap; `total` is
    /// authoritative.
    pub phases: Vec<(String, f64)>,
    /// Simulated wall time of the whole collective.
    pub total: f64,
}

impl CommTiming {
    pub fn phase(&self, name: &str) -> f64 {
        self.phases
            .iter()
            .filter(|(n, _)| n == name)
            .map(|(_, t)| *t)
            .sum()
    }
}

/// Check that all rank buffers have identical length; returns it.
pub(crate) fn uniform_len(buffers: &[Vec<f32>]) -> crate::error::Result<usize> {
    let w = buffers.len();
    if w == 0 {
        return Err(crate::comm_err!("no ranks"));
    }
    let len = buffers[0].len();
    for (r, b) in buffers.iter().enumerate() {
        if b.len() != len {
            return Err(crate::comm_err!(
                "rank {r} buffer has {} elements, rank 0 has {len}",
                b.len()
            ));
        }
    }
    Ok(len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comm_timing_phase_lookup() {
        let t = CommTiming {
            phases: vec![("a".into(), 1.0), ("b".into(), 2.0), ("a".into(), 0.5)],
            total: 3.5,
        };
        assert!((t.phase("a") - 1.5).abs() < 1e-12);
        assert_eq!(t.phase("zzz"), 0.0);
    }

    #[test]
    fn uniform_len_rejects_ragged() {
        let ok = vec![vec![0.0; 4], vec![0.0; 4]];
        assert_eq!(uniform_len(&ok).unwrap(), 4);
        let bad = vec![vec![0.0; 4], vec![0.0; 5]];
        assert!(uniform_len(&bad).is_err());
        let empty: Vec<Vec<f32>> = vec![];
        assert!(uniform_len(&empty).is_err());
    }
}
