//! Wire precision of the exchange payload legs.
//!
//! HetuMoE's bottleneck is the NIC, and every payload row used to cross
//! it as f32. [`WirePrecision`] names the on-wire element format of the
//! dispatch/combine payload legs: the send boundary quantizes each row
//! (round-to-nearest-even), the receive boundary widens back to f32,
//! and everything in between — expert compute, combine accumulation,
//! all gradient math — stays f32. The compressed formats are simulated
//! by the encode→decode round trip in f32 storage, which is exactly the
//! numerical effect a real half-width wire has; byte accounting uses
//! [`WirePrecision::elem_bytes`] so the cost models, the schedule pick
//! and the data path all charge the same (halved) NIC bytes.
//!
//! The f32 mode is the default and is bit-identical to the pre-wire
//! pipeline: `quantize` is the identity and every byte count uses
//! [`F32_BYTES`]. Collectives that never leave f32 (gradient AllReduce,
//! checkpoint AllGather, the padded pipeline) charge [`F32_BYTES`]
//! explicitly rather than a bare `4`.

use crate::error::Result;

/// Bytes of one f32 element — the element size of every collective that
/// stays full-precision regardless of the wire mode.
pub const F32_BYTES: usize = 4;

/// [`F32_BYTES`] as `f64`, for the analytical cost models that work in
/// fractional milliseconds/bytes.
pub const F32_BYTES_F: f64 = F32_BYTES as f64;

/// On-wire element format of the dispatch/combine payload legs.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WirePrecision {
    /// Full precision — the default; bit-identical to the pre-wire
    /// pipeline everywhere.
    #[default]
    F32,
    /// bfloat16: f32's exponent range, 8exponent/7 mantissa bits. Rounds
    /// round-to-nearest-even; never overflows where f32 doesn't.
    Bf16,
    /// IEEE binary16: 5 exponent/10 mantissa bits. More mantissa than
    /// bf16 but narrow range — values above ~65504 saturate to ±inf.
    F16,
}

impl WirePrecision {
    pub fn parse(s: &str) -> Result<WirePrecision> {
        Ok(match s.to_lowercase().as_str() {
            "f32" | "fp32" | "float32" => WirePrecision::F32,
            "bf16" | "bfloat16" => WirePrecision::Bf16,
            "f16" | "fp16" | "float16" | "half" => WirePrecision::F16,
            other => {
                return Err(crate::config_err!(
                    "unknown wire precision '{other}' (expected f32|bf16|f16)"
                ));
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            WirePrecision::F32 => "f32",
            WirePrecision::Bf16 => "bf16",
            WirePrecision::F16 => "f16",
        }
    }

    /// Bytes per payload element on the wire.
    pub fn elem_bytes(&self) -> usize {
        match self {
            WirePrecision::F32 => F32_BYTES,
            WirePrecision::Bf16 | WirePrecision::F16 => 2,
        }
    }

    /// Whether payload legs ship narrower than f32 (enables the packed
    /// dedup/pre-sum index layout where block sizes permit).
    pub fn is_compressed(&self) -> bool {
        self.elem_bytes() < F32_BYTES
    }

    /// Encode→decode round trip of one element: what the receiver sees
    /// after the value crossed the wire. Identity for [`Self::F32`];
    /// idempotent in every mode (a quantized value re-quantizes to
    /// itself), which is what lets legs re-quantize defensively without
    /// drifting.
    pub fn quantize(&self, x: f32) -> f32 {
        match self {
            WirePrecision::F32 => x,
            WirePrecision::Bf16 => bf16_bits_to_f32(f32_to_bf16_bits(x)),
            WirePrecision::F16 => f16_bits_to_f32(f32_to_f16_bits(x)),
        }
    }

    /// Quantize a buffer in place at the send boundary (no-op for f32).
    pub fn quantize_slice(&self, xs: &mut [f32]) {
        if *self == WirePrecision::F32 {
            return;
        }
        for x in xs.iter_mut() {
            *x = self.quantize(*x);
        }
    }
}

/// Round one f32 to bfloat16 with round-to-nearest-even.
pub fn f32_to_bf16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Keep the sign and force a quiet payload bit that survives the
        // truncation (a signaling NaN whose payload lives only in the
        // low 16 bits would otherwise decode as infinity).
        return ((bits >> 16) as u16) | 0x0040;
    }
    let round = ((bits >> 16) & 1) + 0x7FFF;
    (bits.wrapping_add(round) >> 16) as u16
}

/// Widen bfloat16 bits back to f32 (exact — bf16 values are a subset).
pub fn bf16_bits_to_f32(b: u16) -> f32 {
    f32::from_bits((b as u32) << 16)
}

/// The bf16 encode→decode round trip (the packed replication index
/// ships its expansion weights in this format).
pub fn bf16_round(x: f32) -> f32 {
    bf16_bits_to_f32(f32_to_bf16_bits(x))
}

/// Round one f32 to IEEE binary16 with round-to-nearest-even, handling
/// subnormals, overflow-to-infinity and NaN.
pub fn f32_to_f16_bits(x: f32) -> u16 {
    let bits = x.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xFF) as i32;
    let man = bits & 0x007F_FFFF;
    if exp == 0xFF {
        // Inf / NaN: keep NaN-ness even when the payload truncates away.
        if man != 0 {
            return sign | 0x7E00 | ((man >> 13) as u16 & 0x03FF);
        }
        return sign | 0x7C00;
    }
    let unbiased = exp - 127;
    if unbiased >= 16 {
        return sign | 0x7C00; // overflow → ±inf
    }
    if unbiased >= -14 {
        // Normal half range: drop 13 mantissa bits with RNE. A rounding
        // carry may overflow the mantissa into the exponent — that is
        // the correct next-binade (or infinity) result.
        let e16 = (unbiased + 15) as u32;
        let combined = (e16 << 10) | (man >> 13);
        let rem = man & 0x1FFF;
        let half = 0x1000;
        let mut out = combined;
        if rem > half || (rem == half && (combined & 1) != 0) {
            out += 1;
        }
        return sign | out as u16;
    }
    if unbiased >= -25 {
        // Subnormal half: shift the (implicit-1) mantissa into place.
        let m = man | 0x0080_0000;
        let shift = (13 - 14 - unbiased) as u32; // 14..=24
        let sub = m >> shift;
        let rem = m & ((1u32 << shift) - 1);
        let half = 1u32 << (shift - 1);
        let mut out = sub;
        if rem > half || (rem == half && (sub & 1) != 0) {
            out += 1;
        }
        return sign | out as u16;
    }
    sign // underflows to ±0
}

/// Widen IEEE binary16 bits back to f32 (exact).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1F) as u32;
    let man = (h & 0x03FF) as u32;
    if exp == 0x1F {
        return f32::from_bits(sign | 0x7F80_0000 | (man << 13));
    }
    if exp == 0 {
        if man == 0 {
            return f32::from_bits(sign);
        }
        // Subnormal: renormalize into f32's ample exponent range.
        let mut e = -14i32;
        let mut m = man;
        while m & 0x0400 == 0 {
            m <<= 1;
            e -= 1;
        }
        m &= 0x03FF;
        return f32::from_bits(sign | (((e + 127) as u32) << 23) | (m << 13));
    }
    f32::from_bits(sign | ((exp + 112) << 23) | (man << 13))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_and_names() {
        assert_eq!(WirePrecision::parse("f32").unwrap(), WirePrecision::F32);
        assert_eq!(WirePrecision::parse("BF16").unwrap(), WirePrecision::Bf16);
        assert_eq!(WirePrecision::parse("half").unwrap(), WirePrecision::F16);
        assert!(WirePrecision::parse("int8").is_err());
        assert_eq!(WirePrecision::F32.name(), "f32");
        assert_eq!(WirePrecision::Bf16.name(), "bf16");
        assert_eq!(WirePrecision::F16.name(), "f16");
        assert_eq!(WirePrecision::default(), WirePrecision::F32);
    }

    #[test]
    fn byte_widths() {
        assert_eq!(WirePrecision::F32.elem_bytes(), 4);
        assert_eq!(WirePrecision::Bf16.elem_bytes(), 2);
        assert_eq!(WirePrecision::F16.elem_bytes(), 2);
        assert!(!WirePrecision::F32.is_compressed());
        assert!(WirePrecision::Bf16.is_compressed());
    }

    #[test]
    fn f32_quantize_is_identity_bitwise() {
        for v in [0.0f32, -0.0, 1.5, -3.25e-20, 7.1e30, f32::MIN_POSITIVE] {
            assert_eq!(WirePrecision::F32.quantize(v).to_bits(), v.to_bits());
        }
    }

    #[test]
    fn bf16_round_trip_properties() {
        let q = |x: f32| WirePrecision::Bf16.quantize(x);
        // Exactly representable values survive bitwise.
        for v in [0.0f32, 1.0, -2.0, 0.5, 256.0, -0.15625] {
            assert_eq!(q(v).to_bits(), v.to_bits(), "{v}");
        }
        // Relative error bounded by half an ulp (2^-8 relative) for
        // normal f32 inputs (subnormals keep fewer significant bits).
        for v in [1.001f32, -3.14159, 1e-8, 123456.789, 6.1e-30] {
            let r = q(v);
            assert!(((r - v) / v).abs() <= 1.0 / 256.0, "{v} -> {r}");
        }
        // Round-to-nearest-even at the halfway point: 1 + 2^-8 is
        // exactly between 1.0 and 1 + 2^-7; ties go to the even
        // mantissa (1.0).
        assert_eq!(q(1.0 + 1.0 / 256.0), 1.0);
        assert_eq!(q(1.0 + 3.0 / 256.0), 1.0 + 4.0 / 256.0);
        // Idempotent.
        for v in [1.001f32, -3.7e12, 2.5e-30] {
            assert_eq!(q(q(v)).to_bits(), q(v).to_bits());
        }
        // NaN stays NaN; infinities pass through.
        assert!(q(f32::NAN).is_nan());
        assert_eq!(q(f32::INFINITY), f32::INFINITY);
        assert_eq!(q(f32::NEG_INFINITY), f32::NEG_INFINITY);
    }

    #[test]
    fn f16_round_trip_properties() {
        let q = |x: f32| WirePrecision::F16.quantize(x);
        for v in [0.0f32, 1.0, -2.0, 0.5, 2048.0, 65504.0, -0.000061035156] {
            assert_eq!(q(v).to_bits(), v.to_bits(), "{v}");
        }
        // Relative error within half an ulp for normals (2^-11).
        for v in [1.001f32, -3.14159, 0.1, 999.9] {
            let r = q(v);
            assert!(((r - v) / v).abs() <= 1.0 / 2048.0, "{v} -> {r}");
        }
        // Overflow saturates to infinity; subnormals round, tiny → 0.
        assert_eq!(q(70000.0), f32::INFINITY);
        assert_eq!(q(-70000.0), f32::NEG_INFINITY);
        let sub = q(3.0e-5); // below the normal-half threshold 6.1e-5
        assert!(sub > 0.0 && ((sub - 3.0e-5) / 3.0e-5).abs() < 0.02);
        assert_eq!(q(1.0e-9), 0.0);
        assert_eq!(q(-1.0e-9).to_bits(), (-0.0f32).to_bits());
        // Idempotent; NaN preserved.
        for v in [1.001f32, 3.0e-5, -123.456] {
            assert_eq!(q(q(v)).to_bits(), q(v).to_bits());
        }
        assert!(q(f32::NAN).is_nan());
        // RNE at the halfway point around 1.0 (ulp = 2^-10).
        assert_eq!(q(1.0 + 1.0 / 2048.0), 1.0);
        assert_eq!(q(1.0 + 3.0 / 2048.0), 1.0 + 4.0 / 2048.0);
    }

    #[test]
    fn quantize_slice_matches_scalar() {
        let vals: Vec<f32> = (0..64).map(|i| (i as f32 - 31.5) * 0.37).collect();
        for mode in [WirePrecision::F32, WirePrecision::Bf16, WirePrecision::F16] {
            let mut buf = vals.clone();
            mode.quantize_slice(&mut buf);
            for (o, &v) in buf.iter().zip(&vals) {
                assert_eq!(o.to_bits(), mode.quantize(v).to_bits());
            }
        }
    }
}
