//! Ragged (padding-free) token exchange for the MoE dispatch/combine.
//!
//! The padded pipeline ships full `[E, cap, d]` buffers — zeros and all —
//! through both AllToAll legs. The ragged exchange moves **exactly** the
//! occupied rows: every rank sends, per (destination rank, expert), only
//! the tokens the capacity rule actually kept, described by the
//! per-(rank, expert) `kept` matrix from the [`DispatchPlan`]s.
//!
//! Receive layout is **expert-major**: at destination rank `r`, rows for
//! local expert 0 (from every source rank, in rank order) come first,
//! then local expert 1, … — so each expert's batch is one contiguous
//! `[n_e, d]` block and the grouped expert GEMM needs no per-source
//! gather (this is the receive-side layout fold MegaBlocks-style ragged
//! dispatch performs; a real implementation receives into strided
//! offsets). [`ragged_combine`] is the exact inverse permutation, with
//! its timing charged on the transposed rank matrix.
//!
//! Timing is charged through the same cost models the serving router
//! uses ([`alltoallv_timing`] / [`hierarchical_alltoallv_timing`]), so
//! training and serving score traffic identically.
//!
//! [`DispatchPlan`]: crate::gating::DispatchPlan
//! [`alltoallv_timing`]: crate::comm::alltoall::alltoallv_timing
//! [`hierarchical_alltoallv_timing`]: crate::comm::hierarchical::hierarchical_alltoallv_timing

use crate::cluster::{ExpertPlacement, NetworkModel};
use crate::comm::alltoall::alltoallv_timing;
use crate::comm::hierarchical::hierarchical_alltoallv_timing;
use crate::comm::precision::WirePrecision;
use crate::comm::schedule::{transpose_counts, Schedule};
use crate::comm::CommTiming;
use crate::error::Result;

/// Collapse a per-(rank, expert) kept matrix `kept[src][global_expert]`
/// into the rank-level traffic matrix `counts[src][dst]` via the shared
/// expert placement ([`crate::cluster::ExpertPlacement`]: experts
/// partitioned contiguously, `experts_per_rank` per rank).
pub fn rank_counts(kept: &[Vec<usize>], experts_per_rank: usize) -> Vec<Vec<usize>> {
    let w = kept.len();
    if w == 0 {
        return Vec::new();
    }
    let placement = crate::cluster::ExpertPlacement::new(experts_per_rank * w, w);
    debug_assert!(kept.iter().all(|row| row.len() == placement.num_experts));
    placement.traffic_matrix(kept)
}

/// Placement-aware byte split of one **flat** exchange leg: a
/// cross-rank row is NIC traffic only when source and destination GPUs
/// sit on *different nodes*; same-node cross-rank rows ride the node
/// fabric and land in `intra`. (The old `offwire_bytes` charged both as
/// NIC traffic, which inflated `bytes_on_wire` by exactly the traffic
/// the hierarchical schedule's aggregation is about.) Self-traffic is
/// counted in neither.
pub fn split_wire_bytes(
    counts: &[Vec<usize>],
    elem_bytes: usize,
    gpus_per_node: usize,
) -> crate::comm::WireBytes {
    let g = gpus_per_node.max(1);
    let mut wb = crate::comm::WireBytes::default();
    for (s, row) in counts.iter().enumerate() {
        for (d, &c) in row.iter().enumerate() {
            if s == d {
                continue;
            }
            if s / g == d / g {
                wb.intra += c * elem_bytes;
            } else {
                wb.inter += c * elem_bytes;
            }
        }
    }
    wb
}

fn validate(
    net: &NetworkModel,
    buffers: &[Vec<f32>],
    kept: &[Vec<usize>],
    placement: &ExpertPlacement,
) -> Result<usize> {
    let w = buffers.len();
    if w != net.cfg.world() {
        return Err(crate::comm_err!(
            "ragged exchange over {w} buffers but cluster world is {}",
            net.cfg.world()
        ));
    }
    if kept.len() != w {
        return Err(crate::comm_err!("kept matrix must have {w} rows"));
    }
    let e = kept[0].len();
    if e == 0 || e % w != 0 || kept.iter().any(|row| row.len() != e) {
        return Err(crate::comm_err!(
            "kept rows must all list the same expert count divisible by {w}"
        ));
    }
    if placement.num_experts != e || placement.world != w {
        return Err(crate::comm_err!(
            "placement covers {} experts over {} ranks, exchange has {e} over {w}",
            placement.num_experts,
            placement.world
        ));
    }
    Ok(e)
}

fn timing_for(
    net: &NetworkModel,
    counts: &[Vec<usize>],
    elem_bytes: usize,
    schedule: Schedule,
) -> CommTiming {
    match schedule {
        Schedule::Flat => alltoallv_timing(net, counts, elem_bytes),
        Schedule::Hierarchical => hierarchical_alltoallv_timing(net, counts, elem_bytes),
    }
}

/// Dispatch leg: `buffers[s]` holds rank `s`'s ragged layout buffer —
/// `kept[s][e]` rows of width `d` per global expert `e`, expert-major.
/// On return `buffers[r]` holds, for each of rank `r`'s local experts in
/// order, that expert's rows from every source rank (rank order) — each
/// expert's batch contiguous. Returns the simulated timing of the leg
/// under `schedule`.
pub fn ragged_dispatch(
    net: &NetworkModel,
    buffers: &mut [Vec<f32>],
    kept: &[Vec<usize>],
    d: usize,
    schedule: Schedule,
) -> Result<CommTiming> {
    let w = buffers.len().max(1);
    let e = kept.first().map(|r| r.len()).unwrap_or(0);
    if e == 0 || e % w != 0 {
        // Let the placement-aware path produce the shape error.
        let p = ExpertPlacement::new(w, w);
        return ragged_dispatch_placed(net, buffers, kept, d, schedule, &p, WirePrecision::F32);
    }
    let placement = ExpertPlacement::new(e, w);
    ragged_dispatch_placed(net, buffers, kept, d, schedule, &placement, WirePrecision::F32)
}

/// [`ragged_dispatch`] generalized over an arbitrary (possibly
/// elastically remapped) expert placement: each destination rank
/// receives its **hosted** experts' rows — whatever set the placement
/// assigns it — in ascending expert order, each expert's batch
/// contiguous and source-ordered. A dead rank hosting nothing receives
/// an empty buffer.
///
/// `wire` sets the on-wire element format of the payload rows: every
/// row is quantized at the send boundary (uniformly — same-node and
/// same-rank rows too, so the hierarchical path lands on identical
/// bits) and the timing/byte models charge `d · elem_bytes` per row.
pub fn ragged_dispatch_placed(
    net: &NetworkModel,
    buffers: &mut [Vec<f32>],
    kept: &[Vec<usize>],
    d: usize,
    schedule: Schedule,
    placement: &ExpertPlacement,
    wire: WirePrecision,
) -> Result<CommTiming> {
    let e = validate(net, buffers, kept, placement)?;
    let w = buffers.len();
    for (s, buf) in buffers.iter().enumerate() {
        let expect: usize = kept[s].iter().sum::<usize>() * d;
        if buf.len() != expect {
            return Err(crate::comm_err!(
                "rank {s}: ragged buffer has {} elements, kept counts say {expect}",
                buf.len()
            ));
        }
    }
    for buf in buffers.iter_mut() {
        wire.quantize_slice(buf);
    }

    // Source-side offsets (rows) of each expert block.
    let offs: Vec<Vec<usize>> = kept
        .iter()
        .map(|row| {
            let mut off = vec![0usize; e + 1];
            for (i, &c) in row.iter().enumerate() {
                off[i + 1] = off[i] + c;
            }
            off
        })
        .collect();

    // ---- data movement: expert-major receive layout ----
    let mut out: Vec<Vec<f32>> = (0..w)
        .map(|r| {
            let total: usize = placement
                .hosted_experts(r)
                .into_iter()
                .map(|ge| kept.iter().map(|row| row[ge]).sum::<usize>())
                .sum();
            Vec::with_capacity(total * d)
        })
        .collect();
    for (r, out_r) in out.iter_mut().enumerate() {
        for ge in placement.hosted_experts(r) {
            for s in 0..w {
                let lo = offs[s][ge] * d;
                let hi = offs[s][ge + 1] * d;
                out_r.extend_from_slice(&buffers[s][lo..hi]);
            }
        }
    }
    for (b, o) in buffers.iter_mut().zip(out) {
        *b = o;
    }

    let counts = placement.traffic_matrix(kept);
    Ok(timing_for(net, &counts, d * wire.elem_bytes(), schedule))
}

/// Combine leg: the exact inverse of [`ragged_dispatch`]. `buffers[r]`
/// holds rank `r`'s expert outputs in the expert-major receive layout;
/// on return `buffers[s]` is back in rank `s`'s ragged layout order.
/// Timing is charged on the **transposed** rank matrix (every flow
/// reverses).
pub fn ragged_combine(
    net: &NetworkModel,
    buffers: &mut [Vec<f32>],
    kept: &[Vec<usize>],
    d: usize,
    schedule: Schedule,
) -> Result<CommTiming> {
    let w = buffers.len().max(1);
    let e = kept.first().map(|r| r.len()).unwrap_or(0);
    if e == 0 || e % w != 0 {
        let p = ExpertPlacement::new(w, w);
        return ragged_combine_placed(net, buffers, kept, d, schedule, &p, WirePrecision::F32);
    }
    let placement = ExpertPlacement::new(e, w);
    ragged_combine_placed(net, buffers, kept, d, schedule, &placement, WirePrecision::F32)
}

/// [`ragged_combine`] generalized over an arbitrary (possibly
/// elastically remapped) expert placement — the exact inverse of
/// [`ragged_dispatch_placed`] under the same placement.
pub fn ragged_combine_placed(
    net: &NetworkModel,
    buffers: &mut [Vec<f32>],
    kept: &[Vec<usize>],
    d: usize,
    schedule: Schedule,
    placement: &ExpertPlacement,
    wire: WirePrecision,
) -> Result<CommTiming> {
    let e = validate(net, buffers, kept, placement)?;
    let w = buffers.len();
    // Offsets (rows) of block (local expert, source) inside each owner
    // rank's expert-major buffer, local expert = position in the rank's
    // hosted list.
    let mut block_off: Vec<Vec<usize>> = Vec::with_capacity(w);
    for r in 0..w {
        let hosted = placement.hosted_experts(r);
        let mut off = vec![0usize; hosted.len() * w + 1];
        for (le, &ge) in hosted.iter().enumerate() {
            for s in 0..w {
                let i = le * w + s;
                off[i + 1] = off[i] + kept[s][ge];
            }
        }
        block_off.push(off);
    }
    for (r, buf) in buffers.iter().enumerate() {
        let expect = block_off[r].last().copied().unwrap_or(0) * d;
        if buf.len() != expect {
            return Err(crate::comm_err!(
                "rank {r}: expert-major buffer has {} elements, kept counts say {expect}",
                buf.len()
            ));
        }
    }
    for buf in buffers.iter_mut() {
        wire.quantize_slice(buf);
    }

    // ---- data movement: back to source ragged order ----
    let mut out: Vec<Vec<f32>> = (0..w)
        .map(|s| {
            let total: usize = kept[s].iter().sum();
            Vec::with_capacity(total * d)
        })
        .collect();
    for (s, out_s) in out.iter_mut().enumerate() {
        for ge in 0..e {
            let r = placement.rank_of(ge);
            let le = placement.local_of(ge);
            let lo = block_off[r][le * w + s] * d;
            let hi = block_off[r][le * w + s + 1] * d;
            out_s.extend_from_slice(&buffers[r][lo..hi]);
        }
    }
    for (b, o) in buffers.iter_mut().zip(out) {
        *b = o;
    }

    let counts_t = transpose_counts(&placement.traffic_matrix(kept));
    Ok(timing_for(net, &counts_t, d * wire.elem_bytes(), schedule))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;
    use crate::util::proptest::for_all;

    fn net(nodes: usize, gpus: usize) -> NetworkModel {
        let mut cfg = ClusterConfig::commodity(nodes);
        cfg.gpus_per_node = gpus;
        NetworkModel::new(cfg)
    }

    /// Buffers where row values encode (source, expert, position) so the
    /// permutation is fully checkable.
    fn tagged(kept: &[Vec<usize>], d: usize) -> Vec<Vec<f32>> {
        kept.iter()
            .enumerate()
            .map(|(s, row)| {
                let mut v = Vec::new();
                for (e, &c) in row.iter().enumerate() {
                    for p in 0..c {
                        let tag = (s * 1_000_000 + e * 1_000 + p) as f32;
                        for _ in 0..d {
                            v.push(tag);
                        }
                    }
                }
                v
            })
            .collect()
    }

    #[test]
    fn dispatch_groups_rows_expert_major() {
        let m = net(1, 2);
        // 4 experts over 2 ranks (2 per rank).
        let kept = vec![vec![2usize, 0, 1, 1], vec![1, 1, 0, 2]];
        let d = 3;
        let mut bufs = tagged(&kept, d);
        ragged_dispatch(&m, &mut bufs, &kept, d, Schedule::Flat).unwrap();
        // Rank 0 receives expert 0 then expert 1, each source-ordered.
        let tags0: Vec<f32> = bufs[0].iter().step_by(d).copied().collect();
        assert_eq!(
            tags0,
            vec![0.0, 1.0, 1_000_000.0, 1_001_000.0],
            "e0: s0p0, s0p1, s1p0; e1: s1p0"
        );
        // Rank 1 receives expert 2 then expert 3.
        let tags1: Vec<f32> = bufs[1].iter().step_by(d).copied().collect();
        assert_eq!(
            tags1,
            vec![2_000.0, 3_000.0, 1_003_000.0, 1_003_001.0],
            "e2: s0p0; e3: s0p0, s1p0, s1p1"
        );
    }

    #[test]
    fn combine_is_exact_inverse() {
        for (nodes, gpus) in [(1usize, 2usize), (2, 2), (2, 3)] {
            let m = net(nodes, gpus);
            let w = nodes * gpus;
            let e = 2 * w;
            let kept: Vec<Vec<usize>> = (0..w)
                .map(|s| (0..e).map(|ge| (s + ge) % 4).collect())
                .collect();
            let d = 2;
            let mut bufs = tagged(&kept, d);
            let orig = bufs.clone();
            ragged_dispatch(&m, &mut bufs, &kept, d, Schedule::Flat).unwrap();
            ragged_combine(&m, &mut bufs, &kept, d, Schedule::Flat).unwrap();
            assert_eq!(bufs, orig, "nodes={nodes} gpus={gpus}");
        }
    }

    #[test]
    fn conservation_property() {
        for_all(16, |g| {
            let w = 4;
            let m = net(2, 2);
            let e = 8;
            let kept: Vec<Vec<usize>> = (0..w)
                .map(|_| (0..e).map(|_| g.usize_in(0..5)).collect())
                .collect();
            let d = g.usize_in(1..4);
            let mut bufs = tagged(&kept, d);
            let before: usize = bufs.iter().map(|b| b.len()).sum();
            ragged_dispatch(&m, &mut bufs, &kept, d, Schedule::Hierarchical).unwrap();
            let after: usize = bufs.iter().map(|b| b.len()).sum();
            assert_eq!(before, after);
            // Each rank's receive total matches the column sums.
            let counts = rank_counts(&kept, e / w);
            for r in 0..w {
                let col: usize = (0..w).map(|s| counts[s][r]).sum();
                assert_eq!(bufs[r].len(), col * d);
            }
        });
    }

    #[test]
    fn timing_matches_cost_models() {
        let m = net(2, 2);
        let kept = vec![vec![3usize, 1, 0, 2]; 4];
        let d = 4;
        let counts = rank_counts(&kept, 1);
        let mut bufs = tagged(&kept, d);
        let t = ragged_dispatch(&m, &mut bufs, &kept, d, Schedule::Flat).unwrap();
        let expect = alltoallv_timing(&m, &counts, d * 4);
        assert!((t.total - expect.total).abs() < 1e-15);
        let t2 = ragged_combine(&m, &mut bufs, &kept, d, Schedule::Hierarchical).unwrap();
        let expect2 =
            hierarchical_alltoallv_timing(&m, &transpose_counts(&counts), d * 4);
        assert!((t2.total - expect2.total).abs() < 1e-15);
    }

    #[test]
    fn rank_counts_and_wire_byte_split() {
        // 4 experts on 2 ranks: experts 0,1 → rank 0; 2,3 → rank 1.
        let kept = vec![vec![1usize, 2, 3, 4], vec![5, 6, 7, 8]];
        let counts = rank_counts(&kept, 2);
        assert_eq!(counts, vec![vec![3, 7], vec![11, 15]]);
        // 7 + 11 rows cross ranks. With one node they are all node
        // fabric; with one GPU per node they all cross the NIC.
        let same_node = split_wire_bytes(&counts, 4, 2);
        assert_eq!(same_node.intra, (7 + 11) * 4);
        assert_eq!(same_node.inter, 0);
        let cross_node = split_wire_bytes(&counts, 4, 1);
        assert_eq!(cross_node.inter, (7 + 11) * 4);
        assert_eq!(cross_node.intra, 0);
        assert_eq!(same_node.total(), cross_node.total());
    }

    #[test]
    fn wire_split_mixed_topology() {
        // 2 nodes × 2 GPUs: (0→1) intra, (0→2), (0→3), (1→2)… inter.
        let mut counts = vec![vec![0usize; 4]; 4];
        counts[0][0] = 100; // self: counted nowhere
        counts[0][1] = 3;
        counts[0][2] = 5;
        counts[3][2] = 7;
        counts[3][0] = 2;
        let wb = split_wire_bytes(&counts, 2, 2);
        assert_eq!(wb.intra, (3 + 7) * 2);
        assert_eq!(wb.inter, (5 + 2) * 2);
    }

    #[test]
    fn placed_round_trip_with_dead_rank() {
        use crate::cluster::ExpertPlacement;
        let m = net(2, 2);
        let w = 4;
        let e = 8;
        let placement = ExpertPlacement::with_dead(e, w, &[2]);
        // Rank 2 is dead: it sources no tokens and hosts no experts.
        let kept: Vec<Vec<usize>> = (0..w)
            .map(|s| {
                if s == 2 {
                    vec![0usize; e]
                } else {
                    (0..e).map(|ge| (s + ge) % 3).collect()
                }
            })
            .collect();
        let d = 2;
        let mut bufs = tagged(&kept, d);
        assert!(bufs[2].is_empty());
        let orig = bufs.clone();
        ragged_dispatch_placed(
            &m,
            &mut bufs,
            &kept,
            d,
            Schedule::Flat,
            &placement,
            WirePrecision::F32,
        )
        .unwrap();
        // The dead rank received nothing; survivors hold their hosted
        // experts' rows.
        assert!(bufs[2].is_empty());
        for r in 0..w {
            let expect: usize = placement
                .hosted_experts(r)
                .into_iter()
                .map(|ge| kept.iter().map(|row| row[ge]).sum::<usize>())
                .sum();
            assert_eq!(bufs[r].len(), expect * d, "rank {r}");
        }
        // No traffic ever targets the dead rank.
        for row in placement.traffic_matrix(&kept) {
            assert_eq!(row[2], 0);
        }
        ragged_combine_placed(
            &m,
            &mut bufs,
            &kept,
            d,
            Schedule::Flat,
            &placement,
            WirePrecision::F32,
        )
        .unwrap();
        assert_eq!(bufs, orig, "combine inverts dispatch under remap");
    }

    #[test]
    fn placed_rejects_mismatched_placement() {
        use crate::cluster::ExpertPlacement;
        let m = net(1, 2);
        let kept = vec![vec![1usize, 0, 0, 1], vec![0, 1, 1, 0]];
        let mut bufs = tagged(&kept, 2);
        let wrong = ExpertPlacement::new(8, 2);
        assert!(ragged_dispatch_placed(
            &m,
            &mut bufs,
            &kept,
            2,
            Schedule::Flat,
            &wrong,
            WirePrecision::F32
        )
        .is_err());
    }

    #[test]
    fn validates_shapes() {
        let m = net(1, 2);
        let kept = vec![vec![1usize, 0], vec![0, 1]];
        let mut bad_len = vec![vec![0.0f32; 5], vec![0.0; 2]]; // d=2 → rank 0 needs 2
        assert!(ragged_dispatch(&m, &mut bad_len, &kept, 2, Schedule::Flat).is_err());
        let mut ok = vec![vec![0.0f32; 2], vec![0.0; 2]];
        let bad_kept = vec![vec![1usize, 0, 0], vec![0, 1, 0]]; // 3 % 2 != 0
        assert!(ragged_dispatch(&m, &mut ok, &bad_kept, 2, Schedule::Flat).is_err());
        let mut wrong_world = vec![vec![0.0f32; 2]];
        assert!(
            ragged_dispatch(&m, &mut wrong_world, &kept[..1], 2, Schedule::Flat).is_err()
        );
    }
}
