//! Shared AllToAll schedule selection.
//!
//! Both the training layer ([`crate::moe::MoeLayer`] in ragged dispatch
//! mode) and the serving router ([`crate::serve::PlacementRouter`])
//! face the same decision every step: given the per-(src, dst) rank
//! traffic matrix of a dispatch plan, is the flat or the hierarchical
//! AllToAll schedule cheaper *for this step's actual counts*? This
//! module is the one place that decision lives, so training and serving
//! can never drift apart: both legs of the round trip are scored (the
//! combine leg on the **transposed** matrix, since every flow reverses
//! and expert skew makes the two directions cost very different
//! amounts), and the cheaper total wins under [`CommChoice::Auto`].

use crate::cluster::NetworkModel;
use crate::comm::alltoall::alltoallv_timing;
use crate::comm::hier_ragged::DedupTraffic;
use crate::comm::hierarchical::{
    hierarchical_alltoallv_timing, hierarchical_alltoallv_timing_with,
};
use crate::error::Result;

/// One concrete AllToAll schedule (the thing actually executed).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Schedule {
    Flat,
    Hierarchical,
}

impl Schedule {
    pub fn name(&self) -> &'static str {
        match self {
            Schedule::Flat => "flat",
            Schedule::Hierarchical => "hier",
        }
    }
}

/// AllToAll selection policy: force one schedule, or score both per
/// step/batch and take the cheaper one.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommChoice {
    Flat,
    Hierarchical,
    /// Score both schedules on the step's traffic matrix and take the
    /// cheaper one.
    Auto,
}

impl CommChoice {
    pub fn parse(s: &str) -> Result<CommChoice> {
        Ok(match s.to_lowercase().as_str() {
            "flat" => CommChoice::Flat,
            "hier" | "hierarchical" => CommChoice::Hierarchical,
            "auto" => CommChoice::Auto,
            other => {
                return Err(crate::config_err!("unknown comm choice '{other}'"));
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            CommChoice::Flat => "flat",
            CommChoice::Hierarchical => "hier",
            CommChoice::Auto => "auto",
        }
    }
}

/// Outcome of scoring one step's traffic matrix under both schedules.
#[derive(Clone, Debug)]
pub struct SchedulePick {
    /// The schedule to execute (forced by the policy, or the cheaper
    /// round trip under [`CommChoice::Auto`]).
    pub schedule: Schedule,
    /// Predicted dispatch-leg time of the chosen schedule.
    pub dispatch_time: f64,
    /// Predicted combine-leg time of the chosen schedule (charged on
    /// the transposed traffic matrix).
    pub combine_time: f64,
    /// Round-trip (dispatch + combine) predicted times per schedule.
    pub flat_time: f64,
    pub hier_time: f64,
}

/// Transpose a rank traffic matrix (the combine leg reverses every flow).
pub fn transpose_counts(counts: &[Vec<usize>]) -> Vec<Vec<usize>> {
    let w = counts.len();
    (0..w).map(|d| (0..w).map(|s| counts[s][d]).collect()).collect()
}

/// Score `counts[src][dst]` rows of `elem_bytes` under both schedules
/// and pick per `choice` (see module docs). This is the exact decision
/// procedure of the serving router, shared with the training layer.
///
/// **Tie-break (normative):** the hierarchical schedule wins only on a
/// *strictly* lower round-trip prediction; an exact cost tie picks
/// `Flat`. The rule matters because training and serving evaluate this
/// function independently on the same counts — with a deterministic
/// tie-break the two picks can never disagree on a tied step (the
/// single-node degenerate case, where both schedules reduce to the same
/// intra-node exchange, ties on every step).
pub fn pick_schedule(
    net: &NetworkModel,
    counts: &[Vec<usize>],
    elem_bytes: usize,
    choice: CommChoice,
) -> SchedulePick {
    pick_schedule_dedup(net, counts, elem_bytes, choice, None)
}

/// [`pick_schedule`] with dedup-aware hierarchical costing: when the
/// step's [`DedupTraffic`] is provided, the hierarchical dispatch leg is
/// charged for what the deduplicated leader blocks actually push through
/// the NIC (unique payload rows + replication index, adaptively per
/// block) instead of every replica row. The combine leg stays full-rate
/// — the forward return carries distinct per-slot expert outputs (see
/// `comm::hier_ragged` module docs). Flat costing never changes: the
/// flat schedule ships replicas point-to-point and has no aggregation
/// point to dedup at. Same tie-break as [`pick_schedule`].
pub fn pick_schedule_dedup(
    net: &NetworkModel,
    counts: &[Vec<usize>],
    elem_bytes: usize,
    choice: CommChoice,
    dedup: Option<&DedupTraffic>,
) -> SchedulePick {
    let counts_t = transpose_counts(counts);
    let flat_dispatch = alltoallv_timing(net, counts, elem_bytes).total;
    let flat_combine = alltoallv_timing(net, &counts_t, elem_bytes).total;
    let hier_dispatch = match dedup {
        Some(t) => {
            let inter = t.dispatch_inter_bytes(elem_bytes);
            hierarchical_alltoallv_timing_with(net, counts, elem_bytes, Some(&inter)).total
        }
        None => hierarchical_alltoallv_timing(net, counts, elem_bytes).total,
    };
    let hier_combine = hierarchical_alltoallv_timing(net, &counts_t, elem_bytes).total;
    let flat_time = flat_dispatch + flat_combine;
    let hier_time = hier_dispatch + hier_combine;
    let schedule = match choice {
        CommChoice::Flat => Schedule::Flat,
        CommChoice::Hierarchical => Schedule::Hierarchical,
        // Strictly-less: ties resolve to Flat, deterministically.
        CommChoice::Auto => {
            if hier_time < flat_time {
                Schedule::Hierarchical
            } else {
                Schedule::Flat
            }
        }
    };
    let (dispatch_time, combine_time) = match schedule {
        Schedule::Flat => (flat_dispatch, flat_combine),
        Schedule::Hierarchical => (hier_dispatch, hier_combine),
    };
    SchedulePick { schedule, dispatch_time, combine_time, flat_time, hier_time }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn net(nodes: usize, gpus: usize) -> NetworkModel {
        let mut cfg = ClusterConfig::commodity(nodes);
        cfg.gpus_per_node = gpus;
        NetworkModel::new(cfg)
    }

    #[test]
    fn parse_and_names() {
        assert_eq!(CommChoice::parse("flat").unwrap(), CommChoice::Flat);
        assert_eq!(CommChoice::parse("HIER").unwrap(), CommChoice::Hierarchical);
        assert_eq!(CommChoice::parse("auto").unwrap(), CommChoice::Auto);
        assert!(CommChoice::parse("nonsense").is_err());
        assert_eq!(Schedule::Flat.name(), "flat");
        assert_eq!(Schedule::Hierarchical.name(), "hier");
    }

    #[test]
    fn transpose_is_involution() {
        let counts: Vec<Vec<usize>> =
            (0..4).map(|s| (0..4).map(|d| s * 10 + d).collect()).collect();
        assert_eq!(transpose_counts(&transpose_counts(&counts)), counts);
        assert_eq!(transpose_counts(&counts)[2][1], counts[1][2]);
    }

    #[test]
    fn auto_picks_the_cheaper_round_trip() {
        let m = net(4, 8);
        let w = m.cfg.world();
        // Serving-scale small messages: aggregation must win.
        let small = vec![vec![2usize; w]; w];
        let p = pick_schedule(&m, &small, 256, CommChoice::Auto);
        assert_eq!(p.schedule, Schedule::Hierarchical);
        assert!(p.hier_time < p.flat_time);
        assert!((p.dispatch_time + p.combine_time - p.hier_time).abs() < 1e-12);
    }

    #[test]
    fn forced_choices_report_their_own_legs() {
        let m = net(2, 2);
        let counts = vec![vec![8usize; 4]; 4];
        let f = pick_schedule(&m, &counts, 64, CommChoice::Flat);
        assert_eq!(f.schedule, Schedule::Flat);
        assert!((f.dispatch_time + f.combine_time - f.flat_time).abs() < 1e-12);
        let h = pick_schedule(&m, &counts, 64, CommChoice::Hierarchical);
        assert_eq!(h.schedule, Schedule::Hierarchical);
        assert!((h.dispatch_time + h.combine_time - h.hier_time).abs() < 1e-12);
        // Both report the same cross-schedule predictions.
        assert_eq!(f.flat_time, h.flat_time);
        assert_eq!(f.hier_time, h.hier_time);
    }

    #[test]
    fn tie_breaks_to_flat_deterministically() {
        // Single node: both schedules degenerate to the identical
        // intra-node exchange — an exact cost tie on every step. The
        // documented tie-break must pick Flat, always.
        let m = net(1, 4);
        let counts = vec![vec![16usize; 4]; 4];
        let p = pick_schedule(&m, &counts, 256, CommChoice::Auto);
        assert!(
            (p.flat_time - p.hier_time).abs() < 1e-15,
            "single node must tie: flat {} vs hier {}",
            p.flat_time,
            p.hier_time
        );
        assert_eq!(p.schedule, Schedule::Flat, "ties resolve to Flat");
        // And the tie-break is stable across repeated evaluation (the
        // training layer and the serving router call this separately).
        for _ in 0..8 {
            assert_eq!(
                pick_schedule(&m, &counts, 256, CommChoice::Auto).schedule,
                Schedule::Flat
            );
        }
    }

    #[test]
    fn dedup_costing_lowers_only_the_hier_side() {
        use crate::comm::hier_ragged::DedupTraffic;
        let m = net(2, 2);
        let counts = vec![vec![6usize; 4]; 4];
        let base = pick_schedule(&m, &counts, 256, CommChoice::Auto);
        // Node-level summary consistent with `counts` (24 rows per node
        // pair) where half the replica rows dedup away.
        let t = DedupTraffic {
            gpus_per_node: 2,
            rows: vec![vec![24, 24], vec![24, 24]],
            payloads: vec![vec![12, 12], vec![12, 12]],
            heads: vec![vec![24, 24], vec![24, 24]],
            packed_index: false,
        };
        let deduped = pick_schedule_dedup(&m, &counts, 256, CommChoice::Auto, Some(&t));
        assert_eq!(deduped.flat_time, base.flat_time, "flat never dedups");
        assert!(deduped.hier_time < base.hier_time, "dedup must cut the hier cost");
    }

    #[test]
    fn skewed_traffic_flips_legs() {
        // Fan-in to one rank: dispatch cheap, combine serializes.
        let m = net(1, 4);
        let mut counts = vec![vec![0usize; 4]; 4];
        counts[1][0] = 50;
        counts[2][0] = 50;
        counts[3][0] = 50;
        let p = pick_schedule(&m, &counts, 256, CommChoice::Flat);
        assert!(p.combine_time > p.dispatch_time);
    }
}
