//! Typed configuration system.
//!
//! Configs load from JSON files (see `configs/` at the repo root for
//! presets) or from built-in presets; every field is validated before a
//! run starts so misconfigurations fail fast at the CLI boundary rather
//! than deep in a collective.

use crate::error::{HetuError, Result};
use crate::util::json::Json;

/// Which gating strategy to run (the paper's Figure 2 feature matrix).
#[derive(Clone, Debug, PartialEq)]
pub enum GateKind {
    /// Switch Transformer: top-1 with capacity factor + auxiliary loss.
    Switch,
    /// GShard: top-2 with capacity factor.
    GShard,
    /// Generic top-k.
    TopK { k: usize },
    /// M6-T: experts split into `k` prototypes, top-1 within each.
    KTop1 { k: usize },
    /// SAM: hierarchical — switch over `groups`, top-`k` within the group.
    SamHTopK { groups: usize, k: usize },
    /// BASE layer: balanced linear assignment (auction algorithm).
    Base,
    /// Hash layer: deterministic token→expert hash.
    Hash { scheme: HashScheme },
    /// Dense-to-Sparse: Gumbel-softmax with temperature annealing.
    DenseToSparse { tau0: f64, tau_min: f64, anneal_steps: u64 },
}

/// Hash-layer variants (Roller et al., 2021).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum HashScheme {
    Random,
    Balanced,
    Clustered,
}

impl GateKind {
    /// Parse from the JSON config form, e.g.
    /// `{"gate": "switch"}` or `{"gate": "topk", "k": 4}`.
    pub fn from_json(obj: &Json) -> Result<GateKind> {
        let name = obj.str_or("gate", "switch").to_lowercase();
        Ok(match name.as_str() {
            "switch" | "top1" => GateKind::Switch,
            "gshard" | "top2" => GateKind::GShard,
            "topk" => GateKind::TopK { k: obj.usize_or("k", 2) },
            "ktop1" | "m6" => GateKind::KTop1 { k: obj.usize_or("k", 2) },
            "sam" | "htopk" => GateKind::SamHTopK {
                groups: obj.usize_or("groups", 4),
                k: obj.usize_or("k", 2),
            },
            "base" => GateKind::Base,
            "hash" => GateKind::Hash {
                scheme: match obj.str_or("scheme", "random") {
                    "balanced" => HashScheme::Balanced,
                    "clustered" => HashScheme::Clustered,
                    _ => HashScheme::Random,
                },
            },
            "dense_to_sparse" | "d2s" => GateKind::DenseToSparse {
                tau0: obj.f64_or("tau0", 2.0),
                tau_min: obj.f64_or("tau_min", 0.1),
                anneal_steps: obj.f64_or("anneal_steps", 10_000.0) as u64,
            },
            other => {
                return Err(HetuError::Config(format!("unknown gate '{other}'")));
            }
        })
    }

    /// Short display name used in tables.
    pub fn name(&self) -> String {
        match self {
            GateKind::Switch => "switch".into(),
            GateKind::GShard => "gshard".into(),
            GateKind::TopK { k } => format!("top{k}"),
            GateKind::KTop1 { k } => format!("{k}top1"),
            GateKind::SamHTopK { groups, k } => format!("sam_g{groups}k{k}"),
            GateKind::Base => "base".into(),
            GateKind::Hash { scheme } => format!("hash_{scheme:?}").to_lowercase(),
            GateKind::DenseToSparse { .. } => "dense_to_sparse".into(),
        }
    }
}

/// MoE layer configuration (the paper's benchmark layer defaults:
/// 16 experts, hidden 2048, embedding 2048, sequence 1024).
#[derive(Clone, Debug)]
pub struct MoeConfig {
    pub num_experts: usize,
    pub d_model: usize,
    pub ffn_hidden: usize,
    pub capacity_factor: f64,
    pub gate: GateKind,
}

impl MoeConfig {
    pub fn paper_layer() -> MoeConfig {
        MoeConfig {
            num_experts: 16,
            d_model: 2048,
            ffn_hidden: 2048,
            capacity_factor: 1.25,
            gate: GateKind::Switch,
        }
    }

    /// Scaled-down layer for CPU-bound benches (same expert count and
    /// shape ratios as the paper layer).
    pub fn bench_layer() -> MoeConfig {
        MoeConfig {
            num_experts: 16,
            d_model: 256,
            ffn_hidden: 256,
            capacity_factor: 1.25,
            gate: GateKind::Switch,
        }
    }

    pub fn tiny() -> MoeConfig {
        MoeConfig {
            num_experts: 4,
            d_model: 16,
            ffn_hidden: 32,
            capacity_factor: 1.5,
            gate: GateKind::Switch,
        }
    }

    pub fn from_json(obj: &Json) -> Result<MoeConfig> {
        let cfg = MoeConfig {
            num_experts: obj.usize_or("num_experts", 16),
            d_model: obj.usize_or("d_model", 2048),
            ffn_hidden: obj.usize_or("ffn_hidden", 2048),
            capacity_factor: obj.f64_or("capacity_factor", 1.25),
            gate: GateKind::from_json(obj)?,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.num_experts == 0 {
            return Err(HetuError::Config("num_experts must be > 0".into()));
        }
        if self.d_model == 0 || self.ffn_hidden == 0 {
            return Err(HetuError::Config("d_model/ffn_hidden must be > 0".into()));
        }
        if self.capacity_factor <= 0.0 {
            return Err(HetuError::Config("capacity_factor must be > 0".into()));
        }
        match &self.gate {
            GateKind::TopK { k } | GateKind::KTop1 { k } if *k == 0 => {
                return Err(HetuError::Config("k must be > 0".into()));
            }
            GateKind::TopK { k } if *k > self.num_experts => {
                return Err(HetuError::Config(format!(
                    "k={k} exceeds num_experts={}",
                    self.num_experts
                )));
            }
            GateKind::KTop1 { k } if self.num_experts % *k != 0 => {
                return Err(HetuError::Config(format!(
                    "kTop1 needs num_experts divisible by k ({} % {k} != 0)",
                    self.num_experts
                )));
            }
            GateKind::SamHTopK { groups, k } => {
                if *groups == 0 || self.num_experts % *groups != 0 {
                    return Err(HetuError::Config(format!(
                        "SAM needs num_experts divisible by groups ({} % {groups})",
                        self.num_experts
                    )));
                }
                if *k > self.num_experts / *groups {
                    return Err(HetuError::Config(format!(
                        "SAM k={k} exceeds experts per group {}",
                        self.num_experts / *groups
                    )));
                }
            }
            _ => {}
        }
        Ok(())
    }

    /// Expert capacity for `tokens` inputs: `ceil(tokens/E * factor)`,
    /// scaled by the number of expert slots each token consumes.
    pub fn capacity(&self, tokens: usize) -> usize {
        let k = match &self.gate {
            GateKind::Switch | GateKind::Base | GateKind::Hash { .. } => 1,
            GateKind::GShard => 2,
            GateKind::TopK { k } | GateKind::KTop1 { k } => *k,
            GateKind::SamHTopK { k, .. } => *k,
            GateKind::DenseToSparse { .. } => 2,
        };
        (((tokens * k) as f64 / self.num_experts as f64) * self.capacity_factor)
            .ceil()
            .max(1.0) as usize
    }
}

/// Cluster topology + link performance (the simulator's ground truth).
///
/// Defaults model the paper's commodity setting: PCIe ~12 GB/s intra-node,
/// one 100 Gbps NIC per node, with realistic per-message launch latencies.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    pub nodes: usize,
    pub gpus_per_node: usize,
    /// Intra-node (PCIe/NVLink) bandwidth per GPU pair, bytes/sec.
    pub intra_bw: f64,
    /// Inter-node NIC bandwidth, bytes/sec (shared by the node).
    pub inter_bw: f64,
    /// Per-message launch latency intra-node, seconds.
    pub intra_lat: f64,
    /// Per-message latency inter-node, seconds.
    pub inter_lat: f64,
    /// NICs per node (the paper's commodity cluster has 1).
    pub nics_per_node: usize,
    /// On-device memory bandwidth (bytes/sec) — charges the on-GPU layout
    /// transform / message-aggregation copies of hierarchical AllToAll.
    pub gpu_mem_bw: f64,
    /// Small-message bandwidth penalty constant (bytes): a message of size
    /// `m` achieves `bw * m / (m + msg_bw_const)` effective bandwidth.
    /// Calibrated against NCCL busbw curves (≈0.33× peak at 0.5 MiB,
    /// ≈0.95× peak at 32 MiB over 100 Gbps RoCE).
    pub msg_bw_const: f64,
    /// Effective aggregate intra-node bandwidth for the gather/scatter
    /// phases of hierarchical AllToAll (PCIe-switch fabric aggregate,
    /// higher than a single pairwise link).
    pub intra_gather_bw: f64,
}

impl ClusterConfig {
    /// The paper's evaluation cluster: 8 GPUs per node over PCIe, 1 NIC.
    pub fn commodity(nodes: usize) -> ClusterConfig {
        ClusterConfig {
            nodes,
            gpus_per_node: 8,
            intra_bw: 12.0e9,   // PCIe 3.0 x16 practical
            inter_bw: 12.5e9,   // 100 Gbps
            intra_lat: 3.0e-6,  // ~3 µs kernel/copy launch
            inter_lat: 20.0e-6, // ~20 µs RDMA/TCP message setup
            nics_per_node: 1,
            gpu_mem_bw: 600.0e9,    // TITAN RTX HBM-class
            msg_bw_const: 1.0e6,    // ~1 MiB half-peak message size
            intra_gather_bw: 25.0e9, // PCIe switch fabric aggregate
        }
    }

    /// NVLink "hypercluster" for contrast experiments.
    pub fn hypercluster(nodes: usize) -> ClusterConfig {
        ClusterConfig {
            nodes,
            gpus_per_node: 8,
            intra_bw: 300.0e9, // NVLink
            inter_bw: 50.0e9,  // 8×50 Gbps HDR per node aggregated
            intra_lat: 2.0e-6,
            inter_lat: 5.0e-6,
            nics_per_node: 8,
            gpu_mem_bw: 1500.0e9,
            msg_bw_const: 0.25e6,
            intra_gather_bw: 250.0e9,
        }
    }

    pub fn from_json(obj: &Json) -> Result<ClusterConfig> {
        let cfg = ClusterConfig {
            nodes: obj.usize_or("nodes", 1),
            gpus_per_node: obj.usize_or("gpus_per_node", 8),
            intra_bw: obj.f64_or("intra_bw_gbps", 96.0) * 1e9 / 8.0,
            inter_bw: obj.f64_or("inter_bw_gbps", 100.0) * 1e9 / 8.0,
            intra_lat: obj.f64_or("intra_lat_us", 3.0) * 1e-6,
            inter_lat: obj.f64_or("inter_lat_us", 20.0) * 1e-6,
            nics_per_node: obj.usize_or("nics_per_node", 1),
            gpu_mem_bw: obj.f64_or("gpu_mem_bw_gbps", 4800.0) * 1e9 / 8.0,
            msg_bw_const: obj.f64_or("msg_bw_const_mib", 1.0) * 1024.0 * 1024.0,
            intra_gather_bw: obj.f64_or("intra_gather_bw_gbps", 200.0) * 1e9 / 8.0,
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.nodes == 0 || self.gpus_per_node == 0 {
            return Err(HetuError::Config("nodes/gpus_per_node must be > 0".into()));
        }
        if self.intra_bw <= 0.0 || self.inter_bw <= 0.0 {
            return Err(HetuError::Config("bandwidths must be > 0".into()));
        }
        if self.nics_per_node == 0 {
            return Err(HetuError::Config("nics_per_node must be > 0".into()));
        }
        Ok(())
    }

    /// Total GPU (rank) count.
    pub fn world(&self) -> usize {
        self.nodes * self.gpus_per_node
    }

    /// Node index of a rank.
    pub fn node_of(&self, rank: usize) -> usize {
        rank / self.gpus_per_node
    }

    /// Local index of a rank inside its node.
    pub fn local_of(&self, rank: usize) -> usize {
        rank % self.gpus_per_node
    }
}

/// Training-run configuration for the end-to-end driver.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub steps: u64,
    pub batch_size: usize,
    pub seq_len: usize,
    pub log_every: u64,
    pub seed: u64,
    pub artifact_dir: String,
    /// Which artifact variant to train (see `python/compile/aot.py`).
    pub model: String,
}

impl TrainConfig {
    pub fn default_run() -> TrainConfig {
        TrainConfig {
            steps: 300,
            batch_size: 8,
            seq_len: 128,
            log_every: 10,
            seed: 0,
            artifact_dir: "artifacts".into(),
            model: "e2e".into(),
        }
    }

    pub fn from_json(obj: &Json) -> Result<TrainConfig> {
        Ok(TrainConfig {
            steps: obj.f64_or("steps", 300.0) as u64,
            batch_size: obj.usize_or("batch_size", 8),
            seq_len: obj.usize_or("seq_len", 128),
            log_every: obj.f64_or("log_every", 10.0) as u64,
            seed: obj.f64_or("seed", 0.0) as u64,
            artifact_dir: obj.str_or("artifact_dir", "artifacts").to_string(),
            model: obj.str_or("model", "e2e").to_string(),
        })
    }
}

/// Load a JSON config file and dispatch sections.
pub struct ConfigFile {
    pub root: Json,
}

impl ConfigFile {
    pub fn load(path: &str) -> Result<ConfigFile> {
        Ok(ConfigFile { root: Json::from_file(path)? })
    }

    pub fn moe(&self) -> Result<MoeConfig> {
        match self.root.get("moe") {
            Some(o) => MoeConfig::from_json(o),
            None => MoeConfig::from_json(&self.root),
        }
    }

    pub fn cluster(&self) -> Result<ClusterConfig> {
        match self.root.get("cluster") {
            Some(o) => ClusterConfig::from_json(o),
            None => Ok(ClusterConfig::commodity(1)),
        }
    }

    pub fn train(&self) -> Result<TrainConfig> {
        match self.root.get("train") {
            Some(o) => TrainConfig::from_json(o),
            None => Ok(TrainConfig::default_run()),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gate_kind_parsing() {
        let j = Json::parse(r#"{"gate": "gshard"}"#).unwrap();
        assert_eq!(GateKind::from_json(&j).unwrap(), GateKind::GShard);
        let j = Json::parse(r#"{"gate": "topk", "k": 4}"#).unwrap();
        assert_eq!(GateKind::from_json(&j).unwrap(), GateKind::TopK { k: 4 });
        let j = Json::parse(r#"{"gate": "hash", "scheme": "balanced"}"#).unwrap();
        assert_eq!(
            GateKind::from_json(&j).unwrap(),
            GateKind::Hash { scheme: HashScheme::Balanced }
        );
        let j = Json::parse(r#"{"gate": "martian"}"#).unwrap();
        assert!(GateKind::from_json(&j).is_err());
    }

    #[test]
    fn moe_validation() {
        let mut cfg = MoeConfig::paper_layer();
        assert!(cfg.validate().is_ok());
        cfg.gate = GateKind::TopK { k: 99 };
        assert!(cfg.validate().is_err());
        cfg.gate = GateKind::KTop1 { k: 3 }; // 16 % 3 != 0
        assert!(cfg.validate().is_err());
        cfg.gate = GateKind::SamHTopK { groups: 4, k: 2 };
        assert!(cfg.validate().is_ok());
        cfg.gate = GateKind::SamHTopK { groups: 5, k: 2 };
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn capacity_math() {
        let cfg = MoeConfig { capacity_factor: 1.0, ..MoeConfig::paper_layer() };
        // 1024 tokens, 16 experts, top-1, cf=1 → 64 per expert.
        assert_eq!(cfg.capacity(1024), 64);
        let cfg2 = MoeConfig { gate: GateKind::GShard, ..cfg.clone() };
        assert_eq!(cfg2.capacity(1024), 128); // top-2 doubles slots
        let cfg3 = MoeConfig { capacity_factor: 1.25, ..cfg };
        assert_eq!(cfg3.capacity(1024), 80);
    }

    #[test]
    fn cluster_rank_math() {
        let c = ClusterConfig::commodity(4);
        assert_eq!(c.world(), 32);
        assert_eq!(c.node_of(0), 0);
        assert_eq!(c.node_of(8), 1);
        assert_eq!(c.local_of(13), 5);
    }

    #[test]
    fn cluster_json_units() {
        let j = Json::parse(
            r#"{"nodes": 2, "gpus_per_node": 4, "inter_bw_gbps": 100, "inter_lat_us": 20}"#,
        )
        .unwrap();
        let c = ClusterConfig::from_json(&j).unwrap();
        assert_eq!(c.nodes, 2);
        assert!((c.inter_bw - 12.5e9).abs() < 1.0);
        assert!((c.inter_lat - 20e-6).abs() < 1e-12);
    }

    #[test]
    fn config_file_sections() {
        let text = r#"{
            "moe": {"num_experts": 8, "d_model": 64, "ffn_hidden": 128, "gate": "gshard"},
            "cluster": {"nodes": 2, "gpus_per_node": 2},
            "train": {"steps": 5, "batch_size": 2}
        }"#;
        let cf = ConfigFile { root: Json::parse(text).unwrap() };
        let moe = cf.moe().unwrap();
        assert_eq!(moe.num_experts, 8);
        assert_eq!(moe.gate, GateKind::GShard);
        assert_eq!(cf.cluster().unwrap().world(), 4);
        assert_eq!(cf.train().unwrap().steps, 5);
    }

    #[test]
    fn gate_names() {
        assert_eq!(GateKind::Switch.name(), "switch");
        assert_eq!(GateKind::TopK { k: 3 }.name(), "top3");
        assert_eq!(GateKind::KTop1 { k: 2 }.name(), "2top1");
    }
}
