//! Aggregation of per-step phase timings into run-level breakdowns.

use crate::moe::StepReport;
use crate::util::json::Json;
use std::collections::HashMap;

/// Accumulated phase totals over a run.
#[derive(Clone, Debug, Default)]
pub struct MetricsAgg {
    steps: usize,
    wall: HashMap<String, f64>,
    comm: HashMap<String, f64>,
    wall_order: Vec<String>,
    comm_order: Vec<String>,
    pub drop_rate: f64,
    pub padding_waste: f64,
    pub aux_loss: f64,
    bytes_on_wire: f64,
    bytes_on_wire_bwd: f64,
    bytes_intra_node: f64,
    bytes_intra_node_bwd: f64,
    rows_deduped: f64,
    wire: String,
    expert_flops: f64,
    critical_path: f64,
    comm_exposed: f64,
    compute_exposed: f64,
    comm_hidden: f64,
    injected_delay: f64,
    faults_injected: usize,
    retries: usize,
    // Per-step extremes (means average away burst regressions, so the
    // aggregation keeps min/max too; not Welford, whose derived
    // Default would seed min/max at 0.0).
    critical_path_min: f64,
    critical_path_max: f64,
    comm_exposed_min: f64,
    comm_exposed_max: f64,
}

impl MetricsAgg {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, report: &StepReport) {
        if self.steps == 0 {
            self.critical_path_min = report.critical_path;
            self.critical_path_max = report.critical_path;
            self.comm_exposed_min = report.comm_exposed;
            self.comm_exposed_max = report.comm_exposed;
        } else {
            self.critical_path_min = self.critical_path_min.min(report.critical_path);
            self.critical_path_max = self.critical_path_max.max(report.critical_path);
            self.comm_exposed_min = self.comm_exposed_min.min(report.comm_exposed);
            self.comm_exposed_max = self.comm_exposed_max.max(report.comm_exposed);
        }
        self.steps += 1;
        for (name, t) in &report.wall {
            if !self.wall.contains_key(name) {
                self.wall_order.push(name.clone());
            }
            *self.wall.entry(name.clone()).or_insert(0.0) += t;
        }
        for (name, t) in &report.comm {
            if !self.comm.contains_key(name) {
                self.comm_order.push(name.clone());
            }
            *self.comm.entry(name.clone()).or_insert(0.0) += t;
        }
        self.drop_rate += report.drop_rate;
        self.padding_waste += report.padding_waste;
        self.aux_loss += report.aux_loss;
        self.bytes_on_wire += report.bytes_on_wire as f64;
        self.bytes_on_wire_bwd += report.bytes_on_wire_bwd as f64;
        self.bytes_intra_node += report.bytes_intra_node as f64;
        self.bytes_intra_node_bwd += report.bytes_intra_node_bwd as f64;
        self.rows_deduped += report.rows_deduped as f64;
        if !report.wire.is_empty() {
            self.wire = report.wire.clone();
        }
        self.expert_flops += report.expert_flops;
        self.critical_path += report.critical_path;
        self.comm_exposed += report.comm_exposed;
        self.compute_exposed += report.compute_exposed;
        self.comm_hidden += report.comm_hidden;
        self.injected_delay += report.injected_delay;
        self.faults_injected += report.faults_injected;
        self.retries += report.retries;
    }

    pub fn steps(&self) -> usize {
        self.steps
    }

    /// Mean-per-step breakdown, wall phases then comm phases, with
    /// fractions of the combined total.
    pub fn breakdown(&self) -> Breakdown {
        let n = self.steps.max(1) as f64;
        let mut phases: Vec<(String, f64)> = Vec::new();
        for name in &self.wall_order {
            phases.push((name.clone(), self.wall[name] / n));
        }
        for name in &self.comm_order {
            phases.push((name.clone(), self.comm[name] / n));
        }
        let total: f64 = phases.iter().map(|(_, t)| t).sum();
        let exchange = self.comm_hidden + self.comm_exposed;
        Breakdown {
            phases,
            total,
            drop_rate: self.drop_rate / n,
            padding_waste: self.padding_waste / n,
            aux_loss: self.aux_loss / n,
            bytes_on_wire: self.bytes_on_wire / n,
            bytes_on_wire_bwd: self.bytes_on_wire_bwd / n,
            bytes_intra_node: self.bytes_intra_node / n,
            bytes_intra_node_bwd: self.bytes_intra_node_bwd / n,
            rows_deduped: self.rows_deduped / n,
            wire: self.wire.clone(),
            expert_flops: self.expert_flops / n,
            critical_path: self.critical_path / n,
            critical_path_min: self.critical_path_min,
            critical_path_max: self.critical_path_max,
            comm_exposed: self.comm_exposed / n,
            comm_exposed_min: self.comm_exposed_min,
            comm_exposed_max: self.comm_exposed_max,
            compute_exposed: self.compute_exposed / n,
            comm_hidden: self.comm_hidden / n,
            overlap_efficiency: if exchange > 0.0 {
                self.comm_hidden / exchange
            } else {
                0.0
            },
            injected_delay: self.injected_delay / n,
            faults_injected: self.faults_injected,
            retries: self.retries,
        }
    }
}

/// Per-step mean phase times.
#[derive(Clone, Debug)]
pub struct Breakdown {
    pub phases: Vec<(String, f64)>,
    pub total: f64,
    pub drop_rate: f64,
    pub padding_waste: f64,
    pub aux_loss: f64,
    /// Mean NIC (inter-node) bytes per step over both AllToAll legs —
    /// placement-aware: same-node cross-rank rows are *not* counted
    /// here (see `bytes_intra_node`); under hierarchical + dedup this
    /// is the post-deduplication figure.
    pub bytes_on_wire: f64,
    /// Mean NIC bytes on the backward AllToAll legs per step (0 when
    /// the run is forward-only).
    pub bytes_on_wire_bwd: f64,
    /// Mean intra-node fabric bytes per step over both forward legs.
    pub bytes_intra_node: f64,
    /// Mean intra-node fabric bytes per step over both backward legs.
    pub bytes_intra_node_bwd: f64,
    /// Mean replica rows per step the hierarchical dedup/pre-summation
    /// kept off the NIC (0 on flat schedules or with dedup off).
    pub rows_deduped: f64,
    /// Wire element format the run's ragged exchanges used ("f32" |
    /// "bf16" | "f16"; "" when no step reported one). The byte fields
    /// above are already denominated in this format's element size.
    pub wire: String,
    /// Mean expert-FFN FLOPs executed per step.
    pub expert_flops: f64,
    /// Mean modeled critical-path wall of the overlapped exchange/
    /// compute regions per step (see `StepReport::critical_path`).
    pub critical_path: f64,
    /// Fastest single step's critical path (0 on an empty run).
    pub critical_path_min: f64,
    /// Slowest single step's critical path — a burst that the mean
    /// averages away shows up here.
    pub critical_path_max: f64,
    /// Mean exchange time left on the critical path per step.
    pub comm_exposed: f64,
    /// Best single step's exposed-communication time.
    pub comm_exposed_min: f64,
    /// Worst single step's exposed-communication time.
    pub comm_exposed_max: f64,
    /// Mean expert compute left on the critical path per step.
    pub compute_exposed: f64,
    /// Mean exchange time hidden under expert compute per step.
    pub comm_hidden: f64,
    /// Fraction of all exchange time hidden under expert compute over
    /// the whole run (0 when every step ran unchunked).
    pub overlap_efficiency: f64,
    /// Mean injected fault delay per step (0 on a healthy run).
    pub injected_delay: f64,
    /// Injected fault events over the whole run (a count, not a mean).
    pub faults_injected: usize,
    /// Transient-failure retries charged over the whole run (a count).
    pub retries: usize,
}

impl Breakdown {
    /// Fraction of the step spent in phases whose name starts with any
    /// of `prefixes`.
    pub fn fraction_of(&self, prefixes: &[&str]) -> f64 {
        if self.total <= 0.0 {
            return 0.0;
        }
        let t: f64 = self
            .phases
            .iter()
            .filter(|(n, _)| prefixes.iter().any(|p| n.starts_with(p)))
            .map(|(_, t)| t)
            .sum();
        t / self.total
    }

    /// JSON export via the canonical schema module — every consumer
    /// (`--json` flags, the `metrics` harness, `BENCH_*.json`) sees the
    /// same field names (see `obs::schema`).
    pub fn to_json(&self) -> Json {
        crate::obs::schema::breakdown_json(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn report(gate: f64, a2a: f64) -> StepReport {
        StepReport {
            wall: vec![("gate".into(), gate), ("expert".into(), 1.0)],
            comm: vec![("alltoall_dispatch".into(), a2a)],
            drop_rate: 0.1,
            padding_waste: 0.2,
            expert_counts: vec![],
            aux_loss: 1.0,
            bytes_on_wire: 1024,
            bytes_intra_node: 512,
            rows_deduped: 3,
            expert_flops: 2048.0,
            ..Default::default()
        }
    }

    #[test]
    fn aggregates_means() {
        let mut agg = MetricsAgg::new();
        agg.push(&report(0.2, 0.4));
        agg.push(&report(0.4, 0.6));
        let b = agg.breakdown();
        assert_eq!(agg.steps(), 2);
        let gate = b.phases.iter().find(|(n, _)| n == "gate").unwrap().1;
        assert!((gate - 0.3).abs() < 1e-12);
        assert!((b.total - (0.3 + 1.0 + 0.5)).abs() < 1e-12);
        assert!((b.drop_rate - 0.1).abs() < 1e-12);
        assert!((b.bytes_on_wire - 1024.0).abs() < 1e-12);
        assert!((b.bytes_intra_node - 512.0).abs() < 1e-12);
        assert!((b.rows_deduped - 3.0).abs() < 1e-12);
        assert!((b.expert_flops - 2048.0).abs() < 1e-12);
    }

    #[test]
    fn aggregates_overlap_accounting() {
        let mut agg = MetricsAgg::new();
        let mut a = report(0.1, 0.5);
        a.comm_exposed = 0.2;
        a.comm_hidden = 0.3;
        a.compute_exposed = 1.0;
        a.critical_path = 1.2;
        let mut b = report(0.1, 0.5);
        b.comm_exposed = 0.5;
        b.comm_hidden = 0.0;
        b.compute_exposed = 1.0;
        b.critical_path = 1.5;
        agg.push(&a);
        agg.push(&b);
        let bd = agg.breakdown();
        assert!((bd.comm_exposed - 0.35).abs() < 1e-12);
        assert!((bd.comm_hidden - 0.15).abs() < 1e-12);
        assert!((bd.critical_path - 1.35).abs() < 1e-12);
        // Run-level efficiency = total hidden / total exchange time.
        assert!((bd.overlap_efficiency - 0.3 / 1.0).abs() < 1e-12);
    }

    #[test]
    fn min_max_survive_skewed_sequence() {
        // Nine fast steps and one burst: the mean hides the burst, the
        // max must not.
        let mut agg = MetricsAgg::new();
        for _ in 0..9 {
            let mut r = report(0.1, 0.5);
            r.critical_path = 1.0;
            r.comm_exposed = 0.1;
            agg.push(&r);
        }
        let mut burst = report(0.1, 0.5);
        burst.critical_path = 10.0;
        burst.comm_exposed = 4.0;
        agg.push(&burst);
        let b = agg.breakdown();
        assert!((b.critical_path - 1.9).abs() < 1e-12);
        assert_eq!(b.critical_path_min, 1.0);
        assert_eq!(b.critical_path_max, 10.0);
        assert!((b.comm_exposed - 0.49).abs() < 1e-12);
        assert_eq!(b.comm_exposed_min, 0.1);
        assert_eq!(b.comm_exposed_max, 4.0);
        // Empty run: extremes stay at their 0.0 defaults, not ±inf.
        let empty = MetricsAgg::new().breakdown();
        assert_eq!(empty.critical_path_min, 0.0);
        assert_eq!(empty.critical_path_max, 0.0);
    }

    #[test]
    fn fractions() {
        let mut agg = MetricsAgg::new();
        agg.push(&report(1.0, 2.0)); // gate 1, expert 1, a2a 2 → total 4
        let b = agg.breakdown();
        assert!((b.fraction_of(&["alltoall"]) - 0.5).abs() < 1e-12);
        assert!((b.fraction_of(&["gate", "alltoall"]) - 0.75).abs() < 1e-12);
        assert_eq!(b.fraction_of(&["nope"]), 0.0);
    }

    #[test]
    fn json_export() {
        let mut agg = MetricsAgg::new();
        agg.push(&report(1.0, 1.0));
        let j = agg.breakdown().to_json();
        assert!(j.get("phases").is_some());
        assert!(j.f64_field("total").unwrap() > 0.0);
        // The overlap metrics ride along in every JSON export (`train
        // --json`, `layer-bench --json`).
        assert!(j.get("comm_exposed").is_some());
        assert!(j.get("compute_exposed").is_some());
        assert!(j.get("overlap_efficiency").is_some());
        // The honest traffic split rides along in every JSON export.
        assert!(j.get("bytes_intra_node").is_some());
        assert!(j.get("bytes_intra_node_bwd").is_some());
        assert!(j.get("rows_deduped").is_some());
    }
}
