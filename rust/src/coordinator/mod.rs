//! The training coordinator: drives iterations of the expert-parallel
//! MoE pipeline across the simulated mesh, aggregates per-phase metrics
//! (Figure 1's breakdown), and exposes the leader-side run loop used by
//! the `hetumoe` binary and the benches.

pub mod metrics;
pub mod runner;

pub use metrics::{Breakdown, MetricsAgg};
pub use runner::{Coordinator, RunSummary};
