//! Leader run loop: embedding lookup → repeated MoE-layer iterations
//! over per-rank shards, with metrics aggregation.
//!
//! This is the benchmark-loop analog of the paper's evaluation driver
//! (the MoE *layer* is what every Fig-8 system comparison times); full
//! model training with losses and gradients runs through the native
//! [`crate::backprop::NativeTrainer`] (or the artifact-backed
//! `train::Trainer` behind the `pjrt` feature) instead.

use crate::config::{ClusterConfig, MoeConfig};
use crate::coordinator::metrics::{Breakdown, MetricsAgg};
use crate::data::{BatchIter, SyntheticLm};
use crate::error::Result;
use crate::moe::{MoeLayer, MoeLayerOptions};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// End-of-run summary.
#[derive(Clone, Debug)]
pub struct RunSummary {
    pub steps: usize,
    pub breakdown: Breakdown,
    /// Output norm of the last step (smoke signal that compute happened).
    pub last_output_norm: f64,
}

/// Drives repeated MoE-layer steps over synthetic token batches.
pub struct Coordinator {
    pub layer: MoeLayer,
    /// Embedding table `[vocab, d]` (host side — the coordinator embeds
    /// tokens before sharding, mirroring the model's lookup).
    pub embedding: Tensor,
    pub batches: BatchIter,
    pub tokens_per_rank: usize,
}

impl Coordinator {
    pub fn new(
        moe: MoeConfig,
        cluster: ClusterConfig,
        opts: MoeLayerOptions,
        vocab: usize,
        tokens_per_rank: usize,
        seed: u64,
    ) -> Result<Coordinator> {
        let mut rng = Rng::seed(seed ^ 0xC00D);
        let mut embedding = Tensor::randn(&[vocab, moe.d_model], &mut rng);
        embedding.scale(1.0 / (moe.d_model as f32).sqrt());
        let world = cluster.world();
        let layer = MoeLayer::native(moe, cluster, opts, seed)?;
        let task = SyntheticLm::new(vocab, 1.1, 0.85);
        let batches = BatchIter::new(task, world, tokens_per_rank, seed ^ 0xBA7C);
        Ok(Coordinator { layer, embedding, batches, tokens_per_rank })
    }

    /// Embed a flat token batch into per-rank shards.
    pub fn embed_shards(&self, tokens: &[u32]) -> Vec<Tensor> {
        let world = self.layer.cluster.world();
        let d = self.layer.cfg.d_model;
        let per = self.tokens_per_rank;
        assert_eq!(tokens.len(), world * per);
        (0..world)
            .map(|r| {
                let mut shard = Tensor::zeros(&[per, d]);
                for i in 0..per {
                    let tok = tokens[r * per + i] as usize % self.embedding.rows();
                    shard.row_mut(i).copy_from_slice(self.embedding.row(tok));
                }
                shard
            })
            .collect()
    }

    /// Run `steps` iterations; returns the aggregated summary.
    pub fn run(&mut self, steps: usize) -> Result<RunSummary> {
        let mut agg = MetricsAgg::new();
        let mut last_norm = 0.0f64;
        for _ in 0..steps {
            let (tokens, _targets) = self.batches.next_batch();
            let shards = self.embed_shards(&tokens);
            let (outputs, report) = self.layer.forward(&shards)?;
            agg.push(&report);
            last_norm = outputs.iter().map(|t| t.norm() as f64).sum();
        }
        Ok(RunSummary { steps, breakdown: agg.breakdown(), last_output_norm: last_norm })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GateKind;

    fn small() -> (MoeConfig, ClusterConfig) {
        (
            MoeConfig {
                num_experts: 4,
                d_model: 16,
                ffn_hidden: 32,
                capacity_factor: 1.5,
                gate: GateKind::Switch,
            },
            ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) },
        )
    }

    #[test]
    fn runs_steps_and_aggregates() {
        let (moe, cluster) = small();
        let mut coord =
            Coordinator::new(moe, cluster, MoeLayerOptions::default(), 64, 8, 0).unwrap();
        let summary = coord.run(3).unwrap();
        assert_eq!(summary.steps, 3);
        assert!(summary.breakdown.total > 0.0);
        assert!(summary.last_output_norm > 0.0);
        // All six phases present.
        let names: Vec<&str> =
            summary.breakdown.phases.iter().map(|(n, _)| n.as_str()).collect();
        for expect in ["gate", "layout", "expert", "reverse_layout", "alltoall_dispatch"] {
            assert!(names.contains(&expect), "missing {expect}: {names:?}");
        }
    }

    #[test]
    fn embedding_shards_are_lookup_rows() {
        let (moe, cluster) = small();
        let coord =
            Coordinator::new(moe, cluster, MoeLayerOptions::default(), 64, 4, 1).unwrap();
        let tokens: Vec<u32> = (0..16).collect();
        let shards = coord.embed_shards(&tokens);
        assert_eq!(shards.len(), 4);
        assert_eq!(shards[0].shape(), &[4, 16]);
        // Row 0 of shard 0 must equal embedding row of token 0.
        assert_eq!(shards[0].row(0), coord.embedding.row(0));
        assert_eq!(shards[3].row(3), coord.embedding.row(15));
    }
}
