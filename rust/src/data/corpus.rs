//! A tiny embedded text corpus + character tokenizer, for the examples
//! that want "real" (non-synthetic) text without network access.

/// ~2.5 KB of public-domain text (Shakespeare, sonnets 1-3 + Hamlet
/// fragment) — enough for a character-level LM smoke run.
pub const TINY_CORPUS: &str = "\
From fairest creatures we desire increase,
That thereby beauty's rose might never die,
But as the riper should by time decease,
His tender heir might bear his memory:
But thou contracted to thine own bright eyes,
Feed'st thy light's flame with self-substantial fuel,
Making a famine where abundance lies,
Thy self thy foe, to thy sweet self too cruel:
Thou that art now the world's fresh ornament,
And only herald to the gaudy spring,
Within thine own bud buriest thy content,
And, tender churl, mak'st waste in niggarding:
Pity the world, or else this glutton be,
To eat the world's due, by the grave and thee.
When forty winters shall besiege thy brow,
And dig deep trenches in thy beauty's field,
Thy youth's proud livery so gazed on now,
Will be a totter'd weed of small worth held:
Then being asked, where all thy beauty lies,
Where all the treasure of thy lusty days;
To say, within thine own deep sunken eyes,
Were an all-eating shame, and thriftless praise.
How much more praise deserv'd thy beauty's use,
If thou couldst answer 'This fair child of mine
Shall sum my count, and make my old excuse,'
Proving his beauty by succession thine!
This were to be new made when thou art old,
And see thy blood warm when thou feel'st it cold.
To be, or not to be, that is the question:
Whether 'tis nobler in the mind to suffer
The slings and arrows of outrageous fortune,
Or to take arms against a sea of troubles
And by opposing end them. To die: to sleep;
No more; and by a sleep to say we end
The heart-ache and the thousand natural shocks
That flesh is heir to, 'tis a consummation
Devoutly to be wish'd. To die, to sleep;
To sleep: perchance to dream: ay, there's the rub;
For in that sleep of death what dreams may come
When we have shuffled off this mortal coil,
Must give us pause: there's the respect
That makes calamity of so long life;
";

/// Character-level tokenizer over a fixed corpus alphabet.
#[derive(Clone, Debug)]
pub struct CharTokenizer {
    chars: Vec<char>,
    index: std::collections::HashMap<char, u32>,
}

impl CharTokenizer {
    /// Build from a corpus: vocabulary = sorted distinct characters.
    pub fn fit(text: &str) -> CharTokenizer {
        let mut chars: Vec<char> = text.chars().collect::<std::collections::BTreeSet<_>>()
            .into_iter()
            .collect();
        chars.sort_unstable();
        let index = chars.iter().enumerate().map(|(i, &c)| (c, i as u32)).collect();
        CharTokenizer { chars, index }
    }

    pub fn vocab_size(&self) -> usize {
        self.chars.len()
    }

    /// Encode text (unknown characters are skipped).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        text.chars().filter_map(|c| self.index.get(&c).copied()).collect()
    }

    /// Decode ids back to text.
    pub fn decode(&self, ids: &[u32]) -> String {
        ids.iter().map(|&i| self.chars[i as usize]).collect()
    }

    /// Contiguous (input, target) training pairs of length `seq` from the
    /// corpus, tiled with stride `seq`.
    pub fn training_pairs(&self, text: &str, seq: usize) -> Vec<(Vec<u32>, Vec<u32>)> {
        let ids = self.encode(text);
        let mut out = Vec::new();
        let mut i = 0;
        while i + seq + 1 <= ids.len() {
            out.push((ids[i..i + seq].to_vec(), ids[i + 1..i + seq + 1].to_vec()));
            i += seq;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let tok = CharTokenizer::fit(TINY_CORPUS);
        assert!(tok.vocab_size() > 20 && tok.vocab_size() < 128);
        let ids = tok.encode("To be, or not to be");
        assert_eq!(tok.decode(&ids), "To be, or not to be");
    }

    #[test]
    fn unknown_chars_skipped() {
        let tok = CharTokenizer::fit("abc");
        assert_eq!(tok.encode("aXbYc").len(), 3);
    }

    #[test]
    fn training_pairs_shift_by_one() {
        let tok = CharTokenizer::fit(TINY_CORPUS);
        let pairs = tok.training_pairs(TINY_CORPUS, 32);
        assert!(pairs.len() > 10);
        for (x, y) in &pairs {
            assert_eq!(x.len(), 32);
            assert_eq!(y.len(), 32);
            assert_eq!(x[1..], y[..31]);
        }
    }
}
