//! Synthetic and tiny-corpus data for training and benchmarks.

pub mod corpus;
pub mod synthetic;

pub use corpus::{CharTokenizer, TINY_CORPUS};
pub use synthetic::{BatchIter, ClusterTask, SyntheticLm};
