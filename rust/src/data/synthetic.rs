//! Synthetic language-model data: Zipf-distributed tokens with a
//! deterministic next-token structure so a model can actually reduce
//! loss (a pure-noise stream would bottom out at `ln(vocab)`), plus a
//! cluster-correlated feature/label task ([`ClusterTask`]) for the
//! native training loop's loss-curve tests.

use crate::tensor::Tensor;
use crate::util::rng::{hash_u64, Rng, Zipf};

/// A synthetic LM task: token `x_{t+1}` is a deterministic function of
/// `x_t` with probability `p_rule`, otherwise a fresh Zipf draw. The
/// learnable structure is the rule; the Zipf tail supplies realistic
/// imbalance for the MoE router.
#[derive(Clone, Debug)]
pub struct SyntheticLm {
    pub vocab: usize,
    zipf: Zipf,
    p_rule: f64,
    rule_salt: u64,
}

impl SyntheticLm {
    pub fn new(vocab: usize, zipf_s: f64, p_rule: f64) -> Self {
        SyntheticLm {
            vocab,
            zipf: Zipf::new(vocab, zipf_s),
            p_rule,
            rule_salt: 0x5EED,
        }
    }

    /// The deterministic successor rule.
    pub fn successor(&self, token: u32) -> u32 {
        (hash_u64(token as u64 ^ self.rule_salt) % self.vocab as u64) as u32
    }

    /// Generate a sequence of `len` tokens.
    pub fn sequence(&self, len: usize, rng: &mut Rng) -> Vec<u32> {
        let mut out = Vec::with_capacity(len);
        let mut cur = self.zipf.sample(rng) as u32;
        out.push(cur);
        for _ in 1..len {
            cur = if rng.next_f64() < self.p_rule {
                self.successor(cur)
            } else {
                self.zipf.sample(rng) as u32
            };
            out.push(cur);
        }
        out
    }
}

/// Batches of (inputs, targets) for next-token prediction.
pub struct BatchIter {
    task: SyntheticLm,
    rng: Rng,
    pub batch_size: usize,
    pub seq_len: usize,
}

impl BatchIter {
    pub fn new(task: SyntheticLm, batch_size: usize, seq_len: usize, seed: u64) -> Self {
        BatchIter { task, rng: Rng::seed(seed), batch_size, seq_len }
    }

    /// Next batch: `inputs[b*seq + t]`, `targets` shifted by one.
    pub fn next_batch(&mut self) -> (Vec<u32>, Vec<u32>) {
        let mut inputs = Vec::with_capacity(self.batch_size * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch_size * self.seq_len);
        for _ in 0..self.batch_size {
            let seq = self.task.sequence(self.seq_len + 1, &mut self.rng);
            inputs.extend_from_slice(&seq[..self.seq_len]);
            targets.extend_from_slice(&seq[1..]);
        }
        (inputs, targets)
    }
}

/// A *learnable* synthetic classification task: feature vectors drawn
/// around `num_clusters` seeded centroids (plus isotropic noise), label
/// = centroid index. Labels correlate with token clusters by
/// construction, so a model that routes cluster-mates to the same
/// expert and reads out a linear head must drive the loss down — the
/// deterministic substrate for the trainer's loss-curve tests.
///
/// Cluster frequencies are Zipf-tilted (like real token distributions),
/// so the MoE router sees realistic load imbalance and the auxiliary
/// loss has actual work to do.
#[derive(Clone, Debug)]
pub struct ClusterTask {
    /// Centroids `[C, d]`, fixed by the construction seed.
    pub centers: Tensor,
    pub num_clusters: usize,
    pub d: usize,
    /// Noise scale around each centroid.
    pub noise: f32,
    zipf: Zipf,
}

impl ClusterTask {
    /// Deterministic per seed: same seed → same centroids and, with the
    /// same sampling RNG, the same batches.
    pub fn new(num_clusters: usize, d: usize, noise: f32, seed: u64) -> ClusterTask {
        let mut rng = Rng::seed(seed ^ 0xC1A5);
        let mut centers = Tensor::randn(&[num_clusters, d], &mut rng);
        // Spread the centroids so clusters are separable at noise ~0.3.
        centers.scale(1.5);
        ClusterTask { centers, num_clusters, d, noise, zipf: Zipf::new(num_clusters, 1.1) }
    }

    /// Sample `n` (feature row, label) pairs into a `[n, d]` tensor and
    /// a label vector, advancing `rng`.
    pub fn sample(&self, n: usize, rng: &mut Rng) -> (Tensor, Vec<u32>) {
        let mut x = Tensor::zeros(&[n, self.d]);
        let mut labels = Vec::with_capacity(n);
        for i in 0..n {
            let c = self.zipf.sample(rng);
            labels.push(c as u32);
            let center = self.centers.row(c);
            let row = x.row_mut(i);
            for (v, &m) in row.iter_mut().zip(center) {
                *v = m + self.noise * rng.normal_f32();
            }
        }
        (x, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_follow_the_rule_mostly() {
        let task = SyntheticLm::new(100, 1.1, 0.9);
        let mut rng = Rng::seed(0);
        let seq = task.sequence(2000, &mut rng);
        let rule_hits = seq
            .windows(2)
            .filter(|w| w[1] == task.successor(w[0]))
            .count();
        let frac = rule_hits as f64 / (seq.len() - 1) as f64;
        assert!(frac > 0.85, "rule fraction {frac}");
        assert!(seq.iter().all(|&t| (t as usize) < 100));
    }

    #[test]
    fn batches_are_shifted_views() {
        let task = SyntheticLm::new(50, 1.0, 1.0); // fully deterministic
        let mut it = BatchIter::new(task.clone(), 2, 8, 1);
        let (x, y) = it.next_batch();
        assert_eq!(x.len(), 16);
        assert_eq!(y.len(), 16);
        for b in 0..2 {
            for t in 0..7 {
                assert_eq!(y[b * 8 + t], x[b * 8 + t + 1]);
            }
            // And every target is the rule successor (p_rule = 1).
            for t in 0..8 {
                assert_eq!(y[b * 8 + t], task.successor(x[b * 8 + t]));
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let t1 = SyntheticLm::new(64, 1.0, 0.8);
        let mut a = BatchIter::new(t1.clone(), 2, 4, 9);
        let mut b = BatchIter::new(t1, 2, 4, 9);
        assert_eq!(a.next_batch(), b.next_batch());
    }

    #[test]
    fn cluster_task_is_deterministic_per_seed() {
        let t1 = ClusterTask::new(4, 8, 0.3, 5);
        let t2 = ClusterTask::new(4, 8, 0.3, 5);
        assert_eq!(t1.centers, t2.centers);
        let mut r1 = Rng::seed(1);
        let mut r2 = Rng::seed(1);
        let (x1, y1) = t1.sample(32, &mut r1);
        let (x2, y2) = t2.sample(32, &mut r2);
        assert_eq!(x1, x2);
        assert_eq!(y1, y2);
        let t3 = ClusterTask::new(4, 8, 0.3, 6);
        assert_ne!(t1.centers, t3.centers);
    }

    #[test]
    fn cluster_features_hug_their_centroid() {
        let task = ClusterTask::new(4, 16, 0.1, 7);
        let mut rng = Rng::seed(2);
        let (x, labels) = task.sample(200, &mut rng);
        for i in 0..200 {
            let c = labels[i] as usize;
            assert!(c < 4);
            // Distance to own centroid must beat every other centroid.
            let dist = |center: &[f32], row: &[f32]| -> f32 {
                row.iter().zip(center).map(|(a, b)| (a - b) * (a - b)).sum()
            };
            let own = dist(task.centers.row(c), x.row(i));
            for other in 0..4 {
                if other != c {
                    assert!(own < dist(task.centers.row(other), x.row(i)));
                }
            }
        }
    }

    #[test]
    fn cluster_labels_are_zipf_skewed() {
        let task = ClusterTask::new(8, 4, 0.3, 11);
        let mut rng = Rng::seed(3);
        let (_, labels) = task.sample(4000, &mut rng);
        let mut counts = [0usize; 8];
        for &l in &labels {
            counts[l as usize] += 1;
        }
        assert!(counts[0] > counts[7], "head cluster must dominate: {counts:?}");
        assert!(counts.iter().all(|&c| c > 0));
    }
}
