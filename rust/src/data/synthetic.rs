//! Synthetic language-model data: Zipf-distributed tokens with a
//! deterministic next-token structure so a model can actually reduce
//! loss (a pure-noise stream would bottom out at `ln(vocab)`).

use crate::util::rng::{hash_u64, Rng, Zipf};

/// A synthetic LM task: token `x_{t+1}` is a deterministic function of
/// `x_t` with probability `p_rule`, otherwise a fresh Zipf draw. The
/// learnable structure is the rule; the Zipf tail supplies realistic
/// imbalance for the MoE router.
#[derive(Clone, Debug)]
pub struct SyntheticLm {
    pub vocab: usize,
    zipf: Zipf,
    p_rule: f64,
    rule_salt: u64,
}

impl SyntheticLm {
    pub fn new(vocab: usize, zipf_s: f64, p_rule: f64) -> Self {
        SyntheticLm {
            vocab,
            zipf: Zipf::new(vocab, zipf_s),
            p_rule,
            rule_salt: 0x5EED,
        }
    }

    /// The deterministic successor rule.
    pub fn successor(&self, token: u32) -> u32 {
        (hash_u64(token as u64 ^ self.rule_salt) % self.vocab as u64) as u32
    }

    /// Generate a sequence of `len` tokens.
    pub fn sequence(&self, len: usize, rng: &mut Rng) -> Vec<u32> {
        let mut out = Vec::with_capacity(len);
        let mut cur = self.zipf.sample(rng) as u32;
        out.push(cur);
        for _ in 1..len {
            cur = if rng.next_f64() < self.p_rule {
                self.successor(cur)
            } else {
                self.zipf.sample(rng) as u32
            };
            out.push(cur);
        }
        out
    }
}

/// Batches of (inputs, targets) for next-token prediction.
pub struct BatchIter {
    task: SyntheticLm,
    rng: Rng,
    pub batch_size: usize,
    pub seq_len: usize,
}

impl BatchIter {
    pub fn new(task: SyntheticLm, batch_size: usize, seq_len: usize, seed: u64) -> Self {
        BatchIter { task, rng: Rng::seed(seed), batch_size, seq_len }
    }

    /// Next batch: `inputs[b*seq + t]`, `targets` shifted by one.
    pub fn next_batch(&mut self) -> (Vec<u32>, Vec<u32>) {
        let mut inputs = Vec::with_capacity(self.batch_size * self.seq_len);
        let mut targets = Vec::with_capacity(self.batch_size * self.seq_len);
        for _ in 0..self.batch_size {
            let seq = self.task.sequence(self.seq_len + 1, &mut self.rng);
            inputs.extend_from_slice(&seq[..self.seq_len]);
            targets.extend_from_slice(&seq[1..]);
        }
        (inputs, targets)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sequences_follow_the_rule_mostly() {
        let task = SyntheticLm::new(100, 1.1, 0.9);
        let mut rng = Rng::seed(0);
        let seq = task.sequence(2000, &mut rng);
        let rule_hits = seq
            .windows(2)
            .filter(|w| w[1] == task.successor(w[0]))
            .count();
        let frac = rule_hits as f64 / (seq.len() - 1) as f64;
        assert!(frac > 0.85, "rule fraction {frac}");
        assert!(seq.iter().all(|&t| (t as usize) < 100));
    }

    #[test]
    fn batches_are_shifted_views() {
        let task = SyntheticLm::new(50, 1.0, 1.0); // fully deterministic
        let mut it = BatchIter::new(task.clone(), 2, 8, 1);
        let (x, y) = it.next_batch();
        assert_eq!(x.len(), 16);
        assert_eq!(y.len(), 16);
        for b in 0..2 {
            for t in 0..7 {
                assert_eq!(y[b * 8 + t], x[b * 8 + t + 1]);
            }
            // And every target is the rule successor (p_rule = 1).
            for t in 0..8 {
                assert_eq!(y[b * 8 + t], task.successor(x[b * 8 + t]));
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let t1 = SyntheticLm::new(64, 1.0, 0.8);
        let mut a = BatchIter::new(t1.clone(), 2, 4, 9);
        let mut b = BatchIter::new(t1, 2, 4, 9);
        assert_eq!(a.next_batch(), b.next_batch());
    }
}
