//! Library-wide error type.
//!
//! The crate deliberately avoids pulling in `thiserror`/`eyre` (the build
//! environment vendors only the `xla` closure); this is a small hand-rolled
//! error enum with `From` impls for the foreign errors we touch.

use std::fmt;

/// Result alias used across the crate.
pub type Result<T> = std::result::Result<T, HetuError>;

/// Error type for all HetuMoE operations.
#[derive(Debug)]
pub enum HetuError {
    /// Invalid or inconsistent configuration.
    Config(String),
    /// Shape mismatch in tensor / routing plumbing.
    Shape(String),
    /// Communication-layer failure (mesh mismatch, buffer sizes, ...).
    Comm(String),
    /// PJRT / XLA runtime failure.
    Runtime(String),
    /// Artifact missing or malformed (run `make artifacts`).
    Artifact(String),
    /// JSON parse error.
    Json(String),
    /// Gating failure (e.g. assignment did not converge).
    Gating(String),
    /// Fault-injection spec or recovery-path failure.
    Fault(String),
    /// Checkpoint missing, malformed or incompatible with the config.
    Ckpt(String),
    /// Underlying I/O error.
    Io(std::io::Error),
}

impl fmt::Display for HetuError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            HetuError::Config(m) => write!(f, "config error: {m}"),
            HetuError::Shape(m) => write!(f, "shape error: {m}"),
            HetuError::Comm(m) => write!(f, "comm error: {m}"),
            HetuError::Runtime(m) => write!(f, "runtime error: {m}"),
            HetuError::Artifact(m) => write!(
                f,
                "artifact error: {m} (hint: run `make artifacts` to build the HLO artifacts)"
            ),
            HetuError::Json(m) => write!(f, "json error: {m}"),
            HetuError::Gating(m) => write!(f, "gating error: {m}"),
            HetuError::Fault(m) => write!(f, "fault error: {m}"),
            HetuError::Ckpt(m) => write!(f, "checkpoint error: {m}"),
            HetuError::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for HetuError {}

impl From<std::io::Error> for HetuError {
    fn from(e: std::io::Error) -> Self {
        HetuError::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for HetuError {
    fn from(e: xla::Error) -> Self {
        HetuError::Runtime(e.to_string())
    }
}

/// Convenience constructor macros.
#[macro_export]
macro_rules! config_err {
    ($($arg:tt)*) => { $crate::error::HetuError::Config(format!($($arg)*)) };
}
#[macro_export]
macro_rules! shape_err {
    ($($arg:tt)*) => { $crate::error::HetuError::Shape(format!($($arg)*)) };
}
#[macro_export]
macro_rules! comm_err {
    ($($arg:tt)*) => { $crate::error::HetuError::Comm(format!($($arg)*)) };
}
#[macro_export]
macro_rules! fault_err {
    ($($arg:tt)*) => { $crate::error::HetuError::Fault(format!($($arg)*)) };
}
#[macro_export]
macro_rules! ckpt_err {
    ($($arg:tt)*) => { $crate::error::HetuError::Ckpt(format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_formats() {
        let e = HetuError::Config("bad".into());
        assert!(e.to_string().contains("config error: bad"));
        let e = HetuError::Artifact("missing model.hlo.txt".into());
        assert!(e.to_string().contains("make artifacts"));
    }

    #[test]
    fn io_conversion() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "nope");
        let e: HetuError = io.into();
        assert!(matches!(e, HetuError::Io(_)));
    }

    #[test]
    fn macros_build_variants() {
        let e = config_err!("x={}", 3);
        assert!(matches!(e, HetuError::Config(ref m) if m == "x=3"));
        let e = shape_err!("got {:?}", [1, 2]);
        assert!(matches!(e, HetuError::Shape(_)));
        let e = comm_err!("rank {}", 7);
        assert!(matches!(e, HetuError::Comm(ref m) if m.contains('7')));
        let e = fault_err!("bad clause");
        assert!(e.to_string().contains("fault error: bad clause"));
        let e = ckpt_err!("magic mismatch");
        assert!(e.to_string().contains("checkpoint error: magic mismatch"));
    }
}
