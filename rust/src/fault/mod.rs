//! Deterministic fault injection: stragglers, NIC degradation,
//! transient exchange failures and hard rank failures, all scheduled by
//! a seeded, fully reproducible [`FaultPlan`].
//!
//! The plan is a pure function of `(spec, step)`: `at_step` derives the
//! same [`StepFaults`] for a given step on every run (chaos mode hashes
//! the seed with the step index into a fresh RNG stream per step), so a
//! faulted run is exactly replayable. Injection is **purely additive on
//! the simulated clock** — a fault never changes token data, routing,
//! the flat-vs-hier schedule pick or the chunk count, which is what
//! keeps a no-fault run bit-identical to a build without this module
//! and lets determinism tests compare faulted and clean runs
//! loss-for-loss.
//!
//! Grammar (clauses joined by `;`):
//!
//! ```text
//!   straggle:rank=R,x=F[,from=S][,until=T]   rank R's expert compute ×F
//!   nic:node=N,x=F[,from=S][,until=T]        node N's NIC time ×F
//!   flaky:rank=R,step=S[,n=K]                K transient exchange
//!                                            failures at step S (retried
//!                                            with capped exponential
//!                                            backoff, charged on the
//!                                            simulated clock)
//!   kill:rank=R,step=S                       hard rank failure at step S
//!                                            (training recovers from the
//!                                            last checkpoint onto the
//!                                            remapped placement)
//!   dead:rank=R                              rank R is down from step 0
//!   chaos:seed=N                             seeded random stragglers /
//!                                            NIC degradation / flakiness
//!                                            every step (no kills)
//! ```
//!
//! A spec naming an existing file loads that file: one clause per line,
//! `#` comments and blank lines ignored.

use crate::error::{HetuError, Result};
use crate::moe::StepReport;
use crate::util::rng::{hash_u64, Rng};

/// Simulated seconds before a transient exchange failure is detected.
pub const RETRY_TIMEOUT: f64 = 2e-3;
/// Base backoff of the capped exponential retry policy.
pub const RETRY_BACKOFF_BASE: f64 = 1e-3;
/// Backoff cap — waits never exceed this.
pub const RETRY_BACKOFF_CAP: f64 = 16e-3;
/// Retries allowed before an exchange failure is no longer transient.
pub const MAX_RETRIES: u32 = 8;

/// Simulated delay of `failures` transient failures followed by a
/// success: each failed attempt costs the detection timeout plus a
/// capped exponential backoff wait (`min(base·2^i, cap)`).
pub fn retry_delay(failures: u32) -> f64 {
    (0..failures)
        .map(|i| RETRY_TIMEOUT + (RETRY_BACKOFF_BASE * (1u64 << i.min(32)) as f64).min(RETRY_BACKOFF_CAP))
        .sum()
}

#[derive(Clone, Debug, PartialEq)]
enum Clause {
    Straggle { rank: usize, factor: f64, from: usize, until: usize },
    Nic { node: usize, factor: f64, from: usize, until: usize },
    Flaky { rank: usize, step: usize, failures: u32 },
    Kill { rank: usize, step: usize },
    Dead { rank: usize },
}

/// A deterministic, seeded schedule of faults (see module docs).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultPlan {
    clauses: Vec<Clause>,
    chaos_seed: Option<u64>,
}

impl FaultPlan {
    /// The empty plan: injects nothing, ever.
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// True when the plan can never inject anything.
    pub fn is_empty(&self) -> bool {
        self.clauses.is_empty() && self.chaos_seed.is_none()
    }

    /// Parse a spec string, or load a spec file if `spec` names one.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let spec = spec.trim();
        if spec.is_empty() {
            return Ok(FaultPlan::none());
        }
        if std::path::Path::new(spec).is_file() {
            let text = std::fs::read_to_string(spec).map_err(|e| {
                HetuError::Fault(format!("cannot read fault spec file '{spec}': {e}"))
            })?;
            let joined: Vec<&str> = text
                .lines()
                .map(str::trim)
                .filter(|l| !l.is_empty() && !l.starts_with('#'))
                .collect();
            return Self::parse_clauses(&joined.join(";"));
        }
        Self::parse_clauses(spec)
    }

    fn parse_clauses(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::none();
        for raw in spec.split(';') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            let (kind, rest) = raw
                .split_once(':')
                .ok_or_else(|| HetuError::Fault(format!("fault clause '{raw}' has no ':'")))?;
            let kv = parse_kv(raw, rest)?;
            match kind.trim() {
                "straggle" => plan.clauses.push(Clause::Straggle {
                    rank: get_usize(&kv, raw, "rank")?,
                    factor: get_factor(&kv, raw)?,
                    from: opt_usize(&kv, raw, "from")?.unwrap_or(0),
                    until: opt_usize(&kv, raw, "until")?.unwrap_or(usize::MAX),
                }),
                "nic" => plan.clauses.push(Clause::Nic {
                    node: get_usize(&kv, raw, "node")?,
                    factor: get_factor(&kv, raw)?,
                    from: opt_usize(&kv, raw, "from")?.unwrap_or(0),
                    until: opt_usize(&kv, raw, "until")?.unwrap_or(usize::MAX),
                }),
                "flaky" => {
                    let failures = opt_usize(&kv, raw, "n")?.unwrap_or(1) as u32;
                    if failures == 0 || failures > MAX_RETRIES {
                        return Err(HetuError::Fault(format!(
                            "fault clause '{raw}': n must be in 1..={MAX_RETRIES}"
                        )));
                    }
                    plan.clauses.push(Clause::Flaky {
                        rank: get_usize(&kv, raw, "rank")?,
                        step: get_usize(&kv, raw, "step")?,
                        failures,
                    });
                }
                "kill" => plan.clauses.push(Clause::Kill {
                    rank: get_usize(&kv, raw, "rank")?,
                    step: get_usize(&kv, raw, "step")?,
                }),
                "dead" => plan.clauses.push(Clause::Dead { rank: get_usize(&kv, raw, "rank")? }),
                "chaos" => {
                    plan.chaos_seed = Some(get_usize(&kv, raw, "seed")? as u64);
                }
                other => {
                    return Err(HetuError::Fault(format!(
                        "unknown fault kind '{other}' (expected \
                         straggle|nic|flaky|kill|dead|chaos)"
                    )));
                }
            }
        }
        Ok(plan)
    }

    /// Ranks dead from step 0 (`dead:` clauses), sorted and deduped.
    pub fn initial_dead(&self) -> Vec<usize> {
        let mut dead: Vec<usize> = self
            .clauses
            .iter()
            .filter_map(|c| match c {
                Clause::Dead { rank } => Some(*rank),
                _ => None,
            })
            .collect();
        dead.sort_unstable();
        dead.dedup();
        dead
    }

    /// Ranks hard-killed at exactly `step` (`kill:` clauses).
    pub fn kills_at(&self, step: usize) -> Vec<usize> {
        let mut kills: Vec<usize> = self
            .clauses
            .iter()
            .filter_map(|c| match c {
                Clause::Kill { rank, step: s } if *s == step => Some(*rank),
                _ => None,
            })
            .collect();
        kills.sort_unstable();
        kills.dedup();
        kills
    }

    /// Derive the step's timing faults — a pure function of
    /// `(plan, step)`, identical on every run.
    pub fn at_step(&self, step: usize, world: usize, nodes: usize) -> StepFaults {
        let mut f = StepFaults::clean(world, nodes);
        for c in &self.clauses {
            match c {
                Clause::Straggle { rank, factor, from, until } => {
                    if step >= *from && step < *until && *rank < world {
                        f.straggle[*rank] = f.straggle[*rank].max(*factor);
                        f.injected += 1;
                    }
                }
                Clause::Nic { node, factor, from, until } => {
                    if step >= *from && step < *until && *node < nodes {
                        f.nic[*node] = f.nic[*node].max(*factor);
                        f.injected += 1;
                    }
                }
                Clause::Flaky { step: s, failures, .. } => {
                    if *s == step {
                        f.flaky_failures += failures;
                        f.injected += 1;
                    }
                }
                Clause::Kill { .. } | Clause::Dead { .. } => {}
            }
        }
        if let Some(seed) = self.chaos_seed {
            // One fresh stream per step, keyed by (seed, step): replayable
            // without tracking any cross-step RNG state.
            let mut rng =
                Rng::seed(hash_u64(seed ^ (step as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)));
            if rng.next_f64() < 0.35 {
                let rank = rng.below(world);
                f.straggle[rank] = f.straggle[rank].max(1.5 + 2.5 * rng.next_f64());
                f.injected += 1;
            }
            if rng.next_f64() < 0.25 {
                let node = rng.below(nodes);
                f.nic[node] = f.nic[node].max(1.5 + 1.5 * rng.next_f64());
                f.injected += 1;
            }
            if rng.next_f64() < 0.20 {
                f.flaky_failures += 1 + rng.below(2) as u32;
                f.injected += 1;
            }
        }
        f
    }
}

/// The faults active on one step: timing multipliers and transient
/// exchange failures. Hard failures (`kill`/`dead`) are surfaced
/// separately ([`FaultPlan::kills_at`] / [`FaultPlan::initial_dead`]).
#[derive(Clone, Debug, PartialEq)]
pub struct StepFaults {
    /// Per-rank expert-compute slowdown (1.0 = healthy).
    pub straggle: Vec<f64>,
    /// Per-node NIC slowdown (1.0 = healthy).
    pub nic: Vec<f64>,
    /// Transient exchange failures this step, retried with backoff.
    pub flaky_failures: u32,
    /// Count of fault clauses active this step.
    pub injected: usize,
}

impl StepFaults {
    /// No faults (all multipliers 1.0).
    pub fn clean(world: usize, nodes: usize) -> StepFaults {
        StepFaults {
            straggle: vec![1.0; world],
            nic: vec![1.0; nodes],
            flaky_failures: 0,
            injected: 0,
        }
    }

    /// True when this step injects nothing.
    pub fn is_clean(&self) -> bool {
        self.injected == 0 && self.flaky_failures == 0
    }

    /// Worst NIC slowdown across nodes (the inter-node legs serialize
    /// on the slowest NIC).
    pub fn max_nic_factor(&self) -> f64 {
        self.nic.iter().cloned().fold(1.0, f64::max)
    }
}

/// Fold one step's faults into its [`StepReport`] as *additive*
/// simulated delay: per-rank expert straggle over the measured compute
/// profile (via [`crate::cluster::gpu::straggle_extra`]), NIC
/// degradation over the exchange totals (via
/// [`crate::cluster::NetworkModel::degraded_extra`]) and retry/backoff
/// time for transient failures. Returns the total injected seconds.
/// Token data, routing and schedule decisions are never touched.
pub fn apply_to_report(
    report: &mut StepReport,
    faults: &StepFaults,
    net: &crate::cluster::NetworkModel,
    per_rank_compute: &[f64],
) -> f64 {
    if faults.is_clean() {
        return 0.0;
    }
    let w = per_rank_compute.len().max(1) as f64;
    let expert_extra: f64 = per_rank_compute
        .iter()
        .zip(&faults.straggle)
        .map(|(&t, &f)| crate::cluster::gpu::straggle_extra(t, f))
        .sum::<f64>()
        / w;
    let comm_extra = net.degraded_extra(report.comm_total(), faults.max_nic_factor());
    let retry_extra = retry_delay(faults.flaky_failures);
    if expert_extra > 0.0 {
        report.wall.push(("straggle/expert".into(), expert_extra));
    }
    if comm_extra > 0.0 {
        report.comm.push(("straggle/nic".into(), comm_extra));
    }
    if retry_extra > 0.0 {
        report.comm.push(("retry/dispatch".into(), retry_extra));
    }
    let injected = expert_extra + comm_extra + retry_extra;
    report.faults_injected += faults.injected;
    report.retries += faults.flaky_failures as usize;
    report.injected_delay += injected;
    report.critical_path += injected;
    injected
}

fn parse_kv<'s>(clause: &str, rest: &'s str) -> Result<Vec<(&'s str, &'s str)>> {
    rest.split(',')
        .filter(|p| !p.trim().is_empty())
        .map(|pair| {
            pair.split_once('=')
                .map(|(k, v)| (k.trim(), v.trim()))
                .ok_or_else(|| {
                    HetuError::Fault(format!("fault clause '{clause}': '{pair}' is not key=value"))
                })
        })
        .collect()
}

fn opt_usize(kv: &[(&str, &str)], clause: &str, key: &str) -> Result<Option<usize>> {
    match kv.iter().find(|(k, _)| *k == key) {
        None => Ok(None),
        Some((_, v)) => v.parse::<usize>().map(Some).map_err(|_| {
            HetuError::Fault(format!("fault clause '{clause}': {key}={v} is not an integer"))
        }),
    }
}

fn get_usize(kv: &[(&str, &str)], clause: &str, key: &str) -> Result<usize> {
    opt_usize(kv, clause, key)?
        .ok_or_else(|| HetuError::Fault(format!("fault clause '{clause}' needs {key}=")))
}

fn get_factor(kv: &[(&str, &str)], clause: &str) -> Result<f64> {
    let v = kv
        .iter()
        .find(|(k, _)| *k == "x")
        .ok_or_else(|| HetuError::Fault(format!("fault clause '{clause}' needs x=")))?
        .1;
    let f: f64 = v.parse().map_err(|_| {
        HetuError::Fault(format!("fault clause '{clause}': x={v} is not a number"))
    })?;
    if !f.is_finite() || f < 1.0 {
        return Err(HetuError::Fault(format!(
            "fault clause '{clause}': slowdown x={f} must be a finite factor ≥ 1"
        )));
    }
    Ok(f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_plan_is_clean_everywhere() {
        let p = FaultPlan::parse("").unwrap();
        assert!(p.is_empty());
        for step in 0..50 {
            assert!(p.at_step(step, 4, 2).is_clean());
            assert!(p.kills_at(step).is_empty());
        }
        assert!(p.initial_dead().is_empty());
    }

    #[test]
    fn grammar_round_trip() {
        let p = FaultPlan::parse(
            "straggle:rank=1,x=2.5,from=3,until=7; nic:node=0,x=2; \
             flaky:rank=2,step=4,n=2; kill:rank=3,step=9; dead:rank=0",
        )
        .unwrap();
        assert_eq!(p.initial_dead(), vec![0]);
        assert_eq!(p.kills_at(9), vec![3]);
        assert!(p.kills_at(8).is_empty());
        let f3 = p.at_step(3, 4, 2);
        assert_eq!(f3.straggle[1], 2.5);
        assert_eq!(f3.nic[0], 2.0);
        assert_eq!(f3.flaky_failures, 0);
        let f4 = p.at_step(4, 4, 2);
        assert_eq!(f4.flaky_failures, 2);
        let f7 = p.at_step(7, 4, 2);
        assert_eq!(f7.straggle[1], 1.0, "until= is exclusive");
        assert_eq!(f7.nic[0], 2.0, "no until → forever");
    }

    #[test]
    fn chaos_is_deterministic_per_step() {
        let a = FaultPlan::parse("chaos:seed=7").unwrap();
        let b = FaultPlan::parse("chaos:seed=7").unwrap();
        let c = FaultPlan::parse("chaos:seed=8").unwrap();
        let mut injected_any = false;
        let mut differs = false;
        for step in 0..64 {
            let fa = a.at_step(step, 4, 2);
            assert_eq!(fa, b.at_step(step, 4, 2), "same seed must replay");
            injected_any |= !fa.is_clean();
            differs |= fa != c.at_step(step, 4, 2);
            assert!(a.kills_at(step).is_empty(), "chaos never kills");
        }
        assert!(injected_any, "chaos must inject something over 64 steps");
        assert!(differs, "different seeds must differ somewhere");
    }

    #[test]
    fn retry_backoff_is_capped_exponential() {
        assert_eq!(retry_delay(0), 0.0);
        let one = retry_delay(1);
        assert!((one - (RETRY_TIMEOUT + RETRY_BACKOFF_BASE)).abs() < 1e-12);
        // Each extra failure costs more than the last, up to the cap.
        let mut prev = 0.0;
        for n in 1..=MAX_RETRIES {
            let d = retry_delay(n);
            assert!(d > prev);
            prev = d;
        }
        // Deep retries are cap-bounded per attempt.
        let deep = retry_delay(MAX_RETRIES);
        assert!(deep <= MAX_RETRIES as f64 * (RETRY_TIMEOUT + RETRY_BACKOFF_CAP) + 1e-12);
    }

    #[test]
    fn rejects_malformed_specs() {
        assert!(FaultPlan::parse("straggle:rank=0").is_err(), "missing x=");
        assert!(FaultPlan::parse("straggle:rank=0,x=0.5").is_err(), "factor < 1");
        assert!(FaultPlan::parse("wobble:rank=0").is_err(), "unknown kind");
        assert!(FaultPlan::parse("kill:rank=zero,step=1").is_err(), "non-integer");
        assert!(FaultPlan::parse("flaky:rank=0,step=1,n=99").is_err(), "too many retries");
        assert!(FaultPlan::parse("kill rank 3").is_err(), "no colon");
    }

    #[test]
    fn out_of_range_targets_are_ignored_at_derivation() {
        // A clause naming a rank/node outside the world is inert (the
        // trainer validates kill/dead targets; timing clauses degrade
        // gracefully so one spec can drive several topologies).
        let p = FaultPlan::parse("straggle:rank=9,x=3; nic:node=9,x=3").unwrap();
        assert!(p.at_step(0, 4, 2).is_clean());
    }
}
