//! BASE layer gate (Lewis et al., 2021): token→expert allocation as a
//! **balanced linear assignment problem** — maximize total token-expert
//! affinity subject to every expert receiving exactly `N/E` tokens.
//!
//! We solve the transportation-relaxed assignment with the **auction
//! algorithm** (Bertsekas): tokens bid for experts; an over-subscribed
//! expert keeps its highest bidders and raises its price; ε-scaling
//! guarantees termination within `max(score)−min(score) / ε` rounds.
//! A greedy seeding pass makes typical inputs converge in a few rounds.

use crate::gating::{Gate, GateBatch, Routing};
use crate::tensor::Tensor;

/// Balanced-assignment gate.
#[derive(Clone, Debug)]
pub struct BaseLayerGate {
    num_experts: usize,
    /// Auction ε (price increment floor). Larger = faster, less optimal.
    pub epsilon: f32,
    /// Hard cap on auction rounds (bail to greedy fill if exceeded).
    pub max_rounds: usize,
}

impl BaseLayerGate {
    pub fn new(num_experts: usize) -> Self {
        BaseLayerGate { num_experts, epsilon: 1e-3, max_rounds: 2000 }
    }
}

/// Solve balanced assignment: `scores` is `(tokens, E)`; every expert
/// receives `ceil(tokens/E)` or `floor(tokens/E)` tokens. Returns the
/// expert of each token.
pub fn balanced_assignment(
    scores: &Tensor,
    num_experts: usize,
    epsilon: f32,
    max_rounds: usize,
) -> Vec<u32> {
    let tokens = scores.rows();
    let e = num_experts;
    // Per-expert capacity: distribute the remainder to the first experts.
    let base_cap = tokens / e;
    let rem = tokens % e;
    let cap: Vec<usize> = (0..e).map(|i| base_cap + usize::from(i < rem)).collect();

    let mut price = vec![0.0f32; e];
    let mut assign: Vec<i64> = vec![-1; tokens]; // token -> expert
    // Expert slots: holders[e] = tokens currently assigned (worst bidder
    // evicted when over capacity). Track each holder's net value to evict
    // the weakest.
    let mut holders: Vec<Vec<u32>> = vec![Vec::new(); e];

    let mut unassigned: Vec<u32> = (0..tokens as u32).collect();
    let mut rounds = 0usize;
    while let Some(t) = unassigned.pop() {
        rounds += 1;
        if rounds > max_rounds * tokens.max(1) {
            // Safety valve: greedy-fill all remaining.
            unassigned.push(t);
            greedy_fill(scores, &cap, &mut holders, &mut assign, &mut unassigned);
            break;
        }
        let row = scores.row(t as usize);
        // Find best and second-best net value (score - price).
        let (mut b1, mut v1, mut v2) = (0usize, f32::NEG_INFINITY, f32::NEG_INFINITY);
        for j in 0..e {
            let net = row[j] - price[j];
            if net > v1 {
                v2 = v1;
                v1 = net;
                b1 = j;
            } else if net > v2 {
                v2 = net;
            }
        }
        // Bid: raise price by the margin + ε.
        let bid_increment = (v1 - v2) + epsilon;
        assign[t as usize] = b1 as i64;
        holders[b1].push(t);
        if holders[b1].len() > cap[b1] {
            price[b1] += bid_increment;
            // Evict the weakest holder (lowest raw score for this expert).
            let (widx, _) = holders[b1]
                .iter()
                .enumerate()
                .map(|(i, &tok)| (i, scores.at(tok as usize, b1)))
                .fold((0usize, f32::INFINITY), |acc, (i, s)| {
                    if s < acc.1 {
                        (i, s)
                    } else {
                        acc
                    }
                });
            let evicted = holders[b1].swap_remove(widx);
            assign[evicted as usize] = -1;
            unassigned.push(evicted);
        } else if holders[b1].len() == cap[b1] {
            // Expert is now full; nudge price so future bidders prefer others.
            price[b1] += epsilon;
        }
    }
    assign.into_iter().map(|a| a.max(0) as u32).collect()
}

/// Greedy fallback: assign remaining tokens to the best expert with
/// spare capacity.
fn greedy_fill(
    scores: &Tensor,
    cap: &[usize],
    holders: &mut [Vec<u32>],
    assign: &mut [i64],
    unassigned: &mut Vec<u32>,
) {
    while let Some(t) = unassigned.pop() {
        let row = scores.row(t as usize);
        let mut best = usize::MAX;
        let mut bv = f32::NEG_INFINITY;
        for (j, h) in holders.iter().enumerate() {
            if h.len() < cap[j] && row[j] > bv {
                bv = row[j];
                best = j;
            }
        }
        assert!(best != usize::MAX, "capacities must sum to tokens");
        holders[best].push(t);
        assign[t as usize] = best as i64;
    }
}

impl Gate for BaseLayerGate {
    fn name(&self) -> String {
        "base".into()
    }

    fn k(&self) -> usize {
        1
    }

    fn num_experts(&self) -> usize {
        self.num_experts
    }

    fn route(&self, batch: &GateBatch) -> Routing {
        let scores = batch.scores;
        let tokens = scores.rows();
        assert_eq!(scores.row_len(), self.num_experts);
        let assign =
            balanced_assignment(scores, self.num_experts, self.epsilon, self.max_rounds);
        // BASE weight: σ(affinity) of the assigned expert — no softmax
        // competition, no auxiliary loss needed (balance is structural).
        let weights: Vec<f32> = assign
            .iter()
            .enumerate()
            .map(|(t, &e)| {
                let s = scores.at(t, e as usize);
                1.0 / (1.0 + (-s).exp())
            })
            .collect();
        Routing {
            k: 1,
            tokens,
            num_experts: self.num_experts,
            expert_ids: assign,
            weights,
            aux_loss: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;
    use crate::util::stats::load_cv;

    #[test]
    fn assignment_is_perfectly_balanced() {
        let mut rng = Rng::seed(0);
        let scores = Tensor::randn(&[64, 8], &mut rng);
        let gate = BaseLayerGate::new(8);
        let r = gate.route_scores(&scores, 0);
        r.validate().unwrap();
        let counts = r.expert_counts();
        assert_eq!(counts, vec![8; 8]);
        assert!(load_cv(&counts) < 1e-9);
    }

    #[test]
    fn balanced_even_under_skewed_scores() {
        // All tokens prefer expert 0 — balance must still hold (this is
        // the entire point of BASE vs Switch).
        let mut rng = Rng::seed(1);
        let mut scores = Tensor::randn(&[32, 4], &mut rng);
        for t in 0..32 {
            scores.set(t, 0, scores.at(t, 0) + 10.0);
        }
        let r = BaseLayerGate::new(4).route_scores(&scores, 0);
        assert_eq!(r.expert_counts(), vec![8; 4]);
    }

    #[test]
    fn remainder_distribution() {
        let mut rng = Rng::seed(2);
        let scores = Tensor::randn(&[10, 4], &mut rng);
        let r = BaseLayerGate::new(4).route_scores(&scores, 0);
        let mut counts = r.expert_counts();
        counts.sort_unstable();
        assert_eq!(counts, vec![2, 2, 3, 3]); // 10 = 3+3+2+2
    }

    #[test]
    fn beats_random_assignment_on_total_score() {
        let mut rng = Rng::seed(3);
        let scores = Tensor::randn(&[48, 6], &mut rng);
        let assign = balanced_assignment(&scores, 6, 1e-3, 2000);
        let total: f32 = assign
            .iter()
            .enumerate()
            .map(|(t, &e)| scores.at(t, e as usize))
            .sum();
        // Random balanced baseline: round-robin.
        let rr_total: f32 = (0..48).map(|t| scores.at(t, t % 6)).sum();
        assert!(
            total > rr_total,
            "auction {total:.2} must beat round-robin {rr_total:.2}"
        );
    }

    #[test]
    fn property_balance_holds_for_all_shapes() {
        for_all(20, |g| {
            let e = g.usize_in(2..9);
            let tokens = g.usize_in(e..80);
            let mut rng = Rng::seed(g.case as u64 + 100);
            let scores = Tensor::randn(&[tokens, e], &mut rng);
            let assign = balanced_assignment(&scores, e, 1e-3, 2000);
            let mut counts = vec![0usize; e];
            for &a in &assign {
                counts[a as usize] += 1;
            }
            let max = counts.iter().max().unwrap();
            let min = counts.iter().min().unwrap();
            assert!(max - min <= 1, "counts={counts:?}");
        });
    }

    #[test]
    fn weights_are_sigmoid_bounded() {
        let mut rng = Rng::seed(4);
        let scores = Tensor::randn(&[16, 4], &mut rng);
        let r = BaseLayerGate::new(4).route_scores(&scores, 0);
        assert!(r.weights.iter().all(|&w| w > 0.0 && w < 1.0));
    }
}
