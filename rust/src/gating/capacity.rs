//! Expert-capacity enforcement (GShard/Switch semantics).
//!
//! Each expert accepts at most `C` tokens per batch; excess tokens are
//! *dropped* (they bypass the expert and flow through the residual).
//! Slots are granted first-come-first-served in token order — the same
//! deterministic priority rule Switch uses — so the resulting
//! [`DispatchPlan`] is reproducible and the layout transform can place
//! rows without synchronization.

use crate::gating::Routing;

/// Placement of every routing slot into the padded expert buffers.
#[derive(Clone, Debug)]
pub struct DispatchPlan {
    pub num_experts: usize,
    /// Per-expert row budget `C`.
    pub capacity: usize,
    pub tokens: usize,
    pub k: usize,
    /// Flat `[tokens*k]`: destination row in the `[E*C]` expert buffer,
    /// or `u32::MAX` when the slot was dropped (over capacity or weight 0).
    pub dest: Vec<u32>,
    /// Combine weights aligned with `dest` (0 for dropped slots).
    pub weights: Vec<f32>,
    /// Raw demanded counts per expert (before truncation).
    pub demand: Vec<usize>,
    /// Accepted counts per expert (≤ capacity).
    pub kept: Vec<usize>,
}

impl DispatchPlan {
    /// Number of slots dropped by the capacity limit (weight-0 slots
    /// pruned by the gate are not counted — they never demanded a seat).
    pub fn dropped_slots(&self) -> usize {
        self.demand
            .iter()
            .zip(&self.kept)
            .map(|(&d, &k)| d - k)
            .sum()
    }

    /// Fraction of demanded slots dropped.
    pub fn drop_rate(&self) -> f64 {
        let demanded: usize = self.demand.iter().sum();
        self.dropped_slots() as f64 / demanded.max(1) as f64
    }

    /// Total rows in the padded dispatch buffer (`E·C`).
    pub fn buffer_rows(&self) -> usize {
        self.num_experts * self.capacity
    }

    /// Padding waste: fraction of buffer rows that carry no token.
    pub fn padding_waste(&self) -> f64 {
        let used: usize = self.kept.iter().sum();
        1.0 - used as f64 / self.buffer_rows().max(1) as f64
    }

    /// Rows actually occupied across all experts (`Σ kept` — the ragged
    /// buffer's total row count).
    pub fn occupied_rows(&self) -> usize {
        self.kept.iter().sum()
    }

    /// Prefix offsets of each expert's kept block in a ragged buffer:
    /// expert `e` owns rows `offsets[e]..offsets[e+1]` (length `E + 1`).
    pub fn ragged_offsets(&self) -> Vec<usize> {
        let mut off = vec![0usize; self.num_experts + 1];
        for (e, &k) in self.kept.iter().enumerate() {
            off[e + 1] = off[e] + k;
        }
        off
    }

    /// Kept rows destined to each of `world` ranks under the *static*
    /// contiguous expert placement — one row of the AllToAllv traffic
    /// matrix. Callers running a live (possibly adaptive / dead-remapped)
    /// placement use [`DispatchPlan::rank_counts_placed`].
    pub fn rank_counts(&self, world: usize) -> Vec<usize> {
        self.rank_counts_placed(&crate::cluster::ExpertPlacement::new(
            self.num_experts,
            world,
        ))
    }

    /// Kept rows destined to each rank under an arbitrary live
    /// placement (adaptive table, dead-rank remap, or both).
    pub fn rank_counts_placed(
        &self,
        placement: &crate::cluster::ExpertPlacement,
    ) -> Vec<usize> {
        placement.rank_counts_row(&self.kept)
    }
}

/// Assign buffer positions under capacity `C`.
///
/// Note: the *weights* of dropped slots remain in the plan (set to 0) so
/// the reverse transform can still walk `tokens × k` uniformly.
pub fn apply_capacity(routing: &Routing, capacity: usize) -> DispatchPlan {
    let e = routing.num_experts;
    let mut fill = vec![0usize; e];
    let mut demand = vec![0usize; e];
    let slots = routing.tokens * routing.k;
    let mut dest = vec![u32::MAX; slots];
    let mut weights = vec![0.0f32; slots];
    for s in 0..slots {
        let w = routing.weights[s];
        if w == 0.0 {
            continue; // inactive slot (variable-k gates)
        }
        let ex = routing.expert_ids[s] as usize;
        demand[ex] += 1;
        if fill[ex] < capacity {
            dest[s] = (ex * capacity + fill[ex]) as u32;
            weights[s] = w;
            fill[ex] += 1;
        }
        // else: dropped — dest stays MAX, weight stays 0 in the plan,
        // but `routing.weights[s]` keeps the original for drop stats.
    }
    DispatchPlan {
        num_experts: e,
        capacity,
        tokens: routing.tokens,
        k: routing.k,
        dest,
        weights,
        demand,
        kept: fill,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gating::{Gate, SwitchGate};
    use crate::tensor::Tensor;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn routing_1slot(ids: &[u32], e: usize) -> Routing {
        Routing {
            k: 1,
            tokens: ids.len(),
            num_experts: e,
            expert_ids: ids.to_vec(),
            weights: vec![1.0; ids.len()],
            aux_loss: 0.0,
        }
    }

    #[test]
    fn fcfs_priority_and_drop() {
        // 5 tokens all to expert 0, capacity 3 → first 3 kept.
        let r = routing_1slot(&[0, 0, 0, 0, 0], 2);
        let p = apply_capacity(&r, 3);
        assert_eq!(p.dest[..3], [0, 1, 2]);
        assert_eq!(p.dest[3], u32::MAX);
        assert_eq!(p.dest[4], u32::MAX);
        assert_eq!(p.dropped_slots(), 2);
        assert_eq!(p.kept, vec![3, 0]);
        assert_eq!(p.demand, vec![5, 0]);
        assert!((p.drop_rate() - 0.4).abs() < 1e-12);
    }

    #[test]
    fn positions_are_contiguous_per_expert() {
        let r = routing_1slot(&[1, 0, 1, 0, 1], 2);
        let p = apply_capacity(&r, 4);
        // Expert 1 buffer starts at 4.
        assert_eq!(p.dest, vec![4, 0, 5, 1, 6]);
        assert_eq!(p.padding_waste(), 1.0 - 5.0 / 8.0);
    }

    #[test]
    fn zero_weight_slots_skipped() {
        let r = Routing {
            k: 2,
            tokens: 2,
            num_experts: 2,
            expert_ids: vec![0, 1, 0, 1],
            weights: vec![0.7, 0.0, 0.6, 0.4],
            aux_loss: 0.0,
        };
        let p = apply_capacity(&r, 2);
        assert_eq!(p.dest[1], u32::MAX); // zero-weight slot never placed
        assert_eq!(p.demand, vec![2, 1]); // only active slots demand
        assert_eq!(p.dropped_slots(), 0); // pruned ≠ dropped
    }

    #[test]
    fn no_duplicate_destinations_property() {
        for_all(24, |g| {
            let e = g.usize_in(2..8);
            let tokens = g.usize_in(1..100);
            let cap = g.usize_in(1..32);
            let ids: Vec<u32> = (0..tokens).map(|_| g.u32_in(0..e as u32)).collect();
            let r = routing_1slot(&ids, e);
            let p = apply_capacity(&r, cap);
            let mut seen = std::collections::HashSet::new();
            for &d in &p.dest {
                if d != u32::MAX {
                    assert!(seen.insert(d), "duplicate dest {d}");
                    assert!((d as usize) < e * cap);
                }
            }
            // kept ≤ min(demand, cap)
            for ex in 0..e {
                assert_eq!(p.kept[ex], p.demand[ex].min(cap));
            }
        });
    }

    #[test]
    fn ragged_views_of_the_plan() {
        let r = routing_1slot(&[1, 0, 1, 0, 1, 3], 4);
        let p = apply_capacity(&r, 4);
        assert_eq!(p.kept, vec![2, 3, 0, 1]);
        assert_eq!(p.occupied_rows(), 6);
        assert_eq!(p.ragged_offsets(), vec![0, 2, 5, 5, 6]);
        // 4 experts over 2 ranks: experts 0,1 → rank 0; 2,3 → rank 1.
        assert_eq!(p.rank_counts(2), vec![5, 1]);
        assert_eq!(p.rank_counts(4), vec![2, 3, 0, 1]);
    }

    #[test]
    fn integrates_with_switch_gate() {
        let mut rng = Rng::seed(0);
        let scores = Tensor::randn(&[256, 8], &mut rng);
        let r = SwitchGate::new(8, 1.0).route_scores(&scores, 0);
        let cap = 256 / 8; // cf = 1.0
        let p = apply_capacity(&r, cap);
        let total_kept: usize = p.kept.iter().sum();
        assert!(total_kept <= 256);
        assert!(p.drop_rate() < 0.5); // random scores → moderate drops
        assert!(p.kept.iter().all(|&k| k <= cap));
    }
}
