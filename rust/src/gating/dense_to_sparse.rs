//! Dense-to-Sparse gate (Nie et al., 2021): start dense — every token
//! routed to (almost) all experts — and anneal a Gumbel-softmax
//! temperature so routing sharpens into a sparse top-1-like gate as
//! training progresses. Decouples gate learning from expert learning.
//!
//! Implementation: per token, weights are `softmax((log-softmax(scores) +
//! gumbel) / τ(step))`; experts with weight below a threshold are pruned
//! (slot weight 0). `τ` anneals exponentially from `tau0` to `tau_min`
//! over `anneal_steps`.

use crate::gating::{Gate, GateBatch, Routing};
use crate::util::rng::{hash_u64, Rng};

/// Gumbel-softmax gate with temperature annealing.
#[derive(Clone, Debug)]
pub struct DenseToSparseGate {
    num_experts: usize,
    pub tau0: f32,
    pub tau_min: f32,
    pub anneal_steps: u64,
    pub seed: u64,
    /// Slots below this weight are pruned (paper uses a small cutoff so
    /// the layout transform skips negligible experts).
    pub prune_threshold: f32,
}

impl DenseToSparseGate {
    pub fn new(
        num_experts: usize,
        tau0: f32,
        tau_min: f32,
        anneal_steps: u64,
        seed: u64,
    ) -> Self {
        assert!(tau0 >= tau_min && tau_min > 0.0);
        DenseToSparseGate {
            num_experts,
            tau0,
            tau_min,
            anneal_steps: anneal_steps.max(1),
            seed,
            prune_threshold: 0.01,
        }
    }

    /// Temperature at a training step (exponential decay).
    pub fn tau(&self, step: u64) -> f32 {
        let frac = (step.min(self.anneal_steps) as f64) / self.anneal_steps as f64;
        let t = (self.tau0 as f64) * ((self.tau_min / self.tau0) as f64).powf(frac);
        t as f32
    }
}

impl Gate for DenseToSparseGate {
    fn name(&self) -> String {
        "dense_to_sparse".into()
    }

    /// Slots per token = E (dense upper bound; weight-0 slots inactive).
    fn k(&self) -> usize {
        self.num_experts
    }

    fn num_experts(&self) -> usize {
        self.num_experts
    }

    fn route(&self, batch: &GateBatch) -> Routing {
        let scores = batch.scores;
        let tokens = scores.rows();
        let e = self.num_experts;
        assert_eq!(scores.row_len(), e);
        let tau = self.tau(batch.step);
        let mut expert_ids = Vec::with_capacity(tokens * e);
        let mut weights = Vec::with_capacity(tokens * e);
        for t in 0..tokens {
            let row = scores.row(t);
            // log-softmax of scores.
            let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let lse = row.iter().map(|v| (v - max).exp()).sum::<f32>().ln() + max;
            // Gumbel noise, deterministic per (seed, step, token, expert).
            let mut rng =
                Rng::seed(hash_u64(self.seed ^ batch.step.wrapping_mul(0x9E37) ^ (t as u64) << 20));
            let mut logits = vec![0.0f32; e];
            for (j, l) in logits.iter_mut().enumerate() {
                *l = (row[j] - lse + rng.gumbel()) / tau;
            }
            // Softmax.
            let lmax = logits.iter().copied().fold(f32::NEG_INFINITY, f32::max);
            let mut sum = 0.0f32;
            for l in logits.iter_mut() {
                *l = (*l - lmax).exp();
                sum += *l;
            }
            for (j, l) in logits.iter().enumerate() {
                let w = l / sum;
                expert_ids.push(j as u32);
                weights.push(if w >= self.prune_threshold { w } else { 0.0 });
            }
        }
        Routing { k: e, tokens, num_experts: e, expert_ids, weights, aux_loss: 0.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    fn gate() -> DenseToSparseGate {
        DenseToSparseGate::new(8, 4.0, 0.05, 1000, 7)
    }

    #[test]
    fn temperature_anneals_monotonically() {
        let g = gate();
        assert!((g.tau(0) - 4.0).abs() < 1e-5);
        assert!((g.tau(1000) - 0.05).abs() < 1e-5);
        assert!((g.tau(5000) - 0.05).abs() < 1e-5); // clamped
        let mut prev = f32::INFINITY;
        for s in [0u64, 100, 300, 600, 1000] {
            let t = g.tau(s);
            assert!(t < prev);
            prev = t;
        }
    }

    #[test]
    fn starts_dense_becomes_sparse() {
        let g = gate();
        let mut rng = Rng::seed(0);
        let scores = Tensor::randn(&[128, 8], &mut rng);
        let early = g.route_scores(&scores, 0);
        let late = g.route_scores(&scores, 1000);
        early.validate().unwrap();
        late.validate().unwrap();
        let k_early = early.mean_active_k();
        let k_late = late.mean_active_k();
        assert!(
            k_early > 3.0,
            "early routing should be dense-ish, got {k_early:.2}"
        );
        assert!(k_late < 2.0, "late routing should be sparse, got {k_late:.2}");
        assert!(k_early > k_late + 1.0);
    }

    #[test]
    fn weights_form_subprobability() {
        let g = gate();
        let mut rng = Rng::seed(1);
        let scores = Tensor::randn(&[32, 8], &mut rng);
        let r = g.route_scores(&scores, 500);
        for t in 0..32 {
            let s: f32 = r.weights[t * 8..(t + 1) * 8].iter().sum();
            assert!(s <= 1.0 + 1e-5 && s > 0.5, "sum={s}");
        }
    }

    #[test]
    fn deterministic_per_step() {
        let g = gate();
        let mut rng = Rng::seed(2);
        let scores = Tensor::randn(&[16, 8], &mut rng);
        assert_eq!(g.route_scores(&scores, 3).weights, g.route_scores(&scores, 3).weights);
        assert_ne!(g.route_scores(&scores, 3).weights, g.route_scores(&scores, 4).weights);
    }

    #[test]
    fn late_routing_tracks_argmax() {
        // At tiny τ with mild noise, the dominant expert should win almost
        // always.
        let g = DenseToSparseGate::new(4, 1.0, 0.02, 10, 0);
        let mut scores = Tensor::zeros(&[64, 4]);
        for t in 0..64 {
            scores.set(t, t % 4, 6.0);
        }
        let r = g.route_scores(&scores, 10);
        let mut correct = 0;
        for t in 0..64 {
            let w = &r.weights[t * 4..(t + 1) * 4];
            let argmax = w
                .iter()
                .enumerate()
                .max_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            if argmax == t % 4 {
                correct += 1;
            }
        }
        assert!(correct > 56, "correct={correct}/64");
    }
}
