//! GShard gate (Lepikhin et al., 2020): top-2 routing. The second expert
//! is kept with probability proportional to its router weight (the
//! "random routing" trick), and weights are renormalized over the kept
//! pair.

use crate::gating::topk::{softmax_of_selected, top2_row};
use crate::gating::{aux_loss, Gate, GateBatch, Routing};
use crate::nn::softmax_rows;
use crate::tensor::Tensor;
use crate::util::rng::{hash_u64, Rng};

/// Top-2 gate with stochastic second-expert dropping.
#[derive(Clone, Debug)]
pub struct GShardGate {
    num_experts: usize,
    /// Deterministic seed for the second-expert coin flips (reproducible
    /// training).
    pub seed: u64,
    /// If false, always keep the second expert (used by tests/benches).
    pub stochastic_second: bool,
}

impl GShardGate {
    pub fn new(num_experts: usize) -> Self {
        GShardGate { num_experts, seed: 0x65_5348_4152_44, stochastic_second: true }
    }

    pub fn deterministic(num_experts: usize) -> Self {
        GShardGate { num_experts, seed: 0, stochastic_second: false }
    }
}

impl Gate for GShardGate {
    fn name(&self) -> String {
        "gshard".into()
    }

    fn k(&self) -> usize {
        2
    }

    fn num_experts(&self) -> usize {
        self.num_experts
    }

    fn route(&self, batch: &GateBatch) -> Routing {
        let scores = batch.scores;
        let tokens = scores.rows();
        assert_eq!(scores.row_len(), self.num_experts);
        assert!(self.num_experts >= 2, "gshard needs at least 2 experts");
        let mut expert_ids = Vec::with_capacity(tokens * 2);
        let mut weights = Vec::with_capacity(tokens * 2);
        let mut top1 = Vec::with_capacity(tokens);
        for t in 0..tokens {
            let row = scores.row(t);
            let (ids, vals) = top2_row(row);
            let mut p = [0.0f32; 2];
            softmax_of_selected(row, &vals, &mut p);
            top1.push(ids[0]);

            // GShard: keep 2nd expert with prob = 2*p2 (capped at 1) —
            // tokens where the router is confident route to one expert.
            let keep2 = if self.stochastic_second {
                let mut rng = Rng::seed(
                    hash_u64(self.seed ^ batch.step.wrapping_mul(0x9E37) ^ t as u64),
                );
                rng.next_f32() < (2.0 * p[1]).min(1.0)
            } else {
                true
            };
            let denom = p[0] + if keep2 { p[1] } else { 0.0 };
            expert_ids.push(ids[0]);
            weights.push(p[0] / denom);
            expert_ids.push(ids[1]);
            weights.push(if keep2 { p[1] / denom } else { 0.0 });
        }
        let mut probs = scores.clone();
        softmax_rows(&mut probs);
        let loss = aux_loss(&probs, &top1, self.num_experts);
        Routing {
            k: 2,
            tokens,
            num_experts: self.num_experts,
            expert_ids,
            weights,
            aux_loss: loss,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn deterministic_variant_keeps_both() {
        let mut rng = Rng::seed(0);
        let scores = Tensor::randn(&[64, 8], &mut rng);
        let gate = GShardGate::deterministic(8);
        let r = gate.route_scores(&scores, 0);
        r.validate().unwrap();
        assert_eq!(r.k, 2);
        assert!((r.mean_active_k() - 2.0).abs() < 1e-9);
        // Weights renormalized: each token's pair sums to 1.
        for t in 0..64 {
            let s = r.weights[2 * t] + r.weights[2 * t + 1];
            assert!((s - 1.0).abs() < 1e-5);
            // Top-1 weight ≥ top-2 weight.
            assert!(r.weights[2 * t] >= r.weights[2 * t + 1]);
        }
    }

    #[test]
    fn stochastic_second_drops_some() {
        let mut rng = Rng::seed(1);
        // Confident router: big gaps → second prob small → mostly dropped.
        let mut scores = Tensor::randn(&[256, 8], &mut rng);
        for t in 0..256 {
            let j = t % 8;
            scores.set(t, j, scores.at(t, j) + 8.0);
        }
        let gate = GShardGate::new(8);
        let r = gate.route_scores(&scores, 0);
        let active = r.mean_active_k();
        assert!(active < 1.5, "mean active k = {active}");
        // Reproducible for the same step.
        let r2 = gate.route_scores(&scores, 0);
        assert_eq!(r.weights, r2.weights);
        // Different step → different coin flips somewhere.
        let r3 = gate.route_scores(&scores, 1);
        assert_ne!(r.weights, r3.weights);
    }

    #[test]
    fn distinct_experts_per_token() {
        let mut rng = Rng::seed(2);
        let scores = Tensor::randn(&[100, 4], &mut rng);
        let r = GShardGate::deterministic(4).route_scores(&scores, 0);
        for t in 0..100 {
            assert_ne!(r.expert_ids[2 * t], r.expert_ids[2 * t + 1]);
        }
    }
}
