//! Hash layer gates (Roller et al., 2021): parameter-free token→expert
//! mappings. Three variants from the paper:
//! - **Random hash** — `hash(token_id) mod E`;
//! - **Balanced hash** — a precomputed vocab→expert table with exactly
//!   equal vocab shares per expert;
//! - **Clustered hash** — k-means over the token embedding table; each
//!   cluster is an expert (similar tokens share an expert).

use crate::gating::{Gate, GateBatch, Routing};
use crate::tensor::Tensor;
use crate::util::rng::{hash_u64, Rng};

/// Token id for row `t` — hash gates prefer real ids, fall back to the
/// row index (still deterministic).
fn token_id(batch: &GateBatch, t: usize) -> u64 {
    match batch.token_ids {
        Some(ids) => ids[t] as u64,
        None => t as u64,
    }
}

/// `hash(token) mod E`.
#[derive(Clone, Debug)]
pub struct RandomHashGate {
    num_experts: usize,
    pub salt: u64,
}

impl RandomHashGate {
    pub fn new(num_experts: usize) -> Self {
        RandomHashGate { num_experts, salt: 0xAB5E }
    }
}

impl Gate for RandomHashGate {
    fn name(&self) -> String {
        "hash_random".into()
    }

    fn k(&self) -> usize {
        1
    }

    fn num_experts(&self) -> usize {
        self.num_experts
    }

    fn route(&self, batch: &GateBatch) -> Routing {
        let tokens = batch.scores.rows();
        let expert_ids: Vec<u32> = (0..tokens)
            .map(|t| (hash_u64(token_id(batch, t) ^ self.salt) % self.num_experts as u64) as u32)
            .collect();
        Routing {
            k: 1,
            tokens,
            num_experts: self.num_experts,
            expert_ids,
            weights: vec![1.0; tokens],
            aux_loss: 0.0,
        }
    }
}

/// Balanced vocab→expert table: expert `perm[v] % E` where `perm` is a
/// seeded permutation of the vocab — every expert owns exactly
/// `vocab/E` (±1) token types.
#[derive(Clone, Debug)]
pub struct BalancedHashGate {
    num_experts: usize,
    table: Vec<u32>,
}

impl BalancedHashGate {
    pub fn new(num_experts: usize, vocab_size: usize) -> Self {
        // Deterministic permutation of the vocab, then round-robin.
        let mut perm: Vec<u32> = (0..vocab_size as u32).collect();
        let mut rng = Rng::seed(0xBA1A_u64);
        rng.shuffle(&mut perm);
        let mut table = vec![0u32; vocab_size];
        for (pos, &v) in perm.iter().enumerate() {
            table[v as usize] = (pos % num_experts) as u32;
        }
        BalancedHashGate { num_experts, table }
    }

    pub fn vocab_size(&self) -> usize {
        self.table.len()
    }
}

impl Gate for BalancedHashGate {
    fn name(&self) -> String {
        "hash_balanced".into()
    }

    fn k(&self) -> usize {
        1
    }

    fn num_experts(&self) -> usize {
        self.num_experts
    }

    fn route(&self, batch: &GateBatch) -> Routing {
        let tokens = batch.scores.rows();
        let expert_ids: Vec<u32> = (0..tokens)
            .map(|t| {
                let id = token_id(batch, t) as usize % self.table.len();
                self.table[id]
            })
            .collect();
        Routing {
            k: 1,
            tokens,
            num_experts: self.num_experts,
            expert_ids,
            weights: vec![1.0; tokens],
            aux_loss: 0.0,
        }
    }
}

/// K-means-clustered vocab→expert table built from an embedding matrix.
#[derive(Clone, Debug)]
pub struct ClusteredHashGate {
    num_experts: usize,
    table: Vec<u32>,
}

impl ClusteredHashGate {
    /// Fit k-means (Lloyd's, `iters` rounds, seeded init) on the rows of
    /// `embeddings` `[vocab, d]`; cluster = expert.
    pub fn fit(num_experts: usize, embeddings: &Tensor, iters: usize, seed: u64) -> Self {
        let vocab = embeddings.rows();
        let d = embeddings.row_len();
        let mut rng = Rng::seed(seed ^ 0xC1_0573);
        // Init: distinct random rows as centroids.
        let mut centroid_idx: Vec<usize> = (0..vocab).collect();
        rng.shuffle(&mut centroid_idx);
        let mut centroids: Vec<Vec<f32>> = centroid_idx
            .iter()
            .take(num_experts)
            .map(|&i| embeddings.row(i).to_vec())
            .collect();
        // If vocab < E, repeat rows.
        while centroids.len() < num_experts {
            let i = rng.below(vocab);
            centroids.push(embeddings.row(i).to_vec());
        }
        let mut table = vec![0u32; vocab];
        for _ in 0..iters.max(1) {
            // Assign.
            for v in 0..vocab {
                let row = embeddings.row(v);
                let mut best = 0usize;
                let mut bd = f32::INFINITY;
                for (c, cent) in centroids.iter().enumerate() {
                    let mut dist = 0.0f32;
                    for j in 0..d {
                        let diff = row[j] - cent[j];
                        dist += diff * diff;
                    }
                    if dist < bd {
                        bd = dist;
                        best = c;
                    }
                }
                table[v] = best as u32;
            }
            // Update.
            let mut sums = vec![vec![0.0f32; d]; num_experts];
            let mut counts = vec![0usize; num_experts];
            for v in 0..vocab {
                let c = table[v] as usize;
                counts[c] += 1;
                for j in 0..d {
                    sums[c][j] += embeddings.at(v, j);
                }
            }
            for c in 0..num_experts {
                if counts[c] > 0 {
                    for j in 0..d {
                        centroids[c][j] = sums[c][j] / counts[c] as f32;
                    }
                } else {
                    // Re-seed empty cluster.
                    let i = rng.below(vocab);
                    centroids[c] = embeddings.row(i).to_vec();
                }
            }
        }
        ClusteredHashGate { num_experts, table }
    }
}

impl Gate for ClusteredHashGate {
    fn name(&self) -> String {
        "hash_clustered".into()
    }

    fn k(&self) -> usize {
        1
    }

    fn num_experts(&self) -> usize {
        self.num_experts
    }

    fn route(&self, batch: &GateBatch) -> Routing {
        let tokens = batch.scores.rows();
        let expert_ids: Vec<u32> = (0..tokens)
            .map(|t| {
                let id = token_id(batch, t) as usize % self.table.len();
                self.table[id]
            })
            .collect();
        Routing {
            k: 1,
            tokens,
            num_experts: self.num_experts,
            expert_ids,
            weights: vec![1.0; tokens],
            aux_loss: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats::load_cv;

    fn batch_of(ids: &[u32], e: usize) -> (Tensor, Vec<u32>) {
        (Tensor::zeros(&[ids.len(), e]), ids.to_vec())
    }

    #[test]
    fn random_hash_is_deterministic_and_spread() {
        let gate = RandomHashGate::new(8);
        let ids: Vec<u32> = (0..1024).collect();
        let (scores, ids) = batch_of(&ids, 8);
        let b = GateBatch { scores: &scores, token_ids: Some(&ids), step: 0 };
        let r1 = gate.route(&b);
        let r2 = gate.route(&b);
        assert_eq!(r1.expert_ids, r2.expert_ids);
        // Roughly uniform across experts.
        assert!(load_cv(&r1.expert_counts()) < 0.25);
    }

    #[test]
    fn same_token_always_same_expert() {
        let gate = RandomHashGate::new(4);
        let ids = vec![42u32; 16];
        let (scores, ids) = batch_of(&ids, 4);
        let r = gate.route(&GateBatch { scores: &scores, token_ids: Some(&ids), step: 0 });
        assert!(r.expert_ids.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    fn balanced_hash_exact_vocab_balance() {
        let gate = BalancedHashGate::new(4, 100);
        // Count vocab entries per expert.
        let mut counts = vec![0usize; 4];
        for v in 0..100u32 {
            let (scores, ids) = batch_of(&[v], 4);
            let r =
                gate.route(&GateBatch { scores: &scores, token_ids: Some(&ids), step: 0 });
            counts[r.expert_ids[0] as usize] += 1;
        }
        assert_eq!(counts, vec![25; 4]);
    }

    #[test]
    fn clustered_hash_groups_similar_tokens() {
        // Two well-separated blobs of embeddings → the table should give
        // each blob a consistent expert.
        let vocab = 40;
        let d = 4;
        let mut emb = Tensor::zeros(&[vocab, d]);
        for v in 0..vocab {
            let offset = if v < 20 { 10.0 } else { -10.0 };
            for j in 0..d {
                emb.set(v, j, offset + ((v * 7 + j) % 3) as f32 * 0.1);
            }
        }
        let gate = ClusteredHashGate::fit(2, &emb, 10, 0);
        let ids: Vec<u32> = (0..vocab as u32).collect();
        let (scores, ids) = batch_of(&ids, 2);
        let r = gate.route(&GateBatch { scores: &scores, token_ids: Some(&ids), step: 0 });
        let first = r.expert_ids[0];
        assert!(r.expert_ids[..20].iter().all(|&e| e == first));
        let second = r.expert_ids[20];
        assert!(r.expert_ids[20..].iter().all(|&e| e == second));
        assert_ne!(first, second);
    }

    #[test]
    fn fallback_to_row_index_without_ids() {
        let gate = RandomHashGate::new(4);
        let scores = Tensor::zeros(&[8, 4]);
        let r1 = gate.route(&GateBatch { scores: &scores, token_ids: None, step: 0 });
        let r2 = gate.route(&GateBatch { scores: &scores, token_ids: None, step: 9 });
        assert_eq!(r1.expert_ids, r2.expert_ids); // step-independent
        r1.validate().unwrap();
    }
}
