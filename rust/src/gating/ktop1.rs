//! kTop1 gate (M6-T, Yang et al., 2021): experts are partitioned into
//! `k` prototypes; each token takes the top-1 expert *within every
//! prototype* and the prototype outputs are summed.
//!
//! Compared with plain top-k this bounds each prototype's traffic
//! independently and was observed to train better at equal FLOPs.

use crate::error::Result;
use crate::gating::topk::{softmax_of_selected, top1_row};
use crate::gating::{Gate, GateBatch, Routing};

/// M6-style k-prototype top-1 routing. Prototypes are contiguous expert
/// ranges of size `E/k`.
#[derive(Clone, Debug)]
pub struct KTop1Gate {
    num_experts: usize,
    k: usize,
    per_proto: usize,
}

impl KTop1Gate {
    pub fn new(num_experts: usize, k: usize) -> Result<Self> {
        if k == 0 || num_experts % k != 0 {
            return Err(crate::config_err!(
                "kTop1 needs num_experts divisible by k ({num_experts} % {k})"
            ));
        }
        Ok(KTop1Gate { num_experts, k, per_proto: num_experts / k })
    }

    /// Prototype index of an expert.
    pub fn proto_of(&self, expert: usize) -> usize {
        expert / self.per_proto
    }
}

impl Gate for KTop1Gate {
    fn name(&self) -> String {
        format!("{}top1", self.k)
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_experts(&self) -> usize {
        self.num_experts
    }

    fn route(&self, batch: &GateBatch) -> Routing {
        let scores = batch.scores;
        let tokens = scores.rows();
        assert_eq!(scores.row_len(), self.num_experts);
        let mut expert_ids = Vec::with_capacity(tokens * self.k);
        let mut weights = Vec::with_capacity(tokens * self.k);
        for t in 0..tokens {
            let row = scores.row(t);
            for p in 0..self.k {
                let lo = p * self.per_proto;
                let hi = lo + self.per_proto;
                let sub = &row[lo..hi];
                let (i, v) = top1_row(sub);
                // Weight: softmax within the prototype (each prototype
                // contributes an independent mixture component).
                let mut w = [0.0f32; 1];
                softmax_of_selected(sub, &[v], &mut w);
                expert_ids.push((lo + i as usize) as u32);
                // Scale by 1/k so the summed output stays O(1).
                weights.push(w[0] / self.k as f32);
            }
        }
        Routing {
            k: self.k,
            tokens,
            num_experts: self.num_experts,
            expert_ids,
            weights,
            aux_loss: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn one_expert_per_prototype() {
        let mut rng = Rng::seed(0);
        let gate = KTop1Gate::new(8, 4).unwrap();
        let scores = Tensor::randn(&[50, 8], &mut rng);
        let r = gate.route_scores(&scores, 0);
        r.validate().unwrap();
        for t in 0..50 {
            let slots = &r.expert_ids[t * 4..(t + 1) * 4];
            for (p, &e) in slots.iter().enumerate() {
                assert_eq!(gate.proto_of(e as usize), p, "slot {p} expert {e}");
            }
        }
    }

    #[test]
    fn rejects_indivisible() {
        assert!(KTop1Gate::new(8, 3).is_err());
        assert!(KTop1Gate::new(8, 0).is_err());
        assert!(KTop1Gate::new(8, 8).is_ok());
    }

    #[test]
    fn weights_bounded_by_inverse_k() {
        let mut rng = Rng::seed(1);
        let gate = KTop1Gate::new(16, 2).unwrap();
        let scores = Tensor::randn(&[32, 16], &mut rng);
        let r = gate.route_scores(&scores, 0);
        // Each weight ≤ 1/k (softmax prob ≤ 1, scaled by 1/k).
        assert!(r.weights.iter().all(|&w| w <= 0.5 + 1e-6 && w > 0.0));
        // k=1 degenerates to switch-like ids.
        let g1 = KTop1Gate::new(16, 1).unwrap();
        let r1 = g1.route_scores(&scores, 0);
        let sw = crate::gating::SwitchGate::new(16, 1.0).route_scores(&scores, 0);
        assert_eq!(r1.expert_ids, sw.expert_ids);
    }
}
