//! The gating-strategy zoo (paper §3.1, Figure 2).
//!
//! HetuMoE's usability claim is breadth: Switch (Top-1), GShard (Top-2),
//! generic Top-K, M6's kTop1, SAM's hierarchical Top-K, BASE's balanced
//! linear assignment, Hash layers, and Dense-to-Sparse — all behind one
//! [`Gate`] trait so the coordinator, benches and examples treat them
//! uniformly.
//!
//! A gate maps a score matrix `(tokens, experts)` (and optionally token
//! ids / the training step) to a [`Routing`]: `k` expert slots per token
//! with combine weights. Weight `0` marks an inactive slot (used by
//! Dense-to-Sparse whose effective k varies per token).

pub mod base_layer;
pub mod capacity;
pub mod dense_to_sparse;
pub mod gshard;
pub mod hash;
pub mod ktop1;
pub mod sam;
pub mod switch;
pub mod topk;
pub mod topk_gate;

pub use base_layer::BaseLayerGate;
pub use capacity::{apply_capacity, DispatchPlan};
pub use dense_to_sparse::DenseToSparseGate;
pub use gshard::GShardGate;
pub use hash::{BalancedHashGate, ClusteredHashGate, RandomHashGate};
pub use ktop1::KTop1Gate;
pub use sam::SamGate;
pub use switch::SwitchGate;
pub use topk_gate::TopKGate;

use crate::config::{GateKind, HashScheme, MoeConfig};
use crate::error::Result;
use crate::tensor::Tensor;

/// Routing decision for a batch of tokens.
#[derive(Clone, Debug)]
pub struct Routing {
    /// Expert slots per token.
    pub k: usize,
    pub tokens: usize,
    pub num_experts: usize,
    /// Flat `[tokens * k]`: expert id per slot.
    pub expert_ids: Vec<u32>,
    /// Flat `[tokens * k]`: combine weight per slot (0 = inactive slot).
    pub weights: Vec<f32>,
    /// Auxiliary load-balancing loss (0 for gates that don't define one).
    pub aux_loss: f32,
}

impl Routing {
    /// Per-expert demanded token counts (inactive slots excluded).
    pub fn expert_counts(&self) -> Vec<usize> {
        let mut counts = vec![0usize; self.num_experts];
        for (slot, &e) in self.expert_ids.iter().enumerate() {
            if self.weights[slot] != 0.0 {
                counts[e as usize] += 1;
            }
        }
        counts
    }

    /// Mean number of active expert slots per token.
    pub fn mean_active_k(&self) -> f64 {
        let active = self.weights.iter().filter(|&&w| w != 0.0).count();
        active as f64 / self.tokens.max(1) as f64
    }

    /// Internal-consistency check used by tests and debug builds.
    pub fn validate(&self) -> Result<()> {
        if self.expert_ids.len() != self.tokens * self.k
            || self.weights.len() != self.tokens * self.k
        {
            return Err(crate::shape_err!(
                "routing arrays must be tokens*k = {}",
                self.tokens * self.k
            ));
        }
        for &e in &self.expert_ids {
            if e as usize >= self.num_experts {
                return Err(crate::shape_err!(
                    "expert id {e} out of range (E={})",
                    self.num_experts
                ));
            }
        }
        for &w in &self.weights {
            if !(w.is_finite() && w >= 0.0) {
                return Err(crate::shape_err!("bad combine weight {w}"));
            }
        }
        Ok(())
    }
}

/// Input bundle for a gate call.
pub struct GateBatch<'a> {
    /// Raw affinity logits `(tokens, experts)` — typically `x · W_gate`
    /// computed by L2 (or [`crate::nn::matmul`] natively).
    pub scores: &'a Tensor,
    /// Token ids (needed by hash gates; others ignore them).
    pub token_ids: Option<&'a [u32]>,
    /// Training step (needed by Dense-to-Sparse annealing).
    pub step: u64,
}

/// A gating strategy.
pub trait Gate: Send + Sync {
    fn name(&self) -> String;
    /// Expert slots allocated per token (upper bound for variable-k gates).
    fn k(&self) -> usize;
    fn num_experts(&self) -> usize;
    /// Route a batch.
    fn route(&self, batch: &GateBatch) -> Routing;

    /// Convenience wrapper: route from scores only.
    fn route_scores(&self, scores: &Tensor, step: u64) -> Routing {
        self.route(&GateBatch { scores, token_ids: None, step })
    }
}

/// Instantiate a gate from config. `vocab_size` and `embeddings` feed the
/// hash gates (balanced needs the vocab, clustered needs token vectors).
pub fn make_gate(
    cfg: &MoeConfig,
    vocab_size: usize,
    embeddings: Option<&Tensor>,
) -> Result<Box<dyn Gate>> {
    cfg.validate()?;
    let e = cfg.num_experts;
    Ok(match &cfg.gate {
        GateKind::Switch => Box::new(SwitchGate::new(e, cfg.capacity_factor as f32)),
        GateKind::GShard => Box::new(GShardGate::new(e)),
        GateKind::TopK { k } => Box::new(TopKGate::new(e, *k)),
        GateKind::KTop1 { k } => Box::new(KTop1Gate::new(e, *k)?),
        GateKind::SamHTopK { groups, k } => Box::new(SamGate::new(e, *groups, *k)?),
        GateKind::Base => Box::new(BaseLayerGate::new(e)),
        GateKind::Hash { scheme } => match scheme {
            HashScheme::Random => Box::new(RandomHashGate::new(e)),
            HashScheme::Balanced => Box::new(BalancedHashGate::new(e, vocab_size)),
            HashScheme::Clustered => {
                let emb = embeddings.ok_or_else(|| {
                    crate::config_err!("clustered hash gate needs an embedding table")
                })?;
                Box::new(ClusteredHashGate::fit(e, emb, 10, 0))
            }
        },
        GateKind::DenseToSparse { tau0, tau_min, anneal_steps } => Box::new(
            DenseToSparseGate::new(e, *tau0 as f32, *tau_min as f32, *anneal_steps, 0),
        ),
    })
}

/// Switch-style auxiliary load-balancing loss:
/// `E · Σ_e f_e · P_e`, where `f_e` is the fraction of tokens whose top
/// choice is `e` and `P_e` the mean router probability of `e`.
pub(crate) fn aux_loss(probs: &Tensor, top1: &[u32], num_experts: usize) -> f32 {
    let tokens = probs.rows();
    if tokens == 0 {
        return 0.0;
    }
    let mut f = vec![0.0f64; num_experts];
    for &e in top1 {
        f[e as usize] += 1.0;
    }
    let mut p = vec![0.0f64; num_experts];
    for t in 0..tokens {
        for (e, pe) in p.iter_mut().enumerate() {
            *pe += probs.at(t, e) as f64;
        }
    }
    let n = tokens as f64;
    let mut loss = 0.0f64;
    for e in 0..num_experts {
        loss += (f[e] / n) * (p[e] / n);
    }
    (loss * num_experts as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn routing_counts_and_validation() {
        let r = Routing {
            k: 2,
            tokens: 3,
            num_experts: 4,
            expert_ids: vec![0, 1, 1, 2, 3, 3],
            weights: vec![0.5, 0.5, 1.0, 0.0, 0.6, 0.4],
            aux_loss: 0.0,
        };
        r.validate().unwrap();
        assert_eq!(r.expert_counts(), vec![1, 2, 0, 2]); // slot with w=0 excluded
        assert!((r.mean_active_k() - 5.0 / 3.0).abs() < 1e-9);
    }

    #[test]
    fn routing_validation_catches_bad_ids() {
        let r = Routing {
            k: 1,
            tokens: 1,
            num_experts: 2,
            expert_ids: vec![5],
            weights: vec![1.0],
            aux_loss: 0.0,
        };
        assert!(r.validate().is_err());
    }

    #[test]
    fn aux_loss_uniform_is_one() {
        // Perfectly uniform probs and assignment → loss = E * E * (1/E)*(1/E) = 1.
        let e = 4;
        let tokens = 8;
        let probs = Tensor::full(&[tokens, e], 1.0 / e as f32);
        let top1: Vec<u32> = (0..tokens as u32).map(|t| t % e as u32).collect();
        let loss = aux_loss(&probs, &top1, e);
        assert!((loss - 1.0).abs() < 1e-5);
    }

    #[test]
    fn aux_loss_penalizes_collapse() {
        let e = 4;
        let tokens = 8;
        let mut probs = Tensor::zeros(&[tokens, e]);
        for t in 0..tokens {
            probs.set(t, 0, 1.0); // all mass on expert 0
        }
        let top1 = vec![0u32; tokens];
        assert!(aux_loss(&probs, &top1, e) > 3.0);
    }

    #[test]
    fn make_gate_covers_all_kinds() {
        let mut rng = Rng::seed(0);
        let emb = Tensor::randn(&[32, 8], &mut rng);
        let kinds = vec![
            GateKind::Switch,
            GateKind::GShard,
            GateKind::TopK { k: 3 },
            GateKind::KTop1 { k: 2 },
            GateKind::SamHTopK { groups: 2, k: 2 },
            GateKind::Base,
            GateKind::Hash { scheme: HashScheme::Random },
            GateKind::Hash { scheme: HashScheme::Balanced },
            GateKind::Hash { scheme: HashScheme::Clustered },
            GateKind::DenseToSparse { tau0: 2.0, tau_min: 0.2, anneal_steps: 100 },
        ];
        for gate_kind in kinds {
            let cfg = MoeConfig {
                num_experts: 8,
                d_model: 8,
                ffn_hidden: 16,
                capacity_factor: 1.25,
                gate: gate_kind.clone(),
            };
            let gate = make_gate(&cfg, 32, Some(&emb)).unwrap();
            let scores = Tensor::randn(&[16, 8], &mut rng);
            let ids: Vec<u32> = (0..16).collect();
            let r = gate.route(&GateBatch { scores: &scores, token_ids: Some(&ids), step: 5 });
            r.validate().unwrap_or_else(|e| panic!("{}: {e}", gate.name()));
            assert_eq!(r.tokens, 16, "{}", gate.name());
            assert!(r.mean_active_k() > 0.0, "{}", gate.name());
        }
    }

    #[test]
    fn clustered_hash_without_embeddings_errors() {
        let cfg = MoeConfig {
            num_experts: 4,
            d_model: 8,
            ffn_hidden: 8,
            capacity_factor: 1.0,
            gate: GateKind::Hash { scheme: HashScheme::Clustered },
        };
        assert!(make_gate(&cfg, 16, None).is_err());
    }
}
