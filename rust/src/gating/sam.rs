//! SAM hierarchical gate ("Switch and Mixture", Jiang et al., 2021).
//!
//! Experts are grouped by device: a *Switch router* first picks one group
//! per token (top-1 over group scores), then a *Mixture router* picks
//! top-k experts **within** that group. All of a token's experts live on
//! one device, so cross-device traffic is bounded by the group choice —
//! the communication-aware routing the paper lists as "H Topk".

use crate::error::Result;
use crate::gating::topk::{softmax_of_selected, top1_row, topk_select_row};
use crate::gating::{Gate, GateBatch, Routing};

/// Hierarchical switch-then-mixture gate.
#[derive(Clone, Debug)]
pub struct SamGate {
    num_experts: usize,
    groups: usize,
    k: usize,
    per_group: usize,
}

impl SamGate {
    pub fn new(num_experts: usize, groups: usize, k: usize) -> Result<Self> {
        if groups == 0 || num_experts % groups != 0 {
            return Err(crate::config_err!(
                "SAM needs num_experts divisible by groups ({num_experts} % {groups})"
            ));
        }
        let per_group = num_experts / groups;
        if k == 0 || k > per_group {
            return Err(crate::config_err!(
                "SAM k={k} out of range for {per_group} experts/group"
            ));
        }
        Ok(SamGate { num_experts, groups, k, per_group })
    }

    pub fn group_of(&self, expert: usize) -> usize {
        expert / self.per_group
    }
}

impl Gate for SamGate {
    fn name(&self) -> String {
        format!("sam_g{}k{}", self.groups, self.k)
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_experts(&self) -> usize {
        self.num_experts
    }

    fn route(&self, batch: &GateBatch) -> Routing {
        let scores = batch.scores;
        let tokens = scores.rows();
        assert_eq!(scores.row_len(), self.num_experts);
        let mut expert_ids = Vec::with_capacity(tokens * self.k);
        let mut weights = Vec::with_capacity(tokens * self.k);
        let mut group_scores = vec![0.0f32; self.groups];
        let mut sel_ids = vec![0u32; self.k];
        let mut sel_vals = vec![0.0f32; self.k];
        for t in 0..tokens {
            let row = scores.row(t);
            // Switch router: group score = mean expert score in group.
            for g in 0..self.groups {
                let lo = g * self.per_group;
                group_scores[g] = row[lo..lo + self.per_group].iter().sum::<f32>()
                    / self.per_group as f32;
            }
            let (g, _) = top1_row(&group_scores);
            let lo = g as usize * self.per_group;
            let sub = &row[lo..lo + self.per_group];
            // Mixture router: top-k within the chosen group.
            topk_select_row(sub, self.k, &mut sel_ids, &mut sel_vals);
            let mut w = vec![0.0f32; self.k];
            softmax_of_selected(sub, &sel_vals, &mut w);
            let s: f32 = w.iter().sum();
            for (j, &i) in sel_ids.iter().enumerate() {
                expert_ids.push((lo + i as usize) as u32);
                weights.push(w[j] / s);
            }
        }
        Routing {
            k: self.k,
            tokens,
            num_experts: self.num_experts,
            expert_ids,
            weights,
            aux_loss: 0.0,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn all_slots_in_one_group() {
        let mut rng = Rng::seed(0);
        let gate = SamGate::new(16, 4, 3).unwrap();
        let scores = Tensor::randn(&[40, 16], &mut rng);
        let r = gate.route_scores(&scores, 0);
        r.validate().unwrap();
        for t in 0..40 {
            let slots = &r.expert_ids[t * 3..(t + 1) * 3];
            let g0 = gate.group_of(slots[0] as usize);
            assert!(slots.iter().all(|&e| gate.group_of(e as usize) == g0));
            // Distinct experts within the group.
            let mut s = slots.to_vec();
            s.sort_unstable();
            s.dedup();
            assert_eq!(s.len(), 3);
        }
    }

    #[test]
    fn weights_renormalized() {
        let mut rng = Rng::seed(1);
        let gate = SamGate::new(8, 2, 2).unwrap();
        let r = gate.route_scores(&Tensor::randn(&[16, 8], &mut rng), 0);
        for t in 0..16 {
            let s: f32 = r.weights[t * 2..(t + 1) * 2].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn picks_the_strong_group() {
        // Make group 1 (experts 4..8) uniformly dominant for token 0.
        let mut scores = Tensor::zeros(&[1, 8]);
        for e in 4..8 {
            scores.set(0, e, 5.0);
        }
        let gate = SamGate::new(8, 2, 2).unwrap();
        let r = gate.route_scores(&scores, 0);
        assert!(r.expert_ids.iter().all(|&e| e >= 4));
    }

    #[test]
    fn constructor_validation() {
        assert!(SamGate::new(16, 3, 1).is_err()); // 16 % 3
        assert!(SamGate::new(16, 4, 5).is_err()); // k > per_group
        assert!(SamGate::new(16, 4, 4).is_ok());
    }
}
