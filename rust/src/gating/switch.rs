//! Switch Transformer gate (Fedus et al., 2021): top-1 routing with a
//! capacity factor and an auxiliary load-balancing loss.

use crate::gating::topk::{softmax_of_selected, top1_row};
use crate::gating::{aux_loss, Gate, GateBatch, Routing};
use crate::nn::softmax_rows;
use crate::tensor::Tensor;

/// Top-1 gate with auxiliary loss.
#[derive(Clone, Debug)]
pub struct SwitchGate {
    num_experts: usize,
    /// Kept for reporting; capacity is enforced by
    /// [`crate::gating::apply_capacity`].
    pub capacity_factor: f32,
}

impl SwitchGate {
    pub fn new(num_experts: usize, capacity_factor: f32) -> Self {
        SwitchGate { num_experts, capacity_factor }
    }
}

impl Gate for SwitchGate {
    fn name(&self) -> String {
        "switch".into()
    }

    fn k(&self) -> usize {
        1
    }

    fn num_experts(&self) -> usize {
        self.num_experts
    }

    fn route(&self, batch: &GateBatch) -> Routing {
        let scores = batch.scores;
        let tokens = scores.rows();
        assert_eq!(scores.row_len(), self.num_experts);
        let mut expert_ids = Vec::with_capacity(tokens);
        let mut weights = Vec::with_capacity(tokens);
        for t in 0..tokens {
            let row = scores.row(t);
            let (i, v) = top1_row(row);
            let mut p = [0.0f32; 1];
            softmax_of_selected(row, &[v], &mut p);
            expert_ids.push(i);
            weights.push(p[0]);
        }
        // Aux loss needs full probabilities.
        let mut probs = scores.clone();
        softmax_rows(&mut probs);
        let loss = aux_loss(&probs, &expert_ids, self.num_experts);
        Routing {
            k: 1,
            tokens,
            num_experts: self.num_experts,
            expert_ids,
            weights,
            aux_loss: loss,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn routes_to_argmax_with_softmax_weight() {
        let scores =
            Tensor::from_vec(vec![0.0, 2.0, -1.0, 1.0, 0.0, 0.0], &[2, 3]).unwrap();
        let gate = SwitchGate::new(3, 1.25);
        let r = gate.route_scores(&scores, 0);
        r.validate().unwrap();
        assert_eq!(r.expert_ids, vec![1, 0]);
        // Weight = softmax prob of the winner.
        let p0 = (2.0f32).exp() / (1.0 + (2.0f32).exp() + (-1.0f32).exp());
        assert!((r.weights[0] - p0).abs() < 1e-5);
        assert!(r.weights.iter().all(|&w| w > 0.0 && w <= 1.0));
    }

    #[test]
    fn aux_loss_reflects_balance() {
        let mut rng = Rng::seed(0);
        let gate = SwitchGate::new(8, 1.0);
        // Random scores → near-uniform loss ≈ 1.
        let scores = Tensor::randn(&[512, 8], &mut rng);
        let balanced = gate.route_scores(&scores, 0).aux_loss;
        // Biased scores → collapsed routing, loss > balanced.
        let mut biased = Tensor::randn(&[512, 8], &mut rng);
        for t in 0..512 {
            biased.set(t, 0, biased.at(t, 0) + 10.0);
        }
        let collapsed = gate.route_scores(&biased, 0).aux_loss;
        assert!(balanced < 1.5, "balanced={balanced}");
        assert!(collapsed > 4.0, "collapsed={collapsed}");
    }

    #[test]
    fn k_is_one() {
        let gate = SwitchGate::new(4, 1.0);
        assert_eq!(gate.k(), 1);
        assert_eq!(gate.num_experts(), 4);
        let mut rng = Rng::seed(1);
        let r = gate.route_scores(&Tensor::randn(&[10, 4], &mut rng), 0);
        assert!((r.mean_active_k() - 1.0).abs() < 1e-9);
    }
}
