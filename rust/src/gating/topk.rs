//! Top-k selection kernels (the paper's Figure 3 "gating operator").
//!
//! The paper's observation: deep-learning frameworks ship one *generic*
//! top-k (heap/sort based, arbitrary k), but MoE only ever needs tiny k
//! (1 or 2). Specializing removes the heap entirely — a single
//! branch-light pass tracking one (or two) running maxima — and was
//! measured ~25% faster than PyTorch's kernel on average.
//!
//! This module carries both: the specialized kernels (`top1_row`,
//! `top2_row`, `topk_select_row`) that HetuMoE uses, and the generic
//! heap kernel (`topk_heap_row`) standing in for the PyTorch baseline in
//! the Fig-3 bench. Ties resolve to the smallest index in every
//! implementation so results are bit-identical and testable.

use crate::tensor::Tensor;
use crate::util::threadpool::parallel_rows_mut2;
use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Single-pass argmax. Returns (index, value).
#[inline]
pub fn top1_row(row: &[f32]) -> (u32, f32) {
    debug_assert!(!row.is_empty());
    let mut bi = 0u32;
    let mut bv = row[0];
    for (i, &v) in row.iter().enumerate().skip(1) {
        // Strict > keeps the smallest index on ties.
        if v > bv {
            bv = v;
            bi = i as u32;
        }
    }
    (bi, bv)
}

/// Single-pass top-2: two running maxima, no heap, no sort.
/// Returns ([i1, i2], [v1, v2]) with v1 ≥ v2.
#[inline]
pub fn top2_row(row: &[f32]) -> ([u32; 2], [f32; 2]) {
    debug_assert!(row.len() >= 2);
    let (mut i1, mut v1, mut i2, mut v2);
    if row[0] >= row[1] {
        i1 = 0u32;
        v1 = row[0];
        i2 = 1u32;
        v2 = row[1];
    } else {
        i1 = 1;
        v1 = row[1];
        i2 = 0;
        v2 = row[0];
    }
    for (i, &v) in row.iter().enumerate().skip(2) {
        if v > v2 {
            if v > v1 {
                i2 = i1;
                v2 = v1;
                i1 = i as u32;
                v1 = v;
            } else {
                i2 = i as u32;
                v2 = v;
            }
        }
    }
    ([i1, i2], [v1, v2])
}

/// Partial selection for small k (3..8): k passes of masked argmax.
/// O(k·E) with perfect cache behaviour — beats a heap for the k values
/// MoE uses.
pub fn topk_select_row(row: &[f32], k: usize, ids: &mut [u32], vals: &mut [f32]) {
    debug_assert!(k <= row.len());
    let mut taken = [false; 512]; // E ≤ 512 in every config we run
    debug_assert!(row.len() <= 512);
    for slot in 0..k {
        let mut bi = usize::MAX;
        let mut bv = f32::NEG_INFINITY;
        for (i, &v) in row.iter().enumerate() {
            if !taken[i] && v > bv {
                bv = v;
                bi = i;
            }
        }
        taken[bi] = true;
        ids[slot] = bi as u32;
        vals[slot] = bv;
    }
}

/// Heap entry ordered by (value, reversed index) so ties pop the smaller
/// index last — matching the specialized kernels' tie-break.
#[derive(PartialEq)]
struct Entry(f32, u32);
impl Eq for Entry {}
impl PartialOrd for Entry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Entry {
    fn cmp(&self, other: &Self) -> Ordering {
        // "Greatest" entry = the one to evict first: smaller value is
        // greater; among equal values the larger index is greater (so the
        // smallest index survives, matching the specialized kernels).
        other
            .0
            .partial_cmp(&self.0)
            .unwrap_or(Ordering::Equal)
            .then(self.1.cmp(&other.1))
    }
}

/// Generic heap-based top-k — the "PyTorch kernel" baseline of Fig 3.
/// Maintains a size-k min-heap over the row; O(E log k) with heap
/// control flow per element.
pub fn topk_heap_row(row: &[f32], k: usize, ids: &mut [u32], vals: &mut [f32]) {
    debug_assert!(k <= row.len());
    let mut heap: BinaryHeap<Entry> = BinaryHeap::with_capacity(k + 1);
    for (i, &v) in row.iter().enumerate() {
        if heap.len() < k {
            heap.push(Entry(v, i as u32));
        } else if let Some(top) = heap.peek() {
            // top is the current minimum (greatest Entry in our order).
            if v > top.0 {
                heap.pop();
                heap.push(Entry(v, i as u32));
            }
        }
    }
    // Drain: pops minimum first → fill back-to-front.
    let mut slot = k;
    while let Some(Entry(v, i)) = heap.pop() {
        slot -= 1;
        ids[slot] = i;
        vals[slot] = v;
    }
    // Equal values must order by ascending index: stable fix-up pass
    // (k is tiny; insertion sort by (value desc, index asc)).
    for a in 1..k {
        let mut b = a;
        while b > 0
            && (vals[b] > vals[b - 1]
                || (vals[b] == vals[b - 1] && ids[b] < ids[b - 1]))
        {
            vals.swap(b, b - 1);
            ids.swap(b, b - 1);
            b -= 1;
        }
    }
}

/// Batched top-k over a score matrix, dispatching to the specialized
/// kernels (HetuMoE's optimized gating operator). Returns flat
/// `[tokens*k]` ids and values. `threads > 1` shards rows.
pub fn topk_rows(scores: &Tensor, k: usize, threads: usize) -> (Vec<u32>, Vec<f32>) {
    let tokens = scores.rows();
    let e = scores.row_len();
    assert!(k >= 1 && k <= e, "k={k} out of range for E={e}");
    let mut ids = vec![0u32; tokens * k];
    let mut vals = vec![0.0f32; tokens * k];
    // Shard rows: each thread owns a disjoint `&mut` chunk of both
    // output buffers.
    parallel_rows_mut2(&mut ids, &mut vals, k, k, threads, |range, ids_out, vals_out| {
        for (local, t) in range.enumerate() {
            let row = scores.row(t);
            let o = local * k;
            match k {
                1 => {
                    let (i, v) = top1_row(row);
                    ids_out[o] = i;
                    vals_out[o] = v;
                }
                2 => {
                    let (i2, v2) = top2_row(row);
                    ids_out[o..o + 2].copy_from_slice(&i2);
                    vals_out[o..o + 2].copy_from_slice(&v2);
                }
                _ => topk_select_row(row, k, &mut ids_out[o..o + k], &mut vals_out[o..o + k]),
            }
        }
    });
    (ids, vals)
}

/// Batched generic heap top-k (baseline for Fig 3).
pub fn topk_rows_heap(scores: &Tensor, k: usize) -> (Vec<u32>, Vec<f32>) {
    let tokens = scores.rows();
    let mut ids = vec![0u32; tokens * k];
    let mut vals = vec![0.0f32; tokens * k];
    for t in 0..tokens {
        topk_heap_row(scores.row(t), k, &mut ids[t * k..(t + 1) * k], &mut vals[t * k..(t + 1) * k]);
    }
    (ids, vals)
}

/// Softmax probabilities of selected slots given raw logits: computes the
/// full-row softmax denominator in one pass and normalizes the selected
/// values (fused, no materialized softmax matrix).
pub fn softmax_of_selected(row: &[f32], vals: &[f32], out: &mut [f32]) {
    let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
    let denom: f32 = row.iter().map(|&v| (v - max).exp()).sum();
    for (o, &v) in out.iter_mut().zip(vals) {
        *o = (v - max).exp() / denom;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn reference_topk(row: &[f32], k: usize) -> (Vec<u32>, Vec<f32>) {
        // Sort by (value desc, index asc) — the specification.
        let mut idx: Vec<usize> = (0..row.len()).collect();
        idx.sort_by(|&a, &b| row[b].total_cmp(&row[a]).then(a.cmp(&b)));
        let ids = idx[..k].iter().map(|&i| i as u32).collect();
        let vals = idx[..k].iter().map(|&i| row[i]).collect();
        (ids, vals)
    }

    #[test]
    fn top1_matches_reference() {
        let row = [0.3, -1.0, 2.5, 2.5, 0.0];
        let (i, v) = top1_row(&row);
        assert_eq!(i, 2); // tie → smallest index
        assert_eq!(v, 2.5);
    }

    #[test]
    fn top2_matches_reference_with_ties() {
        let row = [1.0, 3.0, 3.0, 2.0];
        let ([i1, i2], [v1, v2]) = top2_row(&row);
        assert_eq!((i1, i2), (1, 2));
        assert_eq!((v1, v2), (3.0, 3.0));
        // First two elements ordering edge case.
        let row = [5.0, 5.0, 1.0];
        let ([i1, i2], _) = top2_row(&row);
        assert_eq!((i1, i2), (0, 1));
    }

    #[test]
    fn all_kernels_agree_property() {
        for_all(60, |g| {
            let e = g.usize_in(2..64);
            let row = g.vec_normal(e..e + 1);
            let kmax = e.min(8);
            let k = g.usize_in(1..kmax + 1);
            let (ref_ids, ref_vals) = reference_topk(&row, k);

            // Heap kernel.
            let mut hi = vec![0u32; k];
            let mut hv = vec![0.0f32; k];
            topk_heap_row(&row, k, &mut hi, &mut hv);
            assert_eq!(hi, ref_ids, "heap ids, row={row:?} k={k}");

            // Specialized kernels.
            match k {
                1 => {
                    let (i, v) = top1_row(&row);
                    assert_eq!(vec![i], ref_ids);
                    assert_eq!(vec![v], ref_vals);
                }
                2 => {
                    let (ids, vals) = top2_row(&row);
                    assert_eq!(ids.to_vec(), ref_ids);
                    assert_eq!(vals.to_vec(), ref_vals);
                }
                _ => {
                    let mut si = vec![0u32; k];
                    let mut sv = vec![0.0f32; k];
                    topk_select_row(&row, k, &mut si, &mut sv);
                    assert_eq!(si, ref_ids);
                    assert_eq!(sv, ref_vals);
                }
            }
        });
    }

    #[test]
    fn batched_matches_rowwise_and_parallel() {
        let mut rng = Rng::seed(3);
        let scores = Tensor::randn(&[100, 16], &mut rng);
        for k in [1, 2, 4] {
            let (ids1, vals1) = topk_rows(&scores, k, 1);
            let (ids4, vals4) = topk_rows(&scores, k, 4);
            let (idh, valh) = topk_rows_heap(&scores, k);
            assert_eq!(ids1, ids4, "k={k}");
            assert_eq!(vals1, vals4, "k={k}");
            assert_eq!(ids1, idh, "k={k}");
            assert_eq!(vals1, valh, "k={k}");
        }
    }

    #[test]
    fn duplicate_values_stable_everywhere() {
        let mut row = vec![1.0f32; 16];
        row[7] = 2.0;
        let (ids, _) = topk_rows(&Tensor::from_vec(row.clone(), &[1, 16]).unwrap(), 3, 1);
        assert_eq!(ids, vec![7, 0, 1]);
        let mut hi = vec![0u32; 3];
        let mut hv = vec![0.0f32; 3];
        topk_heap_row(&row, 3, &mut hi, &mut hv);
        assert_eq!(hi, vec![7, 0, 1]);
    }

    #[test]
    fn softmax_of_selected_matches_full() {
        let row = [0.1f32, 1.2, -0.3, 0.8];
        let ([i1, i2], vals) = top2_row(&row);
        let mut probs = [0.0f32; 2];
        softmax_of_selected(&row, &vals, &mut probs);
        // Full softmax reference.
        let max = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let denom: f32 = row.iter().map(|v| (v - max).exp()).sum();
        let full: Vec<f32> = row.iter().map(|v| (v - max).exp() / denom).collect();
        assert!((probs[0] - full[i1 as usize]).abs() < 1e-6);
        assert!((probs[1] - full[i2 as usize]).abs() < 1e-6);
    }
}
