//! Generic Top-K gate (Shazeer et al., 2017): softmax over the selected
//! k expert scores.

use crate::gating::topk::{softmax_of_selected, topk_rows};
use crate::gating::{aux_loss, Gate, GateBatch, Routing};
use crate::nn::softmax_rows;

/// Top-K routing with per-token weight renormalization over the chosen k.
#[derive(Clone, Debug)]
pub struct TopKGate {
    num_experts: usize,
    k: usize,
}

impl TopKGate {
    pub fn new(num_experts: usize, k: usize) -> Self {
        assert!(k >= 1 && k <= num_experts);
        TopKGate { num_experts, k }
    }
}

impl Gate for TopKGate {
    fn name(&self) -> String {
        format!("top{}", self.k)
    }

    fn k(&self) -> usize {
        self.k
    }

    fn num_experts(&self) -> usize {
        self.num_experts
    }

    fn route(&self, batch: &GateBatch) -> Routing {
        let scores = batch.scores;
        let tokens = scores.rows();
        assert_eq!(scores.row_len(), self.num_experts);
        let (expert_ids, vals) = topk_rows(scores, self.k, 1);
        let mut weights = vec![0.0f32; tokens * self.k];
        let mut top1 = Vec::with_capacity(tokens);
        for t in 0..tokens {
            let row = scores.row(t);
            let sel = &vals[t * self.k..(t + 1) * self.k];
            let out = &mut weights[t * self.k..(t + 1) * self.k];
            softmax_of_selected(row, sel, out);
            // Renormalize over the k selected (standard top-k MoE).
            let s: f32 = out.iter().sum();
            for w in out.iter_mut() {
                *w /= s;
            }
            top1.push(expert_ids[t * self.k]);
        }
        let mut probs = scores.clone();
        softmax_rows(&mut probs);
        let loss = aux_loss(&probs, &top1, self.num_experts);
        Routing {
            k: self.k,
            tokens,
            num_experts: self.num_experts,
            expert_ids,
            weights,
            aux_loss: loss,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;

    #[test]
    fn weights_sum_to_one_and_sorted() {
        let mut rng = Rng::seed(0);
        let scores = Tensor::randn(&[32, 16], &mut rng);
        let gate = TopKGate::new(16, 4);
        let r = gate.route_scores(&scores, 0);
        r.validate().unwrap();
        for t in 0..32 {
            let w = &r.weights[t * 4..(t + 1) * 4];
            assert!((w.iter().sum::<f32>() - 1.0).abs() < 1e-5);
            for i in 1..4 {
                assert!(w[i - 1] >= w[i], "weights must be descending");
            }
        }
    }

    #[test]
    fn k1_matches_switch_expert_choice() {
        let mut rng = Rng::seed(1);
        let scores = Tensor::randn(&[20, 8], &mut rng);
        let topk = TopKGate::new(8, 1).route_scores(&scores, 0);
        let switch =
            crate::gating::SwitchGate::new(8, 1.0).route_scores(&scores, 0);
        assert_eq!(topk.expert_ids, switch.expert_ids);
        // Top-1 renormalized weight is exactly 1.
        assert!(topk.weights.iter().all(|&w| (w - 1.0).abs() < 1e-6));
    }

    #[test]
    fn k_equals_e_routes_everywhere() {
        let mut rng = Rng::seed(2);
        let scores = Tensor::randn(&[10, 4], &mut rng);
        let r = TopKGate::new(4, 4).route_scores(&scores, 0);
        for t in 0..10 {
            let mut ids: Vec<u32> = r.expert_ids[t * 4..(t + 1) * 4].to_vec();
            ids.sort_unstable();
            assert_eq!(ids, vec![0, 1, 2, 3]);
        }
    }
}
