//! Data layout transformation (paper §3.2 "Layout Transform
//! Optimization", Figure 4).
//!
//! After the gate decides token→expert, tokens headed to the same expert
//! must be physically contiguous for the AllToAll and the expert batch
//! GEMM. Two implementations:
//! - [`transform::naive_layout`] — argsort-by-expert then gather, the
//!   "state-of-the-art" general implementation the paper compares
//!   against (`O(T log T)`, two passes over the rows);
//! - [`transform::opt_layout`] — HetuMoE's kernel: the
//!   [`crate::gating::DispatchPlan`] already carries exact destination
//!   rows (counting-sort positions computed in `O(T)` during capacity
//!   assignment), so the transform is a single scatter pass,
//!   parallelizable over disjoint token chunks.
//!
//! Both produce bit-identical buffers; Fig-4's bench measures the gap.
//!
//! [`ragged::ragged_layout`] is the padding-free variant (see
//! `ragged.rs` and DESIGN.md §"Dispatch pipelines"): same scatter, but
//! into a [`ragged::RaggedLayoutBuffer`] holding only occupied rows —
//! no zero-fill, no dead rows through the AllToAlls or the expert GEMMs.

pub mod ragged;
pub mod transform;

pub use ragged::{ragged_layout, ragged_reverse_layout, RaggedLayoutBuffer};
pub use transform::{
    gather_expert_slices, naive_layout, opt_layout, reverse_layout, scatter_expert_slices,
    LayoutBuffer,
};
