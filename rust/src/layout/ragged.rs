//! Ragged (padding-free) layout transforms.
//!
//! The padded [`LayoutBuffer`] reserves `cap` rows per expert and
//! zero-fills whatever the capacity rule didn't occupy — at realistic
//! capacity factors 30–80% of the buffer is dead weight that still
//! flows through both AllToAll legs and the expert GEMMs. The
//! [`RaggedLayoutBuffer`] holds **only the occupied rows**, expert-major
//! with per-expert offsets/counts, so downstream phases touch exactly
//! the tokens that exist:
//!
//! - [`ragged_layout`] — the same single scatter pass as
//!   [`opt_layout`], minus the zero-fill: destination row for slot
//!   `(t, j)` is `offsets[e] + position-within-e`, both already in the
//!   [`DispatchPlan`], so the transform stays `O(T·k)` and race-free.
//! - [`ragged_reverse_layout`] — gathers each token's expert outputs
//!   back to its original position, combining with the gate weights
//!   (same math as [`reverse_layout`], ragged addressing).
//!
//! [`LayoutBuffer`]: crate::layout::LayoutBuffer
//! [`opt_layout`]: crate::layout::opt_layout
//! [`reverse_layout`]: crate::layout::reverse_layout

use crate::error::Result;
use crate::gating::DispatchPlan;
use crate::tensor::Tensor;
use crate::util::threadpool::parallel_rows_mut;

/// Padding-free expert-major buffer: row `offsets[e] + p` holds the
/// `p`-th token accepted by expert `e`; there are no other rows.
#[derive(Clone, Debug)]
pub struct RaggedLayoutBuffer {
    /// `[occupied, d]` — every row carries a real token.
    pub data: Tensor,
    /// Per-expert start row, length `E + 1` (prefix sums of `counts`).
    pub offsets: Vec<usize>,
    /// Kept rows per expert (`counts[e] == offsets[e+1] - offsets[e]`).
    pub counts: Vec<usize>,
}

impl RaggedLayoutBuffer {
    /// Rebuild the buffer around data returned from an exchange (the
    /// reverse path takes ownership — no clone).
    pub fn from_plan(data: Vec<f32>, plan: &DispatchPlan, d: usize) -> Result<Self> {
        let occupied = plan.occupied_rows();
        let data = Tensor::from_vec(data, &[occupied, d])?;
        Ok(RaggedLayoutBuffer {
            data,
            offsets: plan.ragged_offsets(),
            counts: plan.kept.clone(),
        })
    }

    /// Total occupied rows.
    pub fn occupied(&self) -> usize {
        self.data.rows()
    }

    /// Expert `e`'s rows — always exactly its kept tokens, contiguous.
    pub fn expert_rows(&self, e: usize) -> &[f32] {
        let d = self.data.row_len();
        &self.data.data()[self.offsets[e] * d..self.offsets[e + 1] * d]
    }

    /// Ragged row index of a padded-buffer destination slot (also used
    /// by the backward pass's gradient scatter in `backprop/`).
    pub(crate) fn ragged_row(offsets: &[usize], capacity: usize, dest: usize) -> usize {
        let e = dest / capacity;
        offsets[e] + (dest - e * capacity)
    }
}

/// Forward ragged transform: invert the plan's destination slots into a
/// per-row source map (every ragged row carries a real token — FCFS
/// packs each expert's block 0..kept[e] and the blocks tile
/// 0..occupied), then gather rows. `threads > 1` shards the ragged rows
/// into disjoint `&mut` chunks, so the parallel path needs no aliasing
/// tricks.
pub fn ragged_layout(
    tokens: &Tensor,
    plan: &DispatchPlan,
    threads: usize,
) -> RaggedLayoutBuffer {
    let d = tokens.row_len();
    debug_assert_eq!(tokens.rows(), plan.tokens);
    let offsets = plan.ragged_offsets();
    let rows = plan.occupied_rows();
    let k = plan.k;
    let cap = plan.capacity;
    // Invert the padded→ragged row map: src_of[ragged row] = token. The
    // map is injective over kept dests, so one serial pass fills every
    // row exactly once.
    let mut src_of = vec![u32::MAX; rows];
    for t in 0..plan.tokens {
        for j in 0..k {
            let dest = plan.dest[t * k + j];
            if dest != u32::MAX {
                let row = RaggedLayoutBuffer::ragged_row(&offsets, cap, dest as usize);
                src_of[row] = t as u32;
            }
        }
    }
    debug_assert!(src_of.iter().all(|&s| s != u32::MAX), "ragged rows tile 0..occupied");
    let mut out = Tensor::zeros(&[rows, d]);
    parallel_rows_mut(out.data_mut(), d, threads, |range, chunk| {
        for (off, r) in range.enumerate() {
            chunk[off * d..(off + 1) * d].copy_from_slice(tokens.row(src_of[r] as usize));
        }
    });
    RaggedLayoutBuffer { data: out, offsets, counts: plan.kept.clone() }
}

/// Reverse ragged transform: weighted combine of each token's expert
/// outputs back into `[T, d]`; dropped slots contribute nothing.
pub fn ragged_reverse_layout(
    buffer: &RaggedLayoutBuffer,
    plan: &DispatchPlan,
    threads: usize,
) -> Tensor {
    let d = buffer.data.row_len();
    let k = plan.k;
    let cap = plan.capacity;
    let mut out = Tensor::zeros(&[plan.tokens, d]);
    parallel_rows_mut(out.data_mut(), d, threads, |range, chunk| {
        for (off, t) in range.enumerate() {
            let dst = &mut chunk[off * d..(off + 1) * d];
            for j in 0..k {
                let slot = t * k + j;
                let dest = plan.dest[slot];
                if dest == u32::MAX {
                    continue;
                }
                let w = plan.weights[slot];
                let row =
                    RaggedLayoutBuffer::ragged_row(&buffer.offsets, cap, dest as usize);
                let src = buffer.data.row(row);
                for (o, &s) in dst.iter_mut().zip(src) {
                    *o += w * s;
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gating::{apply_capacity, Gate, Routing, SwitchGate};
    use crate::layout::{opt_layout, reverse_layout};
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn plan_from(ids: &[u32], e: usize, cap: usize) -> DispatchPlan {
        let r = Routing {
            k: 1,
            tokens: ids.len(),
            num_experts: e,
            expert_ids: ids.to_vec(),
            weights: vec![1.0; ids.len()],
            aux_loss: 0.0,
        };
        apply_capacity(&r, cap)
    }

    #[test]
    fn ragged_holds_only_occupied_rows() {
        let tokens = Tensor::from_vec(
            vec![
                1.0, 1.0, // t0 -> e1
                2.0, 2.0, // t1 -> e0
                3.0, 3.0, // t2 -> e1
            ],
            &[3, 2],
        )
        .unwrap();
        let plan = plan_from(&[1, 0, 1], 2, 8); // padded would be 16 rows
        let buf = ragged_layout(&tokens, &plan, 1);
        assert_eq!(buf.occupied(), 3);
        assert_eq!(buf.offsets, vec![0, 1, 3]);
        assert_eq!(buf.expert_rows(0), &[2.0, 2.0]);
        assert_eq!(buf.expert_rows(1), &[1.0, 1.0, 3.0, 3.0]);
    }

    #[test]
    fn ragged_matches_padded_occupied_rows() {
        let mut rng = Rng::seed(3);
        for (n, e, cap_frac) in [(64, 8, 1.0), (200, 16, 0.5), (33, 4, 2.0)] {
            let tokens = Tensor::randn(&[n, 8], &mut rng);
            let scores = Tensor::randn(&[n, e], &mut rng);
            let r = SwitchGate::new(e, 1.0).route_scores(&scores, 0);
            let cap = (((n as f64 / e as f64) * cap_frac).ceil() as usize).max(1);
            let plan = apply_capacity(&r, cap);
            let padded = opt_layout(&tokens, &plan, 1);
            let ragged = ragged_layout(&tokens, &plan, 1);
            for ex in 0..e {
                assert_eq!(
                    ragged.expert_rows(ex),
                    padded.expert_rows(ex, plan.kept[ex]),
                    "expert {ex}: ragged rows must equal the padded buffer's occupied rows"
                );
            }
            // And the reverse transforms agree bit-for-bit.
            let a = reverse_layout(&padded, &plan, 1);
            let b = ragged_reverse_layout(&ragged, &plan, 1);
            assert_eq!(a, b);
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::seed(5);
        let tokens = Tensor::randn(&[301, 16], &mut rng);
        let scores = Tensor::randn(&[301, 8], &mut rng);
        let r = SwitchGate::new(8, 1.25).route_scores(&scores, 0);
        let plan = apply_capacity(&r, 48);
        let s = ragged_layout(&tokens, &plan, 1);
        for threads in [2, 4, 8] {
            let p = ragged_layout(&tokens, &plan, threads);
            assert_eq!(s.data, p.data, "threads={threads}");
        }
        let rs = ragged_reverse_layout(&s, &plan, 1);
        for threads in [2, 4] {
            let rp = ragged_reverse_layout(&s, &plan, threads);
            assert!(rs.allclose(&rp, 0.0));
        }
    }

    #[test]
    fn roundtrip_property() {
        for_all(16, |g| {
            let e = g.usize_in(2..6);
            let n = g.usize_in(1..60);
            let d = g.usize_in(1..8);
            let ids: Vec<u32> = (0..n).map(|_| g.u32_in(0..e as u32)).collect();
            let mut rng = Rng::seed(g.case as u64 + 31);
            let tokens = Tensor::randn(&[n, d], &mut rng);
            let plan = plan_from(&ids, e, n.max(1)); // no drops
            let buf = ragged_layout(&tokens, &plan, 1);
            assert_eq!(buf.occupied(), n, "unbounded capacity keeps every token");
            let back = ragged_reverse_layout(&buf, &plan, 1);
            assert!(back.allclose(&tokens, 1e-5));
        });
    }

    #[test]
    fn dropped_tokens_come_back_zero() {
        let tokens = Tensor::from_vec(vec![1.0, 2.0, 3.0], &[3, 1]).unwrap();
        let plan = plan_from(&[0, 0, 0], 2, 1);
        let buf = ragged_layout(&tokens, &plan, 1);
        assert_eq!(buf.occupied(), 1);
        let back = ragged_reverse_layout(&buf, &plan, 1);
        assert_eq!(back.row(0), &[1.0]);
        assert_eq!(back.row(1), &[0.0]);
        assert_eq!(back.row(2), &[0.0]);
    }

    #[test]
    fn from_plan_roundtrips_exchange_data() {
        let mut rng = Rng::seed(9);
        let tokens = Tensor::randn(&[20, 4], &mut rng);
        let ids: Vec<u32> = (0..20).map(|t| (t % 3) as u32).collect();
        let plan = plan_from(&ids, 3, 20);
        let buf = ragged_layout(&tokens, &plan, 1);
        let rebuilt =
            RaggedLayoutBuffer::from_plan(buf.data.data().to_vec(), &plan, 4).unwrap();
        assert_eq!(rebuilt.offsets, buf.offsets);
        assert_eq!(rebuilt.counts, buf.counts);
        assert!(rebuilt.data.allclose(&buf.data, 0.0));
    }
}
