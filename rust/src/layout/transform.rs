//! Forward / reverse layout transforms.

use crate::gating::DispatchPlan;
use crate::tensor::Tensor;
use crate::util::threadpool::parallel_rows_mut;

/// The padded expert-major buffer `[E*C, d]` produced by the forward
/// transform. Row `e*C + p` holds the `p`-th token accepted by expert
/// `e`; unused rows are zero.
#[derive(Clone, Debug)]
pub struct LayoutBuffer {
    pub data: Tensor,
    pub capacity: usize,
    pub num_experts: usize,
}

impl LayoutBuffer {
    /// Rows of expert `e` that are actually occupied.
    pub fn expert_rows<'a>(&'a self, e: usize, kept: usize) -> &'a [f32] {
        let d = self.data.row_len();
        let lo = e * self.capacity;
        &self.data.data()[lo * d..(lo + kept) * d]
    }
}

/// Gather local expert `le`'s capacity slices from an exchanged padded
/// buffer `[W, epr, cap, d]` into a caller-provided contiguous
/// source-major `[W·cap, d]` batch (the same row order as the ragged
/// receive layout, padding rows interleaved). Shared by the inference
/// and training padded pipelines so the slicing arithmetic has one
/// home.
pub fn gather_expert_slices(
    buf: &[f32],
    rows: &mut Tensor,
    w: usize,
    epr: usize,
    le: usize,
    cap: usize,
) {
    let d = rows.row_len();
    for src in 0..w {
        let off = (src * epr + le) * cap * d;
        rows.data_mut()[src * cap * d..(src + 1) * cap * d]
            .copy_from_slice(&buf[off..off + cap * d]);
    }
}

/// Inverse of [`gather_expert_slices`]: scatter a `[W·cap, d]` result
/// back into the expert's capacity slices of the padded buffer.
pub fn scatter_expert_slices(
    buf: &mut [f32],
    data: &[f32],
    w: usize,
    epr: usize,
    le: usize,
    cap: usize,
    d: usize,
) {
    for src in 0..w {
        let off = (src * epr + le) * cap * d;
        buf[off..off + cap * d].copy_from_slice(&data[src * cap * d..(src + 1) * cap * d]);
    }
}

/// HetuMoE's optimized layout transform: invert the precomputed
/// destinations in the [`DispatchPlan`] into a per-destination source
/// map, then gather each buffer row from its token. `threads > 1`
/// shards the destination-row dimension into disjoint `&mut` chunks, so
/// the parallel path needs no aliasing tricks: every thread owns the
/// rows it writes.
pub fn opt_layout(tokens: &Tensor, plan: &DispatchPlan, threads: usize) -> LayoutBuffer {
    let d = tokens.row_len();
    debug_assert_eq!(tokens.rows(), plan.tokens);
    let rows = plan.buffer_rows();
    let k = plan.k;
    // Invert dest[t*k+j] = buffer row → src_of[row] = token. Every kept
    // dest is unique (enforced by apply_capacity), so the serial fill is
    // one pass; u32::MAX marks padding rows.
    let mut src_of = vec![u32::MAX; rows];
    for t in 0..plan.tokens {
        for j in 0..k {
            let dest = plan.dest[t * k + j];
            if dest != u32::MAX {
                src_of[dest as usize] = t as u32;
            }
        }
    }
    let mut out = Tensor::zeros(&[rows, d]);
    parallel_rows_mut(out.data_mut(), d, threads, |range, chunk| {
        for (off, r) in range.enumerate() {
            let src = src_of[r];
            if src != u32::MAX {
                chunk[off * d..(off + 1) * d].copy_from_slice(tokens.row(src as usize));
            }
        }
    });
    LayoutBuffer { data: out, capacity: plan.capacity, num_experts: plan.num_experts }
}

/// Baseline layout transform (the "PyTorch-style" general path of
/// Fig 4): materialize (expert, token, slot) triples, stable-sort by
/// expert, then gather rows in sorted order while re-deriving positions.
/// Produces a buffer bit-identical to [`opt_layout`].
pub fn naive_layout(tokens: &Tensor, plan: &DispatchPlan) -> LayoutBuffer {
    let d = tokens.row_len();
    let k = plan.k;
    // Collect kept slots as (expert, token) — include slot for stability.
    let mut triples: Vec<(u32, u32)> = Vec::with_capacity(plan.tokens * k);
    for t in 0..plan.tokens {
        for j in 0..k {
            let dest = plan.dest[t * k + j];
            if dest != u32::MAX {
                let e = dest / plan.capacity as u32;
                triples.push((e, (t * k + j) as u32));
            }
        }
    }
    // Stable sort by expert (slot order preserved → same positions as
    // first-come-first-served).
    triples.sort_by_key(|&(e, _)| e);
    let mut out = Tensor::zeros(&[plan.buffer_rows(), d]);
    let mut fill = vec![0usize; plan.num_experts];
    for &(e, slot) in &triples {
        let t = slot as usize / k;
        let row = tokens.row(t);
        let pos = e as usize * plan.capacity + fill[e as usize];
        out.row_mut(pos).copy_from_slice(row);
        fill[e as usize] += 1;
    }
    LayoutBuffer { data: out, capacity: plan.capacity, num_experts: plan.num_experts }
}

/// Reverse layout transform ("Reverse_Layout_Transform" of Algorithm 1):
/// gather each token's expert outputs back to its original position,
/// combining with the gate weights. Dropped slots contribute nothing
/// (residual connection handles them upstream).
pub fn reverse_layout(buffer: &LayoutBuffer, plan: &DispatchPlan, threads: usize) -> Tensor {
    let d = buffer.data.row_len();
    let k = plan.k;
    let mut out = Tensor::zeros(&[plan.tokens, d]);
    parallel_rows_mut(out.data_mut(), d, threads, |range, chunk| {
        for (off, t) in range.enumerate() {
            let dst = &mut chunk[off * d..(off + 1) * d];
            for j in 0..k {
                let slot = t * k + j;
                let dest = plan.dest[slot];
                if dest == u32::MAX {
                    continue;
                }
                let w = plan.weights[slot];
                let src = buffer.data.row(dest as usize);
                for (o, &s) in dst.iter_mut().zip(src) {
                    *o += w * s;
                }
            }
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gating::{apply_capacity, Gate, GShardGate, Routing, SwitchGate};
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    fn plan_from(ids: &[u32], e: usize, cap: usize) -> DispatchPlan {
        let r = Routing {
            k: 1,
            tokens: ids.len(),
            num_experts: e,
            expert_ids: ids.to_vec(),
            weights: vec![1.0; ids.len()],
            aux_loss: 0.0,
        };
        apply_capacity(&r, cap)
    }

    #[test]
    fn opt_places_tokens_contiguously() {
        let tokens = Tensor::from_vec(
            vec![
                1.0, 1.0, // t0 -> e1
                2.0, 2.0, // t1 -> e0
                3.0, 3.0, // t2 -> e1
            ],
            &[3, 2],
        )
        .unwrap();
        let plan = plan_from(&[1, 0, 1], 2, 2);
        let buf = opt_layout(&tokens, &plan, 1);
        // e0 buffer rows 0..2: [t1, 0]; e1 rows 2..4: [t0, t2].
        assert_eq!(buf.data.row(0), &[2.0, 2.0]);
        assert_eq!(buf.data.row(1), &[0.0, 0.0]);
        assert_eq!(buf.data.row(2), &[1.0, 1.0]);
        assert_eq!(buf.data.row(3), &[3.0, 3.0]);
    }

    #[test]
    fn naive_matches_opt_bitwise() {
        let mut rng = Rng::seed(0);
        for (tokens_n, e, cap_frac) in [(64, 8, 1.0), (200, 16, 0.5), (33, 4, 2.0)] {
            let tokens = Tensor::randn(&[tokens_n, 8], &mut rng);
            let scores = Tensor::randn(&[tokens_n, e], &mut rng);
            let r = SwitchGate::new(e, 1.0).route_scores(&scores, 0);
            let cap = (((tokens_n as f64 / e as f64) * cap_frac).ceil() as usize).max(1);
            let plan = apply_capacity(&r, cap);
            let a = opt_layout(&tokens, &plan, 1);
            let b = naive_layout(&tokens, &plan);
            assert_eq!(a.data, b.data, "T={tokens_n} E={e} cap={cap}");
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::seed(1);
        let tokens = Tensor::randn(&[301, 16], &mut rng);
        let scores = Tensor::randn(&[301, 8], &mut rng);
        let r = GShardGate::deterministic(8).route_scores(&scores, 0);
        let plan = apply_capacity(&r, 100);
        let s = opt_layout(&tokens, &plan, 1);
        for threads in [2, 4, 8] {
            let p = opt_layout(&tokens, &plan, threads);
            assert_eq!(s.data, p.data, "threads={threads}");
        }
        let rs = reverse_layout(&s, &plan, 1);
        for threads in [2, 4] {
            let rp = reverse_layout(&s, &plan, threads);
            assert!(rs.allclose(&rp, 0.0));
        }
    }

    #[test]
    fn roundtrip_identity_with_unit_weights_no_drops() {
        // k=1, cap ≥ tokens, weights 1 → reverse(opt(x)) == x.
        let mut rng = Rng::seed(2);
        let tokens = Tensor::randn(&[50, 4], &mut rng);
        let ids: Vec<u32> = (0..50).map(|t| (t % 4) as u32).collect();
        let plan = plan_from(&ids, 4, 50);
        let buf = opt_layout(&tokens, &plan, 1);
        let back = reverse_layout(&buf, &plan, 1);
        assert!(back.allclose(&tokens, 1e-6));
    }

    #[test]
    fn roundtrip_property() {
        for_all(16, |g| {
            let e = g.usize_in(2..6);
            let n = g.usize_in(1..60);
            let d = g.usize_in(1..8);
            let ids: Vec<u32> = (0..n).map(|_| g.u32_in(0..e as u32)).collect();
            let mut rng = Rng::seed(g.case as u64 + 7);
            let tokens = Tensor::randn(&[n, d], &mut rng);
            let plan = plan_from(&ids, e, n.max(1));
            let buf = opt_layout(&tokens, &plan, 1);
            let back = reverse_layout(&buf, &plan, 1);
            assert!(back.allclose(&tokens, 1e-5));
        });
    }

    #[test]
    fn dropped_tokens_come_back_zero() {
        // Capacity 1, three tokens to the same expert → tokens 1,2 dropped.
        let tokens = Tensor::from_vec(vec![1.0, 2.0, 3.0], &[3, 1]).unwrap();
        let plan = plan_from(&[0, 0, 0], 2, 1);
        let buf = opt_layout(&tokens, &plan, 1);
        let back = reverse_layout(&buf, &plan, 1);
        assert_eq!(back.row(0), &[1.0]);
        assert_eq!(back.row(1), &[0.0]);
        assert_eq!(back.row(2), &[0.0]);
    }

    #[test]
    fn top2_combines_weighted_sum() {
        // One token to experts 0 and 1 with weights 0.25 / 0.75; expert
        // buffers hold distinct values after "expert compute".
        let tokens = Tensor::from_vec(vec![5.0], &[1, 1]).unwrap();
        let r = Routing {
            k: 2,
            tokens: 1,
            num_experts: 2,
            expert_ids: vec![0, 1],
            weights: vec![0.25, 0.75],
            aux_loss: 0.0,
        };
        let plan = apply_capacity(&r, 1);
        let mut buf = opt_layout(&tokens, &plan, 1);
        // Pretend experts doubled / negated their input.
        buf.data.data_mut()[0] = 10.0; // expert 0 output
        buf.data.data_mut()[1] = -4.0; // expert 1 output
        let back = reverse_layout(&buf, &plan, 1);
        assert!((back.at(0, 0) - (0.25 * 10.0 + 0.75 * -4.0)).abs() < 1e-6);
    }

    #[test]
    fn expert_rows_view() {
        let tokens = Tensor::from_vec(vec![1.0, 2.0, 3.0], &[3, 1]).unwrap();
        let plan = plan_from(&[1, 1, 0], 2, 2);
        let buf = opt_layout(&tokens, &plan, 1);
        assert_eq!(buf.expert_rows(1, plan.kept[1]), &[1.0, 2.0]);
        assert_eq!(buf.expert_rows(0, plan.kept[0]), &[3.0]);
    }
}
