//! # HetuMoE (reproduction)
//!
//! A three-layer (Rust + JAX + Pallas, AOT via XLA/PJRT) reproduction of
//! *HetuMoE: An Efficient Trillion-scale Mixture-of-Expert Distributed
//! Training System* (Nie et al., 2022).
//!
//! The crate is the **Layer-3 coordinator**: it owns the cluster simulator,
//! the collective-communication library (vanilla + hierarchical AllToAll),
//! the full gating-strategy zoo, the optimized layout-transform kernels, the
//! MoE training pipeline (Algorithm 1 of the paper) and the baseline-system
//! reimplementations (DeepSpeed-MoE / FastMoE / Tutel profiles) used by the
//! benchmark harness.
//!
//! Layer 2 (the JAX model) and Layer 1 (Pallas kernels) live under
//! `python/compile/` and are compiled **once** (`make artifacts`) to HLO
//! text; [`runtime`] loads and executes those artifacts through the PJRT
//! CPU client. Python is never on the training hot path. The PJRT
//! execution path is behind the off-by-default `pjrt` cargo feature so
//! the crate builds without the XLA toolchain (see DESIGN.md §8).
//!
//! On top of the training pipeline, [`serve`] turns the same MoE layer
//! into an online inference service: open-loop workload generation,
//! continuous batching under expert-capacity and latency budgets,
//! expert-placement-aware AllToAll selection, and SLO reporting.
//!
//! ## Quick tour
//!
//! ```no_run
//! use hetumoe::gating::{Gate, SwitchGate};
//! use hetumoe::tensor::Tensor;
//! use hetumoe::util::rng::Rng;
//!
//! let mut rng = Rng::seed(0);
//! let scores = Tensor::randn(&[128, 16], &mut rng); // (tokens, experts)
//! let gate = SwitchGate::new(16, 1.25);
//! let routing = gate.route_scores(&scores, 0);
//! assert_eq!(routing.k, 1);
//! ```
//!
//! See `DESIGN.md` for the full system inventory and `EXPERIMENTS.md` for
//! the paper-figure reproductions.

// The whole simulator is safe Rust: parallel kernels carve disjoint
// `&mut` row chunks via `util::threadpool` instead of raw-pointer
// scatter. Enforced here (and spot-checked by `cargo x analysis`).
#![forbid(unsafe_code)]

pub mod backprop;
pub mod baselines;
pub mod benchkit;
pub mod ckpt;
pub mod cli;
pub mod cluster;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod fault;
pub mod gating;
pub mod layout;
pub mod moe;
pub mod nn;
pub mod obs;
pub mod pipeline;
pub mod placement;
pub mod runtime;
pub mod serve;
pub mod tensor;
pub mod train;
pub mod util;

/// Crate version string (from Cargo).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
