//! `hetumoe` — the leader binary.
//!
//! Subcommands:
//! - `train`        — end-to-end training on the AOT artifacts
//! - `layer-bench`  — time the MoE layer pipeline (real CPU execution)
//! - `sim`          — analytic cluster-scale simulation of all systems
//! - `gate-stats`   — routing/load-balance diagnostics for every gate
//! - `alltoall`     — compare flat vs hierarchical AllToAll
//! - `serve`        — online inference serving on the simulated cluster
//! - `metrics`      — pinned fig benches → `BENCH_<n>.json` + regression gate
//! - `info`         — artifact + platform inventory

use hetumoe::baselines::{sim_step, SystemKind, SystemProfile};
use hetumoe::benchkit::Table;
use hetumoe::cli::{usage, Args, CommandSpec};
use hetumoe::cluster::{GpuModel, NetworkModel};
use hetumoe::comm::alltoall::flat_alltoall_timing;
use hetumoe::comm::hierarchical::hierarchical_alltoall_timing;
use hetumoe::config::{ClusterConfig, GateKind, MoeConfig};
use hetumoe::coordinator::Coordinator;
use hetumoe::gating::{make_gate, GateBatch};
use hetumoe::moe::DispatchMode;
use hetumoe::pipeline::ChunkChoice;
use hetumoe::serve::{ArrivalProcess, CommChoice, ServeConfig, ServeEngine};
use hetumoe::tensor::Tensor;
use hetumoe::util::rng::Rng;
use hetumoe::util::stats::{fmt_duration, load_cv, normalized_entropy};

const COMMANDS: &[CommandSpec] = &[
    CommandSpec {
        name: "train",
        about: "end-to-end MoE training (native backward pass; no pjrt needed)",
        options: &[
            ("backend", "native|pjrt (default native; pjrt needs --features pjrt)"),
            ("steps", "training steps (default 300)"),
            ("seed", "model/data seed (default 0)"),
            ("tokens", "tokens per rank per step (default 64)"),
            ("nodes", "simulated nodes (default 2)"),
            ("gpus", "GPUs per node (default 2)"),
            ("experts", "experts (default 8)"),
            ("d-model", "model width (default 32)"),
            ("ffn-hidden", "expert hidden size (default 64)"),
            ("classes", "synthetic-task classes (default 8)"),
            ("lr", "Adam learning rate (default 2e-3)"),
            ("aux-coef", "aux load-balancing loss coefficient (default 0.01)"),
            ("gate", "switch|gshard|topk gate (default switch)"),
            ("dispatch", "padded|ragged pipeline (default ragged)"),
            ("alltoall", "auto|flat|hier schedule selection (default auto)"),
            ("chunks", "auto|N exchange chunks for comm/compute overlap (default auto)"),
            ("dedup", "on|off top-k token dedup on the hierarchical inter-node legs (default on)"),
            ("wire", "f32|bf16|f16 wire format for dispatch/combine payloads (default f32; compressed needs --dispatch ragged)"),
            ("placement", "static|adaptive expert placement (default static; adaptive migrates hot experts at step boundaries)"),
            ("placement-every", "steps between adaptive placement checks (default 25)"),
            ("placement-window", "traffic-window length in steps for the optimizer (default 16)"),
            ("placement-min-gain", "min relative NIC-peak gain to migrate (default 0.01)"),
            ("faults", "fault spec or spec file, e.g. 'straggle:rank=1,x=3;kill:rank=2,step=10' or chaos:seed=7"),
            ("ckpt-every", "checkpoint every N steps (default 0 = never; needs --ckpt-dir)"),
            ("ckpt-dir", "directory for checkpoints (enables rank-failure recovery)"),
            ("restore", "resume from a checkpoint file written by --ckpt-every"),
            ("json", "emit the run summary as JSON (flag)"),
            ("trace-out", "write a Chrome trace of the run (open in Perfetto)"),
            ("config", "JSON config file (pjrt backend)"),
            ("model", "artifact variant (pjrt backend, default e2e)"),
            ("artifacts", "artifact directory (pjrt backend)"),
        ],
    },
    CommandSpec {
        name: "layer-bench",
        about: "run the real MoE-layer pipeline and print the phase breakdown",
        options: &[
            ("system", "hetumoe|tutel|fastmoe|deepspeed (default hetumoe)"),
            ("gate", "switch|gshard|topk|... (default switch)"),
            ("tokens", "tokens per rank (default 512)"),
            ("steps", "iterations (default 5)"),
            ("nodes", "simulated nodes (default 1)"),
            ("gpus", "GPUs per node (default 2)"),
            ("dispatch", "padded|ragged pipeline (default: ragged for hetumoe, padded baselines)"),
            ("alltoall", "auto|flat|hier per-step AllToAll selection in ragged mode (default: auto for hetumoe, else the system's flavor)"),
            ("chunks", "auto|N exchange chunks for comm/compute overlap (default: auto for hetumoe, 1 for the 2022-era baselines)"),
            ("dedup", "on|off top-k token dedup on the hierarchical inter-node legs (default on)"),
            ("wire", "f32|bf16|f16 wire format for dispatch/combine payloads (default f32; compressed needs ragged dispatch)"),
            ("seed", "model/data seed (default 0)"),
            ("json", "emit the aggregated StepReport breakdown as JSON (flag)"),
            ("trace-out", "write a Chrome trace of the run (open in Perfetto)"),
        ],
    },
    CommandSpec {
        name: "sim",
        about: "analytic paper-scale simulation of all four systems",
        options: &[
            ("batches", "comma list of batch sizes (default 16,32,64,128)"),
            ("gate", "switch|gshard (default switch)"),
            ("nodes", "nodes (default 1)"),
        ],
    },
    CommandSpec {
        name: "gate-stats",
        about: "load-balance diagnostics for every gating strategy",
        options: &[("tokens", "tokens (default 4096)"), ("experts", "experts (default 16)")],
    },
    CommandSpec {
        name: "alltoall",
        about: "flat vs hierarchical AllToAll on the simulated cluster",
        options: &[
            ("payload-mib", "per-GPU payload MiB (default 16)"),
            ("nodes", "comma list of node counts (default 2,4,8)"),
        ],
    },
    CommandSpec {
        name: "serve",
        about: "online MoE inference serving on the simulated cluster",
        options: &[
            ("rate", "mean request arrival rate, req/s (default 2000)"),
            ("duration", "simulated seconds of traffic (default 2.0)"),
            ("slo-ms", "per-request latency SLO in ms (default 50)"),
            ("gate", "switch|gshard|topk|... (default switch)"),
            ("comm", "flat|hier|auto AllToAll selection (default auto)"),
            ("chunks", "auto|N exchange chunks for comm/compute overlap (default auto)"),
            ("dedup", "on|off top-k token dedup on the hierarchical inter-node legs (default on)"),
            ("wire", "f32|bf16|f16 wire format for dispatch/combine payloads (default f32)"),
            ("placement", "static|adaptive (adaptive replicates hot experts onto cold ranks online)"),
            ("replicate", "comma list of expert:rank replica pins, e.g. 0:3,5:7"),
            ("workload", "poisson|bursty arrivals (default poisson)"),
            ("nodes", "simulated nodes (default 2)"),
            ("gpus", "GPUs per node (default 8)"),
            ("experts", "experts (default 16)"),
            ("d-model", "model width (default 64)"),
            ("max-tokens", "max tokens per request (default 64)"),
            ("faults", "fault spec or spec file (kills are routed around, not recovered)"),
            ("dead-ranks", "comma list of ranks down from the start, e.g. 3,7"),
            ("seed", "workload/model seed (default 0)"),
            ("json", "emit the SLO report as JSON (flag)"),
            ("trace-out", "write a Chrome trace of the run (open in Perfetto)"),
        ],
    },
    CommandSpec {
        name: "metrics",
        about: "run the pinned fig benches, append BENCH_<n>.json, gate on regressions",
        options: &[
            ("dry-run", "run + compare, but do not write the repo-root record (flag)"),
            ("dir", "directory holding BENCH_*.json records (default .)"),
            ("out", "also write the record to this path (e.g. a CI artifact)"),
            ("trace-out", "write a Chrome trace of the fig runs (open in Perfetto)"),
            ("threshold", "fail when a wall metric exceeds previous × this (default 2.0)"),
        ],
    },
    CommandSpec { name: "info", about: "platform + artifact inventory", options: &[] },
];

fn main() {
    let args = Args::from_env();
    let result = match args.subcommand() {
        Some("train") => cmd_train(&args),
        Some("layer-bench") => cmd_layer_bench(&args),
        Some("sim") => cmd_sim(&args),
        Some("gate-stats") => cmd_gate_stats(&args),
        Some("alltoall") => cmd_alltoall(&args),
        Some("serve") => cmd_serve(&args),
        Some("metrics") => cmd_metrics(&args),
        Some("info") => cmd_info(&args),
        _ => {
            println!("hetumoe {} — MoE distributed training (HetuMoE reproduction)", hetumoe::version());
            println!("{}", usage("hetumoe", COMMANDS));
            Ok(())
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

/// Start the global recorder when `--trace-out <path>` was given;
/// returns the path to hand back to [`trace_finish`] after the run.
fn trace_start(args: &Args) -> Option<String> {
    let path = args.get("trace-out")?.to_string();
    hetumoe::obs::TraceRecorder::start();
    Some(path)
}

/// Stop the recorder and write the Chrome-trace JSON (no-op when
/// tracing was never started). Goes to stderr so `--json` stdout stays
/// machine-parseable.
fn trace_finish(path: Option<String>) -> hetumoe::error::Result<()> {
    if let Some(path) = path {
        let trace = hetumoe::obs::TraceRecorder::stop();
        trace.write(&path)?;
        eprintln!("trace written to {path} (open in Perfetto or chrome://tracing)");
    }
    Ok(())
}

fn cmd_train(args: &Args) -> hetumoe::error::Result<()> {
    match args.str_or("backend", "native") {
        "native" => cmd_train_native(args),
        "pjrt" => cmd_train_pjrt(args),
        other => Err(hetumoe::config_err!("unknown backend '{other}' (expected native|pjrt)")),
    }
}

/// The default training path: pure-Rust backward pass + Adam over the
/// simulated cluster (see `backprop/`). No `pjrt` feature required.
fn cmd_train_native(args: &Args) -> hetumoe::error::Result<()> {
    use hetumoe::moe::DispatchMode;
    use hetumoe::train::{smoothed_losses, NativeTrainer, TrainRunConfig};
    use hetumoe::util::json::Json;

    let mut cfg = TrainRunConfig::default_run();
    cfg.steps = args.usize_or("steps", cfg.steps)?;
    cfg.seed = args.u64_or("seed", cfg.seed)?;
    cfg.tokens_per_rank = args.usize_or("tokens", cfg.tokens_per_rank)?;
    cfg.num_classes = args.usize_or("classes", cfg.num_classes)?;
    cfg.lr = args.f64_or("lr", cfg.lr as f64)? as f32;
    cfg.aux_coef = args.f64_or("aux-coef", cfg.aux_coef as f64)? as f32;
    let nodes = args.usize_or("nodes", cfg.cluster.nodes)?;
    let gpus = args.usize_or("gpus", cfg.cluster.gpus_per_node)?;
    cfg.cluster = ClusterConfig { nodes, gpus_per_node: gpus, ..ClusterConfig::commodity(nodes) };
    cfg.moe.num_experts = args.usize_or("experts", cfg.moe.num_experts)?;
    cfg.moe.d_model = args.usize_or("d-model", cfg.moe.d_model)?;
    cfg.moe.ffn_hidden = args.usize_or("ffn-hidden", cfg.moe.ffn_hidden)?;
    cfg.moe.gate = parse_gate(args)?;
    if let Some(v) = args.get("dispatch") {
        cfg.opts.dispatch = DispatchMode::parse(v)?;
    }
    if let Some(v) = args.get("alltoall") {
        cfg.opts.alltoall = CommChoice::parse(v)?;
    }
    if let Some(v) = args.get("chunks") {
        cfg.opts.chunks = ChunkChoice::parse(v)?;
    }
    if let Some(dedup) = parse_dedup(args)? {
        cfg.opts.dedup = dedup;
    }
    if let Some(v) = args.get("wire") {
        cfg.opts.wire = hetumoe::comm::WirePrecision::parse(v)?;
    }
    cfg.placement =
        hetumoe::placement::PlacementPolicy::parse(args.str_or("placement", "static"))?;
    cfg.placement_every = args.usize_or("placement-every", cfg.placement_every)?;
    cfg.placement_window = args.usize_or("placement-window", cfg.placement_window)?;
    cfg.placement_min_gain = args.f64_or("placement-min-gain", cfg.placement_min_gain)?;
    if let Some(spec) = args.get("faults") {
        cfg.faults = hetumoe::fault::FaultPlan::parse(spec)?;
    }
    cfg.ckpt_every = args.usize_or("ckpt-every", cfg.ckpt_every)?;
    if let Some(dir) = args.get("ckpt-dir") {
        cfg.ckpt_dir = Some(dir.to_string());
    }
    // The pipeline's per-expert FFN batches run on the shared pool.
    cfg.opts.threads = hetumoe::util::threadpool::available_parallelism().min(8);
    let json = args.has_flag("json");
    if json {
        cfg.log_every = 0;
    }
    let mut trainer = match args.get("restore") {
        Some(path) => NativeTrainer::from_checkpoint(cfg, std::path::Path::new(path))?,
        None => NativeTrainer::new(cfg)?,
    };
    if !json {
        println!(
            "native training: {} params | {} experts on {}x{} GPUs | {} dispatch, alltoall={}, wire={}",
            trainer.num_params(),
            trainer.cfg.moe.num_experts,
            trainer.cfg.cluster.nodes,
            trainer.cfg.cluster.gpus_per_node,
            trainer.cfg.opts.dispatch.name(),
            trainer.cfg.opts.alltoall.name(),
            trainer.cfg.opts.wire.name(),
        );
    }
    let trace = trace_start(args);
    let summary = trainer.run()?;
    trace_finish(trace)?;
    let losses = trainer.losses();
    let smooth = smoothed_losses(&losses, 0.1);
    if json {
        let j = Json::obj(vec![
            ("steps", Json::num(summary.steps as f64)),
            ("final_loss", Json::num(summary.final_loss as f64)),
            (
                "smoothed_loss",
                Json::arr(smooth.iter().map(|&l| Json::num(l))),
            ),
            (
                "fwd_schedules",
                Json::obj(vec![
                    ("flat", Json::num(summary.fwd_schedules.0 as f64)),
                    ("hier", Json::num(summary.fwd_schedules.1 as f64)),
                ]),
            ),
            (
                "bwd_schedules",
                Json::obj(vec![
                    ("flat", Json::num(summary.bwd_schedules.0 as f64)),
                    ("hier", Json::num(summary.bwd_schedules.1 as f64)),
                ]),
            ),
            ("recovery_steps", Json::num(summary.recovery_steps as f64)),
            ("migrations", Json::num(summary.migrations as f64)),
            ("bytes_migrated", Json::num(summary.bytes_migrated as f64)),
            // `overlap_efficiency` (plus comm/compute exposure, fault
            // counters) rides inside the breakdown object.
            ("breakdown", summary.breakdown.to_json()),
        ]);
        println!("{}", j.dump());
        return Ok(());
    }
    let first = losses.first().copied().unwrap_or(f32::NAN);
    println!(
        "loss: {first:.4} → {:.4} (smoothed {:.4}) over {} steps",
        summary.final_loss,
        smooth.last().copied().unwrap_or(f64::NAN),
        summary.steps
    );
    println!(
        "schedule picks: fwd {}/{} flat/hier, bwd {}/{} flat/hier",
        summary.fwd_schedules.0,
        summary.fwd_schedules.1,
        summary.bwd_schedules.0,
        summary.bwd_schedules.1
    );
    if trainer.cfg.placement.is_adaptive() {
        println!(
            "adaptive placement: {} expert migrations, {} bytes migrated (params + Adam moments)",
            summary.migrations, summary.bytes_migrated
        );
    }
    let b = &summary.breakdown;
    println!(
        "bytes_on_wire/step (NIC): fwd {:.0} bwd {:.0} | intra-node: fwd {:.0} bwd {:.0} | \
         rows_deduped/step {:.1} | expert_flops/step {:.3e}",
        b.bytes_on_wire,
        b.bytes_on_wire_bwd,
        b.bytes_intra_node,
        b.bytes_intra_node_bwd,
        b.rows_deduped,
        b.expert_flops
    );
    println!(
        "overlap: critical_path/step={} comm_exposed={} compute_exposed={} efficiency={:.1}%",
        fmt_duration(b.critical_path),
        fmt_duration(b.comm_exposed),
        fmt_duration(b.compute_exposed),
        100.0 * b.overlap_efficiency
    );
    if b.faults_injected > 0 || summary.recovery_steps > 0 {
        println!(
            "faults: {} injected, {} retries, {}/step delay | recovery re-ran {} steps",
            b.faults_injected,
            b.retries,
            fmt_duration(b.injected_delay),
            summary.recovery_steps
        );
    }
    let mut table = Table::new(
        "per-step phase breakdown (fwd + bwd + opt)",
        &["phase", "mean/step", "fraction"],
    );
    for (name, t) in &b.phases {
        table.row(vec![
            name.clone(),
            fmt_duration(*t),
            format!("{:.1}%", 100.0 * t / b.total),
        ]);
    }
    table.row(vec!["TOTAL".into(), fmt_duration(b.total), "100%".into()]);
    table.emit(None);
    Ok(())
}

#[cfg(not(feature = "pjrt"))]
fn cmd_train_pjrt(_args: &Args) -> hetumoe::error::Result<()> {
    Err(hetumoe::error::HetuError::Runtime(
        "the pjrt backend executes AOT artifacts through PJRT; \
         rebuild with `cargo build --release --features pjrt` \
         (or drop --backend pjrt to use the native trainer)"
            .into(),
    ))
}

#[cfg(feature = "pjrt")]
fn cmd_train_pjrt(args: &Args) -> hetumoe::error::Result<()> {
    use hetumoe::config::{ConfigFile, TrainConfig};
    use hetumoe::train::Trainer;

    let mut cfg = match args.get("config") {
        Some(path) => ConfigFile::load(path)?.train()?,
        None => TrainConfig::default_run(),
    };
    if let Some(m) = args.get("model") {
        cfg.model = m.to_string();
    }
    cfg.steps = args.u64_or("steps", cfg.steps)?;
    cfg.artifact_dir = args.str_or("artifacts", &cfg.artifact_dir).to_string();
    let mut trainer = Trainer::new(cfg)?;
    println!(
        "platform: {} | params: {} tensors / {} elements",
        trainer.runtime.platform(),
        trainer.num_param_tensors(),
        trainer.num_params()
    );
    let logs = trainer.run()?;
    let first = logs.first().map(|l| l.loss).unwrap_or(f32::NAN);
    let last = logs.last().map(|l| l.loss).unwrap_or(f32::NAN);
    println!("loss: {first:.4} → {last:.4} over {} steps", logs.len());
    Ok(())
}

fn parse_system(name: &str) -> SystemKind {
    match name.to_lowercase().as_str() {
        "tutel" => SystemKind::Tutel,
        "fastmoe" => SystemKind::FastMoE,
        "deepspeed" | "deepspeed-moe" => SystemKind::DeepSpeedMoE,
        _ => SystemKind::HetuMoE,
    }
}

/// `--dedup on|off` (None = keep the option struct's default).
fn parse_dedup(args: &Args) -> hetumoe::error::Result<Option<bool>> {
    Ok(match args.get("dedup") {
        None => None,
        Some(v) => match v.to_lowercase().as_str() {
            "on" | "true" | "1" => Some(true),
            "off" | "false" | "0" => Some(false),
            other => {
                return Err(hetumoe::config_err!(
                    "--dedup expects on|off, got '{other}'"
                ));
            }
        },
    })
}

fn parse_gate(args: &Args) -> hetumoe::error::Result<GateKind> {
    Ok(match args.str_or("gate", "switch") {
        "switch" | "top1" => GateKind::Switch,
        "gshard" | "top2" => GateKind::GShard,
        "topk" => GateKind::TopK { k: 4 },
        "base" => GateKind::Base,
        "hash" => GateKind::Hash { scheme: hetumoe::config::HashScheme::Random },
        other => {
            return Err(hetumoe::config_err!(
                "unknown gate '{other}' (expected switch|gshard|topk|base|hash)"
            ));
        }
    })
}

fn cmd_layer_bench(args: &Args) -> hetumoe::error::Result<()> {
    let system = parse_system(args.str_or("system", "hetumoe"));
    let profile = SystemProfile::of(system);
    let nodes = args.usize_or("nodes", 1)?;
    let gpus = args.usize_or("gpus", 2)?;
    let tokens = args.usize_or("tokens", 512)?;
    let steps = args.usize_or("steps", 5)?;
    let mut cluster = ClusterConfig::commodity(nodes);
    cluster.gpus_per_node = gpus;
    let moe = MoeConfig { gate: parse_gate(args)?, ..MoeConfig::bench_layer() };
    let threads = hetumoe::util::threadpool::available_parallelism().min(8);
    let mut opts = profile.options(threads);
    if system == SystemKind::HetuMoE {
        // HetuMoE's modern hot path: padding-free dispatch with per-step
        // schedule + chunk-count selection (the profile itself pins the
        // paper-era padded pipeline for Fig-8 comparability).
        opts.dispatch = DispatchMode::Ragged;
        opts.alltoall = CommChoice::Auto;
        opts.chunks = ChunkChoice::Auto;
        opts.dedup = true;
    }
    if let Some(v) = args.get("dispatch") {
        opts.dispatch = DispatchMode::parse(v)?;
    }
    if let Some(v) = args.get("alltoall") {
        opts.alltoall = CommChoice::parse(v)?;
    }
    if let Some(v) = args.get("chunks") {
        opts.chunks = ChunkChoice::parse(v)?;
    }
    if let Some(dedup) = parse_dedup(args)? {
        opts.dedup = dedup;
    }
    if let Some(v) = args.get("wire") {
        opts.wire = hetumoe::comm::WirePrecision::parse(v)?;
    }
    let dispatch = opts.dispatch;
    let alltoall = opts.alltoall;
    let chunks = opts.chunks;
    let seed = args.u64_or("seed", 0)?;
    let mut coord = Coordinator::new(moe, cluster, opts, 32_000, tokens, seed)?;
    let trace = trace_start(args);
    let summary = coord.run(steps)?;
    trace_finish(trace)?;
    if args.has_flag("json") {
        use hetumoe::util::json::Json;
        let j = Json::obj(vec![
            ("system", Json::str(system.name())),
            ("dispatch", Json::str(dispatch.name())),
            ("alltoall", Json::str(alltoall.name())),
            ("chunks", Json::str(chunks.name())),
            ("steps", Json::num(steps as f64)),
            ("seed", Json::num(seed as f64)),
            ("breakdown", summary.breakdown.to_json()),
        ]);
        println!("{}", j.dump());
        return Ok(());
    }
    let mut table = Table::new(
        &format!(
            "{} MoE layer breakdown ({} steps, {} dispatch, alltoall={}, chunks={})",
            system.name(),
            steps,
            dispatch.name(),
            alltoall.name(),
            chunks.name()
        ),
        &["phase", "mean/step", "fraction"],
    );
    for (name, t) in &summary.breakdown.phases {
        table.row(vec![
            name.clone(),
            fmt_duration(*t),
            format!("{:.1}%", 100.0 * t / summary.breakdown.total),
        ]);
    }
    table.row(vec!["TOTAL".into(), fmt_duration(summary.breakdown.total), "100%".into()]);
    table.emit(None);
    println!(
        "drop_rate={:.3} padding_waste={:.3} aux_loss={:.3}",
        summary.breakdown.drop_rate,
        summary.breakdown.padding_waste,
        summary.breakdown.aux_loss
    );
    println!(
        "bytes_on_wire/step={:.0} (NIC) bytes_intra_node/step={:.0} rows_deduped/step={:.1} \
         expert_flops/step={:.3e} wire={}",
        summary.breakdown.bytes_on_wire,
        summary.breakdown.bytes_intra_node,
        summary.breakdown.rows_deduped,
        summary.breakdown.expert_flops,
        if summary.breakdown.wire.is_empty() { "f32" } else { &summary.breakdown.wire }
    );
    println!(
        "overlap: critical_path/step={} comm_exposed={} compute_exposed={} efficiency={:.1}%",
        fmt_duration(summary.breakdown.critical_path),
        fmt_duration(summary.breakdown.comm_exposed),
        fmt_duration(summary.breakdown.compute_exposed),
        100.0 * summary.breakdown.overlap_efficiency
    );
    Ok(())
}

fn cmd_sim(args: &Args) -> hetumoe::error::Result<()> {
    let batches = args.usize_list_or("batches", &[16, 32, 64, 128])?;
    let nodes = args.usize_or("nodes", 1)?;
    let cluster = ClusterConfig::commodity(nodes);
    let gpu = GpuModel::titan_rtx();
    let moe = MoeConfig { gate: parse_gate(args)?, ..MoeConfig::paper_layer() };
    let mut table = Table::new(
        &format!(
            "Simulated MoE-layer iteration time, {} gate, {}x{} GPUs (paper Fig 8 scale)",
            moe.gate.name(),
            nodes,
            cluster.gpus_per_node
        ),
        &["batch", "HetuMoE", "Tutel", "FastMoE", "DeepSpeed-MoE", "best-baseline/Hetu"],
    );
    for b in batches {
        let tokens = b * 1024; // per-GPU batch, seq len 1024 (paper setting)
        let times: Vec<f64> = SystemKind::all()
            .iter()
            .map(|&k| sim_step(&SystemProfile::of(k), &moe, &cluster, &gpu, tokens).total())
            .collect();
        let hetu = times[0];
        let best_baseline = times[1..].iter().cloned().fold(f64::INFINITY, f64::min);
        table.row(vec![
            b.to_string(),
            fmt_duration(times[0]),
            fmt_duration(times[1]),
            fmt_duration(times[2]),
            fmt_duration(times[3]),
            format!("{:.2}×", best_baseline / hetu),
        ]);
    }
    table.emit(None);
    Ok(())
}

fn cmd_gate_stats(args: &Args) -> hetumoe::error::Result<()> {
    let tokens = args.usize_or("tokens", 4096)?;
    let e = args.usize_or("experts", 16)?;
    let mut rng = Rng::seed(0);
    let scores = Tensor::randn(&[tokens, e], &mut rng);
    let emb = Tensor::randn(&[1024, 16], &mut rng);
    let token_ids: Vec<u32> = (0..tokens as u32).map(|t| t % 1024).collect();
    let kinds = vec![
        GateKind::Switch,
        GateKind::GShard,
        GateKind::TopK { k: 4 },
        GateKind::KTop1 { k: 4 },
        GateKind::SamHTopK { groups: 4, k: 2 },
        GateKind::Base,
        GateKind::Hash { scheme: hetumoe::config::HashScheme::Random },
        GateKind::Hash { scheme: hetumoe::config::HashScheme::Balanced },
        GateKind::DenseToSparse { tau0: 2.0, tau_min: 0.1, anneal_steps: 1000 },
    ];
    let mut table = Table::new(
        &format!("Gating-strategy diagnostics ({tokens} tokens, {e} experts)"),
        &["gate", "mean k", "load CV", "entropy", "aux loss"],
    );
    for kind in kinds {
        let cfg = MoeConfig {
            num_experts: e,
            d_model: 64,
            ffn_hidden: 64,
            capacity_factor: 1.25,
            gate: kind,
        };
        let gate = make_gate(&cfg, 1024, Some(&emb))?;
        let r = gate.route(&GateBatch { scores: &scores, token_ids: Some(&token_ids), step: 100 });
        let counts = r.expert_counts();
        table.row(vec![
            gate.name(),
            format!("{:.2}", r.mean_active_k()),
            format!("{:.3}", load_cv(&counts)),
            format!("{:.3}", normalized_entropy(&counts)),
            format!("{:.3}", r.aux_loss),
        ]);
    }
    table.emit(None);
    Ok(())
}

fn cmd_alltoall(args: &Args) -> hetumoe::error::Result<()> {
    let payload_mib = args.f64_or("payload-mib", 16.0)?;
    let node_list = args.usize_list_or("nodes", &[2, 4, 8])?;
    let payload = (payload_mib * 1024.0 * 1024.0) as usize;
    let mut table = Table::new(
        &format!("Flat vs hierarchical AllToAll ({payload_mib} MiB per GPU, 8 GPUs/node)"),
        &["nodes", "flat", "hierarchical", "speedup"],
    );
    for n in node_list {
        let net = NetworkModel::new(ClusterConfig::commodity(n));
        let chunk = payload / net.cfg.world();
        let flat = flat_alltoall_timing(&net, chunk).total;
        let hier = hierarchical_alltoall_timing(&net, chunk).total;
        table.row(vec![
            n.to_string(),
            fmt_duration(flat),
            fmt_duration(hier),
            format!("{:.2}×", flat / hier),
        ]);
    }
    table.emit(None);
    Ok(())
}

fn cmd_info(args: &Args) -> hetumoe::error::Result<()> {
    println!("hetumoe {}", hetumoe::version());
    let dir = args.str_or("artifacts", "artifacts");
    match hetumoe::runtime::ArtifactRegistry::load(dir) {
        Ok(reg) => {
            println!("artifacts in {dir}:");
            for name in reg.names() {
                let m = reg.get(name)?;
                println!(
                    "  {name}: {} inputs, {} outputs",
                    m.inputs.len(),
                    m.outputs.len()
                );
            }
        }
        Err(e) => println!("no artifacts: {e}"),
    }
    #[cfg(feature = "pjrt")]
    match xla::PjRtClient::cpu() {
        Ok(c) => println!("pjrt: {} ({} devices)", c.platform_name(), c.device_count()),
        Err(e) => println!("pjrt unavailable: {e}"),
    }
    #[cfg(not(feature = "pjrt"))]
    println!("pjrt: disabled at compile time (rebuild with --features pjrt)");
    Ok(())
}

fn cmd_serve(args: &Args) -> hetumoe::error::Result<()> {
    let rate = args.f64_or("rate", 2000.0)?;
    let duration = args.f64_or("duration", 2.0)?;
    let slo = args.f64_or("slo-ms", 50.0)? * 1e-3;
    let nodes = args.usize_or("nodes", 2)?;
    let gpus = args.usize_or("gpus", 8)?;
    let experts = args.usize_or("experts", 16)?;
    let d_model = args.usize_or("d-model", 64)?;
    let max_tokens = args.usize_or("max-tokens", 64)?;
    let seed = args.u64_or("seed", 0)?;
    let comm = CommChoice::parse(args.str_or("comm", "auto"))?;
    let chunks = ChunkChoice::parse(args.str_or("chunks", "auto"))?;
    let dedup = parse_dedup(args)?.unwrap_or(true);
    let wire = match args.get("wire") {
        Some(v) => hetumoe::comm::WirePrecision::parse(v)?,
        None => hetumoe::comm::WirePrecision::F32,
    };
    let workload = args.str_or("workload", "poisson");
    let process = match workload {
        // Calibrated so the long-run mean equals --rate:
        // (3r·0.05 + 0.5r·0.2) / 0.25 = r (see ArrivalProcess::mean_rate).
        "bursty" => ArrivalProcess::Bursty {
            base_rate: rate * 0.5,
            burst_rate: rate * 3.0,
            mean_burst: 0.05,
            mean_calm: 0.2,
        },
        "poisson" => ArrivalProcess::Poisson { rate },
        other => {
            return Err(hetumoe::config_err!(
                "unknown workload '{other}' (expected poisson|bursty)"
            ));
        }
    };

    let mut cluster = ClusterConfig::commodity(nodes);
    cluster.gpus_per_node = gpus;
    let moe = MoeConfig {
        num_experts: experts,
        d_model,
        ffn_hidden: 2 * d_model,
        capacity_factor: 1.25,
        gate: parse_gate(args)?,
    };
    let faults = match args.get("faults") {
        Some(spec) => hetumoe::fault::FaultPlan::parse(spec)?,
        None => hetumoe::fault::FaultPlan::none(),
    };
    let dead_ranks = args.usize_list_or("dead-ranks", &[])?;
    let placement =
        hetumoe::placement::PlacementPolicy::parse(args.str_or("placement", "static"))?;
    let replicas = parse_replicas(args)?;
    let cfg = ServeConfig {
        moe,
        cluster,
        process,
        comm,
        chunks,
        dedup,
        wire,
        slo,
        duration,
        max_tokens,
        seed,
        dead_ranks,
        faults,
        placement,
        replicas,
        ..ServeConfig::default_run()
    };
    let json = args.has_flag("json");
    if !json {
        println!(
            "serving {} gate on {nodes}x{gpus} GPUs | {rate:.0} req/s {workload} arrivals | \
             comm={} | SLO {:.0} ms",
            cfg.moe.gate.name(),
            cfg.comm.name(),
            slo * 1e3,
        );
    }
    let mut engine = ServeEngine::new(cfg)?;
    let trace = trace_start(args);
    let report = engine.run()?;
    trace_finish(trace)?;
    if json {
        println!("{}", report.to_json().dump());
        return Ok(());
    }
    report.emit();
    let (flat_n, hier_n) = engine.router.comm_decisions();
    println!("comm decisions: {flat_n} flat / {hier_n} hierarchical batches");
    let hot = engine.router.hot_experts(1.5);
    if hot.is_empty() {
        println!("hot experts: none (load within 1.5x of mean)");
    } else {
        println!("hot experts (>1.5x mean load): {hot:?}");
    }
    let replica_pairs = engine.router.replicas().pairs();
    if engine.cfg.placement.is_adaptive() || !replica_pairs.is_empty() {
        println!(
            "replicas: {} live (expert, rank) pairs {:?} | {} added adaptively",
            replica_pairs.len(),
            replica_pairs,
            engine.replications
        );
    }
    Ok(())
}

/// `--replicate e:r,e:r,...` → explicit serving replica pins.
fn parse_replicas(args: &Args) -> hetumoe::error::Result<Vec<(usize, usize)>> {
    let Some(spec) = args.get("replicate") else {
        return Ok(Vec::new());
    };
    let mut out = Vec::new();
    for part in spec.split(',').map(str::trim).filter(|s| !s.is_empty()) {
        let (e, r) = part.split_once(':').ok_or_else(|| {
            hetumoe::config_err!("--replicate expects expert:rank pairs, got '{part}'")
        })?;
        let parse = |s: &str| {
            s.trim().parse::<usize>().map_err(|_| {
                hetumoe::config_err!("--replicate: '{s}' is not a number in '{part}'")
            })
        };
        out.push((parse(e)?, parse(r)?));
    }
    Ok(out)
}

/// The perf-trajectory harness: run the pinned fig subset, compare
/// against the newest committed `BENCH_<n>.json`, fail on wall
/// regressions, and (unless `--dry-run`) append this PR's record.
fn cmd_metrics(args: &Args) -> hetumoe::error::Result<()> {
    use hetumoe::obs::metrics;
    use hetumoe::util::json::Json;

    let threshold = args.f64_or("threshold", metrics::DEFAULT_THRESHOLD)?;
    let dir = std::path::PathBuf::from(args.str_or("dir", "."));
    // The baseline (and this record's ordinal) come from the directory
    // scan, not a pinned constant: highest existing record + 1, or
    // FIRST_BENCH_ID on an empty history.
    let baseline = metrics::previous_bench(&dir);
    let next_id =
        baseline.as_ref().map(|(n, _)| n + 1).unwrap_or(metrics::FIRST_BENCH_ID);
    let trace = trace_start(args);
    println!("running the pinned fig subset (fixed seeds and configs)...");
    let figs = metrics::run_figs()?;
    trace_finish(trace)?;
    let rec = metrics::record(figs, next_id);

    let regressions = match baseline {
        Some((n, path)) => {
            let prev = Json::from_file(&path)?;
            let rows = metrics::compare(&prev, &rec, threshold);
            metrics::emit_comparison(&rows, &format!("BENCH_{n}.json"), threshold)
        }
        None => {
            println!(
                "no previous BENCH_*.json in {} — this record is the baseline",
                dir.display()
            );
            0
        }
    };

    if let Some(out) = args.get("out") {
        if let Some(parent) = std::path::Path::new(out).parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(out, rec.pretty())?;
        println!("record written to {out}");
    }
    if regressions > 0 {
        return Err(hetumoe::error::HetuError::Runtime(format!(
            "{regressions} wall metric(s) regressed beyond {threshold:.2}× \
             (see the delta table above); record NOT appended"
        )));
    }
    if args.has_flag("dry-run") {
        println!("dry run: BENCH_{next_id}.json not written");
    } else {
        let dest = dir.join(format!("BENCH_{next_id}.json"));
        std::fs::write(&dest, rec.pretty())?;
        println!("perf record written to {}", dest.display());
    }
    Ok(())
}
