//! Expert executors: the per-expert FFN computation behind Algorithm 1's
//! step 4, abstracted so the pipeline can run either natively (pure Rust,
//! self-contained benches) or through an AOT-compiled XLA artifact (the
//! production path — L1/L2 compute compiled by `python/compile/aot.py`).

use crate::error::Result;
use crate::nn::Ffn;
#[cfg(feature = "pjrt")]
use crate::runtime::HloRunner;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
#[cfg(feature = "pjrt")]
use std::sync::Arc;

/// One expert's forward computation over a row batch `[n, d] → [n, d]`.
///
/// Not `Send`/`Sync`: the PJRT executable handle behind [`HloExpert`]
/// uses non-atomic reference counting. The coordinator executes experts
/// from the leader thread; intra-kernel parallelism lives below this
/// interface.
pub trait ExpertExecutor {
    fn forward(&self, x: &Tensor) -> Result<Tensor>;
    /// Model dimension.
    fn d_model(&self) -> usize;
    /// FLOPs of a forward over `n` rows (for the roofline model).
    fn flops(&self, n: usize) -> f64;
    /// The concrete [`Ffn`] behind this executor, if it has one. The
    /// pipeline's expert stage uses this to run per-expert batches on
    /// the shared thread pool (`Ffn` is plain data and `Sync`; opaque
    /// executors — e.g. PJRT-backed — return `None` and run serially).
    fn as_ffn(&self) -> Option<&Ffn> {
        None
    }
}

/// Pure-Rust FFN expert.
pub struct NativeExpert {
    ffn: Ffn,
}

impl NativeExpert {
    pub fn init(d: usize, h: usize, rng: &mut Rng) -> Self {
        NativeExpert { ffn: Ffn::init(d, h, rng) }
    }

    pub fn ffn(&self) -> &Ffn {
        &self.ffn
    }
}

impl ExpertExecutor for NativeExpert {
    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        Ok(self.ffn.forward(x))
    }

    fn d_model(&self) -> usize {
        self.ffn.d
    }

    fn flops(&self, n: usize) -> f64 {
        self.ffn.flops(n) as f64
    }

    fn as_ffn(&self) -> Option<&Ffn> {
        Some(&self.ffn)
    }
}

/// Artifact-backed expert: runs the `expert_ffn` HLO (fixed `[C, d]`
/// shape) through PJRT. Inputs shorter than `C` are zero-padded; the
/// padding rows are discarded on return.
#[cfg(feature = "pjrt")]
pub struct HloExpert {
    runner: Arc<HloRunner>,
    /// Expert parameters, uploaded once: w1 [d,h], b1 [h], w2 [h,d], b2 [d].
    params: Vec<Tensor>,
    capacity: usize,
    d: usize,
    h: usize,
}

#[cfg(feature = "pjrt")]
impl HloExpert {
    /// `runner` must be the `expert_ffn` artifact; `params` are this
    /// expert's weights in artifact argument order (after the row input).
    pub fn new(runner: Arc<HloRunner>, params: Vec<Tensor>) -> Result<Self> {
        let shape0 = runner
            .meta
            .inputs
            .first()
            .ok_or_else(|| crate::shape_err!("expert artifact has no inputs"))?
            .clone();
        if shape0.len() != 2 {
            return Err(crate::shape_err!(
                "expert artifact input 0 must be rank-2, got {shape0:?}"
            ));
        }
        let h = runner.meta.attr_usize("ffn_hidden")?;
        Ok(HloExpert { runner, params, capacity: shape0[0], d: shape0[1], h })
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }
}

#[cfg(feature = "pjrt")]
impl ExpertExecutor for HloExpert {
    fn forward(&self, x: &Tensor) -> Result<Tensor> {
        let n = x.rows();
        if n > self.capacity {
            return Err(crate::shape_err!(
                "expert got {n} rows, artifact capacity is {}",
                self.capacity
            ));
        }
        // Pad to the artifact's static shape.
        let mut padded = Tensor::zeros(&[self.capacity, self.d]);
        padded.data_mut()[..n * self.d].copy_from_slice(x.data());
        let mut inputs = vec![padded];
        inputs.extend(self.params.iter().cloned());
        let outs = self.runner.run(&inputs)?;
        let full = outs
            .into_iter()
            .next()
            .ok_or_else(|| crate::shape_err!("expert artifact returned nothing"))?;
        Ok(full.slice_rows(0, n))
    }

    fn d_model(&self) -> usize {
        self.d
    }

    fn flops(&self, n: usize) -> f64 {
        (4 * n * self.d * self.h) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_expert_shapes_and_flops() {
        let mut rng = Rng::seed(0);
        let e = NativeExpert::init(8, 16, &mut rng);
        let x = Tensor::randn(&[5, 8], &mut rng);
        let y = e.forward(&x).unwrap();
        assert_eq!(y.shape(), &[5, 8]);
        assert_eq!(e.d_model(), 8);
        assert_eq!(e.flops(5), (2 * 5 * 8 * 16 * 2) as f64);
    }

    #[test]
    fn native_expert_deterministic() {
        let mut r1 = Rng::seed(1);
        let mut r2 = Rng::seed(1);
        let e1 = NativeExpert::init(4, 8, &mut r1);
        let e2 = NativeExpert::init(4, 8, &mut r2);
        let x = Tensor::randn(&[3, 4], &mut r1);
        let x2 = Tensor::randn(&[3, 4], &mut r2);
        assert!(e1.forward(&x).unwrap().allclose(&e2.forward(&x2).unwrap(), 0.0));
    }
}
