//! The expert-parallel MoE layer (Algorithm 1).
//!
//! Tokens live sharded across `W = nodes·gpus_per_node` simulated ranks;
//! experts are partitioned `E/W` per rank. One forward is the paper's
//! six steps, with each implementation choice (gate kernel, layout
//! kernel, AllToAll flavor) pluggable — the baseline systems of Fig 8
//! are exactly different option tuples over this one pipeline.
//!
//! The six steps themselves are **not** implemented here anymore: this
//! layer (like the training layer and, through the timing model, the
//! serving engine) consumes the shared staged pipeline in
//! [`crate::pipeline`] — `MoeLayer::forward` binds its gate kernel and
//! expert executors into a [`crate::pipeline::StepExecutor`] and runs
//! the forward-only flavor. See DESIGN.md §10 for the stage graph and
//! the chunked comm/compute-overlap model that replaced the
//! sum-of-phases wall clock.

use crate::cluster::NetworkModel;
use crate::comm::schedule::{CommChoice, Schedule};
use crate::comm::WirePrecision;
use crate::config::{ClusterConfig, MoeConfig};
use crate::error::Result;
use crate::gating::topk::{softmax_of_selected, topk_rows_heap};
use crate::gating::{apply_capacity, DispatchPlan, Gate, Routing};
use crate::layout::LayoutBuffer;
use crate::moe::expert::ExpertExecutor;
use crate::nn::matmul;
use crate::pipeline::{ChunkChoice, ExpertBank, OverlapTiming, StepExecutor};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Which top-k kernel the gate phase uses (Fig 3's comparison).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateImpl {
    /// HetuMoE's specialized single-pass kernels.
    Fast,
    /// Generic heap-based top-k (PyTorch-style baseline).
    Generic,
}

/// Which layout transform the padded dispatch uses (Fig 4's comparison;
/// [`DispatchMode::Ragged`] always uses the single-pass ragged scatter).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayoutImpl {
    /// Counting-sort scatter (HetuMoE).
    Optimized,
    /// Stable-sort + gather (generic baseline).
    Naive,
    /// Dense one-hot dispatch einsum (DeepSpeed-MoE style): builds the
    /// `[E·C, T]` one-hot matrix and *matmuls* tokens into place. Exact
    /// same result, enormously more FLOPs at small batch — the mechanism
    /// behind the paper's 8.1× gap.
    DenseEinsum,
}

/// AllToAll flavor (Fig 5 vs Fig 6) for the padded pipeline, which
/// exchanges equal chunks and therefore fixes its schedule up front.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommImpl {
    Flat,
    Hierarchical,
}

impl CommImpl {
    pub fn name(&self) -> &'static str {
        match self {
            CommImpl::Flat => Schedule::Flat.name(),
            CommImpl::Hierarchical => Schedule::Hierarchical.name(),
        }
    }
}

impl From<Schedule> for CommImpl {
    fn from(s: Schedule) -> CommImpl {
        match s {
            Schedule::Flat => CommImpl::Flat,
            Schedule::Hierarchical => CommImpl::Hierarchical,
        }
    }
}

/// Which dispatch pipeline the forward runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// Dense `[E, cap, d]` buffers, zero-padded to capacity — kept as
    /// the comparison baseline (and what the Fig-8 systems model).
    Padded,
    /// Padding-free ragged pipeline: occupied rows only, exact-count
    /// AllToAllv, grouped per-expert compute (the default).
    Ragged,
}

impl DispatchMode {
    pub fn parse(s: &str) -> Result<DispatchMode> {
        Ok(match s.to_lowercase().as_str() {
            "padded" | "dense" => DispatchMode::Padded,
            "ragged" | "dropless" => DispatchMode::Ragged,
            other => {
                return Err(crate::config_err!(
                    "unknown dispatch mode '{other}' (expected padded|ragged)"
                ));
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchMode::Padded => "padded",
            DispatchMode::Ragged => "ragged",
        }
    }
}

/// Pipeline options: a baseline system is a tuple of these.
#[derive(Clone, Debug)]
pub struct MoeLayerOptions {
    pub gate_impl: GateImpl,
    pub layout_impl: LayoutImpl,
    /// Fixed AllToAll flavor of the padded pipeline.
    pub comm_impl: CommImpl,
    /// Which dispatch pipeline to run.
    pub dispatch: DispatchMode,
    /// Per-step AllToAll schedule policy of the ragged pipeline
    /// (`Auto` scores the step's traffic matrix, like the serving
    /// router does per batch).
    pub alltoall: CommChoice,
    /// Chunk count of the ragged exchanges for comm/compute overlap
    /// (`Auto` = picked per step alongside the schedule, from the same
    /// traffic matrix; the padded pipeline is never chunked).
    pub chunks: ChunkChoice,
    /// Top-k token deduplication on the hierarchical schedule's
    /// inter-node legs: a token routed to several experts on one remote
    /// node ships once plus a replication index, and the backward's
    /// return leg pre-sums per-token partial gradients at the expert
    /// node (both bit-identical to the flat exchange; see
    /// `comm::hier_ragged`). Also makes the shared schedule pick score
    /// the deduplicated NIC bytes.
    pub dedup: bool,
    /// Threads for the parallel kernels (1 = serial).
    pub threads: usize,
    /// Element format token rows take across the ragged exchanges
    /// (dispatch + combine, forward and backward): activations and
    /// gradients are quantized at the send boundary and widened back to
    /// f32 on receipt, so expert compute and every accumulation stay
    /// f32. Every cost model (schedule pick, overlap chunker, byte
    /// accounting, serving router, placement optimizer) charges the
    /// same element size. [`WirePrecision::F32`] (the default) is
    /// bit-identical to the pre-wire pipeline; the padded baseline
    /// rejects compressed formats.
    pub wire: WirePrecision,
    /// Ranks that are down (hard-failed or `dead:` from the fault
    /// plan). They source zero-row shards and host no experts — the
    /// placement elastically remaps their experts over the survivors
    /// ([`crate::cluster::ExpertPlacement::with_dead`]). A remapped
    /// (non-contiguous) placement forces the flat exchange and
    /// disables top-k dedup, whose node-aggregation math assumes the
    /// contiguous layout. Empty = every rank healthy.
    pub dead_ranks: Vec<usize>,
    /// Adaptive expert→rank assignment installed by the placement
    /// optimizer (`--placement adaptive`): entry `e` is the rank
    /// hosting expert `e`. `None` (the default, and everything
    /// `--placement static` ever sees) keeps the contiguous formula
    /// `rank = e/(E/W)` — bit-identical to the pre-adaptive pipeline.
    /// A non-contiguous table degrades exactly like a dead-rank remap:
    /// flat exchange, dedup off. Dead-rank remapping composes on top
    /// ([`crate::cluster::ExpertPlacement::resolve`]).
    pub placement_table: Option<Vec<usize>>,
}

impl Default for MoeLayerOptions {
    fn default() -> Self {
        MoeLayerOptions {
            gate_impl: GateImpl::Fast,
            layout_impl: LayoutImpl::Optimized,
            comm_impl: CommImpl::Hierarchical,
            dispatch: DispatchMode::Ragged,
            alltoall: CommChoice::Auto,
            chunks: ChunkChoice::Auto,
            dedup: true,
            threads: 1,
            wire: WirePrecision::F32,
            dead_ranks: Vec::new(),
            placement_table: None,
        }
    }
}

/// Per-step timing + routing quality report.
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    /// Measured wall seconds per local phase, averaged per rank.
    pub wall: Vec<(String, f64)>,
    /// Simulated communication timings (chunked exchanges report the
    /// sum of their chunk legs; the overlap fields below carry the
    /// critical-path view).
    pub comm: Vec<(String, f64)>,
    /// Capacity-drop rate across ranks.
    pub drop_rate: f64,
    /// Padding waste of the dispatch buffers (0 in ragged mode — the
    /// buffers hold only occupied rows).
    pub padding_waste: f64,
    /// Global per-expert token counts.
    pub expert_counts: Vec<usize>,
    /// Mean auxiliary loss across ranks.
    pub aux_loss: f64,
    /// Bytes crossing a **node boundary** (NIC traffic) over both
    /// AllToAll legs — placement-aware: a cross-rank row whose source
    /// and destination GPUs share a node is *not* counted here (it
    /// never touches the NIC); under the hierarchical schedule with
    /// dedup this is the post-deduplication figure, replication-index
    /// overhead included. Padding rows count in padded mode — that's
    /// the waste the ragged pipeline removes.
    pub bytes_on_wire: usize,
    /// Bytes moved over the intra-node fabric on both AllToAll legs:
    /// direct same-node cross-rank rows under the flat schedule, the
    /// leader gather + scatter relays under the hierarchical schedule.
    pub bytes_intra_node: usize,
    /// Replica rows the hierarchical dedup/pre-summation kept off the
    /// NIC this step (forward + absorbed backward legs; 0 when the flat
    /// schedule ran or dedup is off).
    pub rows_deduped: usize,
    /// Expert-FFN FLOPs actually executed across all ranks (padded mode
    /// runs capacity rows, occupied or not).
    pub expert_flops: f64,
    /// AllToAll schedule this step ran ("flat" | "hier").
    pub comm_schedule: String,
    /// Wire element format the ragged exchanges used ("f32" | "bf16" |
    /// "f16"; "" until the ragged pipeline fills it in — the padded
    /// baseline is always f32).
    pub wire: String,
    /// NIC bytes over both *backward* AllToAll legs (0 for forward-only
    /// steps; set by the training backward pass, attributed through the
    /// same placement-aware split as the forward legs).
    pub bytes_on_wire_bwd: usize,
    /// Intra-node fabric bytes over both backward AllToAll legs.
    pub bytes_intra_node_bwd: usize,
    /// AllToAll schedule the backward legs ran ("" for forward-only).
    pub comm_schedule_bwd: String,
    /// Chunk count of the forward exchanges (1 = unchunked; the padded
    /// pipeline is always 1).
    pub n_chunks: usize,
    /// Chunk count of the backward exchanges (0 for forward-only steps).
    pub n_chunks_bwd: usize,
    /// Modeled critical-path wall of the overlapped `dispatch → expert
    /// → combine` region(s) — forward plus any absorbed backward. This
    /// replaces the sum-of-phases view: the step's modeled wall is
    /// [`Self::critical_wall`], not [`Self::wall_total`] +
    /// [`Self::comm_total`].
    pub critical_path: f64,
    /// Exchange time left on the critical path (not hidden under
    /// expert compute).
    pub comm_exposed: f64,
    /// Expert compute left on the critical path (not hidden under the
    /// exchanges).
    pub compute_exposed: f64,
    /// Exchange time hidden under expert compute.
    pub comm_hidden: f64,
    /// Fault clauses active this step (stragglers, NIC degradation,
    /// transient failures — from the seeded fault plan; 0 = clean).
    pub faults_injected: usize,
    /// Transient exchange failures retried this step.
    pub retries: usize,
    /// Simulated seconds of injected delay this step (straggle + NIC
    /// degradation + retry/backoff), already folded into
    /// [`Self::critical_path`]; base phase entries stay untouched so
    /// the breakdown remains honest.
    pub injected_delay: f64,
}

impl StepReport {
    pub fn wall_total(&self) -> f64 {
        self.wall.iter().map(|(_, t)| t).sum()
    }

    pub fn comm_total(&self) -> f64 {
        self.comm.iter().map(|(_, t)| t).sum()
    }

    pub fn wall_phase(&self, name: &str) -> f64 {
        self.wall.iter().filter(|(n, _)| n == name).map(|(_, t)| t).sum()
    }

    pub fn comm_phase(&self, name: &str) -> f64 {
        self.comm.iter().filter(|(n, _)| n == name).map(|(_, t)| t).sum()
    }

    /// Fraction of the exchange time hidden under expert compute
    /// (0 when unchunked — nothing overlaps).
    pub fn overlap_efficiency(&self) -> f64 {
        let total = self.comm_hidden + self.comm_exposed;
        if total <= 0.0 {
            0.0
        } else {
            self.comm_hidden / total
        }
    }

    /// The step's modeled wall under the critical-path model: the
    /// measured local phases plus the overlapped exchange/compute
    /// region(s), instead of the plain sum of all phases.
    pub fn critical_wall(&self) -> f64 {
        self.wall_total() - self.wall_phase("expert") - self.wall_phase("bwd_expert")
            + self.critical_path
    }

    /// Fold one overlapped-exchange round into this report.
    pub fn apply_overlap(&mut self, o: &OverlapTiming) {
        self.n_chunks = o.n_chunks();
        self.critical_path += o.critical_path;
        self.comm_exposed += o.comm_exposed();
        self.compute_exposed += o.compute_exposed();
        self.comm_hidden += o.comm_hidden();
    }

    /// Fold a backward-pass report into this (forward) step report: wall
    /// and comm phases are appended, the backward exchange's bytes,
    /// schedule and chunk count land in the `_bwd` fields, and FLOPs and
    /// the overlap accounting accumulate.
    pub fn absorb_backward(&mut self, bwd: StepReport) {
        self.wall.extend(bwd.wall);
        self.comm.extend(bwd.comm);
        self.bytes_on_wire_bwd += bwd.bytes_on_wire;
        self.bytes_intra_node_bwd += bwd.bytes_intra_node;
        self.rows_deduped += bwd.rows_deduped;
        if !bwd.comm_schedule.is_empty() {
            self.comm_schedule_bwd = bwd.comm_schedule;
        }
        self.expert_flops += bwd.expert_flops;
        self.n_chunks_bwd = bwd.n_chunks;
        self.critical_path += bwd.critical_path;
        self.comm_exposed += bwd.comm_exposed;
        self.compute_exposed += bwd.compute_exposed;
        self.comm_hidden += bwd.comm_hidden;
        self.faults_injected += bwd.faults_injected;
        self.retries += bwd.retries;
        self.injected_delay += bwd.injected_delay;
    }
}

/// The expert-parallel MoE layer.
pub struct MoeLayer {
    pub cfg: MoeConfig,
    pub cluster: ClusterConfig,
    pub net: NetworkModel,
    pub gate: Box<dyn Gate>,
    /// All `E` experts, index = global expert id (rank `e / (E/W)` owns it).
    pub experts: Vec<Box<dyn ExpertExecutor>>,
    /// Router weight `[d, E]` for computing scores natively.
    pub gate_weight: Tensor,
    pub opts: MoeLayerOptions,
}

impl MoeLayer {
    /// Build a layer with native (pure-Rust) experts.
    pub fn native(
        cfg: MoeConfig,
        cluster: ClusterConfig,
        opts: MoeLayerOptions,
        seed: u64,
    ) -> Result<MoeLayer> {
        cfg.validate()?;
        let w = cluster.world();
        if cfg.num_experts % w != 0 {
            return Err(crate::config_err!(
                "num_experts {} must divide by world {w}",
                cfg.num_experts
            ));
        }
        validate_dead_ranks(&opts, w)?;
        validate_placement_table(&opts, cfg.num_experts, w)?;
        let mut rng = Rng::seed(seed);
        let experts: Vec<Box<dyn ExpertExecutor>> = (0..cfg.num_experts)
            .map(|_| {
                Box::new(crate::moe::expert::NativeExpert::init(
                    cfg.d_model,
                    cfg.ffn_hidden,
                    &mut rng,
                )) as Box<dyn ExpertExecutor>
            })
            .collect();
        let mut gate_weight = Tensor::randn(&[cfg.d_model, cfg.num_experts], &mut rng);
        gate_weight.scale(1.0 / (cfg.d_model as f32).sqrt());
        let gate = crate::gating::make_gate(&cfg, 1, None)?;
        let net = NetworkModel::new(cluster.clone());
        Ok(MoeLayer { cfg, cluster, net, gate, experts, gate_weight, opts })
    }

    /// Build with caller-provided experts (e.g. [`crate::moe::HloExpert`]).
    pub fn with_experts(
        cfg: MoeConfig,
        cluster: ClusterConfig,
        opts: MoeLayerOptions,
        gate: Box<dyn Gate>,
        experts: Vec<Box<dyn ExpertExecutor>>,
        gate_weight: Tensor,
    ) -> Result<MoeLayer> {
        let w = cluster.world();
        if cfg.num_experts % w != 0 || experts.len() != cfg.num_experts {
            return Err(crate::config_err!(
                "expert count {} must equal E={} and divide by world {w}",
                experts.len(),
                cfg.num_experts
            ));
        }
        validate_dead_ranks(&opts, w)?;
        validate_placement_table(&opts, cfg.num_experts, w)?;
        let net = NetworkModel::new(cluster.clone());
        Ok(MoeLayer { cfg, cluster, net, gate, experts, gate_weight, opts })
    }

    /// The shared expert-placement map: the adaptive table when one is
    /// installed, otherwise the contiguous formula (`E/W` per rank —
    /// the same layout the serving router derives), with dead ranks'
    /// experts elastically remapped over survivors in either case.
    pub fn placement(&self) -> crate::cluster::ExpertPlacement {
        crate::cluster::ExpertPlacement::resolve(
            self.cfg.num_experts,
            self.cluster.world(),
            self.opts.placement_table.as_deref(),
            &self.opts.dead_ranks,
        )
    }

    /// Experts per rank.
    pub fn experts_per_rank(&self) -> usize {
        self.placement().experts_per_rank()
    }

    /// Forward over per-rank token shards `[T_r, d]` (all equal length).
    /// Returns per-rank outputs (same shapes) and the step report.
    ///
    /// This is the forward-only flavor of the shared
    /// [`crate::pipeline::StepExecutor`]; the training layer runs the
    /// same executor in its forward + cache flavor, so the two can
    /// never drift apart.
    pub fn forward(&self, shards: &[Tensor]) -> Result<(Vec<Tensor>, StepReport)> {
        let route = |scores: &Tensor| self.route_with_impl(scores);
        let exec = StepExecutor {
            cfg: &self.cfg,
            cluster: &self.cluster,
            net: &self.net,
            opts: &self.opts,
            gate_weight: &self.gate_weight,
            experts: ExpertBank::Infer(&self.experts),
            route: &route,
            faults: None,
        };
        let out = exec.run(shards, false)?;
        Ok((out.outputs, out.report))
    }

    /// Route scores through the configured kernel implementation.
    fn route_with_impl(&self, scores: &Tensor) -> Routing {
        match self.opts.gate_impl {
            GateImpl::Fast => self.gate.route_scores(scores, 0),
            GateImpl::Generic => {
                let k = self.gate.k().min(scores.row_len());
                if matches!(
                    self.cfg.gate,
                    crate::config::GateKind::Switch
                        | crate::config::GateKind::GShard
                        | crate::config::GateKind::TopK { .. }
                ) {
                    // Same routing computed with the generic heap kernel.
                    let tokens = scores.rows();
                    let (ids, vals) = topk_rows_heap(scores, k);
                    let mut weights = vec![0.0f32; tokens * k];
                    // Switch keeps the raw softmax prob of the winner;
                    // top-k families renormalize over the selected k.
                    let renormalize =
                        !matches!(self.cfg.gate, crate::config::GateKind::Switch);
                    for t in 0..tokens {
                        let row = scores.row(t);
                        let sel = &vals[t * k..(t + 1) * k];
                        let out = &mut weights[t * k..(t + 1) * k];
                        softmax_of_selected(row, sel, out);
                        if renormalize {
                            let s: f32 = out.iter().sum();
                            for v in out.iter_mut() {
                                *v /= s;
                            }
                        }
                    }
                    Routing {
                        k,
                        tokens,
                        num_experts: self.cfg.num_experts,
                        expert_ids: ids,
                        weights,
                        aux_loss: 0.0,
                    }
                } else {
                    self.gate.route_scores(scores, 0)
                }
            }
        }
    }

    /// Reference (dense, single-machine) forward for testing: every token
    /// runs through its routed experts directly.
    pub fn reference_forward(&self, shards: &[Tensor]) -> Result<Vec<Tensor>> {
        let d = self.cfg.d_model;
        let mut outs = Vec::with_capacity(shards.len());
        let cap = self.cfg.capacity(shards[0].rows());
        for shard in shards {
            let scores = matmul(shard, &self.gate_weight);
            let routing = self.route_with_impl(&scores);
            let plan = apply_capacity(&routing, cap);
            let mut out = Tensor::zeros(&[shard.rows(), d]);
            for t in 0..shard.rows() {
                for j in 0..plan.k {
                    let slot = t * plan.k + j;
                    if plan.dest[slot] == u32::MAX {
                        continue;
                    }
                    let e = routing.expert_ids[slot] as usize;
                    let w = plan.weights[slot];
                    let x = shard.slice_rows(t, t + 1);
                    let y = self.experts[e].forward(&x)?;
                    for (o, &v) in out.row_mut(t).iter_mut().zip(y.row(0)) {
                        *o += w * v;
                    }
                }
            }
            outs.push(out);
        }
        Ok(outs)
    }
}

/// Shared validation of [`MoeLayerOptions::dead_ranks`] against a world
/// size: ranks must exist, at least one must survive, and the padded
/// pipeline — whose equal-chunk AllToAll assumes every rank hosts
/// `E/W` experts — cannot run degraded.
pub fn validate_dead_ranks(opts: &MoeLayerOptions, world: usize) -> Result<()> {
    if opts.dead_ranks.is_empty() {
        return Ok(());
    }
    if let Some(&r) = opts.dead_ranks.iter().find(|&&r| r >= world) {
        return Err(crate::config_err!("dead rank {r} does not exist (world = {world})"));
    }
    let mut distinct = opts.dead_ranks.clone();
    distinct.sort_unstable();
    distinct.dedup();
    if distinct.len() >= world {
        return Err(crate::config_err!("all {world} ranks are dead; nothing can run"));
    }
    if opts.dispatch == DispatchMode::Padded {
        return Err(crate::config_err!(
            "padded dispatch cannot run with dead ranks (its equal-chunk AllToAll \
             assumes the contiguous placement); use --dispatch ragged"
        ));
    }
    Ok(())
}

/// Shared validation of [`MoeLayerOptions::placement_table`] against the
/// layer geometry: the table must assign every expert to an existing
/// rank, and the padded pipeline — which assumes the contiguous formula
/// end to end — only accepts tables equivalent to it.
pub fn validate_placement_table(
    opts: &MoeLayerOptions,
    num_experts: usize,
    world: usize,
) -> Result<()> {
    let Some(table) = opts.placement_table.as_deref() else {
        return Ok(());
    };
    crate::cluster::ExpertPlacement::validate_table(num_experts, world, table)?;
    if opts.dispatch == DispatchMode::Padded
        && !crate::cluster::ExpertPlacement::from_table(num_experts, world, table)
            .is_contiguous()
    {
        return Err(crate::config_err!(
            "padded dispatch cannot run a non-contiguous placement table; \
             use --dispatch ragged"
        ));
    }
    Ok(())
}

/// DeepSpeed-style dense one-hot dispatch: `buffer = onehot · tokens`
/// where `onehot` is `[E·C, T]`. Bit-identical output to the sparse
/// scatter, at `2·(E·C)·T·d` FLOPs of real work (via
/// [`crate::nn::matmul::matmul_dense`], which — like a GPU einsum —
/// cannot skip the zeros).
pub fn dense_einsum_layout(tokens: &Tensor, plan: &DispatchPlan) -> LayoutBuffer {
    let t = plan.tokens;
    let rows = plan.buffer_rows();
    let mut onehot = Tensor::zeros(&[rows, t]);
    for tok in 0..t {
        for j in 0..plan.k {
            let dest = plan.dest[tok * plan.k + j];
            if dest != u32::MAX {
                onehot.set(dest as usize, tok, 1.0);
            }
        }
    }
    let data = crate::nn::matmul::matmul_dense(&onehot, tokens);
    LayoutBuffer { data, capacity: plan.capacity, num_experts: plan.num_experts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GateKind;

    fn tiny_cfg(gate: GateKind) -> MoeConfig {
        MoeConfig {
            num_experts: 4,
            d_model: 8,
            ffn_hidden: 16,
            capacity_factor: 4.0, // generous: no drops in the equality test
            gate,
        }
    }

    fn shards_for(world: usize, tokens: usize, d: usize, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::seed(seed);
        (0..world).map(|_| Tensor::randn(&[tokens, d], &mut rng)).collect()
    }

    #[test]
    fn pipeline_matches_reference_switch() {
        let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
        let layer = MoeLayer::native(
            tiny_cfg(GateKind::Switch),
            cluster,
            MoeLayerOptions::default(),
            42,
        )
        .unwrap();
        let shards = shards_for(4, 12, 8, 7);
        let (out, report) = layer.forward(&shards).unwrap();
        let reference = layer.reference_forward(&shards).unwrap();
        for (o, r) in out.iter().zip(&reference) {
            assert!(o.allclose(r, 1e-4), "diff={}", o.max_abs_diff(r));
        }
        assert_eq!(report.expert_counts.iter().sum::<usize>(), 48);
        assert!(report.comm_total() > 0.0);
        assert!(report.wall_total() > 0.0);
        // The overlap model is always filled in.
        assert!(report.n_chunks >= 1);
        assert!(report.critical_path > 0.0);
        assert!(report.critical_wall() > 0.0);
    }

    #[test]
    fn pipeline_matches_reference_gshard_flat_comm() {
        let cluster = ClusterConfig { nodes: 1, gpus_per_node: 4, ..ClusterConfig::commodity(1) };
        let opts = MoeLayerOptions {
            comm_impl: CommImpl::Flat,
            layout_impl: LayoutImpl::Naive,
            dispatch: DispatchMode::Padded,
            ..Default::default()
        };
        let mut cfg = tiny_cfg(GateKind::GShard);
        cfg.capacity_factor = 8.0;
        let layer = MoeLayer::native(cfg, cluster, opts, 3).unwrap();
        let shards = shards_for(4, 10, 8, 11);
        let (out, report) = layer.forward(&shards).unwrap();
        let reference = layer.reference_forward(&shards).unwrap();
        for (o, r) in out.iter().zip(&reference) {
            assert!(o.allclose(r, 1e-4));
        }
        // The padded pipeline never chunks: everything is exposed.
        assert_eq!(report.n_chunks, 1);
        assert_eq!(report.comm_hidden, 0.0);
        assert_eq!(report.overlap_efficiency(), 0.0);
    }

    #[test]
    fn all_layout_impls_agree() {
        let cluster = ClusterConfig { nodes: 1, gpus_per_node: 2, ..ClusterConfig::commodity(1) };
        let shards = shards_for(2, 16, 8, 5);
        let mut outs = Vec::new();
        for layout_impl in [LayoutImpl::Optimized, LayoutImpl::Naive, LayoutImpl::DenseEinsum] {
            let opts = MoeLayerOptions {
                layout_impl,
                dispatch: DispatchMode::Padded,
                ..Default::default()
            };
            let layer =
                MoeLayer::native(tiny_cfg(GateKind::Switch), cluster.clone(), opts, 9).unwrap();
            let (out, _) = layer.forward(&shards).unwrap();
            outs.push(out);
        }
        for other in &outs[1..] {
            for (a, b) in outs[0].iter().zip(other) {
                assert!(a.allclose(b, 1e-4));
            }
        }
    }

    #[test]
    fn ragged_matches_padded_bitwise() {
        for gate in [GateKind::Switch, GateKind::GShard, GateKind::TopK { k: 2 }] {
            let cluster =
                ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
            let shards = shards_for(4, 24, 8, 3);
            let padded_layer = MoeLayer::native(
                tiny_cfg(gate.clone()),
                cluster.clone(),
                MoeLayerOptions { dispatch: DispatchMode::Padded, ..Default::default() },
                17,
            )
            .unwrap();
            let ragged_layer = MoeLayer::native(
                tiny_cfg(gate.clone()),
                cluster,
                MoeLayerOptions { dispatch: DispatchMode::Ragged, ..Default::default() },
                17,
            )
            .unwrap();
            let (a, pr) = padded_layer.forward(&shards).unwrap();
            let (b, rr) = ragged_layer.forward(&shards).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert!(x.allclose(y, 0.0), "{gate:?}: outputs must be bit-identical");
            }
            assert_eq!(pr.expert_counts, rr.expert_counts, "{gate:?}");
            assert_eq!(pr.drop_rate, rr.drop_rate, "{gate:?}");
        }
    }

    #[test]
    fn ragged_moves_fewer_bytes_and_flops() {
        // capacity_factor 4.0 → heavily padded buffers; ragged must win.
        let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
        let shards = shards_for(4, 32, 8, 23);
        let padded = MoeLayer::native(
            tiny_cfg(GateKind::Switch),
            cluster.clone(),
            MoeLayerOptions { dispatch: DispatchMode::Padded, ..Default::default() },
            5,
        )
        .unwrap();
        let ragged = MoeLayer::native(
            tiny_cfg(GateKind::Switch),
            cluster,
            MoeLayerOptions { dispatch: DispatchMode::Ragged, ..Default::default() },
            5,
        )
        .unwrap();
        let (_, pr) = padded.forward(&shards).unwrap();
        let (_, rr) = ragged.forward(&shards).unwrap();
        assert!(pr.padding_waste > 0.0);
        assert_eq!(rr.padding_waste, 0.0, "ragged buffers carry no padding");
        assert!(
            rr.bytes_on_wire < pr.bytes_on_wire,
            "ragged {} must move fewer bytes than padded {}",
            rr.bytes_on_wire,
            pr.bytes_on_wire
        );
        assert!(
            rr.expert_flops < pr.expert_flops,
            "ragged {} must execute fewer FLOPs than padded {}",
            rr.expert_flops,
            pr.expert_flops
        );
        assert!(rr.bytes_on_wire > 0);
        assert!(rr.expert_flops > 0.0);
    }

    #[test]
    fn ragged_respects_forced_schedules() {
        let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
        let shards = shards_for(4, 16, 8, 29);
        for (choice, expect) in
            [(CommChoice::Flat, "flat"), (CommChoice::Hierarchical, "hier")]
        {
            let layer = MoeLayer::native(
                tiny_cfg(GateKind::Switch),
                cluster.clone(),
                MoeLayerOptions { alltoall: choice, ..Default::default() },
                31,
            )
            .unwrap();
            let (_, report) = layer.forward(&shards).unwrap();
            assert_eq!(report.comm_schedule, expect);
        }
        // Auto picks one of the two.
        let layer = MoeLayer::native(
            tiny_cfg(GateKind::Switch),
            cluster,
            MoeLayerOptions { alltoall: CommChoice::Auto, ..Default::default() },
            31,
        )
        .unwrap();
        let (_, report) = layer.forward(&shards).unwrap();
        assert!(report.comm_schedule == "flat" || report.comm_schedule == "hier");
    }

    #[test]
    fn forced_chunk_counts_are_reported_and_bit_identical() {
        let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
        let shards = shards_for(4, 16, 8, 37);
        // Flat schedule: chunks tile the destination-*rank* axis, so a
        // requested count up to the world size is honored exactly (the
        // hierarchical schedule tiles destination *nodes* — checked
        // separately below).
        let mk = |chunks| {
            MoeLayer::native(
                tiny_cfg(GateKind::Switch),
                cluster.clone(),
                MoeLayerOptions { chunks, alltoall: CommChoice::Flat, ..Default::default() },
                13,
            )
            .unwrap()
        };
        let (base_out, base_rep) = mk(ChunkChoice::Fixed(1)).forward(&shards).unwrap();
        assert_eq!(base_rep.n_chunks, 1);
        assert_eq!(base_rep.comm_hidden, 0.0);
        for n in [2usize, 4] {
            let (out, rep) = mk(ChunkChoice::Fixed(n)).forward(&shards).unwrap();
            assert_eq!(rep.n_chunks, n);
            for (a, b) in base_out.iter().zip(&out) {
                assert!(a.allclose(b, 0.0), "chunking must not change outputs");
            }
            // Critical path never exceeds the serial sum of the region.
            let serial = rep.wall_phase("expert") + rep.comm_total();
            assert!(rep.critical_path <= serial + 1e-9);
        }
        // Hierarchical schedule: chunks tile destination *nodes* (the
        // aggregated inter-node messages stay whole), so Fixed(4) on a
        // 2-node cluster clamps to 2 node-aligned chunks.
        let hier = MoeLayer::native(
            tiny_cfg(GateKind::Switch),
            cluster,
            MoeLayerOptions {
                chunks: ChunkChoice::Fixed(4),
                alltoall: CommChoice::Hierarchical,
                ..Default::default()
            },
            13,
        )
        .unwrap();
        let (out, rep) = hier.forward(&shards).unwrap();
        assert_eq!(rep.n_chunks, 2, "hier chunking is node-axis");
        for (a, b) in base_out.iter().zip(&out) {
            assert!(a.allclose(b, 0.0), "schedule + chunking must not change outputs");
        }
    }

    #[test]
    fn generic_gate_impl_matches_fast_for_topk() {
        let cluster = ClusterConfig { nodes: 1, gpus_per_node: 1, ..ClusterConfig::commodity(1) };
        let shards = shards_for(1, 32, 8, 13);
        let fast = MoeLayer::native(
            tiny_cfg(GateKind::TopK { k: 2 }),
            cluster.clone(),
            MoeLayerOptions { gate_impl: GateImpl::Fast, ..Default::default() },
            21,
        )
        .unwrap();
        let generic = MoeLayer::native(
            tiny_cfg(GateKind::TopK { k: 2 }),
            cluster,
            MoeLayerOptions { gate_impl: GateImpl::Generic, ..Default::default() },
            21,
        )
        .unwrap();
        let (a, _) = fast.forward(&shards).unwrap();
        let (b, _) = generic.forward(&shards).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!(x.allclose(y, 1e-4));
        }
    }

    #[test]
    fn capacity_drops_tokens_silently() {
        let cluster = ClusterConfig { nodes: 1, gpus_per_node: 1, ..ClusterConfig::commodity(1) };
        let mut cfg = tiny_cfg(GateKind::Switch);
        cfg.capacity_factor = 0.3; // forces drops
        let layer = MoeLayer::native(cfg, cluster, MoeLayerOptions::default(), 1).unwrap();
        let shards = shards_for(1, 64, 8, 17);
        let (_, report) = layer.forward(&shards).unwrap();
        assert!(report.drop_rate > 0.0);
    }

    #[test]
    fn dispatch_mode_parsing() {
        assert_eq!(DispatchMode::parse("padded").unwrap(), DispatchMode::Padded);
        assert_eq!(DispatchMode::parse("RAGGED").unwrap(), DispatchMode::Ragged);
        assert_eq!(DispatchMode::parse("dropless").unwrap(), DispatchMode::Ragged);
        assert!(DispatchMode::parse("sparse?").is_err());
        assert_eq!(DispatchMode::Padded.name(), "padded");
        assert_eq!(DispatchMode::Ragged.name(), "ragged");
    }

    #[test]
    fn rejects_indivisible_worlds() {
        let cluster = ClusterConfig { nodes: 1, gpus_per_node: 3, ..ClusterConfig::commodity(1) };
        assert!(MoeLayer::native(
            tiny_cfg(GateKind::Switch),
            cluster,
            MoeLayerOptions::default(),
            0
        )
        .is_err());
    }
}
