//! The expert-parallel MoE layer (Algorithm 1).
//!
//! Tokens live sharded across `W = nodes·gpus_per_node` simulated ranks;
//! experts are partitioned `E/W` per rank. One forward is the paper's
//! six steps, with each implementation choice (gate kernel, layout
//! kernel, AllToAll flavor) pluggable — the baseline systems of Fig 8
//! are exactly different option tuples over this one pipeline.
//!
//! Two dispatch pipelines share the gate phase (see DESIGN.md §"Dispatch
//! pipelines"):
//! - [`DispatchMode::Padded`] — the classic dense `[E, cap, d]` buffers:
//!   every expert padded to capacity, zeros shipped through both
//!   AllToAll legs and the expert GEMMs (the Fig-8 baselines).
//! - [`DispatchMode::Ragged`] — padding-free: only occupied rows are
//!   laid out ([`RaggedLayoutBuffer`]), exchanged (exact per-(rank,
//!   expert) counts via the ragged AllToAllv), and computed (one
//!   `[n_e, d]` FFN batch per expert). The AllToAll schedule (flat vs
//!   hierarchical) is picked **per step** from the step's own traffic
//!   matrix through [`crate::comm::schedule`] — the same decision
//!   procedure the serving router uses.

use crate::cluster::NetworkModel;
use crate::comm::ragged::{offwire_bytes, ragged_combine, ragged_dispatch};
use crate::comm::schedule::{pick_schedule, CommChoice, Schedule};
use crate::comm::{alltoall, hierarchical_alltoall, CommTiming};
use crate::config::{ClusterConfig, MoeConfig};
use crate::error::Result;
use crate::gating::topk::{softmax_of_selected, topk_rows_heap};
use crate::gating::{apply_capacity, DispatchPlan, Gate, Routing};
use crate::layout::{
    gather_expert_slices, naive_layout, opt_layout, ragged_layout, ragged_reverse_layout,
    reverse_layout, scatter_expert_slices, LayoutBuffer, RaggedLayoutBuffer,
};
use crate::moe::expert::ExpertExecutor;
use crate::nn::matmul;
use crate::tensor::Tensor;
use crate::util::rng::Rng;
use std::time::Instant;

/// Which top-k kernel the gate phase uses (Fig 3's comparison).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GateImpl {
    /// HetuMoE's specialized single-pass kernels.
    Fast,
    /// Generic heap-based top-k (PyTorch-style baseline).
    Generic,
}

/// Which layout transform the padded dispatch uses (Fig 4's comparison;
/// [`DispatchMode::Ragged`] always uses the single-pass ragged scatter).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayoutImpl {
    /// Counting-sort scatter (HetuMoE).
    Optimized,
    /// Stable-sort + gather (generic baseline).
    Naive,
    /// Dense one-hot dispatch einsum (DeepSpeed-MoE style): builds the
    /// `[E·C, T]` one-hot matrix and *matmuls* tokens into place. Exact
    /// same result, enormously more FLOPs at small batch — the mechanism
    /// behind the paper's 8.1× gap.
    DenseEinsum,
}

/// AllToAll flavor (Fig 5 vs Fig 6) for the padded pipeline, which
/// exchanges equal chunks and therefore fixes its schedule up front.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CommImpl {
    Flat,
    Hierarchical,
}

impl CommImpl {
    pub fn name(&self) -> &'static str {
        match self {
            CommImpl::Flat => Schedule::Flat.name(),
            CommImpl::Hierarchical => Schedule::Hierarchical.name(),
        }
    }
}

impl From<Schedule> for CommImpl {
    fn from(s: Schedule) -> CommImpl {
        match s {
            Schedule::Flat => CommImpl::Flat,
            Schedule::Hierarchical => CommImpl::Hierarchical,
        }
    }
}

/// Which dispatch pipeline the forward runs.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// Dense `[E, cap, d]` buffers, zero-padded to capacity — kept as
    /// the comparison baseline (and what the Fig-8 systems model).
    Padded,
    /// Padding-free ragged pipeline: occupied rows only, exact-count
    /// AllToAllv, grouped per-expert compute (the default).
    Ragged,
}

impl DispatchMode {
    pub fn parse(s: &str) -> Result<DispatchMode> {
        Ok(match s.to_lowercase().as_str() {
            "padded" | "dense" => DispatchMode::Padded,
            "ragged" | "dropless" => DispatchMode::Ragged,
            other => {
                return Err(crate::config_err!(
                    "unknown dispatch mode '{other}' (expected padded|ragged)"
                ));
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            DispatchMode::Padded => "padded",
            DispatchMode::Ragged => "ragged",
        }
    }
}

/// Pipeline options: a baseline system is a tuple of these.
#[derive(Clone, Debug)]
pub struct MoeLayerOptions {
    pub gate_impl: GateImpl,
    pub layout_impl: LayoutImpl,
    /// Fixed AllToAll flavor of the padded pipeline.
    pub comm_impl: CommImpl,
    /// Which dispatch pipeline to run.
    pub dispatch: DispatchMode,
    /// Per-step AllToAll schedule policy of the ragged pipeline
    /// (`Auto` scores the step's traffic matrix, like the serving
    /// router does per batch).
    pub alltoall: CommChoice,
    /// Threads for the parallel kernels (1 = serial).
    pub threads: usize,
}

impl Default for MoeLayerOptions {
    fn default() -> Self {
        MoeLayerOptions {
            gate_impl: GateImpl::Fast,
            layout_impl: LayoutImpl::Optimized,
            comm_impl: CommImpl::Hierarchical,
            dispatch: DispatchMode::Ragged,
            alltoall: CommChoice::Auto,
            threads: 1,
        }
    }
}

/// Per-step timing + routing quality report.
#[derive(Clone, Debug, Default)]
pub struct StepReport {
    /// Measured wall seconds per local phase, averaged per rank.
    pub wall: Vec<(String, f64)>,
    /// Simulated communication timings.
    pub comm: Vec<(String, f64)>,
    /// Capacity-drop rate across ranks.
    pub drop_rate: f64,
    /// Padding waste of the dispatch buffers (0 in ragged mode — the
    /// buffers hold only occupied rows).
    pub padding_waste: f64,
    /// Global per-expert token counts.
    pub expert_counts: Vec<usize>,
    /// Mean auxiliary loss across ranks.
    pub aux_loss: f64,
    /// Bytes crossing rank boundaries over both AllToAll legs
    /// (self-traffic excluded; padding rows count in padded mode —
    /// that's the waste the ragged pipeline removes).
    pub bytes_on_wire: usize,
    /// Expert-FFN FLOPs actually executed across all ranks (padded mode
    /// runs capacity rows, occupied or not).
    pub expert_flops: f64,
    /// AllToAll schedule this step ran ("flat" | "hier").
    pub comm_schedule: String,
    /// Bytes crossing rank boundaries over both *backward* AllToAll legs
    /// (0 for forward-only steps; set by the training backward pass,
    /// attributed through the same cost models as the forward legs).
    pub bytes_on_wire_bwd: usize,
    /// AllToAll schedule the backward legs ran ("" for forward-only).
    pub comm_schedule_bwd: String,
}

impl StepReport {
    pub fn wall_total(&self) -> f64 {
        self.wall.iter().map(|(_, t)| t).sum()
    }

    pub fn comm_total(&self) -> f64 {
        self.comm.iter().map(|(_, t)| t).sum()
    }

    pub fn wall_phase(&self, name: &str) -> f64 {
        self.wall.iter().filter(|(n, _)| n == name).map(|(_, t)| t).sum()
    }

    /// Fold a backward-pass report into this (forward) step report: wall
    /// and comm phases are appended, the backward exchange's bytes and
    /// schedule land in the `_bwd` fields, and FLOPs accumulate.
    pub fn absorb_backward(&mut self, bwd: StepReport) {
        self.wall.extend(bwd.wall);
        self.comm.extend(bwd.comm);
        self.bytes_on_wire_bwd += bwd.bytes_on_wire;
        if !bwd.comm_schedule.is_empty() {
            self.comm_schedule_bwd = bwd.comm_schedule;
        }
        self.expert_flops += bwd.expert_flops;
    }
}

/// The expert-parallel MoE layer.
pub struct MoeLayer {
    pub cfg: MoeConfig,
    pub cluster: ClusterConfig,
    pub net: NetworkModel,
    pub gate: Box<dyn Gate>,
    /// All `E` experts, index = global expert id (rank `e / (E/W)` owns it).
    pub experts: Vec<Box<dyn ExpertExecutor>>,
    /// Router weight `[d, E]` for computing scores natively.
    pub gate_weight: Tensor,
    pub opts: MoeLayerOptions,
}

impl MoeLayer {
    /// Build a layer with native (pure-Rust) experts.
    pub fn native(
        cfg: MoeConfig,
        cluster: ClusterConfig,
        opts: MoeLayerOptions,
        seed: u64,
    ) -> Result<MoeLayer> {
        cfg.validate()?;
        let w = cluster.world();
        if cfg.num_experts % w != 0 {
            return Err(crate::config_err!(
                "num_experts {} must divide by world {w}",
                cfg.num_experts
            ));
        }
        let mut rng = Rng::seed(seed);
        let experts: Vec<Box<dyn ExpertExecutor>> = (0..cfg.num_experts)
            .map(|_| {
                Box::new(crate::moe::expert::NativeExpert::init(
                    cfg.d_model,
                    cfg.ffn_hidden,
                    &mut rng,
                )) as Box<dyn ExpertExecutor>
            })
            .collect();
        let mut gate_weight = Tensor::randn(&[cfg.d_model, cfg.num_experts], &mut rng);
        gate_weight.scale(1.0 / (cfg.d_model as f32).sqrt());
        let gate = crate::gating::make_gate(&cfg, 1, None)?;
        let net = NetworkModel::new(cluster.clone());
        Ok(MoeLayer { cfg, cluster, net, gate, experts, gate_weight, opts })
    }

    /// Build with caller-provided experts (e.g. [`crate::moe::HloExpert`]).
    pub fn with_experts(
        cfg: MoeConfig,
        cluster: ClusterConfig,
        opts: MoeLayerOptions,
        gate: Box<dyn Gate>,
        experts: Vec<Box<dyn ExpertExecutor>>,
        gate_weight: Tensor,
    ) -> Result<MoeLayer> {
        let w = cluster.world();
        if cfg.num_experts % w != 0 || experts.len() != cfg.num_experts {
            return Err(crate::config_err!(
                "expert count {} must equal E={} and divide by world {w}",
                experts.len(),
                cfg.num_experts
            ));
        }
        let net = NetworkModel::new(cluster.clone());
        Ok(MoeLayer { cfg, cluster, net, gate, experts, gate_weight, opts })
    }

    /// The shared expert-placement map (experts partitioned contiguously,
    /// `E/W` per rank — the same formula the serving router uses).
    pub fn placement(&self) -> crate::cluster::ExpertPlacement {
        crate::cluster::ExpertPlacement::new(self.cfg.num_experts, self.cluster.world())
    }

    /// Experts per rank.
    pub fn experts_per_rank(&self) -> usize {
        self.placement().experts_per_rank()
    }

    /// Forward over per-rank token shards `[T_r, d]` (all equal length).
    /// Returns per-rank outputs (same shapes) and the step report.
    pub fn forward(&self, shards: &[Tensor]) -> Result<(Vec<Tensor>, StepReport)> {
        let w = self.cluster.world();
        if shards.len() != w {
            return Err(crate::shape_err!(
                "got {} shards for world {w}",
                shards.len()
            ));
        }
        let d = self.cfg.d_model;
        let e = self.cfg.num_experts;
        let local_tokens = shards[0].rows();
        for s in shards {
            if s.rows() != local_tokens || s.row_len() != d {
                return Err(crate::shape_err!("ragged shards"));
            }
        }
        // Per-rank, per-expert capacity.
        let cap = self.cfg.capacity(local_tokens);
        let mut report = StepReport::default();
        let mut expert_counts = vec![0usize; e];

        // ---- Step 1 per rank: gate scores, routing, capacity plan ----
        let mut plans: Vec<DispatchPlan> = Vec::with_capacity(w);
        let mut gate_wall = 0.0f64;
        for shard in shards {
            let g0 = Instant::now();
            let scores = matmul(shard, &self.gate_weight);
            let routing = self.route_with_impl(&scores);
            gate_wall += g0.elapsed().as_secs_f64();
            for (i, c) in routing.expert_counts().into_iter().enumerate() {
                expert_counts[i] += c;
            }
            report.aux_loss += routing.aux_loss as f64 / w as f64;
            let plan = apply_capacity(&routing, cap);
            report.drop_rate += plan.drop_rate() / w as f64;
            if self.opts.dispatch == DispatchMode::Padded {
                report.padding_waste += plan.padding_waste() / w as f64;
            }
            plans.push(plan);
        }
        report.wall.push(("gate".into(), gate_wall / w as f64));

        // ---- Steps 2–6: the dispatch pipeline ----
        let outputs = match self.opts.dispatch {
            DispatchMode::Padded => self.forward_padded(shards, &plans, &mut report)?,
            DispatchMode::Ragged => self.forward_ragged(shards, &plans, &mut report)?,
        };

        report.expert_counts = expert_counts;
        Ok((outputs, report))
    }

    /// The classic dense pipeline: padded `[E, cap, d]` buffers through
    /// equal-chunk AllToAlls, experts run over full capacity slices.
    fn forward_padded(
        &self,
        shards: &[Tensor],
        plans: &[DispatchPlan],
        report: &mut StepReport,
    ) -> Result<Vec<Tensor>> {
        let w = self.cluster.world();
        let d = self.cfg.d_model;
        let e = self.cfg.num_experts;
        let epr = self.experts_per_rank();
        let cap = plans[0].capacity;

        // ---- Step 2: layout transform into padded buffers ----
        let l0 = Instant::now();
        let buffers: Vec<LayoutBuffer> = shards
            .iter()
            .zip(plans)
            .map(|(shard, plan)| self.layout_with_impl(shard, plan))
            .collect();
        report
            .wall
            .push(("layout".into(), l0.elapsed().as_secs_f64() / w as f64));

        // ---- Step 3: AllToAll dispatch ----
        // Buffer layout per rank: [E, cap, d] = W chunks of [epr, cap, d].
        let mut flat: Vec<Vec<f32>> =
            buffers.into_iter().map(|b| b.data.into_vec()).collect();
        let timing = self.run_alltoall(&mut flat)?;
        report.comm.push(("alltoall_dispatch".into(), timing.total));
        report.comm_schedule = self.opts.comm_impl.name().into();

        // ---- Step 4: expert compute ----
        // After AllToAll, rank r's buffer is [W, epr, cap, d]: the tokens
        // every source rank sent to r's experts.
        let x0 = Instant::now();
        if epr == 1 {
            // One expert per rank: the whole received buffer [W·cap, d]
            // is already that expert's contiguous batch — run it in
            // place, no gather/scatter copies.
            for (r, buf) in flat.iter_mut().enumerate() {
                let rows = Tensor::from_vec(std::mem::take(buf), &[w * cap, d])?;
                let out = self.experts[r].forward(&rows)?;
                report.expert_flops += self.experts[r].flops(w * cap);
                *buf = out.into_vec();
            }
        } else {
            for (r, buf) in flat.iter_mut().enumerate() {
                // One scratch per rank, reused across its local experts.
                let mut rows = Tensor::zeros(&[w * cap, d]);
                for le in 0..epr {
                    let global_e = r * epr + le;
                    gather_expert_slices(buf, &mut rows, w, epr, le, cap);
                    let out = self.experts[global_e].forward(&rows)?;
                    report.expert_flops += self.experts[global_e].flops(w * cap);
                    scatter_expert_slices(buf, out.data(), w, epr, le, cap, d);
                }
            }
        }
        report
            .wall
            .push(("expert".into(), x0.elapsed().as_secs_f64() / w as f64));

        // ---- Step 5: AllToAll combine (reverse exchange) ----
        let timing2 = self.run_alltoall(&mut flat)?;
        report.comm.push(("alltoall_combine".into(), timing2.total));
        // Every off-diagonal (src, dst) pair ships one [epr, cap, d]
        // chunk per leg, padding included.
        report.bytes_on_wire = 2 * w * w.saturating_sub(1) * epr * cap * d * 4;

        // ---- Step 6: reverse layout per rank ----
        let r0 = Instant::now();
        let mut outputs = Vec::with_capacity(w);
        for (rank, plan) in plans.iter().enumerate() {
            let buffer = LayoutBuffer {
                data: Tensor::from_vec(std::mem::take(&mut flat[rank]), &[e * cap, d])?,
                capacity: cap,
                num_experts: e,
            };
            outputs.push(reverse_layout(&buffer, plan, self.opts.threads));
        }
        report
            .wall
            .push(("reverse_layout".into(), r0.elapsed().as_secs_f64() / w as f64));
        Ok(outputs)
    }

    /// The padding-free pipeline: ragged buffers, exact-count AllToAllv
    /// with per-step schedule selection, grouped expert compute.
    fn forward_ragged(
        &self,
        shards: &[Tensor],
        plans: &[DispatchPlan],
        report: &mut StepReport,
    ) -> Result<Vec<Tensor>> {
        let w = self.cluster.world();
        let d = self.cfg.d_model;
        let epr = self.experts_per_rank();

        // ---- Step 2: ragged layout (occupied rows only, no zero-fill) ----
        let l0 = Instant::now();
        let buffers: Vec<RaggedLayoutBuffer> = shards
            .iter()
            .zip(plans)
            .map(|(shard, plan)| ragged_layout(shard, plan, self.opts.threads))
            .collect();
        report
            .wall
            .push(("layout".into(), l0.elapsed().as_secs_f64() / w as f64));

        // ---- Schedule selection: the serving router's decision
        // procedure, applied per training step ----
        let kept: Vec<Vec<usize>> = plans.iter().map(|p| p.kept.clone()).collect();
        let counts: Vec<Vec<usize>> =
            plans.iter().map(|p| p.rank_counts(w)).collect();
        let row_bytes = d * 4;
        let pick = pick_schedule(&self.net, &counts, row_bytes, self.opts.alltoall);
        let schedule = pick.schedule;
        report.comm_schedule = schedule.name().into();

        // ---- Step 3: ragged AllToAllv dispatch (exact counts) ----
        let mut flat: Vec<Vec<f32>> =
            buffers.into_iter().map(|b| b.data.into_vec()).collect();
        let timing = ragged_dispatch(&self.net, &mut flat, &kept, d, schedule)?;
        report.comm.push(("alltoall_dispatch".into(), timing.total));

        // ---- Step 4: grouped expert compute over true token counts ----
        // The exchange delivered each expert's batch contiguous: one
        // [n_e, d] FFN per expert, no per-source gathers.
        let x0 = Instant::now();
        for (r, buf) in flat.iter_mut().enumerate() {
            let mut off = 0usize;
            for le in 0..epr {
                let ge = r * epr + le;
                let n: usize = kept.iter().map(|row| row[ge]).sum();
                if n > 0 {
                    let rows = Tensor::from_vec(buf[off..off + n * d].to_vec(), &[n, d])?;
                    let out = self.experts[ge].forward(&rows)?;
                    report.expert_flops += self.experts[ge].flops(n);
                    buf[off..off + n * d].copy_from_slice(out.data());
                }
                off += n * d;
            }
        }
        report
            .wall
            .push(("expert".into(), x0.elapsed().as_secs_f64() / w as f64));

        // ---- Step 5: ragged AllToAllv combine (reverse exchange) ----
        let timing2 = ragged_combine(&self.net, &mut flat, &kept, d, schedule)?;
        report.comm.push(("alltoall_combine".into(), timing2.total));
        report.bytes_on_wire = 2 * offwire_bytes(&counts, row_bytes);

        // ---- Step 6: ragged reverse layout (takes ownership — no clone) ----
        let r0 = Instant::now();
        let mut outputs = Vec::with_capacity(w);
        for (rank, plan) in plans.iter().enumerate() {
            let buffer =
                RaggedLayoutBuffer::from_plan(std::mem::take(&mut flat[rank]), plan, d)?;
            outputs.push(ragged_reverse_layout(&buffer, plan, self.opts.threads));
        }
        report
            .wall
            .push(("reverse_layout".into(), r0.elapsed().as_secs_f64() / w as f64));
        Ok(outputs)
    }

    /// Route scores through the configured kernel implementation.
    fn route_with_impl(&self, scores: &Tensor) -> Routing {
        match self.opts.gate_impl {
            GateImpl::Fast => self.gate.route_scores(scores, 0),
            GateImpl::Generic => {
                let k = self.gate.k().min(scores.row_len());
                if matches!(
                    self.cfg.gate,
                    crate::config::GateKind::Switch
                        | crate::config::GateKind::GShard
                        | crate::config::GateKind::TopK { .. }
                ) {
                    // Same routing computed with the generic heap kernel.
                    let tokens = scores.rows();
                    let (ids, vals) = topk_rows_heap(scores, k);
                    let mut weights = vec![0.0f32; tokens * k];
                    // Switch keeps the raw softmax prob of the winner;
                    // top-k families renormalize over the selected k.
                    let renormalize =
                        !matches!(self.cfg.gate, crate::config::GateKind::Switch);
                    for t in 0..tokens {
                        let row = scores.row(t);
                        let sel = &vals[t * k..(t + 1) * k];
                        let out = &mut weights[t * k..(t + 1) * k];
                        softmax_of_selected(row, sel, out);
                        if renormalize {
                            let s: f32 = out.iter().sum();
                            for v in out.iter_mut() {
                                *v /= s;
                            }
                        }
                    }
                    Routing {
                        k,
                        tokens,
                        num_experts: self.cfg.num_experts,
                        expert_ids: ids,
                        weights,
                        aux_loss: 0.0,
                    }
                } else {
                    self.gate.route_scores(scores, 0)
                }
            }
        }
    }

    /// Dispatch tokens into the padded buffer through the configured
    /// layout implementation.
    fn layout_with_impl(&self, shard: &Tensor, plan: &DispatchPlan) -> LayoutBuffer {
        match self.opts.layout_impl {
            LayoutImpl::Optimized => opt_layout(shard, plan, self.opts.threads),
            LayoutImpl::Naive => naive_layout(shard, plan),
            LayoutImpl::DenseEinsum => dense_einsum_layout(shard, plan),
        }
    }

    fn run_alltoall(&self, flat: &mut [Vec<f32>]) -> Result<CommTiming> {
        match self.opts.comm_impl {
            CommImpl::Flat => alltoall(&self.net, flat),
            CommImpl::Hierarchical => hierarchical_alltoall(&self.net, flat),
        }
    }

    /// Reference (dense, single-machine) forward for testing: every token
    /// runs through its routed experts directly.
    pub fn reference_forward(&self, shards: &[Tensor]) -> Result<Vec<Tensor>> {
        let d = self.cfg.d_model;
        let mut outs = Vec::with_capacity(shards.len());
        let cap = self.cfg.capacity(shards[0].rows());
        for shard in shards {
            let scores = matmul(shard, &self.gate_weight);
            let routing = self.route_with_impl(&scores);
            let plan = apply_capacity(&routing, cap);
            let mut out = Tensor::zeros(&[shard.rows(), d]);
            for t in 0..shard.rows() {
                for j in 0..plan.k {
                    let slot = t * plan.k + j;
                    if plan.dest[slot] == u32::MAX {
                        continue;
                    }
                    let e = routing.expert_ids[slot] as usize;
                    let w = plan.weights[slot];
                    let x = shard.slice_rows(t, t + 1);
                    let y = self.experts[e].forward(&x)?;
                    for (o, &v) in out.row_mut(t).iter_mut().zip(y.row(0)) {
                        *o += w * v;
                    }
                }
            }
            outs.push(out);
        }
        Ok(outs)
    }
}

/// DeepSpeed-style dense one-hot dispatch: `buffer = onehot · tokens`
/// where `onehot` is `[E·C, T]`. Bit-identical output to the sparse
/// scatter, at `2·(E·C)·T·d` FLOPs of real work (via
/// [`crate::nn::matmul::matmul_dense`], which — like a GPU einsum —
/// cannot skip the zeros).
pub fn dense_einsum_layout(tokens: &Tensor, plan: &DispatchPlan) -> LayoutBuffer {
    let t = plan.tokens;
    let rows = plan.buffer_rows();
    let mut onehot = Tensor::zeros(&[rows, t]);
    for tok in 0..t {
        for j in 0..plan.k {
            let dest = plan.dest[tok * plan.k + j];
            if dest != u32::MAX {
                onehot.set(dest as usize, tok, 1.0);
            }
        }
    }
    let data = crate::nn::matmul::matmul_dense(&onehot, tokens);
    LayoutBuffer { data, capacity: plan.capacity, num_experts: plan.num_experts }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::GateKind;

    fn tiny_cfg(gate: GateKind) -> MoeConfig {
        MoeConfig {
            num_experts: 4,
            d_model: 8,
            ffn_hidden: 16,
            capacity_factor: 4.0, // generous: no drops in the equality test
            gate,
        }
    }

    fn shards_for(world: usize, tokens: usize, d: usize, seed: u64) -> Vec<Tensor> {
        let mut rng = Rng::seed(seed);
        (0..world).map(|_| Tensor::randn(&[tokens, d], &mut rng)).collect()
    }

    #[test]
    fn pipeline_matches_reference_switch() {
        let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
        let layer = MoeLayer::native(
            tiny_cfg(GateKind::Switch),
            cluster,
            MoeLayerOptions::default(),
            42,
        )
        .unwrap();
        let shards = shards_for(4, 12, 8, 7);
        let (out, report) = layer.forward(&shards).unwrap();
        let reference = layer.reference_forward(&shards).unwrap();
        for (o, r) in out.iter().zip(&reference) {
            assert!(o.allclose(r, 1e-4), "diff={}", o.max_abs_diff(r));
        }
        assert_eq!(report.expert_counts.iter().sum::<usize>(), 48);
        assert!(report.comm_total() > 0.0);
        assert!(report.wall_total() > 0.0);
    }

    #[test]
    fn pipeline_matches_reference_gshard_flat_comm() {
        let cluster = ClusterConfig { nodes: 1, gpus_per_node: 4, ..ClusterConfig::commodity(1) };
        let opts = MoeLayerOptions {
            comm_impl: CommImpl::Flat,
            layout_impl: LayoutImpl::Naive,
            dispatch: DispatchMode::Padded,
            ..Default::default()
        };
        let mut cfg = tiny_cfg(GateKind::GShard);
        cfg.capacity_factor = 8.0;
        let layer = MoeLayer::native(cfg, cluster, opts, 3).unwrap();
        let shards = shards_for(4, 10, 8, 11);
        let (out, _) = layer.forward(&shards).unwrap();
        let reference = layer.reference_forward(&shards).unwrap();
        for (o, r) in out.iter().zip(&reference) {
            assert!(o.allclose(r, 1e-4));
        }
    }

    #[test]
    fn all_layout_impls_agree() {
        let cluster = ClusterConfig { nodes: 1, gpus_per_node: 2, ..ClusterConfig::commodity(1) };
        let shards = shards_for(2, 16, 8, 5);
        let mut outs = Vec::new();
        for layout_impl in [LayoutImpl::Optimized, LayoutImpl::Naive, LayoutImpl::DenseEinsum] {
            let opts = MoeLayerOptions {
                layout_impl,
                dispatch: DispatchMode::Padded,
                ..Default::default()
            };
            let layer =
                MoeLayer::native(tiny_cfg(GateKind::Switch), cluster.clone(), opts, 9).unwrap();
            let (out, _) = layer.forward(&shards).unwrap();
            outs.push(out);
        }
        for other in &outs[1..] {
            for (a, b) in outs[0].iter().zip(other) {
                assert!(a.allclose(b, 1e-4));
            }
        }
    }

    #[test]
    fn ragged_matches_padded_bitwise() {
        for gate in [GateKind::Switch, GateKind::GShard, GateKind::TopK { k: 2 }] {
            let cluster =
                ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
            let shards = shards_for(4, 24, 8, 3);
            let padded_layer = MoeLayer::native(
                tiny_cfg(gate.clone()),
                cluster.clone(),
                MoeLayerOptions { dispatch: DispatchMode::Padded, ..Default::default() },
                17,
            )
            .unwrap();
            let ragged_layer = MoeLayer::native(
                tiny_cfg(gate.clone()),
                cluster,
                MoeLayerOptions { dispatch: DispatchMode::Ragged, ..Default::default() },
                17,
            )
            .unwrap();
            let (a, pr) = padded_layer.forward(&shards).unwrap();
            let (b, rr) = ragged_layer.forward(&shards).unwrap();
            for (x, y) in a.iter().zip(&b) {
                assert!(x.allclose(y, 0.0), "{gate:?}: outputs must be bit-identical");
            }
            assert_eq!(pr.expert_counts, rr.expert_counts, "{gate:?}");
            assert_eq!(pr.drop_rate, rr.drop_rate, "{gate:?}");
        }
    }

    #[test]
    fn ragged_moves_fewer_bytes_and_flops() {
        // capacity_factor 4.0 → heavily padded buffers; ragged must win.
        let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
        let shards = shards_for(4, 32, 8, 23);
        let padded = MoeLayer::native(
            tiny_cfg(GateKind::Switch),
            cluster.clone(),
            MoeLayerOptions { dispatch: DispatchMode::Padded, ..Default::default() },
            5,
        )
        .unwrap();
        let ragged = MoeLayer::native(
            tiny_cfg(GateKind::Switch),
            cluster,
            MoeLayerOptions { dispatch: DispatchMode::Ragged, ..Default::default() },
            5,
        )
        .unwrap();
        let (_, pr) = padded.forward(&shards).unwrap();
        let (_, rr) = ragged.forward(&shards).unwrap();
        assert!(pr.padding_waste > 0.0);
        assert_eq!(rr.padding_waste, 0.0, "ragged buffers carry no padding");
        assert!(
            rr.bytes_on_wire < pr.bytes_on_wire,
            "ragged {} must move fewer bytes than padded {}",
            rr.bytes_on_wire,
            pr.bytes_on_wire
        );
        assert!(
            rr.expert_flops < pr.expert_flops,
            "ragged {} must execute fewer FLOPs than padded {}",
            rr.expert_flops,
            pr.expert_flops
        );
        assert!(rr.bytes_on_wire > 0);
        assert!(rr.expert_flops > 0.0);
    }

    #[test]
    fn ragged_respects_forced_schedules() {
        let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
        let shards = shards_for(4, 16, 8, 29);
        for (choice, expect) in
            [(CommChoice::Flat, "flat"), (CommChoice::Hierarchical, "hier")]
        {
            let layer = MoeLayer::native(
                tiny_cfg(GateKind::Switch),
                cluster.clone(),
                MoeLayerOptions { alltoall: choice, ..Default::default() },
                31,
            )
            .unwrap();
            let (_, report) = layer.forward(&shards).unwrap();
            assert_eq!(report.comm_schedule, expect);
        }
        // Auto picks one of the two.
        let layer = MoeLayer::native(
            tiny_cfg(GateKind::Switch),
            cluster,
            MoeLayerOptions { alltoall: CommChoice::Auto, ..Default::default() },
            31,
        )
        .unwrap();
        let (_, report) = layer.forward(&shards).unwrap();
        assert!(report.comm_schedule == "flat" || report.comm_schedule == "hier");
    }

    #[test]
    fn generic_gate_impl_matches_fast_for_topk() {
        let cluster = ClusterConfig { nodes: 1, gpus_per_node: 1, ..ClusterConfig::commodity(1) };
        let shards = shards_for(1, 32, 8, 13);
        let fast = MoeLayer::native(
            tiny_cfg(GateKind::TopK { k: 2 }),
            cluster.clone(),
            MoeLayerOptions { gate_impl: GateImpl::Fast, ..Default::default() },
            21,
        )
        .unwrap();
        let generic = MoeLayer::native(
            tiny_cfg(GateKind::TopK { k: 2 }),
            cluster,
            MoeLayerOptions { gate_impl: GateImpl::Generic, ..Default::default() },
            21,
        )
        .unwrap();
        let (a, _) = fast.forward(&shards).unwrap();
        let (b, _) = generic.forward(&shards).unwrap();
        for (x, y) in a.iter().zip(&b) {
            assert!(x.allclose(y, 1e-4));
        }
    }

    #[test]
    fn capacity_drops_tokens_silently() {
        let cluster = ClusterConfig { nodes: 1, gpus_per_node: 1, ..ClusterConfig::commodity(1) };
        let mut cfg = tiny_cfg(GateKind::Switch);
        cfg.capacity_factor = 0.3; // forces drops
        let layer = MoeLayer::native(cfg, cluster, MoeLayerOptions::default(), 1).unwrap();
        let shards = shards_for(1, 64, 8, 17);
        let (_, report) = layer.forward(&shards).unwrap();
        assert!(report.drop_rate > 0.0);
    }

    #[test]
    fn dispatch_mode_parsing() {
        assert_eq!(DispatchMode::parse("padded").unwrap(), DispatchMode::Padded);
        assert_eq!(DispatchMode::parse("RAGGED").unwrap(), DispatchMode::Ragged);
        assert_eq!(DispatchMode::parse("dropless").unwrap(), DispatchMode::Ragged);
        assert!(DispatchMode::parse("sparse?").is_err());
        assert_eq!(DispatchMode::Padded.name(), "padded");
        assert_eq!(DispatchMode::Ragged.name(), "ragged");
    }

    #[test]
    fn rejects_indivisible_worlds() {
        let cluster = ClusterConfig { nodes: 1, gpus_per_node: 3, ..ClusterConfig::commodity(1) };
        assert!(MoeLayer::native(
            tiny_cfg(GateKind::Switch),
            cluster,
            MoeLayerOptions::default(),
            0
        )
        .is_err());
    }
}
