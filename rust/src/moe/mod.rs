//! The MoE layer: Algorithm 1 of the paper, end to end.
//!
//! `Gate → Layout_Transform → AllToAll → Expert → AllToAll →
//! Reverse_Layout_Transform`, executed over the simulated expert-parallel
//! mesh with real data movement and per-phase timing.

pub mod expert;
pub mod layer;

#[cfg(feature = "pjrt")]
pub use expert::HloExpert;
pub use expert::{ExpertExecutor, NativeExpert};
pub use layer::{
    validate_dead_ranks, validate_placement_table, CommImpl, DispatchMode, GateImpl,
    LayoutImpl, MoeLayer, MoeLayerOptions, StepReport,
};
