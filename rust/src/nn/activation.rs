//! Elementwise activations.

/// Exact GeLU (erf form approximated with tanh, as used by most frameworks).
#[inline]
pub fn gelu(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    0.5 * x * (1.0 + (C * (x + 0.044715 * x * x * x)).tanh())
}

/// Derivative of [`gelu`] (the same tanh form, differentiated):
/// `g'(x) = 0.5·(1 + tanh u) + 0.5·x·(1 − tanh²u)·C·(1 + 3·0.044715·x²)`
/// with `u = C·(x + 0.044715·x³)`.
#[inline]
pub fn gelu_grad(x: f32) -> f32 {
    const C: f32 = 0.7978845608; // sqrt(2/pi)
    const A: f32 = 0.044715;
    let u = C * (x + A * x * x * x);
    let t = u.tanh();
    0.5 * (1.0 + t) + 0.5 * x * (1.0 - t * t) * C * (1.0 + 3.0 * A * x * x)
}

/// ReLU.
#[inline]
pub fn relu(x: f32) -> f32 {
    x.max(0.0)
}

/// Apply an activation in place.
pub fn map_inplace(xs: &mut [f32], f: impl Fn(f32) -> f32) {
    for x in xs {
        *x = f(*x);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gelu_known_values() {
        assert!(gelu(0.0).abs() < 1e-7);
        assert!((gelu(1.0) - 0.8412).abs() < 1e-3);
        assert!((gelu(-1.0) + 0.1588).abs() < 1e-3);
        // Large |x| saturates to identity / zero.
        assert!((gelu(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu(-10.0).abs() < 1e-4);
    }

    #[test]
    fn gelu_grad_matches_finite_difference() {
        for &x in &[-3.0f32, -1.0, -0.1, 0.0, 0.1, 0.7, 1.5, 4.0] {
            let eps = 1e-3f32;
            let numeric = (gelu(x + eps) - gelu(x - eps)) / (2.0 * eps);
            let analytic = gelu_grad(x);
            assert!(
                (numeric - analytic).abs() < 2e-3,
                "x={x}: numeric {numeric} vs analytic {analytic}"
            );
        }
        // gelu'(0) = 0.5 exactly in the tanh form.
        assert!((gelu_grad(0.0) - 0.5).abs() < 1e-7);
    }

    #[test]
    fn relu_basics() {
        assert_eq!(relu(-2.0), 0.0);
        assert_eq!(relu(3.0), 3.0);
    }

    #[test]
    fn map_inplace_applies() {
        let mut v = vec![-1.0, 2.0];
        map_inplace(&mut v, relu);
        assert_eq!(v, vec![0.0, 2.0]);
    }
}
