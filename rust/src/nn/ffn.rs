//! A feed-forward expert network: `y = GeLU(x·W1 + b1)·W2 + b2`.
//!
//! This is the "expert" of the paper's benchmark model (hidden size 2048)
//! when running the coordinator without PJRT artifacts; the artifact-backed
//! expert ([`crate::moe::expert::HloExpert`]) computes the same function
//! through XLA.

use crate::nn::activation::gelu;
use crate::nn::matmul::matmul_into;
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Two-layer FFN expert with GeLU.
#[derive(Clone, Debug)]
pub struct Ffn {
    pub w1: Tensor, // [d, h]
    pub b1: Vec<f32>,
    pub w2: Tensor, // [h, d]
    pub b2: Vec<f32>,
    pub d: usize,
    pub h: usize,
}

impl Ffn {
    /// Random initialization (He-style scaled normals).
    pub fn init(d: usize, h: usize, rng: &mut Rng) -> Ffn {
        let mut w1 = Tensor::randn(&[d, h], rng);
        w1.scale((2.0 / d as f32).sqrt());
        let mut w2 = Tensor::randn(&[h, d], rng);
        w2.scale((2.0 / h as f32).sqrt());
        Ffn { w1, b1: vec![0.0; h], w2, b2: vec![0.0; d], d, h }
    }

    /// Forward over a batch of rows `[n, d]` → `[n, d]`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.shape()[1], self.d);
        let n = x.rows();
        let mut hid = Tensor::zeros(&[n, self.h]);
        matmul_into(x.data(), self.w1.data(), hid.data_mut(), n, self.d, self.h);
        for i in 0..n {
            let row = hid.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v = gelu(*v + self.b1[j]);
            }
        }
        let mut out = Tensor::zeros(&[n, self.d]);
        matmul_into(hid.data(), self.w2.data(), out.data_mut(), n, self.h, self.d);
        for i in 0..n {
            let row = out.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v += self.b2[j];
            }
        }
        out
    }

    /// Forward into a preallocated output + scratch (hot-path variant used
    /// by the pipeline benches; avoids per-call allocation).
    pub fn forward_into(&self, x: &Tensor, scratch: &mut Vec<f32>, out: &mut Tensor) {
        let n = x.rows();
        scratch.resize(n * self.h, 0.0);
        matmul_into(x.data(), self.w1.data(), &mut scratch[..n * self.h], n, self.d, self.h);
        for i in 0..n {
            for j in 0..self.h {
                let v = scratch[i * self.h + j] + self.b1[j];
                scratch[i * self.h + j] = gelu(v);
            }
        }
        matmul_into(&scratch[..n * self.h], self.w2.data(), out.data_mut(), n, self.h, self.d);
        for i in 0..n {
            let row = out.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v += self.b2[j];
            }
        }
    }

    /// Parameter count.
    pub fn num_params(&self) -> usize {
        self.d * self.h + self.h + self.h * self.d + self.d
    }

    /// FLOPs for a forward over `n` rows.
    pub fn flops(&self, n: usize) -> usize {
        2 * n * self.d * self.h * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_params() {
        let mut rng = Rng::seed(0);
        let f = Ffn::init(16, 64, &mut rng);
        let x = Tensor::randn(&[5, 16], &mut rng);
        let y = f.forward(&x);
        assert_eq!(y.shape(), &[5, 16]);
        assert_eq!(f.num_params(), 16 * 64 + 64 + 64 * 16 + 16);
    }

    #[test]
    fn forward_into_matches_forward() {
        let mut rng = Rng::seed(1);
        let f = Ffn::init(8, 32, &mut rng);
        let x = Tensor::randn(&[7, 8], &mut rng);
        let y = f.forward(&x);
        let mut scratch = Vec::new();
        let mut out = Tensor::zeros(&[7, 8]);
        f.forward_into(&x, &mut scratch, &mut out);
        assert!(y.allclose(&out, 1e-6));
    }

    #[test]
    fn zero_input_gives_bias_path() {
        let mut rng = Rng::seed(2);
        let mut f = Ffn::init(4, 8, &mut rng);
        f.b1.iter_mut().for_each(|b| *b = 0.0);
        f.b2 = vec![0.5; 4];
        let x = Tensor::zeros(&[3, 4]);
        let y = f.forward(&x);
        // gelu(0)=0 so output = b2 everywhere.
        for v in y.data() {
            assert!((v - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::seed(5);
        let mut r2 = Rng::seed(5);
        let f1 = Ffn::init(6, 12, &mut r1);
        let f2 = Ffn::init(6, 12, &mut r2);
        let x = Tensor::randn(&[2, 6], &mut r1);
        let x2 = Tensor::randn(&[2, 6], &mut r2);
        assert!(f1.forward(&x).allclose(&f2.forward(&x2), 0.0));
    }
}
