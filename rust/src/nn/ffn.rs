//! A feed-forward expert network: `y = GeLU(x·W1 + b1)·W2 + b2`.
//!
//! This is the "expert" of the paper's benchmark model (hidden size 2048)
//! when running the coordinator without PJRT artifacts; the artifact-backed
//! expert ([`crate::moe::expert::HloExpert`]) computes the same function
//! through XLA.

use crate::nn::activation::{gelu, gelu_grad};
use crate::nn::matmul::{matmul_into, matmul_nt, matmul_tn};
use crate::tensor::Tensor;
use crate::util::rng::Rng;

/// Saved forward activations for [`Ffn::backward`].
#[derive(Clone, Debug)]
pub struct FfnCache {
    /// Input batch `[n, d]`.
    pub x: Tensor,
    /// Pre-activation hidden `x·W1 + b1`, `[n, h]`.
    pub hpre: Tensor,
    /// Activated hidden `GeLU(hpre)`, `[n, h]`.
    pub hid: Tensor,
}

/// Parameter gradients of one [`Ffn`], plus the input gradient.
#[derive(Clone, Debug)]
pub struct FfnGrads {
    pub dw1: Tensor, // [d, h]
    pub db1: Vec<f32>,
    pub dw2: Tensor, // [h, d]
    pub db2: Vec<f32>,
    /// Gradient w.r.t. the input batch `[n, d]`.
    pub dx: Tensor,
}

/// Two-layer FFN expert with GeLU.
#[derive(Clone, Debug)]
pub struct Ffn {
    pub w1: Tensor, // [d, h]
    pub b1: Vec<f32>,
    pub w2: Tensor, // [h, d]
    pub b2: Vec<f32>,
    pub d: usize,
    pub h: usize,
}

impl Ffn {
    /// Random initialization (He-style scaled normals).
    pub fn init(d: usize, h: usize, rng: &mut Rng) -> Ffn {
        let mut w1 = Tensor::randn(&[d, h], rng);
        w1.scale((2.0 / d as f32).sqrt());
        let mut w2 = Tensor::randn(&[h, d], rng);
        w2.scale((2.0 / h as f32).sqrt());
        Ffn { w1, b1: vec![0.0; h], w2, b2: vec![0.0; d], d, h }
    }

    /// Forward over a batch of rows `[n, d]` → `[n, d]`.
    pub fn forward(&self, x: &Tensor) -> Tensor {
        assert_eq!(x.shape()[1], self.d);
        let n = x.rows();
        let mut hid = Tensor::zeros(&[n, self.h]);
        matmul_into(x.data(), self.w1.data(), hid.data_mut(), n, self.d, self.h);
        for i in 0..n {
            let row = hid.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v = gelu(*v + self.b1[j]);
            }
        }
        let mut out = Tensor::zeros(&[n, self.d]);
        matmul_into(hid.data(), self.w2.data(), out.data_mut(), n, self.h, self.d);
        for i in 0..n {
            let row = out.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v += self.b2[j];
            }
        }
        out
    }

    /// Forward into a preallocated output + scratch (hot-path variant used
    /// by the pipeline benches; avoids per-call allocation).
    pub fn forward_into(&self, x: &Tensor, scratch: &mut Vec<f32>, out: &mut Tensor) {
        let n = x.rows();
        scratch.resize(n * self.h, 0.0);
        matmul_into(x.data(), self.w1.data(), &mut scratch[..n * self.h], n, self.d, self.h);
        for i in 0..n {
            for j in 0..self.h {
                let v = scratch[i * self.h + j] + self.b1[j];
                scratch[i * self.h + j] = gelu(v);
            }
        }
        matmul_into(&scratch[..n * self.h], self.w2.data(), out.data_mut(), n, self.h, self.d);
        for i in 0..n {
            let row = out.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v += self.b2[j];
            }
        }
    }

    /// Forward that saves the activations the backward pass needs.
    /// Produces bit-identical outputs to [`Self::forward`].
    pub fn forward_cached(&self, x: &Tensor) -> (Tensor, FfnCache) {
        assert_eq!(x.shape()[1], self.d);
        let n = x.rows();
        let mut hpre = Tensor::zeros(&[n, self.h]);
        matmul_into(x.data(), self.w1.data(), hpre.data_mut(), n, self.d, self.h);
        for i in 0..n {
            let row = hpre.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v += self.b1[j];
            }
        }
        let mut hid = hpre.clone();
        for v in hid.data_mut() {
            *v = gelu(*v);
        }
        let mut out = Tensor::zeros(&[n, self.d]);
        matmul_into(hid.data(), self.w2.data(), out.data_mut(), n, self.h, self.d);
        for i in 0..n {
            let row = out.row_mut(i);
            for (j, v) in row.iter_mut().enumerate() {
                *v += self.b2[j];
            }
        }
        (out, FfnCache { x: x.clone(), hpre, hid })
    }

    /// Backward pass: upstream `dy [n, d]` → parameter grads + `dx`.
    ///
    /// `db2 = Σ_i dy_i`; `dW2 = hidᵀ·dy`; `d_hid = dy·W2ᵀ`;
    /// `d_hpre = d_hid ⊙ GeLU'(hpre)`; `db1 = Σ_i d_hpre_i`;
    /// `dW1 = xᵀ·d_hpre`; `dx = d_hpre·W1ᵀ`.
    pub fn backward(&self, cache: &FfnCache, dy: &Tensor) -> FfnGrads {
        let n = dy.rows();
        assert_eq!(dy.shape()[1], self.d);
        assert_eq!(cache.x.rows(), n);

        let mut db2 = vec![0.0f32; self.d];
        for i in 0..n {
            for (j, &g) in dy.row(i).iter().enumerate() {
                db2[j] += g;
            }
        }
        let dw2 = matmul_tn(&cache.hid, dy);

        // d_hpre = (dy · W2ᵀ) ⊙ gelu'(hpre)
        let mut dhpre = matmul_nt(dy, &self.w2);
        for (v, &p) in dhpre.data_mut().iter_mut().zip(cache.hpre.data()) {
            *v *= gelu_grad(p);
        }

        let mut db1 = vec![0.0f32; self.h];
        for i in 0..n {
            for (j, &g) in dhpre.row(i).iter().enumerate() {
                db1[j] += g;
            }
        }
        let dw1 = matmul_tn(&cache.x, &dhpre);
        let dx = matmul_nt(&dhpre, &self.w1);
        FfnGrads { dw1, db1, dw2, db2, dx }
    }

    /// Parameter count.
    pub fn num_params(&self) -> usize {
        self.d * self.h + self.h + self.h * self.d + self.d
    }

    /// FLOPs for a forward over `n` rows.
    pub fn flops(&self, n: usize) -> usize {
        2 * n * self.d * self.h * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_params() {
        let mut rng = Rng::seed(0);
        let f = Ffn::init(16, 64, &mut rng);
        let x = Tensor::randn(&[5, 16], &mut rng);
        let y = f.forward(&x);
        assert_eq!(y.shape(), &[5, 16]);
        assert_eq!(f.num_params(), 16 * 64 + 64 + 64 * 16 + 16);
    }

    #[test]
    fn forward_into_matches_forward() {
        let mut rng = Rng::seed(1);
        let f = Ffn::init(8, 32, &mut rng);
        let x = Tensor::randn(&[7, 8], &mut rng);
        let y = f.forward(&x);
        let mut scratch = Vec::new();
        let mut out = Tensor::zeros(&[7, 8]);
        f.forward_into(&x, &mut scratch, &mut out);
        assert!(y.allclose(&out, 1e-6));
    }

    #[test]
    fn zero_input_gives_bias_path() {
        let mut rng = Rng::seed(2);
        let mut f = Ffn::init(4, 8, &mut rng);
        f.b1.iter_mut().for_each(|b| *b = 0.0);
        f.b2 = vec![0.5; 4];
        let x = Tensor::zeros(&[3, 4]);
        let y = f.forward(&x);
        // gelu(0)=0 so output = b2 everywhere.
        for v in y.data() {
            assert!((v - 0.5).abs() < 1e-6);
        }
    }

    #[test]
    fn forward_cached_matches_forward_bitwise() {
        let mut rng = Rng::seed(3);
        let f = Ffn::init(6, 24, &mut rng);
        let x = Tensor::randn(&[9, 6], &mut rng);
        let y = f.forward(&x);
        let (yc, cache) = f.forward_cached(&x);
        assert!(y.allclose(&yc, 0.0));
        assert_eq!(cache.x, x);
        assert_eq!(cache.hpre.shape(), &[9, 24]);
    }

    /// Finite-difference check of every gradient the backward produces.
    /// Scalar loss: `L = Σ dy ⊙ y` with a fixed `dy`, so `∂L/∂θ` equals
    /// the backward's output exactly.
    #[test]
    fn backward_matches_finite_differences() {
        let mut rng = Rng::seed(4);
        let mut f = Ffn::init(5, 11, &mut rng);
        let x = Tensor::randn(&[7, 5], &mut rng);
        let dy = Tensor::randn(&[7, 5], &mut rng);
        let loss = |f: &Ffn, x: &Tensor| -> f64 {
            let y = f.forward(x);
            y.data().iter().zip(dy.data()).map(|(&a, &b)| a as f64 * b as f64).sum()
        };
        let (_, cache) = f.forward_cached(&x);
        let grads = f.backward(&cache, &dy);

        let eps = 1e-2f32;
        let check = |analytic: f32, numeric: f64, what: &str| {
            let err = (analytic as f64 - numeric).abs();
            let scale = numeric.abs().max(analytic.abs() as f64).max(1.0);
            assert!(err / scale < 2e-2, "{what}: analytic {analytic} vs numeric {numeric}");
        };
        // Spot-check a handful of entries per tensor (central differences).
        for idx in [0usize, 7, 23, 41] {
            let i = idx % (5 * 11);
            let orig = f.w1.data()[i];
            f.w1.data_mut()[i] = orig + eps;
            let lp = loss(&f, &x);
            f.w1.data_mut()[i] = orig - eps;
            let lm = loss(&f, &x);
            f.w1.data_mut()[i] = orig;
            check(grads.dw1.data()[i], (lp - lm) / (2.0 * eps as f64), "dw1");
        }
        for i in [0usize, 4, 10] {
            let orig = f.b1[i];
            f.b1[i] = orig + eps;
            let lp = loss(&f, &x);
            f.b1[i] = orig - eps;
            let lm = loss(&f, &x);
            f.b1[i] = orig;
            check(grads.db1[i], (lp - lm) / (2.0 * eps as f64), "db1");
        }
        for idx in [3usize, 19, 37] {
            let i = idx % (11 * 5);
            let orig = f.w2.data()[i];
            f.w2.data_mut()[i] = orig + eps;
            let lp = loss(&f, &x);
            f.w2.data_mut()[i] = orig - eps;
            let lm = loss(&f, &x);
            f.w2.data_mut()[i] = orig;
            check(grads.dw2.data()[i], (lp - lm) / (2.0 * eps as f64), "dw2");
        }
        for i in [0usize, 2, 4] {
            let orig = f.b2[i];
            f.b2[i] = orig + eps;
            let lp = loss(&f, &x);
            f.b2[i] = orig - eps;
            let lm = loss(&f, &x);
            f.b2[i] = orig;
            check(grads.db2[i], (lp - lm) / (2.0 * eps as f64), "db2");
        }
        // Input gradient.
        let mut xp = x.clone();
        for i in [0usize, 12, 30] {
            let orig = xp.data()[i];
            xp.data_mut()[i] = orig + eps;
            let lp = loss(&f, &xp);
            xp.data_mut()[i] = orig - eps;
            let lm = loss(&f, &xp);
            xp.data_mut()[i] = orig;
            check(grads.dx.data()[i], (lp - lm) / (2.0 * eps as f64), "dx");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut r1 = Rng::seed(5);
        let mut r2 = Rng::seed(5);
        let f1 = Ffn::init(6, 12, &mut r1);
        let f2 = Ffn::init(6, 12, &mut r2);
        let x = Tensor::randn(&[2, 6], &mut r1);
        let x2 = Tensor::randn(&[2, 6], &mut r2);
        assert!(f1.forward(&x).allclose(&f2.forward(&x2), 0.0));
    }
}
