//! Blocked matrix multiplication.
//!
//! `C[m,n] = A[m,k] · B[k,n]`. The inner loops use an `i-k-j` ordering so
//! the `j` loop is a contiguous FMA sweep the compiler auto-vectorizes;
//! blocking over `k` keeps the `B` panel in cache. `matmul_par` shards rows
//! across scoped threads for the coordinator's batch-level calls.

use crate::tensor::Tensor;
use crate::util::threadpool::parallel_rows_mut;

/// Cache block size over the reduction dimension.
const KB: usize = 64;

/// Multiply into a caller-provided output slice (`m*n`, zeroed by callee).
pub fn matmul_into(a: &[f32], b: &[f32], c: &mut [f32], m: usize, k: usize, n: usize) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(b.len(), k * n);
    debug_assert_eq!(c.len(), m * n);
    c.fill(0.0);
    matmul_accumulate(a, b, c, m, k, n, 0..m);
}

/// Accumulating kernel over a row range (used by both serial and parallel
/// front-ends). `c` holds rows `rows` of the output, rebased to row 0,
/// and must already be initialized.
fn matmul_accumulate(
    a: &[f32],
    b: &[f32],
    c: &mut [f32],
    _m: usize,
    k: usize,
    n: usize,
    rows: std::ops::Range<usize>,
) {
    let base = rows.start;
    for kb in (0..k).step_by(KB) {
        let ke = (kb + KB).min(k);
        for i in rows.clone() {
            let arow = &a[i * k..i * k + k];
            let crow = &mut c[(i - base) * n..(i - base) * n + n];
            for kk in kb..ke {
                let aik = arow[kk];
                if aik == 0.0 {
                    continue; // dispatch matrices are mostly zero
                }
                let brow = &b[kk * n..kk * n + n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    }
}

/// `A[m,k] · B[k,n]` → new `Tensor[m,n]` (serial).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_into(a.data(), b.data(), out.data_mut(), m, k, n);
    out
}

/// Parallel matmul: rows sharded over `threads` scoped threads.
pub fn matmul_par(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    let (adata, bdata) = (a.data(), b.data());
    // Shard output rows: each chunk is a disjoint `&mut` row slice.
    parallel_rows_mut(out.data_mut(), n, threads, |range, cslice| {
        matmul_accumulate(adata, bdata, cslice, m, k, n, range);
    });
    out
}

/// Blocked matmul **without** the zero-skip: used to model baseline
/// systems whose dense einsums pay full FLOPs on mostly-zero one-hot
/// operands (a GPU einsum cannot skip zeros either). Same result as
/// [`matmul`].
pub fn matmul_dense(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let (k2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(k, k2, "matmul inner dims: {k} vs {k2}");
    let mut out = Tensor::zeros(&[m, n]);
    let (adata, bdata, cdata) = (a.data(), b.data(), out.data_mut());
    for kb in (0..k).step_by(KB) {
        let ke = (kb + KB).min(k);
        for i in 0..m {
            let arow = &adata[i * k..i * k + k];
            let crow = &mut cdata[i * n..i * n + n];
            for kk in kb..ke {
                let aik = arow[kk];
                let brow = &bdata[kk * n..kk * n + n];
                for j in 0..n {
                    crow[j] += aik * brow[j];
                }
            }
        }
    }
    out
}

/// TN kernel over a column range of the output: `C[m,n] = Aᵀ · B` with
/// `A` stored `[l, m]`. `c` holds rows `cols` of the output, rebased to
/// row 0, and must be zero-initialized. Blocked over the reduction
/// dimension `l` so a KB-row panel of `B` stays hot while every output
/// row in the range sweeps it; within each output row the batch rows
/// are still visited in globally increasing `i` order, so the addition
/// chain per element is identical to the unblocked loop.
fn matmul_tn_range(
    adata: &[f32],
    bdata: &[f32],
    c: &mut [f32],
    l: usize,
    m: usize,
    n: usize,
    cols: std::ops::Range<usize>,
) {
    let base = cols.start;
    for ib in (0..l).step_by(KB) {
        let ie = (ib + KB).min(l);
        for j in cols.clone() {
            let crow = &mut c[(j - base) * n..(j - base) * n + n];
            for i in ib..ie {
                let aij = adata[i * m + j];
                if aij == 0.0 {
                    continue; // zero rows (padding) contribute nothing
                }
                let brow = &bdata[i * n..i * n + n];
                for (ck, &bk) in crow.iter_mut().zip(brow) {
                    *ck += aij * bk;
                }
            }
        }
    }
}

/// NT kernel over a row range of the output: `C[m,n] = A · Bᵀ` with `B`
/// stored `[n, l]`. `c` holds rows `rows` of the output, rebased to row
/// 0, and must be zero-initialized. Blocked over the reduction
/// dimension `l`, carrying the accumulator through `C` between blocks —
/// each element's additions happen in the same ascending-`k` order as a
/// single full-length sweep, so results are bit-identical to the
/// unblocked loop.
fn matmul_nt_range(
    adata: &[f32],
    bdata: &[f32],
    c: &mut [f32],
    l: usize,
    n: usize,
    rows: std::ops::Range<usize>,
) {
    let base = rows.start;
    for kb in (0..l).step_by(KB) {
        let ke = (kb + KB).min(l);
        for i in rows.clone() {
            let arow = &adata[i * l..i * l + l];
            let crow = &mut c[(i - base) * n..(i - base) * n + n];
            for (j, cj) in crow.iter_mut().enumerate() {
                let brow = &bdata[j * l..j * l + l];
                let mut acc = *cj;
                for kk in kb..ke {
                    acc += arow[kk] * brow[kk];
                }
                *cj = acc;
            }
        }
    }
}

/// `Aᵀ[m,l]ᵀ · B[l,n]` → `C[m,n]` where `A` is `[l, m]` — the
/// weight-gradient kernel (`dW = xᵀ · dy` sums outer products over the
/// batch rows). Rows are accumulated in increasing row order and
/// all-zero rows are skipped, so inserting zero rows (padded-mode
/// buffers) leaves the result bit-identical — the property the
/// padded-vs-ragged backward equivalence rests on.
pub fn matmul_tn(a: &Tensor, b: &Tensor) -> Tensor {
    let (l, m) = (a.shape()[0], a.shape()[1]);
    let (l2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(l, l2, "matmul_tn row dims: {l} vs {l2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_tn_range(a.data(), b.data(), out.data_mut(), l, m, n, 0..m);
    out
}

/// Parallel [`matmul_tn`]: output rows (weight columns) sharded over
/// `threads` scoped threads. Each output row's accumulation order is
/// the same as the serial kernel's, so results are bit-identical for
/// any thread count.
pub fn matmul_tn_par(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (l, m) = (a.shape()[0], a.shape()[1]);
    let (l2, n) = (b.shape()[0], b.shape()[1]);
    assert_eq!(l, l2, "matmul_tn row dims: {l} vs {l2}");
    let mut out = Tensor::zeros(&[m, n]);
    let (adata, bdata) = (a.data(), b.data());
    parallel_rows_mut(out.data_mut(), n, threads, |range, cslice| {
        matmul_tn_range(adata, bdata, cslice, l, m, n, range);
    });
    out
}

/// `A[m,l] · B[n,l]ᵀ` → `C[m,n]` — the input-gradient kernel
/// (`dx = dy · Wᵀ`). Each output row depends only on its own input row,
/// so per-row results are independent of batch composition.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, l) = (a.shape()[0], a.shape()[1]);
    let (n, l2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(l, l2, "matmul_nt inner dims: {l} vs {l2}");
    let mut out = Tensor::zeros(&[m, n]);
    matmul_nt_range(a.data(), b.data(), out.data_mut(), l, n, 0..m);
    out
}

/// Parallel [`matmul_nt`]: output rows sharded over `threads` scoped
/// threads; bit-identical to the serial kernel for any thread count.
pub fn matmul_nt_par(a: &Tensor, b: &Tensor, threads: usize) -> Tensor {
    let (m, l) = (a.shape()[0], a.shape()[1]);
    let (n, l2) = (b.shape()[0], b.shape()[1]);
    assert_eq!(l, l2, "matmul_nt inner dims: {l} vs {l2}");
    let mut out = Tensor::zeros(&[m, n]);
    let (adata, bdata) = (a.data(), b.data());
    parallel_rows_mut(out.data_mut(), n, threads, |range, cslice| {
        matmul_nt_range(adata, bdata, cslice, l, n, range);
    });
    out
}

/// Naive triple loop for testing the blocked kernels.
pub fn matmul_naive(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape()[0], a.shape()[1]);
    let n = b.shape()[1];
    let mut out = Tensor::zeros(&[m, n]);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for kk in 0..k {
                acc += a.at(i, kk) * b.at(kk, j);
            }
            out.set(i, j, acc);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::proptest::for_all;
    use crate::util::rng::Rng;

    #[test]
    fn matches_naive_small() {
        let mut rng = Rng::seed(0);
        let a = Tensor::randn(&[7, 13], &mut rng);
        let b = Tensor::randn(&[13, 5], &mut rng);
        let fast = matmul(&a, &b);
        let slow = matmul_naive(&a, &b);
        assert!(fast.allclose(&slow, 1e-4), "diff={}", fast.max_abs_diff(&slow));
    }

    #[test]
    fn matches_naive_blocked_boundary() {
        // k crosses the KB=64 block boundary.
        let mut rng = Rng::seed(1);
        let a = Tensor::randn(&[3, 130], &mut rng);
        let b = Tensor::randn(&[130, 9], &mut rng);
        assert!(matmul(&a, &b).allclose(&matmul_naive(&a, &b), 1e-3));
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::seed(2);
        let a = Tensor::randn(&[65, 40], &mut rng);
        let b = Tensor::randn(&[40, 33], &mut rng);
        let s = matmul(&a, &b);
        for threads in [1, 2, 4, 8] {
            let p = matmul_par(&a, &b, threads);
            assert!(p.allclose(&s, 1e-5), "threads={threads}");
        }
    }

    #[test]
    fn identity_multiplication() {
        let mut rng = Rng::seed(3);
        let a = Tensor::randn(&[6, 6], &mut rng);
        let mut eye = Tensor::zeros(&[6, 6]);
        for i in 0..6 {
            eye.set(i, i, 1.0);
        }
        assert!(matmul(&a, &eye).allclose(&a, 1e-6));
        assert!(matmul(&eye, &a).allclose(&a, 1e-6));
    }

    #[test]
    fn property_linear_in_first_argument() {
        for_all(16, |g| {
            let m = g.usize_in(1..8);
            let k = g.usize_in(1..8);
            let n = g.usize_in(1..8);
            let mut rng = Rng::seed(g.case as u64);
            let a1 = Tensor::randn(&[m, k], &mut rng);
            let a2 = Tensor::randn(&[m, k], &mut rng);
            let b = Tensor::randn(&[k, n], &mut rng);
            let mut sum_a = a1.clone();
            sum_a.add_assign(&a2);
            let lhs = matmul(&sum_a, &b);
            let mut rhs = matmul(&a1, &b);
            rhs.add_assign(&matmul(&a2, &b));
            assert!(lhs.allclose(&rhs, 1e-4));
        });
    }

    #[test]
    fn tn_matches_explicit_transpose() {
        let mut rng = Rng::seed(5);
        let a = Tensor::randn(&[9, 4], &mut rng);
        let b = Tensor::randn(&[9, 6], &mut rng);
        let fast = matmul_tn(&a, &b);
        let slow = matmul_naive(&a.transpose(), &b);
        assert!(fast.allclose(&slow, 1e-4), "diff={}", fast.max_abs_diff(&slow));
    }

    #[test]
    fn nt_matches_explicit_transpose() {
        let mut rng = Rng::seed(6);
        let a = Tensor::randn(&[5, 8], &mut rng);
        let b = Tensor::randn(&[7, 8], &mut rng);
        let fast = matmul_nt(&a, &b);
        let slow = matmul_naive(&a, &b.transpose());
        assert!(fast.allclose(&slow, 1e-4), "diff={}", fast.max_abs_diff(&slow));
    }

    #[test]
    fn tn_ignores_interleaved_zero_rows() {
        // The bit-exactness property the padded-vs-ragged backward
        // equivalence needs: adding zero rows anywhere leaves dW
        // bit-identical.
        let mut rng = Rng::seed(7);
        let a = Tensor::randn(&[4, 3], &mut rng);
        let b = Tensor::randn(&[4, 5], &mut rng);
        let compact = matmul_tn(&a, &b);
        // Interleave zero rows: rows 0, z, 1, z, 2, 3, z.
        let order = [Some(0), None, Some(1), None, Some(2), Some(3), None];
        let mut ap = Tensor::zeros(&[order.len(), 3]);
        let mut bp = Tensor::zeros(&[order.len(), 5]);
        for (i, slot) in order.iter().enumerate() {
            if let Some(src) = slot {
                ap.row_mut(i).copy_from_slice(a.row(*src));
                bp.row_mut(i).copy_from_slice(b.row(*src));
            }
        }
        let padded = matmul_tn(&ap, &bp);
        assert!(compact.allclose(&padded, 0.0));
    }

    #[test]
    fn tn_matches_transpose_across_block_boundary() {
        // The reduction dim crosses the KB=64 block boundary.
        let mut rng = Rng::seed(8);
        let a = Tensor::randn(&[150, 4], &mut rng);
        let b = Tensor::randn(&[150, 6], &mut rng);
        let fast = matmul_tn(&a, &b);
        let slow = matmul_naive(&a.transpose(), &b);
        assert!(fast.allclose(&slow, 1e-3), "diff={}", fast.max_abs_diff(&slow));
    }

    #[test]
    fn nt_matches_transpose_across_block_boundary() {
        let mut rng = Rng::seed(9);
        let a = Tensor::randn(&[5, 150], &mut rng);
        let b = Tensor::randn(&[7, 150], &mut rng);
        let fast = matmul_nt(&a, &b);
        let slow = matmul_naive(&a, &b.transpose());
        assert!(fast.allclose(&slow, 1e-3), "diff={}", fast.max_abs_diff(&slow));
    }

    #[test]
    fn tn_parallel_bit_identical_to_serial() {
        let mut rng = Rng::seed(10);
        let a = Tensor::randn(&[130, 9], &mut rng);
        let b = Tensor::randn(&[130, 11], &mut rng);
        let s = matmul_tn(&a, &b);
        for threads in [1, 2, 3, 8] {
            let p = matmul_tn_par(&a, &b, threads);
            assert!(p.allclose(&s, 0.0), "threads={threads}");
        }
    }

    #[test]
    fn nt_parallel_bit_identical_to_serial() {
        let mut rng = Rng::seed(11);
        let a = Tensor::randn(&[9, 130], &mut rng);
        let b = Tensor::randn(&[13, 130], &mut rng);
        let s = matmul_nt(&a, &b);
        for threads in [1, 2, 3, 8] {
            let p = matmul_nt_par(&a, &b, threads);
            assert!(p.allclose(&s, 0.0), "threads={threads}");
        }
    }

    #[test]
    fn skips_zero_entries_correctly() {
        // The `aik == 0.0` skip must not change results.
        let a = Tensor::from_vec(vec![0.0, 2.0, 0.0, 0.0, 3.0, 0.0], &[2, 3]).unwrap();
        let mut rng = Rng::seed(4);
        let b = Tensor::randn(&[3, 4], &mut rng);
        assert!(matmul(&a, &b).allclose(&matmul_naive(&a, &b), 1e-6));
    }
}
