//! Native (pure-Rust) neural-network math.
//!
//! Two uses:
//! 1. **Baseline experts / gates** when benchmarking the coordinator
//!    without PJRT artifacts (the Fig-8 pipelines run thousands of expert
//!    FFN calls; native math keeps the benches self-contained).
//! 2. **Reference implementations** for tests of the HLO-executing path.
//!
//! The hot kernel is [`matmul::matmul`] — a blocked, transposed-B kernel
//! with optional thread parallelism; everything else is elementwise.

pub mod activation;
pub mod ffn;
pub mod matmul;
pub mod ops;

pub use activation::{gelu, gelu_grad, relu};
pub use ffn::{Ffn, FfnCache, FfnGrads};
pub use matmul::{
    matmul, matmul_into, matmul_nt, matmul_nt_par, matmul_par, matmul_tn, matmul_tn_par,
};
pub use ops::{cross_entropy, layernorm, log_softmax, softmax_rows};
