//! Row-wise softmax / layernorm / cross-entropy.

use crate::tensor::Tensor;

/// Numerically-stable softmax over the last axis of a 2-D tensor, in place.
pub fn softmax_rows(t: &mut Tensor) {
    let w = t.row_len();
    let rows = t.rows();
    let data = t.data_mut();
    for i in 0..rows {
        let row = &mut data[i * w..(i + 1) * w];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut sum = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - max).exp();
            sum += *v;
        }
        let inv = 1.0 / sum;
        for v in row.iter_mut() {
            *v *= inv;
        }
    }
}

/// Log-softmax over the last axis, in place.
pub fn log_softmax(t: &mut Tensor) {
    let w = t.row_len();
    let rows = t.rows();
    let data = t.data_mut();
    for i in 0..rows {
        let row = &mut data[i * w..(i + 1) * w];
        let max = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let lse = row.iter().map(|v| (v - max).exp()).sum::<f32>().ln() + max;
        for v in row.iter_mut() {
            *v -= lse;
        }
    }
}

/// LayerNorm over the last axis with learnable gain/bias.
pub fn layernorm(t: &mut Tensor, gamma: &[f32], beta: &[f32], eps: f32) {
    let w = t.row_len();
    assert_eq!(gamma.len(), w);
    assert_eq!(beta.len(), w);
    let rows = t.rows();
    let data = t.data_mut();
    for i in 0..rows {
        let row = &mut data[i * w..(i + 1) * w];
        let mean = row.iter().sum::<f32>() / w as f32;
        let var = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / w as f32;
        let inv = 1.0 / (var + eps).sqrt();
        for (j, v) in row.iter_mut().enumerate() {
            *v = (*v - mean) * inv * gamma[j] + beta[j];
        }
    }
}

/// Mean cross-entropy of logits `[tokens, vocab]` against integer targets.
pub fn cross_entropy(logits: &Tensor, targets: &[u32]) -> f32 {
    assert_eq!(logits.rows(), targets.len());
    let mut ls = logits.clone();
    log_softmax(&mut ls);
    let mut total = 0.0f64;
    for (i, &t) in targets.iter().enumerate() {
        total -= ls.at(i, t as usize) as f64;
    }
    (total / targets.len() as f64) as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn softmax_rows_sum_to_one() {
        let mut rng = Rng::seed(0);
        let mut t = Tensor::randn(&[5, 9], &mut rng);
        softmax_rows(&mut t);
        for i in 0..5 {
            let s: f32 = t.row(i).iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
            assert!(t.row(i).iter().all(|&v| v >= 0.0));
        }
    }

    #[test]
    fn softmax_handles_large_values() {
        let mut t = Tensor::from_vec(vec![1000.0, 1001.0], &[1, 2]).unwrap();
        softmax_rows(&mut t);
        assert!(t.data().iter().all(|v| v.is_finite()));
        assert!((t.at(0, 1) - 0.7311).abs() < 1e-3);
    }

    #[test]
    fn log_softmax_consistent_with_softmax() {
        let mut rng = Rng::seed(1);
        let t = Tensor::randn(&[3, 7], &mut rng);
        let mut sm = t.clone();
        softmax_rows(&mut sm);
        let mut lsm = t.clone();
        log_softmax(&mut lsm);
        for (a, b) in sm.data().iter().zip(lsm.data()) {
            assert!((a.ln() - b).abs() < 1e-4);
        }
    }

    #[test]
    fn layernorm_zero_mean_unit_var() {
        let mut rng = Rng::seed(2);
        let mut t = Tensor::randn(&[4, 32], &mut rng);
        let gamma = vec![1.0; 32];
        let beta = vec![0.0; 32];
        layernorm(&mut t, &gamma, &beta, 1e-5);
        for i in 0..4 {
            let row = t.row(i);
            let mean: f32 = row.iter().sum::<f32>() / 32.0;
            let var: f32 = row.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / 32.0;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn cross_entropy_perfect_prediction_is_small() {
        // Huge logit on the target class → loss ≈ 0.
        let mut logits = Tensor::zeros(&[2, 4]);
        logits.set(0, 1, 50.0);
        logits.set(1, 3, 50.0);
        assert!(cross_entropy(&logits, &[1, 3]) < 1e-4);
        // Uniform logits → ln(vocab).
        let logits = Tensor::zeros(&[2, 4]);
        assert!((cross_entropy(&logits, &[0, 2]) - (4.0f32).ln()).abs() < 1e-5);
    }
}
