//! The `metrics` perf-trajectory harness: pinned fig benches →
//! `BENCH_<n>.json` → regression gate.
//!
//! Runs a fixed-seed, fixed-config subset of the fig benches (fig10
//! ragged, fig12 overlap, fig13 hier+dedup, fig15 wire precision,
//! fig14 placement, fig11 train, fig9 serving)
//! and assembles one durable record — host, git revision, timestamp,
//! per-fig walls and the model-level metrics (`comm_exposed`,
//! `overlap_efficiency`, NIC/intra-node bytes, serving tail latencies).
//! The record is appended at the repo root as `BENCH_<n>.json`, one per
//! PR, following the persistent-metrics pattern of rust-analyzer's
//! xtask. A comparator loads the previous record and fails with a
//! per-metric delta table when any `wall*` metric regresses beyond a
//! threshold — everything else (bytes, quantiles, losses) is
//! informational trajectory data.
//!
//! All numbers here flow through the same schema module as the `--json`
//! flags ([`crate::obs::schema`]), so field names cannot drift between
//! the CLI surfaces and the perf history.

use crate::benchkit::{bench, black_box, BenchOpts, Table};
use crate::comm::schedule::CommChoice;
use crate::comm::{WirePrecision, F32_BYTES};
use crate::config::{ClusterConfig, GateKind, MoeConfig};
use crate::error::Result;
use crate::moe::{DispatchMode, MoeLayer, MoeLayerOptions};
use crate::obs::schema::WALL_PREFIX;
use crate::pipeline::ChunkChoice;
use crate::serve::{ArrivalProcess, ServeConfig, ServeEngine};
use crate::tensor::Tensor;
use crate::train::{NativeTrainer, TrainRunConfig};
use crate::util::json::Json;
use crate::util::rng::Rng;
use std::path::{Path, PathBuf};
use std::time::Instant;

/// Ordinal used for the very first record, when the repo root holds no
/// `BENCH_<n>.json` yet. Every later run derives its ordinal from the
/// highest existing record (`previous_bench` + 1) instead of a pinned
/// constant, so the history can grow without editing this module.
pub const FIRST_BENCH_ID: u32 = 8;

/// Version of the record layout (bump when fig entries change shape).
pub const SCHEMA_VERSION: u32 = 1;

/// Default wall-regression threshold: fail when a wall metric exceeds
/// the previous record's by this factor. Generous on purpose — records
/// are produced on whatever host ran the PR, so only step-function
/// regressions (an accidentally serialized overlap loop, a dropped
/// dedup) should trip it, not run-to-run noise. CI overrides with
/// `--threshold` for its shared-runner variance.
pub const DEFAULT_THRESHOLD: f64 = 2.0;

/// One comparator row: a wall metric in both records.
#[derive(Clone, Debug)]
pub struct DeltaRow {
    pub fig: String,
    pub metric: String,
    pub prev: f64,
    pub cur: f64,
    /// `cur / prev` (infinite when prev is 0).
    pub ratio: f64,
    pub regressed: bool,
}

/// Run the pinned fig subset. Each entry is `(fig name, metrics
/// object)`; metrics whose key starts with [`WALL_PREFIX`] are
/// regression-gated, the rest are trajectory data.
pub fn run_figs() -> Result<Vec<(String, Json)>> {
    Ok(vec![
        ("fig10_ragged".into(), fig10_ragged()?),
        ("fig12_overlap".into(), fig12_overlap()?),
        ("fig13_hier_dedup".into(), fig13_hier_dedup()?),
        ("fig15_wire_precision".into(), fig15_wire_precision()?),
        ("fig14_placement".into(), fig14_placement()?),
        ("fig11_train".into(), fig11_train()?),
        ("fig9_serving".into(), fig9_serving()?),
    ])
}

/// Fig 10 pin: padded vs ragged forward, cf 2.0, 16 experts, 2×2 GPUs,
/// 256 tokens/rank, layer seed 42, data seed 7.
fn fig10_ragged() -> Result<Json> {
    let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
    let world = cluster.world();
    let d = 64usize;
    let cfg = MoeConfig {
        num_experts: 16,
        d_model: d,
        ffn_hidden: 2 * d,
        capacity_factor: 2.0,
        gate: GateKind::Switch,
    };
    let padded = MoeLayer::native(
        cfg.clone(),
        cluster.clone(),
        MoeLayerOptions { dispatch: DispatchMode::Padded, ..Default::default() },
        42,
    )?;
    let ragged = MoeLayer::native(
        cfg,
        cluster,
        MoeLayerOptions { dispatch: DispatchMode::Ragged, ..Default::default() },
        42,
    )?;
    let mut rng = Rng::seed(7);
    let shards: Vec<Tensor> = (0..world).map(|_| Tensor::randn(&[256, d], &mut rng)).collect();
    let (_, rep_p) = padded.forward(&shards)?;
    let (_, rep_r) = ragged.forward(&shards)?;
    let opts = BenchOpts::quick();
    let wall_p = bench("fig10 padded", &opts, || {
        black_box(padded.forward(black_box(&shards)).unwrap());
    });
    let wall_r = bench("fig10 ragged", &opts, || {
        black_box(ragged.forward(black_box(&shards)).unwrap());
    });
    Ok(Json::obj(vec![
        ("wall_padded", Json::num(wall_p.median)),
        ("wall_ragged", Json::num(wall_r.median)),
        ("bytes_on_wire_padded", Json::num(rep_p.bytes_on_wire as f64)),
        ("bytes_on_wire_ragged", Json::num(rep_r.bytes_on_wire as f64)),
        (
            "bytes_saved_frac",
            Json::num(1.0 - rep_r.bytes_on_wire as f64 / rep_p.bytes_on_wire.max(1) as f64),
        ),
        ("flops_saved_frac", Json::num(1.0 - rep_r.expert_flops / rep_p.expert_flops.max(1.0))),
    ]))
}

/// Fig 12 pin: auto-chunked overlap vs unchunked baseline — 16 experts,
/// ffn 512, cf 2.0, 1024 tokens/rank, serial experts, auto schedule.
fn fig12_overlap() -> Result<Json> {
    let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
    let world = cluster.world();
    let d = 64usize;
    let cfg = MoeConfig {
        num_experts: 16,
        d_model: d,
        ffn_hidden: 8 * d,
        capacity_factor: 2.0,
        gate: GateKind::Switch,
    };
    let layer_of = |chunks: ChunkChoice| {
        MoeLayer::native(
            cfg.clone(),
            cluster.clone(),
            MoeLayerOptions {
                alltoall: CommChoice::Auto,
                chunks,
                threads: 1,
                ..Default::default()
            },
            42,
        )
    };
    let base = layer_of(ChunkChoice::Fixed(1))?;
    let auto = layer_of(ChunkChoice::Auto)?;
    let mut rng = Rng::seed(7);
    let shards: Vec<Tensor> = (0..world).map(|_| Tensor::randn(&[1024, d], &mut rng)).collect();
    let (_, rep_base) = base.forward(&shards)?;
    let (_, rep) = auto.forward(&shards)?;
    let wall = bench("fig12 auto-chunked", &BenchOpts::quick(), || {
        black_box(auto.forward(black_box(&shards)).unwrap());
    });
    Ok(Json::obj(vec![
        ("wall_step", Json::num(wall.median)),
        ("n_chunks", Json::num(rep.n_chunks as f64)),
        ("comm_exposed_unchunked", Json::num(rep_base.comm_exposed)),
        ("comm_exposed", Json::num(rep.comm_exposed)),
        ("comm_hidden", Json::num(rep.comm_hidden)),
        ("overlap_efficiency", Json::num(rep.overlap_efficiency())),
        ("critical_path", Json::num(rep.critical_path)),
    ]))
}

/// Skewed batch aligned with adjacent expert pairs — the co-located-
/// replica regime where dedup pays (fig13's construction, pinned to the
/// GShard point).
fn skewed_shards(gate: &Tensor, w: usize, tokens: usize, d: usize, seed: u64) -> Vec<Tensor> {
    let mut rng = Rng::seed(seed);
    let e = gate.row_len();
    let centroids: Vec<Vec<f32>> = (0..3)
        .map(|c| {
            let (e1, e2) = ((2 * c) % e, (2 * c + 1) % e);
            (0..d).map(|i| 3.0 * (gate.row(i)[e1] + gate.row(i)[e2])).collect()
        })
        .collect();
    (0..w)
        .map(|_| {
            let mut x = Tensor::zeros(&[tokens, d]);
            for t in 0..tokens {
                let c = &centroids[t % centroids.len()];
                for (i, v) in x.row_mut(t).iter_mut().enumerate() {
                    *v = c[i] + 0.1 * rng.normal_f32();
                }
            }
            x
        })
        .collect()
}

/// Fig 13 pin: flat vs hier vs hier+dedup NIC bytes on a skewed GShard
/// (k=2) batch — 16 experts, cf 4.0, 2×2 GPUs, 128 tokens/rank.
fn fig13_hier_dedup() -> Result<Json> {
    let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
    let w = cluster.world();
    let d = 64usize;
    let cfg = MoeConfig {
        num_experts: 16,
        d_model: d,
        ffn_hidden: 2 * d,
        capacity_factor: 4.0,
        gate: GateKind::GShard,
    };
    let layer_of = |alltoall: CommChoice, dedup: bool| {
        MoeLayer::native(
            cfg.clone(),
            cluster.clone(),
            MoeLayerOptions {
                alltoall,
                dedup,
                chunks: ChunkChoice::Fixed(1),
                threads: 1,
                ..Default::default()
            },
            42,
        )
    };
    let probe = MoeLayer::native(cfg.clone(), cluster.clone(), Default::default(), 42)?;
    let shards = skewed_shards(&probe.gate_weight, w, 128, d, 9);
    let flat = layer_of(CommChoice::Flat, false)?;
    let hier = layer_of(CommChoice::Hierarchical, false)?;
    let ded = layer_of(CommChoice::Hierarchical, true)?;
    let (_, rep_flat) = flat.forward(&shards)?;
    let (_, rep_hier) = hier.forward(&shards)?;
    let (_, rep_ded) = ded.forward(&shards)?;
    let wall = bench("fig13 hier+dedup", &BenchOpts::quick(), || {
        black_box(ded.forward(black_box(&shards)).unwrap());
    });
    Ok(Json::obj(vec![
        ("wall_step", Json::num(wall.median)),
        ("bytes_nic_flat", Json::num(rep_flat.bytes_on_wire as f64)),
        ("bytes_nic_hier", Json::num(rep_hier.bytes_on_wire as f64)),
        ("bytes_nic_dedup", Json::num(rep_ded.bytes_on_wire as f64)),
        ("bytes_intra_dedup", Json::num(rep_ded.bytes_intra_node as f64)),
        ("rows_deduped", Json::num(rep_ded.rows_deduped as f64)),
        ("exchange_hier", Json::num(rep_hier.comm_total())),
        ("exchange_dedup", Json::num(rep_ded.comm_total())),
    ]))
}

/// Fig 15 pin: wire precision on the fig13 batch — bf16 must exactly
/// halve the NIC and intra-node bills of the f32 run (payload rows,
/// dedup index, and presum entries all shrink 2×) while outputs stay
/// within the encoding's tolerance.
fn fig15_wire_precision() -> Result<Json> {
    let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
    let w = cluster.world();
    let d = 64usize;
    let cfg = MoeConfig {
        num_experts: 16,
        d_model: d,
        ffn_hidden: 2 * d,
        capacity_factor: 4.0,
        gate: GateKind::GShard,
    };
    let layer_of = |wire: WirePrecision| {
        MoeLayer::native(
            cfg.clone(),
            cluster.clone(),
            MoeLayerOptions {
                alltoall: CommChoice::Hierarchical,
                dedup: true,
                wire,
                chunks: ChunkChoice::Fixed(1),
                threads: 1,
                ..Default::default()
            },
            42,
        )
    };
    let probe = MoeLayer::native(cfg.clone(), cluster.clone(), Default::default(), 42)?;
    let shards = skewed_shards(&probe.gate_weight, w, 128, d, 9);
    let full = layer_of(WirePrecision::F32)?;
    let half = layer_of(WirePrecision::Bf16)?;
    let (out_full, rep_full) = full.forward(&shards)?;
    let (out_half, rep_half) = half.forward(&shards)?;
    if rep_full.bytes_on_wire != 2 * rep_half.bytes_on_wire
        || rep_full.bytes_intra_node != 2 * rep_half.bytes_intra_node
    {
        return Err(crate::config_err!(
            "fig15 pin: bf16 must exactly halve the byte bill (NIC {} vs {}, intra {} vs {})",
            rep_full.bytes_on_wire,
            rep_half.bytes_on_wire,
            rep_full.bytes_intra_node,
            rep_half.bytes_intra_node
        ));
    }
    let drift = out_full
        .iter()
        .zip(&out_half)
        .map(|(a, b)| a.max_abs_diff(b))
        .fold(0.0f32, f32::max);
    if !(drift > 0.0 && drift < 1.0) {
        return Err(crate::config_err!("fig15 pin: bf16 output drift {drift} out of range"));
    }
    let wall = bench("fig15 bf16 wire", &BenchOpts::quick(), || {
        black_box(half.forward(black_box(&shards)).unwrap());
    });
    Ok(Json::obj(vec![
        ("wall_step", Json::num(wall.median)),
        ("bytes_nic_f32", Json::num(rep_full.bytes_on_wire as f64)),
        ("bytes_nic_bf16", Json::num(rep_half.bytes_on_wire as f64)),
        ("bytes_intra_bf16", Json::num(rep_half.bytes_intra_node as f64)),
        ("exchange_f32", Json::num(rep_full.comm_total())),
        ("exchange_bf16", Json::num(rep_half.comm_total())),
        ("bf16_output_drift", Json::num(drift as f64)),
    ]))
}

/// Fig 14 pin: adaptive placement on a skewed batch — the optimizer's
/// swap must cut the max per-node NIC load, and a skew-seeded adaptive
/// trainer must migrate experts with honestly charged bytes. Mirrors
/// `benches/fig14_placement.rs` at reduced scale.
fn fig14_placement() -> Result<Json> {
    use crate::placement::{
        max_node_nic_bytes, PlacementOptimizer, PlacementPolicy, ReplicaMap, TrafficWindow,
    };
    use crate::serve::PlacementRouter;
    let cluster = ClusterConfig { nodes: 2, gpus_per_node: 2, ..ClusterConfig::commodity(2) };
    let d = 64usize;
    let cfg = MoeConfig {
        num_experts: 8,
        d_model: d,
        ffn_hidden: 2 * d,
        capacity_factor: 4.0,
        gate: GateKind::Switch,
    };
    let row_bytes = d * F32_BYTES;
    let mut r_static = PlacementRouter::new(cfg.clone(), cluster.clone(), CommChoice::Auto, 14)?;
    // Skewed batch on the co-located pair (0, 1): tokens cluster around
    // their gate columns (fig14's construction, pinned).
    let mut rng = Rng::seed(140);
    let centroids: Vec<Vec<f32>> = [0usize, 1]
        .iter()
        .map(|&e| (0..d).map(|i| 3.0 * r_static.gate_weight.row(i)[e]).collect())
        .collect();
    let mut batch = Tensor::zeros(&[256, d]);
    for t in 0..256 {
        let c = &centroids[t % 2];
        for (i, v) in batch.row_mut(t).iter_mut().enumerate() {
            *v = c[i] + 0.05 * rng.normal_f32();
        }
    }
    let mut window = TrafficWindow::new(8);
    let mut last = None;
    for step in 0..8u64 {
        let dec = r_static.route_batch(&batch, step);
        window.observe(&dec.expert_counts);
        last = Some(dec);
    }
    let d_static = last.unwrap();
    let opt = PlacementOptimizer { min_gain: 0.0, ..Default::default() };
    let current = r_static.placement();
    let replicas = ReplicaMap::new(cfg.num_experts);
    let wall_propose = bench("fig14 propose", &BenchOpts::quick(), || {
        black_box(opt.propose(
            black_box(&window),
            &current,
            &replicas,
            &[],
            &r_static.net,
            row_bytes,
        ));
    });
    let delta = opt
        .propose(&window, &current, &replicas, &[], &r_static.net, row_bytes)
        .ok_or_else(|| crate::config_err!("fig14 pin: optimizer proposed nothing"))?;
    let mut r_adapt = PlacementRouter::new(cfg, cluster, CommChoice::Auto, 14)?;
    r_adapt.set_table(Some(delta.table))?;
    let d_adapt = r_adapt.route_batch(&batch, 0);
    let nic_static = max_node_nic_bytes(&d_static.counts, 2, row_bytes);
    let nic_adapt = max_node_nic_bytes(&d_adapt.counts, 2, row_bytes);

    // Skew-seeded adaptive training: migrations with honest bytes.
    let mut tcfg = TrainRunConfig::default_run();
    tcfg.steps = 15;
    tcfg.tokens_per_rank = 32;
    tcfg.log_every = 0;
    tcfg.seed = 11;
    tcfg.placement = PlacementPolicy::Adaptive;
    tcfg.placement_every = 5;
    tcfg.placement_window = 64;
    tcfg.placement_min_gain = 0.0;
    let mut trainer = NativeTrainer::new(tcfg)?;
    for _ in 0..64 {
        trainer.traffic.observe(&[300, 300, 1, 1, 1, 1, 1, 1]);
    }
    let t0 = Instant::now();
    let summary = trainer.run()?;
    let elapsed = t0.elapsed().as_secs_f64();
    Ok(Json::obj(vec![
        ("wall_propose", Json::num(wall_propose.median)),
        ("wall_adaptive_step", Json::num(elapsed / 15.0)),
        ("max_nic_static", Json::num(nic_static as f64)),
        ("max_nic_adaptive", Json::num(nic_adapt as f64)),
        (
            "nic_saved_frac",
            Json::num(1.0 - nic_adapt as f64 / nic_static.max(1) as f64),
        ),
        ("migrations", Json::num(summary.migrations as f64)),
        ("bytes_migrated", Json::num(summary.bytes_migrated as f64)),
    ]))
}

/// Fig 11 pin: 30 native training steps on the default run config.
fn fig11_train() -> Result<Json> {
    let mut cfg = TrainRunConfig::default_run();
    cfg.steps = 30;
    cfg.log_every = 0;
    let mut trainer = NativeTrainer::new(cfg)?;
    let t0 = Instant::now();
    let summary = trainer.run()?;
    let elapsed = t0.elapsed().as_secs_f64();
    let b = &summary.breakdown;
    Ok(Json::obj(vec![
        ("wall_per_step", Json::num(elapsed / 30.0)),
        ("final_loss", Json::num(summary.final_loss as f64)),
        ("comm_exposed", Json::num(b.comm_exposed)),
        ("comm_exposed_max", Json::num(b.comm_exposed_max)),
        ("overlap_efficiency", Json::num(b.overlap_efficiency)),
        ("bytes_on_wire", Json::num(b.bytes_on_wire)),
        ("bytes_on_wire_bwd", Json::num(b.bytes_on_wire_bwd)),
        ("bytes_intra_node", Json::num(b.bytes_intra_node)),
        ("critical_path", Json::num(b.critical_path)),
        ("critical_path_max", Json::num(b.critical_path_max)),
    ]))
}

/// Fig 9 pin: serving under Poisson 2000 req/s, switch gate, auto
/// schedule, 0.5 simulated seconds, seed 42.
fn fig9_serving() -> Result<Json> {
    let cfg = ServeConfig {
        moe: MoeConfig {
            num_experts: 16,
            d_model: 64,
            ffn_hidden: 128,
            capacity_factor: 1.25,
            gate: GateKind::Switch,
        },
        cluster: ClusterConfig::commodity(2),
        process: ArrivalProcess::Poisson { rate: 2000.0 },
        comm: CommChoice::Auto,
        slo: 0.05,
        duration: 0.5,
        seed: 42,
        ..ServeConfig::default_run()
    };
    let mut engine = ServeEngine::new(cfg)?;
    let t0 = Instant::now();
    let report = engine.run()?;
    let elapsed = t0.elapsed().as_secs_f64();
    let mut fields: Vec<(String, Json)> = vec![
        ("wall_run".into(), Json::num(elapsed)),
        ("completed".into(), Json::num(report.completed as f64)),
    ];
    fields.extend(crate::obs::schema::quantile_fields("latency", &report.latency));
    fields.extend(crate::obs::schema::quantile_fields("latency_window", &report.latency_window));
    fields.push(("goodput_tps".into(), Json::num(report.goodput_tps)));
    fields.push(("drop_rate".into(), Json::num(report.drop_rate)));
    Ok(Json::Obj(fields))
}

fn host_json() -> Json {
    let cores =
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    Json::obj(vec![
        ("os", Json::str(std::env::consts::OS)),
        ("arch", Json::str(std::env::consts::ARCH)),
        ("cores", Json::num(cores as f64)),
    ])
}

fn git_revision() -> String {
    std::process::Command::new("git")
        .args(["rev-parse", "--short", "HEAD"])
        .output()
        .ok()
        .filter(|o| o.status.success())
        .and_then(|o| String::from_utf8(o.stdout).ok())
        .map(|s| s.trim().to_string())
        .unwrap_or_else(|| "unknown".into())
}

fn unix_timestamp() -> f64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0)
}

/// Assemble the full `BENCH_<bench_id>.json` record from the fig
/// entries (callers derive `bench_id` from [`previous_bench`] + 1,
/// falling back to [`FIRST_BENCH_ID`] on an empty history).
pub fn record(figs: Vec<(String, Json)>, bench_id: u32) -> Json {
    Json::obj(vec![
        ("schema_version", Json::num(SCHEMA_VERSION as f64)),
        ("bench_id", Json::num(bench_id as f64)),
        ("revision", Json::str(git_revision())),
        ("timestamp", Json::num(unix_timestamp())),
        ("host", host_json()),
        ("figs", Json::Obj(figs)),
    ])
}

/// Find the newest `BENCH_<n>.json` in `dir` (highest `n`). This is
/// the comparison baseline; on a re-run it can be this PR's own record.
pub fn previous_bench(dir: &Path) -> Option<(u32, PathBuf)> {
    let mut best: Option<(u32, PathBuf)> = None;
    for entry in std::fs::read_dir(dir).ok()? {
        let Ok(entry) = entry else { continue };
        let path = entry.path();
        let Some(n) = path.file_name().and_then(|s| s.to_str()).and_then(|name| {
            name.strip_prefix("BENCH_")?.strip_suffix(".json")?.parse::<u32>().ok()
        }) else {
            continue;
        };
        if best.as_ref().is_none_or(|(b, _)| n > *b) {
            best = Some((n, path));
        }
    }
    best
}

/// Compare every wall metric present in both records. A row regresses
/// when `cur > prev * threshold`.
pub fn compare(prev: &Json, cur: &Json, threshold: f64) -> Vec<DeltaRow> {
    let mut rows = Vec::new();
    let (Some(Json::Obj(cur_figs)), Some(prev_figs)) = (cur.get("figs"), prev.get("figs")) else {
        return rows;
    };
    for (fig, metrics) in cur_figs {
        let Json::Obj(fields) = metrics else { continue };
        let Some(prev_metrics) = prev_figs.get(fig) else { continue };
        for (key, val) in fields {
            if !key.starts_with(WALL_PREFIX) {
                continue;
            }
            let (Some(cur_v), Some(prev_v)) =
                (val.as_f64(), prev_metrics.get(key).and_then(Json::as_f64))
            else {
                continue;
            };
            let ratio = if prev_v > 0.0 { cur_v / prev_v } else { f64::INFINITY };
            rows.push(DeltaRow {
                fig: fig.clone(),
                metric: key.clone(),
                prev: prev_v,
                cur: cur_v,
                ratio,
                regressed: cur_v > prev_v * threshold,
            });
        }
    }
    rows
}

/// Print the per-metric delta table and return the regression count.
pub fn emit_comparison(rows: &[DeltaRow], baseline: &str, threshold: f64) -> usize {
    use crate::util::stats::fmt_duration;
    let mut t = Table::new(
        &format!("Wall-time trajectory vs {baseline} (fail ratio > {threshold:.2})"),
        &["fig", "metric", "previous", "current", "ratio", "verdict"],
    );
    let mut regressions = 0usize;
    for r in rows {
        if r.regressed {
            regressions += 1;
        }
        t.row(vec![
            r.fig.clone(),
            r.metric.clone(),
            fmt_duration(r.prev),
            fmt_duration(r.cur),
            format!("{:.2}×", r.ratio),
            if r.regressed { "REGRESSED".into() } else { "ok".into() },
        ]);
    }
    t.emit(None);
    regressions
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(figs: Vec<(&str, Vec<(&str, f64)>)>) -> Json {
        Json::obj(vec![(
            "figs",
            Json::Obj(
                figs.into_iter()
                    .map(|(f, ms)| {
                        (
                            f.to_string(),
                            Json::Obj(
                                ms.into_iter()
                                    .map(|(k, v)| (k.to_string(), Json::num(v)))
                                    .collect(),
                            ),
                        )
                    })
                    .collect(),
            ),
        )])
    }

    #[test]
    fn comparator_flags_injected_regression() {
        let prev = rec(vec![
            ("fig10_ragged", vec![("wall_ragged", 0.010), ("bytes_on_wire_ragged", 1000.0)]),
            ("fig11_train", vec![("wall_per_step", 0.020)]),
        ]);
        // wall_ragged regresses 3×, wall_per_step improves; the bytes
        // field is informational and must not be gated.
        let cur = rec(vec![
            ("fig10_ragged", vec![("wall_ragged", 0.030), ("bytes_on_wire_ragged", 9999.0)]),
            ("fig11_train", vec![("wall_per_step", 0.010)]),
        ]);
        let rows = compare(&prev, &cur, DEFAULT_THRESHOLD);
        assert_eq!(rows.len(), 2);
        let bad = rows.iter().find(|r| r.metric == "wall_ragged").unwrap();
        assert!(bad.regressed);
        assert!((bad.ratio - 3.0).abs() < 1e-12);
        let good = rows.iter().find(|r| r.metric == "wall_per_step").unwrap();
        assert!(!good.regressed);
        assert_eq!(rows.iter().filter(|r| r.regressed).count(), 1);
    }

    #[test]
    fn comparator_tolerates_missing_and_new_figs() {
        let prev = rec(vec![("fig10_ragged", vec![("wall_ragged", 0.010)])]);
        let cur = rec(vec![
            ("fig10_ragged", vec![("wall_ragged", 0.011), ("wall_new_metric", 5.0)]),
            ("fig99_future", vec![("wall_x", 1.0)]),
        ]);
        // Only metrics present in BOTH records produce rows: new figs
        // and new walls establish their baseline silently.
        let rows = compare(&prev, &cur, DEFAULT_THRESHOLD);
        assert_eq!(rows.len(), 1);
        assert!(!rows[0].regressed);
    }

    #[test]
    fn previous_bench_picks_highest_ordinal() {
        let dir = std::env::temp_dir()
            .join(format!("hetumoe-bench-scan-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        for name in ["BENCH_2.json", "BENCH_10.json", "BENCH_bad.json", "notes.txt"] {
            std::fs::write(dir.join(name), "{}").unwrap();
        }
        let (n, path) = previous_bench(&dir).unwrap();
        assert_eq!(n, 10);
        assert!(path.ends_with("BENCH_10.json"));
        std::fs::remove_dir_all(&dir).unwrap();
        assert!(previous_bench(Path::new("/nonexistent-hetumoe")).is_none());
    }

    #[test]
    fn record_shape_is_stable() {
        let figs = vec![("fig10_ragged".to_string(), Json::obj(vec![("wall_x", Json::num(1.0))]))];
        let r = record(figs, FIRST_BENCH_ID + 3);
        assert_eq!(r.f64_field("schema_version").unwrap(), SCHEMA_VERSION as f64);
        assert_eq!(r.f64_field("bench_id").unwrap(), (FIRST_BENCH_ID + 3) as f64);
        assert!(r.get("revision").is_some());
        assert!(r.get("timestamp").is_some());
        assert!(r.get("host").unwrap().get("cores").is_some());
        assert!(r.get("figs").unwrap().get("fig10_ragged").is_some());
        // Round-trips through the hand-rolled parser.
        assert_eq!(Json::parse(&r.pretty()).unwrap(), r);
    }
}
