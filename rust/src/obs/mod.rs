//! Observability: step-level tracing and the durable perf trajectory.
//!
//! Two halves (DESIGN.md §12):
//!
//! - [`trace`] — a zero-dependency [`trace::TraceRecorder`] of nested
//!   spans that the step executor, the chunked overlap engine, the
//!   hierarchical exchange phases, the backward legs and the serving
//!   engine emit into, exported as Chrome trace-event JSON (loadable in
//!   Perfetto via `--trace-out`). Off by default; when disabled every
//!   emission site reduces to one relaxed atomic load, and enabling it
//!   is purely observational — outputs and gradients are bit-identical
//!   (property-tested in `tests/trace_neutrality.rs`).
//! - [`metrics`] — the `metrics` CLI harness: pinned fig benches →
//!   `BENCH_<n>.json` at the repo root → wall-time regression gate
//!   against the previous record.
//!
//! [`schema`] is the shared JSON vocabulary both halves and every
//! `--json` flag emit through.

pub mod metrics;
pub mod schema;
pub mod trace;

pub use trace::{ModelLane, Trace, TraceRecorder};
