//! The single JSON schema behind every machine-readable surface.
//!
//! Before this module, three hand-rolled emission paths could drift:
//! `Breakdown::to_json` (consumed by `train --json` and
//! `layer-bench --json`), `SloReport::to_json` (`serve --json`) and the
//! ad-hoc objects the bench harness wrote. All of them now delegate
//! here, and the `metrics` perf-trajectory records
//! ([`crate::obs::metrics`]) are built from the same emitters — so a
//! field renamed in one place renames everywhere, and the key-list
//! constants below let tests pin the schema (see DESIGN.md §12 for the
//! documented layout).

use crate::benchkit::BenchResult;
use crate::coordinator::metrics::Breakdown;
use crate::serve::slo::SloReport;
use crate::util::json::Json;
use crate::util::stats::Quantiles;

/// Keys of a breakdown object, in emission order.
pub const BREAKDOWN_KEYS: &[&str] = &[
    "phases",
    "total",
    "drop_rate",
    "padding_waste",
    "aux_loss",
    "bytes_on_wire",
    "bytes_on_wire_bwd",
    "bytes_intra_node",
    "bytes_intra_node_bwd",
    "rows_deduped",
    "wire",
    "expert_flops",
    "critical_path",
    "critical_path_min",
    "critical_path_max",
    "comm_exposed",
    "comm_exposed_min",
    "comm_exposed_max",
    "compute_exposed",
    "comm_hidden",
    "overlap_efficiency",
    "injected_delay",
    "faults_injected",
    "retries",
];

/// Keys of a serving SLO report object, in emission order.
pub const SLO_KEYS: &[&str] = &[
    "duration",
    "offered",
    "completed",
    "dropped",
    "rejected",
    "slo_violations",
    "latency_p50",
    "latency_p95",
    "latency_p99",
    "latency_window_p50",
    "latency_window_p95",
    "latency_window_p99",
    "latency_window_len",
    "mean_latency",
    "goodput_rps",
    "goodput_tps",
    "drop_rate",
    "mean_queue_depth",
    "max_queue_depth",
    "faults_injected",
    "retries",
    "breakdown",
];

/// Keys of one bench-harness result object.
pub const BENCH_RESULT_KEYS: &[&str] = &["name", "median", "mad", "mean", "p90", "iters"];

/// Wall metrics in `BENCH_*.json` fig entries start with this prefix;
/// the regression comparator gates on exactly these keys (everything
/// else — bytes, quantiles, losses — is informational).
pub const WALL_PREFIX: &str = "wall";

/// `{prefix}_p50/_p95/_p99` fields of a latency distribution.
pub fn quantile_fields(prefix: &str, q: &Quantiles) -> Vec<(String, Json)> {
    vec![
        (format!("{prefix}_p50"), Json::num(q.p50)),
        (format!("{prefix}_p95"), Json::num(q.p95)),
        (format!("{prefix}_p99"), Json::num(q.p99)),
    ]
}

/// The canonical breakdown object ([`Breakdown::to_json`] delegates
/// here).
pub fn breakdown_json(b: &Breakdown) -> Json {
    Json::obj(vec![
        (
            "phases",
            Json::Obj(b.phases.iter().map(|(n, t)| (n.clone(), Json::num(*t))).collect()),
        ),
        ("total", Json::num(b.total)),
        ("drop_rate", Json::num(b.drop_rate)),
        ("padding_waste", Json::num(b.padding_waste)),
        ("aux_loss", Json::num(b.aux_loss)),
        ("bytes_on_wire", Json::num(b.bytes_on_wire)),
        ("bytes_on_wire_bwd", Json::num(b.bytes_on_wire_bwd)),
        ("bytes_intra_node", Json::num(b.bytes_intra_node)),
        ("bytes_intra_node_bwd", Json::num(b.bytes_intra_node_bwd)),
        ("rows_deduped", Json::num(b.rows_deduped)),
        ("wire", Json::str(&b.wire)),
        ("expert_flops", Json::num(b.expert_flops)),
        ("critical_path", Json::num(b.critical_path)),
        ("critical_path_min", Json::num(b.critical_path_min)),
        ("critical_path_max", Json::num(b.critical_path_max)),
        ("comm_exposed", Json::num(b.comm_exposed)),
        ("comm_exposed_min", Json::num(b.comm_exposed_min)),
        ("comm_exposed_max", Json::num(b.comm_exposed_max)),
        ("compute_exposed", Json::num(b.compute_exposed)),
        ("comm_hidden", Json::num(b.comm_hidden)),
        ("overlap_efficiency", Json::num(b.overlap_efficiency)),
        ("injected_delay", Json::num(b.injected_delay)),
        ("faults_injected", Json::num(b.faults_injected as f64)),
        ("retries", Json::num(b.retries as f64)),
    ])
}

/// The canonical serving report object ([`SloReport::to_json`]
/// delegates here).
pub fn slo_json(r: &SloReport) -> Json {
    let mut fields: Vec<(String, Json)> = vec![
        ("duration".into(), Json::num(r.duration)),
        ("offered".into(), Json::num(r.offered as f64)),
        ("completed".into(), Json::num(r.completed as f64)),
        ("dropped".into(), Json::num(r.dropped as f64)),
        ("rejected".into(), Json::num(r.rejected as f64)),
        ("slo_violations".into(), Json::num(r.slo_violations as f64)),
    ];
    fields.extend(quantile_fields("latency", &r.latency));
    fields.extend(quantile_fields("latency_window", &r.latency_window));
    fields.push(("latency_window_len".into(), Json::num(r.latency_window_len as f64)));
    fields.push(("mean_latency".into(), Json::num(r.mean_latency)));
    fields.push(("goodput_rps".into(), Json::num(r.goodput_rps)));
    fields.push(("goodput_tps".into(), Json::num(r.goodput_tps)));
    fields.push(("drop_rate".into(), Json::num(r.drop_rate)));
    fields.push(("mean_queue_depth".into(), Json::num(r.mean_queue_depth)));
    fields.push(("max_queue_depth".into(), Json::num(r.max_queue_depth)));
    fields.push(("faults_injected".into(), Json::num(r.faults_injected as f64)));
    fields.push(("retries".into(), Json::num(r.retries as f64)));
    fields.push(("breakdown".into(), r.breakdown.to_json()));
    Json::Obj(fields)
}

/// The canonical bench-harness result object ([`BenchResult::to_json`]
/// delegates here).
pub fn bench_result_json(r: &BenchResult) -> Json {
    Json::obj(vec![
        ("name", Json::str(&r.name)),
        ("median", Json::num(r.median)),
        ("mad", Json::num(r.mad)),
        ("mean", Json::num(r.mean)),
        ("p90", Json::num(r.p90)),
        ("iters", Json::num(r.iters as f64)),
    ])
}

fn keys_of(j: &Json) -> Vec<String> {
    match j {
        Json::Obj(fields) => fields.iter().map(|(k, _)| k.clone()).collect(),
        _ => Vec::new(),
    }
}

/// Assert an emitted object carries exactly the pinned key list (used
/// by the drift tests here and in the consumer modules).
pub fn assert_keys(j: &Json, expect: &[&str]) {
    let got = keys_of(j);
    assert_eq!(got, expect.to_vec(), "schema drift: emitted keys diverge from the pin");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::metrics::MetricsAgg;
    use crate::moe::StepReport;

    #[test]
    fn breakdown_emission_matches_pinned_keys() {
        let mut agg = MetricsAgg::new();
        agg.push(&StepReport {
            wall: vec![("gate".into(), 0.1)],
            comm: vec![("alltoall_dispatch".into(), 0.2)],
            ..Default::default()
        });
        assert_keys(&agg.breakdown().to_json(), BREAKDOWN_KEYS);
    }

    #[test]
    fn slo_emission_matches_pinned_keys() {
        use crate::serve::slo::SloTracker;
        let r = SloTracker::new().report(1.0);
        let j = r.to_json();
        assert_keys(&j, SLO_KEYS);
        // The nested breakdown rides the same schema.
        assert_keys(j.get("breakdown").unwrap(), BREAKDOWN_KEYS);
    }

    #[test]
    fn bench_result_emission_matches_pinned_keys() {
        let r = BenchResult {
            name: "x".into(),
            median: 1.0,
            mad: 0.1,
            mean: 1.1,
            p90: 1.2,
            iters: 10,
        };
        assert_keys(&r.to_json(), BENCH_RESULT_KEYS);
    }

    #[test]
    fn quantile_fields_follow_the_prefix() {
        let q = Quantiles { p50: 1.0, p90: 2.0, p95: 3.0, p99: 4.0 };
        let f = quantile_fields("latency", &q);
        assert_eq!(f[0].0, "latency_p50");
        assert_eq!(f[2].0, "latency_p99");
    }
}
