//! Zero-dependency step-level tracing: nested spans on per-thread
//! lanes, exported as Chrome trace-event JSON (loadable in Perfetto or
//! `chrome://tracing`).
//!
//! Recording is **off by default** and purely observational: spans read
//! clocks and copy already-computed numbers, never data buffers, so
//! enabled and disabled runs are bit-identical in outputs, gradients
//! and every [`crate::moe::StepReport`] field (property-tested in
//! `tests/trace_neutrality.rs`). When disabled, [`span`] is one relaxed
//! atomic load returning an inert guard — the hot loop pays ~nothing.
//!
//! Two clock domains, exported as two Chrome processes:
//!
//! - **pid 1 (measured)** — wall-clock spans from `Instant` around the
//!   real stages (gate, layout, exchange data paths, expert batches,
//!   reverse layout, the backward legs). One lane (`tid`) per OS
//!   thread; guards are scope-ordered, so same-lane spans always nest.
//! - **pid 2 (modeled)** — the overlap engine's simulated timeline: the
//!   per-chunk `dispatch → expert → combine` schedule reconstructed
//!   from [`OverlapTiming::chunk_timeline`], laid out on a `net` lane
//!   and an `expert` lane per thread. Consecutive steps occupy
//!   consecutive windows (a per-thread modeled-clock cursor), so a
//!   whole training run reads as a contiguous timeline.
//!
//! Span args carry the step's accounting — `bytes_on_wire`,
//! `bytes_intra_node`, `rows_deduped`, the schedule and chunk picks —
//! so a Perfetto click answers "why was this step slow".

use crate::error::Result;
use crate::pipeline::OverlapTiming;
use crate::util::json::Json;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU32, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::Instant;

/// Chrome process id of the measured (wall-clock) lanes.
pub const PID_MEASURED: u32 = 1;
/// Chrome process id of the modeled (overlap-timeline) lanes.
pub const PID_MODELED: u32 = 2;

/// Recorded-event cap: a backstop so tracing a long bench loop cannot
/// exhaust memory. Events past the cap are counted, not stored.
pub const MAX_EVENTS: usize = 100_000;

/// One span argument value.
#[derive(Clone, Debug, PartialEq)]
pub enum TraceVal {
    Num(f64),
    Str(String),
}

impl From<f64> for TraceVal {
    fn from(v: f64) -> Self {
        TraceVal::Num(v)
    }
}

impl From<usize> for TraceVal {
    fn from(v: usize) -> Self {
        TraceVal::Num(v as f64)
    }
}

impl From<&str> for TraceVal {
    fn from(v: &str) -> Self {
        TraceVal::Str(v.to_string())
    }
}

impl From<String> for TraceVal {
    fn from(v: String) -> Self {
        TraceVal::Str(v)
    }
}

/// One complete span ("X" phase in the Chrome trace-event format).
/// Times are seconds: from the recorder epoch on measured lanes, from
/// the modeled-clock origin on modeled lanes.
#[derive(Clone, Debug)]
pub struct TraceEvent {
    pub name: String,
    pub pid: u32,
    pub tid: u32,
    pub ts: f64,
    pub dur: f64,
    pub args: Vec<(String, TraceVal)>,
}

struct RecorderState {
    events: Vec<TraceEvent>,
    /// Per-thread modeled-clock cursors: `(thread ordinal, seconds)`.
    cursors: Vec<(u32, f64)>,
    dropped: usize,
}

static ENABLED: AtomicBool = AtomicBool::new(false);
static OPEN_SPANS: AtomicI64 = AtomicI64::new(0);
static STATE: Mutex<RecorderState> =
    Mutex::new(RecorderState { events: Vec::new(), cursors: Vec::new(), dropped: 0 });
static EPOCH: OnceLock<Instant> = OnceLock::new();
static NEXT_THREAD: AtomicU32 = AtomicU32::new(0);

thread_local! {
    static THREAD_ORD: u32 = NEXT_THREAD.fetch_add(1, Ordering::Relaxed);
}

fn thread_ord() -> u32 {
    THREAD_ORD.with(|t| *t)
}

fn now_s() -> f64 {
    EPOCH.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Is recording on? One relaxed load — the entire disabled-path cost.
#[inline]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Spans begun but not yet ended (0 whenever all guards have dropped —
/// the "every begin has an end" property the tests assert).
pub fn open_spans() -> i64 {
    OPEN_SPANS.load(Ordering::Relaxed)
}

fn push_event(ev: TraceEvent) {
    let mut st = STATE.lock().unwrap();
    if st.events.len() >= MAX_EVENTS {
        st.dropped += 1;
    } else {
        st.events.push(ev);
    }
}

/// The process-global recorder. All methods are associated functions:
/// there is exactly one recorder, matching the one process the
/// simulated cluster runs in.
pub struct TraceRecorder;

impl TraceRecorder {
    /// Enable recording, clearing any previously captured events.
    pub fn start() {
        let _ = EPOCH.get_or_init(Instant::now);
        let mut st = STATE.lock().unwrap();
        st.events.clear();
        st.cursors.clear();
        st.dropped = 0;
        OPEN_SPANS.store(0, Ordering::Relaxed);
        ENABLED.store(true, Ordering::Relaxed);
    }

    /// Disable recording and drain the captured trace. Measured-lane
    /// timestamps are re-based so the earliest measured span starts at
    /// zero.
    pub fn stop() -> Trace {
        ENABLED.store(false, Ordering::Relaxed);
        let mut st = STATE.lock().unwrap();
        let mut events = std::mem::take(&mut st.events);
        let dropped = std::mem::take(&mut st.dropped);
        st.cursors.clear();
        drop(st);
        let t0 = events
            .iter()
            .filter(|e| e.pid == PID_MEASURED)
            .map(|e| e.ts)
            .fold(f64::INFINITY, f64::min);
        if t0.is_finite() {
            for e in events.iter_mut().filter(|e| e.pid == PID_MEASURED) {
                e.ts -= t0;
            }
        }
        Trace { events, dropped }
    }
}

/// Guard of one measured span on the calling thread's lane. Inert (and
/// allocation-free) when recording is disabled. Dropping the guard ends
/// the span; Rust scoping makes same-lane spans nest by construction.
pub struct SpanGuard {
    info: Option<SpanInfo>,
}

struct SpanInfo {
    name: String,
    tid: u32,
    start: f64,
    args: Vec<(String, TraceVal)>,
}

/// Begin a measured span (ends when the guard drops).
pub fn span(name: &str) -> SpanGuard {
    if !enabled() {
        return SpanGuard { info: None };
    }
    OPEN_SPANS.fetch_add(1, Ordering::Relaxed);
    SpanGuard {
        info: Some(SpanInfo {
            name: name.to_string(),
            tid: thread_ord(),
            start: now_s(),
            args: Vec::new(),
        }),
    }
}

impl SpanGuard {
    /// Attach an argument (visible in Perfetto's span details). No-op
    /// on an inert guard.
    pub fn arg(&mut self, key: &str, val: impl Into<TraceVal>) {
        if let Some(info) = &mut self.info {
            info.args.push((key.to_string(), val.into()));
        }
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        if let Some(info) = self.info.take() {
            let dur = now_s() - info.start;
            OPEN_SPANS.fetch_sub(1, Ordering::Relaxed);
            // The recorder may have stopped mid-span; the begin is
            // still balanced above, the event is simply not kept.
            if enabled() {
                push_event(TraceEvent {
                    name: info.name,
                    pid: PID_MEASURED,
                    tid: info.tid,
                    ts: info.start,
                    dur,
                    args: info.args,
                });
            }
        }
    }
}

/// Modeled-timeline lane of one event (two lanes per thread, mirroring
/// the overlap model's two serialized resources).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelLane {
    Net,
    Expert,
}

fn model_tid(lane: ModelLane) -> u32 {
    let base = thread_ord() * 2;
    match lane {
        ModelLane::Net => base,
        ModelLane::Expert => base + 1,
    }
}

/// Reserve a window of `dur` modeled seconds on this thread's modeled
/// timeline and return its start time. Consecutive calls lay windows
/// out back-to-back, so the modeled lanes read as one contiguous run.
/// Returns 0.0 (and reserves nothing) when recording is disabled.
pub fn model_window(dur: f64) -> f64 {
    if !enabled() {
        return 0.0;
    }
    let tid = thread_ord();
    let mut st = STATE.lock().unwrap();
    if let Some((_, cursor)) = st.cursors.iter_mut().find(|(t, _)| *t == tid) {
        let at = *cursor;
        *cursor += dur;
        at
    } else {
        st.cursors.push((tid, dur));
        0.0
    }
}

/// Emit one modeled event at absolute modeled time `start` (obtained
/// from [`model_window`]). No-op when recording is disabled.
pub fn model_event(
    lane: ModelLane,
    name: &str,
    start: f64,
    dur: f64,
    args: Vec<(String, TraceVal)>,
) {
    if !enabled() {
        return;
    }
    push_event(TraceEvent {
        name: name.to_string(),
        pid: PID_MODELED,
        tid: model_tid(lane),
        ts: start,
        dur,
        args,
    });
}

/// Emit the per-chunk timeline of one overlapped exchange region
/// starting at modeled time `at`: a `{prefix}exchange` container span
/// on the net lane carrying `args`, per-chunk `dispatch.c`/`combine.c`
/// spans inside it, and per-chunk `expert.c` spans on the expert lane —
/// all placed by [`OverlapTiming::chunk_timeline`], i.e. by exactly the
/// resource model that produced `critical_path`. No-op when disabled.
pub fn model_overlap(
    at: f64,
    prefix: &str,
    overlap: &OverlapTiming,
    mut args: Vec<(String, TraceVal)>,
) {
    if !enabled() {
        return;
    }
    args.push(("n_chunks".into(), overlap.n_chunks().into()));
    model_event(
        ModelLane::Net,
        &format!("{prefix}exchange"),
        at,
        overlap.critical_path,
        args,
    );
    for (c, (d_start, e_start, c_start)) in
        overlap.chunk_timeline().into_iter().enumerate()
    {
        model_event(
            ModelLane::Net,
            &format!("{prefix}dispatch.{c}"),
            at + d_start,
            overlap.dispatch[c],
            Vec::new(),
        );
        model_event(
            ModelLane::Expert,
            &format!("{prefix}expert.{c}"),
            at + e_start,
            overlap.compute[c],
            Vec::new(),
        );
        model_event(
            ModelLane::Net,
            &format!("{prefix}combine.{c}"),
            at + c_start,
            overlap.combine[c],
            Vec::new(),
        );
    }
}

/// A drained trace, ready for export.
pub struct Trace {
    pub events: Vec<TraceEvent>,
    /// Events discarded past [`MAX_EVENTS`].
    pub dropped: usize,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Export as a Chrome trace-event JSON object (`traceEvents` array
    /// of complete "X" events plus process/thread metadata; `ts`/`dur`
    /// in microseconds as the format requires).
    pub fn to_chrome_json(&self) -> Json {
        let mut evs: Vec<Json> = Vec::with_capacity(self.events.len() + 8);
        let meta = |name: &str, pid: u32, tid: Option<u32>, label: String| {
            let mut fields = vec![
                ("name", Json::str(name)),
                ("ph", Json::str("M")),
                ("pid", Json::num(pid as f64)),
            ];
            if let Some(t) = tid {
                fields.push(("tid", Json::num(t as f64)));
            }
            fields.push(("args", Json::obj(vec![("name", Json::str(&label))])));
            Json::obj(fields)
        };
        let mut lanes: Vec<(u32, u32)> = self.events.iter().map(|e| (e.pid, e.tid)).collect();
        lanes.sort_unstable();
        lanes.dedup();
        if lanes.iter().any(|&(p, _)| p == PID_MEASURED) {
            evs.push(meta("process_name", PID_MEASURED, None, "measured (wall clock)".into()));
        }
        if lanes.iter().any(|&(p, _)| p == PID_MODELED) {
            evs.push(meta(
                "process_name",
                PID_MODELED,
                None,
                "modeled (overlap timeline)".into(),
            ));
        }
        for &(pid, tid) in &lanes {
            let label = if pid == PID_MEASURED {
                format!("host-{tid}")
            } else if tid % 2 == 0 {
                format!("net-{}", tid / 2)
            } else {
                format!("expert-{}", tid / 2)
            };
            evs.push(meta("thread_name", pid, Some(tid), label));
        }
        for e in &self.events {
            evs.push(Json::obj(vec![
                ("name", Json::str(&e.name)),
                (
                    "cat",
                    Json::str(if e.pid == PID_MEASURED { "measured" } else { "modeled" }),
                ),
                ("ph", Json::str("X")),
                ("pid", Json::num(e.pid as f64)),
                ("tid", Json::num(e.tid as f64)),
                ("ts", Json::num(e.ts * 1e6)),
                ("dur", Json::num(e.dur * 1e6)),
                (
                    "args",
                    Json::Obj(
                        e.args
                            .iter()
                            .map(|(k, v)| {
                                let j = match v {
                                    TraceVal::Num(x) => Json::num(*x),
                                    TraceVal::Str(s) => Json::str(s),
                                };
                                (k.clone(), j)
                            })
                            .collect(),
                    ),
                ),
            ]));
        }
        let mut top = vec![
            ("traceEvents", Json::Arr(evs)),
            ("displayTimeUnit", Json::str("ms")),
        ];
        if self.dropped > 0 {
            top.push(("droppedEvents", Json::num(self.dropped as f64)));
        }
        Json::obj(top)
    }

    /// Write the Chrome-trace JSON to `path`.
    pub fn write(&self, path: &str) -> Result<()> {
        std::fs::write(path, self.to_chrome_json().pretty()).map_err(|e| {
            crate::error::HetuError::Runtime(format!("writing trace {path}: {e}"))
        })
    }

    /// Check that spans nest on every lane: sorted by start (ties:
    /// longest first), each span must either be disjoint from or fully
    /// contained in the enclosing one — partial overlap is an error.
    pub fn check_nesting(&self) -> std::result::Result<(), String> {
        const EPS: f64 = 1e-9;
        let mut lanes: Vec<(u32, u32)> = self.events.iter().map(|e| (e.pid, e.tid)).collect();
        lanes.sort_unstable();
        lanes.dedup();
        for (pid, tid) in lanes {
            let mut spans: Vec<&TraceEvent> = self
                .events
                .iter()
                .filter(|e| e.pid == pid && e.tid == tid)
                .collect();
            spans.sort_by(|a, b| a.ts.total_cmp(&b.ts).then(b.dur.total_cmp(&a.dur)));
            let mut stack: Vec<(f64, String)> = Vec::new();
            for s in spans {
                let end = s.ts + s.dur;
                while let Some((top_end, _)) = stack.last() {
                    if *top_end <= s.ts + EPS {
                        stack.pop();
                    } else {
                        break;
                    }
                }
                if let Some((top_end, top_name)) = stack.last() {
                    if end > *top_end + EPS {
                        return Err(format!(
                            "lane ({pid},{tid}): span '{}' [{:.9}, {:.9}] partially \
                             overlaps enclosing '{top_name}' ending {top_end:.9}",
                            s.name, s.ts, end
                        ));
                    }
                }
                stack.push((end, s.name.clone()));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // Only tests that leave the recorder DISABLED may live in this
    // binary: the lib unit tests run in parallel, and any concurrent
    // test exercising an instrumented path would pollute the global
    // event buffer. Tests that enable the recorder run serialized in
    // the `tests/trace_neutrality.rs` integration binary.

    #[test]
    fn disabled_recorder_is_inert() {
        assert!(!enabled());
        {
            let mut g = span("never-recorded");
            g.arg("x", 1.0);
        }
        assert_eq!(open_spans(), 0);
        assert_eq!(model_window(1.0), 0.0);
        model_event(ModelLane::Net, "nope", 0.0, 1.0, Vec::new());
    }

    #[test]
    fn nesting_check_rejects_partial_overlap() {
        let bad = Trace {
            events: vec![
                TraceEvent {
                    name: "a".into(),
                    pid: 1,
                    tid: 0,
                    ts: 0.0,
                    dur: 1.0,
                    args: vec![],
                },
                TraceEvent {
                    name: "b".into(),
                    pid: 1,
                    tid: 0,
                    ts: 0.5,
                    dur: 1.0,
                    args: vec![],
                },
            ],
            dropped: 0,
        };
        assert!(bad.check_nesting().is_err());
        // Same intervals on different lanes are fine.
        let ok = Trace {
            events: vec![
                TraceEvent {
                    name: "a".into(),
                    pid: 1,
                    tid: 0,
                    ts: 0.0,
                    dur: 1.0,
                    args: vec![],
                },
                TraceEvent {
                    name: "b".into(),
                    pid: 1,
                    tid: 1,
                    ts: 0.5,
                    dur: 1.0,
                    args: vec![],
                },
            ],
            dropped: 0,
        };
        ok.check_nesting().unwrap();
    }
}
