//! The staged step executor: one implementation of Algorithm 1's six
//! steps shared by every consumer.
//!
//! [`StepExecutor::run`] drives the stages
//!
//! ```text
//!   StageGate → StageLayout → StageDispatch → StageExpert → StageCombine
//!   (scores,      padded or      ragged/equal    per-expert     reverse
//!    routing,     ragged         chunk exchange   FFN batches    exchange +
//!    capacity)    buffers)                        on the pool)   reverse layout
//! ```
//!
//! in two flavors selected by the `collect_cache` flag:
//!
//! - **forward-only** — what [`crate::moe::MoeLayer::forward`] (and,
//!   via the timing model, the serving engine) consumes;
//! - **forward + cache** — additionally saves scores, routings, plans,
//!   per-expert FFN activation caches and the pre-reverse expert
//!   outputs, exactly what the training backward pass needs
//!   ([`crate::backprop::TrainMoeLayer`] consumes this flavor; the old
//!   duplicated six-step forward in `backprop/layer.rs` is gone).
//!
//! The expert stage runs each rank's per-expert ragged batches on the
//! shared [`crate::util::threadpool`] when `opts.threads > 1` and the
//! expert bank exposes concrete FFNs; outputs are bit-identical to
//! serial execution because every batch is an independent pure function
//! writing a disjoint buffer region. Exchange timing is attributed by
//! the chunked overlap model ([`crate::pipeline::overlap`]): the
//! per-step schedule still comes from the shared
//! [`crate::comm::schedule::pick_schedule`] decision (so training and
//! serving can never disagree), and the chunk count is then chosen from
//! the same traffic matrix plus the measured per-rank expert walls.

use crate::cluster::{ExpertPlacement, NetworkModel};
use crate::comm::hier_ragged::{
    dedup_traffic, hier_ragged_combine, hier_ragged_dispatch, row_meta, DedupMeta,
    DedupTraffic, RowMeta,
};
use crate::comm::ragged::{ragged_combine_placed, ragged_dispatch_placed, split_wire_bytes};
use crate::comm::schedule::{pick_schedule_dedup, transpose_counts, Schedule};
use crate::comm::{alltoall, hierarchical_alltoall, CommTiming, WireBytes, F32_BYTES};
use crate::config::{ClusterConfig, MoeConfig};
use crate::error::Result;
use crate::gating::{apply_capacity, DispatchPlan, Routing};
use crate::layout::{
    gather_expert_slices, naive_layout, opt_layout, ragged_layout, ragged_reverse_layout,
    reverse_layout, scatter_expert_slices, LayoutBuffer, RaggedLayoutBuffer,
};
use crate::moe::expert::ExpertExecutor;
use crate::moe::layer::dense_einsum_layout;
use crate::moe::{CommImpl, DispatchMode, LayoutImpl, MoeLayerOptions, StepReport};
use crate::nn::{matmul, Ffn, FfnCache};
use crate::obs::trace;
use crate::pipeline::{OverlapTiming, StagePlan};
use crate::tensor::Tensor;
use crate::util::threadpool;
use std::time::Instant;

/// The expert substrate the pipeline's expert stage runs on.
pub enum ExpertBank<'a> {
    /// Trait-object executors (the inference layer; may be
    /// artifact-backed). Runs serially unless every executor exposes a
    /// concrete [`Ffn`] through [`ExpertExecutor::as_ffn`].
    Infer(&'a [Box<dyn ExpertExecutor>]),
    /// Concrete FFNs that can cache activations (the training layer).
    Train(&'a [Ffn]),
}

impl<'a> ExpertBank<'a> {
    fn flops(&self, ge: usize, n: usize) -> f64 {
        match self {
            ExpertBank::Infer(ex) => ex[ge].flops(n),
            ExpertBank::Train(ffns) => ffns[ge].flops(n) as f64,
        }
    }

    /// Concrete FFN views when every expert exposes one (enables the
    /// pool-parallel expert stage); `None` if any executor is opaque.
    fn ffns(&self) -> Option<Vec<&'a Ffn>> {
        match self {
            ExpertBank::Train(ffns) => Some(ffns.iter().collect()),
            ExpertBank::Infer(ex) => ex.iter().map(|e| e.as_ffn()).collect(),
        }
    }

    fn run_serial(
        &self,
        ge: usize,
        rows: &Tensor,
        want_cache: bool,
    ) -> Result<(Tensor, Option<FfnCache>)> {
        match self {
            ExpertBank::Infer(ex) => Ok((ex[ge].forward(rows)?, None)),
            ExpertBank::Train(ffns) => {
                if want_cache {
                    let (out, cache) = ffns[ge].forward_cached(rows);
                    Ok((out, Some(cache)))
                } else {
                    Ok((ffns[ge].forward(rows), None))
                }
            }
        }
    }
}

/// `(global expert, element offset, rows)` of each non-empty local
/// batch in rank `r`'s expert-major receive buffer. Shared with the
/// backward pass, whose gradient buffers have the identical layout —
/// one scan, two consumers.
pub(crate) fn rank_expert_jobs(
    placement: &ExpertPlacement,
    kept: &[Vec<usize>],
    r: usize,
    d: usize,
) -> Vec<(usize, usize, usize)> {
    let hosted = placement.hosted_experts(r);
    let mut jobs = Vec::with_capacity(hosted.len());
    let mut off = 0usize;
    for ge in hosted {
        let n: usize = kept.iter().map(|row| row[ge]).sum();
        if n > 0 {
            jobs.push((ge, off, n));
        }
        off += n * d;
    }
    jobs
}

/// Forward activations saved by the cached flavor for the backward
/// pass (the training layer's `TrainCache`).
pub struct ForwardCache {
    /// Per-rank gate scores `[T, E]`.
    pub scores: Vec<Tensor>,
    pub routings: Vec<Routing>,
    pub plans: Vec<DispatchPlan>,
    /// Per-(rank, expert) kept counts — the exchange's traffic source.
    pub kept: Vec<Vec<usize>>,
    /// Per-expert FFN caches over the received batch (None if 0 rows).
    pub expert_caches: Vec<Option<FfnCache>>,
    /// Per-rank post-combine buffers in source layout — the expert
    /// outputs each slot's combine-weight gradient dots against.
    pub expert_out: Vec<Vec<f32>>,
    /// Schedule the forward exchanges ran; the backward exchanges reuse
    /// it (same traffic matrix, same decision).
    pub schedule: Schedule,
}

/// Everything one pipeline run produces.
pub struct StepOutput {
    pub outputs: Vec<Tensor>,
    pub report: StepReport,
    /// Present iff the run was the forward + cache flavor.
    pub cache: Option<ForwardCache>,
}

/// The unified staged step pipeline (see module docs).
pub struct StepExecutor<'a> {
    pub cfg: &'a MoeConfig,
    pub cluster: &'a ClusterConfig,
    pub net: &'a NetworkModel,
    pub opts: &'a MoeLayerOptions,
    /// Router weight `[d, E]`.
    pub gate_weight: &'a Tensor,
    pub experts: ExpertBank<'a>,
    /// Routing kernel: scores `[T, E]` → routing. The caller binds the
    /// gate implementation and the training step here.
    pub route: &'a dyn Fn(&Tensor) -> Routing,
    /// Timing faults active this step (`None` = healthy). Injection is
    /// purely additive on the simulated clock — token data, routing and
    /// schedule decisions are never touched.
    pub faults: Option<&'a crate::fault::StepFaults>,
}

impl<'a> StepExecutor<'a> {
    fn placement(&self) -> ExpertPlacement {
        ExpertPlacement::resolve(
            self.cfg.num_experts,
            self.cluster.world(),
            self.opts.placement_table.as_deref(),
            &self.opts.dead_ranks,
        )
    }

    /// Run the pipeline over per-rank token shards `[T, d]` (all equal
    /// length). `collect_cache` selects the forward + cache flavor.
    pub fn run(&self, shards: &[Tensor], collect_cache: bool) -> Result<StepOutput> {
        let w = self.cluster.world();
        if shards.len() != w {
            return Err(crate::shape_err!("got {} shards for world {w}", shards.len()));
        }
        let d = self.cfg.d_model;
        // Dead ranks (elastic remap active) ship empty shards; every
        // alive shard must agree on the token count.
        let mut dead: Vec<usize> = self.opts.dead_ranks.clone();
        dead.retain(|&r| r < w);
        dead.sort_unstable();
        dead.dedup();
        let alive = (w - dead.len()).max(1);
        let local_tokens = shards.iter().map(Tensor::rows).max().unwrap_or(0);
        for (r, s) in shards.iter().enumerate() {
            if dead.binary_search(&r).is_ok() {
                if s.rows() != 0 {
                    return Err(crate::shape_err!(
                        "dead rank {r} must ship an empty shard, got {} rows",
                        s.rows()
                    ));
                }
            } else if s.rows() != local_tokens || s.row_len() != d {
                return Err(crate::shape_err!("ragged shards"));
            }
        }
        let cap = self.cfg.capacity(local_tokens);
        let mut report = StepReport::default();
        let mut expert_counts = vec![0usize; self.cfg.num_experts];
        let mut step_span = trace::span("step");

        // ---- StageGate: scores, routing, capacity plan per rank ----
        let g0 = Instant::now();
        let gate_span = trace::span("gate");
        let mut scores_all = Vec::with_capacity(w);
        let mut routings = Vec::with_capacity(w);
        let mut plans: Vec<DispatchPlan> = Vec::with_capacity(w);
        for shard in shards {
            if shard.rows() == 0 {
                // Dead rank: no tokens, no routing, nothing kept — the
                // empty plan keeps the per-rank vectors index-aligned.
                let routing = Routing {
                    k: 1,
                    tokens: 0,
                    num_experts: self.cfg.num_experts,
                    expert_ids: Vec::new(),
                    weights: Vec::new(),
                    aux_loss: 0.0,
                };
                plans.push(apply_capacity(&routing, cap.max(1)));
                scores_all.push(Tensor::zeros(&[0, self.cfg.num_experts]));
                routings.push(routing);
                continue;
            }
            let scores = matmul(shard, self.gate_weight);
            let routing = (self.route)(&scores);
            for (i, c) in routing.expert_counts().into_iter().enumerate() {
                expert_counts[i] += c;
            }
            report.aux_loss += routing.aux_loss as f64 / alive as f64;
            let plan = apply_capacity(&routing, cap);
            report.drop_rate += plan.drop_rate() / alive as f64;
            if self.opts.dispatch == DispatchMode::Padded {
                report.padding_waste += plan.padding_waste() / alive as f64;
            }
            scores_all.push(scores);
            routings.push(routing);
            plans.push(plan);
        }
        drop(gate_span);
        report.wall.push(("gate".into(), g0.elapsed().as_secs_f64() / w as f64));
        report.expert_counts = expert_counts;

        let kept: Vec<Vec<usize>> = plans.iter().map(|p| p.kept.clone()).collect();
        let (outputs, expert_caches, expert_out, schedule) = match self.opts.dispatch {
            DispatchMode::Ragged => {
                self.run_ragged(shards, &plans, &kept, collect_cache, &mut report)?
            }
            DispatchMode::Padded => {
                self.run_padded(shards, &plans, collect_cache, &mut report)?
            }
        };
        report.wire = self.opts.wire.name().into();
        step_span.arg("comm_schedule", report.comm_schedule.as_str());
        step_span.arg("wire", self.opts.wire.name());
        step_span.arg("n_chunks", report.n_chunks);
        step_span.arg("bytes_on_wire", report.bytes_on_wire);
        step_span.arg("bytes_intra_node", report.bytes_intra_node);
        step_span.arg("rows_deduped", report.rows_deduped);

        let cache = if collect_cache {
            Some(ForwardCache {
                scores: scores_all,
                routings,
                plans,
                kept,
                expert_caches,
                expert_out,
                schedule,
            })
        } else {
            None
        };
        Ok(StepOutput { outputs, report, cache })
    }

    /// The padding-free pipeline with chunked comm/compute overlap.
    #[allow(clippy::type_complexity)]
    fn run_ragged(
        &self,
        shards: &[Tensor],
        plans: &[DispatchPlan],
        kept: &[Vec<usize>],
        collect_cache: bool,
        report: &mut StepReport,
    ) -> Result<(Vec<Tensor>, Vec<Option<FfnCache>>, Vec<Vec<f32>>, Schedule)> {
        let w = self.cluster.world();
        let d = self.cfg.d_model;
        let placement = self.placement();

        // ---- StageLayout: ragged (occupied rows only, no zero-fill) ----
        let l0 = Instant::now();
        let layout_span = trace::span("layout");
        let buffers: Vec<RaggedLayoutBuffer> = shards
            .iter()
            .zip(plans)
            .map(|(shard, plan)| ragged_layout(shard, plan, self.opts.threads))
            .collect();
        drop(layout_span);
        report.wall.push(("layout".into(), l0.elapsed().as_secs_f64() / w as f64));

        // ---- Schedule selection: the decision procedure shared with
        // the serving router, scoring the dedup-aware NIC bytes when
        // dedup is on (the router scores the identical summary) ----
        let counts = placement.traffic_matrix(kept);
        // Element size is the one knob the whole stack must agree on:
        // the data path quantizes at the send boundary, and every cost
        // model below (schedule pick, overlap chunker, byte accounting)
        // charges the identical per-row wire bytes.
        let wire = self.opts.wire;
        let row_bytes = d * wire.elem_bytes();
        let g = self.cluster.gpus_per_node;
        // A remapped placement breaks the contiguous expert blocks the
        // hierarchical four-phase data path and the top-k dedup fold are
        // built around — degraded mode runs the flat exchange with dedup
        // off until the world heals.
        let elastic = !placement.is_contiguous();
        let dedup: Option<DedupTraffic> = (self.opts.dedup && !elastic)
            .then(|| dedup_traffic(plans.iter(), &placement, self.cluster).with_wire(wire));
        let schedule = if elastic {
            Schedule::Flat
        } else {
            pick_schedule_dedup(
                self.net,
                &counts,
                row_bytes,
                self.opts.alltoall,
                dedup.as_ref(),
            )
            .schedule
        };

        // ---- StageDispatch: exact-count exchange. Under the
        // hierarchical schedule this *executes* the four-phase data
        // path (gather → leader aggregation/dedup → inter-node
        // AllToAllv → expansion/scatter), not just the timing charge;
        // final buffers are bit-identical to the flat exchange either
        // way. The permutation is applied once; timing is attributed
        // per chunk by the overlap model below, so chunked and
        // unchunked execution are bit-identical by construction. ----
        let mut flat: Vec<Vec<f32>> =
            buffers.into_iter().map(|b| b.data.into_vec()).collect();
        let mut rows_deduped = 0usize;
        let mut dispatch_span = trace::span("dispatch_data");
        dispatch_span.arg("schedule", schedule.name());
        let dispatch_wire: WireBytes = match schedule {
            Schedule::Flat => {
                ragged_dispatch_placed(
                    self.net, &mut flat, kept, d, schedule, &placement, wire,
                )?;
                split_wire_bytes(&counts, row_bytes, g)
            }
            Schedule::Hierarchical => {
                // Row metadata is only needed to describe dedup groups.
                let metas: Vec<RowMeta> = if self.opts.dedup {
                    plans.iter().map(|p| row_meta(p, &placement, g)).collect()
                } else {
                    Vec::new()
                };
                let dm = self
                    .opts
                    .dedup
                    .then(|| DedupMeta { rows: &metas, payloads: shards, scaled: false });
                let leg =
                    hier_ragged_dispatch(self.net, &mut flat, kept, d, dm.as_ref(), wire)?;
                rows_deduped += leg.rows_saved;
                leg.wire
            }
        };
        dispatch_span.arg("bytes_on_wire", dispatch_wire.inter);
        dispatch_span.arg("bytes_intra_node", dispatch_wire.intra);
        dispatch_span.arg("rows_deduped", rows_deduped);
        drop(dispatch_span);

        // ---- StageExpert: grouped per-expert batches, wall measured
        // per destination rank (the overlap model's compute profile) ----
        let mut expert_caches: Vec<Option<FfnCache>> = Vec::new();
        expert_caches.resize_with(self.cfg.num_experts, || None);
        let mut rank_wall = vec![0.0f64; w];
        let expert_span = trace::span("expert");
        for (r, buf) in flat.iter_mut().enumerate() {
            let jobs = rank_expert_jobs(&placement, kept, r, d);
            let x0 = Instant::now();
            let results = self.run_expert_jobs(&jobs, &buf[..], collect_cache)?;
            for ((ge, off, n), (out, fcache)) in jobs.into_iter().zip(results) {
                report.expert_flops += self.experts.flops(ge, n);
                buf[off..off + n * d].copy_from_slice(out.data());
                if let Some(c) = fcache {
                    expert_caches[ge] = Some(c);
                }
            }
            rank_wall[r] = x0.elapsed().as_secs_f64();
        }
        drop(expert_span);
        report.wall.push(("expert".into(), rank_wall.iter().sum::<f64>() / w as f64));

        // ---- Overlap model (the StagePlan's chunk half): chunk count
        // from the same traffic matrix, per-rank compute in the
        // report's per-rank-mean convention ----
        let compute_per_rank: Vec<f64> =
            rank_wall.iter().map(|t| t / w as f64).collect();
        let (stage_plan, overlap) = StagePlan::for_schedule(
            self.net,
            &counts,
            row_bytes,
            schedule,
            self.opts.chunks,
            &compute_per_rank,
            dedup.as_ref(),
            false,
        );
        report.comm_schedule = stage_plan.schedule.name().into();
        report.comm.push(("alltoall_dispatch".into(), overlap.dispatch_total()));

        // ---- StageCombine: exact inverse exchange + reverse layout.
        // The forward return carries distinct per-slot expert outputs
        // (the combine-weight gradient needs them token-side), so it is
        // never pre-summed — full rows on either schedule. ----
        let combine_span = trace::span("combine_data");
        let combine_wire: WireBytes = match schedule {
            Schedule::Flat => {
                ragged_combine_placed(
                    self.net, &mut flat, kept, d, schedule, &placement, wire,
                )?;
                split_wire_bytes(&transpose_counts(&counts), row_bytes, g)
            }
            Schedule::Hierarchical => {
                hier_ragged_combine(self.net, &mut flat, kept, d, None, wire)?.wire
            }
        };
        drop(combine_span);
        report.comm.push(("alltoall_combine".into(), overlap.combine_total()));
        report.bytes_on_wire = dispatch_wire.inter + combine_wire.inter;
        report.bytes_intra_node = dispatch_wire.intra + combine_wire.intra;
        report.rows_deduped = rows_deduped;
        report.apply_overlap(&overlap);
        if let Some(faults) = self.faults {
            crate::fault::apply_to_report(report, faults, self.net, &rank_wall);
        }
        if trace::enabled() {
            let at = trace::model_window(overlap.critical_path);
            trace::model_overlap(
                at,
                "",
                &overlap,
                vec![
                    ("schedule".into(), schedule.name().into()),
                    ("bytes_on_wire".into(), report.bytes_on_wire.into()),
                    ("bytes_intra_node".into(), report.bytes_intra_node.into()),
                    ("rows_deduped".into(), rows_deduped.into()),
                ],
            );
        }

        let r0 = Instant::now();
        let reverse_span = trace::span("reverse_layout");
        let mut outputs = Vec::with_capacity(w);
        let mut expert_out: Vec<Vec<f32>> = Vec::new();
        for (rank, plan) in plans.iter().enumerate() {
            let buffer =
                RaggedLayoutBuffer::from_plan(std::mem::take(&mut flat[rank]), plan, d)?;
            outputs.push(ragged_reverse_layout(&buffer, plan, self.opts.threads));
            if collect_cache {
                expert_out.push(buffer.data.into_vec());
            }
        }
        drop(reverse_span);
        report
            .wall
            .push(("reverse_layout".into(), r0.elapsed().as_secs_f64() / w as f64));
        Ok((outputs, expert_caches, expert_out, schedule))
    }

    /// The classic dense pipeline: padded `[E, cap, d]` buffers through
    /// equal-chunk AllToAlls (fixed schedule, never chunked — the
    /// comparison baseline the Fig-8 systems model).
    #[allow(clippy::type_complexity)]
    fn run_padded(
        &self,
        shards: &[Tensor],
        plans: &[DispatchPlan],
        collect_cache: bool,
        report: &mut StepReport,
    ) -> Result<(Vec<Tensor>, Vec<Option<FfnCache>>, Vec<Vec<f32>>, Schedule)> {
        if self.opts.wire.is_compressed() {
            // The padded baseline keeps its classic f32 buffers; wire
            // compression is a property of the ragged exchange.
            return Err(crate::config_err!(
                "wire precision {} requires the ragged dispatch path",
                self.opts.wire.name()
            ));
        }
        let w = self.cluster.world();
        let d = self.cfg.d_model;
        let e = self.cfg.num_experts;
        let placement = self.placement();
        let epr = placement.experts_per_rank();
        let cap = plans[0].capacity;

        // ---- StageLayout: padded, through the configured transform ----
        let l0 = Instant::now();
        let layout_span = trace::span("layout");
        let buffers: Vec<LayoutBuffer> = shards
            .iter()
            .zip(plans)
            .map(|(shard, plan)| match self.opts.layout_impl {
                LayoutImpl::Optimized => opt_layout(shard, plan, self.opts.threads),
                LayoutImpl::Naive => naive_layout(shard, plan),
                LayoutImpl::DenseEinsum => dense_einsum_layout(shard, plan),
            })
            .collect();
        drop(layout_span);
        report.wall.push(("layout".into(), l0.elapsed().as_secs_f64() / w as f64));

        // ---- StageDispatch: equal-chunk AllToAll ----
        let mut flat: Vec<Vec<f32>> =
            buffers.into_iter().map(|b| b.data.into_vec()).collect();
        let dispatch_span = trace::span("dispatch_data");
        let timing = self.run_alltoall(&mut flat)?;
        drop(dispatch_span);
        report.comm.push(("alltoall_dispatch".into(), timing.total));
        let schedule = match self.opts.comm_impl {
            CommImpl::Flat => Schedule::Flat,
            CommImpl::Hierarchical => Schedule::Hierarchical,
        };
        report.comm_schedule = schedule.name().into();

        // ---- StageExpert: capacity slices per local expert ----
        // After AllToAll, rank r's buffer is [W, epr, cap, d]; gather
        // each local expert's rows source-major (same order as the
        // ragged receive layout, padding rows interleaved — the zero
        // rows drop out of every gradient sum, which is what keeps the
        // two backward paths bit-identical).
        let mut expert_caches: Vec<Option<FfnCache>> = Vec::new();
        expert_caches.resize_with(e, || None);
        let x0 = Instant::now();
        let expert_span = trace::span("expert");
        for (r, buf) in flat.iter_mut().enumerate() {
            if epr == 1 {
                // One expert per rank: the received buffer already is
                // that expert's contiguous batch — run it in place, no
                // gather/scatter copies.
                let rows = Tensor::from_vec(std::mem::take(buf), &[w * cap, d])?;
                let (out, fcache) = self.experts.run_serial(r, &rows, collect_cache)?;
                report.expert_flops += self.experts.flops(r, w * cap);
                *buf = out.into_vec();
                expert_caches[r] = fcache;
                continue;
            }
            // One scratch per rank, reused across its local experts.
            let mut rows = Tensor::zeros(&[w * cap, d]);
            for le in 0..epr {
                let ge = placement.expert_of(r, le);
                gather_expert_slices(buf, &mut rows, w, epr, le, cap);
                let (out, fcache) = self.experts.run_serial(ge, &rows, collect_cache)?;
                report.expert_flops += self.experts.flops(ge, w * cap);
                scatter_expert_slices(buf, out.data(), w, epr, le, cap, d);
                expert_caches[ge] = fcache;
            }
        }
        drop(expert_span);
        let expert_wall = x0.elapsed().as_secs_f64() / w as f64;
        report.wall.push(("expert".into(), expert_wall));

        // ---- StageCombine: reverse AllToAll + reverse layout ----
        let combine_span = trace::span("combine_data");
        let timing2 = self.run_alltoall(&mut flat)?;
        drop(combine_span);
        report.comm.push(("alltoall_combine".into(), timing2.total));
        // Every off-diagonal (src, dst) pair ships one [epr, cap, d]
        // chunk per leg, padding included — split placement-aware:
        // only cross-node pairs touch a NIC, same-node cross-rank
        // pairs ride the node fabric.
        let (nodes, g) = (self.cluster.nodes, self.cluster.gpus_per_node);
        let chunk_bytes = epr * cap * d * F32_BYTES;
        let inter_pairs = w * w - nodes * g * g;
        let intra_pairs = nodes * g * g.saturating_sub(1);
        report.bytes_on_wire = 2 * inter_pairs * chunk_bytes;
        report.bytes_intra_node = 2 * intra_pairs * chunk_bytes;
        // The equal-chunk exchange is never chunked: one-chunk overlap
        // model, whole round trip exposed on the critical path.
        let overlap = OverlapTiming {
            dispatch: vec![timing.total],
            compute: vec![expert_wall],
            combine: vec![timing2.total],
            critical_path: timing.total + expert_wall + timing2.total,
        };
        report.apply_overlap(&overlap);
        if let Some(faults) = self.faults {
            // The padded expert stage measures one aggregate wall; charge
            // stragglers against the uniform per-rank approximation.
            crate::fault::apply_to_report(report, faults, self.net, &vec![expert_wall; w]);
        }
        if trace::enabled() {
            let at = trace::model_window(overlap.critical_path);
            trace::model_overlap(
                at,
                "",
                &overlap,
                vec![
                    ("schedule".into(), schedule.name().into()),
                    ("bytes_on_wire".into(), report.bytes_on_wire.into()),
                    ("bytes_intra_node".into(), report.bytes_intra_node.into()),
                ],
            );
        }

        let r0 = Instant::now();
        let reverse_span = trace::span("reverse_layout");
        let mut outputs = Vec::with_capacity(w);
        let mut expert_out: Vec<Vec<f32>> = Vec::new();
        for (rank, plan) in plans.iter().enumerate() {
            let buffer = LayoutBuffer {
                data: Tensor::from_vec(std::mem::take(&mut flat[rank]), &[e * cap, d])?,
                capacity: cap,
                num_experts: e,
            };
            outputs.push(reverse_layout(&buffer, plan, self.opts.threads));
            if collect_cache {
                expert_out.push(buffer.data.into_vec());
            }
        }
        drop(reverse_span);
        report
            .wall
            .push(("reverse_layout".into(), r0.elapsed().as_secs_f64() / w as f64));
        Ok((outputs, expert_caches, expert_out, schedule))
    }

    /// Run one rank's per-expert FFN batches: `jobs` are disjoint
    /// `(global expert, element offset, rows)` regions of `buf`. Runs
    /// on the shared pool when the bank exposes concrete FFNs and
    /// `opts.threads > 1`; serial otherwise. Outputs are bit-identical
    /// either way — each batch is an independent pure function.
    fn run_expert_jobs(
        &self,
        jobs: &[(usize, usize, usize)],
        buf: &[f32],
        want_cache: bool,
    ) -> Result<Vec<(Tensor, Option<FfnCache>)>> {
        let d = self.cfg.d_model;
        if let Some(ffns) = self.experts.ffns() {
            return Ok(threadpool::pooled(self.opts.threads, jobs.len(), |j| {
                let (ge, off, n) = jobs[j];
                let mut job_span = trace::span("expert_job");
                job_span.arg("expert", ge);
                job_span.arg("rows", n);
                let rows = Tensor::from_vec(buf[off..off + n * d].to_vec(), &[n, d])
                    .expect("job region sized by kept counts");
                if want_cache {
                    let (out, cache) = ffns[ge].forward_cached(&rows);
                    (out, Some(cache))
                } else {
                    (ffns[ge].forward(&rows), None)
                }
            }));
        }
        // Opaque executors (e.g. artifact-backed): serial trait-object path.
        let mut out = Vec::with_capacity(jobs.len());
        for &(ge, off, n) in jobs {
            let rows = Tensor::from_vec(buf[off..off + n * d].to_vec(), &[n, d])?;
            out.push(self.experts.run_serial(ge, &rows, want_cache)?);
        }
        Ok(out)
    }

    fn run_alltoall(&self, flat: &mut [Vec<f32>]) -> Result<CommTiming> {
        match self.opts.comm_impl {
            CommImpl::Flat => alltoall(self.net, flat),
            CommImpl::Hierarchical => hierarchical_alltoall(self.net, flat),
        }
    }
}
