//! The unified staged step pipeline (and its chunked-overlap timing
//! model).
//!
//! HetuMoE's speedups come from treating the MoE step as *one* pipeline
//! whose phases can each be specialized; this module is where that
//! pipeline now lives, once, instead of being written out separately by
//! the inference layer, the training layer and the serving engine:
//!
//! - [`StepExecutor`] — the staged execution of Algorithm 1
//!   (`StageGate → StageLayout → StageDispatch → StageExpert →
//!   StageCombine`), in a forward-only and a forward + cache flavor.
//!   [`crate::moe::MoeLayer`] and [`crate::backprop::TrainMoeLayer`]
//!   both consume it; the serving engine consumes the same stage
//!   structure through the timing model.
//! - [`overlap`] — micro-chunked comm/compute overlap: each ragged
//!   exchange is split into chunks along the destination-rank axis so
//!   dispatch-of-chunk-*i* overlaps expert-FFN-of-chunk-*i − 1* (and
//!   symmetrically on combine and on the backward's transposed
//!   exchanges). The sum-of-phases wall is replaced by a critical-path
//!   model with a `comm_exposed` / `compute_exposed` breakdown and an
//!   `overlap_efficiency` metric (surfaced through
//!   [`crate::coordinator::metrics`]).
//! - [`StagePlan`] — the per-step exchange decision: the flat-vs-hier
//!   schedule (via the shared [`pick_schedule`] procedure, so training
//!   and serving still agree) *and* the chunk count, chosen together
//!   from the step's traffic matrix.
//!
//! Chunked and unchunked execution are bit-identical (same outputs,
//! same gradients) — property-tested in `tests/overlap_equivalence.rs`;
//! `benches/fig12_overlap.rs` measures exposed comm across chunk
//! counts, batch sizes and schedules.

pub mod executor;
pub mod overlap;

pub use executor::{ExpertBank, ForwardCache, StepExecutor, StepOutput};
pub use overlap::{
    chunk_ranges, pipe_critical_path, plan_overlap, schedule_chunk_ranges, ChunkChoice,
    OverlapTiming,
};

use crate::cluster::NetworkModel;
use crate::comm::hier_ragged::DedupTraffic;
use crate::comm::schedule::{pick_schedule_dedup, CommChoice, Schedule};

/// One step's exchange plan: which AllToAll schedule runs and into how
/// many destination-rank chunks each leg is split.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct StagePlan {
    pub schedule: Schedule,
    pub n_chunks: usize,
}

impl StagePlan {
    /// The chunk half of the per-step decision, for callers that
    /// already hold the schedule (the executor picks it once via
    /// [`pick_schedule`]; the serving engine gets it from the router's
    /// identical decision): the chunk count minimizing the modeled
    /// critical path under that schedule, from the step's traffic
    /// matrix and compute profile. Returns the plan plus the winning
    /// [`OverlapTiming`].
    /// `dedup` is the step's node-level traffic summary (None = dedup
    /// off): hierarchical dispatch legs are charged the deduplicated
    /// NIC bytes, and `presum_combine` additionally charges the combine
    /// leg for pre-summed return blocks (the backward's transposed
    /// exchanges). The hierarchical schedule chunks along the
    /// destination-node axis (see [`schedule_chunk_ranges`]).
    #[allow(clippy::too_many_arguments)]
    pub fn for_schedule(
        net: &NetworkModel,
        counts: &[Vec<usize>],
        elem_bytes: usize,
        schedule: Schedule,
        chunks: ChunkChoice,
        compute_per_rank: &[f64],
        dedup: Option<&DedupTraffic>,
        presum_combine: bool,
    ) -> (StagePlan, OverlapTiming) {
        let overlap = plan_overlap(
            net,
            counts,
            elem_bytes,
            schedule,
            compute_per_rank,
            chunks,
            dedup,
            presum_combine,
        );
        (StagePlan { schedule, n_chunks: overlap.n_chunks() }, overlap)
    }

    /// The joint per-step decision in one call: flat-vs-hier via the
    /// shared [`pick_schedule_dedup`] round-trip comparison (identical
    /// to the serving router's — chunking preserves total traffic, so
    /// the schedule ranking is decided on the unchunked round trip),
    /// then [`Self::for_schedule`] for the chunk count.
    #[allow(clippy::too_many_arguments)]
    pub fn pick(
        net: &NetworkModel,
        counts: &[Vec<usize>],
        elem_bytes: usize,
        choice: CommChoice,
        chunks: ChunkChoice,
        compute_per_rank: &[f64],
        dedup: Option<&DedupTraffic>,
        presum_combine: bool,
    ) -> (StagePlan, OverlapTiming) {
        let pick = pick_schedule_dedup(net, counts, elem_bytes, choice, dedup);
        StagePlan::for_schedule(
            net,
            counts,
            elem_bytes,
            pick.schedule,
            chunks,
            compute_per_rank,
            dedup,
            presum_combine,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    #[test]
    fn stage_plan_pick_is_consistent_with_its_parts() {
        let mut cfg = ClusterConfig::commodity(2);
        cfg.gpus_per_node = 2;
        let net = NetworkModel::new(cfg);
        let counts: Vec<Vec<usize>> =
            (0..4).map(|s| (0..4).map(|d| 4 + s + d).collect()).collect();
        let compute = vec![0.05f64; 4];
        let (plan, overlap) = StagePlan::pick(
            &net,
            &counts,
            64,
            CommChoice::Auto,
            ChunkChoice::Auto,
            &compute,
            None,
            false,
        );
        // Same schedule as the bare shared decision.
        let bare = crate::comm::schedule::pick_schedule(&net, &counts, 64, CommChoice::Auto);
        assert_eq!(plan.schedule, bare.schedule);
        assert_eq!(plan.n_chunks, overlap.n_chunks());
        assert!(plan.n_chunks >= 1 && plan.n_chunks <= 4);
        // Forced schedules pass through.
        let (flat, _) = StagePlan::pick(
            &net,
            &counts,
            64,
            CommChoice::Flat,
            ChunkChoice::Fixed(2),
            &compute,
            None,
            false,
        );
        assert_eq!(flat.schedule, Schedule::Flat);
        assert_eq!(flat.n_chunks, 2);
    }
}
