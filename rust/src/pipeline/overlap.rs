//! Micro-chunked comm/compute overlap: the critical-path timing model.
//!
//! The ragged exchanges are split into `n` chunks along the
//! **destination-rank axis**: chunk `c` carries every row whose
//! destination rank falls in a contiguous group of ranks. Because the
//! receive layout is expert-major per destination rank, a destination
//! group's expert batches are complete as soon as *its* chunk lands —
//! so expert FFNs of chunk `c − 1` can run while chunk `c` is still on
//! the wire, and symmetrically each group's combine leg can return
//! while later groups are still computing (the MegaScale-MoE-style
//! overlap on top of an X-MoE-style padding-free substrate).
//!
//! The model treats the network as one serialized resource (it executes
//! `dispatch[0..n]` then `combine[0..n]` in order) and the expert
//! compute as another (chunks compute back-to-back):
//!
//! - dispatch chunk `c` starts when the network is free;
//! - compute chunk `c` starts when its dispatch landed **and** the
//!   previous compute chunk finished;
//! - combine chunk `c` starts when its compute finished **and** the
//!   network is free.
//!
//! With `n = 1` the critical path reduces exactly to
//! `dispatch + compute + combine` — the old sum-of-phases wall — and
//! for any `n` it is bounded by that sum (overlap can only hide time),
//! while the per-chunk comm times sum to *at least* the unchunked time
//! (splitting a collective loses cross-rank pipelining inside the
//! collective — chunking is only a win when compute hides the loss).
//! [`plan_overlap`] evaluates candidate chunk counts against the step's
//! own traffic matrix and compute profile and keeps the best.

use crate::cluster::NetworkModel;
use crate::comm::alltoall::alltoallv_timing;
use crate::comm::hier_ragged::DedupTraffic;
use crate::comm::hierarchical::hierarchical_alltoallv_timing_with;
use crate::comm::schedule::{transpose_counts, Schedule};
use crate::error::Result;
use std::ops::Range;

/// How many chunks the ragged exchanges are split into.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ChunkChoice {
    /// Evaluate candidate counts on the step's traffic matrix and
    /// compute profile; keep the one minimizing the modeled critical
    /// path (never worse than unchunked — `1` is always a candidate).
    Auto,
    /// Force a chunk count (clamped to `[1, world]`).
    Fixed(usize),
}

impl ChunkChoice {
    pub fn parse(s: &str) -> Result<ChunkChoice> {
        let t = s.to_lowercase();
        if t == "auto" {
            return Ok(ChunkChoice::Auto);
        }
        match t.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(ChunkChoice::Fixed(n)),
            _ => Err(crate::config_err!(
                "chunk choice expects 'auto' or a positive integer, got '{s}'"
            )),
        }
    }

    pub fn name(&self) -> String {
        match self {
            ChunkChoice::Auto => "auto".into(),
            ChunkChoice::Fixed(n) => n.to_string(),
        }
    }
}

/// Contiguous destination-rank groups for `n` chunks over `w` ranks
/// (the effective chunk count is `out.len() ≤ n`).
pub fn chunk_ranges(w: usize, n: usize) -> Vec<Range<usize>> {
    let n = n.clamp(1, w.max(1));
    let per = w.div_ceil(n);
    let mut out = Vec::new();
    let mut lo = 0usize;
    while lo < w {
        let hi = (lo + per).min(w);
        out.push(lo..hi);
        lo = hi;
    }
    out
}

/// Destination groups for `n` chunks under a given schedule: the flat
/// schedule chunks along the destination-**rank** axis; the
/// hierarchical schedule chunks along the destination-**node** axis
/// (ranges are node-aligned), so the leaders' aggregated inter-node
/// messages stay whole and a dedup group — one token's replicas on one
/// node — never straddles two chunks.
pub fn schedule_chunk_ranges(
    w: usize,
    gpus_per_node: usize,
    schedule: Schedule,
    n: usize,
) -> Vec<Range<usize>> {
    match schedule {
        Schedule::Flat => chunk_ranges(w, n),
        Schedule::Hierarchical => {
            let g = gpus_per_node.max(1);
            chunk_ranges(w / g, n)
                .into_iter()
                .map(|r| r.start * g..r.end * g)
                .collect()
        }
    }
}

/// Per-chunk timings of both exchange legs. Dispatch chunk `c` carries
/// the columns (destination ranks) of `counts` inside `ranges[c]`; its
/// combine leg is the transpose — those ranks' rows on the way back.
///
/// With a [`DedupTraffic`] and the hierarchical schedule, each chunk's
/// dispatch leg is charged the deduplicated NIC bytes of its
/// destination nodes (ranges must be node-aligned — non-aligned ranges
/// fall back to raw costing, since a split node would break the dedup
/// groups); `presum_combine` additionally charges the combine leg for
/// the pre-summed return blocks (the backward's transposed exchanges).
pub fn chunk_comm_times(
    net: &NetworkModel,
    counts: &[Vec<usize>],
    elem_bytes: usize,
    schedule: Schedule,
    ranges: &[Range<usize>],
    dedup: Option<&DedupTraffic>,
    presum_combine: bool,
) -> (Vec<f64>, Vec<f64>) {
    let g = net.cfg.gpus_per_node.max(1);
    let mut dispatch = Vec::with_capacity(ranges.len());
    let mut combine = Vec::with_capacity(ranges.len());
    for range in ranges {
        let masked: Vec<Vec<usize>> = counts
            .iter()
            .map(|row| {
                row.iter()
                    .enumerate()
                    .map(|(dst, &c)| if range.contains(&dst) { c } else { 0 })
                    .collect()
            })
            .collect();
        let masked_t = transpose_counts(&masked);
        match schedule {
            Schedule::Flat => {
                dispatch.push(alltoallv_timing(net, &masked, elem_bytes).total);
                combine.push(alltoallv_timing(net, &masked_t, elem_bytes).total);
            }
            Schedule::Hierarchical => {
                let aligned = range.start % g == 0 && range.end % g == 0;
                let masked_dedup = match dedup {
                    Some(t) if aligned => {
                        Some(t.mask_dst_nodes(range.start / g, range.end / g))
                    }
                    _ => None,
                };
                let d_inter =
                    masked_dedup.as_ref().map(|t| t.dispatch_inter_bytes(elem_bytes));
                dispatch.push(
                    hierarchical_alltoallv_timing_with(
                        net,
                        &masked,
                        elem_bytes,
                        d_inter.as_deref(),
                    )
                    .total,
                );
                let c_inter = if presum_combine {
                    masked_dedup.as_ref().map(|t| t.presum_inter_bytes_t(elem_bytes))
                } else {
                    None
                };
                combine.push(
                    hierarchical_alltoallv_timing_with(
                        net,
                        &masked_t,
                        elem_bytes,
                        c_inter.as_deref(),
                    )
                    .total,
                );
            }
        }
    }
    (dispatch, combine)
}

/// Critical path of the chunked `dispatch → expert → combine` region
/// (see module docs for the resource model).
pub fn pipe_critical_path(dispatch: &[f64], compute: &[f64], combine: &[f64]) -> f64 {
    let n = dispatch.len();
    debug_assert!(compute.len() == n && combine.len() == n);
    let mut net_free = 0.0f64;
    let mut d_done = Vec::with_capacity(n);
    for &d in dispatch {
        net_free += d;
        d_done.push(net_free);
    }
    let mut e_prev = 0.0f64;
    let mut e_done = Vec::with_capacity(n);
    for (c, &e) in compute.iter().enumerate() {
        let start = if d_done[c] > e_prev { d_done[c] } else { e_prev };
        e_prev = start + e;
        e_done.push(e_prev);
    }
    for (c, &cb) in combine.iter().enumerate() {
        if e_done[c] > net_free {
            net_free = e_done[c];
        }
        net_free += cb;
    }
    net_free
}

/// One modeled execution of the overlapped region: per-chunk leg times,
/// per-chunk expert compute, and the resulting critical path.
#[derive(Clone, Debug, Default)]
pub struct OverlapTiming {
    pub dispatch: Vec<f64>,
    pub compute: Vec<f64>,
    pub combine: Vec<f64>,
    /// Modeled wall of the whole `dispatch → expert → combine` region.
    pub critical_path: f64,
}

impl OverlapTiming {
    pub fn n_chunks(&self) -> usize {
        self.dispatch.len()
    }

    pub fn dispatch_total(&self) -> f64 {
        self.dispatch.iter().sum()
    }

    pub fn combine_total(&self) -> f64 {
        self.combine.iter().sum()
    }

    pub fn comm_total(&self) -> f64 {
        self.dispatch_total() + self.combine_total()
    }

    pub fn compute_total(&self) -> f64 {
        self.compute.iter().sum()
    }

    /// Exchange time left on the critical path (not hidden under
    /// expert compute). With one chunk nothing overlaps, so this is
    /// *exactly* the whole exchange.
    pub fn comm_exposed(&self) -> f64 {
        if self.n_chunks() <= 1 {
            return self.comm_total();
        }
        (self.critical_path - self.compute_total()).max(0.0)
    }

    /// Expert compute left on the critical path (not hidden under the
    /// exchanges).
    pub fn compute_exposed(&self) -> f64 {
        if self.n_chunks() <= 1 {
            return self.compute_total();
        }
        (self.critical_path - self.comm_total()).max(0.0)
    }

    /// Exchange time hidden under expert compute: the serial
    /// sum-of-phases of the region minus its critical path (exactly 0
    /// with one chunk — nothing overlaps).
    pub fn comm_hidden(&self) -> f64 {
        if self.n_chunks() <= 1 {
            return 0.0;
        }
        (self.comm_total() + self.compute_total() - self.critical_path).max(0.0)
    }

    /// Fraction of the exchange time hidden under expert compute.
    pub fn overlap_efficiency(&self) -> f64 {
        let total = self.comm_total();
        if total <= 0.0 {
            0.0
        } else {
            self.comm_hidden() / total
        }
    }

    /// Per-chunk start times `(dispatch, compute, combine)` under the
    /// exact resource model of [`pipe_critical_path`] — the schedule
    /// the tracing layer draws on the modeled timeline. The last
    /// combine chunk ends at `critical_path` by construction.
    pub fn chunk_timeline(&self) -> Vec<(f64, f64, f64)> {
        let n = self.dispatch.len();
        let mut out = Vec::with_capacity(n);
        let mut net_free = 0.0f64;
        let mut d_done = Vec::with_capacity(n);
        for &dt in &self.dispatch {
            out.push((net_free, 0.0, 0.0));
            net_free += dt;
            d_done.push(net_free);
        }
        let mut e_prev = 0.0f64;
        let mut e_done = Vec::with_capacity(n);
        for (c, &e) in self.compute.iter().enumerate() {
            let start = if d_done[c] > e_prev { d_done[c] } else { e_prev };
            out[c].1 = start;
            e_prev = start + e;
            e_done.push(e_prev);
        }
        for (c, &cb) in self.combine.iter().enumerate() {
            if e_done[c] > net_free {
                net_free = e_done[c];
            }
            out[c].2 = net_free;
            net_free += cb;
        }
        out
    }
}

/// Build the overlap model for one exchange round and pick the chunk
/// count per `choice`.
///
/// `compute_per_rank[r]` is the expert-compute wall attributed to
/// destination rank `r` *in the report's per-rank-mean convention* (the
/// values sum to the step's `expert` wall phase); a chunk's compute is
/// the sum over its ranks, so totals are conserved for every chunk
/// count and `n = 1` reproduces the unchunked phases exactly.
#[allow(clippy::too_many_arguments)]
pub fn plan_overlap(
    net: &NetworkModel,
    counts: &[Vec<usize>],
    elem_bytes: usize,
    schedule: Schedule,
    compute_per_rank: &[f64],
    choice: ChunkChoice,
    dedup: Option<&DedupTraffic>,
    presum_combine: bool,
) -> OverlapTiming {
    let w = counts.len();
    debug_assert_eq!(compute_per_rank.len(), w);
    let g = net.cfg.gpus_per_node.max(1);
    // Chunkable units: destination ranks (flat) or destination nodes
    // (hierarchical — the inter leg's aggregated messages stay whole).
    let units = match schedule {
        Schedule::Flat => w,
        Schedule::Hierarchical => w / g,
    };
    let build = |n: usize| -> OverlapTiming {
        let ranges = schedule_chunk_ranges(w, g, schedule, n);
        let (dispatch, combine) = chunk_comm_times(
            net,
            counts,
            elem_bytes,
            schedule,
            &ranges,
            dedup,
            presum_combine,
        );
        let compute: Vec<f64> = ranges
            .iter()
            .map(|r| compute_per_rank[r.start..r.end].iter().sum::<f64>())
            .collect();
        let critical_path = pipe_critical_path(&dispatch, &compute, &combine);
        OverlapTiming { dispatch, compute, combine, critical_path }
    };
    match choice {
        ChunkChoice::Fixed(n) => build(n),
        ChunkChoice::Auto => {
            // Candidates: powers of two up to the unit count, plus the
            // unit count itself (one destination rank/node per chunk).
            let mut best = build(1);
            let mut n = 2usize;
            while n <= units {
                let cand = build(n);
                if cand.critical_path < best.critical_path {
                    best = cand;
                }
                n *= 2;
            }
            if units > 1 && !units.is_power_of_two() {
                let cand = build(units);
                if cand.critical_path < best.critical_path {
                    best = cand;
                }
            }
            best
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::hierarchical::hierarchical_alltoallv_timing;
    use crate::config::ClusterConfig;

    fn net(nodes: usize, gpus: usize) -> NetworkModel {
        let mut cfg = ClusterConfig::commodity(nodes);
        cfg.gpus_per_node = gpus;
        NetworkModel::new(cfg)
    }

    fn skewed_counts(w: usize) -> Vec<Vec<usize>> {
        (0..w).map(|s| (0..w).map(|d| 8 + 3 * s + d).collect()).collect()
    }

    fn leg_time(
        net: &NetworkModel,
        counts: &[Vec<usize>],
        elem_bytes: usize,
        schedule: Schedule,
    ) -> f64 {
        match schedule {
            Schedule::Flat => alltoallv_timing(net, counts, elem_bytes).total,
            Schedule::Hierarchical => {
                hierarchical_alltoallv_timing(net, counts, elem_bytes).total
            }
        }
    }

    #[test]
    fn chunk_ranges_tile_the_world() {
        for w in 1..9usize {
            for n in 1..10usize {
                let ranges = chunk_ranges(w, n);
                assert!(ranges.len() <= n.min(w).max(1));
                assert_eq!(ranges[0].start, 0);
                assert_eq!(ranges.last().unwrap().end, w);
                for pair in ranges.windows(2) {
                    assert_eq!(pair[0].end, pair[1].start);
                }
            }
        }
    }

    #[test]
    fn single_chunk_matches_unchunked_legs() {
        let m = net(2, 2);
        let counts = skewed_counts(4);
        for schedule in [Schedule::Flat, Schedule::Hierarchical] {
            let ranges = schedule_chunk_ranges(4, 2, schedule, 1);
            let (d, c) = chunk_comm_times(&m, &counts, 8, schedule, &ranges, None, false);
            assert_eq!(d.len(), 1);
            assert!((d[0] - leg_time(&m, &counts, 8, schedule)).abs() < 1e-15);
            let t = transpose_counts(&counts);
            assert!((c[0] - leg_time(&m, &t, 8, schedule)).abs() < 1e-15);
        }
    }

    #[test]
    fn chunked_comm_sums_at_least_unchunked() {
        // Splitting a collective loses cross-rank pipelining inside the
        // collective: per-chunk sums can only grow.
        let m = net(2, 4);
        let counts = skewed_counts(8);
        for schedule in [Schedule::Flat, Schedule::Hierarchical] {
            let full = leg_time(&m, &counts, 16, schedule);
            for n in [2usize, 4, 8] {
                let ranges = schedule_chunk_ranges(8, 4, schedule, n);
                let (d, _) =
                    chunk_comm_times(&m, &counts, 16, schedule, &ranges, None, false);
                let sum: f64 = d.iter().sum();
                assert!(
                    sum >= full - 1e-12,
                    "{schedule:?} n={n}: chunk sum {sum} < unchunked {full}"
                );
            }
        }
    }

    #[test]
    fn hier_chunks_are_node_aligned() {
        // 3 nodes × 2 GPUs: hierarchical ranges must sit on node
        // boundaries so dedup groups and aggregated messages stay whole.
        for n in 1..7usize {
            let ranges = schedule_chunk_ranges(6, 2, Schedule::Hierarchical, n);
            assert!(ranges.len() <= 3.min(n.max(1)));
            assert_eq!(ranges[0].start, 0);
            assert_eq!(ranges.last().unwrap().end, 6);
            for r in &ranges {
                assert_eq!(r.start % 2, 0, "n={n}: chunk splits a node");
                assert_eq!(r.end % 2, 0, "n={n}: chunk splits a node");
            }
        }
        // Flat ranges are unchanged rank tiling.
        assert_eq!(schedule_chunk_ranges(6, 2, Schedule::Flat, 6).len(), 6);
    }

    #[test]
    fn dedup_lowers_chunked_hier_dispatch_times() {
        use crate::comm::hier_ragged::DedupTraffic;
        let m = net(2, 2);
        let counts = vec![vec![16usize; 4]; 4];
        // 64 rows per node pair, half of them dedup away.
        let t = DedupTraffic {
            gpus_per_node: 2,
            rows: vec![vec![64, 64], vec![64, 64]],
            payloads: vec![vec![32, 32], vec![32, 32]],
            heads: vec![vec![40, 40], vec![40, 40]],
            packed_index: false,
        };
        let ranges = schedule_chunk_ranges(4, 2, Schedule::Hierarchical, 2);
        let (raw, raw_c) = chunk_comm_times(
            &m,
            &counts,
            256,
            Schedule::Hierarchical,
            &ranges,
            None,
            false,
        );
        let (ded, ded_c) = chunk_comm_times(
            &m,
            &counts,
            256,
            Schedule::Hierarchical,
            &ranges,
            Some(&t),
            false,
        );
        for (a, b) in raw.iter().zip(&ded) {
            assert!(b < a, "dedup must cut each chunk's dispatch leg: {b} vs {a}");
        }
        // Without presum the combine legs are identical.
        for (a, b) in raw_c.iter().zip(&ded_c) {
            assert!((a - b).abs() < 1e-15);
        }
        // With presum the combine legs shrink too.
        let (_, pre_c) = chunk_comm_times(
            &m,
            &counts,
            256,
            Schedule::Hierarchical,
            &ranges,
            Some(&t),
            true,
        );
        for (a, b) in raw_c.iter().zip(&pre_c) {
            assert!(b < a, "presum must cut each chunk's combine leg: {b} vs {a}");
        }
    }

    #[test]
    fn pipe_reduces_to_sum_of_phases_at_one_chunk() {
        let p = pipe_critical_path(&[0.3], &[0.5], &[0.2]);
        assert!((p - 1.0).abs() < 1e-15);
    }

    #[test]
    fn pipe_never_exceeds_sum_and_never_undershoots_busy_resources() {
        let d = [0.1, 0.2, 0.15, 0.05];
        let e = [0.3, 0.1, 0.25, 0.2];
        let c = [0.05, 0.1, 0.2, 0.1];
        let p = pipe_critical_path(&d, &e, &c);
        let sum: f64 =
            d.iter().sum::<f64>() + e.iter().sum::<f64>() + c.iter().sum::<f64>();
        let comm: f64 = d.iter().sum::<f64>() + c.iter().sum::<f64>();
        let compute: f64 = e.iter().sum();
        assert!(p <= sum + 1e-12);
        assert!(p >= comm - 1e-12, "network busy time bounds the wall");
        assert!(p >= compute - 1e-12, "compute busy time bounds the wall");
    }

    #[test]
    fn compute_dominated_steps_hide_comm() {
        // Expert compute far above comm: chunking must hide most of the
        // exchange time and Auto must prefer a chunked plan.
        let m = net(2, 2);
        let counts = skewed_counts(4);
        let compute = vec![0.25f64; 4]; // seconds per rank, >> comm
        let unchunked = plan_overlap(
            &m,
            &counts,
            256,
            Schedule::Flat,
            &compute,
            ChunkChoice::Fixed(1),
            None,
            false,
        );
        assert_eq!(unchunked.n_chunks(), 1);
        assert_eq!(unchunked.comm_hidden(), 0.0);
        assert!(
            (unchunked.comm_exposed() - unchunked.comm_total()).abs() < 1e-12,
            "one chunk exposes the whole exchange"
        );
        let auto = plan_overlap(
            &m,
            &counts,
            256,
            Schedule::Flat,
            &compute,
            ChunkChoice::Auto,
            None,
            false,
        );
        assert!(auto.n_chunks() > 1, "auto must chunk a compute-dominated step");
        assert!(auto.comm_hidden() > 0.0);
        assert!(auto.critical_path < unchunked.critical_path);
        assert!(auto.comm_exposed() < unchunked.comm_exposed());
        assert!(auto.overlap_efficiency() > 0.0 && auto.overlap_efficiency() <= 1.0);
    }

    #[test]
    fn auto_never_models_worse_than_unchunked() {
        let m = net(2, 4);
        let counts = skewed_counts(8);
        for compute_scale in [0.0f64, 1e-7, 1e-3] {
            let compute = vec![compute_scale; 8];
            for schedule in [Schedule::Flat, Schedule::Hierarchical] {
                let one = plan_overlap(
                    &m,
                    &counts,
                    64,
                    schedule,
                    &compute,
                    ChunkChoice::Fixed(1),
                    None,
                    false,
                );
                let auto = plan_overlap(
                    &m,
                    &counts,
                    64,
                    schedule,
                    &compute,
                    ChunkChoice::Auto,
                    None,
                    false,
                );
                assert!(auto.critical_path <= one.critical_path + 1e-15);
            }
        }
    }

    #[test]
    fn fixed_is_clamped_and_totals_conserved() {
        let m = net(1, 3);
        let counts = skewed_counts(3);
        let compute = vec![0.01f64, 0.02, 0.03];
        let o = plan_overlap(
            &m,
            &counts,
            32,
            Schedule::Flat,
            &compute,
            ChunkChoice::Fixed(99),
            None,
            false,
        );
        assert_eq!(o.n_chunks(), 3, "fixed counts clamp to the world size");
        assert!((o.compute_total() - 0.06).abs() < 1e-12, "compute is conserved");
    }

    #[test]
    fn chunk_timeline_is_consistent_with_critical_path() {
        let d = [0.1, 0.2, 0.15, 0.05];
        let e = [0.3, 0.1, 0.25, 0.2];
        let c = [0.05, 0.1, 0.2, 0.1];
        let o = OverlapTiming {
            dispatch: d.to_vec(),
            compute: e.to_vec(),
            combine: c.to_vec(),
            critical_path: pipe_critical_path(&d, &e, &c),
        };
        let tl = o.chunk_timeline();
        assert_eq!(tl.len(), 4);
        // Last combine chunk ends exactly at the critical path.
        let (_, _, last_cb) = tl[3];
        assert!((last_cb + c[3] - o.critical_path).abs() < 1e-12);
        for i in 0..4 {
            let (ds, es, cs) = tl[i];
            // Compute waits for its dispatch; combine waits for compute.
            assert!(es + 1e-15 >= ds + d[i]);
            assert!(cs + 1e-15 >= es + e[i]);
            if i > 0 {
                // The network is serialized: dispatch i starts at the
                // end of dispatch i − 1; combine i after combine i − 1.
                let (pds, _, pcs) = tl[i - 1];
                assert!((ds - (pds + d[i - 1])).abs() < 1e-12);
                assert!(cs + 1e-15 >= pcs + c[i - 1]);
            }
        }
        // One chunk reduces to the serial phases.
        let one = OverlapTiming {
            dispatch: vec![0.3],
            compute: vec![0.5],
            combine: vec![0.2],
            critical_path: 1.0,
        };
        assert_eq!(one.chunk_timeline(), vec![(0.0, 0.3, 0.8)]);
    }

    #[test]
    fn chunk_choice_parsing() {
        assert_eq!(ChunkChoice::parse("auto").unwrap(), ChunkChoice::Auto);
        assert_eq!(ChunkChoice::parse("AUTO").unwrap(), ChunkChoice::Auto);
        assert_eq!(ChunkChoice::parse("4").unwrap(), ChunkChoice::Fixed(4));
        assert!(ChunkChoice::parse("0").is_err());
        assert!(ChunkChoice::parse("-2").is_err());
        assert!(ChunkChoice::parse("lots").is_err());
        assert_eq!(ChunkChoice::Auto.name(), "auto");
        assert_eq!(ChunkChoice::Fixed(2).name(), "2");
    }
}
