//! Adaptive expert placement: swap + replicate hot experts from
//! observed traffic.
//!
//! The static formula `rank = e/(E/W)` assumes expert demand is flat.
//! Under the Zipf-skewed workloads this repo actually runs (the
//! `ClusterTask` trainer data, bursty serving traffic), a handful of
//! hot experts concentrate on one node and its NIC saturates while the
//! others idle. This module closes the loop the paper leaves open: it
//! ingests the per-expert kept-token counts already flowing through
//! every [`crate::moe::StepReport`] (a rolling [`TrafficWindow`]),
//! scores candidate expert **swaps** (training + serving) and
//! **replications** (serving only — training keeps single assignment
//! so gradients stay exact) against the same `alltoallv` cost models
//! the schedule pick uses, and emits a [`PlacementDelta`] when a
//! strictly better layout exists.
//!
//! **Objective.** The leading objective is the *per-leg directional
//! NIC peak*: on the dispatch leg, each node's NIC carries inbound
//! bytes (rows destined to its experts from off-node sources) and
//! outbound bytes (rows its sources ship off-node) on independent
//! full-duplex directions; the combine leg is the exact mirror. The
//! peak over (node, direction) bounds both legs' walls, and — unlike
//! total NIC bytes, which is placement-invariant under symmetric
//! sources — it strictly improves when co-located hot experts spread
//! across nodes or a dominant expert gains a second-node replica. The
//! secondary objective is the predicted round-trip time of the
//! schedule the layout would actually run ([`pick_schedule`]; a
//! non-contiguous table or an active replica degrades the exchange to
//! the flat schedule with dedup off, and candidates are scored under
//! that regime, never an imaginary one).
//!
//! **Determinism.** Proposals are pure functions of (window, current
//! placement, replicas, dead set, config): candidate enumeration is
//! ordered, f64 comparisons use `total_cmp`, and ties keep the
//! incumbent. Training and serving can both re-derive every decision.

use crate::cluster::{ExpertPlacement, NetworkModel};
use crate::comm::schedule::{pick_schedule, CommChoice};
use crate::comm::F32_BYTES;
use crate::error::Result;
use std::collections::VecDeque;

/// `--placement static|adaptive` (static is bit-identical to the
/// pre-adaptive pipeline).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum PlacementPolicy {
    #[default]
    Static,
    Adaptive,
}

impl PlacementPolicy {
    pub fn parse(s: &str) -> Result<PlacementPolicy> {
        Ok(match s.to_lowercase().as_str() {
            "static" => PlacementPolicy::Static,
            "adaptive" => PlacementPolicy::Adaptive,
            other => {
                return Err(crate::config_err!(
                    "unknown placement policy '{other}' (expected static|adaptive)"
                ));
            }
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::Static => "static",
            PlacementPolicy::Adaptive => "adaptive",
        }
    }

    pub fn is_adaptive(&self) -> bool {
        matches!(self, PlacementPolicy::Adaptive)
    }
}

/// Rolling window of observed per-expert kept-token counts (one entry
/// per step/batch, straight from `StepReport::expert_counts`).
#[derive(Clone, Debug)]
pub struct TrafficWindow {
    window: usize,
    steps: VecDeque<Vec<f64>>,
}

impl TrafficWindow {
    pub fn new(window: usize) -> TrafficWindow {
        TrafficWindow { window: window.max(1), steps: VecDeque::new() }
    }

    /// Fold one step's global per-expert kept counts into the window.
    pub fn observe(&mut self, expert_counts: &[usize]) {
        if self.steps.len() == self.window {
            self.steps.pop_front();
        }
        self.steps.push_back(expert_counts.iter().map(|&c| c as f64).collect());
    }

    pub fn len(&self) -> usize {
        self.steps.len()
    }

    pub fn is_empty(&self) -> bool {
        self.steps.is_empty()
    }

    /// Mean per-expert kept rows per step over the window (`None` when
    /// nothing was observed yet or the window saw zero traffic).
    pub fn mean_load(&self) -> Option<Vec<f64>> {
        let first = self.steps.front()?;
        let mut sum = vec![0.0f64; first.len()];
        for step in &self.steps {
            for (s, &c) in sum.iter_mut().zip(step) {
                *s += c;
            }
        }
        let n = self.steps.len() as f64;
        for s in sum.iter_mut() {
            *s /= n;
        }
        if sum.iter().sum::<f64>() <= 0.0 {
            return None;
        }
        Some(sum)
    }
}

/// Serving-side replica assignment: extra ranks hosting a *read-only
/// copy* of an expert on top of the placement's primary rank. Training
/// never replicates (single assignment keeps gradients exact), so this
/// map lives on the router only.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ReplicaMap {
    /// Per expert: extra host ranks (sorted, primary not included).
    ranks: Vec<Vec<usize>>,
}

impl ReplicaMap {
    pub fn new(num_experts: usize) -> ReplicaMap {
        ReplicaMap { ranks: vec![Vec::new(); num_experts] }
    }

    /// Add a replica of `expert` on `rank` (idempotent).
    pub fn add(&mut self, expert: usize, rank: usize) {
        let list = &mut self.ranks[expert];
        if let Err(pos) = list.binary_search(&rank) {
            list.insert(pos, rank);
        }
    }

    /// Drop every replica hosted on `rank` (a killed rank degrades each
    /// affected expert to its surviving copies — no recovery window).
    pub fn remove_rank(&mut self, rank: usize) {
        for list in self.ranks.iter_mut() {
            list.retain(|&r| r != rank);
        }
    }

    /// All ranks serving `expert`: the placement's primary plus live
    /// replicas, sorted and deduplicated. Never empty — the primary
    /// always survives (the elastic placement remaps it off dead
    /// ranks).
    pub fn copies(&self, expert: usize, placement: &ExpertPlacement) -> Vec<usize> {
        let mut out = self.ranks[expert].clone();
        let primary = placement.rank_of(expert);
        if let Err(pos) = out.binary_search(&primary) {
            out.insert(pos, primary);
        }
        out
    }

    /// Number of extra copies of `expert`.
    pub fn num_replicas(&self, expert: usize) -> usize {
        self.ranks[expert].len()
    }

    /// True when no expert has a replica.
    pub fn is_empty(&self) -> bool {
        self.ranks.iter().all(Vec::is_empty)
    }

    /// `(expert, rank)` pairs, expert-major — the checkpoint encoding.
    pub fn pairs(&self) -> Vec<(usize, usize)> {
        self.ranks
            .iter()
            .enumerate()
            .flat_map(|(e, list)| list.iter().map(move |&r| (e, r)))
            .collect()
    }

    pub fn from_pairs(num_experts: usize, pairs: &[(usize, usize)]) -> ReplicaMap {
        let mut map = ReplicaMap::new(num_experts);
        for &(e, r) in pairs {
            map.add(e, r);
        }
        map
    }
}

/// One expert migration.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExpertMove {
    pub expert: usize,
    pub from: usize,
    pub to: usize,
}

/// Scored cost of one candidate layout.
#[derive(Clone, Copy, Debug)]
pub struct PlacementCost {
    /// Per-leg directional NIC peak, bytes (see module docs).
    pub max_node_nic_bytes: f64,
    /// Predicted exchange round trip under the layout's actual regime.
    pub round_trip: f64,
}

/// The optimizer's output: migrations (training + serving) and new
/// replicas (serving only), with the before/after scores that justified
/// them.
#[derive(Clone, Debug)]
pub struct PlacementDelta {
    pub moves: Vec<ExpertMove>,
    /// `(expert, rank)` replicas to add (empty unless replication was
    /// allowed).
    pub replicate: Vec<(usize, usize)>,
    /// The resulting full expert→rank table.
    pub table: Vec<usize>,
    pub cost_before: PlacementCost,
    pub cost_after: PlacementCost,
}

/// Greedy hill-climbing placement optimizer (see module docs).
#[derive(Clone, Debug)]
pub struct PlacementOptimizer {
    /// Minimum relative improvement on the leading objective for a
    /// candidate to be accepted (guards against migration thrash on
    /// noise-level gains). The fig14 bench sets 0 to surface every
    /// strict win.
    pub min_gain: f64,
    /// Swap/replicate steps per proposal (migration volume cap).
    pub max_moves: usize,
    /// Consider replica candidates (serving only).
    pub allow_replicate: bool,
    /// Max extra copies per expert when replicating.
    pub max_replicas: usize,
}

impl Default for PlacementOptimizer {
    fn default() -> Self {
        PlacementOptimizer {
            min_gain: 0.01,
            max_moves: 4,
            allow_replicate: false,
            max_replicas: 1,
        }
    }
}

impl PlacementOptimizer {
    /// Score one candidate `(table, replicas)` layout against the
    /// observed per-expert load. Sources are the alive ranks,
    /// symmetric (every rank's shard draws from the same distribution);
    /// an expert's load splits evenly across its copies (the router's
    /// rotating spread).
    pub fn cost_of(
        net: &NetworkModel,
        load: &[f64],
        table: &[usize],
        replicas: Option<&ReplicaMap>,
        dead: &[usize],
        row_bytes: usize,
    ) -> PlacementCost {
        let w = net.cfg.world();
        let g = net.cfg.gpus_per_node;
        let nodes = net.cfg.nodes;
        let alive: Vec<bool> = (0..w).map(|r| !dead.contains(&r)).collect();
        let n_alive = alive.iter().filter(|&&a| a).count().max(1);
        let placement = ExpertPlacement::from_table(load.len(), w, table);
        let mut rank_load = vec![0.0f64; w];
        let mut replicated = false;
        for (e, &l) in load.iter().enumerate() {
            match replicas {
                Some(map) if map.num_replicas(e) > 0 => {
                    let copies = map.copies(e, &placement);
                    replicated = true;
                    let share = l / copies.len() as f64;
                    for &r in &copies {
                        rank_load[r] += share;
                    }
                }
                _ => rank_load[table[e]] += l,
            }
        }
        // Directional per-node NIC peak on the dispatch leg.
        let total: f64 = rank_load.iter().sum();
        let mut max_nic = 0.0f64;
        for n in 0..nodes {
            let node_ranks = n * g..(n + 1) * g;
            let node_load: f64 = node_ranks.clone().map(|r| rank_load[r]).sum();
            let srcs_in: usize = node_ranks.clone().filter(|&r| alive[r]).count();
            let srcs_out = n_alive - srcs_in;
            let inbound = node_load * srcs_out as f64 / n_alive as f64;
            let outbound = (total - node_load) * srcs_in as f64 / n_alive as f64;
            max_nic = max_nic.max(inbound.max(outbound));
        }
        max_nic *= row_bytes as f64;
        // Round trip under the layout's actual regime: a non-contiguous
        // table or an active replica runs the flat schedule with dedup
        // off, so score it there — never against a schedule it cannot
        // execute.
        let counts: Vec<Vec<usize>> = (0..w)
            .map(|src| {
                (0..w)
                    .map(|dst| {
                        if alive[src] {
                            (rank_load[dst] / n_alive as f64).round() as usize
                        } else {
                            0
                        }
                    })
                    .collect()
            })
            .collect();
        let pick = pick_schedule(net, &counts, row_bytes, CommChoice::Auto);
        let round_trip = if placement.is_contiguous() && !replicated {
            pick.flat_time.min(pick.hier_time)
        } else {
            pick.flat_time
        };
        PlacementCost { max_node_nic_bytes: max_nic, round_trip }
    }

    /// Does `cand` strictly beat `cur` under the lexicographic
    /// objective with the configured gain threshold?
    fn improves(&self, cand: &PlacementCost, cur: &PlacementCost) -> bool {
        if cand.max_node_nic_bytes < cur.max_node_nic_bytes * (1.0 - self.min_gain) {
            return true;
        }
        cand.max_node_nic_bytes <= cur.max_node_nic_bytes
            && cand.round_trip < cur.round_trip * (1.0 - self.min_gain)
    }

    /// Propose a placement delta from the observed window, or `None`
    /// when the incumbent layout is already (near-)optimal under the
    /// candidate moves considered. Pure function of its arguments.
    pub fn propose(
        &self,
        window: &TrafficWindow,
        current: &ExpertPlacement,
        replicas: &ReplicaMap,
        dead: &[usize],
        net: &NetworkModel,
        row_bytes: usize,
    ) -> Option<PlacementDelta> {
        let load = window.mean_load()?;
        if load.len() != current.num_experts {
            return None;
        }
        let w = current.world;
        let e = current.num_experts;
        let before_table = current.table_vec();
        let mut table = before_table.clone();
        let mut reps = replicas.clone();
        let mut new_reps: Vec<(usize, usize)> = Vec::new();
        let cost_before =
            Self::cost_of(net, &load, &table, Some(&reps), dead, row_bytes);
        let mut cur_cost = cost_before;
        // Hottest-first expert order drives both candidate loops.
        let mut by_load: Vec<usize> = (0..e).collect();
        by_load.sort_by(|&a, &b| load[b].total_cmp(&load[a]).then(a.cmp(&b)));
        for _ in 0..self.max_moves {
            let mut best: Option<(PlacementCost, Option<(usize, usize)>, Option<(usize, usize)>)> =
                None;
            // Swap candidates: hot expert × every expert on another rank.
            for &e1 in &by_load {
                for e2 in 0..e {
                    if table[e1] == table[e2] {
                        continue;
                    }
                    let mut cand = table.clone();
                    cand.swap(e1, e2);
                    let c = Self::cost_of(net, &load, &cand, Some(&reps), dead, row_bytes);
                    let beats_best = best
                        .as_ref()
                        .is_none_or(|(bc, _, _)| self.improves(&c, bc));
                    if self.improves(&c, &cur_cost) && beats_best {
                        best = Some((c, Some((e1, e2)), None));
                    }
                }
            }
            // Replica candidates (serving): hot expert × alive rank not
            // already a copy holder.
            if self.allow_replicate {
                for &he in &by_load {
                    if reps.num_replicas(he) >= self.max_replicas {
                        continue;
                    }
                    let placement = ExpertPlacement::from_table(e, w, &table);
                    let copies = reps.copies(he, &placement);
                    for r in 0..w {
                        if dead.contains(&r) || copies.contains(&r) {
                            continue;
                        }
                        let mut cand_reps = reps.clone();
                        cand_reps.add(he, r);
                        let c = Self::cost_of(
                            net,
                            &load,
                            &table,
                            Some(&cand_reps),
                            dead,
                            row_bytes,
                        );
                        let beats_best = best
                            .as_ref()
                            .is_none_or(|(bc, _, _)| self.improves(&c, bc));
                        if self.improves(&c, &cur_cost) && beats_best {
                            best = Some((c, None, Some((he, r))));
                        }
                    }
                }
            }
            match best {
                Some((c, Some((e1, e2)), None)) => {
                    table.swap(e1, e2);
                    cur_cost = c;
                }
                Some((c, None, Some((he, r)))) => {
                    reps.add(he, r);
                    new_reps.push((he, r));
                    cur_cost = c;
                }
                _ => break,
            }
        }
        let moves: Vec<ExpertMove> = (0..e)
            .filter(|&ex| table[ex] != before_table[ex])
            .map(|ex| ExpertMove { expert: ex, from: before_table[ex], to: table[ex] })
            .collect();
        if moves.is_empty() && new_reps.is_empty() {
            return None;
        }
        Some(PlacementDelta {
            moves,
            replicate: new_reps,
            table,
            cost_before,
            cost_after: cur_cost,
        })
    }
}

/// Bytes one expert migration moves: FFN params (`w1 [d,h]`, `b1 [h]`,
/// `w2 [h,d]`, `b2 [d]`) **plus both Adam moments** — three f32 copies
/// of every parameter cross the wire.
pub fn migration_bytes_per_expert(d_model: usize, ffn_hidden: usize) -> usize {
    let params = d_model * ffn_hidden + ffn_hidden + ffn_hidden * d_model + d_model;
    params * F32_BYTES * 3
}

/// Directional per-node NIC peak of an *actual* integer rank traffic
/// matrix (dispatch leg): max over (node, direction) of cross-node
/// rows × `row_bytes`. The bench-side ground truth the optimizer's
/// model is validated against.
pub fn max_node_nic_bytes(
    counts: &[Vec<usize>],
    gpus_per_node: usize,
    row_bytes: usize,
) -> usize {
    let w = counts.len();
    let nodes = w.div_ceil(gpus_per_node.max(1));
    let node_of = |r: usize| r / gpus_per_node.max(1);
    let mut peak = 0usize;
    for n in 0..nodes {
        let mut inbound = 0usize;
        let mut outbound = 0usize;
        for (src, row) in counts.iter().enumerate() {
            for (dst, &c) in row.iter().enumerate() {
                if node_of(src) == node_of(dst) {
                    continue;
                }
                if node_of(dst) == n {
                    inbound += c;
                }
                if node_of(src) == n {
                    outbound += c;
                }
            }
        }
        peak = peak.max(inbound.max(outbound));
    }
    peak * row_bytes
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::ClusterConfig;

    fn net(nodes: usize, gpus: usize) -> NetworkModel {
        NetworkModel::new(ClusterConfig {
            nodes,
            gpus_per_node: gpus,
            ..ClusterConfig::commodity(nodes)
        })
    }

    #[test]
    fn window_rolls_and_averages() {
        let mut w = TrafficWindow::new(2);
        assert!(w.mean_load().is_none());
        w.observe(&[4, 0]);
        w.observe(&[0, 4]);
        assert_eq!(w.mean_load().unwrap(), vec![2.0, 2.0]);
        w.observe(&[0, 8]); // evicts [4, 0]
        assert_eq!(w.len(), 2);
        assert_eq!(w.mean_load().unwrap(), vec![0.0, 6.0]);
        let mut z = TrafficWindow::new(3);
        z.observe(&[0, 0]);
        assert!(z.mean_load().is_none(), "zero traffic is not a signal");
    }

    #[test]
    fn optimizer_spreads_colocated_hot_experts() {
        // E=8 over 2x2: contiguous hosts hot experts {0, 1} both on
        // rank 0 (node 0). Spreading one of them across the node
        // boundary halves the directional NIC peak.
        let net = net(2, 2);
        let mut window = TrafficWindow::new(4);
        for _ in 0..4 {
            window.observe(&[100, 100, 1, 1, 1, 1, 1, 1]);
        }
        let current = ExpertPlacement::new(8, 4);
        let opt = PlacementOptimizer { min_gain: 0.0, ..Default::default() };
        let delta = opt
            .propose(&window, &current, &ReplicaMap::new(8), &[], &net, 64 * 4)
            .expect("skewed load must yield a delta");
        assert!(!delta.moves.is_empty());
        assert!(
            delta.cost_after.max_node_nic_bytes < delta.cost_before.max_node_nic_bytes,
            "NIC peak must strictly improve: {:?} -> {:?}",
            delta.cost_before,
            delta.cost_after
        );
        // The two hot experts end on different nodes.
        let node = |r: usize| r / 2;
        assert_ne!(node(delta.table[0]), node(delta.table[1]));
        // Every move is reflected in the table, table stays valid.
        assert!(ExpertPlacement::validate_table(8, 4, &delta.table).is_ok());
        for m in &delta.moves {
            assert_eq!(delta.table[m.expert], m.to);
            assert_ne!(m.from, m.to);
        }
        // Pure function: proposing again yields the identical delta.
        let again = opt
            .propose(&window, &current, &ReplicaMap::new(8), &[], &net, 64 * 4)
            .unwrap();
        assert_eq!(again.table, delta.table);
    }

    #[test]
    fn optimizer_is_quiet_on_uniform_load() {
        let net = net(2, 2);
        let mut window = TrafficWindow::new(4);
        for _ in 0..4 {
            window.observe(&[10; 8]);
        }
        let opt = PlacementOptimizer::default();
        let delta = opt.propose(
            &window,
            &ExpertPlacement::new(8, 4),
            &ReplicaMap::new(8),
            &[],
            &net,
            64 * 4,
        );
        assert!(delta.is_none(), "uniform load is already optimal: {delta:?}");
    }

    #[test]
    fn optimizer_replicates_a_dominant_expert() {
        // One expert carries all traffic: no single-assignment swap can
        // move the NIC peak (the hot node just changes identity), but a
        // second-node replica halves it.
        let net = net(2, 2);
        let mut window = TrafficWindow::new(2);
        window.observe(&[400, 1, 1, 1, 1, 1, 1, 1]);
        window.observe(&[400, 1, 1, 1, 1, 1, 1, 1]);
        let opt = PlacementOptimizer {
            min_gain: 0.05,
            allow_replicate: true,
            ..Default::default()
        };
        let delta = opt
            .propose(
                &window,
                &ExpertPlacement::new(8, 4),
                &ReplicaMap::new(8),
                &[],
                &net,
                64 * 4,
            )
            .expect("dominant expert must be replicated");
        assert!(
            delta.replicate.iter().any(|&(e, r)| e == 0 && r / 2 == 1),
            "expert 0 needs a node-1 replica: {:?}",
            delta.replicate
        );
        assert!(delta.cost_after.max_node_nic_bytes < delta.cost_before.max_node_nic_bytes);
    }

    #[test]
    fn optimizer_never_targets_dead_ranks() {
        let net = net(2, 2);
        let mut window = TrafficWindow::new(2);
        window.observe(&[400, 1, 1, 1, 1, 1, 1, 1]);
        let current = ExpertPlacement::with_dead(8, 4, &[2]);
        let opt = PlacementOptimizer {
            min_gain: 0.0,
            allow_replicate: true,
            max_moves: 8,
            ..Default::default()
        };
        if let Some(delta) =
            opt.propose(&window, &current, &ReplicaMap::new(8), &[2], &net, 64 * 4)
        {
            for m in &delta.moves {
                assert_ne!(m.to, 2, "migrated onto a dead rank");
            }
            for &(_, r) in &delta.replicate {
                assert_ne!(r, 2, "replicated onto a dead rank");
            }
        }
    }

    #[test]
    fn replica_map_round_trips_and_degrades() {
        let mut map = ReplicaMap::new(4);
        map.add(1, 3);
        map.add(1, 3); // idempotent
        map.add(2, 0);
        assert_eq!(map.pairs(), vec![(1, 3), (2, 0)]);
        assert_eq!(map, ReplicaMap::from_pairs(4, &map.pairs()));
        let p = ExpertPlacement::new(4, 4);
        assert_eq!(map.copies(1, &p), vec![1, 3]);
        map.remove_rank(3);
        assert_eq!(map.copies(1, &p), vec![1], "killed holder degrades to the primary");
        assert!(!map.is_empty());
        map.remove_rank(0);
        assert!(map.is_empty());
    }

    #[test]
    fn migration_bytes_counts_params_and_both_moments() {
        // d=32, h=64: (32*64 + 64 + 64*32 + 32) f32 params, x3 copies.
        assert_eq!(migration_bytes_per_expert(32, 64), (2048 + 64 + 2048 + 32) * 4 * 3);
    }

    #[test]
    fn nic_peak_of_counts_matrix() {
        // 2 nodes x 2 ranks; everything flows to rank 0.
        let counts = vec![
            vec![9, 0, 0, 0], // self: crosses nothing
            vec![7, 0, 0, 0], // intra-node
            vec![5, 0, 0, 0], // inter
            vec![3, 0, 0, 0], // inter
        ];
        // Node 0 inbound = 5 + 3 = 8 rows; node 1 outbound = 8 rows.
        assert_eq!(max_node_nic_bytes(&counts, 2, 4), 8 * 4);
        assert_eq!(max_node_nic_bytes(&counts, 4, 4), 0, "one node: no NIC");
        assert_eq!(PlacementPolicy::parse("adaptive").unwrap(), PlacementPolicy::Adaptive);
        assert_eq!(PlacementPolicy::default(), PlacementPolicy::Static);
        assert!(PlacementPolicy::parse("nope").is_err());
    }
}
