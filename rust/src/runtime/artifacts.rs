//! Artifact registry: discovers `artifacts/*.hlo.txt` and the shapes
//! recorded in `artifacts/meta.json` by `python/compile/aot.py`.

use crate::error::{HetuError, Result};
use crate::util::json::Json;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Metadata for one compiled artifact (one jitted function).
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub name: String,
    /// Path to the HLO text file.
    pub path: PathBuf,
    /// Input shapes in argument order (row-major dims).
    pub inputs: Vec<Vec<usize>>,
    /// Output shapes (the function returns a tuple of these).
    pub outputs: Vec<Vec<usize>>,
    /// Free-form attributes emitted by aot.py (model dims, vocab, ...).
    pub attrs: BTreeMap<String, f64>,
}

impl ArtifactMeta {
    fn from_json(name: &str, dir: &Path, obj: &Json) -> Result<ArtifactMeta> {
        let shapes = |key: &str| -> Result<Vec<Vec<usize>>> {
            let arr = obj
                .req(key)?
                .as_arr()
                .ok_or_else(|| HetuError::Json(format!("{name}.{key} must be an array")))?;
            arr.iter()
                .map(|s| {
                    s.as_arr()
                        .ok_or_else(|| {
                            HetuError::Json(format!("{name}.{key} entries must be arrays"))
                        })?
                        .iter()
                        .map(|d| {
                            d.as_usize().ok_or_else(|| {
                                HetuError::Json(format!("{name}.{key}: bad dim"))
                            })
                        })
                        .collect()
                })
                .collect()
        };
        let mut attrs = BTreeMap::new();
        if let Some(Json::Obj(pairs)) = obj.get("attrs") {
            for (k, v) in pairs {
                if let Some(x) = v.as_f64() {
                    attrs.insert(k.clone(), x);
                }
            }
        }
        Ok(ArtifactMeta {
            name: name.to_string(),
            path: dir.join(format!("{name}.hlo.txt")),
            inputs: shapes("inputs")?,
            outputs: shapes("outputs")?,
            attrs,
        })
    }

    /// Attribute lookup with error context.
    pub fn attr(&self, key: &str) -> Result<f64> {
        self.attrs.get(key).copied().ok_or_else(|| {
            HetuError::Artifact(format!("artifact '{}' missing attr '{key}'", self.name))
        })
    }

    pub fn attr_usize(&self, key: &str) -> Result<usize> {
        Ok(self.attr(key)? as usize)
    }
}

/// All artifacts in a directory.
#[derive(Clone, Debug)]
pub struct ArtifactRegistry {
    pub dir: PathBuf,
    metas: BTreeMap<String, ArtifactMeta>,
}

impl ArtifactRegistry {
    /// Load `dir/meta.json` and index the artifacts it describes.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactRegistry> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        if !meta_path.exists() {
            return Err(HetuError::Artifact(format!(
                "{} not found",
                meta_path.display()
            )));
        }
        let root = Json::from_file(&meta_path)?;
        let mut metas = BTreeMap::new();
        if let Json::Obj(pairs) = &root {
            for (name, obj) in pairs {
                metas.insert(name.clone(), ArtifactMeta::from_json(name, &dir, obj)?);
            }
        } else {
            return Err(HetuError::Json("meta.json root must be an object".into()));
        }
        Ok(ArtifactRegistry { dir, metas })
    }

    /// Look up one artifact; verifies the HLO file exists.
    pub fn get(&self, name: &str) -> Result<&ArtifactMeta> {
        let meta = self.metas.get(name).ok_or_else(|| {
            HetuError::Artifact(format!(
                "artifact '{name}' not in meta.json (have: {:?})",
                self.names()
            ))
        })?;
        if !meta.path.exists() {
            return Err(HetuError::Artifact(format!(
                "{} listed in meta.json but file missing",
                meta.path.display()
            )));
        }
        Ok(meta)
    }

    pub fn names(&self) -> Vec<&str> {
        self.metas.keys().map(String::as_str).collect()
    }

    pub fn len(&self) -> usize {
        self.metas.len()
    }

    pub fn is_empty(&self) -> bool {
        self.metas.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn write_registry(dir: &Path, meta: &str, files: &[&str]) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("meta.json"), meta).unwrap();
        for f in files {
            let mut fh = std::fs::File::create(dir.join(f)).unwrap();
            writeln!(fh, "HloModule dummy").unwrap();
        }
    }

    #[test]
    fn loads_and_indexes() {
        let dir = std::env::temp_dir().join("hetu_test_artifacts_1");
        write_registry(
            &dir,
            r#"{
              "gate": {"inputs": [[4, 8]], "outputs": [[4, 2]],
                       "attrs": {"num_experts": 8}}
            }"#,
            &["gate.hlo.txt"],
        );
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert_eq!(reg.len(), 1);
        let m = reg.get("gate").unwrap();
        assert_eq!(m.inputs, vec![vec![4, 8]]);
        assert_eq!(m.outputs, vec![vec![4, 2]]);
        assert_eq!(m.attr_usize("num_experts").unwrap(), 8);
        assert!(m.attr("missing").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_and_file_errors_mention_make_artifacts() {
        let err = ArtifactRegistry::load("/nonexistent/dir").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));

        let dir = std::env::temp_dir().join("hetu_test_artifacts_2");
        write_registry(
            &dir,
            r#"{"ghost": {"inputs": [], "outputs": []}}"#,
            &[],
        );
        let reg = ArtifactRegistry::load(&dir).unwrap();
        assert!(reg.get("ghost").is_err()); // file missing
        assert!(reg.get("unknown").is_err()); // not in meta
        std::fs::remove_dir_all(&dir).ok();
    }
}
