//! PJRT client wrapper and compiled-executable cache.
//!
//! Follows the pattern validated in `/opt/xla-example/load_hlo`: HLO text
//! → `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `PjRtClient::compile` → `execute`. Training keeps parameters resident
//! as device buffers and uses `execute_b` so the step loop never copies
//! weights through the host.

use crate::error::{HetuError, Result};
use crate::runtime::artifacts::{ArtifactMeta, ArtifactRegistry};
use crate::tensor::Tensor;
use std::collections::HashMap;

/// A compiled artifact ready to run.
pub struct HloRunner {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

impl HloRunner {
    /// Execute with host tensors; returns host tensors (tuple flattened).
    ///
    /// Inputs are uploaded as owned `PjRtBuffer`s and run through
    /// `execute_b`: the crate's literal-taking `execute()` leaks every
    /// uploaded input buffer (its C shim never frees them), which is
    /// fatal for large, repeated calls (see `train::trainer`).
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(HetuError::Runtime(format!(
                "artifact '{}' wants {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            )));
        }
        let client = self.exe.client();
        let mut bufs = Vec::with_capacity(inputs.len());
        for (i, (t, shape)) in inputs.iter().zip(&self.meta.inputs).enumerate() {
            if t.shape() != shape.as_slice() {
                return Err(HetuError::Runtime(format!(
                    "artifact '{}' input {i}: shape {:?} expected {:?}",
                    self.meta.name,
                    t.shape(),
                    shape
                )));
            }
            bufs.push(client.buffer_from_host_buffer(t.data(), t.shape(), None)?);
        }
        let out = self.run_buffers(&bufs)?;
        drop(bufs);
        let lit = out.to_literal_sync()?;
        self.from_tuple(lit)
    }

    /// Execute with raw literals (callers that manage their own literal
    /// types, e.g. the trainer's i32 token batches). Returns the single
    /// (tuple) output literal.
    pub fn execute_literals<L: std::borrow::Borrow<xla::Literal>>(
        &self,
        args: &[L],
    ) -> Result<xla::Literal> {
        let result = self.exe.execute(args)?;
        Ok(result[0][0].to_literal_sync()?)
    }

    /// Execute with device buffers (no host copies of the inputs);
    /// returns the raw output buffer for chaining.
    pub fn run_buffers(&self, inputs: &[xla::PjRtBuffer]) -> Result<xla::PjRtBuffer> {
        let mut result = self.exe.execute_b::<xla::PjRtBuffer>(inputs)?;
        Ok(result.remove(0).remove(0))
    }

    /// Upload host tensors as input literals, validating shapes against
    /// the artifact metadata.
    pub fn to_literals(&self, inputs: &[Tensor]) -> Result<Vec<xla::Literal>> {
        if inputs.len() != self.meta.inputs.len() {
            return Err(HetuError::Runtime(format!(
                "artifact '{}' wants {} inputs, got {}",
                self.meta.name,
                self.meta.inputs.len(),
                inputs.len()
            )));
        }
        inputs
            .iter()
            .zip(&self.meta.inputs)
            .enumerate()
            .map(|(i, (t, shape))| {
                if t.shape() != shape.as_slice() {
                    return Err(HetuError::Runtime(format!(
                        "artifact '{}' input {i}: shape {:?} expected {:?}",
                        self.meta.name,
                        t.shape(),
                        shape
                    )));
                }
                let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
                Ok(xla::Literal::vec1(t.data()).reshape(&dims)?)
            })
            .collect()
    }

    /// Unpack a (possibly tuple) output literal into host tensors.
    pub fn from_tuple(&self, out: xla::Literal) -> Result<Vec<Tensor>> {
        let parts = out.to_tuple()?;
        if parts.len() != self.meta.outputs.len() {
            return Err(HetuError::Runtime(format!(
                "artifact '{}' returned {} outputs, meta says {}",
                self.meta.name,
                parts.len(),
                self.meta.outputs.len()
            )));
        }
        parts
            .into_iter()
            .zip(&self.meta.outputs)
            .map(|(lit, shape)| {
                let v = lit.to_vec::<f32>()?;
                Tensor::from_vec(v, shape)
            })
            .collect()
    }

    /// Unpack the output buffer of [`Self::run_buffers`] to host tensors.
    pub fn buffer_to_tensors(&self, buf: &xla::PjRtBuffer) -> Result<Vec<Tensor>> {
        let lit = buf.to_literal_sync()?;
        self.from_tuple(lit)
    }
}

/// PJRT CPU client + executable cache over an artifact registry.
pub struct RuntimeClient {
    pub client: xla::PjRtClient,
    pub registry: ArtifactRegistry,
    cache: HashMap<String, std::sync::Arc<HloRunner>>,
}

impl RuntimeClient {
    /// Create a CPU PJRT client over `artifact_dir`.
    pub fn cpu(artifact_dir: &str) -> Result<RuntimeClient> {
        let registry = ArtifactRegistry::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(RuntimeClient { client, registry, cache: HashMap::new() })
    }

    /// Load + compile an artifact (cached).
    pub fn runner(&mut self, name: &str) -> Result<std::sync::Arc<HloRunner>> {
        if let Some(r) = self.cache.get(name) {
            return Ok(r.clone());
        }
        let meta = self.registry.get(name)?.clone();
        let proto = xla::HloModuleProto::from_text_file(&meta.path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let runner = std::sync::Arc::new(HloRunner { meta, exe });
        self.cache.insert(name.to_string(), runner.clone());
        Ok(runner)
    }

    /// Upload a host tensor to a device buffer.
    pub fn upload(&self, t: &Tensor) -> Result<xla::PjRtBuffer> {
        let dims: Vec<usize> = t.shape().to_vec();
        Ok(self
            .client
            .buffer_from_host_buffer(t.data(), &dims, None)?)
    }

    /// Platform description for logs.
    pub fn platform(&self) -> String {
        format!(
            "{} ({} devices)",
            self.client.platform_name(),
            self.client.device_count()
        )
    }
}

// Tests for this module live in `tests/runtime_integration.rs`; they need
// real artifacts (built by `make artifacts`) and a PJRT client, which we
// keep out of the unit-test path.
