//! PJRT runtime: load AOT-compiled HLO artifacts and execute them.
//!
//! `make artifacts` runs `python/compile/aot.py` once; it writes
//! `artifacts/<name>.hlo.txt` (HLO **text** — xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos, see DESIGN.md) plus `artifacts/meta.json`
//! describing shapes. This module is the only place the coordinator
//! touches XLA: everything above works with [`crate::tensor::Tensor`].
//!
//! The executing half ([`client`]) needs the `xla` crate and is gated
//! behind the `pjrt` cargo feature; artifact discovery stays available
//! in every build so `hetumoe info` can inventory a checkout.

pub mod artifacts;
#[cfg(feature = "pjrt")]
pub mod client;

pub use artifacts::{ArtifactMeta, ArtifactRegistry};
#[cfg(feature = "pjrt")]
pub use client::{HloRunner, RuntimeClient};
